#!/usr/bin/env python
"""DIGEST-A under heterogeneity (paper Fig. 7): one straggler worker with
an 8-10 s delay; async training sails past the synchronous barrier.

  PYTHONPATH=src python examples/async_straggler.py
"""
from repro.core import (AsyncSettings, digest_a_train, prepare_graph_data,
                        sync_time_per_round)
from repro.graph import make_dataset
from repro.models.gnn import GNNConfig
from repro.optim import adam


def main():
    g = make_dataset("flickr-sim", scale=0.3)
    data = prepare_graph_data(g, 4)
    cfg = GNNConfig(model="gcn", num_layers=3,
                    in_dim=g.features.shape[1], hidden_dim=64,
                    num_classes=int(g.labels.max()) + 1)
    settings = AsyncSettings(sync_interval=10, straggler=0, seed=7)
    _, hist = digest_a_train(cfg, adam(5e-3), data, settings,
                             total_rounds=240, eval_every_rounds=60)
    t_sync = sync_time_per_round(settings, 4)
    t_async = hist["sim_time"][-1] / hist["round"][-1]
    print(f"{'round':>6s} {'sim_t(s)':>9s} {'val F1':>7s} {'delay':>6s}")
    for r, t, f1, d in zip(hist["round"], hist["sim_time"],
                           hist["val_f1"], hist["delay"]):
        print(f"{r:6d} {t:9.1f} {f1:7.4f} {d:6d}")
    print(f"\nper-round: async {t_async:.2f}s vs sync barrier "
          f"{t_sync:.2f}s -> {t_sync/t_async:.1f}x faster under the "
          f"straggler (paper Fig. 7 behaviour)")


if __name__ == "__main__":
    main()
