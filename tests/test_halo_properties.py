"""Property-based (hypothesis) guarantees of the halo wire formats.

Pin the two quantitative claims the HaloExchange docs make:

  * int8 wire: a push→pull round trip perturbs each element by at most
    scale/2 = max|row|/254 (symmetric per-row quantization, round to
    nearest); bf16 by at most 2^-8·|x| (half-ulp of an 8-bit mantissa).
  * error feedback (``push_ef``): after ANY push sequence, the served
    (dequantized) value plus the carried residual telescopes to the
    exact fp32 history — per step ``deq_t + e_t = reps_t + e_{t-1}``,
    cumulatively ``Σ deq_t + e_T = Σ reps_t`` — so repeated pushes of
    slowly-moving representations stay unbiased at 1-byte wire cost.

Uses the real ``hypothesis`` when installed (CI); otherwise the
deterministic stand-in from conftest (same given/settings API).
"""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.core import halo_exchange as hx
from repro.core.halo_exchange import HaloPrecision
import pytest

pytestmark = pytest.mark.leg("m16-ppd2-hlo")

L1 = 2


def _make_store_and_rows(hidden, rows, seed, storage, amp_log2):
    """A synthetic single-part owner-sharded store: slots [0, rows) owned
    by part 0, sentinel at row ``rows`` — no graph build needed."""
    rng = np.random.default_rng(seed)
    reps = (rng.normal(size=(1, L1, rows, hidden))
            * 2.0 ** amp_log2).astype(np.float32)
    store = hx.init_store(L1, rows, hidden, HaloPrecision(storage))
    slots = jnp.arange(rows, dtype=jnp.int32)[None]
    valid = jnp.ones((1, rows), bool)
    sent = jnp.asarray([rows], jnp.int32)
    return store, reps, slots, valid, sent


@settings(max_examples=15, deadline=None)
@given(hidden=st.integers(1, 48), rows=st.integers(1, 12),
       seed=st.integers(0, 2 ** 16), amp_log2=st.integers(-8, 8))
def test_int8_roundtrip_error_bounded_by_half_scale(hidden, rows, seed,
                                                    amp_log2):
    store, reps, slots, valid, sent = _make_store_and_rows(
        hidden, rows, seed, "int8", amp_log2)
    store = hx.push(store, slots, valid, jnp.asarray(reps), sent)
    served = np.asarray(hx.pull(store, slots))          # (1, L1, rows, h)
    scale = np.abs(reps).max(axis=-1, keepdims=True) / 127.0
    err = np.abs(served - reps)
    # Half-scale per element, plus fp32 headroom for the divide/multiply.
    bound = scale / 2 * (1 + 1e-5) + 1e-12
    assert (err <= bound).all(), float((err - bound).max())


@settings(max_examples=15, deadline=None)
@given(hidden=st.integers(1, 48), rows=st.integers(1, 12),
       seed=st.integers(0, 2 ** 16), amp_log2=st.integers(-8, 8))
def test_bf16_roundtrip_error_bounded_by_half_ulp(hidden, rows, seed,
                                                  amp_log2):
    store, reps, slots, valid, sent = _make_store_and_rows(
        hidden, rows, seed, "bf16", amp_log2)
    store = hx.push(store, slots, valid, jnp.asarray(reps), sent)
    served = np.asarray(hx.pull(store, slots))
    err = np.abs(served - reps)
    assert (err <= np.abs(reps) * 2.0 ** -8 + 1e-30).all()


@settings(max_examples=10, deadline=None)
@given(hidden=st.integers(1, 32), rows=st.integers(1, 8),
       steps=st.integers(1, 8), seed=st.integers(0, 2 ** 16),
       storage=st.sampled_from(["int8", "bf16"]))
def test_error_feedback_residual_telescopes_exactly(hidden, rows, steps,
                                                    seed, storage):
    rng = np.random.default_rng(seed)
    store = hx.init_store(L1, rows, hidden, HaloPrecision(storage))
    slots = jnp.arange(rows, dtype=jnp.int32)[None]
    # A fixed random valid mask: invalid rows must stay 0/0 throughout.
    valid_np = rng.random((1, rows)) < 0.8
    valid = jnp.asarray(valid_np)
    sent = jnp.asarray([rows], jnp.int32)
    residual = jnp.zeros((1, L1, rows, hidden), jnp.float32)

    sum_true = np.zeros((1, L1, rows, hidden), np.float64)
    sum_served = np.zeros((1, L1, rows, hidden), np.float64)
    for _ in range(steps):
        reps = rng.normal(size=(1, L1, rows, hidden)).astype(np.float32)
        prev_residual = np.asarray(residual)
        store, residual = hx.push_ef(store, slots, valid,
                                     jnp.asarray(reps), residual, sent)
        served = np.asarray(hx.pull(store, slots))
        mask = valid_np[:, None, :, None]
        # Per-step: served + residual == reps + previous residual (the
        # quantizer's rounding is fully captured by the carried term).
        np.testing.assert_allclose(
            np.where(mask, served + np.asarray(residual), 0.0),
            np.where(mask, reps + prev_residual, 0.0),
            rtol=1e-6, atol=1e-7)
        # Invalid rows are never served and carry no residual.
        assert np.all(np.where(mask, 0.0, served) == 0.0)
        assert np.all(np.where(mask, 0.0, np.asarray(residual)) == 0.0)
        sum_true += np.where(mask, reps, 0.0)
        sum_served += np.where(mask, served, 0.0)
    # Telescoped: the cumulative served signal plus the final residual is
    # the exact fp32 update history (float64 accumulation on the host so
    # the comparison itself adds no noise).
    np.testing.assert_allclose(
        sum_served + np.where(valid_np[:, None, :, None],
                              np.asarray(residual), 0.0),
        sum_true, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(hidden=st.integers(1, 32), rows=st.integers(1, 8),
       seed=st.integers(0, 2 ** 16))
def test_ef_time_average_converges_at_scale_over_steps(hidden, rows,
                                                       seed):
    """The unbiasedness payoff: pushing the SAME row T times with error
    feedback leaves a time-averaged served value within ~scale/(2T) of
    the truth (the telescoped residual: avg − true = (e_0 − e_T)/T), an
    O(T) improvement over the plain push's persistent scale/2 rounding
    bias."""
    rng = np.random.default_rng(seed)
    base = rng.normal(size=(1, L1, rows, hidden)).astype(np.float32)
    slots = jnp.arange(rows, dtype=jnp.int32)[None]
    valid = jnp.ones((1, rows), bool)
    sent = jnp.asarray([rows], jnp.int32)
    steps = 16

    ef = hx.init_store(L1, rows, hidden, HaloPrecision("int8"))
    residual = jnp.zeros_like(jnp.asarray(base))
    avg_ef = np.zeros(base.shape, np.float64)
    for _ in range(steps):
        ef, residual = hx.push_ef(ef, slots, valid, jnp.asarray(base),
                                  residual, sent)
        avg_ef += np.asarray(hx.pull(ef, slots)) / steps
    err_ef = np.abs(avg_ef - base).max()
    # The compensated rows' amax (hence the adaptive per-push scale)
    # stays within ~half a quantization step of the input's amax.
    scale_bound = (np.abs(base).max() / 127.0) * 1.1 + 1e-9
    assert err_ef <= scale_bound / 2 / steps * 1.5 + 1e-6, \
        (err_ef, scale_bound)
