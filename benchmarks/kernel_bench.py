"""Kernel micro-benchmarks (CPU host timings of the jnp paths; the Pallas
TPU kernels are validated in interpret mode and characterized structurally
in the roofline — wall-clock kernel timing needs real hardware).

The resident-vs-streaming halo_spmm pair runs both Pallas variants in
interpret mode on an identical int8 slab: the numbers are Python-
interpreter timings (not TPU wall clock) but pin the structural cost of
chunking — and, more importantly, that the streaming path handles a slab
several chunks long while the resident path parks it whole in VMEM."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import halo_exchange as hx
from repro.graph.generators import community_powerlaw_graph
from repro.graph.partition import build_chunk_worklist, build_partitions
from repro.kernels.flash_attention import multi_head_attention
from repro.kernels.spmm import (SKIP_OCCUPANCY_MAX, halo_spmm_pallas,
                                halo_spmm_skip_pallas,
                                halo_spmm_stream_pallas, spmm)
from repro.models.attention import chunked_attention


def _occupancy_sweep(rng) -> list[dict]:
    """Dense-stream vs chunk-skipping stream on synthetic slabs whose
    (row_block × chunk) occupancy is pinned at 5/25/75%: each 128-row
    block references slots confined to its own random subset of chunks.
    Reports chunks-visited and bytes-streamed next to wall time — the
    structural claim is that the skip stream's DMA traffic follows
    occupancy while the dense stream always pays row_blocks × n_chunks
    chunks (interpret-mode wall clock is Python-loop bound, so the byte
    counts are the hardware-relevant signal)."""
    rows_out, deg, feat, chunk, n_chunks = 512, 8, 128, 128, 16
    ntab = n_chunks * chunk                      # 2048-row int8 slab
    n_blocks = rows_out // 128
    slab = rng.normal(size=(ntab, feat)).astype(np.float32)
    slab[-1] = 0
    data, scale = hx.quantize_rows(jnp.asarray(slab),
                                   hx.HaloPrecision("int8"))
    data = jnp.asarray(np.asarray(data).copy())
    # One streamed chunk tile: int8 stripe + fp32 scale column per row.
    chunk_bytes = chunk * (feat * 1 + 4)
    wts = jnp.asarray(rng.random((rows_out, deg)), jnp.float32)
    stm = jax.jit(lambda a, b, c, d: halo_spmm_stream_pallas(
        a, b, c, d, chunk_rows=chunk, interpret=True))
    rows = []
    for pct in (5, 25, 75):
        k = max(int(round(n_chunks * pct / 100)), 1)
        nbr = np.empty((rows_out, deg), np.int64)
        for b in range(n_blocks):
            mine = rng.choice(n_chunks, size=k, replace=False)
            base = mine[rng.integers(0, k, (128, deg))] * chunk
            nbr[b * 128:(b + 1) * 128] = base + rng.integers(
                0, chunk, (128, deg))
        nbr = jnp.asarray(np.minimum(nbr, ntab - 2), jnp.int32)
        wl = build_chunk_worklist(np.asarray(nbr), ntab, chunk)
        skp = jax.jit(lambda a, b, c, d, i, n: halo_spmm_skip_pallas(
            a, b, c, d, wl_ids=i, wl_cnt=n, chunk_rows=chunk,
            interpret=True))
        ids, cnt = jnp.asarray(wl.ids), jnp.asarray(wl.cnt)
        np.testing.assert_array_equal(
            np.asarray(skp(nbr, wts, data, scale, ids, cnt)),
            np.asarray(stm(nbr, wts, data, scale)))
        rows.append({
            "name": f"kernel/halo_spmm_stream_dense_occ{pct:02d}",
            "us_per_call": round(time_call(stm, nbr, wts, data, scale), 1),
            "chunks_visited": n_blocks * n_chunks,
            "bytes_streamed": n_blocks * n_chunks * chunk_bytes})
        rows.append({
            "name": f"kernel/halo_spmm_stream_skip_occ{pct:02d}",
            "us_per_call": round(time_call(skp, nbr, wts, data, scale,
                                           ids, cnt), 1),
            "chunks_visited": wl.visited_chunks,
            "bytes_streamed": wl.visited_chunks * chunk_bytes})
    return rows


def _order_sweep() -> list[dict]:
    """Ordered-vs-unordered locality on a REAL graph (not the synthetic
    pinned-occupancy slabs above): the same community power-law graph is
    partitioned with order="none" and order="rcm" and the resulting
    stacked chunk worklists compared — chunks visited, bytes streamed per
    layer (int8 slab convention of the sweep above) and, decisively,
    which streaming backend ``halo_spmm``'s static selection picks at
    the measured occupancy.  The structural claim recorded here: RCM
    drops occupancy across the SKIP_OCCUPANCY_MAX crossover, so the
    chunk-skipping kernel is auto-selected where the identity layout
    still pays the dense stream.  us_per_call is the host-side
    partition+ordering build time (the cost of the locality pass)."""
    chunk, feat, M = 256, 128, 8
    g = community_powerlaw_graph(num_nodes=40000, seed=0,
                                 name="bench-powerlaw")
    chunk_bytes = chunk * (feat * 1 + 4)
    rows = []
    for order in ("none", "rcm"):
        t0 = time.perf_counter()
        sp = build_partitions(g, M, halo_weight=0.25, order=order,
                              order_chunk_rows=chunk)
        dt = (time.perf_counter() - t0) * 1e6
        wl = sp.chunk_worklist(chunk)
        backend = ("pallas_skip" if wl.occupancy <= SKIP_OCCUPANCY_MAX
                   else "pallas_stream")
        rows.append({
            "name": f"kernel/halo_spmm_order_{order}",
            "us_per_call": round(dt, 1),
            "occupancy": round(wl.occupancy, 4),
            "chunks_visited": wl.visited_chunks,
            "chunks_total": wl.total_pairs,
            "bytes_streamed": wl.visited_chunks * chunk_bytes,
            "selected_backend": backend})
    return rows


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    rows = []
    # SpMM: aggregation for a 4096-node subgraph, deg 16, d=128.
    nbr = jnp.asarray(rng.integers(0, 4097, (4096, 16)), jnp.int32)
    wts = jnp.asarray(rng.random((4096, 16)), jnp.float32)
    tab = jnp.asarray(rng.normal(size=(4097, 128)), jnp.float32)
    f = jax.jit(lambda a, b, c: spmm(a, b, c, backend="jnp"))
    rows.append({"name": "kernel/spmm_4096x16x128",
                 "us_per_call": round(time_call(f, nbr, wts, tab), 1)})
    # Resident vs streaming fused halo pull+aggregate (interpret mode)
    # over a 2048-row int8 slab — 4 chunks of 512 for the streaming path.
    h_nbr = jnp.asarray(rng.integers(0, 2048, (128, 8)), jnp.int32)
    h_wts = jnp.asarray(rng.random((128, 8)), jnp.float32)
    slab = jnp.asarray(rng.normal(size=(2048, 128)), jnp.float32)
    data, scale = hx.quantize_rows(slab, hx.HaloPrecision("int8"))
    data = data.at[-1].set(0)
    res = jax.jit(lambda a, b, c, d: halo_spmm_pallas(
        a, b, c, d, interpret=True))
    stm = jax.jit(lambda a, b, c, d: halo_spmm_stream_pallas(
        a, b, c, d, chunk_rows=512, interpret=True))
    rows.append({"name": "kernel/halo_spmm_resident_2048x128_int8",
                 "us_per_call": round(time_call(res, h_nbr, h_wts, data,
                                                scale), 1)})
    rows.append({"name": "kernel/halo_spmm_stream_2048x128_int8",
                 "us_per_call": round(time_call(stm, h_nbr, h_wts, data,
                                                scale), 1)})
    # Dense vs chunk-skipping stream across pinned occupancies.
    rows.extend(_occupancy_sweep(rng))
    # Ordered vs unordered layout on a real community power-law graph.
    rows.extend(_order_sweep())
    # Attention 2x1024x8x64.
    q = jnp.asarray(rng.normal(size=(2, 1024, 8, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(2, 1024, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(2, 1024, 2, 64)), jnp.bfloat16)
    g = jax.jit(lambda a, b, c: multi_head_attention(a, b, c,
                                                     backend="jnp"))
    rows.append({"name": "kernel/attn_dense_1k",
                 "us_per_call": round(time_call(g, q, k, v), 1)})
    h = jax.jit(lambda a, b, c: chunked_attention(a, b, c, chunk=256))
    rows.append({"name": "kernel/attn_chunked_1k",
                 "us_per_call": round(time_call(h, q, k, v), 1)})
    return rows


if __name__ == "__main__":
    emit(run())
