"""SAT staleness-alleviated prediction: history purity, the "none"
contract, crash-safe resume, and the collective census arithmetic.

Pins the predictor PR's guarantees:

  * **History purity** — ``update_history`` is a pure function of the
    accepted-push sequence: replaying the same (reps, ok) sequence is
    bitwise reproducible, masked parts freeze every history leaf, and
    the online-learned coefficient starts at exactly 0 (the first
    pushes emit all-zero pstore rows — raw-stale pulls until the
    history has explained past motion).
  * **Coefficient learning** — on a linear trajectory (constant
    per-sync delta) the least-squares fit is exactly 1, the β-EMA
    coefficient climbs toward it, and applying the emitted rows
    strictly reduces the next sync's staleness error.
  * **The "none" contract** — ``kind="none"`` creates NO predictor
    leaves and its γ/β knobs are inert: runs with different disabled
    configs are bitwise identical on both engines (SPMD epoch loop and
    the DIGEST-A simulator).  An *enabled* predictor with γ = 0 keeps
    params and store bitwise equal to the predictor-free run while the
    history leaves exist and advance — the prediction epilogue is
    exactly additive.
  * **Exact resume** — kill-and-resume restores the pstore + history
    leaves from the checksummed checkpoint bitwise.
  * **Census arithmetic** — on the compiled 8-device collective epoch
    the pstore rides the existing exchange: all_to_all grows by exactly
    one op per pstore tensor (×2 under int8), all-gather / permute /
    reduce-scatter stay ZERO, and the GAT dedup program is unchanged
    (prediction folded shard-locally before projection).
"""
import functools
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AsyncSettings, PredictorConfig, TrainSettings,
                        digest_a_train, digest_train, predictor,
                        prepare_graph_data)
from repro.graph import make_dataset
from repro.models.gnn import GNNConfig
from repro.optim import adam

pytestmark = pytest.mark.leg("sat-smoke")


@functools.lru_cache(maxsize=None)
def _graph(seed: int = 0):
    return make_dataset("flickr-sim", scale=0.12, seed=seed)


def _cfg(g, model="gcn", num_layers=2, hidden=32):
    return GNNConfig(model=model, num_layers=num_layers,
                     in_dim=g.features.shape[1], hidden_dim=hidden,
                     num_classes=int(g.labels.max()) + 1, heads=2)


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        jnp.array_equal(x, y) for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# History transition: purity, masking, zero-start
# ---------------------------------------------------------------------------

def _reps_seq(key, n, shape):
    return [jax.random.normal(k, shape) for k in jax.random.split(key, n)]


def test_history_update_is_pure_and_masked():
    M, L1, S, H = 3, 2, 5, 4
    cfg = PredictorConfig(kind="ema", beta=0.5)
    seq = _reps_seq(jax.random.PRNGKey(0), 6, (M, L1, S, H))
    oks = [jnp.array([True, True, False]), jnp.array([True, False, True]),
           jnp.array([True, True, True])] * 2

    def replay():
        hist = predictor.init_history(M, L1, S, H)
        rows = []
        for reps, ok in zip(seq, oks):
            hist, r = predictor.update_history(hist, reps, ok, cfg)
            rows.append(r)
        return hist, rows

    h1, r1 = replay()
    h2, r2 = replay()
    # Pure: same accepted-push sequence → bitwise-identical history and
    # emitted rows.
    assert _leaves_equal(h1, h2) and _leaves_equal(r1, r2)
    # count tallies exactly the accepted pushes per part.
    want = np.sum([np.asarray(ok) for ok in oks], axis=0)
    assert np.array_equal(np.asarray(h1["count"]), want)

    # A masked part freezes EVERY history leaf at that event.
    hist = predictor.init_history(M, L1, S, H)
    for reps, ok in zip(seq[:3], oks[:3]):
        hist, _ = predictor.update_history(
            hist, reps, jnp.ones((M,), bool), cfg)
    frozen, _ = predictor.update_history(
        hist, seq[3], jnp.array([True, False, True]), cfg)
    for leaf in ("prev", "ema", "coef", "count"):
        assert jnp.array_equal(frozen[leaf][1], hist[leaf][1]), leaf
    assert not jnp.array_equal(frozen["prev"][0], hist["prev"][0])


@pytest.mark.parametrize("kind", ["delta", "ema"])
def test_first_pushes_emit_zero_rows(kind):
    # The coefficient starts at 0 and the first delta is gated, so the
    # first two pushes predict NOTHING — pulls stay bitwise raw-stale.
    M, L1, S, H = 2, 1, 4, 3
    cfg = PredictorConfig(kind=kind)
    hist = predictor.init_history(M, L1, S, H)
    ok = jnp.ones((M,), bool)
    for reps in _reps_seq(jax.random.PRNGKey(1), 2, (M, L1, S, H)):
        hist, rows = predictor.update_history(hist, reps, ok, cfg)
        assert not jnp.any(rows), rows
    assert not jnp.any(hist["coef"])


def test_coef_learns_linear_trajectory():
    # reps_t = t·v: every per-sync delta equals v, the least-squares fit
    # of realized change against the previous push's base rows is
    # exactly 1, and the β-EMA coefficient climbs 0 → 0.5 → 0.75 → ...
    M, L1, S, H = 2, 2, 4, 3
    cfg = PredictorConfig(kind="delta", beta=0.5)
    v = jax.random.normal(jax.random.PRNGKey(2), (M, L1, S, H))
    hist = predictor.init_history(M, L1, S, H)
    ok = jnp.ones((M,), bool)
    coefs, rows = [], None
    for t in range(1, 7):
        hist, rows = predictor.update_history(hist, t * v, ok, cfg)
        coefs.append(float(hist["coef"].min()))
    assert coefs[0] == coefs[1] == 0.0          # no evidence yet
    assert all(b > a for a, b in zip(coefs[2:], coefs[3:]))
    assert coefs[-1] == pytest.approx(1.0, abs=0.1)
    # Applying the emitted rows strictly reduces next-sync staleness:
    # |reps_7 − (reps_6 + rows)| < |reps_7 − reps_6|.
    raw_err = jnp.linalg.norm(7 * v - 6 * v)
    pred_err = jnp.linalg.norm(7 * v - (6 * v + rows))
    assert pred_err < 0.2 * raw_err, (pred_err, raw_err)
    # The coefficient is clipped into [COEF_MIN, COEF_MAX] even when the
    # trajectory reverses violently (fit would be far below -1).
    hist2, _ = predictor.update_history(hist, -100 * v, ok, cfg)
    assert jnp.all(hist2["coef"] >= predictor.COEF_MIN)
    assert jnp.all(hist2["coef"] <= predictor.COEF_MAX)


def test_config_validation():
    with pytest.raises(ValueError):
        PredictorConfig(kind="linear")
    with pytest.raises(ValueError):
        PredictorConfig(kind="ema", beta=0.0)
    assert not PredictorConfig().enabled
    assert PredictorConfig(kind="ema").enabled


# ---------------------------------------------------------------------------
# The "none" contract + γ=0 additivity, on both engines
# ---------------------------------------------------------------------------

def _spmd_run(pcfg, epochs=8):
    g = _graph()
    data = prepare_graph_data(g, 4)
    settings = TrainSettings(sync_interval=2, mode="digest",
                             predictor=pcfg)
    return digest_train(_cfg(g), adam(5e-3), data, settings, epochs,
                        eval_every=epochs)


def test_none_is_inert_and_gamma0_additive_spmd():
    base, base_hist = _spmd_run(PredictorConfig())
    assert "pstore" not in base and "predictor" not in base
    # kind="none" ignores γ/β entirely — bitwise-identical run, no
    # predictor leaves.
    off, _ = _spmd_run(PredictorConfig(kind="none", gamma=7.0, beta=0.9))
    assert _leaves_equal(base, off)
    # Enabled predictor, γ=0: the consume-side epilogue adds exactly
    # γ·pstore, so params/store/cache stay bitwise equal while the
    # history leaves exist and advance.
    g0, g0_hist = _spmd_run(PredictorConfig(kind="ema", gamma=0.0))
    for key in ("params", "store", "cache", "opt_state"):
        assert _leaves_equal(base[key], g0[key]), key
    assert base_hist["loss"] == g0_hist["loss"]
    assert {"pstore", "predictor", "pcache"} <= set(g0)
    assert int(g0["predictor"]["count"].min()) > 0


def test_none_is_inert_and_gamma0_additive_async():
    g = _graph()
    data = prepare_graph_data(g, 4)
    cfg = _cfg(g)
    base = dict(sync_interval=4, straggler=0, seed=3)

    def run(pcfg):
        return digest_a_train(cfg, adam(5e-3), data,
                              AsyncSettings(predictor=pcfg, **base),
                              total_rounds=24, eval_every_rounds=24)

    s_plain, h_plain = run(PredictorConfig())
    assert "pstore" not in s_plain
    s_off, _ = run(PredictorConfig(kind="none", gamma=7.0, beta=0.9))
    assert _leaves_equal(s_plain, s_off)
    s_g0, h_g0 = run(PredictorConfig(kind="ema", gamma=0.0))
    assert _leaves_equal(s_plain["params"], s_g0["params"])
    assert h_plain["loss"] == h_g0["loss"]
    assert h_plain["round_worker"] == h_g0["round_worker"]
    assert "pstore" in s_g0
    # An enabled γ>0 run actually diverges once predictions land —
    # the parity above is additivity, not a dead code path.
    s_on, _ = run(PredictorConfig(kind="ema"))
    assert not _leaves_equal(s_plain["params"], s_on["params"])


# ---------------------------------------------------------------------------
# Crash-safe resume with the history leaves
# ---------------------------------------------------------------------------

def test_kill_and_resume_bitwise_with_history(tmp_path):
    g = _graph()
    data = prepare_graph_data(g, 4)
    cfg = _cfg(g)
    settings = TrainSettings(sync_interval=2, mode="digest",
                             predictor=PredictorConfig(kind="ema"))
    full, _ = digest_train(cfg, adam(5e-3), data, settings, 10,
                           eval_every=10,
                           ckpt_dir=str(tmp_path / "a"), ckpt_every=2)
    # "Kill" after 6 epochs, then resume the SAME invocation to 10.
    digest_train(cfg, adam(5e-3), data, settings, 6, eval_every=6,
                 ckpt_dir=str(tmp_path / "b"), ckpt_every=2)
    resumed, _ = digest_train(cfg, adam(5e-3), data, settings, 10,
                              eval_every=10,
                              ckpt_dir=str(tmp_path / "b"), ckpt_every=2,
                              resume=True)
    # Bitwise — including the pstore and every predictor history leaf.
    assert {"pstore", "predictor", "pcache"} <= set(resumed)
    assert _leaves_equal(full, resumed)


# ---------------------------------------------------------------------------
# Compiled-HLO census arithmetic on the 8-device collective epoch
# ---------------------------------------------------------------------------

def _census_checks():
    import hlo_utils
    from repro.launch.mesh import make_host_mesh

    D = 8
    assert jax.device_count() >= D, jax.device_count()
    mesh = make_host_mesh(data=D)
    g = make_dataset("flickr-sim", scale=0.1, seed=5)
    pcfg = PredictorConfig(kind="ema")

    # gcn raw-store pull: +1 all_to_all per pstore tensor (data, or
    # data+scale under int8); still zero all-gather / permute / rs.
    for storage in ("fp32", "int8"):
        compiled = hlo_utils.compile_epoch(
            g, D, mesh, storage=storage, pull_mode="collective",
            predictor=pcfg)
        c = hlo_utils.collective_counts(compiled.as_text())
        label = f"gcn {storage} predictor"
        assert c["all-gather"] == 0, (label, c)
        assert c["collective-permute"] == 0, (label, c)
        assert c["reduce-scatter"] == 0, (label, c)
        want = hlo_utils.expected_all_to_all(storage, predictor=True)
        base = hlo_utils.expected_all_to_all(storage)
        assert want == 2 * base          # the arithmetic being pinned
        assert c["all-to-all"] == want, (label, c)

    # GAT dedup: prediction folds into the owner-shard projection, the
    # pulled z tensors are unchanged — the census must EQUAL the
    # predictor-free program's op-for-op.
    for storage in ("fp32", "int8"):
        on = hlo_utils.collective_counts(hlo_utils.compile_epoch(
            g, D, mesh, storage=storage, pull_mode="collective",
            model="gat", predictor=pcfg).as_text())
        off = hlo_utils.collective_counts(hlo_utils.compile_epoch(
            g, D, mesh, storage=storage, pull_mode="collective",
            model="gat").as_text())
        assert on == off, (storage, on, off)
        assert on["all-to-all"] == hlo_utils.expected_all_to_all(
            storage, model="gat", predictor=True), (storage, on)


@pytest.mark.forced_devices(8)
def test_predictor_hlo_census_inprocess():
    _census_checks()


def test_predictor_hlo_census_subprocess():
    """Force an 8-device CPU platform in a subprocess so the census
    arithmetic is checked even on single-device hosts."""
    if jax.device_count() >= 8:
        pytest.skip("covered by the in-process variant")
    import hlo_utils
    hlo_utils.run_forced_device_subprocess(__file__, "SAT_CENSUS_OK")


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    _census_checks()
    print("SAT_CENSUS_OK")
