"""Analytic communication/time model — paper §3.3 complexity, with hardware
constants — used for the speedup tables (Table 1 / Fig. 4 / Fig. 5) since
this container has no real interconnect to measure.

Per-epoch communication:
  partition:    params only                      O(M·|W|)
  digest:       params + (pull halo + push local)·d·(L-1)/N    [amortized]
  propagation:  params + fresh k-hop halos every epoch, k = 1..L-1
                (neighbor explosion: the ℓ-th layer's exact recompute needs
                 the ℓ-hop halo)

Hardware constants default to TPU v5e (DESIGN.md §5); the GPU testbed of the
paper can be modeled by swapping constants.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.halo_exchange import HaloPrecision
from repro.graph.graph import Graph
from repro.graph.partition import StackedPartitions


@dataclasses.dataclass(frozen=True)
class CommConstants:
    link_bandwidth: float = 50e9      # bytes/s per ICI link (v5e ~50 GB/s)
    flops: float = 197e12             # bf16 peak per chip
    bytes_per_scalar: int = 4


def khop_halo_sizes(g: Graph, sp: StackedPartitions, k_max: int
                    ) -> np.ndarray:
    """(M, k_max) — size of the k-hop halo of each subgraph (BFS on host)."""
    M = sp.num_parts
    out = np.zeros((M, k_max), np.int64)
    assign = np.full(g.num_nodes, -1, np.int64)
    for m in range(M):
        loc = sp.local_ids[m][sp.local_valid[m]]
        assign[loc] = m
    for m in range(M):
        frontier = set(sp.local_ids[m][sp.local_valid[m]].tolist())
        visited = set(frontier)
        halo_total: set = set()
        for k in range(k_max):
            nxt = set()
            for v in frontier:
                for u in g.neighbors(int(v)):
                    if u not in visited:
                        visited.add(u)
                        nxt.add(int(u))
            halo_total |= nxt
            out[m, k] = len(halo_total)
            frontier = nxt
    return out


def epoch_comm_bytes(mode: str, sp: StackedPartitions, g: Graph,
                     param_count: int, hidden: int, num_layers: int,
                     sync_interval: int = 10,
                     consts: CommConstants = CommConstants(),
                     halo_precision: Optional[HaloPrecision] = None
                     ) -> float:
    """Per-epoch wire bytes.  ``halo_precision`` (digest only) swaps the
    §3.3 pull/push terms onto the HaloExchange wire format: compact
    boundary rows in fp32/bf16/int8(+scale) instead of dense fp32 — the
    2–4× reduction reported by ``benchmarks/comm_complexity.py``."""
    B = consts.bytes_per_scalar
    M = sp.num_parts
    params_bytes = 2.0 * M * param_count * B           # broadcast + reduce
    L1 = max(num_layers - 1, 0)
    if mode == "partition":
        return params_bytes
    halo1 = sp.halo_valid.sum(axis=1).astype(np.float64)       # (M,)
    local = sp.local_valid.sum(axis=1).astype(np.float64)
    if mode == "digest":
        if halo_precision is not None:
            rb = halo_precision.row_bytes(hidden)
            pull = float(sp.pull_rows()) * L1 * rb
            push = float(sp.push_rows()) * L1 * rb
        else:
            pull = float(halo1.sum()) * hidden * L1 * B
            push = float(local.sum()) * hidden * L1 * B
        return params_bytes + (pull + push) / sync_interval
    if mode == "propagation":
        khop = khop_halo_sizes(g, sp, L1) if L1 else np.zeros((M, 0))
        fresh = float(khop.sum()) * hidden * B
        return params_bytes + fresh
    raise ValueError(mode)


def epoch_time_model(mode: str, sp: StackedPartitions, g: Graph,
                     param_count: int, hidden: int, num_layers: int,
                     feature_dim: int, sync_interval: int = 10,
                     consts: CommConstants = CommConstants()) -> dict:
    """Compute + communication per-epoch time under the analytic model."""
    M = sp.num_parts
    S = float(sp.local_valid.sum(axis=1).max())
    deg = float((sp.in_wts > 0).sum() + (sp.out_wts > 0).sum()) / max(
        sp.local_valid.sum(), 1)
    # Per-device FLOPs: L·(aggregation 2·S·deg·d + dense 2·S·d·d).
    d = hidden
    flops = num_layers * (2 * S * deg * d + 2 * S * max(d, feature_dim) * d)
    t_compute = flops / consts.flops
    comm = epoch_comm_bytes(mode, sp, g, param_count, hidden, num_layers,
                            sync_interval, consts)
    t_comm = comm / (M * consts.link_bandwidth)
    return {"bytes": comm, "t_compute": t_compute, "t_comm": t_comm,
            "t_epoch": t_compute + t_comm}
