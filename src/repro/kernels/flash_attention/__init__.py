from repro.kernels.flash_attention.flash_attention import (
    flash_attention_pallas)
from repro.kernels.flash_attention.ops import multi_head_attention
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["attention_ref", "flash_attention_pallas",
           "multi_head_attention"]
