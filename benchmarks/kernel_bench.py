"""Kernel micro-benchmarks (CPU host timings of the jnp paths; the Pallas
TPU kernels are validated in interpret mode and characterized structurally
in the roofline — wall-clock kernel timing needs real hardware)."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.kernels.flash_attention import multi_head_attention
from repro.kernels.spmm import spmm
from repro.models.attention import chunked_attention


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    rows = []
    # SpMM: aggregation for a 4096-node subgraph, deg 16, d=128.
    nbr = jnp.asarray(rng.integers(0, 4097, (4096, 16)), jnp.int32)
    wts = jnp.asarray(rng.random((4096, 16)), jnp.float32)
    tab = jnp.asarray(rng.normal(size=(4097, 128)), jnp.float32)
    f = jax.jit(lambda a, b, c: spmm(a, b, c, backend="jnp"))
    rows.append({"name": "kernel/spmm_4096x16x128",
                 "us_per_call": round(time_call(f, nbr, wts, tab), 1)})
    # Attention 2x1024x8x64.
    q = jnp.asarray(rng.normal(size=(2, 1024, 8, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(2, 1024, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(2, 1024, 2, 64)), jnp.bfloat16)
    g = jax.jit(lambda a, b, c: multi_head_attention(a, b, c,
                                                     backend="jnp"))
    rows.append({"name": "kernel/attn_dense_1k",
                 "us_per_call": round(time_call(g, q, k, v), 1)})
    h = jax.jit(lambda a, b, c: chunked_attention(a, b, c, chunk=256))
    rows.append({"name": "kernel/attn_chunked_1k",
                 "us_per_call": round(time_call(h, q, k, v), 1)})
    return rows


if __name__ == "__main__":
    emit(run())
