"""O(E) partitioner equivalence + locality-ordering invariance.

Three contracts of the PR-6 partition layer:

  * the replica-array ``greedy_partition`` (O(E) memory) reproduces the
    retired dense-``is_halo`` formulation's assignments exactly, for
    ``halo_weight = 0`` (bit-identical score path) AND ``> 0`` (the
    replica arrays maintain the same membership the (M, N) bool matrix
    did) — the dense reference lives in this file, nowhere else;
  * ``build_partitions(order="rcm")`` is a pure permutation of each
    part's local rows: RCM output is a valid permutation, stacked
    worklist occupancy never increases (guarded per part), and training
    is invariant — per-row quantities (the pushed owner-sharded store,
    keyed by global id) are **bitwise** equal across orders for
    gcn/sage/gat, trajectories equal to tight tolerance (cross-row
    reductions reassociate under XLA, so exact equality is only defined
    per-row), across gather and collective pull modes;
  * ``partition_report`` exposes the locality columns and
    ``random_partition`` warns when its no-op ``halo_weight`` is set.

The collective/multi-pod legs need >= 8 forced host devices
(REPRO_HOST_DEVICES=8, same as tests/test_multipod.py) and skip
elsewhere.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (TrainSettings, evaluate, init_state, make_epoch_fn,
                        prepare_graph_data)
from repro.graph import (build_partitions, community_powerlaw_graph,
                         greedy_partition, make_dataset, partition_report,
                         random_partition, reverse_cuthill_mckee, sbm_graph)
from repro.models.gnn import GNNConfig
from repro.optim import adam

pytestmark = pytest.mark.leg("m16-ppd2-hlo")


# ---------------------------------------------------------------------------
# Dense reference for the streaming partitioner (the retired formulation)
# ---------------------------------------------------------------------------

def _dense_greedy(g, num_parts, seed=0, slack=1.05, halo_weight=0.0):
    """The pre-PR-6 greedy_partition: identical score, but halo
    membership in a dense (num_parts, num_nodes) bool matrix."""
    n = g.num_nodes
    rng = np.random.default_rng(seed)
    capacity = slack * n / num_parts
    assign = np.full(n, -1, np.int32)
    sizes = np.zeros(num_parts, np.int64)

    order = np.empty(n, np.int64)
    seen = np.zeros(n, bool)
    pos = 0
    for root in rng.permutation(n):
        if seen[root]:
            continue
        queue = [root]
        seen[root] = True
        while queue:
            v = queue.pop()
            order[pos] = v
            pos += 1
            for u in g.neighbors(v):
                if not seen[u]:
                    seen[u] = True
                    queue.append(u)
    assert pos == n

    is_halo = np.zeros((num_parts, n), bool) if halo_weight else None
    for v in order:
        nbrs = g.neighbors(v)
        counts = np.zeros(num_parts, np.float64)
        assigned = assign[nbrs]
        valid = assigned >= 0
        anbrs = nbrs[valid]
        if valid.any():
            np.add.at(counts, assigned[valid], 1.0)
        score = counts * (1.0 - sizes / capacity)
        if halo_weight:
            present = counts > 0
            pen = np.full(num_parts, float(present.sum()))
            pen -= present
            if len(anbrs):
                au = assign[anbrs]
                fresh = ~is_halo[:, anbrs]
                out_of_p = au[None, :] != np.arange(num_parts)[:, None]
                pen += (fresh & out_of_p).sum(axis=1)
            score = score - halo_weight * pen
            score[sizes >= capacity] = -np.inf
        score += 1e-9 * (capacity - sizes)
        best = int(np.argmax(score))
        assign[v] = best
        sizes[best] += 1
        if halo_weight and len(anbrs):
            au = assign[anbrs]
            other = au != best
            is_halo[au[other], v] = True
            is_halo[best, anbrs[other]] = True
    return assign


@pytest.mark.parametrize("halo_weight", [0.0, 0.1, 0.25, 0.5])
def test_streaming_greedy_matches_dense_reference(halo_weight):
    for g, M in [(make_dataset("flickr-sim", scale=0.25), 4),
                 (sbm_graph(600, num_classes=6, seed=3), 6),
                 (community_powerlaw_graph(800, num_comm=8, seed=2), 4)]:
        want = _dense_greedy(g, M, halo_weight=halo_weight)
        got = greedy_partition(g, M, halo_weight=halo_weight)
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"{g.name} hw={halo_weight}")


def test_streaming_greedy_no_dense_matrix(monkeypatch):
    """The O(E) path must never allocate an array with a num_parts×n
    (or larger) bool/row footprint — the point of the rewrite.  Guarded
    by intercepting np.zeros, the only constructor the dense matrix ever
    used."""
    g = make_dataset("flickr-sim", scale=0.25)
    M = 16
    limit = M * g.num_nodes
    real_zeros = np.zeros

    def checked_zeros(shape, *a, **k):
        size = int(np.prod(shape)) if np.ndim(shape) else int(shape)
        assert size < limit, f"dense-scale allocation {shape}"
        return real_zeros(shape, *a, **k)

    monkeypatch.setattr(np, "zeros", checked_zeros)
    assign = greedy_partition(g, M, halo_weight=0.25)
    assert len(np.unique(assign)) == M


# ---------------------------------------------------------------------------
# RCM ordering: valid permutation, occupancy never increases
# ---------------------------------------------------------------------------

def test_rcm_is_valid_permutation():
    g = sbm_graph(400, num_classes=4, seed=0)
    perm = reverse_cuthill_mckee(g.indptr, g.indices)
    assert len(perm) == g.num_nodes
    assert np.array_equal(np.sort(perm), np.arange(g.num_nodes))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(80, 400), classes=st.integers(2, 8),
       parts=st.sampled_from([2, 4, 8]), seed=st.integers(0, 5),
       chunk_rows=st.sampled_from([64, 128, 512]))
def test_rcm_never_increases_occupancy(n, classes, parts, seed,
                                       chunk_rows):
    g = sbm_graph(n, num_classes=classes, avg_degree=8.0, seed=seed)
    a = build_partitions(g, parts, halo_weight=0.25, order="none",
                         order_chunk_rows=chunk_rows)
    b = build_partitions(g, parts, halo_weight=0.25, order="rcm",
                         order_chunk_rows=chunk_rows)
    # Pure permutation of the local rows, per part.
    np.testing.assert_array_equal(a.assign, b.assign)
    for m in range(parts):
        np.testing.assert_array_equal(
            np.sort(a.local_ids[m][a.local_valid[m]]),
            np.sort(b.local_ids[m][b.local_valid[m]]))
        np.testing.assert_array_equal(
            np.sort(a.halo_ids[m][a.halo_valid[m]]),
            np.sort(b.halo_ids[m][b.halo_valid[m]]))
    occ_a = a.chunk_worklist(chunk_rows).occupancy
    occ_b = b.chunk_worklist(chunk_rows).occupancy
    assert occ_b <= occ_a + 1e-12
    assert b.order == "rcm" and a.order == "none"


def test_rcm_reduces_occupancy_on_community_graph():
    """On a community-structured graph the ordering must actually WIN,
    not just not-lose — this is the crossover the kernel selection
    rides (benchmarks/kernel_bench.py records it on the full-size
    graph)."""
    g = community_powerlaw_graph(8000, num_comm=80, seed=0)
    a = build_partitions(g, 8, halo_weight=0.25, order="none",
                         order_chunk_rows=256)
    b = build_partitions(g, 8, halo_weight=0.25, order="rcm",
                         order_chunk_rows=256)
    occ_a = a.chunk_worklist(256).occupancy
    occ_b = b.chunk_worklist(256).occupancy
    assert occ_b < occ_a, (occ_a, occ_b)


def test_build_partitions_rejects_unknown_order():
    g = sbm_graph(200, seed=0)
    with pytest.raises(ValueError, match="order"):
        build_partitions(g, 2, order="sorted")


# ---------------------------------------------------------------------------
# Training invariance across order= none / rcm
# ---------------------------------------------------------------------------

def _train(g, order, model, pull_mode="gather", mesh=None, parts=4,
           epochs=2):
    data = prepare_graph_data(g, parts, halo_weight=0.25, order=order)
    cfg = GNNConfig(model=model, num_layers=3, in_dim=g.features.shape[1],
                    hidden_dim=32, num_classes=int(g.labels.max()) + 1)
    opt = adam(5e-3)
    settings_ = TrainSettings(sync_interval=2, mode="digest",
                              pull_mode=pull_mode)
    state = init_state(cfg, opt, data)
    fn = jax.jit(make_epoch_fn(cfg, opt, settings_, mesh=mesh))
    tdata = {k: v for k, v in data.items() if not k.startswith("_")}
    metrics = None
    for _ in range(epochs):
        state, metrics = fn(state, tdata)
    ev = evaluate(cfg, state["params"], tdata)
    return {"store": np.asarray(state["store"]["data"]),
            "loss": float(metrics["loss"]),
            "val_f1": float(ev["val_f1"]),
            "sp": data["_sp"]}


@pytest.mark.parametrize("model", ["gcn", "sage", "gat"])
def test_order_invariant_training_gather(model):
    g = community_powerlaw_graph(2000, num_comm=20, seed=1)
    a = _train(g, "none", model)
    b = _train(g, "rcm", model)
    # The pushed owner-sharded store is keyed by global id (slot =
    # owner·shard_rows + rank), per-row, sentinels re-zeroed — bitwise
    # equal across layouts with NO un-permutation needed; this is the
    # strongest per-row trajectory pin XLA admits (cross-row reductions
    # such as the loss mean reassociate under a row permutation).
    np.testing.assert_array_equal(a["store"], b["store"])
    # evaluate() runs the order-independent full (M=1) view: bitwise.
    assert a["val_f1"] == b["val_f1"]
    tol = 1e-6 if model == "gat" else 1e-5
    assert abs(a["loss"] - b["loss"]) <= tol


def test_order_invariant_rows_unpermute():
    """Per-part local ids are the same set across orders and the stored
    per-id labels/masks follow the permutation — un-permuting by global
    id recovers identical per-node tables."""
    g = community_powerlaw_graph(1500, num_comm=15, seed=4)
    a = build_partitions(g, 4, halo_weight=0.25, order="none")
    b = build_partitions(g, 4, halo_weight=0.25, order="rcm")
    for m in range(4):
        ia = a.local_ids[m][a.local_valid[m]]
        ib = b.local_ids[m][b.local_valid[m]]
        inv_a, inv_b = np.argsort(ia), np.argsort(ib)
        np.testing.assert_array_equal(ia[inv_a], ib[inv_b])
        np.testing.assert_array_equal(
            a.labels[m][a.local_valid[m]][inv_a],
            b.labels[m][b.local_valid[m]][inv_b])
        np.testing.assert_array_equal(
            a.train_mask[m][a.local_valid[m]][inv_a],
            b.train_mask[m][b.local_valid[m]][inv_b])


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs REPRO_HOST_DEVICES=8 forced devices")
@pytest.mark.parametrize("model", ["gcn", "sage"])
def test_order_invariant_training_collective(model):
    from repro.launch.mesh import make_host_mesh

    g = community_powerlaw_graph(2000, num_comm=20, seed=1)
    mesh = make_host_mesh(data=8)
    a = _train(g, "none", model, pull_mode="collective", mesh=mesh,
               parts=8)
    b = _train(g, "rcm", model, pull_mode="collective", mesh=mesh,
               parts=8)
    np.testing.assert_array_equal(a["store"], b["store"])
    assert a["val_f1"] == b["val_f1"]
    assert abs(a["loss"] - b["loss"]) <= 1e-5


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs REPRO_HOST_DEVICES=8 forced devices")
def test_order_invariant_training_multipod():
    from repro.launch.mesh import make_host_mesh

    g = community_powerlaw_graph(2000, num_comm=20, seed=1)
    mesh = make_host_mesh(pod=2, data=4, model=1)
    a = _train(g, "none", "gcn", pull_mode="collective", mesh=mesh,
               parts=8)
    b = _train(g, "rcm", "gcn", pull_mode="collective", mesh=mesh,
               parts=8)
    np.testing.assert_array_equal(a["store"], b["store"])
    assert a["val_f1"] == b["val_f1"]
    assert abs(a["loss"] - b["loss"]) <= 1e-5


# ---------------------------------------------------------------------------
# Satellites: report columns, random_partition warning
# ---------------------------------------------------------------------------

def test_partition_report_locality_columns():
    g = make_dataset("flickr-sim", scale=0.25)
    sp = build_partitions(g, 4, order="rcm")
    rep = partition_report(g, sp, chunk_rows=128, row_bytes=100)
    for k in ("wl_occupancy", "wl_visited", "wl_total",
              "stream_bytes_skip", "stream_bytes_dense", "order"):
        assert k in rep, k
    assert rep["order"] == "rcm"
    assert 0.0 < rep["wl_occupancy"] <= 1.0
    assert rep["wl_visited"] <= rep["wl_total"]
    assert rep["stream_bytes_skip"] == rep["wl_visited"] * 128 * 100
    assert rep["stream_bytes_dense"] == rep["wl_total"] * 128 * 100
    assert (rep["stream_bytes_skip"] / rep["stream_bytes_dense"]
            == pytest.approx(rep["wl_occupancy"]))


def test_random_partition_warns_on_halo_weight():
    g = sbm_graph(200, seed=0)
    with pytest.warns(UserWarning, match="ignores halo_weight"):
        random_partition(g, 4, halo_weight=0.25)
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        random_partition(g, 4, halo_weight=0.0)   # no warning at 0
