"""Multi-pod collective halo exchange: equivalence + 2D-mesh HLO census.

``pull_mode="collective"`` auto-detects a mesh "pod" axis and runs the
two-stage exchange — intra-pod ragged ``all_to_all`` over "data", then
``pods - 1`` inter-pod ``ppermute`` rounds over "pod" (see the routing
section of ``repro.core.halo_exchange``).  On a forced 8-device host
shaped as ("pod", "data") = (2, 4), these tests pin down:

  * pulls, pushes and the Theorem-1 staleness probe are **bitwise**
    equal across the dense-gather fallback, the single-pod collective
    (flat data=8 mesh) and the multi-pod collective, for M in {8, 16}
    (k = parts/device in {1, 2}) in fp32 and int8;
  * two full epochs (PUSH at r=1, PULL at r=2) leave stores, pulled
    slabs and staleness maxima equal across single-device execution,
    the sharded gather fallback, the single-pod collective and the
    multi-pod collective — gcn/sage bitwise, gat to 1e-6;
  * the compiled multi-pod epoch's collective census, **per mesh
    axis**: the pull all-to-alls ride only "data" groups (intra-pod),
    the permutes only "pod" pairs (inter-pod), with exact counts
    (``expected_all_to_all`` / ``expected_collective_permute``) and
    ZERO all-gather / reduce-scatter anywhere;
  * an M that is not a multiple of pods·data raises the spelled-out
    ValueError from every collective entry point (and from
    ``check_collective_geometry``) instead of corrupting slot math.

Needs >= 8 forced host devices; on single-device hosts the subprocess
variant re-launches this file (same pattern as test_collective_ppd).
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.leg("multipod-2x4")


def _tree_equal(a: dict, b: dict, what: str = ""):
    assert sorted(a) == sorted(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=f"{what}[{k}]")


def _pod_mesh(pods: int = 2, data: int = 4):
    from repro.launch.mesh import make_host_mesh

    return make_host_mesh(pod=pods, data=data, model=1)


def _kvs_parity(g, M: int, pods: int, D: int):
    """collective_pull / shard_push / shard_staleness_error on the
    ("pod", "data") = (pods, D) mesh == the flat data=pods·D collective
    == the dense fallback forms, bitwise, with k = M/(pods·D) shards
    per device."""
    from repro.core import halo_exchange as hx
    from repro.core.halo_exchange import HaloPrecision
    from repro.graph import build_partitions
    from repro.launch.mesh import make_host_mesh

    pod_mesh = _pod_mesh(pods, D)
    flat_mesh = make_host_mesh(data=pods * D)
    assert hx.exchange_axes(pod_mesh) == ("pod", "data")
    assert hx.exchange_axes(flat_mesh) == ("data",)
    assert hx.exchange_size(pod_mesh) == pods * D

    sp = build_partitions(g, M)
    k = M // (pods * D)
    assert hx.shards_per_device(M, pod_mesh) == k
    L1, hid = 2, 32
    rng = np.random.default_rng(M * 131 + pods)
    reps = jnp.asarray(
        rng.normal(size=(M, L1, sp.part_size, hid)).astype(np.float32))
    slots = jnp.asarray(sp.local_slots)
    valid = jnp.asarray(sp.local_valid)
    sent = jnp.asarray(sp.sentinel_slots)
    boundary = jnp.asarray(sp.local_boundary)
    plan = sp.pull_plan()
    send = jnp.asarray(plan.send_offsets)
    recv = jnp.asarray(plan.recv_positions)

    for storage in ("fp32", "int8"):
        prec = HaloPrecision(storage)
        label = f"M={M} ({pods}x{D}) {storage}"
        store = hx.init_store(L1, sp.store_rows - 1, hid, prec)
        store = hx.push(store, slots, valid, reps, sent)

        want = hx.pull_slab(store, jnp.asarray(sp.halo_slots))
        got_pod = hx.collective_pull(store, send, recv, sp.halo_size,
                                     pod_mesh)
        got_flat = hx.collective_pull(store, send, recv, sp.halo_size,
                                      flat_mesh)
        _tree_equal(got_pod, want, f"pull-pod-vs-gather {label}")
        _tree_equal(got_pod, got_flat, f"pull-pod-vs-flat {label}")

        base = hx.init_store(L1, sp.store_rows - 1, hid, prec)
        via_spmd = hx.push(base, slots, valid, reps, sent)
        via_pod = hx.shard_push(base, slots, valid, reps, sp.shard_rows,
                                pod_mesh)
        via_flat = hx.shard_push(base, slots, valid, reps, sp.shard_rows,
                                 flat_mesh)
        _tree_equal(via_pod, via_spmd, f"push-pod-vs-spmd {label}")
        _tree_equal(via_pod, via_flat, f"push-pod-vs-flat {label}")

        fresh = jnp.asarray(
            rng.normal(size=reps.shape).astype(np.float32))
        eps_ref = hx.staleness_error(store, fresh, slots, boundary)
        eps_pod = hx.shard_staleness_error(store, fresh, slots, boundary,
                                           sp.shard_rows, pod_mesh)
        np.testing.assert_array_equal(np.asarray(eps_pod),
                                      np.asarray(eps_ref),
                                      err_msg=f"staleness {label}")


def _epoch_equivalence(g, M: int, model: str, storage: str, exact: bool):
    """Two epochs: post-epoch stores, the r=2 pulled slab and the r=1
    staleness maxima agree across single-device execution, the sharded
    gather fallback, the single-pod collective and the multi-pod
    collective (the acceptance check)."""
    import hlo_utils
    from repro.launch.mesh import make_host_mesh

    pod_mesh = _pod_mesh()
    flat_mesh = make_host_mesh(data=8)
    runs = {}
    for name, m, pull_mode in (("single", None, "gather"),
                               ("gather", pod_mesh, "gather"),
                               ("flat", flat_mesh, "collective"),
                               ("multipod", pod_mesh, "collective")):
        fn, state, tdata = hlo_utils.make_epoch(
            g, M, m, storage=storage, pull_mode=pull_mode, model=model)
        state, m1 = fn(state, tdata)     # r=1: PUSH fresh reps
        store1 = {k: np.asarray(v) for k, v in state["store"].items()}
        state, _ = fn(state, tdata)      # r=2: PULL the r=1 store
        runs[name] = {
            "store": store1,
            "slab": {k: np.asarray(v) for k, v in state["cache"].items()},
            "eps": np.asarray(m1["staleness_eps"]),
        }

    ref = runs["single"]
    for name in ("gather", "flat", "multipod"):
        got = runs[name]
        label = f"{model}/{storage} M={M} {name}"
        if exact:
            _tree_equal(got["store"], ref["store"], f"store {label}")
            _tree_equal(got["slab"], ref["slab"], f"slab {label}")
            np.testing.assert_array_equal(got["eps"], ref["eps"],
                                          err_msg=label)
        else:
            for part in ("store", "slab"):
                for k in ref[part]:
                    np.testing.assert_allclose(
                        got[part][k].astype(np.float32),
                        ref[part][k].astype(np.float32),
                        atol=1e-6, err_msg=f"{part} {label}")
    # Multi-pod vs the single-pod collective: bitwise on every model —
    # the two-stage exchange reorders only the transport, never values.
    _tree_equal(runs["multipod"]["store"], runs["flat"]["store"],
                f"store {model}/{storage} M={M} multipod-vs-flat")
    _tree_equal(runs["multipod"]["slab"], runs["flat"]["slab"],
                f"slab {model}/{storage} M={M} multipod-vs-flat")
    np.testing.assert_array_equal(runs["multipod"]["eps"],
                                  runs["flat"]["eps"],
                                  err_msg=f"{model} multipod-vs-flat eps")


def _hlo_census(g):
    """Per-axis census of the compiled multi-pod epoch: all-to-alls ride
    "data" only, permutes ride "pod" only, counts exact, zero
    all-gather / reduce-scatter; the gather fallback on the same mesh is
    the positive control (all-gathers, no all-to-all)."""
    import hlo_utils

    pods = 2
    mesh = _pod_mesh(pods, 4)
    for M, storage, model in ((8, "fp32", "gcn"), (16, "int8", "gcn"),
                              (8, "int8", "gat")):
        compiled = hlo_utils.compile_epoch(
            g, M, mesh, storage=storage, pull_mode="collective",
            model=model)
        text = compiled.as_text()
        c = hlo_utils.collective_counts(text)
        census = hlo_utils.collective_axis_census(text, mesh)
        label = f"multipod M={M} {model}/{storage}"
        assert c["all-gather"] == 0, (label, c)
        assert c["reduce-scatter"] == 0, (label, c)
        want_a2a = hlo_utils.expected_all_to_all(storage, model=model)
        want_cp = hlo_utils.expected_collective_permute(storage, pods,
                                                        model=model)
        assert c["all-to-all"] == want_a2a, (label, c)
        assert c["collective-permute"] == want_cp, (label, c)
        # Stage 1 must stay inside the pod, stage 2 must touch only the
        # pod axis — neither may widen to the combined axes.
        assert census["all-to-all"] == {("data",): want_a2a}, (
            label, census)
        assert census["collective-permute"] == {("pod",): want_cp}, (
            label, census)
        assert census["all-gather"] == {}, (label, census)
        assert sum(census["all-reduce"].values()) == c["all-reduce"] > 0, (
            label, census)

    compiled = hlo_utils.compile_epoch(g, 8, mesh, storage="fp32",
                                       pull_mode="gather")
    c = hlo_utils.collective_counts(compiled.as_text())
    assert c["all-gather"] > 0, c
    assert c["all-to-all"] == 0, c


def _mismatch_raises(g):
    """M not a multiple of pods·data → the spelled-out ValueError from
    every collective entry point; the message names both counts."""
    from repro.core import check_collective_geometry, prepare_graph_data
    from repro.core import halo_exchange as hx
    from repro.core.halo_exchange import HaloPrecision
    from repro.graph import build_partitions

    mesh = _pod_mesh(2, 4)                    # 8 exchange devices
    M = 12                                    # 12 % 8 != 0
    sp = build_partitions(g, M)
    plan = sp.pull_plan()
    store = hx.init_store(2, sp.store_rows - 1, 16, HaloPrecision())
    zeros = jnp.zeros((M, 2, sp.part_size, 16))
    for fn, args in (
            (hx.collective_pull, (store, jnp.asarray(plan.send_offsets),
                                  jnp.asarray(plan.recv_positions),
                                  sp.halo_size, mesh)),
            (hx.shard_push, (store, jnp.asarray(sp.local_slots),
                             jnp.asarray(sp.local_valid), zeros,
                             sp.shard_rows, mesh)),
            (hx.shard_staleness_error,
             (store, zeros, jnp.asarray(sp.local_slots),
              jnp.asarray(sp.local_boundary), sp.shard_rows, mesh))):
        with pytest.raises(ValueError) as e:
            fn(*args)
        msg = str(e.value)
        assert "num_parts=12" in msg and "8 devices" in msg, msg
        assert "pod" in msg, msg            # names the multi-pod layout
    data = prepare_graph_data(g, M)
    with pytest.raises(ValueError) as e:
        check_collective_geometry(data, mesh)
    assert "num_parts=12" in str(e.value), str(e.value)
    # Sanity: the same M works on a mesh whose axes it divides.
    assert check_collective_geometry(data, _pod_mesh(2, 2)) == 3


def _checks():
    from repro.graph import make_dataset

    assert jax.device_count() >= 8, jax.device_count()
    g = make_dataset("flickr-sim", scale=0.1, seed=11)

    for M in (8, 16):                         # k = 1 and 2 per device
        _kvs_parity(g, M, 2, 4)
    _mismatch_raises(g)
    _hlo_census(g)

    # Full-epoch equivalence incl. the acceptance case: multi-pod
    # collective bitwise-equal to the single-pod collective and the
    # gather fallback (gcn/sage; gat to 1e-6).
    _epoch_equivalence(g, 8, "gcn", "fp32", exact=True)
    _epoch_equivalence(g, 16, "sage", "int8", exact=True)
    _epoch_equivalence(g, 8, "gat", "fp32", exact=False)


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (CI REPRO_HOST_DEVICES=8 job)")
def test_multipod_collective_inprocess():
    _checks()


def test_multipod_collective_subprocess():
    """Force an 8-device CPU platform in a subprocess so the multi-pod
    paths are exercised even on single-device hosts."""
    if jax.device_count() >= 8:
        pytest.skip("covered by the in-process variant")
    import hlo_utils
    hlo_utils.run_forced_device_subprocess(__file__, "MULTIPOD_OK")


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    _checks()
    print("MULTIPOD_OK")
