#!/usr/bin/env python
"""Production training launcher.

On a real TPU fleet this runs under `python -m repro.launch.train` per host
with jax.distributed; on CPU it runs reduced configs end to end with the
same code path (mesh building, sharding rules, DIGEST pod sync,
checkpointing).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
      --steps 50 --sync-mode digest --n-pod 2
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_arch, get_smoke_arch
from repro.data import make_lm_pipeline
from repro.distributed.sharding import axis_rules
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.train import TrainSettings, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--sync-mode", default="every_step",
                    choices=["every_step", "digest"])
    ap.add_argument("--n-pod", type=int, default=1)
    ap.add_argument("--sync-interval", type=int, default=10)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the (16,16)/(2,16,16) v5e mesh (TPU fleet)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_arch(args.arch) if args.smoke else get_arch(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(cfg, vocab_size=min(cfg.vocab_size, 512))
    settings = TrainSettings(sync_mode=args.sync_mode, n_pod=args.n_pod,
                             sync_interval=args.sync_interval,
                             total_steps=args.steps,
                             warmup_steps=max(args.steps // 20, 2))
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.n_pod > 1)
    else:
        mesh = make_host_mesh(1, 1)

    with axis_rules(mesh, {"embed": "data"}):
        state = init_train_state(cfg, settings)
        if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
            state, start = restore_checkpoint(args.ckpt_dir, state)
            print(f"resumed from step {start}")
        step_fn = jax.jit(make_train_step(cfg, settings))
        data = make_lm_pipeline(cfg.vocab_size, args.batch, args.seq)
        n_params = sum(int(np.prod(l.shape))
                       for l in jax.tree.leaves(state["params"]))
        print(f"arch={cfg.name} params={n_params:,} mesh={dict(mesh.shape)} "
              f"sync={args.sync_mode}/{args.sync_interval}")
        t0 = time.perf_counter()
        for i in range(args.steps):
            b = next(data)
            state, m = step_fn(state, {"tokens": b.tokens,
                                       "labels": b.labels,
                                       "mask": b.mask})
            if (i + 1) % args.log_every == 0:
                print(f"step {int(state['step']):5d} "
                      f"loss={float(m['loss']):.4f} "
                      f"{(time.perf_counter()-t0)/(i+1):.3f}s/step",
                      flush=True)
        if args.ckpt_dir:
            save_checkpoint(args.ckpt_dir, int(state["step"]), state)
            print(f"saved {args.ckpt_dir}")


if __name__ == "__main__":
    main()
