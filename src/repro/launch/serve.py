#!/usr/bin/env python
"""Production serving launcher: batched KV-cache decode (optionally the
DIGEST stale-KV long-context mode).

  PYTHONPATH=src python -m repro.launch.serve --arch musicgen-large \
      --smoke --batch 4 --gen 16 [--long]
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_arch, get_smoke_arch
from repro.distributed.sharding import axis_rules
from repro.launch.mesh import make_host_mesh
from repro.launch.serving_driver import run_serve_loop
from repro.models.transformer import (arch_specs, init_cache,
                                      precompute_vision_cache)
from repro.nn import init_params
from repro.train import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--long", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_arch(args.arch) if args.smoke else get_arch(args.arch)
    if args.long:
        cfg = dataclasses.replace(cfg, long_window=32, long_ratio=8)
    mesh = make_host_mesh(1, 1)
    with axis_rules(mesh, {}):
        params = init_params(jax.random.PRNGKey(0), arch_specs(cfg))
        cache = init_cache(cfg, args.batch, args.max_seq, long=args.long)
        if cfg.vision_dim:
            vis = jax.random.normal(
                jax.random.PRNGKey(2),
                (args.batch, cfg.num_patches, cfg.vision_dim))
            cache = precompute_vision_cache(cfg, params, cache, vis)
        serve = jax.jit(make_serve_step(cfg, long=args.long))
        toks = jax.random.randint(jax.random.PRNGKey(1),
                                  (args.batch, 1), 0, cfg.vocab_size)

        def step_fn(carry, _):
            cache, toks = carry
            logits, cache = serve(params, cache, toks)
            return (cache, jnp.argmax(logits[:, -1:], axis=-1)), None

        _, _, stats = run_serve_loop(step_fn, range(args.gen),
                                     carry=(cache, toks), warmup=1,
                                     items_per_call=args.batch)
        print(f"arch={cfg.name} long={args.long} batch={args.batch}: "
              f"{stats.total_s/args.gen*1e3:.1f} ms/token "
              f"(steady p50 {stats.p50_ms:.1f} / p99 {stats.p99_ms:.1f} ms)"
              f" on {jax.default_backend()}")


if __name__ == "__main__":
    main()
