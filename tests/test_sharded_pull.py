"""Owner-sharded halo store: multi-device collective pull/push parity.

The core checks (`_multi_device_checks`) need 8 devices.  Under the CI
8-device job (REPRO_HOST_DEVICES=8, see conftest) they run in-process;
on a single-device host the subprocess test re-launches this file with
``--xla_force_host_platform_device_count=8`` so the collective paths are
exercised everywhere.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


def _tree_equal(a: dict, b: dict):
    assert sorted(a) == sorted(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def _multi_device_checks():
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    from repro.core import (TrainSettings, halo_exchange as hx, init_state,
                            make_epoch_fn, prepare_graph_data)
    from repro.core.halo_exchange import HaloPrecision, HaloSpec
    from repro.graph import build_partitions, make_dataset
    from repro.launch.mesh import make_host_mesh
    from repro.models.gnn import GNNConfig
    from repro.optim import adam

    M = 8
    assert jax.device_count() >= M, jax.device_count()
    mesh = make_host_mesh(data=M)
    g = make_dataset("flickr-sim", scale=0.12, seed=5)
    sp = build_partitions(g, M)
    L1, hid = 2, 32
    rng = np.random.default_rng(0)
    reps = rng.normal(size=(M, L1, sp.part_size, hid)).astype(np.float32)
    slots = jnp.asarray(sp.local_slots)
    valid = jnp.asarray(sp.local_valid)
    sent = jnp.asarray(sp.sentinel_slots)

    for storage in ("fp32", "int8"):
        prec = HaloPrecision(storage)
        store = hx.init_store(L1, sp.store_rows - 1, hid, prec)
        store = hx.push(store, slots, valid, jnp.asarray(reps), sent)

        # Owner-sharded placement: per-device residency is exactly 1/M.
        slot_sh = NamedSharding(mesh, P(None, "data", None))
        store = {k: jax.device_put(v, slot_sh) for k, v in store.items()}
        spec = HaloSpec.from_partitions(sp, hid, L1 + 1, prec)
        for v in store.values():
            shard_bytes = {s.data.nbytes for s in v.addressable_shards}
            assert shard_bytes == {v.nbytes // M}
        assert spec.shard_nbytes() == spec.store_nbytes() // M

        # Ragged collective pull == dense-gather pull, bitwise (both in
        # storage precision; gathers do no arithmetic).
        plan = sp.pull_plan()
        want = hx.pull_slab(store, jnp.asarray(sp.halo_slots))
        got = hx.collective_pull(store, jnp.asarray(plan.send_offsets),
                                 jnp.asarray(plan.recv_positions),
                                 sp.halo_size, mesh)
        _tree_equal(got, want)

        # Explicit shard-local push == SPMD push, bitwise.
        base = hx.init_store(L1, sp.store_rows - 1, hid, prec)
        via_spmd = hx.push(base, slots, valid, jnp.asarray(reps), sent)
        base_sh = {k: jax.device_put(v, slot_sh) for k, v in base.items()}
        via_shmap = hx.shard_push(base_sh, slots, valid, jnp.asarray(reps),
                                  sp.shard_rows, mesh)
        _tree_equal(via_shmap, via_spmd)

    # Training: collective-pull trajectory == gather-pull trajectory.
    data = prepare_graph_data(g, M)
    tdata = {k: v for k, v in data.items() if not k.startswith("_")}
    cfg = GNNConfig(model="gcn", num_layers=3, in_dim=g.features.shape[1],
                    hidden_dim=32, num_classes=int(g.labels.max()) + 1)
    opt = adam(5e-3)
    losses, finals = {}, {}
    for pull_mode in ("gather", "collective"):
        settings = TrainSettings(sync_interval=2, mode="digest",
                                 pull_mode=pull_mode,
                                 precision=HaloPrecision("int8"))
        state = init_state(cfg, opt, data, precision=settings.precision)
        fn = jax.jit(make_epoch_fn(cfg, opt, settings, mesh=mesh))
        ls = []
        for _ in range(5):
            state, m = fn(state, tdata)
            ls.append(float(m["loss"]))
        losses[pull_mode] = ls
        finals[pull_mode] = state
    # The pulled slabs are bitwise identical (asserted above); the whole
    # epoch *programs* differ (shard_map changes XLA scheduling of
    # unrelated fp ops), so trajectories agree to fp32 reassociation
    # tolerance rather than bit-for-bit.
    np.testing.assert_allclose(losses["gather"], losses["collective"],
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(finals["gather"]["store"]["data"], np.float32),
        np.asarray(finals["collective"]["store"]["data"], np.float32),
        atol=1)          # int8 codes may differ by 1 ulp of rounding

    # Checkpoint round-trip of the sharded store: save (host-gathers the
    # shards), restore into the template, re-place on the mesh.
    import tempfile
    state = finals["collective"]
    with tempfile.TemporaryDirectory() as tmp:
        save_checkpoint(tmp, 5, {"store": state["store"]},
                        meta={"halo_storage": "int8",
                              "shard_rows": sp.shard_rows,
                              "num_parts": M})
        restored, _ = restore_checkpoint(tmp, {"store": state["store"]})
        _tree_equal(restored["store"], state["store"])
        slot_sh = NamedSharding(mesh, P(None, "data", None))
        placed, _ = restore_checkpoint(
            tmp, {"store": state["store"]},
            sharding={"store": {k: slot_sh for k in state["store"]}})
        _tree_equal(placed["store"], state["store"])
        for v in placed["store"].values():
            assert len(v.addressable_shards) == M


def test_pull_slab_matches_manual_gather():
    """Single-device: pull_slab is exactly the per-subgraph gather of the
    store rows each halo slot references (plus the zero sentinel row)."""
    from repro.core import halo_exchange as hx
    from repro.graph import build_partitions, make_dataset

    g = make_dataset("flickr-sim", scale=0.1, seed=2)
    sp = build_partitions(g, 3)
    L1, hid = 2, 16
    rng = np.random.default_rng(1)
    reps = rng.normal(size=(sp.num_parts, L1, sp.part_size, hid)) \
        .astype(np.float32)
    store = hx.init_store(L1, sp.store_rows - 1, hid,
                          hx.HaloPrecision("int8"))
    store = hx.push(store, jnp.asarray(sp.local_slots),
                    jnp.asarray(sp.local_valid), jnp.asarray(reps),
                    jnp.asarray(sp.sentinel_slots))
    slab = hx.pull_slab(store, jnp.asarray(sp.halo_slots))
    H = sp.halo_size
    assert slab["data"].shape == (sp.num_parts, L1, H + 1, hid)
    for m in range(sp.num_parts):
        want = np.asarray(store["data"])[:, sp.halo_slots[m], :]
        np.testing.assert_array_equal(np.asarray(slab["data"][m, :, :H]),
                                      want)
        assert np.abs(np.asarray(slab["data"][m, :, H],
                                 np.float32)).max() == 0
        np.testing.assert_array_equal(
            np.asarray(slab["scale"][m, :, :H]),
            np.asarray(store["scale"])[:, sp.halo_slots[m], :])
    # Dequantized slab rows == the classic pull of the same slots.
    deq = hx.dequantize_rows(slab["data"], slab["scale"])
    classic = hx.pull(store, jnp.asarray(sp.halo_slots))
    np.testing.assert_array_equal(np.asarray(deq[:, :, :H]),
                                  np.asarray(classic))


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (CI REPRO_HOST_DEVICES=8 job)")
def test_sharded_collective_multidevice_inprocess():
    _multi_device_checks()


def test_sharded_collective_multidevice_subprocess():
    """Force an 8-device CPU platform in a subprocess so the collective
    pull/push paths are exercised even on single-device hosts."""
    if jax.device_count() >= 8:
        pytest.skip("covered by the in-process variant")
    import hlo_utils
    hlo_utils.run_forced_device_subprocess(__file__, "MULTI_DEVICE_OK")


if __name__ == "__main__":
    _multi_device_checks()
    print("MULTI_DEVICE_OK")
