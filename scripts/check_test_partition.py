"""Fail the build unless the CI legs exactly partition the test files.

The tier-1 matrix legs in .github/workflows/ci.yml select tests with
``pytest -m leg_<name>`` markers stamped from the tests/ci_legs.py
registry.  This script is the completeness gate behind that scheme:

  * the registry's per-leg file sets are pairwise disjoint;
  * every file the registry names exists under tests/;
  * every ``tests/test_*.py`` file maps to exactly one leg (files not
    claimed by a dedicated leg belong to the default collective-8dev
    leg);
  * an explicit ``pytestmark = pytest.mark.leg("...")`` declaration in
    a test file agrees with the registry — and every file a dedicated
    leg owns carries one, so ownership is visible in the file itself.

Pure source-level checks — no jax, no pytest plugins — so it runs in
the lint job in seconds.

  PYTHONPATH=src python scripts/check_test_partition.py
"""
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
TESTS = REPO / "tests"
sys.path.insert(0, str(TESTS))

from ci_legs import DEFAULT_LEG, LEGS, leg_for  # noqa: E402

_LEG_MARK = re.compile(
    r"^pytestmark\s*=.*pytest\.mark\.leg\(\s*['\"]([^'\"]+)['\"]\s*\)",
    re.MULTILINE)


def main() -> int:
    errors = []
    stems = sorted(p.stem for p in TESTS.glob("test_*.py"))

    # Registry names only real files, and no file is claimed twice.
    claimed = {}
    for leg, files in sorted(LEGS.items()):
        for stem in sorted(files):
            if stem not in stems:
                errors.append(f"{leg}: registry names missing file "
                              f"tests/{stem}.py")
            if stem in claimed:
                errors.append(f"tests/{stem}.py claimed by both "
                              f"'{claimed[stem]}' and '{leg}'")
            claimed[stem] = leg

    # Every test file lands on exactly one leg, and any in-file
    # declaration matches; dedicated-leg files must declare.
    partition = {leg: [] for leg in [DEFAULT_LEG, *LEGS]}
    for stem in stems:
        try:
            leg = leg_for(stem)
        except ValueError as e:            # duplicate claim (redundant
            errors.append(str(e))          # with the loop above, kept
            continue                       # for leg_for's own contract)
        partition[leg].append(stem)
        declared = _LEG_MARK.findall((TESTS / f"{stem}.py").read_text())
        if len(declared) > 1:
            errors.append(f"tests/{stem}.py declares multiple leg "
                          f"markers: {declared}")
        elif declared and declared[0] != leg:
            errors.append(f"tests/{stem}.py declares leg "
                          f"'{declared[0]}' but the registry assigns "
                          f"'{leg}'")
        elif not declared and leg != DEFAULT_LEG:
            errors.append(f"tests/{stem}.py is owned by '{leg}' but "
                          f"carries no pytestmark leg declaration")

    for leg, files in partition.items():
        print(f"{leg} ({len(files)}):")
        for stem in files:
            print(f"  tests/{stem}.py")
    if errors:
        print("\nPARTITION VIOLATIONS:", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    total = sum(len(v) for v in partition.values())
    print(f"\nOK: {total} test files partitioned across "
          f"{len(partition)} legs")
    return 0


if __name__ == "__main__":
    sys.exit(main())
