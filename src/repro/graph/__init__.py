from repro.graph.graph import (EllMatrix, Graph, coo_to_ell, from_edges,
                               gcn_norm_weights)
from repro.graph.partition import (ChunkWorklist, LOCAL_ORDERS, PullPlan,
                                   StackedPartitions, build_chunk_worklist,
                                   build_partitions, edge_cut,
                                   greedy_partition, partition_report,
                                   random_partition, reverse_cuthill_mckee)
from repro.graph.generators import (DATASETS, community_powerlaw_graph,
                                    make_dataset, powerlaw_graph, sbm_graph)
from repro.graph.sampler import NeighborSampler, build_sampler

__all__ = [
    "EllMatrix", "Graph", "coo_to_ell", "from_edges", "gcn_norm_weights",
    "ChunkWorklist", "LOCAL_ORDERS", "PullPlan", "StackedPartitions",
    "build_chunk_worklist", "build_partitions", "edge_cut",
    "greedy_partition", "partition_report", "random_partition",
    "reverse_cuthill_mckee", "DATASETS", "community_powerlaw_graph",
    "NeighborSampler", "build_sampler",
    "make_dataset", "powerlaw_graph", "sbm_graph",
]
