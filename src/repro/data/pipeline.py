"""Token data pipeline for the assigned language-model architectures.

Offline container → we synthesize deterministic pseudo-corpora: a Zipfian
unigram-with-bigram-structure stream (so losses actually *decrease* when the
model learns), chunked into (batch, seq) with next-token labels, with
double-buffered host prefetch.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TokenBatch:
    tokens: jax.Array   # (batch, seq) int32
    labels: jax.Array   # (batch, seq) int32 (next token)
    mask: jax.Array     # (batch, seq) float32


class SyntheticLMDataset:
    """Deterministic synthetic LM stream with learnable bigram structure."""

    def __init__(self, vocab_size: int, seed: int = 0,
                 n_states: int = 64):
        self.vocab_size = vocab_size
        rng = np.random.default_rng(seed)
        self.n_states = n_states
        # Markov chain over hidden states, each state emits a Zipf slice.
        self.trans = rng.dirichlet(np.ones(n_states) * 0.2, size=n_states)
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        zipf = 1.0 / ranks ** 1.1
        self.emit = np.stack([
            np.roll(zipf, rng.integers(vocab_size)) for _ in range(n_states)])
        self.emit /= self.emit.sum(axis=1, keepdims=True)

    def sample(self, rng: np.random.Generator, batch: int,
               seq: int) -> tuple[np.ndarray, np.ndarray]:
        states = rng.integers(self.n_states, size=batch)
        toks = np.empty((batch, seq + 1), np.int32)
        for t in range(seq + 1):
            # Vectorized categorical draws per row.
            u = rng.random(batch)
            cdf = np.cumsum(self.emit[states], axis=1)
            toks[:, t] = (u[:, None] > cdf).sum(axis=1)
            u2 = rng.random(batch)
            cdf2 = np.cumsum(self.trans[states], axis=1)
            states = (u2[:, None] > cdf2).sum(axis=1)
        return toks[:, :-1], toks[:, 1:]


def make_lm_pipeline(vocab_size: int, batch: int, seq: int,
                     seed: int = 0, prefetch: int = 2,
                     ) -> Iterator[TokenBatch]:
    """Host-threaded prefetching iterator of TokenBatch."""
    ds = SyntheticLMDataset(vocab_size, seed=seed)
    rng = np.random.default_rng(seed + 1)
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)

    def producer():
        while True:
            toks, labels = ds.sample(rng, batch, seq)
            q.put((toks, labels))

    thread = threading.Thread(target=producer, daemon=True)
    thread.start()

    while True:
        toks, labels = q.get()
        yield TokenBatch(
            tokens=jnp.asarray(toks),
            labels=jnp.asarray(labels),
            mask=jnp.ones((batch, seq), jnp.float32))
