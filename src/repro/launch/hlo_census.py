"""Shared parsing of collective ops in compiled-HLO text.

One home for the device-group grammar of XLA's collective ops, used by
both the dry-run roofline census (``repro.launch.dryrun``) and the test
harness (``tests/hlo_utils``) — the two consumers must never disagree
about what counts as a group, or the CI inter-pod byte split and the
per-axis census could drift apart.  Import-safe by construction: pure
regex + numpy, no jax import (``dryrun`` itself sets ``XLA_FLAGS`` at
import time and must not be imported by tests).

Grammar covered (one line per op in ``Compiled.as_text()``):

  * explicit groups   ``replica_groups={{0,1,2,3},{4,5,6,7}}``
  * iota groups       ``replica_groups=[ng,gs]<=[dims]`` with an
                      optional ``T(perm)`` transpose suffix
  * permute pairs     ``source_target_pairs={{0,4},{4,0},...}`` — each
                      (src, tgt) pair is one two-device group, which is
                      exactly what pod-crossing / axis classification
                      needs
"""
from __future__ import annotations

import re

import numpy as np

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_EXPLICIT_RE = re.compile(r"replica_groups=\{(\{[\d,\{\} ]*\})\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{(\{[\d,\{\} ]*\})\}")


def match_collective(line: str):
    """Collective-op name of one HLO line, or None.

    Async pairs are attributed to the ``-start`` op; the matching
    ``-done`` line returns None so censuses never double-count."""
    s = line.strip()
    for c in COLLECTIVES:
        if f"{c}-done(" in s:
            return None
        if re.search(rf"\s{c}(-start)?\(", s):
            return c
    return None


def op_groups(line: str):
    """Device-id groups of one collective-op line, or None when the op
    carries no parsable group attribute."""
    m = _IOTA_RE.search(line)
    if m:
        ng, gs = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        devices = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            devices = devices.transpose(
                [int(x) for x in m.group(4).split(",")])
        return [list(map(int, grp)) for grp in devices.reshape(ng, gs)]
    m = _EXPLICIT_RE.search(line) or _PAIRS_RE.search(line)
    if m:
        return [[int(x) for x in grp.replace(" ", "").split(",") if x]
                for grp in re.findall(r"\{([\d, ]+)\}", m.group(1))]
    return None


def groups_cross_boundary(groups, boundary: int) -> bool:
    """True when any group spans device ids on both sides of
    ``boundary`` (id < boundary vs >= boundary) — i.e. the collective
    rides the link between the two id ranges (the inter-pod hop)."""
    return any(g and min(g) < boundary <= max(g) for g in groups)
