"""Fig. 6: sensitivity to the synchronization interval N (1, 5, 10, 20)
— raw stale pulls vs the SAT staleness predictor (``-sat`` rows, EMA
history), whose claim is matching accuracy at wider intervals."""
from benchmarks.common import bench_scale, emit
from benchmarks.gnn_common import setup, train_mode
from repro.core import PredictorConfig


def run() -> list[dict]:
    scale = bench_scale()
    _, data, cfg = setup("products-sim", scale=0.2 * scale)
    epochs = max(int(100 * scale), 30)
    rows = []
    for predictor, tag in ((None, ""),
                           (PredictorConfig(kind="ema"), "-sat")):
        for interval in (1, 5, 10, 20):
            hist, _, per_epoch = train_mode(cfg, data, "digest", epochs,
                                            interval=interval,
                                            predictor=predictor)
            rows.append({
                "name": f"fig6/N={interval}{tag}",
                "us_per_call": round(per_epoch * 1e6, 1),
                "f1": round(hist["val_f1"][-1], 4),
                "staleness_eps_mean": round(
                    sum(hist["staleness_eps"][-1]) /
                    max(len(hist["staleness_eps"][-1]), 1), 4),
            })
    return rows


if __name__ == "__main__":
    emit(run())
