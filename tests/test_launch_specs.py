"""Dry-run spec plumbing (shapes only, no 512-device mesh needed)."""
import jax
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.launch.specs import (SHAPES, input_specs, opt_state_specs,
                                serve_state_specs, train_state_specs,
                                abstract_from_specs)
from repro.models.transformer import arch_specs
from repro.nn.params import is_spec
from repro.optim import adafactor, adamw


@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_shapes(shape_name):
    cfg = get_arch("qwen3-0.6b")
    sp = input_specs(cfg, shape_name)
    sh = SHAPES[shape_name]
    if sh["kind"] in ("train", "prefill"):
        assert sp["tokens"].shape == (sh["batch"], sh["seq"])
    else:
        assert sp["tokens"].shape == (sh["batch"], 1)


def test_vlm_gets_vision_stub():
    cfg = get_arch("llama-3.2-vision-11b")
    sp = input_specs(cfg, "train_4k")
    assert sp["vision"].shape == (256, cfg.num_patches, cfg.vision_dim)


def test_opt_state_specs_match_real_structure():
    cfg = get_arch("qwen3-0.6b")
    p_specs = arch_specs(cfg)
    abstract = abstract_from_specs(p_specs)
    for name, opt in (("adamw", adamw(1e-3)), ("adafactor",
                                               adafactor(1e-2))):
        want = jax.eval_shape(opt.init, abstract)
        got = abstract_from_specs(opt_state_specs(name, p_specs))
        ws = jax.tree.structure(want)
        gs = jax.tree.structure(got)
        assert ws == gs, (name, ws, gs)
        for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
            assert a.shape == b.shape and a.dtype == b.dtype


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_serve_state_specs_build(arch):
    cfg = get_arch(arch)
    ss = serve_state_specs(cfg, "decode_32k")
    leaves = jax.tree.leaves(ss["cache"], is_leaf=is_spec)
    assert leaves


def test_train_state_specs_pod_stacking():
    cfg = get_arch("qwen3-0.6b")
    ss = train_state_specs(cfg, n_pod=2, digest_pods=True)
    leaf = jax.tree.leaves(ss["params"], is_leaf=is_spec)[0]
    assert leaf.shape[0] == 2 and leaf.axes[0] == "pod_stack"
