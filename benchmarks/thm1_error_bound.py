"""Theorem 1: measured staleness gradient error vs the analytic bound —
including the quantization-corrected bound (ε + ε_quant) for bf16/int8
HaloExchange storage, where rounding error is made explicit instead of
being absorbed into the measured ε."""
from benchmarks.common import bench_scale, emit
from benchmarks.gnn_common import setup
from repro.core import (HaloPrecision, TrainSettings, digest_train,
                        measure_error_and_bound)
from repro.optim import adam


def run() -> list[dict]:
    scale = bench_scale()
    _, data, cfg = setup("flickr-sim", scale=0.3 * scale)
    rows = []
    for interval in (1, 10, 20):
        st, _ = digest_train(cfg, adam(5e-3), data,
                             TrainSettings(sync_interval=interval),
                             epochs=max(int(30 * scale), 10),
                             eval_every=100)
        res = measure_error_and_bound(cfg, st["params"], data, st["store"])
        rows.append({
            "name": f"thm1/N={interval}",
            "us_per_call": "",
            "err_measured": round(res["err_measured"], 6),
            "bound": round(res["bound"], 2),
            "holds": res["err_measured"] <= res["bound"],
            "eps_max": round(max(res["eps"]), 4),
            "grad_norm": round(res["grad_norm_fresh"], 4),
        })
    # SAT predictor: the stale side becomes dequant(store)+γ·dequant
    # (pstore); ε is the residual staleness the predictor leaves, and
    # eps_raw the uncorrected ε the same store would serve (Fig. 6's
    # comparison axis — residual ≤ raw is the bench-regression gate).
    from repro.core import PredictorConfig
    for interval in (10, 20):
        st, _ = digest_train(
            cfg, adam(5e-3), data,
            TrainSettings(sync_interval=interval,
                          predictor=PredictorConfig(kind="ema")),
            epochs=max(int(30 * scale), 10), eval_every=100)
        res = measure_error_and_bound(
            cfg, st["params"], data, st["store"], pstore=st["pstore"])
        rows.append({
            "name": f"thm1/N={interval}-sat",
            "us_per_call": "",
            "err_measured": round(res["err_measured"], 6),
            "bound": round(res["bound"], 2),
            "holds": res["err_measured"] <= res["bound"],
            "eps_max": round(max(res["eps"]), 4),
            "eps_raw_max": round(max(res["eps_raw"]), 4),
            "grad_norm": round(res["grad_norm_fresh"], 4),
        })
    # Quantized storage: the corrected bound carries the explicit
    # scale/2·√d (int8) / ulp (bf16) term on top of the measured ε.
    for storage in ("bf16", "int8"):
        st, _ = digest_train(
            cfg, adam(5e-3), data,
            TrainSettings(sync_interval=10,
                          precision=HaloPrecision(storage)),
            epochs=max(int(30 * scale), 10), eval_every=100)
        res = measure_error_and_bound(cfg, st["params"], data, st["store"])
        rows.append({
            "name": f"thm1/N=10-{storage}",
            "us_per_call": "",
            "err_measured": round(res["err_measured"], 6),
            "bound": round(res["bound"], 2),
            "bound_with_quant": round(res["bound_with_quant"], 2),
            "holds": res["err_measured"] <= res["bound_with_quant"],
            "eps_quant_max": round(max(res["eps_quant"]), 6),
        })
    return rows


if __name__ == "__main__":
    emit(run())
