"""Pallas TPU kernel: blocked causal flash attention (online softmax).

Used by the transformer prefill path of the assigned architectures.

TPU design:
  * grid = (batch*heads, q_blocks, k_blocks), k dimension sequential
    ("arbitrary") so the online-softmax running state can live in VMEM
    scratch across k steps; q/k tiles are (128, head_dim) MXU-aligned.
  * running max m, normalizer l, and accumulator acc are f32 scratch;
    output written on the final k step.
  * causal masking skips fully-masked k blocks via ``pl.when`` on the block
    index (upper-triangular blocks do no work).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref, *,
                 sm_scale: float, causal: bool, block_q: int, block_k: int,
                 num_k_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        q = q_ref[0].astype(jnp.float32) * sm_scale     # (BQ, hd)
        k = k_ref[0].astype(jnp.float32)                # (BK, hd)
        v = v_ref[0].astype(jnp.float32)                # (BK, hd)
        s = jnp.dot(q, k.T)                             # (BQ, BK)
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            k_pos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_ref[...]                             # (BQ, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                          # (BQ, BK)
        alpha = jnp.exp(m_prev - m_new)                 # (BQ, 1)
        l_new = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(p, v)
        m_ref[...] = m_new
        l_ref[...] = l_new

    if causal:
        # Skip blocks strictly above the diagonal.
        pl.when(qi * block_q + block_q - 1 >= ki * block_k)(_compute)
    else:
        _compute()

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        out_ref[0] = (acc_ref[...] / l).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "sm_scale",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True,
                           sm_scale: float | None = None,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = True) -> jax.Array:
    """q, k, v: (bh, seq, head_dim) — batch*heads flattened on axis 0."""
    bh, seq, hd = q.shape
    if sm_scale is None:
        sm_scale = hd ** -0.5
    bq = min(block_q, seq)
    bk = min(block_k, seq)
    if seq % bq or seq % bk:
        raise ValueError(f"seq={seq} must divide blocks ({bq},{bk})")
    nq, nk = seq // bq, seq // bk
    grid = (bh, nq, nk)
    kernel = functools.partial(
        _attn_kernel, sm_scale=sm_scale, causal=causal, block_q=bq,
        block_k=bk, num_k_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq, hd), q.dtype),
        scratch_shapes=[
            # m, l, acc live across the sequential k grid dimension (VMEM).
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        # jax ≥ 0.7 renamed TPUCompilerParams → CompilerParams; support both.
        compiler_params=getattr(pltpu, "CompilerParams",
                                getattr(pltpu, "TPUCompilerParams", None))(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
