"""Graph containers: host-side CSR plus jit-friendly padded ELL forms.

TPU adaptation note (DESIGN.md §3): neighbor aggregation on TPU wants an
*affine* access pattern, so the runtime format is degree-padded ELL
(``(num_nodes, max_degree)`` neighbor-id and weight matrices) rather than
CSR+scatter.  Padding entries point at a sentinel row with weight 0.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class Graph:
    """Undirected graph in CSR with node features/labels (host side)."""

    indptr: np.ndarray       # (N+1,) int64
    indices: np.ndarray      # (E,) int32 — column ids, sorted per row
    features: np.ndarray     # (N, d) float32
    labels: np.ndarray       # (N,) int32
    train_mask: np.ndarray   # (N,) bool
    val_mask: np.ndarray     # (N,) bool
    test_mask: np.ndarray    # (N,) bool
    name: str = "graph"

    @property
    def num_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def num_edges(self) -> int:
        return len(self.indices)

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def validate(self) -> None:
        n = self.num_nodes
        assert self.indptr[0] == 0 and self.indptr[-1] == len(self.indices)
        assert np.all(np.diff(self.indptr) >= 0)
        assert self.indices.min(initial=0) >= 0
        assert self.indices.max(initial=-1) < n
        assert self.features.shape[0] == n
        assert self.labels.shape[0] == n


def from_edges(num_nodes: int, edges: np.ndarray, features: np.ndarray,
               labels: np.ndarray, masks: Optional[tuple] = None,
               name: str = "graph") -> Graph:
    """Build a symmetrized, dedup'd CSR graph from an (E, 2) edge list."""
    e = np.asarray(edges, np.int64)
    e = e[e[:, 0] != e[:, 1]]                       # drop self loops (P adds them)
    both = np.concatenate([e, e[:, ::-1]], axis=0)  # symmetrize
    key = both[:, 0] * num_nodes + both[:, 1]
    both = both[np.unique(key, return_index=True)[1]]
    order = np.lexsort((both[:, 1], both[:, 0]))
    both = both[order]
    indptr = np.zeros(num_nodes + 1, np.int64)
    np.add.at(indptr, both[:, 0] + 1, 1)
    indptr = np.cumsum(indptr)
    if masks is None:
        n = num_nodes
        idx = np.random.default_rng(0).permutation(n)
        tr, va = int(0.6 * n), int(0.8 * n)
        train = np.zeros(n, bool); train[idx[:tr]] = True
        val = np.zeros(n, bool); val[idx[tr:va]] = True
        test = np.zeros(n, bool); test[idx[va:]] = True
        masks = (train, val, test)
    return Graph(indptr=indptr, indices=both[:, 1].astype(np.int32),
                 features=np.asarray(features, np.float32),
                 labels=np.asarray(labels, np.int32),
                 train_mask=masks[0], val_mask=masks[1], test_mask=masks[2],
                 name=name)


def gcn_norm_weights(g: Graph, add_self_loops: bool = True
                     ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """GCN propagation P = D^{-1/2} (A + I) D^{-1/2} in COO.

    Returns (rows, cols, weights) including self loops.
    """
    rows = np.repeat(np.arange(g.num_nodes, dtype=np.int32),
                     g.degrees().astype(np.int64))
    cols = g.indices.astype(np.int32)
    if add_self_loops:
        loop = np.arange(g.num_nodes, dtype=np.int32)
        rows = np.concatenate([rows, loop])
        cols = np.concatenate([cols, loop])
    deg = np.zeros(g.num_nodes, np.float64)
    np.add.at(deg, rows, 1.0)
    dinv = 1.0 / np.sqrt(np.maximum(deg, 1.0))
    w = (dinv[rows] * dinv[cols]).astype(np.float32)
    return rows, cols, w


@dataclasses.dataclass
class EllMatrix:
    """Padded ELL sparse matrix: out[i] = sum_k w[i,k] * x[nbr[i,k]]."""

    nbr: np.ndarray   # (rows, max_deg) int32 — column index; sentinel = n_cols
    wts: np.ndarray   # (rows, max_deg) float32 — 0 at padding
    n_cols: int       # logical column count (sentinel row appended at n_cols)

    @property
    def shape(self) -> tuple[int, int]:
        return (self.nbr.shape[0], self.n_cols)

    @property
    def max_degree(self) -> int:
        return self.nbr.shape[1]

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, np.float64)
        rows = np.repeat(np.arange(self.nbr.shape[0]), self.nbr.shape[1])
        cols = self.nbr.reshape(-1)
        vals = self.wts.reshape(-1).astype(np.float64)
        keep = cols < self.n_cols
        np.add.at(out, (rows[keep], cols[keep]), vals[keep])
        return out.astype(np.float32)


def coo_to_ell(rows: np.ndarray, cols: np.ndarray, wts: np.ndarray,
               n_rows: int, n_cols: int, min_pad: int = 1,
               pad_multiple: int = 1) -> EllMatrix:
    """Convert COO to padded ELL. Padding slots point at column ``n_cols``."""
    order = np.argsort(rows, kind="stable")
    rows, cols, wts = rows[order], cols[order], wts[order]
    counts = np.zeros(n_rows, np.int64)
    np.add.at(counts, rows, 1)
    max_deg = max(int(counts.max(initial=0)), min_pad)
    if pad_multiple > 1:
        max_deg = ((max_deg + pad_multiple - 1) // pad_multiple) * pad_multiple
    nbr = np.full((n_rows, max_deg), n_cols, np.int32)
    w = np.zeros((n_rows, max_deg), np.float32)
    start = np.zeros(n_rows + 1, np.int64)
    start[1:] = np.cumsum(counts)
    slots = np.arange(len(rows), dtype=np.int64) - start[rows]
    nbr[rows, slots] = cols
    w[rows, slots] = wts
    return EllMatrix(nbr=nbr, wts=w, n_cols=n_cols)
