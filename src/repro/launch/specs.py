"""Input/state ShapeDtypeStruct stand-ins and shardings for the dry-run.

``input_specs(cfg, shape_name)`` follows the assignment's four shapes:

    train_4k       seq=4096    global_batch=256   (training)
    prefill_32k    seq=32768   global_batch=32    (inference-prefill)
    decode_32k     seq=32768   global_batch=128   (decode: 1 token + cache)
    long_500k      seq=524288  global_batch=1     (long-context decode,
                                                   stale-KV / recurrent)

Modality stubs: VLM shapes add precomputed patch embeddings; musicgen's
tokens *are* the EnCodec frame codes (vocab 2048).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.transformer import ArchConfig, arch_specs, cache_specs
from repro.nn.params import ParamSpec, is_spec

Pytree = Any

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode_long"),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape_name: str) -> dict:
    """Abstract model inputs for one assignment shape (no allocation)."""
    sh = SHAPES[shape_name]
    b, s = sh["batch"], sh["seq"]
    kind = sh["kind"]
    out: dict[str, Any] = {}
    if kind == "train":
        out["tokens"] = _sds((b, s), jnp.int32)
        out["labels"] = _sds((b, s), jnp.int32)
        out["mask"] = _sds((b, s), jnp.float32)
    elif kind == "prefill":
        out["tokens"] = _sds((b, s), jnp.int32)
    else:  # decode / decode_long — ONE new token
        out["tokens"] = _sds((b, 1), jnp.int32)
    if cfg.vision_dim and kind in ("train", "prefill"):
        out["vision"] = _sds((b, cfg.num_patches, cfg.vision_dim),
                             jnp.bfloat16)
    return out


def batch_logical_axes(cfg: ArchConfig, shape_name: str) -> dict:
    sh = SHAPES[shape_name]
    kind = sh["kind"]
    tok = ("batch", "seq")
    out = {"tokens": tok}
    if kind == "train":
        out["labels"] = tok
        out["mask"] = tok
    if cfg.vision_dim and kind in ("train", "prefill"):
        out["vision"] = ("batch", "patches", None)
    return out


# ---------------------------------------------------------------------------
# Optimizer-state specs (mirrors repro.optim structures, for shardings)
# ---------------------------------------------------------------------------

def opt_state_specs(opt_name: str, param_specs: Pytree) -> Pytree:
    """Mirrors the *actual* state structure of repro.optim optimizers."""
    f32 = jnp.float32

    def like(spec: ParamSpec):
        return ParamSpec(spec.shape, spec.axes, init="zeros", dtype=f32)

    if opt_name in ("adam", "adamw"):
        return {"m": jax.tree.map(like, param_specs, is_leaf=is_spec),
                "v": jax.tree.map(like, param_specs, is_leaf=is_spec)}
    if opt_name == "adafactor":
        def leaf(spec: ParamSpec):
            if len(spec.shape) >= 2:
                row = ParamSpec(spec.shape[:-1], spec.axes[:-1],
                                init="zeros", dtype=f32)
                col = ParamSpec(spec.shape[:-2] + spec.shape[-1:],
                                spec.axes[:-2] + spec.axes[-1:],
                                init="zeros", dtype=f32)
                return {"row": row, "col": col}
            return {"v": like(spec)}
        return jax.tree.map(leaf, param_specs, is_leaf=is_spec)
    if opt_name == "sgd":
        return ()
    raise ValueError(opt_name)


def train_state_specs(cfg: ArchConfig, n_pod: int = 1,
                      digest_pods: bool = False) -> dict:
    """ParamSpec pytree for the full train state (params + opt + step)."""
    from repro.models.transformer import _stack_spec  # shared helper
    p_specs = arch_specs(cfg)
    o_specs = opt_state_specs(cfg.optimizer, p_specs)
    if digest_pods and n_pod > 1:
        stack = lambda t: jax.tree.map(
            lambda s: dataclasses.replace(
                _stack_spec(s, n_pod),
                axes=("pod_stack",) + s.axes), t, is_leaf=is_spec)
        p_specs = stack(p_specs)
        o_specs = stack(o_specs)
    return {"params": p_specs, "opt_state": o_specs,
            "step": ParamSpec((), (), init="zeros", dtype=jnp.int32)}


def abstract_from_specs(specs: Pytree) -> Pytree:
    return jax.tree.map(lambda s: _sds(s.shape, s.dtype), specs,
                        is_leaf=is_spec)


def serve_state_specs(cfg: ArchConfig, shape_name: str) -> dict:
    sh = SHAPES[shape_name]
    long = sh["kind"] == "decode_long"
    return {"params": arch_specs(cfg),
            "cache": cache_specs(cfg, sh["batch"], sh["seq"], long=long)}
