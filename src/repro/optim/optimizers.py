"""Optimizers (no optax): SGD, Adam(W), Adafactor, with LR schedules.

An optimizer is a pair of pure functions wrapped in :class:`Optimizer`:
``init(params) -> state`` and
``update(grads, state, params, step) -> (new_params, new_state)``.

Adafactor exists because the trillion-parameter assigned architecture
(kimi-k2) cannot hold Adam's 8 bytes/param of momenta on a 512-chip v5e
footprint; factored second moments cost O(rows+cols).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Pytree = Any
Schedule = Callable[[jax.Array], jax.Array]


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine_schedule(peak_lr: float, warmup_steps: int,
                           total_steps: int,
                           final_frac: float = 0.1) -> Schedule:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * (step + 1.0) / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return fn


# ---------------------------------------------------------------------------
# Optimizer container
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Pytree], Pytree]
    update: Callable[[Pytree, Pytree, Pytree, jax.Array],
                     tuple[Pytree, Pytree]]


def _global_norm(tree: Pytree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: Pytree, max_norm: float) -> Pytree:
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads)


# ---------------------------------------------------------------------------
# SGD (+ momentum)
# ---------------------------------------------------------------------------

def sgd(lr: float | Schedule, momentum: float = 0.0) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params, step):
        lr_t = sched(step)
        if momentum == 0.0:
            new_params = jax.tree.map(
                lambda p, g: (p - lr_t * g.astype(p.dtype)).astype(p.dtype),
                params, grads)
            return new_params, state
        new_m = jax.tree.map(lambda m, g: momentum * m + g, state, grads)
        new_params = jax.tree.map(
            lambda p, m: (p - lr_t * m).astype(p.dtype), params, new_m)
        return new_params, new_m

    return Optimizer("sgd", init, update)


# ---------------------------------------------------------------------------
# Adam / AdamW
# ---------------------------------------------------------------------------

def adamw(lr: float | Schedule, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        lr_t = sched(step)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t
        new_m = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        new_v = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2)
            * jnp.square(g.astype(jnp.float32)), state["v"], grads)

        def upd(p, m, v):
            mh = m / bc1
            vh = v / bc2
            delta = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, new_m, new_v)
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer("adamw", init, update)


def adam(lr: float | Schedule, **kw) -> Optimizer:
    return adamw(lr, weight_decay=0.0, **kw)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments, no first moment)
# ---------------------------------------------------------------------------

def adafactor(lr: float | Schedule, decay: float = 0.8,
              eps: float = 1e-30, clip_threshold: float = 1.0) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def leaf(p):
            if _factored(p):
                # Factor over the two trailing dims; leading dims (layers,
                # experts) are kept — still O(rows+cols) per matrix.
                row = jnp.zeros(p.shape[:-1], jnp.float32)
                col = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                return {"row": row, "col": col}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return jax.tree.map(leaf, params)

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)
        lr_t = sched(step)

        def leaf(p, g, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p):
                row = beta * s["row"] + (1 - beta) * jnp.mean(g2, axis=-1)
                col = beta * s["col"] + (1 - beta) * jnp.mean(g2, axis=-2)
                row_mean = jnp.mean(row, axis=-1, keepdims=True)
                vhat = (row[..., :, None] / jnp.maximum(row_mean[..., None],
                                                        eps)
                        * col[..., None, :])
                upd = g * jax.lax.rsqrt(jnp.maximum(vhat, eps))
                new_s = {"row": row, "col": col}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                upd = g * jax.lax.rsqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            # Update clipping (RMS of update <= clip_threshold).
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-30)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            new_p = (p.astype(jnp.float32) - lr_t * upd).astype(p.dtype)
            return new_p, new_s

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state)
        out = [leaf(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_state = jax.tree.unflatten(treedef, [o[1] for o in out])
        return new_params, new_state

    return Optimizer("adafactor", init, update)


REGISTRY = {"sgd": sgd, "adam": adam, "adamw": adamw, "adafactor": adafactor}


def make_optimizer(name: str, lr: float | Schedule, **kw) -> Optimizer:
    return REGISTRY[name](lr, **kw)
