"""Gate a dry-run collective-census JSONL on the zero-all-gather rule.

The CI dryrun-smoke job compiles the 512-chip multi-pod collective
epoch (repro.launch.dryrun_gnn) and must fail the build if the lowered
HLO picked up a dense-fallback collective.  That assert used to live
as an inline heredoc in .github/workflows/ci.yml — untestable and
invisible to grep.  It is now this entrypoint:

  PYTHONPATH=src python -m repro.launch.census_check census.jsonl \\
      [--records 2]

For every JSON line the census must show

  * all-gather == 0 and reduce-scatter == 0 — the two ops the
    owner-sharded two-stage exchange exists to avoid;
  * all-to-all >= 1 — the intra-pod ragged pull is actually present;
  * collective-permute >= 1 — so is the inter-pod hop.

``--records`` (default 2: the fp32 and int8/ppd=2 compiles the smoke
job runs) pins the line count so a silently-skipped compile cannot
pass; ``--records 0`` accepts any non-empty file.
"""
from __future__ import annotations

import argparse
import json
import sys


def check_census(records: list[dict], expect_records: int = 2) -> list[str]:
    """Return a list of violation strings (empty = census OK)."""
    errors = []
    if expect_records and len(records) != expect_records:
        errors.append(f"expected {expect_records} census records, "
                      f"found {len(records)}")
    if not records:
        errors.append("census file is empty")
    for rec in records:
        counts = rec.get("collective_counts")
        label = (f"{rec.get('mesh')} {rec.get('precision')} "
                 f"ppd={rec.get('parts_per_device')} "
                 f"predictor={rec.get('predictor', 'none')}")
        if counts is None:
            errors.append(f"{label}: record has no collective_counts")
            continue
        for op in ("all-gather", "reduce-scatter"):
            if counts.get(op, 0) != 0:
                errors.append(f"{label}: {op} == {counts.get(op)} "
                              f"(must be 0): {counts}")
        for op in ("all-to-all", "collective-permute"):
            if counts.get(op, 0) < 1:
                errors.append(f"{label}: {op} == {counts.get(op, 0)} "
                              f"(two-stage exchange missing): {counts}")
    return errors


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("census", help="JSONL file from dryrun_gnn --out")
    ap.add_argument("--records", type=int, default=2,
                    help="exact record count expected (0 = any non-empty)")
    args = ap.parse_args(argv)
    with open(args.census) as f:
        records = [json.loads(line) for line in f if line.strip()]
    errors = check_census(records, expect_records=args.records)
    for rec in records:
        status = "FAIL" if errors else "OK"
        print(f"census {status}: {rec.get('mesh')} {rec.get('precision')} "
              f"ppd={rec.get('parts_per_device')} "
              f"predictor={rec.get('predictor', 'none')} "
              f"{rec.get('collective_counts')}")
    for e in errors:
        print(f"census violation: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
