"""Analytic communication model (§3.3): ordering + scaling claims."""
import numpy as np

from repro.core import epoch_comm_bytes, epoch_time_model, khop_halo_sizes
from repro.graph import build_partitions, make_dataset
from repro.models.gnn import GNNConfig, gnn_specs
from repro.nn import param_count


def _setup():
    g = make_dataset("flickr-sim", scale=0.2)
    sp = build_partitions(g, 4)
    cfg = GNNConfig(num_layers=3, in_dim=g.features.shape[1],
                    hidden_dim=64, num_classes=8)
    pc = param_count(gnn_specs(cfg))
    return g, sp, pc


def test_mode_ordering():
    g, sp, pc = _setup()
    b = {m: epoch_comm_bytes(m, sp, g, pc, 64, 3, 10)
         for m in ("partition", "digest", "propagation")}
    assert b["partition"] < b["digest"] < b["propagation"]


def test_interval_amortization():
    g, sp, pc = _setup()
    b1 = epoch_comm_bytes("digest", sp, g, pc, 64, 3, 1)
    b10 = epoch_comm_bytes("digest", sp, g, pc, 64, 3, 10)
    assert b10 < b1


def test_khop_halo_monotone():
    g, sp, _ = _setup()
    kh = khop_halo_sizes(g, sp, 3)
    assert (np.diff(kh, axis=1) >= 0).all()     # halos grow with depth


def test_time_model_positive():
    g, sp, pc = _setup()
    t = epoch_time_model("digest", sp, g, pc, 64, 3, g.features.shape[1])
    assert t["t_epoch"] > 0 and t["bytes"] > 0
