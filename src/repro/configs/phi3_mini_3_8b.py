"""phi3-mini-3.8b [dense] — RoPE SwiGLU, MHA-ish (kv=32).

[arXiv:2404.14219] 32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064.
long_500k runs through stale-KV block attention (the paper's blocksparse
long variant mapped to the DIGEST mechanism).
"""
import dataclasses
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="phi3-mini-3.8b", family="dense",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32064, head_dim=96,
    pattern=("attn",), rope_theta=10000.0,
    optimizer="adamw", learning_rate=3e-4,
    source="arXiv:2404.14219",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512, head_dim=32, dtype="float32")
