"""Train/serve steps for the assigned architectures, with DIGEST-style
periodic parameter synchronization across pods.

DIGEST generalized (DESIGN.md §4.1): within a pod, gradients all-reduce
every step over the fast intra-pod ICI (the paper's per-round parameter
AGG); *across pods*, parameters are synchronized only every N steps over
the slow inter-pod link (the paper's periodic stale sync, aimed exactly at
the weakest link).  Implementation: parameters carry an explicit leading
``(n_pod, ...)`` dim sharded over the "pod" mesh axis; the per-pod step is
``vmap``-ed over that dim (local SGD), and a ``lax.cond`` on
``step % N == N-1`` averages the copies — pure GSPMD, no manual
collectives, lowers to one all-reduce over "pod" every N steps.

``sync_mode``:
  "every_step" — baseline data parallelism (pod axis folded into batch;
                 no divergence; the paper's "propagation"-style fresh sync)
  "digest"     — periodic parameter sync as above (the paper's method)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.transformer import (ArchConfig, arch_specs, aux_moe_loss,
                                      decode_step, forward)
from repro.nn import init_params, softmax_cross_entropy
from repro.optim import (Optimizer, clip_by_global_norm, make_optimizer,
                         warmup_cosine_schedule)

Pytree = Any


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    sync_mode: str = "every_step"        # every_step | digest
    sync_interval: int = 10              # N (pod-sync period, digest mode)
    n_pod: int = 1
    # "vmap": per-pod parameter copies with an explicit leading dim —
    #   single-device-runnable semantics (tests, CPU).
    # "shard_map": manual over the mesh "pod" axis, GSPMD inside — the
    #   production path (each pod compiles like a single-pod program; one
    #   conditional pmean over "pod" every N steps; no layout churn).
    pod_impl: str = "vmap"
    grad_clip: float = 1.0
    aux_loss_weight: float = 0.01
    total_steps: int = 10_000
    warmup_steps: int = 200


def make_arch_optimizer(cfg: ArchConfig, settings: TrainSettings
                        ) -> Optimizer:
    sched = warmup_cosine_schedule(cfg.learning_rate,
                                   settings.warmup_steps,
                                   settings.total_steps)
    if cfg.optimizer == "adafactor":
        return make_optimizer("adafactor", sched)
    if cfg.optimizer == "adamw":
        return make_optimizer("adamw", sched, weight_decay=0.01)
    return make_optimizer(cfg.optimizer, sched)


def _stacked_pods(settings: TrainSettings) -> bool:
    return (settings.sync_mode == "digest" and settings.n_pod > 1
            and settings.pod_impl == "vmap")


def init_train_state(cfg: ArchConfig, settings: TrainSettings,
                     seed: int = 0) -> dict:
    opt = make_arch_optimizer(cfg, settings)
    params = init_params(jax.random.PRNGKey(seed), arch_specs(cfg))
    if _stacked_pods(settings):
        params = jax.tree.map(
            lambda p: jnp.broadcast_to(p[None],
                                       (settings.n_pod,) + p.shape),
            params)
        opt_state = jax.vmap(opt.init)(params)
    else:
        opt_state = opt.init(params)
    return {"params": params, "opt_state": opt_state,
            "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(cfg: ArchConfig, settings: TrainSettings) -> dict:
    """ShapeDtypeStruct state for dry-run lowering (no allocation)."""
    return jax.eval_shape(lambda: init_train_state(cfg, settings))


def _loss_fn(cfg: ArchConfig, settings: TrainSettings, params: Pytree,
             batch: dict) -> tuple[jax.Array, dict]:
    logits = forward(cfg, params, batch["tokens"], batch.get("vision"))
    ce = softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))
    loss = ce
    aux = jnp.asarray(0.0, jnp.float32)
    if cfg.num_experts:
        aux = aux_moe_loss(cfg, params, batch["tokens"])
        loss = loss + settings.aux_loss_weight * aux
    return loss, {"ce": ce, "aux": aux}


def make_train_step(cfg: ArchConfig, settings: TrainSettings
                    ) -> Callable[[dict, dict], tuple[dict, dict]]:
    opt = make_arch_optimizer(cfg, settings)

    def one_pod_step(params, opt_state, batch, step):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: _loss_fn(cfg, settings, p, batch), has_aux=True
        )(params)
        if settings.grad_clip:
            grads = clip_by_global_norm(grads, settings.grad_clip)
        new_params, new_opt = opt.update(grads, opt_state, params, step)
        return new_params, new_opt, loss, parts

    if settings.sync_mode != "digest" or settings.n_pod <= 1:
        def train_step(state, batch):
            params, opt_state, loss, parts = one_pod_step(
                state["params"], state["opt_state"], batch, state["step"])
            metrics = {"loss": loss, **parts}
            return {"params": params, "opt_state": opt_state,
                    "step": state["step"] + 1}, metrics
        return train_step

    if settings.pod_impl == "shard_map":
        return _make_pod_shard_map_step(cfg, settings, opt, one_pod_step)

    n_pod = settings.n_pod

    def train_step(state, batch):
        # batch tokens: (B_global, S) → (n_pod, B/n_pod, S)
        def split(x):
            return x.reshape((n_pod, x.shape[0] // n_pod) + x.shape[1:])
        pod_batch = jax.tree.map(split, batch)
        params, opt_state, loss, parts = jax.vmap(
            one_pod_step, in_axes=(0, 0, 0, None))(
                state["params"], state["opt_state"], pod_batch,
                state["step"])

        # Periodic cross-pod parameter synchronization (DIGEST).
        do_sync = (state["step"] + 1) % settings.sync_interval == 0

        def sync(tree):
            return jax.tree.map(
                lambda p: jnp.broadcast_to(
                    jnp.mean(p.astype(jnp.float32), axis=0,
                             keepdims=True).astype(p.dtype),
                    p.shape),
                tree)

        params = jax.lax.cond(do_sync, sync, lambda t: t, params)
        metrics = {"loss": jnp.mean(loss),
                   "ce": jnp.mean(parts["ce"]),
                   "aux": jnp.mean(parts["aux"]),
                   "pod_divergence": _pod_divergence(params)}
        return {"params": params, "opt_state": opt_state,
                "step": state["step"] + 1}, metrics

    return train_step


def _make_pod_shard_map_step(cfg: ArchConfig, settings: TrainSettings,
                             opt: Optimizer, one_pod_step) -> Callable:
    """DIGEST pod sync, production form: jax.shard_map manual over "pod",
    GSPMD auto inside. Parameters carry NO pod dimension — each pod's
    devices hold their own (divergent between syncs) copy under a
    nominally-replicated layout (check_vma=False), exactly local SGD.
    One conditional ``pmean`` over "pod" every N steps is the only
    inter-pod collective."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import current_mesh

    def train_step(state, batch):
        mesh = current_mesh()
        if mesh is None or "pod" not in mesh.axis_names:
            raise ValueError("pod_impl='shard_map' needs a mesh with a "
                             "'pod' axis active via axis_rules(...)")

        def pod_local(params, opt_state, step, batch):
            new_params, new_opt, loss, parts = one_pod_step(
                params, opt_state, batch, step)
            do_sync = (step + 1) % settings.sync_interval == 0

            def sync(t):
                return jax.tree.map(
                    lambda a: jax.lax.pmean(a, "pod"), t)

            new_params = jax.lax.cond(do_sync, sync, lambda t: t,
                                      new_params)
            loss = jax.lax.pmean(loss, "pod")
            parts = jax.tree.map(lambda a: jax.lax.pmean(a, "pod"), parts)
            return new_params, new_opt, loss, parts

        batch_specs = jax.tree.map(lambda _: P("pod"), batch)
        sm = jax.shard_map(
            pod_local, mesh=mesh,
            in_specs=(P(), P(), P(), batch_specs),
            out_specs=(P(), P(), P(), P()),
            axis_names={"pod"}, check_vma=False)
        params, opt_state, loss, parts = sm(
            state["params"], state["opt_state"], state["step"], batch)
        metrics = {"loss": loss, **parts}
        return {"params": params, "opt_state": opt_state,
                "step": state["step"] + 1}, metrics

    return train_step


def _pod_divergence(params: Pytree) -> jax.Array:
    """Mean L2 distance of pod copies from their mean (diagnostic)."""
    def leaf(p):
        mu = jnp.mean(p.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.sum(jnp.square(p.astype(jnp.float32) - mu))
    total = sum(jax.tree.leaves(jax.tree.map(leaf, params)))
    return jnp.sqrt(total)


def make_serve_step(cfg: ArchConfig, long: bool = False) -> Callable:
    """serve_step(params, cache, tokens) → (logits, new_cache)."""
    def serve_step(params, cache, tokens):
        return decode_step(cfg, params, cache, tokens, long=long)
    return serve_step
