"""Graph partitioning and the stacked per-subgraph ELL views DIGEST trains on.

The paper partitions with METIS; offline we implement a deterministic
multilevel-flavored greedy (LDG/Fennel-style streaming over a BFS order),
which like METIS optimizes edge cut under balance constraints, plus random
partitioning as the ablation baseline.

Boundary-aware accounting at production scale: the ``halo_weight`` term of
:func:`greedy_partition` charges each candidate part the *marginal new halo
rows* an assignment creates, which needs an exact "is u already a halo row
of part p" membership test during the stream.  That membership is kept in
**per-node replica arrays** — for every node, the distinct parts it is
currently replicated into, stored in one flat O(E) buffer laid out by the
CSR degree slots (a node can only ever be a halo row of a part one of its
neighbors was assigned to, so ``|replicas(u)| <= deg(u)`` and the total is
bounded by 2E).  Each assignment touches only the <= deg(v) adjacent
entries; no (num_parts, num_nodes) matrix is ever materialized, so a
1M-node x 256-part build runs in O(E) extra memory and near-linear time.

Locality-aware local row ordering: ``build_partitions(order="rcm")``
reorders each part's local rows with reverse Cuthill-McKee over the
induced subgraph (and re-lays each per-subgraph halo slab's owner runs by
first-referencing row) so consecutive 128-row output blocks reference
clustered halo-slab ranges.  That drives the static
:class:`ChunkWorklist` occupancy down into the regime where the
chunk-skipping streamed kernel (``halo_spmm_skip_pallas``) is selected
and streams a fraction of the dense bytes.  The ordering is a pure
permutation of local rows (per-row ELL edge order, the owner-sharded
store layout and the PullPlan routing are untouched), guarded per part:
a part keeps its identity order if RCM would not reduce its visited
(row_block x chunk) count at the build geometry, so occupancy never
increases.

``build_partitions`` produces a :class:`StackedPartitions`: every subgraph
padded to identical (S, H, deg) sizes so the whole structure stacks into
(M, ...) arrays — directly shardable over the mesh "data" axis with one
subgraph per device slice, and vmap-able on CPU.
"""
from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from repro.graph.graph import EllMatrix, Graph, coo_to_ell, gcn_norm_weights


# ---------------------------------------------------------------------------
# Partitioners
# ---------------------------------------------------------------------------

def random_partition(g: Graph, num_parts: int, seed: int = 0,
                     halo_weight: float = 0.0) -> np.ndarray:
    # halo_weight accepted so every PARTITIONERS entry has the same
    # signature under build_partitions — but random assignment has no
    # streaming score to weight, so a sweep comparing partitioners at
    # halo_weight > 0 would silently misreport this leg as boundary-aware.
    if halo_weight:
        warnings.warn(
            f"random_partition ignores halo_weight={halo_weight!r}: the "
            f"boundary-aware marginal-halo score only exists in the "
            f"greedy streaming partitioner (method='greedy'/'metis')",
            stacklevel=2)
    rng = np.random.default_rng(seed)
    assign = np.arange(g.num_nodes) % num_parts
    rng.shuffle(assign)
    return assign.astype(np.int32)


def _ragged_take(buf: np.ndarray, starts: np.ndarray, lens: np.ndarray
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Gather ``concatenate([buf[s:s+l] for s, l in zip(starts, lens)])``
    plus the index of the (start, len) pair each element came from —
    vectorized (no per-slice Python loop)."""
    total = int(lens.sum())
    src = np.repeat(np.arange(len(starts)), lens)
    base = np.repeat(np.cumsum(lens) - lens, lens)
    pos = np.repeat(starts, lens) + (np.arange(total) - base)
    return buf[pos], src


def greedy_partition(g: Graph, num_parts: int, seed: int = 0,
                     slack: float = 1.05,
                     halo_weight: float = 0.0) -> np.ndarray:
    """LDG-style streaming partition over a BFS order (METIS stand-in).

    ``halo_weight`` adds a boundary-aware term to the streaming score: the
    classic LDG objective minimizes *edge cut*, but the compact store's
    residency and §3.3's wire cost both scale with ``Σ_m |halo(G_m)|``
    (vertex replication), which equal-cut partitions can differ a lot on.
    With a positive weight each candidate part is charged the *marginal
    new halo rows* its assignment would create — v replicated into every
    other adjacent part, plus every out-of-part neighbor that is not yet
    a halo row of the candidate (tracked exactly during the stream) —
    and parts at capacity are masked out so the penalty cannot trade
    balance for halo (the additive term would otherwise defeat the
    multiplicative balance factor).  ``halo_weight=0`` reproduces the
    original assignments bit-for-bit; 0.1–0.25 trims Σ|halo| a few
    percent on the test graphs at unchanged balance (edge cut drifts up
    slightly — the point is that cut is the wrong cost proxy).

    Cost note: halo membership is tracked in per-node **replica arrays**
    (one flat int32 buffer laid out by the CSR degree slots — a node is
    only ever replicated into parts its neighbors were assigned to, so
    ``|replicas(u)| <= deg(u)`` and the whole structure is O(E)).  Each
    step touches the <= deg(v) adjacent entries plus the candidates'
    replica arrays (``O(sum_{u in N(v)} |replicas(u)|)``); no
    (num_parts, num_nodes) matrix exists anywhere, so the 1M-node x
    256-part dry-run regime builds in O(E) extra memory.  The accounting
    is exactly the dense formulation's: ``is_halo[p, u]`` holds iff u is
    assigned and some assigned neighbor of u lives in part ``p !=
    assign[u]`` — the invariant the replica arrays maintain
    incrementally (asserted against a dense reference in
    tests/test_order_invariance.py).
    """
    n = g.num_nodes
    rng = np.random.default_rng(seed)
    capacity = slack * n / num_parts
    assign = np.full(n, -1, np.int32)
    sizes = np.zeros(num_parts, np.int64)
    indptr, indices = g.indptr, g.indices

    # BFS order from random seeds → locality in the stream.  LIFO
    # traversal appending unseen neighbors in CSR order — semantically
    # the per-edge Python loop of the original implementation, run as
    # one vectorized step per visited node (bit-identical order).
    order = np.empty(n, np.int64)
    seen = np.zeros(n, bool)
    stack = np.empty(n, np.int64)
    pos = 0
    for root in rng.permutation(n):
        if seen[root]:
            continue
        stack[0] = root
        top = 1
        seen[root] = True
        while top:
            top -= 1
            v = stack[top]
            order[pos] = v
            pos += 1
            ns = indices[indptr[v]:indptr[v + 1]]
            new = ns[~seen[ns]]
            if len(new):
                seen[new] = True
                stack[top:top + len(new)] = new
                top += len(new)
    assert pos == n

    if halo_weight:
        # Per-node replica arrays: node u's current replica set (the
        # distinct parts u is a halo row of) lives unsorted at
        # rep_buf[indptr[u] : indptr[u] + rep_len[u]] — capacity deg(u)
        # suffices because every entry is the part of some assigned
        # neighbor.  O(E) total, vs the dense (num_parts, n) bool.
        rep_buf = np.zeros(len(indices), np.int32)
        rep_len = np.zeros(n, np.int64)

    for v in order:
        nbrs = indices[indptr[v]:indptr[v + 1]]
        counts = np.zeros(num_parts, np.float64)
        assigned = assign[nbrs]
        valid = assigned >= 0
        anbrs = nbrs[valid]
        if valid.any():
            np.add.at(counts, assigned[valid], 1.0)
        score = counts * (1.0 - sizes / capacity)
        if halo_weight:
            present = counts > 0
            # Marginal Σ_m |halo| of assigning v to p: v becomes a halo
            # row of every other adjacent part, and each assigned
            # neighbor outside p becomes a halo row of p unless it
            # already is one.  The dense form's per-part neighbor term
            # (fresh & out_of_p).sum(axis=1) equals
            #   |anbrs| − counts[p] − #{u : p ∈ replicas(u)}
            # (replica sets never contain the node's own part), so only
            # the candidates' replica arrays are gathered — no column
            # scan of an (M, n) matrix.
            pen = np.full(num_parts, float(present.sum()))
            pen -= present
            if len(anbrs):
                pen += len(anbrs) - counts
                reps, _ = _ragged_take(rep_buf, indptr[anbrs],
                                       rep_len[anbrs])
                if len(reps):
                    pen -= np.bincount(reps, minlength=num_parts)
            score = score - halo_weight * pen
            score[sizes >= capacity] = -np.inf
        # Tie-break toward the emptiest part for balance.
        score += 1e-9 * (capacity - sizes)
        best = int(np.argmax(score))
        assign[v] = best
        sizes[best] += 1
        if halo_weight and len(anbrs):
            au = assign[anbrs]
            other = au != best
            if other.any():
                # v is now a halo row of every other adjacent part …
                mine = np.unique(au[other]).astype(np.int32)
                s = indptr[v]
                rep_buf[s:s + len(mine)] = mine
                rep_len[v] = len(mine)
                # … and each out-of-part assigned neighbor becomes a
                # halo row of `best` unless it already is one.
                targets = anbrs[other]
                reps, src = _ragged_take(rep_buf, indptr[targets],
                                         rep_len[targets])
                has = np.zeros(len(targets), bool)
                if len(reps):
                    has[src[reps == best]] = True
                fresh_t = targets[~has]
                rep_buf[indptr[fresh_t] + rep_len[fresh_t]] = best
                rep_len[fresh_t] += 1
    return assign


# Chunk geometry the RCM ordering guard scores candidates at when the
# caller does not thread its own (mirrors kernels.spmm.STREAM_CHUNK_ROWS;
# prepare_graph_data passes the actual build knob through).
ORDER_GUARD_CHUNK_ROWS = 512
# Output rows per kernel row block (mirrors kernels.spmm.BLOCK_ROWS).
ORDER_BLOCK_ROWS = 128

LOCAL_ORDERS = ("none", "rcm")


def reverse_cuthill_mckee(indptr: np.ndarray, indices: np.ndarray
                          ) -> np.ndarray:
    """Deterministic RCM ordering of a CSR graph; returns a permutation
    ``perm`` such that ``perm[i]`` is the old index of new row i.

    Classic Cuthill–McKee — BFS from the minimum-degree node of each
    component (ties by lowest id), neighbors enqueued in ascending
    (degree, id) order — reversed.  Consecutive rows of the reordered
    matrix then share neighborhoods (small bandwidth), which is what
    clusters the (row_block x chunk) occupancy of the streamed halo
    kernels."""
    n = len(indptr) - 1
    deg = np.diff(indptr)
    visited = np.zeros(n, bool)
    order = np.empty(n, np.int64)
    seeds = np.lexsort((np.arange(n), deg))   # min degree first, ties by id
    si = 0
    pos = 0
    while pos < n:
        while visited[seeds[si]]:
            si += 1
        root = seeds[si]
        visited[root] = True
        order[pos] = root
        head, pos = pos, pos + 1
        while head < pos:
            v = order[head]
            head += 1
            ns = indices[indptr[v]:indptr[v + 1]]
            new = ns[~visited[ns]]
            if len(new):
                new = new[np.lexsort((new, deg[new]))]
                visited[new] = True
                order[pos:pos + len(new)] = new
                pos += len(new)
    return order[::-1].copy()


def _induced_csr(loc: np.ndarray, g2l: np.ndarray, indptr: np.ndarray,
                 indices: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """CSR of the subgraph induced on ``loc`` (ascending global ids), in
    local indices; ``g2l`` maps global id → local index (−1 outside)."""
    lens = indptr[loc + 1] - indptr[loc]
    flat, src = _ragged_take(indices, indptr[loc], lens)
    lcols = g2l[flat]
    keep = lcols >= 0
    rows_l = src[keep]
    cols_l = lcols[keep]
    new_indptr = np.zeros(len(loc) + 1, np.int64)
    new_indptr[1:] = np.cumsum(np.bincount(rows_l, minlength=len(loc)))
    return new_indptr, cols_l.astype(np.int64)


def _visited_pairs(loc_rows: np.ndarray, halo_pos: np.ndarray,
                   n_blocks: int, n_chunks: int, chunk_rows: int) -> int:
    """# of distinct (row_block, slab_chunk) pairs the out-edges of one
    part occupy — exactly ``ChunkWorklist.visited_chunks`` for that part
    at the same geometry (real references only; padding/sentinel rows
    reference nothing)."""
    if len(loc_rows) == 0:
        return 0
    blocks = np.minimum(loc_rows // ORDER_BLOCK_ROWS, n_blocks - 1)
    key = blocks.astype(np.int64) * n_chunks + halo_pos // chunk_rows
    return len(np.unique(key))


def edge_cut(g: Graph, assign: np.ndarray) -> int:
    rows = np.repeat(np.arange(g.num_nodes), g.degrees().astype(np.int64))
    cols = g.indices
    return int(np.sum(assign[rows] != assign[cols]) // 2)


PARTITIONERS = {"greedy": greedy_partition, "random": random_partition,
                "metis": greedy_partition}


def parts_per_device(num_parts: int, num_devices: int,
                     what: str = "collective halo exchange") -> int:
    """k = num_parts / num_devices — owner shards (and subgraphs) on each
    exchange-axis device under the collective halo paths.

    ``num_devices`` counts every mesh axis the exchange shards M over:
    the "data" axis alone on a single-pod mesh, pods · data on the
    multi-pod ("pod", "data") mesh (see
    ``halo_exchange.exchange_axes``).  The collective pull/push block
    the owner-sharded slot space (and the PullPlan) into k contiguous
    shards per device, so any M that is a *multiple* of the device
    count works (M > pod size = parts-per-device > 1).  A non-multiple
    M would silently corrupt the owner-local slot math (a device could
    not tell where its shards start), so it is rejected loudly instead
    — this is the single authoritative check;
    ``halo_exchange.shards_per_device`` and
    ``StackedPartitions.shards_per_device`` both delegate here.
    """
    if num_devices <= 0 or num_parts % num_devices != 0:
        raise ValueError(
            f"{what}: num_parts={num_parts} must be a whole multiple of "
            f"the mesh exchange axes ({num_devices} devices — the "
            f"\"data\" axis, times \"pod\" on a multi-pod mesh) — each "
            f"device owns k = num_parts/{num_devices} contiguous "
            f"shards, but {num_parts} % {max(num_devices, 1)} = "
            f"{num_parts % num_devices if num_devices > 0 else num_parts}"
            f".  Use a part count divisible by the device count, or the "
            f"dense-gather fallback (pull_slab / push / "
            f"pull_mode='gather'), which is correct on any device count.")
    return num_parts // num_devices


def partition_report(g: Graph, sp: "StackedPartitions",
                     chunk_rows: int = ORDER_GUARD_CHUNK_ROWS,
                     row_bytes: int = 256) -> dict:
    """Partition quality by what the compact store actually pays for.

    Edge cut is the classic METIS objective, but §3.3's wire cost scales
    with Σ_m |halo(G_m)| (rows pulled per sync) and the store residency
    with |boundary| (union of halos) — two partitions with equal cut can
    differ a lot on both.  Reported side by side so fig9 scores the real
    cost drivers.

    The worklist columns score the *locality* of the layout, not just its
    size: ``wl_occupancy`` is the stacked :class:`ChunkWorklist` fraction
    of (row_block × chunk) pairs the streamed halo kernels must visit at
    ``chunk_rows`` geometry (below ``SKIP_OCCUPANCY_MAX`` the skip kernel
    is auto-selected), and ``stream_bytes_skip`` / ``stream_bytes_dense``
    estimate the per-layer slab traffic of the skip vs dense stream
    (visited resp. all chunks × ``chunk_rows`` slab rows × ``row_bytes``
    per row — default 256 B = the 64-wide fp32 hidden slab).
    """
    sizes = sp.local_valid.sum(axis=1).astype(np.float64)
    wl = sp.chunk_worklist(chunk_rows, block_rows=ORDER_BLOCK_ROWS)
    chunk_bytes = chunk_rows * row_bytes
    return {
        "edge_cut": edge_cut(g, sp.assign),
        "halo_rows": sp.pull_rows(),              # Σ_m |halo(G_m)|
        "boundary": sp.num_boundary,              # |∪_m halo(G_m)|
        "boundary_frac": sp.boundary_fraction(),
        "balance": float(sizes.max() / max(sizes.mean(), 1.0)),
        "order": sp.order,
        "wl_occupancy": wl.occupancy,
        "wl_visited": wl.visited_chunks,
        "wl_total": wl.total_pairs,
        "stream_bytes_skip": wl.visited_chunks * chunk_bytes,
        "stream_bytes_dense": wl.total_pairs * chunk_bytes,
    }


# ---------------------------------------------------------------------------
# Streamed-kernel occupancy worklist
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ChunkWorklist:
    """Static (row-block × slab-chunk) occupancy of a streamed halo SpMM.

    The chunk-skipping kernel (``repro.kernels.spmm.halo_spmm_skip_pallas``)
    re-indexes the innermost grid dimension of the streamed pull+aggregate
    through this CSR-style worklist: row block i visits exactly the chunks
    ``ids[..., i, :cnt[..., i]]`` (ascending), instead of all
    ``n_chunks`` — owner-sharded halo references are strongly clustered
    by owner, so most (row_block, chunk) pairs reference nothing and DMA-
    ing them is pure waste.  ``ids`` is padded to the static
    ``max_chunks`` width with a *repeat of the last visited chunk* (0 for
    empty blocks), so padded grid steps re-address the block already in
    VMEM (no new DMA) and are masked out of the FMA by ``t >= cnt``.

    Computed once at partition time from the halo tables (numpy, host
    side); geometry must match the kernel call: ``block_rows`` rows per
    row block after the caller pads rows up to a ``block_rows`` multiple,
    ``chunk_rows``-row slab chunks over the (H+1)-row slab.
    """

    chunk_rows: int          # slab rows per streamed chunk
    block_rows: int          # output rows per row block (kernel BLOCK_ROWS)
    n_chunks: int            # ceil(slab_rows / chunk_rows)
    max_chunks: int          # static padded worklist width (grid dim)
    ids: np.ndarray          # (..., n_row_blocks, max_chunks) int32
    cnt: np.ndarray          # (..., n_row_blocks) int32 — valid prefix len

    @property
    def visited_chunks(self) -> int:
        """Σ chunk visits — what the skip kernel actually streams."""
        return int(self.cnt.sum())

    @property
    def total_pairs(self) -> int:
        """row_blocks × n_chunks (× M) — what the dense stream pays."""
        return int(np.prod(self.cnt.shape) * self.n_chunks)

    @property
    def occupancy(self) -> float:
        """visited / total — the static kernel-selection signal."""
        return self.visited_chunks / max(self.total_pairs, 1)


def build_chunk_worklist(nbr: np.ndarray, n_slab_rows: int,
                         chunk_rows: int, block_rows: int = 128
                         ) -> ChunkWorklist:
    """Occupancy worklist of an ELL adjacency against a slab.

    Args:
      nbr: (rows, deg) or (M, rows, deg) slab-row indices; the sentinel
        row ``n_slab_rows - 1`` (the zero row every padding entry points
        at) is excluded — chunks referenced only through it contribute
        exactly zero and are skipped.
      n_slab_rows: gather-table rows *before* chunk padding (H+1).
      chunk_rows / block_rows: streamed-kernel tile geometry; rows are
        assumed padded up to a ``block_rows`` multiple by the caller
        (``repro.kernels.spmm.ops`` pads to 128 = BLOCK_ROWS), extra rows
        referencing nothing.
    """
    nbr = np.asarray(nbr)
    stacked = nbr.ndim == 3
    batch = nbr.shape[0] if stacked else 1
    rows = nbr.shape[-2]
    n_blocks = max(-(-rows // block_rows), 1)
    n_chunks = max(-(-n_slab_rows // chunk_rows), 1)
    sentinel = n_slab_rows - 1

    flat = nbr.reshape(batch, rows, -1)
    block_of = np.minimum(np.arange(rows) // block_rows, n_blocks - 1)
    occ = np.zeros((batch, n_blocks, n_chunks), bool)
    for m in range(batch):
        valid = flat[m] < sentinel
        b = np.broadcast_to(block_of[:, None], flat[m].shape)[valid]
        occ[m, b, flat[m][valid] // chunk_rows] = True

    cnt = occ.sum(axis=2).astype(np.int32)
    max_chunks = max(int(cnt.max()), 1)
    ids = np.zeros((batch, n_blocks, max_chunks), np.int32)
    for m in range(batch):
        for i in range(n_blocks):
            ch = np.where(occ[m, i])[0]
            ids[m, i, :len(ch)] = ch
            # Pad with the last visited chunk: the pipeline re-addresses
            # the resident block instead of DMA-ing a fresh one.
            ids[m, i, len(ch):] = ch[-1] if len(ch) else 0
    if not stacked:
        ids, cnt = ids[0], cnt[0]
    return ChunkWorklist(chunk_rows=chunk_rows, block_rows=block_rows,
                         n_chunks=n_chunks, max_chunks=max_chunks,
                         ids=ids, cnt=cnt)


# ---------------------------------------------------------------------------
# Stacked per-subgraph views
# ---------------------------------------------------------------------------

def build_pull_plan(halo_slots: np.ndarray, halo_valid: np.ndarray,
                    halo_size: int, shard_rows: int) -> "PullPlan":
    """Ragged per-(owner, requester) collective-pull routing over ANY
    owner-sharded slot layout (see :class:`PullPlan`).

    The only layout facts the plan depends on are that slots are grouped
    in M contiguous shards of ``shard_rows`` rows (owner = slot //
    shard_rows) with the owner's zero sentinel at the shard's last row —
    so the same builder routes both the training store (boundary rows
    only, ``StackedPartitions.pull_plan``) and the all-node serving
    store (``repro.core.serving.build_serve_plan``), which lay slots out
    differently but share the shard/sentinel convention.

    halo_slots: (M, H) slot of each halo entry (any value where invalid);
    halo_valid: (M, H) bool; padding pairs route owner-sentinel rows into
    the slab's sentinel position ``halo_size``.
    """
    M = halo_slots.shape[0]
    sr = shard_rows
    owner_of = halo_slots // sr                       # (M, H)
    counts = np.zeros((M, M), np.int64)
    for m in range(M):
        np.add.at(counts[m], owner_of[m][halo_valid[m]], 1)
    K = max(int(counts.max()), 1)
    send_off = np.full((M, M, K), sr - 1, np.int32)
    recv_pos = np.full((M, M, K), halo_size, np.int32)
    for m in range(M):                                # requester
        for j in range(M):                            # owner
            sel = np.where(halo_valid[m] & (owner_of[m] == j))[0]
            send_off[j, m, :len(sel)] = halo_slots[m, sel] - j * sr
            recv_pos[m, j, :len(sel)] = sel
    return PullPlan(max_rows=K, send_offsets=send_off,
                    recv_positions=recv_pos)


@dataclasses.dataclass
class PullPlan:
    """Ragged per-(owner, requester) routing of the collective halo pull.

    For every requester m and owner j, the plan lists which rows of owner
    j's store *shard* feed subgraph m's halo slab, padded to a common
    width ``max_rows`` so the exchange is one dense ``all_to_all``:

      send_offsets[j, m, k]   owner-local row offset (< shard_rows) of the
                              k-th row owner j ships to requester m;
                              padding points at owner j's zero sentinel.
      recv_positions[m, j, k] halo-slab position (< H+1) where requester m
                              lands that row; padding points at slab row H
                              (the slab's zero sentinel).

    Both tables are **device-blockable**: offsets are owner-local and
    positions requester-local, so sharding the leading axis over a mesh
    data axis of D devices hands each device the k = M/D contiguous
    (owner-block, requester-block) slices it needs — this is what lets
    ``collective_pull``/``shard_push`` run with parts-per-device > 1
    (M exceeding the pod size) without rebuilding the plan.
    """

    max_rows: int                 # K — padded per-pair row count
    send_offsets: np.ndarray      # (M_owner, M_req, K) int32
    recv_positions: np.ndarray    # (M_req, M_owner, K) int32


@dataclasses.dataclass
class StackedPartitions:
    """All M subgraphs padded to identical sizes and stacked on axis 0.

    Sentinel id == num_nodes (a zero row is appended to every global table).

    Boundary / compact-store views: the **boundary set** is the union of
    all subgraph halos — the only rows the stale store ever serves.  Slots
    are **owner-sharded**: every boundary node is owned by the part it is
    local to, and the slot space is laid out as M contiguous shards of
    ``shard_rows`` rows each (``slot = owner · shard_rows + rank``), the
    last row of every shard a per-owner zero sentinel.  Device m of a
    "data"-sharded mesh therefore holds exactly the rows it pushes, and a
    pull is a collective gather of each subgraph's halo slots from the
    owner shards (see ``repro.core.halo_exchange``).  ``store_map`` sends
    non-boundary ids (and the global sentinel id N) to the *global*
    sentinel slot ``M·shard_rows − 1``.
    """

    num_nodes: int
    num_parts: int
    num_boundary: int        # |boundary| — true boundary nodes, no padding
    shard_rows: int          # rows per owner shard (incl. its sentinel row)
    assign: np.ndarray       # (N,) int32 node → owning part
    local_ids: np.ndarray    # (M, S) int32, global node id or sentinel
    local_valid: np.ndarray  # (M, S) bool
    halo_ids: np.ndarray     # (M, H) int32, global node id or sentinel
    halo_valid: np.ndarray   # (M, H) bool
    in_nbr: np.ndarray       # (M, S, Din) int32 → local slot index or S
    in_wts: np.ndarray       # (M, S, Din) float32
    out_nbr: np.ndarray      # (M, S, Dout) int32 → halo slot index or H
    out_wts: np.ndarray      # (M, S, Dout) float32
    labels: np.ndarray       # (M, S) int32
    train_mask: np.ndarray   # (M, S) bool (False at padding)
    val_mask: np.ndarray     # (M, S) bool
    test_mask: np.ndarray    # (M, S) bool
    # Owner-sharded compact-store indexing, emitted for HaloExchange.
    store_map: np.ndarray    # (N+1,) int32 global id → slot (sentinel: R-1)
    store_ids: np.ndarray    # (R,) int32 slot → global id, N at pad rows
    store_owner: np.ndarray  # (R,) int32 slot → owner part
    sentinel_slots: np.ndarray  # (M,) int32 per-part sentinel slot
    halo_slots: np.ndarray   # (M, H) int32 store slot of each halo entry
    local_slots: np.ndarray  # (M, S) int32 store slot of each local row
                             #   (part m's sentinel where not boundary)
    local_boundary: np.ndarray  # (M, S) bool valid AND boundary (served)
    out_nbr_store: np.ndarray   # (M, S, Dout) int32 → store slot or R-1
    out_nbr_global: np.ndarray  # (M, S, Dout) int32 → global id or N
    order: str = "none"      # local-row layout knob build_partitions used

    @property
    def part_size(self) -> int:
        return self.local_ids.shape[1]

    @property
    def halo_size(self) -> int:
        return self.halo_ids.shape[1]

    @property
    def store_rows(self) -> int:
        """Total slab rows R = num_parts · shard_rows (incl. sentinels)."""
        return len(self.store_ids)

    def halo_ratio(self) -> np.ndarray:
        """Paper Fig. 9 metric: |out-of-subgraph| / |in-subgraph| per part."""
        return (self.halo_valid.sum(axis=1)
                / np.maximum(self.local_valid.sum(axis=1), 1))

    def boundary_fraction(self) -> float:
        """|boundary| / N — the compact-vs-dense store row ratio."""
        return self.num_boundary / max(self.num_nodes, 1)

    def push_rows(self) -> int:
        """Σ_m |boundary ∩ V_m| — rows shipped per PUSH sync (§3.3)."""
        return int(self.local_boundary.sum())

    def pull_rows(self) -> int:
        """Σ_m |halo(G_m)| — rows shipped per PULL sync (§3.3)."""
        return int(self.halo_valid.sum())

    def shards_per_device(self, num_devices: int) -> int:
        """k = M / num_devices under the collective paths; raises the
        spelled-out ValueError of :func:`parts_per_device` when M is not
        a multiple (the collective slot math would silently be wrong;
        the dense-gather fallback is the correct choice there)."""
        return parts_per_device(self.num_parts, num_devices)

    def chunk_worklist(self, chunk_rows: int, block_rows: int = 128
                       ) -> ChunkWorklist:
        """Per-subgraph (row_block × chunk) occupancy of the out-ELL
        against the (H+1)-row pulled halo slab (see
        :class:`ChunkWorklist`): ids (M, n_blocks, max_chunks),
        cnt (M, n_blocks)."""
        return build_chunk_worklist(self.out_nbr, self.halo_size + 1,
                                    chunk_rows, block_rows)

    def pull_plan(self) -> PullPlan:
        """Ragged collective-pull routing (see :class:`PullPlan`)."""
        return build_pull_plan(self.halo_slots, self.halo_valid,
                               self.halo_size, self.shard_rows)


def build_partitions(g: Graph, num_parts: int, method: str = "greedy",
                     seed: int = 0, pad_multiple: int = 8,
                     halo_weight: float = 0.0, order: str = "none",
                     order_chunk_rows: int = None) -> StackedPartitions:
    """Partition ``g`` into the stacked per-subgraph views.

    ``order`` selects the local-row layout of every part: ``"none"``
    keeps ascending global ids; ``"rcm"`` reorders each part's rows by
    reverse Cuthill–McKee over its induced subgraph (and re-lays the
    halo slab's owner runs by first-referencing row) so consecutive
    ``ORDER_BLOCK_ROWS``-row blocks reference clustered slab chunks —
    a pure local-row permutation that drives :class:`ChunkWorklist`
    occupancy down (see the module docstring).  Each part keeps its
    identity order unless RCM strictly helps at the ``order_chunk_rows``
    guard geometry (default ``ORDER_GUARD_CHUNK_ROWS``; pass the same
    ``chunk_rows`` the epoch streams with), so occupancy never
    increases.
    """
    if order not in LOCAL_ORDERS:
        raise ValueError(f"order={order!r} not in {LOCAL_ORDERS}")
    assign = PARTITIONERS[method](g, num_parts, seed=seed,
                                  halo_weight=halo_weight)
    n = g.num_nodes
    rows, cols, wts = gcn_norm_weights(g)

    def _pad_to(x: int) -> int:
        return max(((x + pad_multiple - 1) // pad_multiple) * pad_multiple,
                   pad_multiple)

    parts_local = [np.where(assign == m)[0].astype(np.int32)
                   for m in range(num_parts)]
    # Halo = out-of-subgraph endpoints of P rows owned by the part,
    # ordered by (owner, ...): each subgraph's halo slab is then laid out
    # as contiguous owner runs — the slab-side mirror of the owner-
    # sharded store.  Local rows referencing few owners touch few slab
    # ranges, which is what makes the streamed kernel's (row_block ×
    # chunk) worklist sparse (gathers do no arithmetic, and the per-row
    # ELL edge order is untouched, so results are bitwise identical for
    # any slab-run layout).  Within each owner run the rows sort by id
    # (order="none") or by first-referencing local row (order="rcm" —
    # keeping a block's references contiguous in the slab).
    e_part = assign[rows]
    parts_out = []               # per-part out-edge COO (global ids)
    parts_halo = []
    for m in range(num_parts):
        sel = e_part == m
        out = assign[cols[sel]] != m
        parts_out.append((rows[sel][out], cols[sel][out]))
        parts_halo.append(np.unique(cols[sel][out]).astype(np.int32))

    S = _pad_to(max(len(p) for p in parts_local))
    H = _pad_to(max((len(h) for h in parts_halo), default=1))

    chunk_rows = (ORDER_GUARD_CHUNK_ROWS if order_chunk_rows is None
                  else order_chunk_rows)
    n_blocks = max(-(-S // ORDER_BLOCK_ROWS), 1)
    n_chunks = max(-(-(H + 1) // chunk_rows), 1)
    for m in range(num_parts):
        loc, halo = parts_local[m], parts_halo[m]
        r_out, c_out = parts_out[m]
        owners = assign[halo]
        # Candidate A — identity: ascending local ids, owner runs by id.
        halo_a = halo[np.lexsort((halo, owners))]
        if order != "rcm" or len(loc) == 0:
            parts_halo[m] = halo_a
            continue
        g2l = np.full(n, -1, np.int64)
        g2l[loc] = np.arange(len(loc))
        # Candidate B — RCM local rows + first-ref slab runs.
        ip_l, ix_l = _induced_csr(loc.astype(np.int64), g2l, g.indptr,
                                  g.indices)
        perm = reverse_cuthill_mckee(ip_l, ix_l)
        loc_b = loc[perm]
        pos_b = np.full(n, -1, np.int64)
        pos_b[loc_b] = np.arange(len(loc))
        rows_b = pos_b[r_out]
        hidx = np.searchsorted(halo, c_out)
        first_ref = np.full(len(halo), S, np.int64)
        if len(c_out):
            np.minimum.at(first_ref, hidx, rows_b)
        halo_b = halo[np.lexsort((halo, first_ref, owners))]
        # Keep whichever candidate the streamed kernels visit fewer
        # (row_block × chunk) pairs under — RCM only ever on a win, so
        # the stacked worklist occupancy is non-increasing vs "none".
        pos_ha = np.full(n, -1, np.int64)
        pos_ha[halo_a] = np.arange(len(halo))
        pos_hb = np.full(n, -1, np.int64)
        pos_hb[halo_b] = np.arange(len(halo))
        v_a = _visited_pairs(g2l[r_out], pos_ha[c_out], n_blocks,
                             n_chunks, chunk_rows)
        v_b = _visited_pairs(rows_b, pos_hb[c_out], n_blocks, n_chunks,
                             chunk_rows)
        if v_b <= v_a:
            parts_local[m] = loc_b
            parts_halo[m] = halo_b
        else:
            parts_halo[m] = halo_a

    local_ids = np.full((num_parts, S), n, np.int32)
    local_valid = np.zeros((num_parts, S), bool)
    halo_ids = np.full((num_parts, H), n, np.int32)
    halo_valid = np.zeros((num_parts, H), bool)
    in_ells, out_ells = [], []
    max_din, max_dout = 1, 1

    for m in range(num_parts):
        loc, halo = parts_local[m], parts_halo[m]
        local_ids[m, :len(loc)] = loc
        local_valid[m, :len(loc)] = True
        halo_ids[m, :len(halo)] = halo
        halo_valid[m, :len(halo)] = True

        g2l = np.full(n + 1, S, np.int64)   # global → local slot
        g2l[loc] = np.arange(len(loc))
        g2h = np.full(n + 1, H, np.int64)   # global → halo slot
        g2h[halo] = np.arange(len(halo))

        sel = assign[rows] == m
        r_m, c_m, w_m = rows[sel], cols[sel], wts[sel]
        local_rows = g2l[r_m].astype(np.int32)
        is_in = assign[c_m] == m

        ell_in = coo_to_ell(local_rows[is_in],
                            g2l[c_m[is_in]].astype(np.int32),
                            w_m[is_in], S, S)
        ell_out = coo_to_ell(local_rows[~is_in],
                             g2h[c_m[~is_in]].astype(np.int32),
                             w_m[~is_in], S, H)
        in_ells.append(ell_in)
        out_ells.append(ell_out)
        max_din = max(max_din, ell_in.max_degree)
        max_dout = max(max_dout, ell_out.max_degree)

    max_din, max_dout = _pad_to(max_din), _pad_to(max_dout)

    def _stack(ells: list[EllMatrix], deg: int, n_cols: int):
        nbr = np.full((num_parts, S, deg), n_cols, np.int32)
        w = np.zeros((num_parts, S, deg), np.float32)
        for m, e in enumerate(ells):
            nbr[m, :, :e.max_degree] = e.nbr
            w[m, :, :e.max_degree] = e.wts
        return nbr, w

    in_nbr, in_wts = _stack(in_ells, max_din, S)
    out_nbr, out_wts = _stack(out_ells, max_dout, H)

    labels = np.zeros((num_parts, S), np.int32)
    tr = np.zeros((num_parts, S), bool)
    va = np.zeros((num_parts, S), bool)
    te = np.zeros((num_parts, S), bool)
    for m, loc in enumerate(parts_local):
        labels[m, :len(loc)] = g.labels[loc]
        tr[m, :len(loc)] = g.train_mask[loc]
        va[m, :len(loc)] = g.val_mask[loc]
        te[m, :len(loc)] = g.test_mask[loc]

    # Boundary set = union of all halos, laid out **owner-sharded**: part
    # m's locally-owned boundary nodes occupy the contiguous slot range
    # [m·shard_rows, m·shard_rows + |owned_m|), the last row of each shard
    # is that owner's zero sentinel, and the global sentinel (non-boundary
    # ids and id n) is the last row of the last shard.  Sharding the slab
    # slot-wise over the mesh "data" axis then gives every device exactly
    # the rows it pushes; pulls gather from the owner shards.
    boundary = (np.unique(np.concatenate(parts_halo))
                if any(len(h) for h in parts_halo)
                else np.empty(0, np.int32)).astype(np.int32)
    B = len(boundary)
    owned = [np.sort(boundary[assign[boundary] == m])
             for m in range(num_parts)]
    shard_rows = _pad_to(max((len(o) for o in owned), default=0) + 1)
    R = num_parts * shard_rows
    store_map = np.full(n + 1, R - 1, np.int32)
    store_ids = np.full(R, n, np.int32)
    store_owner = np.repeat(np.arange(num_parts, dtype=np.int32),
                            shard_rows)
    for m, o in enumerate(owned):
        slots = m * shard_rows + np.arange(len(o), dtype=np.int32)
        store_map[o] = slots
        store_ids[slots] = o
    sentinel_slots = ((np.arange(num_parts, dtype=np.int32) + 1)
                      * shard_rows - 1)
    halo_slots = store_map[halo_ids]
    raw_slots = store_map[local_ids]
    local_boundary = local_valid & (raw_slots != R - 1)
    # Non-boundary / padding local rows push into the *owner's* sentinel
    # row so scatters never leave the device-local shard.
    local_slots = np.where(local_boundary, raw_slots,
                           sentinel_slots[:, None]).astype(np.int32)

    # Per-part remaps of the out-ELL: halo-slot → store-slot / global id,
    # so the out-of-subgraph product can gather straight from the shared
    # compact slab (or from x_global for layer 0) with no per-part table.
    out_nbr_store = np.empty_like(out_nbr)
    out_nbr_global = np.empty_like(out_nbr)
    for m in range(num_parts):
        ext_s = np.concatenate([halo_slots[m], [R - 1]]).astype(np.int32)
        ext_g = np.concatenate([halo_ids[m], [n]]).astype(np.int32)
        out_nbr_store[m] = ext_s[out_nbr[m]]
        out_nbr_global[m] = ext_g[out_nbr[m]]

    return StackedPartitions(
        num_nodes=n, num_parts=num_parts, num_boundary=B,
        shard_rows=shard_rows, assign=assign,
        local_ids=local_ids, local_valid=local_valid,
        halo_ids=halo_ids, halo_valid=halo_valid,
        in_nbr=in_nbr, in_wts=in_wts, out_nbr=out_nbr, out_wts=out_wts,
        labels=labels, train_mask=tr, val_mask=va, test_mask=te,
        store_map=store_map, store_ids=store_ids, store_owner=store_owner,
        sentinel_slots=sentinel_slots,
        halo_slots=halo_slots, local_slots=local_slots,
        local_boundary=local_boundary,
        out_nbr_store=out_nbr_store, out_nbr_global=out_nbr_global,
        order=order)
