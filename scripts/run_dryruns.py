#!/usr/bin/env python
"""Run the full dry-run matrix (10 archs x 4 shapes x 2 meshes) as isolated
subprocesses with per-case timeouts and skip-unrolled fallback.

Single-pod cases get the dual (scan + unrolled) pass for true roofline
costs; multi-pod cases prove lowering/sharding coherence with the fast
scan pass (costs rescaled by layer count).
"""
import json
import os
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))

ARCHS_CHEAP = ["qwen3-0.6b", "musicgen-large", "phi3-mini-3.8b",
               "xlstm-1.3b"]
ARCHS_MED = ["minitron-8b", "recurrentgemma-9b", "llama-3.2-vision-11b"]
ARCHS_BIG = ["deepseek-coder-33b", "llama4-scout-17b-a16e",
             "kimi-k2-1t-a32b"]
SHAPES = ["decode_32k", "long_500k", "prefill_32k", "train_4k"]


def run_case(arch, shape, multi, out, skip_unrolled, timeout):
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape,
           "--multi-pod", "multi" if multi else "single",
           "--out", out]
    if skip_unrolled:
        cmd.append("--skip-unrolled")
    t0 = time.time()
    try:
        rc = subprocess.call(cmd, env=ENV, timeout=timeout,
                             stdout=subprocess.DEVNULL,
                             stderr=subprocess.DEVNULL)
    except subprocess.TimeoutExpired:
        rc = -9
    print(f"{arch:26s} {shape:12s} multi={int(multi)} "
          f"skip_unrolled={int(skip_unrolled)} rc={rc} "
          f"{time.time()-t0:6.1f}s", flush=True)
    return rc


def main():
    os.makedirs(os.path.join(ROOT, "results"), exist_ok=True)
    single_out = os.path.join(ROOT, "results", "dryrun_single.jsonl")
    multi_out = os.path.join(ROOT, "results", "dryrun_multi.jsonl")
    failures = []

    # Phase 1: single-pod, cheap->big, dual pass w/ fallback.
    for arch in ARCHS_CHEAP + ARCHS_MED + ARCHS_BIG:
        for shape in SHAPES:
            big = arch in ARCHS_BIG
            timeout = 2400 if big else 1500
            rc = run_case(arch, shape, False, single_out,
                          skip_unrolled=False, timeout=timeout)
            if rc != 0:
                rc = run_case(arch, shape, False, single_out,
                              skip_unrolled=True, timeout=900)
                if rc != 0:
                    failures.append((arch, shape, "single"))

    # Phase 2: multi-pod, scan-only (proves the pod axis shards).
    for arch in ARCHS_CHEAP + ARCHS_MED + ARCHS_BIG:
        for shape in SHAPES:
            rc = run_case(arch, shape, True, multi_out,
                          skip_unrolled=True, timeout=1800)
            if rc != 0:
                failures.append((arch, shape, "multi"))

    print("FAILURES:", json.dumps(failures))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
