"""DIGEST-A — asynchronous, non-blocking distributed GNN training.

The paper's async mode removes the global round barrier: each subgraph
worker fetches current server parameters, trains locally against its own
(possibly stale) halo cache, and pushes its update whenever it finishes —
the server applies updates immediately (bounded-delay async SGD, Theorem 3).

There is no wall-clock asynchrony inside one SPMD program, so DIGEST-A is
realized as an **event-driven simulator** over the same jitted per-subgraph
gradient kernel used by the synchronous path: a heap of (finish_time,
worker) events, per-worker compute-time models (including the paper's §5.2
straggler experiment: one worker slowed by a uniform 8–10 s delay), a
simulated clock, and delayed parameter snapshots.  This keeps the *algorithm*
exact while making staleness/delay measurable and deterministic.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt_io
from repro.core import faults as faults_mod
from repro.core import halo_exchange
from repro.core import predictor as predictor_mod
from repro.core.digest import (check_worklist_geometry, evaluate,
                               make_subgraph_loss)
from repro.core.predictor import PredictorConfig
from repro.models.gnn import GNNConfig, gnn_specs
from repro.nn import init_params
from repro.optim import Optimizer

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AsyncSettings:
    sync_interval: int = 10                  # N, counted in worker rounds
    base_round_time: float = 1.0             # sim seconds per worker round
    worker_speed_jitter: float = 0.15        # lognormal jitter of speeds
    straggler: Optional[int] = None          # worker index to slow down
    straggler_delay: tuple[float, float] = (8.0, 10.0)  # paper §5.2
    precision: halo_exchange.HaloPrecision = halo_exchange.HaloPrecision()
    seed: int = 0
    # Round-0 push of every worker's initial representations.  The pull
    # and push cadences are offset (pull at r % N == 0, push at
    # (r-1) % N == 0), so without the warm start a fast worker's first
    # pull at r = N can read never-pushed all-zero rows from a shard
    # whose owner (e.g. the straggler) has not finished round 1 yet —
    # silently aggregating zeros.  False reproduces the cold-store
    # behavior (the regression test's positive control).
    warm_start: bool = True
    # Deterministic fault injection (repro.core.faults.FaultConfig):
    # crashes with restart-after-k-rounds, dropped pushes with
    # retry-with-backoff, delayed pulls (degraded to the last-known-good
    # cache), and corrupted-then-CRC-rejected wire rows.  None (or an
    # all-zero-rate config) leaves the trajectory bitwise identical.
    faults: Optional[faults_mod.FaultConfig] = None
    # Bounded-staleness watchdog, measured in SERVER STEPS (the unit of
    # the delay/staleness the probe reports): when any valid halo slot a
    # pull is about to read is >= max_staleness steps old, the owner's
    # latest computed representations are force-applied to the store
    # (a blocking resync) before the pull proceeds.  None disables.
    max_staleness: Optional[int] = None
    # SAT staleness-alleviated prediction (repro.core.predictor): every
    # ACCEPTED push (warm start, cadence, retries, forced resyncs)
    # advances the owner's history and writes the delta rows into a
    # second store-shaped pstore; pulls then read
    # dequant(store) + γ·dequant(pstore).  kind="none" leaves the
    # simulator bitwise identical to a predictor-free run.
    predictor: PredictorConfig = PredictorConfig()


def store_geometry(data: dict) -> tuple[int, int]:
    """(num_slots, shard_rows) of the owner-sharded store for a prepared
    data dict — audited against the per-shard sentinel layout.

    The store has R = M·shard_rows rows, slot = owner·shard_rows + rank,
    with each shard's last row its zero sentinel
    (``sentinel_slots[m] = (m+1)·shard_rows − 1``); ``init_store`` takes
    ``num_slots = R − 1`` and appends the global sentinel as row R−1 —
    which *is* shard M−1's sentinel, so the async simulator's store is
    byte-compatible with the SPMD epoch's (:func:`repro.core.digest.
    init_state`), a property pinned by tests/test_async_engine.py.
    Raises if the data dict's slot views do not satisfy the layout."""
    total_rows = int(data["store_ids"].shape[0])
    num_parts = int(data["local_slots"].shape[0])
    sentinels = np.asarray(data["sentinel_slots"])
    shard_rows = int(sentinels[0]) + 1
    expect = (np.arange(num_parts) + 1) * shard_rows - 1
    if (total_rows != num_parts * shard_rows
            or not np.array_equal(sentinels, expect)):
        raise ValueError(
            f"owner-sharded store layout violated: {total_rows} rows, "
            f"{num_parts} parts, sentinel_slots={sentinels.tolist()} "
            f"(want (m+1)*shard_rows-1 with shard_rows={shard_rows})")
    return total_rows - 1, shard_rows


def digest_a_train(cfg: GNNConfig, opt: Optimizer, data: dict,
                   settings: AsyncSettings, total_rounds: int,
                   eval_every_rounds: int = 20, seed: int = 0,
                   ckpt_dir: Optional[str] = None,
                   ckpt_every_rounds: int = 0, resume: bool = False
                   ) -> tuple[dict, dict]:
    """Run DIGEST-A; returns (final_state_dict, history).

    history["sim_time"] is the simulated wall clock — the paper's Figure 7
    x-axis — under which async should dominate sync when a straggler exists.
    At each eval tick, ``loss`` is the mean of every worker's most recent
    round loss (not whichever worker happened to land on the tick) and
    ``delay`` the *max* staleness across workers; ``round_loss`` /
    ``round_worker`` log every completed round, ``cold_rows`` the
    running count of all-zero (never-pushed) valid halo rows consumed by
    pulls — 0 under the default warm start — and ``pull_age`` the
    running max age (server steps since the owning worker's last
    accepted push) over the valid halo slots pulls have read: the
    fault-induced component of staleness, measured per-slot from the
    ``last_push_step`` age table rather than inferred.

    Fault semantics (``settings.faults``, all decisions replayable —
    see :mod:`repro.core.faults`): a *crashed* worker skips its round
    and restarts ``crash_rounds`` round-times later, re-fetching server
    params and re-pulling its halo before the next round; a *dropped*
    or *corrupted-and-rejected* push leaves the store at its
    last-known-good rows and the worker retries on later rounds with
    exponential backoff (retries send the CURRENT round's
    representations — fresher than the lost payload); a *delayed* pull
    keeps computing on the stale local cache and re-attempts next
    round.  ``settings.max_staleness`` arms the watchdog documented on
    :class:`AsyncSettings`.  Final counters land in
    ``state["fault_counters"]``.

    ``ckpt_dir`` + ``ckpt_every_rounds`` write an atomic, checksummed
    checkpoint of the COMPLETE simulator state (params, opt state,
    store, per-worker caches/snapshots/residuals, event heap, age
    table, fault bookkeeping, RNG cursor) every N completed rounds;
    ``resume=True`` restores the newest valid one and continues —
    kill-and-resume is bitwise equal to the uninterrupted run.
    """
    check_worklist_geometry(cfg, data)
    rng = np.random.default_rng(settings.seed)
    M = int(data["halo_ids"].shape[0])
    H = int(data["halo_ids"].shape[1])
    L1 = max(cfg.num_layers - 1, 1)
    schedule = faults_mod.check_schedule(settings.faults)
    fcfg = settings.faults or faults_mod.FaultConfig()

    params = init_params(jax.random.PRNGKey(seed), gnn_specs(cfg))
    opt_state = opt.init(params)
    num_slots, shard_rows = store_geometry(data)
    store = halo_exchange.init_store(L1, num_slots, cfg.hidden_dim,
                                     settings.precision)
    halo_cache = [jnp.zeros((L1, H, cfg.hidden_dim), jnp.float32)
                  for _ in range(M)]

    loss_fn = make_subgraph_loss(cfg)

    @jax.jit
    def worker_grad(params, x_loc, x_h0, m_cache, struct, labels, mask):
        # Plain (H, d) tables normalize to halo refs inside the layers
        # (_as_halo_ref), which picks the chunk worklist off the struct
        # dict — so the async engine's aggregation goes through the same
        # occupancy-aware kernel selection as the SPMD epoch.  (GAT's
        # owner-shard projection dedup does NOT apply here: each worker
        # owns a private fp32 cache, and the simulator's per-worker
        # gradient kernel keeps the paper's exact async semantics.)
        def f(p):
            tables = [x_h0] + [m_cache[i] for i in range(cfg.num_layers - 1)]
            return loss_fn(p, x_loc, tables, struct, labels, mask)
        (loss, (push, _)), grads = jax.value_and_grad(f, has_aux=True)(params)
        return loss, grads, push

    @jax.jit
    def apply_update(params, opt_state, grads, step):
        return opt.update(grads, opt_state, params, step)

    # Owner-sharded store: each worker's push is a dynamic-update-slice
    # of exactly its own shard (owner_push) — the write region is bounded
    # by construction instead of relying on the partitioner to keep a
    # whole-slab scatter shard-local.  shard_rows comes from the audited
    # store_geometry above (slot = owner·shard_rows + rank).
    @jax.jit
    def push_rows(store, owner, slots, valid, reps):
        return halo_exchange.owner_push(store, owner, slots, valid, reps,
                                        shard_rows)

    @jax.jit
    def push_rows_ef(store, owner, slots, valid, reps, residual):
        return halo_exchange.owner_push_ef(store, owner, slots, valid,
                                           reps, residual, shard_rows)

    # Per-worker rounding residuals (error-feedback pushes): each worker
    # compensates its own repeated pushes, the motivating async scenario.
    S = int(data["local_ids"].shape[1])
    push_residual = [jnp.zeros((L1, S, cfg.hidden_dim), jnp.float32)
                     for _ in range(M)]

    # SAT predictor state: a second store-shaped pstore + per-worker
    # history (leading axis 1 — update_history's part axis), advanced on
    # every ACCEPTED push so the sequence matches the SPMD engine's
    # shard-local one exactly (pure in the accepted-push sequence).
    pcfg = settings.predictor
    pred = pcfg.enabled and cfg.num_layers > 1
    pstore = (halo_exchange.init_store(L1, num_slots, cfg.hidden_dim,
                                       settings.precision)
              if pred else None)
    phist = ([predictor_mod.init_history(1, L1, S, cfg.hidden_dim)
              for _ in range(M)] if pred else None)

    def apply_accepted_push(m: int, reps):
        """History transition + pstore scatter for one accepted push of
        worker m — warm start, cadence pushes, retries and forced
        resyncs all flow through here (and ONLY accepted ones, so a
        degraded shard's history freezes at last-known-good)."""
        nonlocal pstore
        phist[m], prows = predictor_mod.update_history(
            phist[m], reps[None], jnp.ones((1,), bool), pcfg)
        pstore = halo_exchange.owner_push(
            pstore, jnp.asarray(m, jnp.int32), data["local_slots"][m],
            data["local_valid"][m], prows[0], shard_rows)

    x_local_all = np.asarray(data["x_global"])[np.asarray(data["local_ids"])]
    x_halo_all = np.asarray(data["x_global"])[np.asarray(data["halo_ids"])]

    # Host-side slot views for the per-slot age table and fault paths.
    ls_np = np.asarray(data["local_slots"])
    lv_np = np.asarray(data["local_valid"])
    hs_np = np.asarray(data["halo_slots"])
    hv_np = np.asarray(data["halo_valid"])
    total_rows = (num_slots + 1)
    # Per-slot age table: server step of the last ACCEPTED push that
    # wrote each store row.  Feeds the pull-time staleness measurement
    # and the max_staleness watchdog; pure host bookkeeping, never
    # touches the jitted math.
    last_push_step = np.zeros(total_rows, np.int64)
    # Latest representations each worker computed (the payload a forced
    # resync re-applies) + whether any exist yet.
    last_reps = [jnp.zeros((L1, S, cfg.hidden_dim), jnp.float32)
                 for _ in range(M)]
    has_reps = np.zeros(M, bool)
    # Fault bookkeeping (all inert when no schedule is active).
    push_failed = np.zeros(M, bool)
    retry_at = np.zeros(M, np.int64)       # worker round of next retry
    fail_count = np.zeros(M, np.int64)
    pull_pending = np.zeros(M, bool)       # delayed pull → retry next round
    restarting = np.zeros(M, bool)         # crashed; re-fetch on wake
    counters = {"crashes": 0, "dropped_pushes": 0, "rejected_pushes": 0,
                "retried_pushes": 0, "delayed_pulls": 0,
                "forced_resyncs": 0}
    pull_age_max = 0

    # Resume decides whether the warm start below runs at all: the
    # restored store/caches already contain the (possibly much later)
    # state, so recomputing round-0 pushes would be wasted work.
    resume_step = (ckpt_io.latest_step(ckpt_dir)
                   if (resume and ckpt_dir) else None)

    if settings.warm_start and cfg.num_layers > 1 and resume_step is None:
        # Round-0 PUSH: seed every shard with the representations at the
        # initial parameters before any worker runs — the same bits each
        # worker's own round-1 push will write (round 1 trains against
        # the initial snapshot), so no pull can ever read a never-pushed
        # all-zero row, straggler or not.
        for m in range(M):
            struct_m = {k: v[m] for k, v in data["struct"].items()}
            _, _, push0 = worker_grad(
                params, jnp.asarray(x_local_all[m]),
                jnp.asarray(x_halo_all[m]), halo_cache[m], struct_m,
                data["labels"][m], data["train_mask"][m])
            owner = jnp.asarray(m, jnp.int32)
            if settings.precision.error_feedback:
                store, push_residual[m] = push_rows_ef(
                    store, owner, data["local_slots"][m],
                    data["local_valid"][m], push0, push_residual[m])
            else:
                store = push_rows(store, owner, data["local_slots"][m],
                                  data["local_valid"][m], push0)
            if pred:
                apply_accepted_push(m, push0)
            last_reps[m] = push0
            has_reps[m] = True
            last_push_step[ls_np[m][lv_np[m]]] = 0

    # Per-worker speed model.
    speeds = np.exp(rng.normal(0, settings.worker_speed_jitter, size=M))

    def round_time(m: int) -> float:
        t = settings.base_round_time * speeds[m]
        if settings.straggler is not None and m == settings.straggler:
            t += rng.uniform(*settings.straggler_delay)
        return t

    # Event loop.
    heap = [(round_time(m), m) for m in range(M)]
    heapq.heapify(heap)
    worker_round = np.zeros(M, np.int64)
    step = jnp.asarray(0, jnp.int32)
    hist = {"round": [], "sim_time": [], "loss": [], "val_f1": [],
            "test_f1": [], "delay": [], "round_worker": [],
            "round_loss": [], "cold_rows": [], "pull_age": []}
    snapshot_step = np.zeros(M, np.int64)   # server step when params fetched
    params_snapshots: list = [params] * M
    rounds_done = 0
    # Per-worker trackers backing the eval-tick aggregates: each tick
    # logs the MEAN of every worker's latest round loss and the MAX
    # staleness — a tick used to sample whichever single worker happened
    # to finish last, i.e. per-worker noise, not training state.
    last_loss = np.full(M, np.nan)
    last_delay = np.zeros(M, np.int64)
    cold_rows = 0   # all-zero valid halo rows consumed by pulls (probe)

    tdata = {k: v for k, v in data.items() if not k.startswith("_")}

    def ckpt_tree():
        """The COMPLETE simulator state as one pytree (see docstring).
        The heap always holds exactly one event per worker, so it
        round-trips as two (M,) arrays; heapify of the same multiset
        pops in the same (time, worker) order."""
        hsort = sorted(heap)
        extra = ({"pstore": pstore, "phist": phist} if pred else {})
        return {
            "params": params, "opt_state": opt_state, "store": store,
            "step": step, **extra,
            "halo_cache": halo_cache, "push_residual": push_residual,
            "snapshots": params_snapshots,
            "worker_round": worker_round, "snapshot_step": snapshot_step,
            "last_loss": last_loss, "last_delay": last_delay,
            "heap_t": np.asarray([t for t, _ in hsort], np.float64),
            "heap_m": np.asarray([w for _, w in hsort], np.int64),
            "last_push_step": last_push_step,
            "last_reps": jnp.stack(last_reps), "has_reps": has_reps,
            "push_failed": push_failed, "retry_at": retry_at,
            "fail_count": fail_count, "pull_pending": pull_pending,
            "restarting": restarting,
        }

    if resume_step is not None:
        tree, _ = ckpt_io.restore_checkpoint(ckpt_dir, ckpt_tree(),
                                             step=resume_step)
        meta = ckpt_io.read_manifest(ckpt_dir, resume_step)["meta"]
        params, opt_state, store = (tree["params"], tree["opt_state"],
                                    tree["store"])
        step = jnp.asarray(tree["step"], jnp.int32)
        if pred:
            pstore = tree["pstore"]
            phist = list(tree["phist"])
        halo_cache = list(tree["halo_cache"])
        push_residual = list(tree["push_residual"])
        params_snapshots = list(tree["snapshots"])
        worker_round = tree["worker_round"]
        snapshot_step = tree["snapshot_step"]
        last_loss, last_delay = tree["last_loss"], tree["last_delay"]
        heap = [(float(t), int(w))
                for t, w in zip(tree["heap_t"], tree["heap_m"])]
        heapq.heapify(heap)
        last_push_step = tree["last_push_step"]
        last_reps = [jnp.asarray(x) for x in tree["last_reps"]]
        has_reps = tree["has_reps"]
        push_failed, retry_at = tree["push_failed"], tree["retry_at"]
        fail_count = tree["fail_count"]
        pull_pending, restarting = (tree["pull_pending"],
                                    tree["restarting"])
        rng.bit_generator.state = meta["rng_state"]
        rounds_done = int(meta["rounds_done"])
        cold_rows = int(meta["cold_rows"])
        counters = dict(meta["counters"])
        pull_age_max = int(meta["pull_age_max"])
        hist = {k: list(v) for k, v in meta["hist"].items()}

    def accept_push(store, m, r, reps, residual):
        """One wire transfer of worker m's rows at its round r: subject
        to the drop / corrupt schedule; the receiver CRC-checks the
        payload and rejects corrupted rows (observable effect = a drop
        plus a ``rejected_pushes`` count).  Returns (store, residual,
        accepted)."""
        if schedule is not None:
            if schedule.drops_push(r, m):
                counters["dropped_pushes"] += 1
                return store, residual, False
            if schedule.corrupts_push(r, m):
                wire = np.asarray(reps)
                sent = faults_mod.corrupt_rows(wire, fcfg.seed, r, m)
                if (faults_mod.wire_crc32(sent)
                        != faults_mod.wire_crc32(wire)):
                    counters["rejected_pushes"] += 1
                    return store, residual, False
        owner = jnp.asarray(m, jnp.int32)
        if settings.precision.error_feedback:
            store, residual = push_rows_ef(
                store, owner, data["local_slots"][m],
                data["local_valid"][m], reps, residual)
        else:
            store = push_rows(store, owner, data["local_slots"][m],
                              data["local_valid"][m], reps)
        if pred:
            apply_accepted_push(m, reps)
        last_push_step[ls_np[m][lv_np[m]]] = int(step)
        return store, residual, True

    while rounds_done < total_rounds:
        now, m = heapq.heappop(heap)
        if restarting[m]:
            # Crashed worker coming back: re-fetch server params and
            # force a halo re-pull before its next round — a restart is
            # a resync, not a resumption of lost in-flight state.
            params_snapshots[m] = params
            snapshot_step[m] = int(step)
            pull_pending[m] = True
            restarting[m] = False
        if schedule is not None and schedule.crashes(worker_round[m] + 1, m):
            # The worker goes down instead of running this round — the
            # round's work is lost (the counter advances so the restart
            # queries a FRESH schedule round, not the same crashing one)
            # and it restarts crash_rounds round-times later.  No rng
            # draws — the downtime uses the deterministic base speed so
            # a zero-rate schedule perturbs nothing.
            counters["crashes"] += 1
            worker_round[m] += 1
            restarting[m] = True
            down = fcfg.crash_rounds * settings.base_round_time * speeds[m]
            heapq.heappush(heap, (now + down, m))
            continue
        worker_round[m] += 1
        r = worker_round[m]

        # Periodic PULL from the shared compact store (non-blocking read;
        # dequantized into this worker's private fp32 table).  A delayed
        # pull degrades to the last-known-good cache and re-attempts next
        # round; the age table measures exactly how stale the rows a pull
        # reads are, and the watchdog force-resyncs overdue owners first.
        if r % settings.sync_interval == 0 or pull_pending[m]:
            if schedule is not None and schedule.delays_pull(r, m):
                counters["delayed_pulls"] += 1
                pull_pending[m] = True
            else:
                pull_pending[m] = False
                if cfg.num_layers > 1:
                    hs, hv = hs_np[m], hv_np[m]
                    ages = int(step) - last_push_step[hs]
                    if settings.max_staleness is not None:
                        over = hv & (ages >= settings.max_staleness)
                        if over.any():
                            # Blocking resync: apply the overdue owners'
                            # latest representations before reading.
                            for o in np.unique(hs[over] // shard_rows):
                                if not has_reps[o]:
                                    continue
                                owner = jnp.asarray(int(o), jnp.int32)
                                if settings.precision.error_feedback:
                                    store, push_residual[o] = push_rows_ef(
                                        store, owner, data["local_slots"][o],
                                        data["local_valid"][o], last_reps[o],
                                        push_residual[o])
                                else:
                                    store = push_rows(
                                        store, owner, data["local_slots"][o],
                                        data["local_valid"][o], last_reps[o])
                                if pred:
                                    apply_accepted_push(int(o),
                                                        last_reps[o])
                                last_push_step[ls_np[o][lv_np[o]]] = int(step)
                                push_failed[o] = False
                                fail_count[o] = 0
                                counters["forced_resyncs"] += 1
                            ages = int(step) - last_push_step[hs]
                    if hv.any():
                        pull_age_max = max(pull_age_max,
                                           int(ages[hv].max()))
                pulled = halo_exchange.pull(
                    store, data["halo_slots"][m][None])[0]
                if pred:
                    # SAT: serve the predicted rows.  A never-pushed
                    # slot is zero in BOTH stores, so the cold-row
                    # probe below still sees exact zeros.
                    pulled = pulled + (
                        jnp.float32(pcfg.gamma) * halo_exchange.pull(
                            pstore, data["halo_slots"][m][None])[0])
                # Cold-store probe: a valid halo row that is all-zero
                # across every layer was never pushed (legitimately-
                # pushed rows are post-relu representations of a real
                # forward — an exactly all-zero one is measure-zero).
                # Stays 0 under warm_start.
                zero_rows = ((jnp.abs(pulled).max(axis=(0, 2)) == 0)
                             & data["halo_valid"][m])
                cold_rows += int(zero_rows.sum())
                halo_cache[m] = pulled

        struct_m = {k: v[m] for k, v in data["struct"].items()}
        loss, grads, push = worker_grad(
            params_snapshots[m], jnp.asarray(x_local_all[m]),
            jnp.asarray(x_halo_all[m]), halo_cache[m], struct_m,
            data["labels"][m], data["train_mask"][m])

        delay = int(step) - int(snapshot_step[m])
        last_loss[m] = float(loss)
        last_delay[m] = delay
        hist["round_worker"].append(m)
        hist["round_loss"].append(float(loss))
        # Server applies immediately (async, non-blocking).
        params, opt_state = apply_update(params, opt_state, grads, step)
        step = step + 1

        # Periodic PUSH of fresh representations (boundary rows only),
        # with retry-with-backoff on wire failures: a failed push round
        # marks the worker and later rounds re-send the then-current
        # representations (each attempt re-subject to the schedule).
        if cfg.num_layers > 1:
            last_reps[m] = push
            has_reps[m] = True
            if (r - 1) % settings.sync_interval == 0:
                store, push_residual[m], ok = accept_push(
                    store, m, r, push, push_residual[m])
                if ok:
                    push_failed[m] = False
                    fail_count[m] = 0
                else:
                    push_failed[m] = True
                    fail_count[m] += 1
                    retry_at[m] = r + fcfg.retry_backoff
            elif push_failed[m] and r >= retry_at[m]:
                store, push_residual[m], ok = accept_push(
                    store, m, r, push, push_residual[m])
                if ok:
                    counters["retried_pushes"] += 1
                    push_failed[m] = False
                    fail_count[m] = 0
                else:
                    fail_count[m] += 1
                    backoff = min(
                        fcfg.retry_backoff * 2 ** (int(fail_count[m]) - 1),
                        fcfg.retry_backoff_cap)
                    retry_at[m] = r + backoff

        # Fetch fresh params, schedule next round.
        params_snapshots[m] = params
        snapshot_step[m] = int(step)
        heapq.heappush(heap, (now + round_time(m), m))
        rounds_done += 1

        if rounds_done % eval_every_rounds == 0 or \
                rounds_done == total_rounds:
            ev = evaluate(cfg, params, tdata)
            seen = ~np.isnan(last_loss)
            hist["round"].append(rounds_done)
            hist["sim_time"].append(float(now))
            hist["loss"].append(float(last_loss[seen].mean()))
            hist["val_f1"].append(float(ev["val_f1"]))
            hist["test_f1"].append(float(ev["test_f1"]))
            hist["delay"].append(int(last_delay.max()))
            hist["cold_rows"].append(cold_rows)
            hist["pull_age"].append(pull_age_max)

        if (ckpt_dir and ckpt_every_rounds
                and rounds_done % ckpt_every_rounds == 0
                and rounds_done < total_rounds):
            meta = {"rng_state": rng.bit_generator.state,
                    "rounds_done": rounds_done, "cold_rows": cold_rows,
                    "counters": counters, "pull_age_max": pull_age_max,
                    "hist": hist}
            ckpt_io.save_checkpoint(ckpt_dir, rounds_done, ckpt_tree(),
                                    meta=meta)

    state = {"params": params, "opt_state": opt_state, "store": store,
             "step": step, "fault_counters": counters,
             "pull_age_max": pull_age_max}
    if pred:
        state["pstore"] = pstore
    return state, hist


def sync_time_per_round(settings: AsyncSettings, M: int,
                        n_rounds: int = 200) -> float:
    """Expected per-round time of *synchronous* DIGEST under the same speed
    model (the barrier waits for the slowest worker — incl. the straggler)."""
    rng = np.random.default_rng(settings.seed)
    speeds = np.exp(rng.normal(0, settings.worker_speed_jitter, size=M))
    total = 0.0
    for _ in range(n_rounds):
        times = settings.base_round_time * speeds
        if settings.straggler is not None:
            times = times.copy()
            times[settings.straggler] += rng.uniform(
                *settings.straggler_delay)
        total += times.max()
    return total / n_rounds
