"""Pure-jnp oracle for flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True,
                  sm_scale: float | None = None) -> jax.Array:
    """q, k, v: (bh, seq, head_dim). Dense softmax(QKᵀ)V oracle."""
    bh, seq, hd = q.shape
    if sm_scale is None:
        sm_scale = hd ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    if causal:
        mask = jnp.tril(jnp.ones((seq, seq), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
