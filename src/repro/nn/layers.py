"""Functional layer primitives shared by GNN and transformer stacks."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
          ) -> jax.Array:
    """x: (..., in) @ w: (in, out) [+ b]."""
    y = jnp.einsum("...i,io->...o", x, w)
    if b is not None:
        y = y + b
    return y


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = dense(x, w_gate)
    u = dense(x, w_up)
    return dense(jax.nn.silu(g) * u, w_down)


def gelu_mlp(x: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    return dense(jax.nn.gelu(dense(x, w_up)), w_down)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,), float32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)  # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., seq, hd/2)
    sin = jnp.sin(ang)[..., :, None, :]  # (..., seq, 1, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses / metrics
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean CE over (optionally masked) positions. labels: int ids."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def accuracy(logits: jax.Array, labels: jax.Array,
             mask: Optional[jax.Array] = None) -> jax.Array:
    pred = jnp.argmax(logits, axis=-1)
    hit = (pred == labels).astype(jnp.float32)
    if mask is None:
        return jnp.mean(hit)
    mask = mask.astype(jnp.float32)
    return jnp.sum(hit * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def micro_f1(logits: jax.Array, labels: jax.Array,
             mask: Optional[jax.Array] = None) -> jax.Array:
    """Micro-averaged F1 == accuracy for single-label classification; kept
    as a named metric to mirror the paper's reporting."""
    return accuracy(logits, labels, mask)
