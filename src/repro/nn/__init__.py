from repro.nn.params import (ParamSpec, abstract_params, init_params,
                             param_axes, param_bytes, param_count)
from repro.nn.layers import (accuracy, apply_rope, dense, gelu_mlp,
                             layer_norm, micro_f1, rms_norm, rope_freqs,
                             softmax_cross_entropy, swiglu)

__all__ = [
    "ParamSpec", "abstract_params", "init_params", "param_axes",
    "param_bytes", "param_count", "accuracy", "apply_rope", "dense",
    "gelu_mlp", "layer_norm", "micro_f1", "rms_norm", "rope_freqs",
    "softmax_cross_entropy", "swiglu",
]
