"""Stale-KV block attention (DIGEST for long context)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.stale_kv import (StaleKVConfig, init_stale_kv_cache,
                                   stale_kv_decode, summaries_from_full_kv)


def _decode_many(cfg, q_all, k_all, v_all):
    b, s, h, d = q_all.shape
    kv = k_all.shape[2]
    cache = init_stale_kv_cache(cfg, b, kv, d, jnp.float32)
    outs = []
    for t in range(s):
        o, cache = stale_kv_decode(cfg, cache, q_all[:, t:t+1],
                                   k_all[:, t:t+1], v_all[:, t:t+1],
                                   jnp.asarray([t] * b))
        outs.append(o)
    return jnp.concatenate(outs, axis=1), cache


def test_exact_within_window():
    """While pos < window nothing is stale — must equal full attention."""
    from repro.models.attention import decode_attention
    rng = np.random.default_rng(0)
    b, s, h, d = 1, 48, 2, 16
    cfg = StaleKVConfig(max_seq=64, window=64, ratio=8)
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    out, _ = _decode_many(cfg, q, k, v)
    # full-cache oracle
    kc = jnp.zeros((b, 64, h, d)).at[:, :s].set(k)
    vc = jnp.zeros((b, 64, h, d)).at[:, :s].set(v)
    for t in range(s):
        ref = decode_attention(q[:, t:t+1], kc, vc, jnp.asarray([t]))
        np.testing.assert_allclose(out[:, t:t+1], ref, atol=1e-5,
                                   rtol=1e-5)


def test_sublinear_far_field_approximates():
    rng = np.random.default_rng(1)
    b, s, h, d = 1, 128, 2, 8
    cfg = StaleKVConfig(max_seq=128, window=32, ratio=8)
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)) * 0.1, jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    out, cache = _decode_many(cfg, q, k, v)
    assert bool(jnp.all(jnp.isfinite(out)))
    # summaries must have been pushed for completed blocks
    n_complete = (s // cfg.ratio)
    pushed = np.asarray(jnp.abs(cache["k_sum"]).sum(axis=(0, 2, 3)))
    assert (pushed[:n_complete - 1] > 0).any()


def test_summary_push_is_mean_pool():
    rng = np.random.default_rng(2)
    cfg = StaleKVConfig(max_seq=32, window=8, ratio=4)
    b, h, d = 1, 1, 4
    cache = init_stale_kv_cache(cfg, b, h, d, jnp.float32)
    ks, vs = [], []
    for t in range(4):
        kt = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
        vt = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
        ks.append(kt)
        q = jnp.zeros((b, 1, 1, d))
        _, cache = stale_kv_decode(cfg, cache, q, kt, vt,
                                   jnp.asarray([t]))
    want = jnp.mean(jnp.concatenate(ks, axis=1), axis=1)
    np.testing.assert_allclose(cache["k_sum"][:, 0], want, atol=1e-5)


def test_summaries_from_full_kv():
    rng = np.random.default_rng(3)
    cfg = StaleKVConfig(max_seq=64, window=16, ratio=8)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 4)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 4)), jnp.float32)
    ks, vs = summaries_from_full_kv(cfg, k, v)
    assert ks.shape == (1, 8, 2, 4)
    np.testing.assert_allclose(ks[:, 0], k[:, :8].mean(axis=1), atol=1e-5)
