"""Fig. 5: speedup vs number of workers M (1..8), DIGEST vs propagation.

CPU wall-time cannot show multi-device scaling, so this uses the §3.3
analytic epoch-time model (v5e constants) on the partitioned graph —
per-worker compute shrinks with M while DIGEST's sync cost is amortized."""
from benchmarks.common import bench_scale, emit
from repro.core import epoch_time_model
from repro.graph import build_partitions, make_dataset
from repro.models.gnn import GNNConfig, gnn_specs
from repro.nn import param_count


def run() -> list[dict]:
    scale = bench_scale()
    g = make_dataset("products-sim", scale=0.3 * scale)
    cfg = GNNConfig(num_layers=3, in_dim=g.features.shape[1],
                    hidden_dim=128, num_classes=int(g.labels.max()) + 1)
    pc = param_count(gnn_specs(cfg))
    rows = []
    base = None
    for m in (1, 2, 4, 8):
        sp = build_partitions(g, m)
        times = {mode: epoch_time_model(mode, sp, g, pc, cfg.hidden_dim,
                                        cfg.num_layers, cfg.in_dim)
                 for mode in ("digest", "propagation")}
        if m == 1:
            base = times["propagation"]["t_epoch"]
        for mode, t in times.items():
            rows.append({
                "name": f"fig5/{mode}/M={m}",
                "us_per_call": round(t["t_epoch"] * 1e6, 2),
                "speedup_vs_1gpu_dgl": round(base / t["t_epoch"], 3),
                "comm_mb": round(t["bytes"] / 1e6, 3),
            })
    return rows


if __name__ == "__main__":
    emit(run())
