"""Fig. 3/8: loss and validation F1 over training time, all frameworks.

Emits one row per (mode, eval point) — plotting-ready CSV."""
from benchmarks.common import bench_scale, emit
from benchmarks.gnn_common import MODE_LABEL, setup, train_mode


def run(model: str = "gcn") -> list[dict]:
    scale = bench_scale()
    _, data, cfg = setup("reddit-sim", model=model, scale=0.2 * scale)
    epochs = max(int(100 * scale), 30)
    rows = []
    for mode in ("propagation", "llcg", "digest"):
        hist, _, _ = train_mode(cfg, data, mode, epochs)
        for e, t, loss, f1 in zip(hist["epoch"], hist["time"],
                                  hist["loss"], hist["val_f1"]):
            rows.append({"name": f"fig3/{model}/{MODE_LABEL[mode]}/e{e}",
                         "us_per_call": "",
                         "t_s": round(t, 3), "loss": round(loss, 4),
                         "val_f1": round(f1, 4)})
    return rows


if __name__ == "__main__":
    emit(run())
