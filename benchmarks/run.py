"""Benchmark harness: one module per paper table/figure.

Usage:
  PYTHONPATH=src python -m benchmarks.run             # all
  PYTHONPATH=src python -m benchmarks.run table1 fig6 # subset
  REPRO_BENCH_SCALE=0.5 ... (scale datasets/epochs)

Prints ``name,us_per_call,derived`` CSV rows.
"""
import sys
import time
import traceback

from benchmarks.common import emit

BENCHES = ["table1_f1_speedup", "fig3_curves", "fig4_time_per_epoch",
           "fig5_scalability", "fig6_sync_interval", "fig7_straggler",
           "fig9_memory_ratio", "thm1_error_bound", "comm_complexity",
           "kernel_bench", "serve_bench", "sampling_variance",
           "sat_prediction"]


def main() -> int:
    wanted = sys.argv[1:]
    mods = [b for b in BENCHES
            if not wanted or any(w in b for w in wanted)]
    print("name,us_per_call,derived")
    failures = 0
    for name in mods:
        t0 = time.perf_counter()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            emit(mod.run())
            print(f"# {name} done in {time.perf_counter()-t0:.1f}s",
                  flush=True)
        except Exception:
            failures += 1
            print(f"# {name} FAILED", flush=True)
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
