"""Shared benchmark utilities."""
from __future__ import annotations

import os
import time

import jax


def bench_scale() -> float:
    """REPRO_BENCH_SCALE scales dataset sizes/epochs (default CPU-budget)."""
    return float(os.environ.get("REPRO_BENCH_SCALE", "1.0"))


def time_call(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(rows: list[dict]) -> None:
    """Print benchmark rows as `name,us_per_call,derived` CSV."""
    for r in rows:
        name = r.pop("name")
        us = r.pop("us_per_call", "")
        derived = ";".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{us},{derived}", flush=True)
