"""Stale store (the KVS): push/pull semantics."""
import jax.numpy as jnp
import numpy as np

from repro.core import stale_store


def test_push_pull_roundtrip():
    store = stale_store.init_store(2, 10, 4)
    local_ids = jnp.asarray([[0, 3, 10], [5, 7, 10]])   # 10 = sentinel pad
    valid = jnp.asarray([[True, True, False], [True, True, False]])
    reps = jnp.arange(2 * 2 * 3 * 4, dtype=jnp.float32).reshape(2, 2, 3, 4)
    store = stale_store.push(store, local_ids, valid, reps)
    # pull back the pushed rows
    pulled = stale_store.pull(store, local_ids)
    np.testing.assert_allclose(np.asarray(pulled)[:, :, :2],
                               np.asarray(reps)[:, :, :2])
    # sentinel row must stay zero (padding reads are zeros)
    assert float(jnp.abs(store[:, 10]).max()) == 0.0


def test_pull_shape():
    store = stale_store.init_store(3, 20, 8)
    halo = jnp.asarray([[1, 2, 20], [4, 20, 20]])
    out = stale_store.pull(store, halo)
    assert out.shape == (2, 3, 3, 8)


def test_staleness_error_zero_after_push():
    store = stale_store.init_store(1, 6, 2)
    ids = jnp.asarray([[0, 1], [2, 3]])
    valid = jnp.ones((2, 2), bool)
    reps = jnp.ones((2, 1, 2, 2))
    store = stale_store.push(store, ids, valid, reps)
    eps = stale_store.staleness_error(store, reps, ids, valid)
    assert float(eps.max()) == 0.0
    eps2 = stale_store.staleness_error(store, 3 * reps, ids, valid)
    assert float(eps2.max()) > 0.0
