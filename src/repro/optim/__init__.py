from repro.optim.optimizers import (Optimizer, adafactor, adam, adamw,
                                    clip_by_global_norm, constant_schedule,
                                    make_optimizer, sgd,
                                    warmup_cosine_schedule)

__all__ = [
    "Optimizer", "adafactor", "adam", "adamw", "clip_by_global_norm",
    "constant_schedule", "make_optimizer", "sgd", "warmup_cosine_schedule",
]
