"""Unit tests for the dry-run HLO analysis tooling (pure parsing — no
512-device mesh required)."""
import numpy as np

from repro.launch.dryrun import (_groups_cross_pod, collective_bytes)


HLO_SAMPLE = """
HloModule test
  %ar = f32[16,4096]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256]
  %ag.1 = bf16[2,32768,32,64]{3,2,1,0} all-gather(%y), replica_groups=[16,16]<=[256], dimensions={1}
  %done = f32[8]{0} all-reduce-done(%h)
  %a2a = f32[128]{0} all-to-all(%z), replica_groups=[2,256]<=[512]
  %other = f32[4]{0} add(%a, %b)
"""


def test_collective_bytes_totals():
    out = collective_bytes(HLO_SAMPLE)
    ar = 16 * 4096 * 4
    ag = 2 * 32768 * 32 * 64 * 2
    a2a = 128 * 4
    assert out["per_op"]["all-reduce"] == ar       # -done not re-counted
    assert out["per_op"]["all-gather"] == ag
    assert out["per_op"]["all-to-all"] == a2a
    assert out["total"] == ar + ag + a2a
    assert out["counts"]["all-reduce"] == 1


def test_inter_pod_classification_contiguous():
    # groups of 16 contiguous devices inside a 512 fleet: never cross 256
    line = "%ar = f32[4]{0} all-reduce(%x), replica_groups=[32,16]<=[512]"
    assert not _groups_cross_pod(line, 256)
    # one group of all 512 devices: crosses
    line2 = "%ar = f32[4]{0} all-reduce(%x), replica_groups=[1,512]<=[512]"
    assert _groups_cross_pod(line2, 256)


def test_inter_pod_classification_transposed():
    # [256,2]<=[2,256]T(1,0): groups pair device i with i+256 → cross-pod
    line = ("%cp = f32[4]{0} collective-permute(%x), "
            "replica_groups=[256,2]<=[2,256]T(1,0)")
    assert _groups_cross_pod(line, 256)


def test_inter_pod_explicit_format():
    line = "%ar = f32[4]{0} all-reduce(%x), replica_groups={{0,1},{2,3}}"
    assert not _groups_cross_pod(line, 256)
    line2 = "%ar = f32[4]{0} all-reduce(%x), replica_groups={{0,300}}"
    assert _groups_cross_pod(line2, 256)


def test_inter_pod_source_target_pairs():
    # collective-permute carries source_target_pairs, not replica_groups
    # — the two-stage halo exchange's inter-pod hop is exactly such an
    # op and must count toward the inter-pod byte split.
    line = ("%cp = f32[4]{0} collective-permute(%x), "
            "source_target_pairs={{0,256},{256,0},{1,257},{257,1}}")
    assert _groups_cross_pod(line, 256)
    line2 = ("%cp = f32[4]{0} collective-permute(%x), "
             "source_target_pairs={{0,1},{1,0}}")
    assert not _groups_cross_pod(line2, 256)


def test_shared_group_grammar():
    # One grammar, two consumers: the dry-run inter-pod split and the
    # per-axis test census both read groups through hlo_census.
    from repro.launch.hlo_census import match_collective, op_groups

    line = ("%cp = f32[4]{0} collective-permute(%x), "
            "source_target_pairs={{0,4},{4,0}}")
    assert match_collective(line) == "collective-permute"
    assert op_groups(line) == [[0, 4], [4, 0]]
    assert match_collective("%d = f32[4]{0} all-reduce-done(%h)") is None


def test_pod_split_totals():
    out = collective_bytes(HLO_SAMPLE, pod_boundary=256)
    # the 512-wide all-to-all ([2,256]<=[512] → contiguous 256-blocks: each
    # group is exactly one pod) must NOT count as inter-pod
    assert out["inter_pod"] == 0
