"""§3.3 complexity: per-epoch communication bytes vs mode, N, depth L, and
HaloExchange wire precision (fp32 / bf16 / int8 + per-row scales).  The
digest pull term is reported three ways: the ragged ideal (Σ_m |halo|
rows), the padded all_to_all that collective_pull actually ships
(M·M·K rows, K the PullPlan max pair width), and the replicated-snapshot
all-gather baseline ((M-1)·(B+1) rows)."""
from benchmarks.common import bench_scale, emit
from repro.core import HaloPrecision, HaloSpec, epoch_comm_bytes
from repro.graph import build_partitions, make_dataset
from repro.models.gnn import GNNConfig, gnn_specs
from repro.nn import param_count


def run() -> list[dict]:
    scale = bench_scale()
    g = make_dataset("reddit-sim", scale=0.2 * scale)
    sp = build_partitions(g, 4)
    plan_k = sp.pull_plan().max_rows
    rows = []
    for L in (2, 3, 4):
        cfg = GNNConfig(num_layers=L, in_dim=g.features.shape[1],
                        hidden_dim=64, num_classes=8)
        pc = param_count(gnn_specs(cfg))
        for mode in ("partition", "digest", "propagation"):
            b = epoch_comm_bytes(mode, sp, g, pc, 64, L, 10)
            rows.append({"name": f"comm/L={L}/{mode}", "us_per_call": "",
                         "mbytes_per_epoch": round(b / 1e6, 4)})
        # Wire-precision ablation for the DIGEST pull/push terms, with
        # the sharded (ragged collective) vs replicated (snapshot
        # all-gather) pull cost side by side.
        for storage in ("fp32", "bf16", "int8"):
            prec = HaloPrecision(storage)
            b = epoch_comm_bytes("digest", sp, g, pc, 64, L, 10,
                                 halo_precision=prec)
            spec = HaloSpec.from_partitions(sp, 64, L, prec)
            sync = spec.comm_bytes(sp.pull_rows(), sp.push_rows())
            repl = spec.replicated_pull_nbytes()
            coll = spec.collective_pull_nbytes(plan_k)
            rows.append({"name": f"comm/L={L}/digest-{storage}",
                         "us_per_call": "",
                         "mbytes_per_epoch": round(b / 1e6, 4),
                         "pull_mb_per_sync": round(
                             sync["pull_bytes"] / 1e6, 4),
                         "pull_collective_mb_per_sync": round(coll / 1e6,
                                                              4),
                         "pull_replicated_mb_per_sync": round(repl / 1e6,
                                                              4),
                         "pull_sharded_saving": round(
                             repl / max(sync["pull_bytes"], 1), 2),
                         "push_mb_per_sync": round(
                             sync["push_bytes"] / 1e6, 4)})
    return rows


if __name__ == "__main__":
    emit(run())
