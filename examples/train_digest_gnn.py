#!/usr/bin/env python
"""End-to-end DIGEST GNN training driver (the paper's experiment):
dataset build → METIS-style partition → DIGEST training with periodic
stale sync → eval + checkpointing + communication accounting.

  PYTHONPATH=src python examples/train_digest_gnn.py \
      --dataset products-sim --parts 8 --epochs 200 --interval 10

Collective mode
---------------
``--pull`` selects the PULL/PUSH transport of the halo store:

  * ``gather`` (default): dense gather/scatter; XLA's SPMD partitioner
    inserts an all-gather of the owner-sharded slab under pjit.  Correct
    on any device count — the fallback when ``--parts`` does not divide
    the mesh data axis.
  * ``collective``: the fully-SPMD ``shard_map`` epoch.  PULL is one
    ragged ``all_to_all`` shipping only the slots each subgraph's halo
    references (per the PullPlan); PUSH and the Theorem-1 staleness
    probe run with owner-local offsets inside each device's own shards.
    Needs ``--parts`` to be a *multiple* of ``--data-axis``: each device
    then carries k = parts/data-axis subgraphs and owner shards
    (parts-per-device > 1 is the M-exceeds-pod-size regime; a
    non-multiple raises a spelled-out ValueError).

HLO guarantees (regression-tested in tests/test_hlo_collectives.py):
the compiled collective-mode epoch contains exactly one all-to-all per
store tensor (layers batched inside) and **zero** all-gather /
collective-permute / reduce-scatter ops — pushes provably never cross
devices, so §3.3's owner-local cost model is a property of the emitted
program, not a partitioner heuristic.
"""
import argparse
import json
import os

from repro.checkpoint import save_checkpoint
from repro.core import (HaloSpec, TrainSettings, digest_train,
                        epoch_comm_bytes, prepare_graph_data)
from repro.graph import make_dataset, partition_report
from repro.models.gnn import GNNConfig, gnn_specs
from repro.nn import param_count
from repro.optim import adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="products-sim")
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--model", default="gcn", choices=["gcn", "gat",
                                                       "sage"])
    ap.add_argument("--parts", type=int, default=8)
    ap.add_argument("--epochs", type=int, default=200)
    ap.add_argument("--interval", type=int, default=10)
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--pull", default="gather",
                    choices=("gather", "collective"),
                    help="halo PULL/PUSH transport (see module "
                         "docstring); collective needs --parts to be a "
                         "multiple of --data-axis")
    ap.add_argument("--data-axis", type=int, default=1,
                    help="mesh data-axis size for --pull collective "
                         "(1 on a single-device host)")
    ap.add_argument("--pods", type=int, default=1,
                    help="mesh pod-axis size for --pull collective; "
                         "> 1 runs the two-stage multi-pod exchange "
                         "(--parts must be a multiple of pods x "
                         "data-axis)")
    ap.add_argument("--halo-weight", type=float, default=0.0,
                    help="boundary-aware partitioning score weight "
                         "(0 = classic edge-cut LDG)")
    ap.add_argument("--no-gat-dedup", action="store_true",
                    help="disable the GAT owner-shard projection dedup")
    ap.add_argument("--ckpt-dir", default="/tmp/digest_ckpt")
    args = ap.parse_args()

    g = make_dataset(args.dataset, scale=args.scale)
    data = prepare_graph_data(g, args.parts, halo_weight=args.halo_weight)
    cfg = GNNConfig(model=args.model,
                    num_layers=3 if args.model != "gat" else 2,
                    in_dim=g.features.shape[1], hidden_dim=args.hidden,
                    num_classes=int(g.labels.max()) + 1, heads=4,
                    halo_occupancy=data["_worklist"].occupancy,
                    gat_halo_dedup=not args.no_gat_dedup)
    pc = param_count(gnn_specs(cfg))
    print(f"dataset={g.name} nodes={g.num_nodes} edges={g.num_edges} "
          f"parts={args.parts} params={pc:,}")
    print(f"halo ratio per part: {data['_sp'].halo_ratio().round(2)}")
    quality = partition_report(g, data["_sp"])
    print(f"partition: edge_cut={quality['edge_cut']} "
          f"halo_rows={quality['halo_rows']} "
          f"boundary={quality['boundary']} "
          f"balance={quality['balance']:.3f}")
    spec = HaloSpec.from_partitions(data["_sp"], args.hidden,
                                    cfg.num_layers)
    print(f"halo store: {spec.store_nbytes()/1e6:.2f} MB total, "
          f"{spec.shard_nbytes()/1e6:.2f} MB/device (owner-sharded)")

    mesh = None
    if args.pull == "collective":
        from repro.core import check_collective_geometry
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(data=args.data_axis, pod=args.pods)
        ppd = check_collective_geometry(data, mesh)
        print(f"collective mode: {ppd} subgraph(s) per device over "
              f"{dict(mesh.shape)}")
    state, hist = digest_train(
        cfg, adam(args.lr), data,
        TrainSettings(sync_interval=args.interval, mode="digest",
                      pull_mode=args.pull),
        epochs=args.epochs, eval_every=max(args.epochs // 10, 1),
        verbose=True, mesh=mesh)

    comm = epoch_comm_bytes("digest", data["_sp"], g, pc, args.hidden,
                            cfg.num_layers, args.interval)
    comm_prop = epoch_comm_bytes("propagation", data["_sp"], g, pc,
                                 args.hidden, cfg.num_layers)
    print(f"\nfinal: loss={hist['loss'][-1]:.4f} "
          f"val_f1={hist['val_f1'][-1]:.4f} "
          f"test_f1={hist['test_f1'][-1]:.4f}")
    print(f"comm/epoch: digest={comm/1e6:.2f} MB vs "
          f"propagation={comm_prop/1e6:.2f} MB "
          f"({comm_prop/comm:.1f}x reduction)")
    path = save_checkpoint(args.ckpt_dir, args.epochs,
                           {"params": state["params"]})
    print(f"checkpoint: {path}")
    with open(os.path.join(args.ckpt_dir, "history.json"), "w") as f:
        json.dump(hist, f)


if __name__ == "__main__":
    main()
