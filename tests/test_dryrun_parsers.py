"""Unit tests for the dry-run HLO analysis tooling (pure parsing — no
512-device mesh required)."""

from repro.launch.dryrun import (_groups_cross_pod, collective_bytes)


HLO_SAMPLE = """
HloModule test
  %ar = f32[16,4096]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256]
  %ag.1 = bf16[2,32768,32,64]{3,2,1,0} all-gather(%y), replica_groups=[16,16]<=[256], dimensions={1}
  %done = f32[8]{0} all-reduce-done(%h)
  %a2a = f32[128]{0} all-to-all(%z), replica_groups=[2,256]<=[512]
  %other = f32[4]{0} add(%a, %b)
"""


def test_collective_bytes_totals():
    out = collective_bytes(HLO_SAMPLE)
    ar = 16 * 4096 * 4
    ag = 2 * 32768 * 32 * 64 * 2
    a2a = 128 * 4
    assert out["per_op"]["all-reduce"] == ar       # -done not re-counted
    assert out["per_op"]["all-gather"] == ag
    assert out["per_op"]["all-to-all"] == a2a
    assert out["total"] == ar + ag + a2a
    assert out["counts"]["all-reduce"] == 1


def test_inter_pod_classification_contiguous():
    # groups of 16 contiguous devices inside a 512 fleet: never cross 256
    line = "%ar = f32[4]{0} all-reduce(%x), replica_groups=[32,16]<=[512]"
    assert not _groups_cross_pod(line, 256)
    # one group of all 512 devices: crosses
    line2 = "%ar = f32[4]{0} all-reduce(%x), replica_groups=[1,512]<=[512]"
    assert _groups_cross_pod(line2, 256)


def test_inter_pod_classification_transposed():
    # [256,2]<=[2,256]T(1,0): groups pair device i with i+256 → cross-pod
    line = ("%cp = f32[4]{0} collective-permute(%x), "
            "replica_groups=[256,2]<=[2,256]T(1,0)")
    assert _groups_cross_pod(line, 256)


def test_inter_pod_explicit_format():
    line = "%ar = f32[4]{0} all-reduce(%x), replica_groups={{0,1},{2,3}}"
    assert not _groups_cross_pod(line, 256)
    line2 = "%ar = f32[4]{0} all-reduce(%x), replica_groups={{0,300}}"
    assert _groups_cross_pod(line2, 256)


def test_inter_pod_source_target_pairs():
    # collective-permute carries source_target_pairs, not replica_groups
    # — the two-stage halo exchange's inter-pod hop is exactly such an
    # op and must count toward the inter-pod byte split.
    line = ("%cp = f32[4]{0} collective-permute(%x), "
            "source_target_pairs={{0,256},{256,0},{1,257},{257,1}}")
    assert _groups_cross_pod(line, 256)
    line2 = ("%cp = f32[4]{0} collective-permute(%x), "
             "source_target_pairs={{0,1},{1,0}}")
    assert not _groups_cross_pod(line2, 256)


def test_shared_group_grammar():
    # One grammar, two consumers: the dry-run inter-pod split and the
    # per-axis test census both read groups through hlo_census.
    from repro.launch.hlo_census import match_collective, op_groups

    line = ("%cp = f32[4]{0} collective-permute(%x), "
            "source_target_pairs={{0,4},{4,0}}")
    assert match_collective(line) == "collective-permute"
    assert op_groups(line) == [[0, 4], [4, 0]]
    assert match_collective("%d = f32[4]{0} all-reduce-done(%h)") is None


def test_pod_split_totals():
    out = collective_bytes(HLO_SAMPLE, pod_boundary=256)
    # the 512-wide all-to-all ([2,256]<=[512] → contiguous 256-blocks: each
    # group is exactly one pod) must NOT count as inter-pod
    assert out["inter_pod"] == 0


def _census_rec(**over):
    rec = {"mesh": "2x16x16", "precision": "fp32", "parts_per_device": 1,
           "collective_counts": {"all-gather": 0, "all-reduce": 12,
                                 "reduce-scatter": 0, "all-to-all": 1,
                                 "collective-permute": 1}}
    rec["collective_counts"].update(
        over.pop("counts", {}))
    rec.update(over)
    return rec


def test_census_check_accepts_clean_census(tmp_path):
    from repro.launch.census_check import check_census, main
    recs = [_census_rec(), _census_rec(precision="int8",
                                       parts_per_device=2,
                                       counts={"all-to-all": 2})]
    assert check_census(recs) == []
    path = tmp_path / "census.jsonl"
    path.write_text("".join(__import__("json").dumps(r) + "\n"
                            for r in recs))
    assert main([str(path)]) == 0


def test_census_check_rejects_all_gather(tmp_path):
    from repro.launch.census_check import check_census, main
    recs = [_census_rec(), _census_rec(counts={"all-gather": 3})]
    errs = check_census(recs)
    assert len(errs) == 1 and "all-gather" in errs[0]
    path = tmp_path / "census.jsonl"
    path.write_text("".join(__import__("json").dumps(r) + "\n"
                            for r in recs))
    assert main([str(path)]) == 1


def test_census_check_rejects_missing_exchange_and_bad_count():
    from repro.launch.census_check import check_census
    # a silently-skipped compile (1 record instead of 2) fails ...
    assert check_census([_census_rec()]) != []
    # ... so does a record whose two-stage exchange vanished
    errs = check_census([_census_rec(counts={"all-to-all": 0}),
                         _census_rec(counts={"collective-permute": 0})])
    assert any("all-to-all" in e for e in errs)
    assert any("collective-permute" in e for e in errs)
    # an empty census never passes, even with --records 0
    assert check_census([], expect_records=0) != []
