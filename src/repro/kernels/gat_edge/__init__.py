from repro.kernels.gat_edge.gat_edge import gat_edge_partial_pallas
from repro.kernels.gat_edge.ops import gat_aggregate
from repro.kernels.gat_edge.ref import gat_edge_partial_ref, merge_partials

__all__ = ["gat_aggregate", "gat_edge_partial_pallas",
           "gat_edge_partial_ref", "merge_partials"]
