"""Fused GAT edge-softmax kernel vs oracle, and split-merge exactness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.gat_edge import (gat_aggregate, gat_edge_partial_pallas,
                                    gat_edge_partial_ref, merge_partials)


def _case(rng, rows, deg, ncols, feat):
    nbr = rng.integers(0, ncols + 1, size=(rows, deg)).astype(np.int32)
    valid = (rng.random((rows, deg)) > 0.3) & (nbr < ncols)
    # ensure at least one valid edge per row (degenerate rows are padded
    # rows in practice and excluded from assertions)
    valid[:, 0] = True
    nbr[:, 0] = rng.integers(0, ncols, size=rows)
    s_dst = rng.normal(size=(rows,)).astype(np.float32)
    s_src = rng.normal(size=(ncols + 1,)).astype(np.float32)
    z = rng.normal(size=(ncols + 1, feat)).astype(np.float32)
    z[-1] = 0
    return (jnp.asarray(nbr), jnp.asarray(valid), jnp.asarray(s_dst),
            jnp.asarray(s_src), jnp.asarray(z))


@pytest.mark.parametrize("rows,deg,ncols,feat", [
    (128, 8, 64, 128), (256, 4, 200, 128), (128, 1, 10, 256),
])
def test_gat_kernel_matches_ref(rows, deg, ncols, feat):
    rng = np.random.default_rng(rows)
    args = _case(rng, rows, deg, ncols, feat)
    acc_p, m_p, l_p = gat_edge_partial_pallas(*args, interpret=True)
    acc_r, m_r, l_r = gat_edge_partial_ref(*args)
    np.testing.assert_allclose(m_p, m_r, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(l_p, l_r, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(acc_p, acc_r, atol=1e-4, rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(rows=st.sampled_from([128, 256]), deg=st.integers(1, 10),
       ncols=st.integers(2, 120), seed=st.integers(0, 10_000))
def test_gat_kernel_property(rows, deg, ncols, seed):
    rng = np.random.default_rng(seed)
    args = _case(rng, rows, deg, ncols, 128)
    acc_p, m_p, l_p = gat_edge_partial_pallas(*args, interpret=True)
    acc_r, m_r, l_r = gat_edge_partial_ref(*args)
    np.testing.assert_allclose(acc_p, acc_r, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(l_p, l_r, atol=1e-5, rtol=1e-5)


def test_split_merge_equals_joint_softmax():
    """Partials over two edge sets, merged, must equal the softmax over
    the union — DIGEST's split (Eq. 4) is exact for GAT too."""
    rng = np.random.default_rng(0)
    rows, deg, ncols, feat = 64, 6, 40, 32
    nbr = rng.integers(0, ncols, size=(rows, 2 * deg)).astype(np.int32)
    valid = np.ones((rows, 2 * deg), bool)
    s_dst = rng.normal(size=(rows,)).astype(np.float32)
    s_src = rng.normal(size=(ncols + 1,)).astype(np.float32)
    z = rng.normal(size=(ncols + 1, feat)).astype(np.float32)

    joint = gat_edge_partial_ref(
        jnp.asarray(nbr), jnp.asarray(valid), jnp.asarray(s_dst),
        jnp.asarray(s_src), jnp.asarray(z))
    joint_out = np.asarray(joint[0]) / np.asarray(joint[2])[:, None]

    parts = [gat_edge_partial_ref(
        jnp.asarray(nbr[:, i * deg:(i + 1) * deg]),
        jnp.asarray(valid[:, i * deg:(i + 1) * deg]),
        jnp.asarray(s_dst), jnp.asarray(s_src), jnp.asarray(z))
        for i in range(2)]
    merged = merge_partials(parts)
    np.testing.assert_allclose(merged, joint_out, atol=1e-5, rtol=1e-5)


def test_gat_aggregate_backends_agree():
    rng = np.random.default_rng(1)
    rows, deg, nloc, nhalo, feat = 128, 4, 60, 30, 128
    in_nbr, in_valid, s_dst, s_loc, z_loc = _case(rng, rows, deg, nloc,
                                                  feat)
    out_nbr, out_valid, _, s_halo, z_halo = _case(rng, rows, deg, nhalo,
                                                  feat)
    a = gat_aggregate(in_nbr, in_valid, out_nbr, out_valid, s_dst,
                      s_loc, s_halo, z_loc, z_halo, backend="jnp")
    b = gat_aggregate(in_nbr, in_valid, out_nbr, out_valid, s_dst,
                      s_loc, s_halo, z_loc, z_halo,
                      backend="pallas_interpret")
    np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)
