"""DENSE REFERENCE stale store — the oracle for HaloExchange parity tests.

Production consumers have migrated to :mod:`repro.core.halo_exchange`,
which keeps a *compact* precision-aware slab of boundary rows only.  This
module retains the seed's dense formulation

    store: (L-1, N+1, hidden)   # row N is the zero sentinel

indexed by **global node id**, purely as the easy-to-audit reference
semantics: ``pull``/``push``/``staleness_error`` here and in
``halo_exchange`` must agree bitwise at fp32 on every row the compact
store serves (see ``tests/test_stale_store.py``).  Do not add new
consumers — the dense layout is O(N·L·d) HBM per replica, which is exactly
the implementation artifact the compact store removes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_store(num_hidden_layers: int, num_nodes: int, hidden: int,
               dtype=jnp.float32) -> jax.Array:
    """Zero-initialized store; (L-1, N+1, hidden), sentinel row at N."""
    return jnp.zeros((num_hidden_layers, num_nodes + 1, hidden), dtype)


def pull(store: jax.Array, halo_ids: jax.Array) -> jax.Array:
    """Gather stale halo tables.

    halo_ids: (M, H) global node ids (sentinel N at padding).
    Returns (M, L-1, H, hidden).
    """
    out = store[:, halo_ids, :]            # (L-1, M, H, hidden)
    return jnp.swapaxes(out, 0, 1)


def push(store: jax.Array, local_ids: jax.Array, local_valid: jax.Array,
         reps: jax.Array) -> jax.Array:
    """Scatter fresh local reps into the store.

    local_ids: (M, S); local_valid: (M, S) bool;
    reps: (M, L-1, S, hidden) — per-subgraph per-layer fresh representations.
    Invalid (padding) slots are routed to the sentinel row with zero values,
    and the sentinel row is re-zeroed afterwards, keeping pulls of padded
    halo slots exactly zero.
    """
    n_sentinel = store.shape[1] - 1
    m, s = local_ids.shape
    ids = jnp.where(local_valid, local_ids, n_sentinel).reshape(-1)
    vals = jnp.where(local_valid[:, None, :, None], reps, 0.0)
    vals = jnp.swapaxes(vals, 0, 1).reshape(store.shape[0], m * s, -1)
    new = store.at[:, ids, :].set(vals.astype(store.dtype))
    return new.at[:, n_sentinel, :].set(0.0)


def staleness_error(store: jax.Array, fresh: jax.Array,
                    local_ids: jax.Array, local_valid: jax.Array
                    ) -> jax.Array:
    """ε^(ℓ) = max_v ‖h_v^(ℓ) − h̃_v^(ℓ)‖₂ (Theorem 1's per-layer staleness).

    fresh: (M, L-1, S, hidden) this epoch's representations.
    Returns (L-1,) per-hidden-layer max error.
    """
    stale = pull(store, local_ids)          # (M, L-1, S, hidden)
    diff = jnp.linalg.norm(fresh - stale, axis=-1)     # (M, L-1, S)
    diff = jnp.where(local_valid[:, None, :], diff, 0.0)
    return jnp.max(diff, axis=(0, 2))
