"""Jitted public attention entry point with backend dispatch.

``backend="auto"`` → Pallas kernel on TPU, jnp oracle on CPU (same math).
Accepts (batch, seq, heads, head_dim) with GQA K/V (fewer kv heads) and
flattens to the kernel's (bh, seq, hd) layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import (
    flash_attention_pallas)
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "backend"))
def multi_head_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         causal: bool = True,
                         backend: str = "auto") -> jax.Array:
    """q: (B, S, H, D); k, v: (B, S, KV, D) with H % KV == 0.

    Returns (B, S, H, D).
    """
    b, s, h, d = q.shape
    kv = k.shape[2]
    rep = h // kv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)

    if backend == "auto":
        backend = ("pallas" if jax.default_backend() == "tpu" else "jnp")
    if backend == "jnp":
        out = attention_ref(qf, kf, vf, causal=causal)
    else:
        out = flash_attention_pallas(qf, kf, vf, causal=causal,
                                     interpret=(backend != "pallas"))
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)
