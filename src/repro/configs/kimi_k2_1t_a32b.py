"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper-table scale test).

[arXiv:2501.kimi2] 61L d_model=7168 64H (GQA kv=8) d_ff=2048 (per expert)
vocab=163840, MoE 384e top-8 + shared expert. Adafactor is mandatory at
this scale (Adam state alone would exceed 512 x 16 GB HBM).
"""
import dataclasses
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=2048, vocab_size=163840, head_dim=112,
    pattern=("moe",), num_experts=384, experts_per_token=8,
    shared_expert=True, rope_theta=1000000.0,
    optimizer="adafactor", learning_rate=1e-4,
    source="arXiv:2501.kimi2",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=64, vocab_size=512, head_dim=32, num_experts=4,
    experts_per_token=2, dtype="float32", optimizer="adamw",
    moe_impl="ref")
