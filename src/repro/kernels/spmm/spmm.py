"""Pallas TPU kernel: blocked ELL SpMM — the GNN neighbor-aggregation hotspot.

Computes ``out[i] = sum_k wts[i, k] * table[nbr[i, k]]`` for a degree-padded
ELL matrix (see repro.graph.graph.EllMatrix).  This is the P_in·H / P_out·H̃
product at the heart of DIGEST's Eq. 5.

TPU design (vs. the CUDA scatter/atomic formulation):
  * grid = (row_blocks, feature_blocks); rows and features tiled to
    (BLOCK_ROWS, BLOCK_F) = (128, 128) → MXU/VPU-aligned tiles.
  * the gather *table* is carried per feature-block into VMEM
    ((n_cols+1, BLOCK_F)); DIGEST subgraph tables are S,H ≲ 8k rows,
    so a 128-wide feature stripe is ≤ 4 MiB — inside the 16 MiB VMEM
    budget.  Larger tables would need a double-buffered HBM DMA loop;
    out of scope here and documented.
  * per-row-block neighbor ids/weights live in VMEM; the degree loop is a
    ``fori_loop`` of vector gathers + FMAs (affine, no atomics).
  * padding entries point at the sentinel row (id == n_cols) whose weight is
    0.0, so no masking branch is needed in the inner loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 128
BLOCK_F = 128


def _spmm_kernel(nbr_ref, wts_ref, table_ref, out_ref):
    """One (row_block, feature_block) tile."""
    deg = nbr_ref.shape[1]
    table = table_ref[...]                      # (n_cols+1, BF) in VMEM

    def body(k, acc):
        idx = nbr_ref[:, k]                     # (BR,) int32
        gathered = jnp.take(table, idx, axis=0)  # (BR, BF)
        w = wts_ref[:, k].astype(jnp.float32)
        return acc + w[:, None] * gathered.astype(jnp.float32)

    acc = jnp.zeros(out_ref.shape, jnp.float32)
    acc = jax.lax.fori_loop(0, deg, body, acc)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def spmm_pallas(nbr: jax.Array, wts: jax.Array, table: jax.Array,
                interpret: bool = True) -> jax.Array:
    """ELL SpMM via pallas_call.

    Args:
      nbr:   (rows, deg) int32 — indices into ``table`` (sentinel allowed,
             must be < table.shape[0]).
      wts:   (rows, deg) float — 0 at padding slots.
      table: (n_cols_padded, feat) — gather table *including* sentinel row.
    Returns:
      (rows, feat) float32 result.
    """
    rows, deg = nbr.shape
    n_tab, feat = table.shape
    br = min(BLOCK_ROWS, rows)
    bf = min(BLOCK_F, feat)
    if rows % br or feat % bf:
        raise ValueError(f"rows={rows} feat={feat} must be divisible by "
                         f"block ({br},{bf}); pad upstream")
    grid = (rows // br, feat // bf)
    return pl.pallas_call(
        _spmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, deg), lambda i, j: (i, 0)),
            pl.BlockSpec((br, deg), lambda i, j: (i, 0)),
            pl.BlockSpec((n_tab, bf), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((br, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, feat), jnp.float32),
        interpret=interpret,
    )(nbr, wts, table)
