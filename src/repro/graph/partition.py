"""Graph partitioning and the stacked per-subgraph ELL views DIGEST trains on.

The paper partitions with METIS; offline we implement a deterministic
multilevel-flavored greedy (LDG/Fennel-style streaming over a BFS order),
which like METIS optimizes edge cut under balance constraints, plus random
partitioning as the ablation baseline.

``build_partitions`` produces a :class:`StackedPartitions`: every subgraph
padded to identical (S, H, deg) sizes so the whole structure stacks into
(M, ...) arrays — directly shardable over the mesh "data" axis with one
subgraph per device slice, and vmap-able on CPU.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.graph import EllMatrix, Graph, coo_to_ell, gcn_norm_weights


# ---------------------------------------------------------------------------
# Partitioners
# ---------------------------------------------------------------------------

def random_partition(g: Graph, num_parts: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    assign = np.arange(g.num_nodes) % num_parts
    rng.shuffle(assign)
    return assign.astype(np.int32)


def greedy_partition(g: Graph, num_parts: int, seed: int = 0,
                     slack: float = 1.05) -> np.ndarray:
    """LDG-style streaming partition over a BFS order (METIS stand-in)."""
    n = g.num_nodes
    rng = np.random.default_rng(seed)
    capacity = slack * n / num_parts
    assign = np.full(n, -1, np.int32)
    sizes = np.zeros(num_parts, np.int64)

    # BFS order from random seeds → locality in the stream.
    order = np.empty(n, np.int64)
    seen = np.zeros(n, bool)
    pos = 0
    for root in rng.permutation(n):
        if seen[root]:
            continue
        queue = [root]
        seen[root] = True
        while queue:
            v = queue.pop()
            order[pos] = v
            pos += 1
            for u in g.neighbors(v):
                if not seen[u]:
                    seen[u] = True
                    queue.append(u)
    assert pos == n

    for v in order:
        nbrs = g.neighbors(v)
        counts = np.zeros(num_parts, np.float64)
        assigned = assign[nbrs]
        valid = assigned >= 0
        if valid.any():
            np.add.at(counts, assigned[valid], 1.0)
        score = counts * (1.0 - sizes / capacity)
        # Tie-break toward the emptiest part for balance.
        score += 1e-9 * (capacity - sizes)
        best = int(np.argmax(score))
        assign[v] = best
        sizes[best] += 1
    return assign


def edge_cut(g: Graph, assign: np.ndarray) -> int:
    rows = np.repeat(np.arange(g.num_nodes), g.degrees().astype(np.int64))
    cols = g.indices
    return int(np.sum(assign[rows] != assign[cols]) // 2)


PARTITIONERS = {"greedy": greedy_partition, "random": random_partition,
                "metis": greedy_partition}


# ---------------------------------------------------------------------------
# Stacked per-subgraph views
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StackedPartitions:
    """All M subgraphs padded to identical sizes and stacked on axis 0.

    Sentinel id == num_nodes (a zero row is appended to every global table).

    Boundary / compact-store views: the **boundary set** is the union of
    all subgraph halos — the only rows the stale store ever serves.  The
    global→slot map (``store_map``) lets the HaloExchange subsystem keep a
    compact ``(L-1, |boundary|+1, hidden)`` slab instead of a dense
    ``(L-1, N+1, hidden)`` array; slot ``num_boundary`` is the sentinel.
    """

    num_nodes: int
    num_parts: int
    local_ids: np.ndarray    # (M, S) int32, global node id or sentinel
    local_valid: np.ndarray  # (M, S) bool
    halo_ids: np.ndarray     # (M, H) int32, global node id or sentinel
    halo_valid: np.ndarray   # (M, H) bool
    in_nbr: np.ndarray       # (M, S, Din) int32 → local slot index or S
    in_wts: np.ndarray       # (M, S, Din) float32
    out_nbr: np.ndarray      # (M, S, Dout) int32 → halo slot index or H
    out_wts: np.ndarray      # (M, S, Dout) float32
    labels: np.ndarray       # (M, S) int32
    train_mask: np.ndarray   # (M, S) bool (False at padding)
    val_mask: np.ndarray     # (M, S) bool
    test_mask: np.ndarray    # (M, S) bool
    # Compact-store (boundary) indexing, emitted for HaloExchange.
    store_map: np.ndarray    # (N+1,) int32 global id → slot or B sentinel
    store_ids: np.ndarray    # (B+1,) int32 slot → global id, [B] == N
    halo_slots: np.ndarray   # (M, H) int32 store slot of each halo entry
    local_slots: np.ndarray  # (M, S) int32 store slot of each local row
                             #   (B where the local node is not boundary)
    out_nbr_store: np.ndarray   # (M, S, Dout) int32 → store slot or B
    out_nbr_global: np.ndarray  # (M, S, Dout) int32 → global id or N

    @property
    def part_size(self) -> int:
        return self.local_ids.shape[1]

    @property
    def halo_size(self) -> int:
        return self.halo_ids.shape[1]

    @property
    def num_boundary(self) -> int:
        return len(self.store_ids) - 1

    def halo_ratio(self) -> np.ndarray:
        """Paper Fig. 9 metric: |out-of-subgraph| / |in-subgraph| per part."""
        return (self.halo_valid.sum(axis=1)
                / np.maximum(self.local_valid.sum(axis=1), 1))

    def boundary_fraction(self) -> float:
        """|boundary| / N — the compact-vs-dense store row ratio."""
        return self.num_boundary / max(self.num_nodes, 1)

    def push_rows(self) -> int:
        """Σ_m |boundary ∩ V_m| — rows shipped per PUSH sync (§3.3)."""
        return int((self.local_valid
                    & (self.local_slots < self.num_boundary)).sum())

    def pull_rows(self) -> int:
        """Σ_m |halo(G_m)| — rows shipped per PULL sync (§3.3)."""
        return int(self.halo_valid.sum())


def build_partitions(g: Graph, num_parts: int, method: str = "greedy",
                     seed: int = 0, pad_multiple: int = 8
                     ) -> StackedPartitions:
    assign = PARTITIONERS[method](g, num_parts, seed=seed)
    n = g.num_nodes
    rows, cols, wts = gcn_norm_weights(g)

    def _pad_to(x: int) -> int:
        return max(((x + pad_multiple - 1) // pad_multiple) * pad_multiple,
                   pad_multiple)

    parts_local = [np.where(assign == m)[0].astype(np.int32)
                   for m in range(num_parts)]
    # Halo = out-of-subgraph endpoints of P rows owned by the part.
    parts_halo = []
    for m in range(num_parts):
        sel = assign[rows] == m
        out = assign[cols[sel]] != m
        halo = np.unique(cols[sel][out]).astype(np.int32)
        parts_halo.append(halo)

    S = _pad_to(max(len(p) for p in parts_local))
    H = _pad_to(max((len(h) for h in parts_halo), default=1))

    local_ids = np.full((num_parts, S), n, np.int32)
    local_valid = np.zeros((num_parts, S), bool)
    halo_ids = np.full((num_parts, H), n, np.int32)
    halo_valid = np.zeros((num_parts, H), bool)
    in_ells, out_ells = [], []
    max_din, max_dout = 1, 1

    for m in range(num_parts):
        loc, halo = parts_local[m], parts_halo[m]
        local_ids[m, :len(loc)] = loc
        local_valid[m, :len(loc)] = True
        halo_ids[m, :len(halo)] = halo
        halo_valid[m, :len(halo)] = True

        g2l = np.full(n + 1, S, np.int64)   # global → local slot
        g2l[loc] = np.arange(len(loc))
        g2h = np.full(n + 1, H, np.int64)   # global → halo slot
        g2h[halo] = np.arange(len(halo))

        sel = assign[rows] == m
        r_m, c_m, w_m = rows[sel], cols[sel], wts[sel]
        local_rows = g2l[r_m].astype(np.int32)
        is_in = assign[c_m] == m

        ell_in = coo_to_ell(local_rows[is_in],
                            g2l[c_m[is_in]].astype(np.int32),
                            w_m[is_in], S, S)
        ell_out = coo_to_ell(local_rows[~is_in],
                             g2h[c_m[~is_in]].astype(np.int32),
                             w_m[~is_in], S, H)
        in_ells.append(ell_in)
        out_ells.append(ell_out)
        max_din = max(max_din, ell_in.max_degree)
        max_dout = max(max_dout, ell_out.max_degree)

    max_din, max_dout = _pad_to(max_din), _pad_to(max_dout)

    def _stack(ells: list[EllMatrix], deg: int, n_cols: int):
        nbr = np.full((num_parts, S, deg), n_cols, np.int32)
        w = np.zeros((num_parts, S, deg), np.float32)
        for m, e in enumerate(ells):
            nbr[m, :, :e.max_degree] = e.nbr
            w[m, :, :e.max_degree] = e.wts
        return nbr, w

    in_nbr, in_wts = _stack(in_ells, max_din, S)
    out_nbr, out_wts = _stack(out_ells, max_dout, H)

    labels = np.zeros((num_parts, S), np.int32)
    tr = np.zeros((num_parts, S), bool)
    va = np.zeros((num_parts, S), bool)
    te = np.zeros((num_parts, S), bool)
    for m, loc in enumerate(parts_local):
        labels[m, :len(loc)] = g.labels[loc]
        tr[m, :len(loc)] = g.train_mask[loc]
        va[m, :len(loc)] = g.val_mask[loc]
        te[m, :len(loc)] = g.test_mask[loc]

    # Boundary set = union of all halos; global→compact-slot map for the
    # HaloExchange store (slot B is the sentinel, like id n globally).
    boundary = (np.unique(np.concatenate(parts_halo))
                if any(len(h) for h in parts_halo)
                else np.empty(0, np.int32)).astype(np.int32)
    B = len(boundary)
    store_map = np.full(n + 1, B, np.int32)
    store_map[boundary] = np.arange(B, dtype=np.int32)
    store_ids = np.concatenate([boundary, [n]]).astype(np.int32)
    halo_slots = store_map[halo_ids]
    local_slots = store_map[local_ids]

    # Per-part remaps of the out-ELL: halo-slot → store-slot / global id,
    # so the out-of-subgraph product can gather straight from the shared
    # compact slab (or from x_global for layer 0) with no per-part table.
    out_nbr_store = np.empty_like(out_nbr)
    out_nbr_global = np.empty_like(out_nbr)
    for m in range(num_parts):
        ext_s = np.concatenate([halo_slots[m], [B]]).astype(np.int32)
        ext_g = np.concatenate([halo_ids[m], [n]]).astype(np.int32)
        out_nbr_store[m] = ext_s[out_nbr[m]]
        out_nbr_global[m] = ext_g[out_nbr[m]]

    return StackedPartitions(
        num_nodes=n, num_parts=num_parts,
        local_ids=local_ids, local_valid=local_valid,
        halo_ids=halo_ids, halo_valid=halo_valid,
        in_nbr=in_nbr, in_wts=in_wts, out_nbr=out_nbr, out_wts=out_wts,
        labels=labels, train_mask=tr, val_mask=va, test_mask=te,
        store_map=store_map, store_ids=store_ids,
        halo_slots=halo_slots, local_slots=local_slots,
        out_nbr_store=out_nbr_store, out_nbr_global=out_nbr_global)
