"""Compiled-HLO invariants of the fully-SPMD collective epoch.

DIGEST §3.3's cost model requires pushes to stay owner-local and pulls
to ship exactly the ragged halo blocks.  These tests make that a
*regression-tested property of the compiled program*: the collective-mode
epoch's partitioned HLO must contain

  * exactly the expected ragged all-to-all pulls (one per store tensor,
    layers batched inside — see ``hlo_utils.expected_all_to_all``), and
  * ZERO all-gather / collective-permute / reduce-scatter ops — i.e. no
    cross-device scatter or dynamic-update-slice traffic for pushes, and
    no replicated-slab fallback for pulls (post-SPMD, all cross-device
    movement is explicit collectives; see tests/hlo_utils.py).

Checked for M == devices and M == 2·devices (parts-per-device = 2) on a
forced 8-device host mesh; GAT's projected-row pull (owner-shard
projection dedup) is censused separately — the shard-local projection
einsums must add zero collectives beyond the per-layer z exchanges; the
dense-gather fallback is compiled too as a positive control (it *does*
materialize all-gathers).
"""
import os
import sys

import jax
import pytest

pytestmark = pytest.mark.leg("m16-ppd2-hlo")


def _hlo_checks():
    import hlo_utils
    from repro.graph import make_dataset
    from repro.launch.mesh import make_host_mesh

    D = 8
    assert jax.device_count() >= D, jax.device_count()
    mesh = make_host_mesh(data=D)
    g = make_dataset("flickr-sim", scale=0.1, seed=5)

    for M in (D, 2 * D):                      # one and two parts/device
        for storage in ("fp32", "int8"):
            compiled = hlo_utils.compile_epoch(
                g, M, mesh, storage=storage, pull_mode="collective")
            c = hlo_utils.collective_counts(compiled.as_text())
            label = f"M={M} D={D} {storage}"
            # No cross-device push/pull fallback traffic of any kind.
            assert c["all-gather"] == 0, (label, c)
            assert c["collective-permute"] == 0, (label, c)
            assert c["reduce-scatter"] == 0, (label, c)
            # Exactly the expected ragged pull exchanges.
            want = hlo_utils.expected_all_to_all(storage)
            assert c["all-to-all"] == want, (label, c)
            # Gradient AGG / metric reductions are the only other
            # collectives and they do exist (sanity that the census
            # sees the module at all).
            assert c["all-reduce"] > 0, (label, c)

    # GAT projected-row pull: the owner-shard projection (once per layer,
    # shard-local einsum on the slot-sharded store) must add ZERO extra
    # collectives — still one all-to-all per pulled z tensor per hidden
    # layer, still no all-gather/permute/reduce-scatter.
    for storage in ("fp32", "int8"):
        compiled = hlo_utils.compile_epoch(
            g, D, mesh, storage=storage, pull_mode="collective",
            model="gat")
        c = hlo_utils.collective_counts(compiled.as_text())
        label = f"gat D={D} {storage}"
        assert c["all-gather"] == 0, (label, c)
        assert c["collective-permute"] == 0, (label, c)
        assert c["reduce-scatter"] == 0, (label, c)
        assert c["all-to-all"] == hlo_utils.expected_all_to_all(
            storage, model="gat"), (label, c)
        assert c["all-reduce"] > 0, (label, c)

    # Positive control: the partitioner-dependent gather/scatter
    # fallback DOES replicate the slab (all-gathers, no all-to-all) —
    # i.e. the census distinguishes the two programs.
    compiled = hlo_utils.compile_epoch(g, D, mesh, storage="fp32",
                                       pull_mode="gather")
    c = hlo_utils.collective_counts(compiled.as_text())
    assert c["all-gather"] > 0, c
    assert c["all-to-all"] == 0, c


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (CI REPRO_HOST_DEVICES=8 job)")
def test_hlo_collective_invariants_inprocess():
    _hlo_checks()


def test_hlo_collective_invariants_subprocess():
    """Force an 8-device CPU platform in a subprocess so the HLO
    invariants are checked even on single-device hosts."""
    if jax.device_count() >= 8:
        pytest.skip("covered by the in-process variant")
    import hlo_utils
    hlo_utils.run_forced_device_subprocess(__file__, "HLO_INVARIANTS_OK")


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    _hlo_checks()
    print("HLO_INVARIANTS_OK")
