#!/usr/bin/env python
"""End-to-end LM training driver on an assigned architecture (reduced for
CPU) with DIGEST periodic pod synchronization (local SGD across n_pod
parameter copies).

  PYTHONPATH=src python examples/train_lm.py --arch qwen3-0.6b \
      --steps 300 --n-pod 2 --sync-interval 10
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_smoke_arch
from repro.data import make_lm_pipeline
from repro.train import TrainSettings, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-pod", type=int, default=2)
    ap.add_argument("--sync-interval", type=int, default=10)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/digest_lm_ckpt")
    args = ap.parse_args()

    cfg = dataclasses.replace(get_smoke_arch(args.arch),
                              vocab_size=args.vocab,
                              learning_rate=args.lr)
    settings = TrainSettings(
        sync_mode="digest" if args.n_pod > 1 else "every_step",
        n_pod=args.n_pod, sync_interval=args.sync_interval,
        total_steps=args.steps, warmup_steps=max(args.steps // 20, 5))
    state = init_train_state(cfg, settings)
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} (reduced) params={n_params:,} "
          f"n_pod={args.n_pod} sync_interval={args.sync_interval}")

    step_fn = jax.jit(make_train_step(cfg, settings))
    data = make_lm_pipeline(args.vocab, args.batch, args.seq, seed=0)
    t0 = time.perf_counter()
    for i in range(args.steps):
        b = next(data)
        state, m = step_fn(state, {"tokens": b.tokens,
                                   "labels": b.labels, "mask": b.mask})
        if (i + 1) % max(args.steps // 10, 1) == 0:
            div = float(m.get("pod_divergence", 0.0))
            print(f"step {i+1:5d} loss={float(m['loss']):.4f} "
                  f"pod_div={div:.4f} "
                  f"({(time.perf_counter()-t0)/(i+1):.2f}s/step)")
    save_checkpoint(args.ckpt_dir, args.steps, {"params": state["params"]})
    print(f"done; checkpoint in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
