"""Pallas TPU kernel: fused GAT edge-softmax + neighbor aggregation.

Computes, for one padded-ELL adjacency structure (one of DIGEST's two —
in-subgraph or out-of-subgraph), the *unnormalized online-softmax partial*:

    e[i,k]  = LeakyReLU(s_dst[i] + s_src[nbr[i,k]])        (masked)
    m[i]    = max_k e[i,k]
    l[i]    = Σ_k exp(e[i,k] − m[i])
    acc[i,:]= Σ_k exp(e[i,k] − m[i]) · z[nbr[i,k], :]

Returning (acc, m, l) instead of the normalized output lets the caller
merge the in-subgraph and stale out-of-subgraph partials exactly (same
online-softmax algebra as flash attention / stale-KV), so the fused kernel
composes with DIGEST's split aggregation without materializing edge
scores in HBM — the GPU implementation's segment-softmax writes e twice.

TPU design: grid (row_blocks, feat_blocks); the degree loop runs online
softmax in registers; gather tables (z stripe, s_src) live in VMEM; m/l
are written once (feature-block 0 owns them).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 128
BLOCK_F = 128
NEG_INF = -1e30
LEAKY_SLOPE = 0.2


def _gat_kernel(nbr_ref, valid_ref, sdst_ref, ssrc_ref, z_ref,
                acc_ref, m_ref, l_ref):
    deg = nbr_ref.shape[1]
    j = pl.program_id(1)
    z = z_ref[...]                                   # (n_tab, BF)
    ssrc = ssrc_ref[...]                             # (n_tab,)
    sdst = sdst_ref[...]                             # (BR,)

    def body(k, carry):
        m_prev, l_prev, acc = carry
        idx = nbr_ref[:, k]                          # (BR,)
        sv = jnp.take(ssrc, idx, axis=0)             # (BR,)
        e = sdst + sv
        e = jnp.where(e >= 0, e, LEAKY_SLOPE * e)    # LeakyReLU
        e = jnp.where(valid_ref[:, k], e, NEG_INF)
        m_new = jnp.maximum(m_prev, e)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(e - m_new)
        l_new = alpha * l_prev + p
        rows = jnp.take(z, idx, axis=0).astype(jnp.float32)  # (BR, BF)
        acc = acc * alpha[:, None] + p[:, None] * rows
        return m_new, l_new, acc

    br, bf = acc_ref.shape
    init = (jnp.full((br,), NEG_INF, jnp.float32),
            jnp.zeros((br,), jnp.float32),
            jnp.zeros((br, bf), jnp.float32))
    m, l, acc = jax.lax.fori_loop(0, deg, body, init)
    acc_ref[...] = acc

    @pl.when(j == 0)
    def _write_stats():
        m_ref[...] = m
        l_ref[...] = l


@functools.partial(jax.jit, static_argnames=("interpret",))
def gat_edge_partial_pallas(nbr: jax.Array, valid: jax.Array,
                            s_dst: jax.Array, s_src: jax.Array,
                            z: jax.Array, interpret: bool = True
                            ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused partial-softmax aggregation.

    Args:
      nbr:   (rows, deg) int32 indices into z/s_src (sentinel allowed).
      valid: (rows, deg) bool — edge validity mask.
      s_dst: (rows,) f32 destination scores.
      s_src: (n_tab,) f32 source-score table (incl. sentinel row).
      z:     (n_tab, feat) value table (incl. sentinel row).
    Returns:
      (acc (rows, feat) f32, m (rows,) f32, l (rows,) f32).
    """
    rows, deg = nbr.shape
    n_tab, feat = z.shape
    br = min(BLOCK_ROWS, rows)
    bf = min(BLOCK_F, feat)
    if rows % br or feat % bf:
        raise ValueError(f"rows={rows}/feat={feat} must divide ({br},{bf})")
    grid = (rows // br, feat // bf)
    return pl.pallas_call(
        _gat_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, deg), lambda i, j: (i, 0)),
            pl.BlockSpec((br, deg), lambda i, j: (i, 0)),
            pl.BlockSpec((br,), lambda i, j: (i,)),
            pl.BlockSpec((n_tab,), lambda i, j: (0,)),
            pl.BlockSpec((n_tab, bf), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((br, bf), lambda i, j: (i, j)),
            pl.BlockSpec((br,), lambda i, j: (i,)),
            pl.BlockSpec((br,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, feat), jnp.float32),
            jax.ShapeDtypeStruct((rows,), jnp.float32),
            jax.ShapeDtypeStruct((rows,), jnp.float32),
        ],
        interpret=interpret,
    )(nbr, valid, s_dst.astype(jnp.float32), s_src.astype(jnp.float32), z)
