#!/usr/bin/env python
"""Partition-at-scale smoke: the O(E) streaming partitioner on a graph
two orders of magnitude past the test suite's, under a wall-clock budget.

Builds a 200k-node community power-law graph and runs the boundary-aware
``greedy_partition(halo_weight=0.25)`` at 64 parts — the regime where the
retired dense ``(num_parts, num_nodes)`` halo matrix would have cost
12.8M bools *per scoring step* and the build minutes of column scans.
The replica-array partitioner touches only the <= deg(v) adjacent
entries per step, so the whole build must land inside the (generous,
env-overridable) budget; the script asserts the wall clock, a sane
partition (every part non-empty, balance within the LDG slack), and
that the halo accounting matches a direct recount from the assignment.

  PYTHONPATH=src python scripts/partition_scale_smoke.py
  REPRO_SCALE_NODES=1000000 REPRO_SCALE_PARTS=256 \
      REPRO_SCALE_BUDGET_S=900 PYTHONPATH=src \
      python scripts/partition_scale_smoke.py   # the 1M x 256 dry-run

Pure numpy/host — no JAX devices needed; CI runs this as the
`partition-scale` leg.
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

from repro.graph import community_powerlaw_graph
from repro.graph.partition import build_partitions, greedy_partition

NODES = int(os.environ.get("REPRO_SCALE_NODES", 200_000))
PARTS = int(os.environ.get("REPRO_SCALE_PARTS", 64))
BUDGET_S = float(os.environ.get("REPRO_SCALE_BUDGET_S", 420.0))
HALO_WEIGHT = 0.25
SLACK = 1.05


def main() -> int:
    t0 = time.perf_counter()
    g = community_powerlaw_graph(num_nodes=NODES, seed=0,
                                 feature_dim=8, name="scale-smoke")
    t_gen = time.perf_counter() - t0
    edges = len(g.indices) // 2
    print(f"graph: {g.num_nodes} nodes, {edges} edges "
          f"(generated in {t_gen:.1f}s)", flush=True)

    t0 = time.perf_counter()
    assign = greedy_partition(g, PARTS, halo_weight=HALO_WEIGHT)
    t_part = time.perf_counter() - t0
    print(f"greedy_partition: {PARTS} parts, halo_weight={HALO_WEIGHT} "
          f"in {t_part:.1f}s "
          f"({1e6 * t_part / g.num_nodes:.1f}us/node)", flush=True)

    sizes = np.bincount(assign, minlength=PARTS)
    assert sizes.min() > 0, f"empty part: {sizes}"
    balance = sizes.max() / (g.num_nodes / PARTS)
    # Capacity mask admits one last node into a part sitting just under
    # slack·n/M, so the hard ceiling is floor(capacity) + 1 rows.
    cap = int(SLACK * g.num_nodes / PARTS) + 1
    assert sizes.max() <= cap, f"part size {sizes.max()} > cap {cap}"

    # Recount Σ_m |halo| directly from the assignment — the quantity the
    # replica arrays tracked incrementally during the stream.
    rows = np.repeat(np.arange(g.num_nodes),
                     np.diff(g.indptr).astype(np.int64))
    cut = assign[rows] != assign[g.indices]
    halo_rows = len(np.unique(
        assign[rows[cut]].astype(np.int64) * g.num_nodes
        + g.indices[cut]))
    print(f"partition: balance={balance:.4f} "
          f"edge_cut={int(cut.sum()) // 2} halo_rows={halo_rows}",
          flush=True)

    elapsed = t_gen + t_part
    assert elapsed <= BUDGET_S, \
        f"partition-scale smoke took {elapsed:.1f}s > budget {BUDGET_S}s"
    print(f"OK: {NODES} nodes / {PARTS} parts in {elapsed:.1f}s "
          f"(budget {BUDGET_S:.0f}s)")

    # Small-scale RCM cross-check rides along (64 parts of 200k rows is
    # too slow to double-build here; the ordering is covered at depth by
    # tests/test_order_invariance.py): the full build_partitions plumbing
    # at a fraction of the nodes, asserting the ordered worklist never
    # regresses the identity layout.
    if os.environ.get("REPRO_SCALE_SKIP_ORDER") != "1":
        gs = community_powerlaw_graph(num_nodes=NODES // 10, seed=1,
                                      feature_dim=8, name="order-smoke")
        a = build_partitions(gs, 8, halo_weight=HALO_WEIGHT, order="none")
        b = build_partitions(gs, 8, halo_weight=HALO_WEIGHT, order="rcm")
        occ_a = a.chunk_worklist(512).occupancy
        occ_b = b.chunk_worklist(512).occupancy
        assert occ_b <= occ_a + 1e-12, (occ_a, occ_b)
        print(f"order: occupancy none={occ_a:.3f} rcm={occ_b:.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
