"""Online serving benchmark: query latency, throughput, cache hit-rate.

Headline: batched queries (B=1024) against the 8-part owner-sharded
serving store of a 40k-node power-law graph (papers-sim), Zipf(1.1)
traffic with hubs hottest — p50/p99 latency and queries/sec with the
hot-row cache off and at 10% capacity, then the hit-rate surface over
Zipf skew × cache capacity (steady-state: counters snapshotted after a
warm phase), and the served-vs-``full_graph_forward`` parity record per
model.  Writes ``BENCH_serving.json`` at the repo root next to the CSV
rows.

Capacity intuition: a c·n-row cache can at best hold the c·n hottest
nodes, so the ceiling is the Zipf mass of the head —
``H(c·n, s) / H(n, s)`` ≈ 87% for s=1.1, c=0.1, n=40k.  The 4-way LRU
lands within a few points of that ceiling; at s=0.8 (flatter) the same
capacity is worth far less, which is exactly what the sweep shows.
"""
from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_scale
from repro.core import serving
from repro.core.digest import (full_graph_forward, prepare_graph_data,
                               top_layer_reps)
from repro.graph import make_dataset
from repro.launch.serving_driver import run_serve_loop
from repro.models.gnn import GNNConfig, gnn_specs
from repro.nn import init_params

import jax

PARTS = 8
BATCH = 1024
SKEWS = (0.8, 1.1, 1.3)
CAPACITIES = (0.01, 0.05, 0.10, 0.20)
WARM_BATCHES = 16
MEASURE_BATCHES = 48


def _setup(model: str, dataset: str, scale: float, hidden: int = 64,
           parts: int = PARTS):
    g = make_dataset(dataset, scale=scale, seed=0)
    data = prepare_graph_data(g, parts, seed=0)
    cfg = GNNConfig(model=model, num_layers=2,
                    in_dim=g.features.shape[1], hidden_dim=hidden,
                    num_classes=int(g.labels.max()) + 1)
    params = init_params(jax.random.PRNGKey(0), gnn_specs(cfg))
    plan = serving.build_serve_plan(data)
    store = serving.make_refresh_fn()(
        serving.init_serve_store(plan, cfg.hidden_dim),
        top_layer_reps(cfg, params, data), plan.refresh_data())
    return g, data, cfg, params, plan, store


def _cache_rows(n: int, frac: float, ways: int = 4) -> int:
    return max(int(n * frac) // ways, 1) * ways


def _drive(cfg, scfg, params, store, qdata, queries, warmup):
    cache = serving.init_cache(scfg, cfg.num_classes)

    def step(cache, q):
        _, cache = serving.serve_query(cfg, scfg, params, store, cache,
                                       qdata, jnp.asarray(q))
        return cache, None

    cache, _, stats = run_serve_loop(step, queries, carry=cache,
                                     warmup=warmup,
                                     items_per_call=scfg.batch_size)
    return cache, stats


def run() -> list[dict]:
    rows, result = [], {}
    g, data, cfg, params, plan, store = _setup(
        "gcn", "papers-sim", bench_scale())
    n = g.num_nodes
    qdata = plan.query_data()
    hot = np.argsort(-g.degrees()).astype(np.int32)
    result["config"] = {
        "dataset": "papers-sim", "num_nodes": n, "num_parts": PARTS,
        "model": "gcn", "hidden": cfg.hidden_dim,
        "batch_size": BATCH, "cache_ways": 4,
        "store_rows": plan.store_rows, "backend": jax.default_backend(),
        "devices": jax.device_count()}

    # --- headline latency / throughput, cache off vs 10% capacity -----
    result["latency"] = {}
    for frac in (0.0, 0.10):
        cr = 0 if frac == 0 else _cache_rows(n, frac)
        scfg = serving.ServeConfig(batch_size=BATCH, cache_rows=cr)
        queries = serving.zipf_queries(n, BATCH, 24, 1.1, seed=1,
                                       hot_ids=hot)
        cache, stats = _drive(cfg, scfg, params, store, qdata, queries,
                              warmup=4)
        rec = {"cache_rows": cr, "p50_ms": round(stats.p50_ms, 3),
               "p99_ms": round(stats.p99_ms, 3),
               "queries_per_sec": round(stats.per_sec),
               "hit_rate": round(serving.hit_rate(cache), 4)}
        result["latency"][f"cache_{int(frac*100)}pct"] = rec
        rows.append({"name": f"serve_gcn_b{BATCH}_cache{int(frac*100)}pct",
                     "us_per_call": round(stats.mean_ms * 1e3, 1), **rec})

    # --- hit-rate surface: Zipf skew × cache capacity -----------------
    result["hit_rate_sweep"] = []
    for skew in SKEWS:
        queries = serving.zipf_queries(
            n, BATCH, WARM_BATCHES + MEASURE_BATCHES, skew, seed=2,
            hot_ids=hot)
        for frac in CAPACITIES:
            scfg = serving.ServeConfig(batch_size=BATCH,
                                       cache_rows=_cache_rows(n, frac))
            cache, _ = _drive(cfg, scfg, params, store, qdata,
                              queries[:WARM_BATCHES], warmup=0)
            h0, m0 = int(cache["hits"]), int(cache["misses"])

            def step(cache, q):
                _, cache = serving.serve_query(cfg, scfg, params, store,
                                               cache, qdata,
                                               jnp.asarray(q))
                return cache, None

            cache, _, _ = run_serve_loop(step, queries[WARM_BATCHES:],
                                         carry=cache)
            dh = int(cache["hits"]) - h0
            dm = int(cache["misses"]) - m0
            steady = dh / max(dh + dm, 1)
            result["hit_rate_sweep"].append(
                {"skew": skew, "capacity_frac": frac,
                 "cache_rows": scfg.cache_rows,
                 "hit_rate_steady": round(steady, 4),
                 "hit_rate_total": round(serving.hit_rate(cache), 4)})
            rows.append({"name": f"serve_hit_s{skew}_c{int(frac*100)}pct",
                         "us_per_call": "",
                         "hit_rate": round(steady, 4)})

    # --- served-vs-offline parity record per model --------------------
    result["parity"] = {}
    for model in ("gcn", "sage", "gat"):
        gs, ds, cfgs, ps, plans, stores = _setup(
            model, "flickr-sim", 0.25 * bench_scale() or 0.25)
        ref = np.asarray(full_graph_forward(cfgs, ps, ds)[0])
        scfg = serving.ServeConfig(batch_size=256)
        cache = serving.init_cache(scfg, cfgs.num_classes)
        err = 0.0
        qd = plans.query_data()
        for lo in range(0, gs.num_nodes, 256):
            q = np.full(256, gs.num_nodes, np.int32)
            ids = np.arange(lo, min(lo + 256, gs.num_nodes))
            q[:len(ids)] = ids
            out, cache = serving.serve_query(cfgs, scfg, ps, stores,
                                             cache, qd, jnp.asarray(q))
            err = max(err, float(np.abs(
                np.asarray(out)[:len(ids)] - ref[ids]).max()))
        result["parity"][model] = {"max_abs_diff": err,
                                   "bitwise": err == 0.0}
        rows.append({"name": f"serve_parity_{model}", "us_per_call": "",
                     "max_abs_diff": f"{err:.2e}"})

    out_path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_serving.json")
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
