"""Sampled-training variance probe: control variates vs plain neighbor
sampling at EQUAL fanout.

From one warmed state (a few exact full-coverage steps populate the
stale store and the local history), draw K fanout-bounded batches and
run one SGD step per draw under each estimator.  Two error measures
against the exact full-coverage step from the same state:

  * ``grad_mse``  — MSE of the updated parameters (SGD: update = -lr·g,
    so this is lr²·the gradient estimator's MSE);
  * ``act_mse``   — MSE of the estimated hidden-layer activations (the
    step's ``hist`` refresh) against the exact activations.

The CV rows must come out strictly below the plain rows — the VR-GCN
variance-reduction claim, realized on the DIGEST stale store.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import bench_scale, emit
from benchmarks.gnn_common import setup
from repro.core import TrainSettings, make_sampled_epoch_fn, sampled_train
from repro.graph import build_sampler
from repro.optim import sgd


def _settings(estimator: str) -> TrainSettings:
    return TrainSettings(sync_interval=2, mode="digest",
                         pull_mode="gather", sample_estimator=estimator)


def run() -> list[dict]:
    scale = bench_scale()
    _, data, cfg = setup("flickr-sim", scale=0.15 * scale)
    opt = sgd(0.1)
    tdata = {k: v for k, v in data.items() if not k.startswith("_")}

    probe = build_sampler(data, fanout=1, batch_seeds=1 << 30)
    full = build_sampler(data, fanout=max(probe.max_in_degree, 1),
                         batch_seeds=1 << 30)
    state, _ = sampled_train(cfg, opt, data, full, _settings("cv"),
                             steps=6, eval_every=6)

    steps = {e: jax.jit(make_sampled_epoch_fn(cfg, opt, _settings(e)))
             for e in ("cv", "plain")}
    ref_batch = {k: jnp.asarray(v) for k, v in full.full_batch().items()}
    ref, _ = steps["cv"](state, tdata, ref_batch)
    ref_params = jax.tree.leaves(ref["params"])

    draws = max(int(8 * scale), 4)
    rows = []
    for fanout in (2, 4):
        sampler = build_sampler(data, fanout=fanout,
                                batch_seeds=1 << 30, seed=11)
        for est, step in steps.items():
            gmse = amse = 0.0
            for t in range(draws):
                batch = {k: jnp.asarray(v)
                         for k, v in sampler.sample(t).items()}
                s, _ = step(state, tdata, batch)
                gmse += float(sum(
                    jnp.mean((a - b) ** 2)
                    for a, b in zip(jax.tree.leaves(s["params"]),
                                    ref_params)))
                amse += float(jnp.mean((s["hist"] - ref["hist"]) ** 2))
            rows.append({
                "name": f"sampling/fanout={fanout}/{est}",
                "grad_mse": f"{gmse / draws:.3e}",
                "act_mse": f"{amse / draws:.3e}",
                "draws": draws,
            })
    return rows


if __name__ == "__main__":
    emit(run())
