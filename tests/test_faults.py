"""Fault-tolerance subsystem: deterministic injection, degraded-mode
operation, and crash-safe resume.

Pins the PR's three guarantees:

  * **Zero-fault parity** — attaching the fault-aware state leaves (a
    different compiled program: masked pushes, age table, watchdog)
    with an all-True mask changes NOTHING: trajectories stay bitwise
    identical to the pre-fault program, for both the SPMD epoch loop
    and the DIGEST-A event simulator, and the compiled-HLO collective
    census is unchanged (zero all-gathers, same all_to_all count).
  * **Degradation, not divergence** — under injected crashes / dropped
    pushes / corrupted wire rows the run completes finite; the probe's
    measured staleness is elevated above the fault-free baseline but
    stays within the ``max_staleness`` watchdog bound.
  * **Exact resume** — kill-and-resume from the checksummed checkpoint
    is bitwise equal to the uninterrupted run (faults included — the
    schedule is a pure function of (seed, round, worker)), and a
    corrupted newest checkpoint falls back to the previous valid one.
"""
import functools
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AsyncSettings, FaultConfig, FaultSchedule,
                        TrainSettings, digest_a_train, digest_train)
from repro.checkpoint import latest_step
from repro.graph import make_dataset
from repro.models.gnn import GNNConfig
from repro.optim import adam

pytestmark = pytest.mark.leg("fault-smoke")


@functools.lru_cache(maxsize=None)
def _graph(seed: int = 0):
    return make_dataset("flickr-sim", scale=0.12, seed=seed)


def _cfg(g, num_layers=2, hidden=32):
    return GNNConfig(model="gcn", num_layers=num_layers,
                     in_dim=g.features.shape[1], hidden_dim=hidden,
                     num_classes=int(g.labels.max()) + 1)


def _leaves_equal(a, b):
    return all(jnp.array_equal(x, y) for x, y in
               zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# Schedule determinism
# ---------------------------------------------------------------------------

def test_schedule_is_pure_and_order_independent():
    cfg = FaultConfig(seed=3, crash_rate=0.2, drop_push_rate=0.3,
                      delay_pull_rate=0.1, corrupt_rate=0.15)
    s1, s2 = FaultSchedule(cfg), FaultSchedule(cfg)
    # Same (round, worker) query → same answer, regardless of the order
    # (or number of times) other queries were issued in between.
    fwd = [(s1.crashes(r, w), s1.drops_push(r, w), s1.delays_pull(r, w),
            s1.corrupts_push(r, w))
           for r in range(1, 30) for w in range(4)]
    rev = [(s2.crashes(r, w), s2.drops_push(r, w), s2.delays_pull(r, w),
            s2.corrupts_push(r, w))
           for r in reversed(range(1, 30)) for w in reversed(range(4))]
    assert fwd == list(reversed(rev))
    # Every fault class actually fires somewhere at these rates.
    hits = np.array(fwd).any(axis=0)
    assert hits.all(), hits
    # The fault classes draw from disjoint streams (distinct tags).
    cols = np.array(fwd)
    assert not np.array_equal(cols[:, 0], cols[:, 1])
    # A different seed gives a different schedule.
    s3 = FaultSchedule(FaultConfig(seed=4, crash_rate=0.2,
                                   drop_push_rate=0.3))
    assert any(s3.crashes(r, w) != s1.crashes(r, w)
               for r in range(1, 30) for w in range(4))


def test_push_ok_matches_predicates():
    cfg = FaultConfig(seed=7, crash_rate=0.15, crash_rounds=2,
                      drop_push_rate=0.25, corrupt_rate=0.1)
    s = FaultSchedule(cfg)
    for r in range(1, 20):
        ok = s.push_ok(r, 4)
        for m in range(4):
            lost = (s.drops_push(r, m) or s.corrupts_push(r, m)
                    or s.down(r, m))
            assert ok[m] == (not lost), (r, m)
    # The crash window: a crash at round r keeps the worker down for
    # crash_rounds rounds (inclusive), then it is back.
    r, w = next((r, w) for r in range(1, 50) for w in range(4)
                if s.crashes(r, w))
    assert s.down(r, w) and s.down(r + 1, w)
    # down() never reaches past the window.
    assert not any(s.crashes(c, w)
                   for c in range(r + 1, r + cfg.crash_rounds + 1)) \
        or s.down(r + cfg.crash_rounds, w)


# ---------------------------------------------------------------------------
# Zero-fault parity: fault-aware program == plain program, bitwise
# ---------------------------------------------------------------------------

def _spmd_run(max_staleness=None, faults=None, epochs=6):
    g = _graph()
    from repro.core import prepare_graph_data
    data = prepare_graph_data(g, 4)
    settings = TrainSettings(sync_interval=2, mode="digest",
                             max_staleness=max_staleness)
    return digest_train(_cfg(g), adam(5e-3), data, settings, epochs,
                        eval_every=epochs, faults=faults)


def test_zero_fault_parity_spmd():
    base_state, base_hist = _spmd_run()
    # A disabled (all-zero-rate) schedule is normalized away entirely.
    off_state, _ = _spmd_run(faults=FaultConfig(seed=9))
    assert _leaves_equal(base_state, off_state)
    # The fault-AWARE program (push mask + age table + watchdog leaves
    # in the jitted state) with an all-True mask: bitwise-identical
    # params AND store to the plain program.
    fa_state, fa_hist = _spmd_run(max_staleness=10 ** 6)
    assert _leaves_equal(base_state["params"], fa_state["params"])
    assert _leaves_equal(base_state["store"], fa_state["store"])
    assert base_hist["loss"] == fa_hist["loss"]
    # Fault-free push age stays under the sync interval.
    assert max(fa_hist["push_age"]) <= 2, fa_hist["push_age"]


def test_zero_fault_parity_async():
    g = _graph()
    from repro.core import prepare_graph_data
    data = prepare_graph_data(g, 4)
    cfg = _cfg(g)
    base = dict(sync_interval=4, straggler=0, seed=3)
    s_plain, h_plain = digest_a_train(cfg, adam(5e-3), data,
                                      AsyncSettings(**base),
                                      total_rounds=24,
                                      eval_every_rounds=24)
    # Fault bookkeeping on (watchdog armed, zero-rate schedule): the
    # event order, pulls, pushes and losses are untouched.
    s_fa, h_fa = digest_a_train(
        cfg, adam(5e-3), data,
        AsyncSettings(faults=FaultConfig(seed=5), max_staleness=10 ** 6,
                      **base),
        total_rounds=24, eval_every_rounds=24)
    assert _leaves_equal(s_plain["params"], s_fa["params"])
    assert h_plain["loss"] == h_fa["loss"]
    assert h_plain["round_worker"] == h_fa["round_worker"]
    assert all(v == 0 for v in s_fa["fault_counters"].values())


# ---------------------------------------------------------------------------
# Degradation under faults: finite, elevated-but-bounded staleness
# ---------------------------------------------------------------------------

def test_spmd_faulty_run_bounded_staleness():
    _, clean_hist = _spmd_run(max_staleness=10 ** 6, epochs=10)
    faults = FaultConfig(seed=1, crash_rate=0.1, crash_rounds=2,
                         drop_push_rate=0.5, corrupt_rate=0.1)
    state, hist = _spmd_run(max_staleness=6, faults=faults, epochs=10)
    assert np.isfinite(hist["loss"]).all(), hist["loss"]
    for leaf in jax.tree.leaves(state["params"]):
        assert np.isfinite(np.asarray(leaf)).all()
    # Probe sees the fault-induced staleness...
    assert max(hist["push_age"]) > max(clean_hist["push_age"])
    # ...and the watchdog keeps it under the bound.
    assert max(hist["push_age"]) < 6, hist["push_age"]
    # Faults really changed the trajectory (mask was not all-True).
    assert hist["loss"] != clean_hist["loss"]


def test_async_faulty_run_all_classes():
    g = _graph()
    from repro.core import prepare_graph_data
    data = prepare_graph_data(g, 4)
    cfg = _cfg(g)
    faults = FaultConfig(seed=2, crash_rate=0.05, crash_rounds=2,
                         drop_push_rate=0.25, delay_pull_rate=0.2,
                         corrupt_rate=0.1, retry_backoff=1)
    bound = 40
    state, hist = digest_a_train(
        cfg, adam(5e-3), data,
        AsyncSettings(sync_interval=4, straggler=0, seed=3, faults=faults,
                      max_staleness=bound),
        total_rounds=80, eval_every_rounds=20)
    c = state["fault_counters"]
    # Every fault class was exercised at these rates/rounds.
    assert c["crashes"] > 0 and c["dropped_pushes"] > 0, c
    assert c["rejected_pushes"] > 0 and c["delayed_pulls"] > 0, c
    assert c["retried_pushes"] > 0, c
    assert np.isfinite(hist["loss"]).all(), hist["loss"]
    # Measured staleness bounded by the watchdog.
    assert state["pull_age_max"] <= bound, state["pull_age_max"]
    # Tight bound → the watchdog has to force resyncs.
    tight, _ = digest_a_train(
        cfg, adam(5e-3), data,
        AsyncSettings(sync_interval=4, straggler=0, seed=3, faults=faults,
                      max_staleness=10),
        total_rounds=80, eval_every_rounds=80)
    assert tight["fault_counters"]["forced_resyncs"] > 0
    assert tight["pull_age_max"] <= 10, tight["pull_age_max"]


# ---------------------------------------------------------------------------
# Crash-safe checkpoint/resume: kill-and-resume is bitwise
# ---------------------------------------------------------------------------

def test_kill_and_resume_spmd_bitwise(tmp_path):
    g = _graph()
    from repro.core import prepare_graph_data
    data = prepare_graph_data(g, 4)
    cfg = _cfg(g)
    settings = TrainSettings(sync_interval=2, mode="digest",
                             max_staleness=6)
    faults = FaultConfig(seed=1, drop_push_rate=0.4, crash_rate=0.1)
    kw = dict(faults=faults, ckpt_every=2)

    full, _ = digest_train(cfg, adam(5e-3), data, settings, 10,
                           ckpt_dir=str(tmp_path / "a"), **kw)
    # "Kill" after 6 epochs, then resume the SAME invocation to 10.
    digest_train(cfg, adam(5e-3), data, settings, 6,
                 ckpt_dir=str(tmp_path / "b"), **kw)
    resumed, _ = digest_train(cfg, adam(5e-3), data, settings, 10,
                              ckpt_dir=str(tmp_path / "b"), resume=True,
                              **kw)
    assert _leaves_equal(full, resumed)


def test_kill_and_resume_async_bitwise(tmp_path):
    g = _graph()
    from repro.core import prepare_graph_data
    data = prepare_graph_data(g, 4)
    cfg = _cfg(g)
    faults = FaultConfig(seed=2, crash_rate=0.05, drop_push_rate=0.2,
                         delay_pull_rate=0.1, corrupt_rate=0.1)
    settings = AsyncSettings(sync_interval=4, straggler=0, seed=3,
                             faults=faults, max_staleness=40)

    full, fh = digest_a_train(cfg, adam(5e-3), data, settings,
                              total_rounds=60, eval_every_rounds=20,
                              ckpt_dir=str(tmp_path / "a"),
                              ckpt_every_rounds=10)
    digest_a_train(cfg, adam(5e-3), data, settings, total_rounds=25,
                   eval_every_rounds=25, ckpt_dir=str(tmp_path / "b"),
                   ckpt_every_rounds=10)
    resumed, rh = digest_a_train(cfg, adam(5e-3), data, settings,
                                 total_rounds=60, eval_every_rounds=20,
                                 ckpt_dir=str(tmp_path / "b"),
                                 ckpt_every_rounds=10, resume=True)
    assert _leaves_equal(full["params"], resumed["params"])
    assert full["fault_counters"] == resumed["fault_counters"]
    assert fh["round_loss"] == rh["round_loss"]
    assert full["pull_age_max"] == resumed["pull_age_max"]


def test_resume_falls_back_past_corrupt_newest(tmp_path):
    g = _graph()
    from repro.core import prepare_graph_data
    data = prepare_graph_data(g, 4)
    cfg = _cfg(g)
    settings = TrainSettings(sync_interval=2, mode="digest")
    d = str(tmp_path)
    digest_train(cfg, adam(5e-3), data, settings, 6, ckpt_dir=d,
                 ckpt_every=2)
    assert latest_step(d) == 6
    # Truncate the newest npz mid-write: the resume must fall back to
    # step 4 and still complete the run.
    npz = os.path.join(d, "ckpt_00000006.npz")
    with open(npz, "r+b") as f:
        f.truncate(os.path.getsize(npz) // 2)
    assert latest_step(d) == 4
    state, hist = digest_train(cfg, adam(5e-3), data, settings, 8,
                               ckpt_dir=d, ckpt_every=2, resume=True)
    assert np.isfinite(hist["loss"]).all()
    assert int(np.asarray(state["epoch"])) == 8


# ---------------------------------------------------------------------------
# Compiled-HLO census: fault masking adds ZERO communication
# ---------------------------------------------------------------------------

def _fault_hlo_checks():
    import hlo_utils
    from repro.launch.mesh import make_host_mesh

    D = 8
    assert jax.device_count() >= D, jax.device_count()
    mesh = make_host_mesh(data=D)
    g = make_dataset("flickr-sim", scale=0.1, seed=5)

    for storage in ("fp32", "int8"):
        plain = hlo_utils.compile_epoch(g, D, mesh, storage=storage,
                                        pull_mode="collective")
        faulty = hlo_utils.compile_epoch(g, D, mesh, storage=storage,
                                         pull_mode="collective",
                                         fault_state=True, max_staleness=6)
        cp = hlo_utils.collective_counts(plain.as_text())
        cf = hlo_utils.collective_counts(faulty.as_text())
        label = f"fault-aware {storage}"
        # Masking is elementwise on device-local rows: no gathers, no
        # permutes, no scatter fallback appear...
        assert cf["all-gather"] == 0, (label, cf)
        assert cf["collective-permute"] == 0, (label, cf)
        assert cf["reduce-scatter"] == 0, (label, cf)
        # ...and the ragged pull count is exactly the plain program's.
        assert cf["all-to-all"] == cp["all-to-all"], (label, cp, cf)
        want = hlo_utils.expected_all_to_all(storage)
        assert cf["all-to-all"] == want, (label, cf)
        assert cf["all-reduce"] >= cp["all-reduce"], (label, cp, cf)


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (CI REPRO_HOST_DEVICES=8 job)")
def test_fault_hlo_census_inprocess():
    _fault_hlo_checks()


def test_fault_hlo_census_subprocess():
    """Force an 8-device CPU platform in a subprocess so the fault-mask
    census is checked even on single-device hosts."""
    if jax.device_count() >= 8:
        pytest.skip("covered by the in-process variant")
    import hlo_utils
    hlo_utils.run_forced_device_subprocess(__file__, "FAULT_HLO_OK")


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    _fault_hlo_checks()
    print("FAULT_HLO_OK")
