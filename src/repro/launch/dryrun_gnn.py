"""GNN dry-run: DIGEST's own workload (Algorithm 1) lowered on the
production mesh — M = k·256 subgraphs of a large synthetic graph, k per
chip on the "data" axis (``--parts-per-device``), compact HaloExchange
store sharded slot-wise.  ``--pull collective`` lowers the fully-SPMD
shard_map epoch instead of the partitioner-dependent gather/scatter
fallback: the ragged all_to_all pull on the single-pod 16x16 mesh, the
two-stage intra-pod all_to_all + inter-pod ppermute exchange over the
("pod", "data") axes on the multi-pod 2x16x16 one (``--multi-pod`` /
``--pods``), shard-local pushes on both — the lowered 512-chip program
must carry ZERO all-gathers (the CI dry-run smoke asserts it from this
script's census output).

  PYTHONPATH=src python -m repro.launch.dryrun_gnn [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun_gnn --multi-pod \\
      --pull collective --parts-per-device 2

Run as its own process (512 placeholder devices).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import (HaloPrecision, PredictorConfig, TrainSettings,
                        make_epoch_fn)
from repro.launch.dryrun import collective_bytes, cost_properties
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS,
                               make_production_mesh)
from repro.models.gnn import GNNConfig, gnn_specs
from repro.nn import abstract_params
from repro.optim import adam


def abstract_gnn_case(num_nodes: int, num_parts: int, feat: int,
                      hidden: int, classes: int, deg_in: int, deg_out: int,
                      halo_frac: float, boundary_frac: float = 0.5,
                      chunk_rows: int = 512):
    """ShapeDtypeStruct stand-ins for a partitioned graph (no host build —
    at 256 parts × 1M nodes the partitioner would dominate; shapes are what
    the compiler needs).  ``boundary_frac`` models |boundary| / N — the
    compact HaloExchange store holds only those rows."""
    S = num_nodes // num_parts
    H = int(S * halo_frac)
    i32 = jnp.int32
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    # Node tables carry the sentinel row; pad row count to shard evenly.
    rows = ((num_nodes + 1 + num_parts - 1) // num_parts) * num_parts
    # Owner-sharded compact store: shard_rows rows per owner (incl. the
    # per-owner sentinel), R = num_parts · shard_rows total.
    shard_rows = ((int(num_nodes * boundary_frac) // num_parts + 1 + 7)
                  // 8) * 8
    slots = num_parts * shard_rows
    # Ragged pull-plan width: halo spread uniformly over owners.
    K = max((H + num_parts - 1) // num_parts, 1)
    # Chunk worklist of the out-ELL vs the (H+1)-row slab: 128-row output
    # blocks, worst-case static width = every chunk occupied.
    n_blocks = max(-(-S // 128), 1)
    n_chunks = max(-(-(H + 1) // chunk_rows), 1)
    data = {
        "x_global": sds((rows, feat), f32),
        "struct": {"in_nbr": sds((num_parts, S, deg_in), i32),
                   "in_wts": sds((num_parts, S, deg_in), f32),
                   "out_nbr": sds((num_parts, S, deg_out), i32),
                   "out_wts": sds((num_parts, S, deg_out), f32),
                   "wl_ids": sds((num_parts, n_blocks, n_chunks), i32),
                   "wl_cnt": sds((num_parts, n_blocks), i32)},
        "local_ids": sds((num_parts, S), i32),
        "local_valid": sds((num_parts, S), jnp.bool_),
        "halo_ids": sds((num_parts, H), i32),
        "halo_valid": sds((num_parts, H), jnp.bool_),
        "halo_ids_x": sds((num_parts, H + 1), i32),
        "local_slots": sds((num_parts, S), i32),
        "local_boundary": sds((num_parts, S), jnp.bool_),
        "halo_slots": sds((num_parts, H), i32),
        "store_ids": sds((slots,), i32),
        "sentinel_slots": sds((num_parts,), i32),
        "pull_send": sds((num_parts, num_parts, K), i32),
        "pull_recv": sds((num_parts, num_parts, K), i32),
        "labels": sds((num_parts, S), i32),
        "train_mask": sds((num_parts, S), jnp.bool_),
        "val_mask": sds((num_parts, S), jnp.bool_),
        "test_mask": sds((num_parts, S), jnp.bool_),
        # full-graph view (eval only; not used by the epoch fn)
        "full_struct": {"in_nbr": sds((1, 8, 1), i32),
                        "in_wts": sds((1, 8, 1), f32),
                        "out_nbr": sds((1, 8, 1), i32),
                        "out_wts": sds((1, 8, 1), f32)},
        "full_ids": sds((1, 8), i32),
        "full_valid": sds((1, 8), jnp.bool_),
        "full_labels": sds((1, 8), i32),
        "full_train_mask": sds((1, 8), jnp.bool_),
        "full_val_mask": sds((1, 8), jnp.bool_),
        "full_test_mask": sds((1, 8), jnp.bool_),
    }
    return data, S, H, rows, slots


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--nodes", type=int, default=1_048_576)
    ap.add_argument("--feat", type=int, default=128)
    ap.add_argument("--hidden", type=int, default=256)
    ap.add_argument("--deg", type=int, default=16)
    ap.add_argument("--precision", default="fp32",
                    choices=("fp32", "bf16", "int8"))
    ap.add_argument("--pull", default="gather",
                    choices=("gather", "collective"),
                    help="collective = fully-SPMD shard_map epoch: "
                         "ragged all_to_all pull + shard-local push on "
                         "a single pod; with --multi-pod/--pods the "
                         "two-stage intra-pod all_to_all + inter-pod "
                         "ppermute exchange over ('pod', 'data')")
    ap.add_argument("--pods", type=int, default=None,
                    help="pod-axis size of the production mesh "
                         "(default: 2 with --multi-pod, else 1; the "
                         "forced host platform has 512 devices, so "
                         "pods x 256 must fit)")
    ap.add_argument("--parts-per-device", type=int, default=1,
                    help="k subgraphs/owner shards per 'data' device "
                         "(M = k x data axis; the M > pod-size regime)")
    ap.add_argument("--backend", default="jnp",
                    choices=("jnp", "auto", "pallas"),
                    help="aggregation kernel backend the epoch lowers "
                         "with; the forced-host-device dry run compiles "
                         "for CPU, so only 'jnp' lowers here — 'auto'/"
                         "'pallas' are for running this script on a real "
                         "TPU pod, where the knobs below select kernels")
    ap.add_argument("--stream-chunk-rows", type=int, default=512,
                    help="slab rows per streamed halo_spmm chunk (also "
                         "the abstract worklist geometry)")
    ap.add_argument("--resident-max-bytes", type=int, default=None,
                    help="VMEM budget above which halo_spmm streams "
                         "(default: kernel RESIDENT_STRIPE_MAX_BYTES; "
                         "Pallas backends only)")
    ap.add_argument("--skip-occupancy-max", type=float, default=None,
                    help="occupancy threshold for the chunk-skipping "
                         "stream (default: kernel SKIP_OCCUPANCY_MAX; "
                         "Pallas backends only)")
    ap.add_argument("--halo-occupancy", type=float, default=None,
                    help="assumed (row-block x chunk) occupancy of the "
                         "abstract worklist (no host graph to measure it "
                         "from); with a Pallas backend, a value at or "
                         "below the threshold selects the skip-stream "
                         "kernel in the lowered epoch")
    ap.add_argument("--order", default=None, choices=("none", "rcm"),
                    help="modelled local-row layout (no host partitioner "
                         "in the abstract dry run): sets the default "
                         "--halo-occupancy to the measured regime of "
                         "that layout (none=0.85, rcm=0.40 — rcm lands "
                         "below SKIP_OCCUPANCY_MAX, so a Pallas backend "
                         "lowers the chunk-skipping stream) and is "
                         "recorded in the JSON line")
    ap.add_argument("--predictor", default="none",
                    choices=("none", "delta", "ema"),
                    help="SAT staleness predictor kind: history pstore "
                         "rides the store sharding, prediction fuses "
                         "into the pull/dequant epilogue (adds exactly "
                         "one all_to_all per history tensor in the "
                         "collective census; 'none' lowers the identical "
                         "program as before)")
    ap.add_argument("--predictor-gamma", type=float, default=1.0)
    ap.add_argument("--predictor-beta", type=float, default=0.5)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.halo_occupancy is None and args.order is not None:
        # Measured regimes of the two layouts on the community power-law
        # benchmark graphs (see benchmarks/kernel_bench.py): identity
        # order sits well above the skip threshold, RCM below it.
        args.halo_occupancy = {"none": 0.85, "rcm": 0.40}[args.order]

    mesh = make_production_mesh(multi_pod=args.multi_pod, pods=args.pods)
    data_axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    num_parts = args.parts_per_device
    for a in data_axes:
        num_parts *= mesh.shape[a]

    cfg = GNNConfig(model="gcn", num_layers=3, in_dim=args.feat,
                    hidden_dim=args.hidden, num_classes=64,
                    backend=args.backend,
                    stream_chunk_rows=args.stream_chunk_rows,
                    resident_max_bytes=args.resident_max_bytes,
                    skip_occupancy_max=args.skip_occupancy_max,
                    halo_occupancy=args.halo_occupancy)
    opt = adam(5e-3)
    precision = HaloPrecision(args.precision)
    pcfg = PredictorConfig(kind=args.predictor, gamma=args.predictor_gamma,
                           beta=args.predictor_beta)
    settings = TrainSettings(sync_interval=10, mode="digest",
                             pull_mode=args.pull, precision=precision,
                             predictor=pcfg)
    # (No M-vs-mesh geometry check needed here: num_parts is derived
    # from the mesh exchange axes above, so it divides by construction —
    # unlike train_gnn/examples, where --parts is user-supplied.)
    data, S, H, rows, slots = abstract_gnn_case(
        args.nodes, num_parts, args.feat, args.hidden, 64, args.deg,
        args.deg // 2, halo_frac=1.0, chunk_rows=args.stream_chunk_rows)

    rep = NamedSharding(mesh, P())
    mdim = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
    m_shard = NamedSharding(mesh, P(mdim))
    node_shard = NamedSharding(mesh, P(mdim))

    specs = gnn_specs(cfg)
    params_abs = abstract_params(specs)
    # Owner-sharded HaloExchange store (L-1, M·shard_rows, hidden) in
    # storage precision (int8 adds the per-row scale column): each device
    # keeps only the shard it pushes.  The pulled cache is the device-
    # local per-subgraph halo slab (M, L-1, H+1, hidden).
    l1 = cfg.num_layers - 1
    H = data["halo_ids"].shape[1]
    store_abs = {"data": jax.ShapeDtypeStruct(
        (l1, slots, args.hidden), precision.dtype)}
    store_sh = {"data": NamedSharding(mesh, P(None, mdim, None))}
    cache_abs = {"data": jax.ShapeDtypeStruct(
        (num_parts, l1, H + 1, args.hidden), precision.dtype)}
    cache_sh = {"data": NamedSharding(mesh, P(mdim, None, None, None))}
    if precision.has_scale:
        store_abs["scale"] = jax.ShapeDtypeStruct(
            (l1, slots, 1), jnp.float32)
        store_sh["scale"] = NamedSharding(mesh, P(None, mdim, None))
        cache_abs["scale"] = jax.ShapeDtypeStruct(
            (num_parts, l1, H + 1, 1), jnp.float32)
        cache_sh["scale"] = NamedSharding(mesh, P(mdim, None, None, None))
    state_abs = {
        "params": params_abs,
        "opt_state": jax.eval_shape(opt.init, params_abs),
        "store": store_abs,
        "cache": cache_abs,
        "epoch": jax.ShapeDtypeStruct((), jnp.int32),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    state_sh = {
        "params": jax.tree.map(lambda _: rep, params_abs),
        "opt_state": jax.tree.map(lambda _: rep,
                                  state_abs["opt_state"]),
        "store": store_sh, "cache": cache_sh,
        "epoch": rep, "step": rep,
    }
    if pcfg.enabled:
        # SAT history rides the exact store / slab geometry: the pstore
        # is a second owner-sharded table, the raw-rep history is a
        # device-local per-subgraph slab, the pulled pcache mirrors the
        # halo cache (gcn dry-run model — not gat-projected).
        state_abs["pstore"] = dict(store_abs)
        state_sh["pstore"] = dict(store_sh)
        slab = jax.ShapeDtypeStruct((num_parts, l1, S, args.hidden),
                                    jnp.float32)
        slab_sh = NamedSharding(mesh, P(mdim, None, None, None))
        state_abs["predictor"] = {
            "prev": slab, "ema": slab,
            "coef": jax.ShapeDtypeStruct((num_parts, l1), jnp.float32),
            "count": jax.ShapeDtypeStruct((num_parts,), jnp.int32)}
        state_sh["predictor"] = {
            "prev": slab_sh, "ema": slab_sh,
            "coef": NamedSharding(mesh, P(mdim, None)),
            "count": m_shard}
        state_abs["pcache"] = dict(cache_abs)
        state_sh["pcache"] = dict(cache_sh)
    data_sh = {}
    for k, v in data.items():
        if k == "x_global":
            # Feature-table rows shard over "data" ONLY — one replica
            # per pod, sharded within it (same per-device residency as
            # the single-pod layout).  Sharding rows over the combined
            # ("pod", "data") axes makes XLA partition the layer-0
            # x_global[ids] gathers with inter-pod index all-gathers;
            # per-pod replication keeps those gathers intra-pod and the
            # compiled epoch all-gather-free (the CI census gate).
            data_sh[k] = NamedSharding(mesh, P("data", None))
        elif k == "store_ids":
            data_sh[k] = rep
        elif k in ("pull_send", "pull_recv"):
            data_sh[k] = NamedSharding(mesh, P(mdim, None, None))
        elif k == "struct":
            data_sh[k] = {kk: m_shard for kk in v}
        elif k.startswith("full_"):
            data_sh[k] = jax.tree.map(lambda _: rep, v)
        else:
            data_sh[k] = m_shard

    epoch_fn = make_epoch_fn(
        cfg, opt, settings,
        mesh=mesh if args.pull == "collective" else None)
    t0 = time.perf_counter()
    lowered = jax.jit(epoch_fn, in_shardings=(state_sh, data_sh)).lower(
        state_abs, data)
    compiled = lowered.compile()
    cost = cost_properties(compiled)
    mem = compiled.memory_analysis()
    # Census on the partitioned HLO: per-op byte totals AND op counts
    # (the CI dry-run smoke asserts all-gather == 0 from this JSON);
    # with a pod axis, replica-group analysis splits intra- vs
    # inter-pod bytes (device ids [0, data·model) are pod 0).
    pods = int(mesh.shape.get("pod", 1))
    # Devices per pod from the MESH shape (data·model), not the forced
    # host device count — logical ids [0, data·model) are pod 0
    # regardless of how many placeholder devices the platform exposes.
    pod_boundary = (int(mesh.shape["data"] * mesh.shape["model"])
                    if pods > 1 else 0)
    coll = collective_bytes(compiled.as_text(), pod_boundary)
    out = {
        "case": "digest_gnn_epoch",
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "nodes": args.nodes, "parts": num_parts, "S": S, "H": H,
        "hidden": args.hidden, "precision": args.precision,
        "pull_mode": args.pull, "parts_per_device": args.parts_per_device,
        "store_slots": slots, "shard_rows": slots // num_parts,
        "stream_chunk_rows": args.stream_chunk_rows,
        "halo_occupancy": args.halo_occupancy,
        "order": args.order,
        "predictor": args.predictor,
        "hlo_flops": float(cost.get("flops", 0.0)),
        "hlo_bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll["total"],
        "collective_per_op": coll["per_op"],
        "collective_counts": coll["counts"],
        "collective_inter_pod_bytes": coll["inter_pod"],
        "compute_term_s": float(cost.get("flops", 0.0)) / PEAK_FLOPS,
        "memory_term_s": float(cost.get("bytes accessed", 0.0)) / HBM_BW,
        "collective_term_s": coll["total"] / ICI_BW,
        "t_compile_s": round(time.perf_counter() - t0, 2),
    }
    if mem is not None:
        out["mem_temp_gb"] = round(mem.temp_size_in_bytes / 1e9, 3)
        out["mem_arg_gb"] = round(mem.argument_size_in_bytes / 1e9, 3)
    line = json.dumps(out)
    print(line)
    if args.out:
        with open(args.out, "a") as f:
            f.write(line + "\n")


if __name__ == "__main__":
    main()
