import os

# Tests see exactly ONE device by default (the dry-run sets 512 in its
# own process); keep any inherited XLA_FLAGS out.  The CI collective job
# opts into N forced host devices via REPRO_HOST_DEVICES so the sharded
# pull/push paths run in-process (see test_sharded_pull.py).
os.environ.pop("XLA_FLAGS", None)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
_n_dev = os.environ.get("REPRO_HOST_DEVICES")
if _n_dev:
    os.environ["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={_n_dev}"

import functools
import inspect
import random
import sys
import types

import numpy as np
import pytest

# ---------------------------------------------------------------------------
# Minimal deterministic `hypothesis` stand-in.
#
# The container has no hypothesis wheel and nothing may be pip-installed;
# rather than skip the property tests, provide the tiny subset they use
# (given / settings / strategies.integers / strategies.sampled_from) drawing
# `max_examples` pseudo-random examples from a fixed seed.  If the real
# hypothesis is installed (e.g. in CI) it is used untouched.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: r.choice(elements))

    def _given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 10)
                rng = random.Random(0xD16E57)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)
            # Hide the drawn params from pytest's fixture resolution.
            sig = inspect.signature(fn)
            params = [p for name, p in sig.parameters.items()
                      if name not in strategies]
            wrapper.__signature__ = sig.replace(parameters=params)
            del wrapper.__wrapped__
            return wrapper
        return deco

    def _settings(max_examples=10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# CI leg partition markers.
#
# tests/ci_legs.py is the single source of truth for which 8-device CI
# leg owns each test file; the hooks below register the markers, stamp
# every collected test with its leg's derived ``leg_<name>`` marker
# (so the workflow selects with ``pytest -m leg_<name>`` instead of an
# --ignore list), and skip ``forced_devices(n)`` tests when the forced
# host platform is smaller than n.
# ---------------------------------------------------------------------------
from ci_legs import ALL_LEGS, leg_for, marker_name  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "leg(name): CI leg that owns this test file (see tests/ci_legs.py; "
        "checked against the registry by scripts/check_test_partition.py)")
    config.addinivalue_line(
        "markers",
        "forced_devices(n): requires >= n forced host devices "
        "(REPRO_HOST_DEVICES); skipped on smaller platforms")
    for leg in ALL_LEGS:
        config.addinivalue_line(
            "markers",
            f"{marker_name(leg)}: derived — tests owned by the "
            f"'{leg}' CI leg (stamped from tests/ci_legs.py)")


def pytest_collection_modifyitems(config, items):
    num_devices = int(os.environ.get("REPRO_HOST_DEVICES", "1"))
    for item in items:
        stem = os.path.splitext(os.path.basename(str(item.fspath)))[0]
        declared = item.get_closest_marker("leg")
        leg = declared.args[0] if declared else leg_for(stem)
        item.add_marker(getattr(pytest.mark, marker_name(leg)))
        forced = item.get_closest_marker("forced_devices")
        if forced and num_devices < int(forced.args[0]):
            item.add_marker(pytest.mark.skip(
                reason=f"needs {forced.args[0]} forced host devices "
                       f"(REPRO_HOST_DEVICES={num_devices})"))
