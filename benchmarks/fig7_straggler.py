"""Fig. 7: heterogeneous environment — one straggler worker (8-10 s delay).

DIGEST-A (async) vs synchronous DIGEST on *simulated* wall-clock.

Also runs the fault sweep: DIGEST-A on the community power-law graph
("papers-sim") under increasing crash + dropped-push rates, recording
the final loss and the *measured* max staleness (the per-slot age
table) against the fault-free baseline — written to BENCH_faults.json
at the repo root (like serve_bench's BENCH_serving.json).
"""
import json
import os

from benchmarks.common import bench_scale, emit
from benchmarks.gnn_common import setup
from repro.core import (AsyncSettings, FaultConfig, digest_a_train,
                        sync_time_per_round)
from repro.optim import adam

# (crash_rate, drop_push_rate) grid of the fault sweep; rates are
# per-(round, worker) — documented operating points, not extremes.
FAULT_GRID = [(0.0, 0.0), (0.01, 0.05), (0.02, 0.15), (0.05, 0.30)]


def run() -> list[dict]:
    scale = bench_scale()
    _, data, cfg = setup("flickr-sim", scale=0.3 * scale)
    M = int(data["halo_ids"].shape[0])
    settings = AsyncSettings(sync_interval=10, straggler=0, seed=7)
    rounds = max(int(M * 60 * scale), M * 20)
    _, hist = digest_a_train(cfg, adam(5e-3), data, settings,
                             total_rounds=rounds,
                             eval_every_rounds=max(rounds // 6, 1))
    t_sync = sync_time_per_round(settings, M)
    rows = [{
        "name": "fig7/digest_a",
        "us_per_call": round(hist["sim_time"][-1] / hist["round"][-1] * 1e6,
                             1),
        "f1": round(hist["val_f1"][-1], 4),
        "sim_time_s": round(hist["sim_time"][-1], 2),
        "max_delay": max(hist["delay"]),
    }, {
        "name": "fig7/digest_sync_barrier",
        "us_per_call": round(t_sync * 1e6, 1),
        "note": "per-round barrier time under the same straggler model",
    }]
    rows += fault_sweep(scale)
    return rows


def fault_sweep(scale: float) -> list[dict]:
    _, data, cfg = setup("papers-sim", scale=0.02 * scale, hidden=32)
    M = int(data["halo_ids"].shape[0])
    rounds = max(int(M * 40 * scale), M * 15)
    max_staleness = 30 * M          # server steps; the watchdog bound
    rows, sweep = [], []
    for crash, drop in FAULT_GRID:
        settings = AsyncSettings(
            sync_interval=5, seed=7, max_staleness=max_staleness,
            faults=FaultConfig(seed=11, crash_rate=crash, crash_rounds=3,
                               drop_push_rate=drop))
        state, hist = digest_a_train(cfg, adam(5e-3), data, settings,
                                     total_rounds=rounds,
                                     eval_every_rounds=max(rounds // 4, 1))
        point = {
            "crash_rate": crash,
            "drop_rate": drop,
            "final_loss": round(hist["loss"][-1], 4),
            "val_f1": round(hist["val_f1"][-1], 4),
            "max_staleness_measured": int(state["pull_age_max"]),
            "max_staleness_bound": max_staleness,
            "fault_counters": state["fault_counters"],
        }
        sweep.append(point)
        rows.append({
            "name": f"fig7/faults_c{crash}_d{drop}",
            "loss": point["final_loss"],
            "f1": point["val_f1"],
            "staleness": point["max_staleness_measured"],
            "crashes": point["fault_counters"]["crashes"],
            "dropped": point["fault_counters"]["dropped_pushes"],
        })
    result = {
        "dataset": "papers-sim",
        "num_parts": M,
        "rounds": rounds,
        "sync_interval": 5,
        "staleness_unit": "server steps since owning shard's last "
                          "accepted push",
        "sweep": sweep,
    }
    out_path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_faults.json")
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {out_path}", flush=True)
    return rows


if __name__ == "__main__":
    emit(run())
