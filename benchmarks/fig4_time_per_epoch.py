"""Fig. 4: training time per epoch per framework (products stand-in)."""
from benchmarks.common import bench_scale, emit
from benchmarks.gnn_common import MODE_LABEL, setup, train_mode


def run() -> list[dict]:
    scale = bench_scale()
    _, data, cfg = setup("products-sim", scale=0.2 * scale)
    epochs = max(int(20 * scale), 6)
    rows = []
    for mode in ("propagation", "llcg", "partition", "digest"):
        _, _, per_epoch = train_mode(cfg, data, mode, epochs)
        rows.append({"name": f"fig4/{MODE_LABEL[mode]}",
                     "us_per_call": round(per_epoch * 1e6, 1)})
    return rows


if __name__ == "__main__":
    emit(run())
