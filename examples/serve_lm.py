#!/usr/bin/env python
"""Serving driver: batched prefill → KV-cache decode, plus the DIGEST
stale-KV long-context mode.

  PYTHONPATH=src python examples/serve_lm.py --arch phi3-mini-3.8b \
      --batch 4 --prompt-len 64 --gen 32 --long
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_arch
from repro.launch.serving_driver import run_serve_loop
from repro.models.transformer import (arch_specs, decode_step, forward,
                                      init_cache)
from repro.nn import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--long", action="store_true",
                    help="use stale-KV block attention (DIGEST mode)")
    args = ap.parse_args()

    cfg = get_smoke_arch(args.arch)
    if args.long:
        cfg = dataclasses.replace(cfg, long_window=32, long_ratio=8)
    params = init_params(jax.random.PRNGKey(0), arch_specs(cfg))
    max_seq = args.prompt_len + args.gen
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)

    # Prefill by teacher-forced decode (fills the cache), then generate.
    cache = init_cache(cfg, args.batch, max_seq, long=args.long)
    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t,
                                               long=args.long))
    def prefill_step(carry, tok):
        cache, _ = carry
        logits, cache = step(params, cache, tok)
        return (cache, logits), None

    carry, _, prefill = run_serve_loop(
        prefill_step, [prompts[:, t:t + 1] for t in range(args.prompt_len)],
        carry=(cache, None), items_per_call=args.batch)

    def gen_step(carry, _):
        cache, logits = carry
        nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        logits, cache = step(params, cache, nxt)
        return (cache, logits), nxt

    carry, generated, gen = run_serve_loop(gen_step, range(args.gen),
                                           carry=carry,
                                           items_per_call=args.batch)
    out = jnp.concatenate(generated, axis=1)

    mode = "stale-KV (DIGEST)" if args.long else "full KV cache"
    print(f"arch={cfg.name} (reduced)  mode={mode}")
    print(f"prefill {args.prompt_len} toks x{args.batch}: "
          f"{prefill.total_s:.2f}s; decode {args.gen} toks: "
          f"{gen.total_s/args.gen*1e3:.1f} ms/tok "
          f"(p50 {gen.p50_ms:.1f} / p99 {gen.p99_ms:.1f} ms)")
    print(f"sample continuation ids: {out[0, :16].tolist()}")


if __name__ == "__main__":
    main()
