"""Checkpoint save/restore."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


def test_roundtrip(tmp_path):
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "layers": [jnp.ones((2,)), jnp.zeros((3,))]},
            "step": jnp.asarray(7)}
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 7
    np.testing.assert_allclose(restored["params"]["w"],
                               tree["params"]["w"])
    np.testing.assert_allclose(restored["params"]["layers"][0],
                               tree["params"]["layers"][0])


def test_latest_of_many(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 5, 3):
        save_checkpoint(str(tmp_path), s, tree)
    assert latest_step(str(tmp_path)) == 5


def test_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"x": jnp.zeros((3,))})


def test_missing_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "nope"), {"x": jnp.zeros(1)})


def test_bf16_store_roundtrip(tmp_path):
    """bfloat16 leaves (ml_dtypes extension type) survive npz via the f32
    widening path and restore back to bf16 losslessly."""
    from repro.core import halo_exchange as hx

    store = hx.init_store(1, 4, 8, hx.HaloPrecision("bf16"))
    store = hx.push(store, jnp.asarray([[0, 2]]), jnp.ones((1, 2), bool),
                    jnp.asarray(np.random.default_rng(0).normal(
                        size=(1, 1, 2, 8)).astype(np.float32)))
    save_checkpoint(str(tmp_path), 1, {"store": store})
    restored, _ = restore_checkpoint(str(tmp_path), {"store": store})
    assert restored["store"]["data"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        restored["store"]["data"].astype(np.float32),
        np.asarray(store["data"]).astype(np.float32))


def test_compact_halo_store_roundtrip(tmp_path):
    """The quantized HaloExchange store serializes losslessly (int8 data +
    fp32 scales keep their dtypes), with the precision in the manifest."""
    from repro.checkpoint import read_manifest
    from repro.core import halo_exchange as hx

    store = hx.init_store(2, 9, 8, hx.HaloPrecision("int8"))
    slots = jnp.asarray([[0, 4, 8]])
    valid = jnp.asarray([[True, True, False]])
    reps = jnp.asarray(np.random.default_rng(0).normal(
        size=(1, 2, 3, 8)).astype(np.float32))
    store = hx.push(store, slots, valid, reps)
    state = {"store": store, "step": jnp.asarray(5)}

    save_checkpoint(str(tmp_path), 5, state, meta={"halo_storage": "int8"})
    restored, step = restore_checkpoint(str(tmp_path), state)
    assert step == 5
    assert restored["store"]["data"].dtype == np.int8
    np.testing.assert_array_equal(restored["store"]["data"],
                                  np.asarray(store["data"]))
    np.testing.assert_array_equal(restored["store"]["scale"],
                                  np.asarray(store["scale"]))
    assert read_manifest(str(tmp_path), 5)["meta"]["halo_storage"] == "int8"
