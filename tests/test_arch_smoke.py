"""Per-assigned-architecture smoke tests (deliverable f).

Each of the 10 architectures instantiates a REDUCED same-family variant
(≤2-ish layers, d_model ≤ 512, ≤4 experts) and runs one forward + one
train step on CPU, asserting output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch, get_smoke_arch
from repro.models.transformer import (arch_specs, forward, init_cache,
                                      decode_step, precompute_vision_cache)
from repro.nn import init_params
from repro.train import TrainSettings, init_train_state, make_train_step


def _batch(cfg, b=2, s=16, seed=0):
    kq, kv = jax.random.split(jax.random.PRNGKey(seed))
    batch = {
        "tokens": jax.random.randint(kq, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(kv, (b, s), 0, cfg.vocab_size),
        "mask": jnp.ones((b, s), jnp.float32),
    }
    if cfg.vision_dim:
        batch["vision"] = jax.random.normal(
            kq, (b, cfg.num_patches, cfg.vision_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("name", ARCH_IDS)
def test_smoke_forward(name):
    cfg = get_smoke_arch(name)
    assert cfg.d_model <= 512 and cfg.num_experts <= 4
    params = init_params(jax.random.PRNGKey(0), arch_specs(cfg))
    batch = _batch(cfg)
    logits = forward(cfg, params, batch["tokens"], batch.get("vision"))
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("name", ARCH_IDS)
def test_smoke_train_step(name):
    cfg = get_smoke_arch(name)
    settings = TrainSettings(sync_mode="every_step", total_steps=100,
                             warmup_steps=5)
    state = init_train_state(cfg, settings)
    step = jax.jit(make_train_step(cfg, settings))
    batch = _batch(cfg)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state["step"]) == 1
    # parameters actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()),
        state["params"], new_state["params"])
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("name", ARCH_IDS)
def test_smoke_decode_step(name):
    cfg = get_smoke_arch(name)
    params = init_params(jax.random.PRNGKey(0), arch_specs(cfg))
    batch = _batch(cfg)
    cache = init_cache(cfg, 2, 32)
    if cfg.vision_dim:
        cache = precompute_vision_cache(cfg, params, cache,
                                        batch["vision"])
    logits, cache = decode_step(cfg, params, cache, batch["tokens"][:, :1])
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert int(cache["pos"][0]) == 1


def test_production_configs_match_assignment():
    """Exact spec table from the assignment."""
    spec = {
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
    }
    for arch_id in ARCH_IDS:
        cfg = get_arch(arch_id)
        want = spec[cfg.name]
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads,
               cfg.num_kv_heads, cfg.d_ff, cfg.vocab_size)
        assert got == want, (cfg.name, got, want)
        assert cfg.source, cfg.name


def test_moe_configs():
    scout = get_arch("llama4-scout-17b-a16e")
    assert scout.num_experts == 16 and scout.experts_per_token == 1
    kimi = get_arch("kimi-k2-1t-a32b")
    assert kimi.num_experts == 384 and kimi.experts_per_token == 8
    assert kimi.optimizer == "adafactor"
