"""Recurrent blocks: parallel/scan forms vs single-step decode forms."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.recurrent import (mlstm_parallel, mlstm_step, rg_lru,
                                    rg_lru_step, slstm_scan)


def test_rg_lru_scan_matches_stepwise():
    rng = np.random.default_rng(0)
    b, s, d = 2, 24, 8
    x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    gx = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    ga = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    lam = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    y, h_last = rg_lru(x, gx, ga, lam)
    h = jnp.zeros((b, d))
    outs = []
    for t in range(s):
        o, h = rg_lru_step(x[:, t], gx[:, t], ga[:, t], lam, h)
        outs.append(o)
    y_step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(y, y_step, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(h_last, h, atol=1e-5, rtol=1e-5)


def test_rg_lru_state_continuation():
    rng = np.random.default_rng(1)
    b, s, d = 1, 16, 4
    args = [jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
            for _ in range(3)]
    lam = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    y_full, _ = rg_lru(*args, lam)
    y1, h1 = rg_lru(*[a[:, :8] for a in args], lam)
    y2, _ = rg_lru(*[a[:, 8:] for a in args], lam, h0=h1)
    np.testing.assert_allclose(
        y_full, jnp.concatenate([y1, y2], axis=1), atol=1e-5, rtol=1e-5)


def test_mlstm_parallel_matches_stepwise():
    rng = np.random.default_rng(2)
    b, h, s, d = 1, 2, 12, 4
    q, k, v = [jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
               for _ in range(3)]
    i_pre = jnp.asarray(rng.normal(size=(b, h, s)), jnp.float32)
    f_pre = jnp.asarray(rng.normal(size=(b, h, s)) + 2.0, jnp.float32)
    y_par = mlstm_parallel(q, k, v, i_pre, f_pre)
    state = {"C": jnp.zeros((b, h, d, d)), "n": jnp.zeros((b, h, d)),
             "m": jnp.zeros((b, h))}
    outs = []
    for t in range(s):
        o, state = mlstm_step(q[:, :, t], k[:, :, t], v[:, :, t],
                              i_pre[:, :, t], f_pre[:, :, t], state)
        outs.append(o)
    y_step = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(y_par, y_step, atol=1e-3, rtol=1e-2)


def test_slstm_state_continuation():
    rng = np.random.default_rng(3)
    b, s, h, d = 2, 10, 2, 4
    wx = jnp.asarray(rng.normal(size=(b, s, h, 4, d)), jnp.float32)
    r = {g: jnp.asarray(rng.normal(size=(h, d, d)) * 0.1, jnp.float32)
         for g in "zifo"}
    y_full, _ = slstm_scan(wx, r)
    y1, st1 = slstm_scan(wx[:, :5], r)
    y2, _ = slstm_scan(wx[:, 5:], r, state=st1)
    np.testing.assert_allclose(
        y_full, jnp.concatenate([y1, y2], axis=1), atol=1e-5, rtol=1e-5)


def test_rg_lru_stability():
    """Decay a ∈ (0,1) ⇒ bounded state over long sequences."""
    rng = np.random.default_rng(4)
    b, s, d = 1, 2048, 4
    x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    y, h = rg_lru(x, x, x, jnp.ones((d,)))
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(jnp.abs(h).max()) < 100.0
