"""Single source of truth for the tier-1 CI leg partition.

The CI matrix legs (see .github/workflows/ci.yml) PARTITION the test
files: the ``single-device`` leg runs the whole suite on one device
(multi-device coverage via the subprocess fallbacks baked into the
files), while the 8-forced-device legs split the files among
themselves so no file runs twice across them.  Membership used to live
as an ``--ignore`` list in the workflow — silently wrong the moment a
new leg-owned file landed.  It now lives HERE, is stamped onto every
collected test as a derived ``leg_<name>`` marker by conftest.py, and
is selected in the workflow with ``pytest -m leg_<name>``.

Invariants (enforced by scripts/check_test_partition.py, which fails
the build):

  * the explicit sets below are pairwise disjoint;
  * every named file exists under tests/;
  * every ``tests/test_*.py`` file maps to exactly one leg — files not
    claimed below default to ``collective-8dev``;
  * a file's ``pytestmark = pytest.mark.leg("...")`` declaration (when
    present) agrees with this registry.

This module is imported by conftest.py during collection — keep it
dependency-free (no jax, no pytest).
"""

# Files not claimed by any leg below run on this leg.
DEFAULT_LEG = "collective-8dev"

# leg name -> test-file stems it owns (and the matrix runs with 8
# forced host devices).  Keep in sync with the ci.yml matrix.
LEGS = {
    "m16-ppd2-hlo": frozenset({
        "test_hlo_collectives",
        "test_collective_ppd",
        "test_halo_properties",
        "test_skip_stream",
        "test_order_invariance",
    }),
    "multipod-2x4": frozenset({"test_multipod"}),
    "serving-smoke": frozenset({"test_serving"}),
    "sampling-smoke": frozenset({"test_sampling", "test_async_engine"}),
    "fault-smoke": frozenset({"test_faults"}),
    "sat-smoke": frozenset({"test_predictor"}),
}

ALL_LEGS = (DEFAULT_LEG,) + tuple(sorted(LEGS))


def marker_name(leg: str) -> str:
    """``-m``-selectable marker derived from a leg name."""
    return "leg_" + leg.replace("-", "_")


def leg_for(stem: str) -> str:
    """The unique leg owning a test-file stem (default when unclaimed).

    Raises if the registry claims the stem twice — the partition
    violation also fails scripts/check_test_partition.py, but raising
    here surfaces it in every local pytest run too.
    """
    owners = [leg for leg, files in LEGS.items() if stem in files]
    if len(owners) > 1:
        raise ValueError(
            f"{stem} is claimed by multiple CI legs: {sorted(owners)}")
    return owners[0] if owners else DEFAULT_LEG
