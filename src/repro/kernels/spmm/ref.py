"""Pure-jnp oracle for the ELL SpMM kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spmm_ref(nbr: jax.Array, wts: jax.Array, table: jax.Array) -> jax.Array:
    """out[i] = sum_k wts[i,k] * table[nbr[i,k]] — vectorized gather form."""
    gathered = jnp.take(table, nbr, axis=0)        # (rows, deg, feat)
    w = wts.astype(jnp.float32)[..., None]
    return jnp.sum(w * gathered.astype(jnp.float32), axis=1)


def halo_spmm_ref(nbr: jax.Array, wts: jax.Array, data: jax.Array,
                  scale: jax.Array = None) -> jax.Array:
    """Fused pull+aggregate oracle: SpMM against a (possibly quantized)
    compact slab with per-row dequant scales folded into the weights."""
    w = wts.astype(jnp.float32)
    if scale is not None:
        w = w * jnp.take(scale[:, 0], nbr, axis=0)
    gathered = jnp.take(data, nbr, axis=0).astype(jnp.float32)
    return jnp.sum(w[..., None] * gathered, axis=1)
