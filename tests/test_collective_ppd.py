"""Parts-per-device > 1 collective paths: equivalence + loud mismatch.

``collective_pull`` / ``shard_push`` / ``shard_staleness_error`` now
block the owner-sharded slot space into k = M/devices shards per device.
These tests pin down, for M in {4, 8} on 2- and 4-device meshes (k in
{1, 2, 4}) plus the M=16-on-8 acceptance case:

  * pull slabs, pushed stores and staleness maxima are **bitwise** equal
    to the dense-gather/SPMD fallback forms, in fp32 and int8;
  * a full collective-mode epoch leaves a store bitwise-equal to the
    gather fallback's and to single-device execution (gcn/sage; gat to
    1e-6 — its multi-head attention einsums reassociate under vmap), and
    the r=2 pulled slab (reading the r=1 store) is bitwise-equal too;
  * a part count that does not divide the mesh axis raises the
    spelled-out ValueError instead of corrupting slot math.

Needs >= 8 forced host devices; on single-device hosts the subprocess
variant re-launches this file (same pattern as test_sharded_pull).
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.leg("m16-ppd2-hlo")


def _tree_equal(a: dict, b: dict, what: str = ""):
    assert sorted(a) == sorted(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=f"{what}[{k}]")


def _kvs_parity(g, M: int, D: int):
    """collective_pull / shard_push / shard_staleness_error == the dense
    fallback forms, bitwise, with k = M/D owner shards per device."""
    from repro.core import halo_exchange as hx
    from repro.core.halo_exchange import HaloPrecision
    from repro.graph import build_partitions
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(data=D)
    sp = build_partitions(g, M)
    assert sp.shards_per_device(D) == M // D
    L1, hid = 2, 32
    rng = np.random.default_rng(M * 31 + D)
    reps = rng.normal(size=(M, L1, sp.part_size, hid)).astype(np.float32)
    slots = jnp.asarray(sp.local_slots)
    valid = jnp.asarray(sp.local_valid)
    sent = jnp.asarray(sp.sentinel_slots)
    boundary = jnp.asarray(sp.local_boundary)
    plan = sp.pull_plan()

    for storage in ("fp32", "int8"):
        prec = HaloPrecision(storage)
        store = hx.init_store(L1, sp.store_rows - 1, hid, prec)
        store = hx.push(store, slots, valid, jnp.asarray(reps), sent)

        want = hx.pull_slab(store, jnp.asarray(sp.halo_slots))
        got = hx.collective_pull(store, jnp.asarray(plan.send_offsets),
                                 jnp.asarray(plan.recv_positions),
                                 sp.halo_size, mesh)
        _tree_equal(got, want, f"pull M={M} D={D} {storage}")

        base = hx.init_store(L1, sp.store_rows - 1, hid, prec)
        via_spmd = hx.push(base, slots, valid, jnp.asarray(reps), sent)
        via_shmap = hx.shard_push(base, slots, valid, jnp.asarray(reps),
                                  sp.shard_rows, mesh)
        _tree_equal(via_shmap, via_spmd, f"push M={M} D={D} {storage}")

        fresh = jnp.asarray(
            rng.normal(size=reps.shape).astype(np.float32))
        eps_spmd = hx.staleness_error(store, fresh, slots, boundary)
        eps_shmap = hx.shard_staleness_error(store, fresh, slots,
                                             boundary, sp.shard_rows,
                                             mesh)
        np.testing.assert_array_equal(np.asarray(eps_shmap),
                                      np.asarray(eps_spmd))


def _epoch_equivalence(g, M: int, D: int, model: str, storage: str,
                       exact: bool):
    """Two epochs (push at r=1, pull at r=2 with N=2): post-epoch stores
    and the r=2 pulled slab agree across single-device execution, the
    sharded gather fallback, and the fully-SPMD collective epoch."""
    import hlo_utils
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(data=D)
    runs = {}
    for name, m, pull_mode in (("single", None, "gather"),
                               ("gather", mesh, "gather"),
                               ("collective", mesh, "collective")):
        fn, state, tdata = hlo_utils.make_epoch(
            g, M, m, storage=storage, pull_mode=pull_mode, model=model)
        state, m1 = fn(state, tdata)     # r=1: PUSH fresh reps
        store1 = {k: np.asarray(v) for k, v in state["store"].items()}
        state, m2 = fn(state, tdata)     # r=2: PULL the r=1 store
        runs[name] = {
            "store": store1,
            "slab": {k: np.asarray(v) for k, v in state["cache"].items()},
            "eps": np.asarray(m1["staleness_eps"]),
        }

    ref = runs["single"]
    for name in ("gather", "collective"):
        got = runs[name]
        label = f"{model}/{storage} M={M} D={D} {name}"
        if exact:
            _tree_equal(got["store"], ref["store"], f"store {label}")
            _tree_equal(got["slab"], ref["slab"], f"slab {label}")
            np.testing.assert_array_equal(got["eps"], ref["eps"],
                                          err_msg=label)
        else:
            for k in ref["store"]:
                np.testing.assert_allclose(
                    got["store"][k].astype(np.float32),
                    ref["store"][k].astype(np.float32),
                    atol=1e-6, err_msg=f"store {label}")
            for k in ref["slab"]:
                np.testing.assert_allclose(
                    got["slab"][k].astype(np.float32),
                    ref["slab"][k].astype(np.float32),
                    atol=1e-6, err_msg=f"slab {label}")
    # The two sharded paths against each other (the acceptance check:
    # collective == dense-gather fallback, bitwise).
    if exact:
        _tree_equal(runs["collective"]["store"], runs["gather"]["store"],
                    f"store {model}/{storage} M={M} D={D} coll-vs-gather")
        _tree_equal(runs["collective"]["slab"], runs["gather"]["slab"],
                    f"slab {model}/{storage} M={M} D={D} coll-vs-gather")


def _mismatch_raises(g):
    from repro.core import halo_exchange as hx
    from repro.core.halo_exchange import HaloPrecision
    from repro.graph import build_partitions
    from repro.launch.mesh import make_host_mesh

    mesh3 = make_host_mesh(data=3)
    sp = build_partitions(g, 4)
    plan = sp.pull_plan()
    store = hx.init_store(2, sp.store_rows - 1, 16, HaloPrecision())
    for fn, args in (
            (hx.collective_pull, (store, jnp.asarray(plan.send_offsets),
                                  jnp.asarray(plan.recv_positions),
                                  sp.halo_size, mesh3)),
            (hx.shard_push, (store, jnp.asarray(sp.local_slots),
                             jnp.asarray(sp.local_valid),
                             jnp.zeros((4, 2, sp.part_size, 16)),
                             sp.shard_rows, mesh3)),
            (hx.shard_staleness_error,
             (store, jnp.zeros((4, 2, sp.part_size, 16)),
              jnp.asarray(sp.local_slots),
              jnp.asarray(sp.local_boundary), sp.shard_rows, mesh3))):
        try:
            fn(*args)
        except ValueError as e:
            msg = str(e)
            assert "num_parts=4" in msg and "3 devices" in msg, msg
        else:
            raise AssertionError(f"{fn.__name__} accepted M=4 on a "
                                 f"3-device axis")
    try:
        sp.shards_per_device(3)
    except ValueError as e:
        assert "num_parts=4" in str(e) and "3 devices" in str(e)
    else:
        raise AssertionError("shards_per_device accepted 4 % 3")


def _checks():
    from repro.graph import make_dataset

    assert jax.device_count() >= 8, jax.device_count()
    g = make_dataset("flickr-sim", scale=0.1, seed=7)

    for M in (4, 8):
        for D in (2, 4):
            _kvs_parity(g, M, D)
    _mismatch_raises(g)

    # Full-epoch equivalence: gcn/sage bitwise, gat to 1e-6, at
    # parts-per-device 2 — and the M=16-on-8-devices acceptance case.
    _epoch_equivalence(g, 8, 4, "gcn", "fp32", exact=True)
    _epoch_equivalence(g, 8, 4, "sage", "int8", exact=True)
    _epoch_equivalence(g, 8, 4, "gat", "fp32", exact=False)
    _epoch_equivalence(g, 16, 8, "gcn", "int8", exact=True)


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (CI REPRO_HOST_DEVICES=8 job)")
def test_collective_parts_per_device_inprocess():
    _checks()


def test_collective_parts_per_device_subprocess():
    """Force an 8-device CPU platform in a subprocess so the
    parts-per-device paths are exercised even on single-device hosts."""
    if jax.device_count() >= 8:
        pytest.skip("covered by the in-process variant")
    import hlo_utils
    hlo_utils.run_forced_device_subprocess(__file__, "PPD_OK")


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    _checks()
    print("PPD_OK")
