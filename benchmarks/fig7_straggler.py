"""Fig. 7: heterogeneous environment — one straggler worker (8-10 s delay).

DIGEST-A (async) vs synchronous DIGEST on *simulated* wall-clock."""
from benchmarks.common import bench_scale, emit
from benchmarks.gnn_common import setup
from repro.core import (AsyncSettings, digest_a_train, sync_time_per_round)
from repro.optim import adam


def run() -> list[dict]:
    scale = bench_scale()
    _, data, cfg = setup("flickr-sim", scale=0.3 * scale)
    M = int(data["halo_ids"].shape[0])
    settings = AsyncSettings(sync_interval=10, straggler=0, seed=7)
    rounds = max(int(M * 60 * scale), M * 20)
    _, hist = digest_a_train(cfg, adam(5e-3), data, settings,
                             total_rounds=rounds,
                             eval_every_rounds=max(rounds // 6, 1))
    t_sync = sync_time_per_round(settings, M)
    rows = [{
        "name": "fig7/digest_a",
        "us_per_call": round(hist["sim_time"][-1] / hist["round"][-1] * 1e6,
                             1),
        "f1": round(hist["val_f1"][-1], 4),
        "sim_time_s": round(hist["sim_time"][-1], 2),
        "max_delay": max(hist["delay"]),
    }, {
        "name": "fig7/digest_sync_barrier",
        "us_per_call": round(t_sync * 1e6, 1),
        "note": "per-round barrier time under the same straggler model",
    }]
    return rows


if __name__ == "__main__":
    emit(run())
