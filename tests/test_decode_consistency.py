"""Teacher-forcing: token-by-token decode must match the training forward
for every architecture family (attn, GQA, qk-norm, moe, rec, xlstm, vlm)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_arch
from repro.models.transformer import (arch_specs, decode_step, forward,
                                      init_cache, precompute_vision_cache)
from repro.nn import init_params

FAMILIES = ["qwen3_0_6b", "recurrentgemma_9b", "xlstm_1_3b",
            "llama4_scout_17b_a16e", "llama_3_2_vision_11b",
            "musicgen_large"]


@pytest.mark.parametrize("name", FAMILIES)
def test_decode_matches_forward(name):
    cfg = get_smoke_arch(name)
    params = init_params(jax.random.PRNGKey(0), arch_specs(cfg))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    vis = None
    if cfg.vision_dim:
        vis = jax.random.normal(jax.random.PRNGKey(2),
                                (B, cfg.num_patches, cfg.vision_dim))
    ref = forward(cfg, params, toks, vis)
    cache = init_cache(cfg, B, S)
    if cfg.vision_dim:
        cache = precompute_vision_cache(cfg, params, cache, vis)
    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    outs = []
    for t in range(S):
        lg, cache = step(params, cache, toks[:, t:t+1])
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    scale = float(jnp.max(jnp.abs(ref)))
    assert float(jnp.max(jnp.abs(dec - ref))) / scale < 2e-2


def test_long_decode_exact_within_window():
    import dataclasses
    cfg = get_smoke_arch("phi3_mini_3_8b")
    cfg = dataclasses.replace(cfg, long_window=32, long_ratio=8)
    params = init_params(jax.random.PRNGKey(0), arch_specs(cfg))
    B, S = 1, 48
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    cache_f = init_cache(cfg, B, S)
    cache_l = init_cache(cfg, B, S, long=True)
    sf = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    sl = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t, long=True))
    for t in range(S):
        lf, cache_f = sf(params, cache_f, toks[:, t:t+1])
        ll, cache_l = sl(params, cache_l, toks[:, t:t+1])
        if t < cfg.long_window:
            np.testing.assert_allclose(ll, lf, atol=1e-4, rtol=1e-4)
        assert bool(jnp.all(jnp.isfinite(ll)))
