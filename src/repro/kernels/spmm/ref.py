"""Pure-jnp oracle for the ELL SpMM kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spmm_ref(nbr: jax.Array, wts: jax.Array, table: jax.Array) -> jax.Array:
    """out[i] = sum_k wts[i,k] * table[nbr[i,k]] — vectorized gather form."""
    gathered = jnp.take(table, nbr, axis=0)        # (rows, deg, feat)
    w = wts.astype(jnp.float32)[..., None]
    return jnp.sum(w * gathered.astype(jnp.float32), axis=1)
