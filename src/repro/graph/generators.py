"""Synthetic graph generators (offline stand-ins for OGB/Flickr/Reddit).

Each generator produces a :class:`Graph` with class-informative node features
so the GNN training curves behave like the paper's (loss drops, F1 rises, and
partition-induced information loss is *measurable*).
"""
from __future__ import annotations

import numpy as np

from repro.graph.graph import Graph, from_edges


def _features_from_labels(labels: np.ndarray, num_classes: int, dim: int,
                          noise: float, rng: np.random.Generator
                          ) -> np.ndarray:
    centers = rng.normal(size=(num_classes, dim)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    x = centers[labels] + noise * rng.normal(size=(len(labels), dim))
    return x.astype(np.float32)


def _masks(n: int, frac: tuple[float, float, float],
           rng: np.random.Generator):
    idx = rng.permutation(n)
    a = int(frac[0] * n)
    b = a + int(frac[1] * n)
    train = np.zeros(n, bool); train[idx[:a]] = True
    val = np.zeros(n, bool); val[idx[a:b]] = True
    test = np.zeros(n, bool); test[idx[b:]] = True
    return train, val, test


def sbm_graph(num_nodes: int = 4000, num_classes: int = 8,
              avg_degree: float = 12.0, p_in_out_ratio: float = 8.0,
              feature_dim: int = 64, noise: float = 0.8, seed: int = 0,
              frac=(0.6, 0.2, 0.2), name: str = "sbm") -> Graph:
    """Stochastic block model with community-aligned labels."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(num_classes, size=num_nodes).astype(np.int32)
    # Expected degree: d = p_in * n_in + p_out * n_out.
    n_in = num_nodes / num_classes
    n_out = num_nodes - n_in
    p_out = avg_degree / (p_in_out_ratio * n_in + n_out)
    p_in = p_in_out_ratio * p_out

    # Sample edges in blocks without materializing the N^2 matrix.
    # Intra-class edges are drawn PER CLASS (rejection sampling over
    # uniform pairs under-produces same-class pairs by ~num_classes x,
    # silently destroying homophily for many-class datasets).
    edges = []
    m_intra = int(rng.poisson(0.5 * p_in * n_in * num_nodes))
    m_inter = int(rng.poisson(0.5 * p_out * n_out * num_nodes))
    nodes_by_class = [np.where(labels == c)[0] for c in range(num_classes)]
    sizes = np.array([len(nc) for nc in nodes_by_class], np.float64)
    wts = np.maximum(sizes, 1.0) ** 2
    per_class = rng.multinomial(m_intra, wts / wts.sum())
    for c, m_c in enumerate(per_class):
        nc = nodes_by_class[c]
        if len(nc) < 2 or m_c == 0:
            continue
        u = rng.choice(nc, size=m_c)
        v = rng.choice(nc, size=m_c)
        edges.append(np.stack([u, v], 1))
    u = rng.integers(num_nodes, size=int(1.5 * m_inter) + 1)
    v = rng.integers(num_nodes, size=int(1.5 * m_inter) + 1)
    diff = labels[u] != labels[v]
    edges.append(np.stack([u[diff][:m_inter], v[diff][:m_inter]], 1))
    edges = np.concatenate(edges, axis=0)

    feats = _features_from_labels(labels, num_classes, feature_dim, noise,
                                  rng)
    return from_edges(num_nodes, edges, feats, labels,
                      masks=_masks(num_nodes, frac, rng), name=name)


def powerlaw_graph(num_nodes: int = 4000, num_classes: int = 8,
                   m_attach: int = 6, feature_dim: int = 64,
                   noise: float = 0.8, seed: int = 0,
                   frac=(0.6, 0.2, 0.2), name: str = "powerlaw") -> Graph:
    """Barabási–Albert preferential attachment; labels by spectral-ish
    propagation from random seeds so they correlate with structure."""
    rng = np.random.default_rng(seed)
    targets = list(range(m_attach))
    repeated: list[int] = list(range(m_attach))
    edges = []
    for v in range(m_attach, num_nodes):
        choice = rng.choice(len(repeated), size=m_attach, replace=False)
        chosen = {repeated[c] for c in choice}
        for u in chosen:
            edges.append((v, u))
            repeated.append(u)
        repeated.extend([v] * len(chosen))
    edges = np.asarray(edges, np.int64)

    # Structure-correlated labels: seed random labels, 3 rounds of majority.
    labels = rng.integers(num_classes, size=num_nodes).astype(np.int32)
    adj = [[] for _ in range(num_nodes)]
    for a, b in edges:
        adj[a].append(b)
        adj[b].append(a)
    for _ in range(3):
        new = labels.copy()
        for v in range(num_nodes):
            if adj[v]:
                vals, cnt = np.unique(labels[adj[v]], return_counts=True)
                new[v] = vals[np.argmax(cnt)]
        labels = new

    feats = _features_from_labels(labels, num_classes, feature_dim, noise,
                                  rng)
    return from_edges(num_nodes, edges, feats, labels,
                      masks=_masks(num_nodes, frac, rng), name=name)


def community_powerlaw_graph(num_nodes: int = 40000, num_comm: int = None,
                             num_classes: int = 8, avg_degree: float = 10.0,
                             gamma: float = 2.5, p_intra: float = 0.9,
                             feature_dim: int = 64, noise: float = 0.8,
                             seed: int = 0, frac=(0.6, 0.2, 0.2),
                             name: str = "community-powerlaw") -> Graph:
    """Degree-corrected community Chung–Lu graph, fully vectorized.

    The production-scale generator: every step is a numpy bulk op (no
    per-edge Python loop, unlike :func:`powerlaw_graph`'s preferential
    attachment), so million-node instances build in seconds — big enough
    to exercise the O(E) streaming partitioner and the chunk-skipping
    kernel regime.  Nodes split into ``num_comm`` communities (default
    ``num_nodes // 100``); per-node expected degrees follow a power law
    with exponent ``gamma`` (weights ``rank^(-1/(gamma-1))``, the classic
    Chung–Lu construction), a ``p_intra`` fraction of edges sampled
    weight-proportionally *within* each community and the rest globally.
    The community structure is what gives partition-time locality work
    to do: RCM row ordering clusters each part's rows by community, so
    halo references concentrate into few slab chunks (see
    ``graph.partition``).  Labels are community-aligned (``comm %
    num_classes``) with the usual class-informative features.
    """
    rng = np.random.default_rng(seed)
    if num_comm is None:
        num_comm = max(num_nodes // 100, 8)
    comm = np.sort(rng.integers(num_comm, size=num_nodes)).astype(np.int32)
    starts = np.searchsorted(comm, np.arange(num_comm))
    ends = np.searchsorted(comm, np.arange(num_comm), side="right")
    csize = ends - starts
    # Power-law expected degrees, restarting the rank ladder inside each
    # community so every community gets its own hubs.
    rank = np.arange(num_nodes) - starts[comm] + 1.0
    w = rank ** (-1.0 / (gamma - 1.0))

    m = int(avg_degree * num_nodes / 2)
    m_in = int(p_intra * m)
    m_out = m - m_in
    edges = []
    # Intra-community edges: weight-proportional endpoints inside each
    # community, edge budget split by community size.  One cumulative-sum
    # table over all nodes serves every community (per-community CDF =
    # slice of the global cumsum minus its left edge).
    cum = np.cumsum(w)
    left = cum[starts] - w[starts]
    tot = cum[ends - 1] - left
    per = rng.multinomial(m_in, csize / max(csize.sum(), 1))
    e_comm = np.repeat(np.arange(num_comm), per)
    if len(e_comm):
        lo, width = left[e_comm], tot[e_comm]
        u = np.searchsorted(cum, lo + rng.random(len(e_comm)) * width)
        v = np.searchsorted(cum, lo + rng.random(len(e_comm)) * width)
        edges.append(np.stack([u, v], 1))
    # Global (inter-community) edges: weight-proportional over all nodes.
    if m_out:
        cdf = cum / cum[-1]
        u = np.searchsorted(cdf, rng.random(m_out))
        v = np.searchsorted(cdf, rng.random(m_out))
        edges.append(np.stack([u, v], 1))
    edges = np.concatenate(edges, axis=0)
    edges = np.minimum(edges, num_nodes - 1)

    labels = (comm % num_classes).astype(np.int32)
    feats = _features_from_labels(labels, num_classes, feature_dim, noise,
                                  rng)
    return from_edges(num_nodes, edges, feats, labels,
                      masks=_masks(num_nodes, frac, rng), name=name)


# ---------------------------------------------------------------------------
# Named dataset registry — scaled stand-ins for the paper's four benchmarks.
# (# nodes/edges scaled ~40x down to the CPU budget; density ordering and
# train-fraction profiles match Table 3 of the paper.)
# ---------------------------------------------------------------------------

def make_dataset(name: str, seed: int = 0, scale: float = 1.0) -> Graph:
    n = lambda base: max(256, int(base * scale))
    # p_in_out_ratio ≈ num_classes keeps ~50-65% of edges intra-class
    # (matching the real datasets' homophily); with the default 8 a
    # 40-class SBM would be ~17% homophilous and aggregation would mix
    # classes into the global mean.
    if name == "arxiv-sim":      # OGB-Arxiv: medium, sparse, 40 classes
        return sbm_graph(n(4200), num_classes=40, avg_degree=13.7,
                         p_in_out_ratio=60.0,
                         feature_dim=128, noise=0.7, seed=seed,
                         frac=(0.537, 0.176, 0.287), name=name)
    if name == "flickr-sim":     # Flickr: small, sparse, 7 classes
        return sbm_graph(n(2200), num_classes=7, avg_degree=10.1,
                         feature_dim=100, noise=1.0, seed=seed,
                         frac=(0.5, 0.25, 0.25), name=name)
    if name == "reddit-sim":     # Reddit: dense (deg ~100), 41 classes
        return sbm_graph(n(2900), num_classes=41, avg_degree=99.6,
                         p_in_out_ratio=60.0,
                         feature_dim=120, noise=0.8, seed=seed,
                         frac=(0.66, 0.10, 0.24), name=name)
    if name == "products-sim":   # OGB-Products: large, deg ~50, 47 classes
        return sbm_graph(n(12000), num_classes=47, avg_degree=50.5,
                         p_in_out_ratio=70.0,
                         feature_dim=100, noise=0.8, seed=seed,
                         frac=(0.08, 0.02, 0.90), name=name)
    if name == "powerlaw-sim":
        return powerlaw_graph(n(3000), seed=seed, name=name)
    if name == "papers-sim":     # OGB-Papers100M-ish: huge, power-law,
        return community_powerlaw_graph(    # community-structured
            n(40000), seed=seed, name=name)
    raise KeyError(f"unknown dataset {name!r}")


DATASETS = ["arxiv-sim", "flickr-sim", "reddit-sim", "products-sim",
            "powerlaw-sim", "papers-sim"]
