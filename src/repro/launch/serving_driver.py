"""Shared serving-loop driver: batching, warmup, latency capture.

Every serving entry point in the repo — the LM decode loops
(``examples/serve_lm.py``, ``repro.launch.serve``) and the GNN
embedding-serving path (``examples/serve_gnn.py``,
``repro.launch.serve_gnn``, ``benchmarks/serve_bench.py``) — is the same
shape: thread a carry (KV cache / hot-row cache) through a jitted step
over a stream of work items, blocking on each result so wall-clock
actually measures the step, and summarize the latency distribution.
This module is that loop, written once.

``step_fn(carry, item) -> (carry, out)`` is the only contract; the
driver owns timing (``jax.block_until_ready`` on everything the step
returns — without it XLA's async dispatch would attribute a step's cost
to whoever blocks next) and the stats: p50/p99 latency over the
steady-state calls (the first ``warmup`` calls — compile + cache-warm —
are excluded) and items/sec throughput.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable

import jax
import numpy as np


@dataclasses.dataclass
class ServeStats:
    """Latency capture of one serving loop."""

    latencies_s: list           # per-call wall-clock seconds, in order
    warmup: int = 0             # leading calls excluded from percentiles
    items_per_call: int = 1     # batch size, for the throughput number

    @property
    def steady(self) -> list:
        tail = self.latencies_s[self.warmup:]
        return tail if tail else self.latencies_s

    @property
    def total_s(self) -> float:
        return float(sum(self.latencies_s))

    def percentile_ms(self, q: float) -> float:
        return float(np.percentile(np.asarray(self.steady), q) * 1e3)

    @property
    def p50_ms(self) -> float:
        return self.percentile_ms(50)

    @property
    def p99_ms(self) -> float:
        return self.percentile_ms(99)

    @property
    def mean_ms(self) -> float:
        return float(np.mean(np.asarray(self.steady)) * 1e3)

    @property
    def per_sec(self) -> float:
        """Steady-state items (queries / tokens) per second."""
        denom = max(float(sum(self.steady)), 1e-12)
        return self.items_per_call * len(self.steady) / denom

    def summary(self) -> dict:
        return {"calls": len(self.latencies_s), "warmup": self.warmup,
                "items_per_call": self.items_per_call,
                "p50_ms": self.p50_ms, "p99_ms": self.p99_ms,
                "mean_ms": self.mean_ms, "per_sec": self.per_sec}


def run_serve_loop(step_fn: Callable[[Any, Any], tuple],
                   items: Iterable, carry: Any = None, warmup: int = 0,
                   items_per_call: int = 1,
                   ) -> tuple[Any, list, ServeStats]:
    """Drive ``step_fn`` over ``items``, timing every call.

    step_fn(carry, item) -> (carry, out); each call is blocked on before
    the clock stops.  Returns (final carry, [out per call], ServeStats);
    the first ``warmup`` calls stay in the latency list but are excluded
    from the percentile/throughput stats.
    """
    latencies, outs = [], []
    for item in items:
        t0 = time.perf_counter()
        carry, out = step_fn(carry, item)
        jax.block_until_ready((carry, out))
        latencies.append(time.perf_counter() - t0)
        outs.append(out)
    warmup = min(warmup, max(len(latencies) - 1, 0))
    return carry, outs, ServeStats(latencies, warmup=warmup,
                                   items_per_call=items_per_call)
