"""Graph substrate invariants (+ property tests)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph import (build_partitions, edge_cut, from_edges,
                         gcn_norm_weights, greedy_partition, make_dataset,
                         random_partition, sbm_graph)


def test_dataset_registry():
    for name in ["arxiv-sim", "flickr-sim", "reddit-sim"]:
        g = make_dataset(name, scale=0.05)
        g.validate()
        assert g.train_mask.sum() > 0
        assert not (g.train_mask & g.val_mask).any()


def test_gcn_norm_rows_bounded():
    g = make_dataset("flickr-sim", scale=0.1)
    rows, cols, w = gcn_norm_weights(g)
    sums = np.zeros(g.num_nodes)
    np.add.at(sums, rows, w)
    assert (w > 0).all()
    # symmetric normalization keeps row sums O(1) (not strictly <=1)
    assert sums.max() < 3.0
    assert sums.min() > 0.0


@pytest.mark.parametrize("method", ["greedy", "random"])
def test_partition_covers_all_nodes(method):
    g = make_dataset("flickr-sim", scale=0.15)
    sp = build_partitions(g, 4, method=method)
    ids = sp.local_ids[sp.local_valid]
    assert len(ids) == g.num_nodes
    assert len(np.unique(ids)) == g.num_nodes


def test_greedy_cut_beats_random():
    g = make_dataset("flickr-sim", scale=0.2)
    cg = edge_cut(g, greedy_partition(g, 4))
    cr = edge_cut(g, random_partition(g, 4))
    assert cg < cr


def test_partition_reconstructs_p():
    """P_in + P_out per subgraph == global P rows (no edge dropped)."""
    g = sbm_graph(num_nodes=300, num_classes=4, seed=1)
    sp = build_partitions(g, 3)
    rows, cols, w = gcn_norm_weights(g)
    P = np.zeros((g.num_nodes, g.num_nodes))
    P[rows, cols] = w
    for m in range(3):
        loc = sp.local_ids[m][sp.local_valid[m]]
        halo = sp.halo_ids[m][sp.halo_valid[m]]
        S, H = sp.part_size, sp.halo_size
        Pin = np.zeros((S, S))
        Pout = np.zeros((S, H))
        for i in range(S):
            for kk in range(sp.in_nbr.shape[-1]):
                c = sp.in_nbr[m, i, kk]
                if c < S:
                    Pin[i, c] += sp.in_wts[m, i, kk]
            for kk in range(sp.out_nbr.shape[-1]):
                c = sp.out_nbr[m, i, kk]
                if c < H:
                    Pout[i, c] += sp.out_wts[m, i, kk]
        np.testing.assert_allclose(Pin[:len(loc), :len(loc)],
                                   P[np.ix_(loc, loc)], atol=1e-6)
        np.testing.assert_allclose(Pout[:len(loc), :len(halo)],
                                   P[np.ix_(loc, halo)], atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(20, 120), m=st.integers(2, 5),
       seed=st.integers(0, 1000))
def test_partition_property(n, m, seed):
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(max(n * 3, 16), 2))
    feats = rng.normal(size=(n, 8)).astype(np.float32)
    labels = rng.integers(0, 3, size=n).astype(np.int32)
    g = from_edges(n, e, feats, labels)
    sp = build_partitions(g, m)
    # every node exactly once; halo ∩ local = ∅ per part
    ids = sp.local_ids[sp.local_valid]
    assert sorted(ids.tolist()) == list(range(n))
    for i in range(m):
        loc = set(sp.local_ids[i][sp.local_valid[i]].tolist())
        halo = set(sp.halo_ids[i][sp.halo_valid[i]].tolist())
        assert not loc & halo
    # halo ratio metric is finite
    assert np.isfinite(sp.halo_ratio()).all()
