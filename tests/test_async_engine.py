"""DIGEST-A simulator regression tests.

Pins the three correctness fixes of the async engine:

  * **Store-layout parity** (property test): the async store is built
    from the audited ``store_geometry`` — shapes, shard_rows, per-shard
    sentinels and owner blocks identical to the SPMD epoch's store
    (``init_state``) for the same partitions, across partition counts
    and graph seeds.
  * **Cold-store pulls**: pushes fire at (r−1) % N == 0 but pulls at
    r % N == 0, so without the round-0 warm start a fast worker's first
    pull could consume never-pushed all-zero rows from a straggler's
    shard.  The engine's ``cold_rows`` probe must stay 0 under the
    default warm start and goes positive with ``warm_start=False``
    under a straggler (the probe provably detects the bug).
  * **Eval history aggregation**: each tick logs the MEAN of every
    worker's latest round loss (replayed from the per-round log) and
    the MAX staleness — not whichever single worker landed on the tick.
"""
import functools

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (AsyncSettings, digest_a_train, halo_exchange,
                        init_state, prepare_graph_data, store_geometry)
from repro.graph import make_dataset
from repro.models.gnn import GNNConfig
from repro.optim import adam

pytestmark = pytest.mark.leg("sampling-smoke")


@functools.lru_cache(maxsize=None)
def _graph(seed: int = 0):
    return make_dataset("flickr-sim", scale=0.12, seed=seed)


@functools.lru_cache(maxsize=None)
def _data(num_parts: int, seed: int = 0):
    return prepare_graph_data(_graph(seed), num_parts)


def _cfg(g, num_layers=2, hidden=32):
    return GNNConfig(model="gcn", num_layers=num_layers,
                     in_dim=g.features.shape[1], hidden_dim=hidden,
                     num_classes=int(g.labels.max()) + 1)


# ---------------------------------------------------------------------------
# Satellite 3: async/SPMD store-layout parity
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(num_parts=st.sampled_from([2, 3, 4, 6]), seed=st.integers(0, 1))
def test_async_store_layout_matches_spmd(num_parts, seed):
    g = _graph(seed)
    data = _data(num_parts, seed)
    cfg = _cfg(g)
    num_slots, shard_rows = store_geometry(data)
    sp = data["_sp"]

    # shard_rows from the sentinel layout == the partitioner's.
    assert shard_rows == sp.shard_rows
    total_rows = int(data["store_ids"].shape[0])
    assert total_rows == num_parts * shard_rows
    assert num_slots == total_rows - 1

    # Per-shard sentinel layout: slot = owner·shard_rows + rank, each
    # shard's last row its zero sentinel; init_store's appended global
    # sentinel (row R−1) IS the last shard's sentinel.
    sentinels = np.asarray(data["sentinel_slots"])
    assert np.array_equal(sentinels,
                          (np.arange(num_parts) + 1) * shard_rows - 1)
    # Sentinel rows map to the graph's zero-feature sentinel node.
    store_ids = np.asarray(data["store_ids"])
    assert np.all(store_ids[sentinels] == g.num_nodes)

    # The async store (init_store on store_geometry's numbers) has the
    # same pytree shapes as the SPMD epoch's store for every precision.
    for prec in (halo_exchange.HaloPrecision(),
                 halo_exchange.HaloPrecision("int8")):
        state = init_state(cfg, adam(1e-3), data, precision=prec)
        async_store = halo_exchange.init_store(
            cfg.num_layers - 1, num_slots, cfg.hidden_dim, prec)
        assert {k: v.shape for k, v in async_store.items()} == \
               {k: v.shape for k, v in state["store"].items()}

    # Owner blocks: every part's boundary rows get real slots strictly
    # inside its own shard (below the shard sentinel); valid non-boundary
    # rows alias the part's own zero sentinel so their pushes are no-ops.
    slots = np.asarray(data["local_slots"])
    valid = np.asarray(data["local_valid"])
    boundary = np.asarray(data["local_boundary"])
    for m in range(num_parts):
        b = slots[m][boundary[m]]
        assert np.all((b >= m * shard_rows) & (b < sentinels[m])), m
        interior = slots[m][valid[m] & ~boundary[m]]
        assert np.all(interior == sentinels[m]), m


def test_store_geometry_rejects_broken_layout():
    data = dict(_data(4))
    bad = np.asarray(data["sentinel_slots"]).copy()
    bad[0] += 1
    data["sentinel_slots"] = bad
    with pytest.raises(ValueError, match="store layout"):
        store_geometry(data)


# ---------------------------------------------------------------------------
# Satellite 2: no cold-zero pulls under a straggler (warm start)
# ---------------------------------------------------------------------------

def test_no_cold_pulls_with_straggler():
    g = _graph()
    data = _data(4)
    cfg = _cfg(g)
    base = dict(sync_interval=4, straggler=0, seed=3)

    _, hist = digest_a_train(cfg, adam(5e-3), data,
                             AsyncSettings(**base), total_rounds=24,
                             eval_every_rounds=24)
    assert hist["cold_rows"][-1] == 0, hist["cold_rows"]

    # Positive control: disabling the warm start reproduces the bug and
    # the probe sees it — fast workers' first pulls at r = N consume
    # all-zero rows from the straggler's never-pushed shard.
    _, hist = digest_a_train(cfg, adam(5e-3), data,
                             AsyncSettings(warm_start=False, **base),
                             total_rounds=24, eval_every_rounds=24)
    assert hist["cold_rows"][-1] > 0, hist["cold_rows"]


# ---------------------------------------------------------------------------
# Satellite 1: eval history aggregates across workers
# ---------------------------------------------------------------------------

def test_history_loss_is_mean_across_workers():
    g = _graph()
    data = _data(4)
    cfg = _cfg(g)
    settings_ = AsyncSettings(sync_interval=3, seed=1)
    _, hist = digest_a_train(cfg, adam(5e-3), data, settings_,
                             total_rounds=18, eval_every_rounds=6)

    # Replay the per-round log: at each tick the logged loss must be the
    # mean of every worker's LATEST round loss up to that tick.
    workers = hist["round_worker"]
    losses = hist["round_loss"]
    assert len(workers) == len(losses) == 18
    for tick, rounds_done in enumerate(hist["round"]):
        last = {}
        for w, l in zip(workers[:rounds_done], losses[:rounds_done]):
            last[w] = l
        want = float(np.mean(list(last.values())))
        assert hist["loss"][tick] == pytest.approx(want, rel=1e-6), tick
    # More than one worker contributes by the first tick — the old code
    # logged a single worker's loss, which only coincides with the mean
    # if every other worker's loss is identical.
    assert len({w for w in workers[:hist["round"][0]]}) > 1


def test_history_delay_is_max_staleness():
    g = _graph()
    data = _data(4)
    cfg = _cfg(g)
    settings_ = AsyncSettings(sync_interval=3, straggler=0, seed=2)
    _, hist = digest_a_train(cfg, adam(5e-3), data, settings_,
                             total_rounds=60, eval_every_rounds=60)
    # The straggler sits on an 8–10 s round while ~3 fast workers do ~1 s
    # rounds: its snapshot goes ~3·8 server steps stale.  The max across
    # workers must reflect that; a fast worker (the likely tick-lander
    # the old code sampled) stays near delay ≈ 3.
    assert hist["delay"][-1] >= 8, hist["delay"]
