"""Pytree checkpointing: flattened-key npz payload + JSON manifest.

Atomic (write to tmp, rename), step-indexed, restores into an arbitrary
template pytree (used for both DIGEST GNN training state and the transformer
train states).  Leaf dtypes are preserved by npz, so the compact
HaloExchange store ({"data": int8/bf16/fp32, "scale": fp32}) round-trips
its quantized layout byte-for-byte; ``meta`` lets callers record the
precision/layout config alongside (see ``read_manifest``).

The owner-sharded store needs no special casing on save — ``np.asarray``
on a sharded jax array gathers the full (L-1, M·shard_rows, hidden) slab
to host, and the slot layout is positional *in part order, not device
order*, so a checkpoint written from an M-part run restores
bit-identically on any device count — including a different
parts-per-device blocking (M parts on M devices vs M parts on M/k
devices resolve to the same host slab).  Pass ``sharding=`` (a pytree of
shardings, or one sharding for all leaves) to ``restore_checkpoint`` to
place restored leaves straight onto the mesh instead of round-tripping
through a replicated host buffer.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np

Pytree = Any

_SEP = "/"


def _flatten_with_paths(tree: Pytree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_fmt(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":
            # ml_dtypes extension types (bfloat16 etc.) round-trip through
            # npz as raw void bytes that np can't cast back; store as f32
            # (lossless widening) and let restore narrow to the template
            # dtype.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _fmt(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


def save_checkpoint(ckpt_dir: str, step: int, tree: Pytree,
                    meta: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten_with_paths(tree)
    manifest = {"step": int(step), "keys": sorted(flat)}
    if meta:
        manifest["meta"] = meta
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    with open(os.path.join(ckpt_dir, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(manifest, f)
    return path


def read_manifest(ckpt_dir: str, step: int) -> dict:
    with open(os.path.join(ckpt_dir, f"ckpt_{step:08d}.json")) as f:
        return json.load(f)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1))
             for name in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"ckpt_(\d+)\.npz", name))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, template: Pytree,
                       step: Optional[int] = None,
                       sharding: Optional[Any] = None
                       ) -> tuple[Pytree, int]:
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path_entries, leaf in paths:
        key = _SEP.join(_fmt(p) for p in path_entries)
        if key not in data:
            raise KeyError(f"checkpoint missing key {key!r}")
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(
                f"shape mismatch for {key!r}: ckpt {arr.shape} vs "
                f"template {np.shape(leaf)}")
        leaves.append(arr.astype(np.asarray(leaf).dtype))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if sharding is not None:
        tree = jax.device_put(tree, sharding)
    return tree, int(step)
