"""GNN split-aggregation exactness: fresh halo == full-graph forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.digest import full_graph_forward, prepare_graph_data
from repro.core.error_bound import fresh_halo_cache
from repro.graph import make_dataset
from repro.models.gnn import GNNConfig, gnn_forward, gnn_specs
from repro.nn import init_params


@pytest.mark.parametrize("model", ["gcn", "sage", "gat"])
def test_distributed_fresh_equals_full_graph(model):
    """With FRESH halo tables (propagation mode), the partitioned forward
    must reproduce the full-graph forward exactly — the paper's 'no
    information loss' claim for its split formulation (Eq. 4/5)."""
    g = make_dataset("flickr-sim", scale=0.1)
    data = prepare_graph_data(g, 3)
    cfg = GNNConfig(model=model, num_layers=2,
                    in_dim=g.features.shape[1], hidden_dim=32,
                    num_classes=int(g.labels.max()) + 1, heads=4)
    params = init_params(jax.random.PRNGKey(0), gnn_specs(cfg))

    full_logits, _ = full_graph_forward(cfg, params, data)
    fresh = fresh_halo_cache(cfg, params, data)          # (M, L-1, H, hid)

    M = data["halo_ids"].shape[0]
    x_local = data["x_global"][data["local_ids"]]
    x_halo0 = data["x_global"][data["halo_ids"]]
    for m in range(M):
        struct = {k: v[m] for k, v in data["struct"].items()}
        tables = [x_halo0[m]] + [fresh[m][i]
                                 for i in range(cfg.num_layers - 1)]
        logits_m, _ = gnn_forward(cfg, params, x_local[m], tables, struct)
        # map back to full-graph row order
        loc = np.asarray(data["local_ids"][m])
        valid = np.asarray(data["local_valid"][m])
        got = np.asarray(logits_m)[valid]
        want = np.asarray(full_logits)[loc[valid]]
        np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("model", ["gcn", "sage", "gat"])
def test_zero_slab_ref_equals_zero_tables(model):
    """Partition-mode semantics: a zeroed shared slab (halo-ref form, real
    ELL weights) must equal the legacy zeroed per-part tables — dropped
    neighbors still count as zero vectors in GAT's attention denominator
    and SAGE's mean, they don't vanish from the normalization."""
    from repro.models.gnn import halo_ref

    g = make_dataset("flickr-sim", scale=0.05)
    data = prepare_graph_data(g, 2)
    cfg = GNNConfig(model=model, num_layers=2, in_dim=g.features.shape[1],
                    hidden_dim=32, num_classes=int(g.labels.max()) + 1,
                    heads=4)
    params = init_params(jax.random.PRNGKey(1), gnn_specs(cfg))
    m = 0
    x_local = data["x_global"][data["local_ids"]][m]
    struct = {k: v[m] for k, v in data["struct"].items()}
    H = data["halo_ids"].shape[1]
    B = int(data["store_ids"].shape[0]) - 1

    legacy_tables = [jnp.zeros((H, cfg.in_dim))] + \
        [jnp.zeros((H, cfg.hidden_dim))] * (cfg.num_layers - 1)
    want, _ = gnn_forward(cfg, params, x_local, legacy_tables, struct)

    n1 = data["x_global"].shape[0]
    sp = data["_sp"]
    refs = [halo_ref(jnp.zeros((n1, cfg.in_dim)), None,
                     jnp.asarray(sp.out_nbr_global[m]),
                     struct["out_wts"])] + \
        [halo_ref(jnp.zeros((B + 1, cfg.hidden_dim)), None,
                  jnp.asarray(sp.out_nbr_store[m]),
                  struct["out_wts"])] * \
        (cfg.num_layers - 1)
    got, _ = gnn_forward(cfg, params, x_local, refs, struct)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=1e-6)


def test_normalization_applied():
    g = make_dataset("flickr-sim", scale=0.05)
    data = prepare_graph_data(g, 2)
    cfg = GNNConfig(model="gcn", num_layers=3, in_dim=g.features.shape[1],
                    hidden_dim=16, num_classes=4, normalize=True)
    params = init_params(jax.random.PRNGKey(0), gnn_specs(cfg))
    x_local = data["x_global"][data["local_ids"]][0]
    tables = [data["x_global"][data["halo_ids"]][0]] + [
        jnp.zeros((data["halo_ids"].shape[1], 16))] * 2
    struct = {k: v[0] for k, v in data["struct"].items()}
    _, push = gnn_forward(cfg, params, x_local, tables, struct)
    for rep in push:
        norms = np.asarray(jnp.linalg.norm(rep, axis=-1))
        nonzero = norms[norms > 1e-6]     # padding rows stay zero
        assert len(nonzero) > 0
        assert np.abs(nonzero - 1.0).max() < 1e-3  # unit rows
