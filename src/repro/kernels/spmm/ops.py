"""Jitted public entry point for neighbor aggregation.

Dispatch: ``backend="auto"`` uses the Pallas kernel on TPU and the pure-jnp
reference on CPU (interpret-mode Pallas is Python-slow; the oracle is the
same math).  Tests pin ``backend="pallas_interpret"`` to validate the kernel
body itself.

``halo_spmm``'s Pallas path picks between three kernels:

  * **resident** — the slab's 128-wide feature stripe fits the
    ``resident_max_bytes`` VMEM budget (default
    ``RESIDENT_STRIPE_MAX_BYTES``): carry it whole into VMEM.
  * **dense stream** — above the budget: chunked double-buffered DMA of
    every ``chunk_rows``-row slab chunk past the accumulator tile.
  * **skip stream** — above the budget *and* a (row_block × chunk)
    worklist is supplied whose static measured ``occupancy`` is at or
    below ``skip_occupancy_max`` (default ``SKIP_OCCUPANCY_MAX``): stream
    only the chunks each row block references
    (:func:`repro.kernels.spmm.halo_pull.halo_spmm_skip_pallas`).  At
    high occupancy the worklist degenerates to the dense schedule while
    paying the scalar-prefetch indirection, so the dense stream wins —
    hence the threshold, overridable per call (it is a static, jit-cache-
    keyed argument, like every selection knob here).

Pin ``backend="pallas_stream[_interpret]"`` / ``"pallas_skip[_interpret]"``
to force a specific streamed variant (tests / benchmarks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.spmm.halo_pull import (STREAM_CHUNK_ROWS,
                                          halo_spmm_pallas,
                                          halo_spmm_skip_pallas,
                                          halo_spmm_stream_pallas)
from repro.kernels.spmm.ref import halo_spmm_ref, spmm_ref
from repro.kernels.spmm.spmm import BLOCK_F, spmm_pallas

# Largest slab stripe the resident kernel may carry whole into VMEM; a
# 128-wide fp32 stripe hits this at 8k rows (int8: 32k rows).  Above it,
# halo_spmm streams the slab through chunked double-buffered DMA.
RESIDENT_STRIPE_MAX_BYTES = 4 * 1024 * 1024

# Highest (row_block × chunk) occupancy at which the chunk-skipping
# stream is auto-selected over the dense stream.  Above it most chunks
# are visited anyway and the dense schedule's simpler (non-indirected)
# prefetch wins; below it DMA bytes shrink proportionally to occupancy.
SKIP_OCCUPANCY_MAX = 0.5


def _pad_dim(x: jax.Array, axis: int, multiple: int,
             value=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("backend",))
def spmm(nbr: jax.Array, wts: jax.Array, table: jax.Array,
         backend: str = "auto") -> jax.Array:
    """Neighbor aggregation out[i] = Σ_k wts[i,k]·table[nbr[i,k]].

    Handles arbitrary (unpadded) shapes by padding to kernel block sizes.
    """
    if backend == "auto":
        backend = ("pallas" if jax.default_backend() == "tpu" else "jnp")
    if backend == "jnp":
        return spmm_ref(nbr, wts, table)

    interpret = backend != "pallas"
    rows, feat = nbr.shape[0], table.shape[1]
    nbr_p = _pad_dim(nbr, 0, 128, value=table.shape[0] - 1)
    wts_p = _pad_dim(wts, 0, 128, value=0)
    tab_p = _pad_dim(table, 1, 128, value=0)
    out = spmm_pallas(nbr_p, wts_p, tab_p, interpret=interpret)
    return out[:rows, :feat]


@jax.jit
def halo_gather(nbr: jax.Array, data: jax.Array,
                scale: jax.Array = None) -> jax.Array:
    """Gather + dequantize individual slab rows: out[..., :] =
    dequant(data[nbr[...]]).

    The non-reducing read primitive of the serving query path: GAT's
    attention needs every neighbor row individually (scores before the
    weighted sum), and the hot-row cache's miss fill wants raw rows —
    neither can ride :func:`halo_spmm`, whose contraction is fused.
    gcn/sage reductions should keep using :func:`halo_spmm` so they hit
    the resident/stream/skip selection ladder.
    """
    rows = jnp.take(data, nbr, axis=0).astype(jnp.float32)
    if scale is not None:
        rows = rows * jnp.take(scale, nbr, axis=0)
    return rows


@functools.partial(jax.jit,
                   static_argnames=("backend", "resident_max_bytes",
                                    "chunk_rows", "occupancy",
                                    "skip_occupancy_max", "gamma"))
def halo_spmm(nbr: jax.Array, wts: jax.Array, data: jax.Array,
              scale: jax.Array = None, wl_ids: jax.Array = None,
              wl_cnt: jax.Array = None, pdata: jax.Array = None,
              pscale: jax.Array = None, gamma: float = 1.0,
              backend: str = "auto",
              resident_max_bytes: int = None, chunk_rows: int = None,
              occupancy: float = None,
              skip_occupancy_max: float = None) -> jax.Array:
    """Fused halo pull+aggregate against the compact HaloExchange slab.

    out[i] = Σ_k wts[i,k] · dequant(data[nbr[i,k]]) with optional per-row
    int8 scales — the out-of-subgraph side of Eq. 5 read directly from
    storage precision (no materialized per-subgraph halo table).

    With a predictor slab (``pdata``/``pscale``, the SAT history rows in
    the data slab's exact layout; see ``repro.core.predictor``) every
    gathered row becomes the staleness-alleviated prediction
    ``dequant(data[s]) + gamma·dequant(pdata[s])`` — fused into the
    dequant epilogue of whichever kernel the ladder selects, one extra
    gather+FMA per edge rather than a second aggregation pass.  ``gamma``
    is static (jit-cache-keyed); with ``pdata=None`` the emitted program
    is exactly the predictor-free one.

    Optional occupancy-aware streaming (see module docstring for the
    selection ladder):

      wl_ids / wl_cnt: the (row_blocks, max_chunks)/(row_blocks,) chunk
        worklist from ``repro.graph.partition.build_chunk_worklist`` —
        built with the same ``chunk_rows`` and 128-row blocks.
      occupancy: the worklist's static measured occupancy
        (``ChunkWorklist.occupancy``), used for auto-selection; it is a
        host-side float (jit-cache key), never a traced value.
      chunk_rows / resident_max_bytes / skip_occupancy_max: overrides of
        the module-level streaming constants; all static (jit-cache-
        keyed), so an explicit override never aliases executables traced
        with the defaults.
    """
    if backend == "auto":
        backend = ("pallas" if jax.default_backend() == "tpu" else "jnp")
    if backend == "jnp":
        return halo_spmm_ref(nbr, wts, data, scale, pdata, pscale, gamma)

    interpret = backend not in ("pallas", "pallas_stream", "pallas_skip")
    force_stream = backend.startswith("pallas_stream")
    force_skip = backend.startswith("pallas_skip")
    has_worklist = wl_ids is not None and wl_cnt is not None
    if force_skip and not has_worklist:
        raise ValueError(f"backend={backend!r} needs the (wl_ids, wl_cnt)"
                         " chunk worklist")
    stream = force_stream or force_skip
    if not stream:
        # Auto-select: stream once the per-feature-block slab stripe
        # (data + scale column) outgrows the VMEM-resident budget.
        if resident_max_bytes is None:
            resident_max_bytes = RESIDENT_STRIPE_MAX_BYTES
        stripe = data.shape[0] * (min(BLOCK_F, data.shape[1])
                                  * data.dtype.itemsize
                                  + (4 if scale is not None else 0))
        if pdata is not None:
            # The history slab rides the same tiles — double the stripe.
            stripe += data.shape[0] * (min(BLOCK_F, pdata.shape[1])
                                       * pdata.dtype.itemsize
                                       + (4 if pscale is not None else 0))
        stream = stripe > resident_max_bytes
    skip = force_skip
    if stream and not force_stream and not force_skip and has_worklist:
        # Skip-stream when the static measured occupancy says most
        # (row_block, chunk) pairs are empty.
        if skip_occupancy_max is None:
            skip_occupancy_max = SKIP_OCCUPANCY_MAX
        skip = occupancy is not None and occupancy <= skip_occupancy_max
    if chunk_rows is None:
        chunk_rows = STREAM_CHUNK_ROWS
    rows, feat = nbr.shape[0], data.shape[1]
    nbr_p = _pad_dim(nbr, 0, 128, value=data.shape[0] - 1)
    wts_p = _pad_dim(wts, 0, 128, value=0)
    dat_p = _pad_dim(data, 1, 128, value=0)
    pdat_p = _pad_dim(pdata, 1, 128, value=0) if pdata is not None else None
    if skip:
        out = halo_spmm_skip_pallas(nbr_p, wts_p, dat_p, scale,
                                    wl_ids=wl_ids, wl_cnt=wl_cnt,
                                    pdata=pdat_p, pscale=pscale,
                                    gamma=gamma, chunk_rows=chunk_rows,
                                    interpret=interpret)
    elif stream:
        out = halo_spmm_stream_pallas(nbr_p, wts_p, dat_p, scale,
                                      pdata=pdat_p, pscale=pscale,
                                      gamma=gamma, chunk_rows=chunk_rows,
                                      interpret=interpret)
    else:
        out = halo_spmm_pallas(nbr_p, wts_p, dat_p, scale,
                               pdata=pdat_p, pscale=pscale, gamma=gamma,
                               interpret=interpret)
    return out[:rows, :feat]
