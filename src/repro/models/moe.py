"""Mixture-of-Experts FFN with TPU expert parallelism.

Two implementations, one math:

* ``moe_ref``: exact dropless reference (computes every expert for every
  token) — the oracle for tests and the smoke-test path for ≤4 experts.
* ``moe_ep``: production path. Experts are sharded over the mesh "model"
  axis; tokens are sharded over ("pod","data") and *replicated* over
  "model", so each device routes its local tokens, keeps only assignments
  targeting its resident experts (sort → fixed-capacity select → ragged_dot
  grouped matmul), and the partial outputs are summed with one psum over
  "model" — the same collective volume as a Megatron FFN all-reduce, with
  no all-to-all needed.  Capacity overflow drops tokens (capacity_factor
  controls the drop rate), matching standard TPU MoE practice.

Router load-balance aux loss follows the Switch/GShard formulation.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import _manual_axes, current_mesh


def _expert_ffn_batched(xs: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                        w_down: jax.Array) -> jax.Array:
    """Capacity-batched SwiGLU. xs: (E_loc, C_e, d); weights (E_loc, d, f).

    A dense batched einsum — MXU-shaped, exact FLOP accounting (a
    ragged_dot here is cost-modeled as dense over every local expert,
    inflating HLO FLOPs ~E_loc×)."""
    f32 = jnp.float32
    g = jnp.einsum("ecd,edf->ecf", xs, w_gate.astype(xs.dtype),
                   preferred_element_type=f32)
    u = jnp.einsum("ecd,edf->ecf", xs, w_up.astype(xs.dtype),
                   preferred_element_type=f32)
    h = (jax.nn.silu(g) * u).astype(xs.dtype)
    return jnp.einsum("ecf,efd->ecd", h, w_down.astype(xs.dtype),
                      preferred_element_type=f32)


def _route(x_flat: jax.Array, router_w: jax.Array, k: int
           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (weights (T,k) f32, ids (T,k) i32, logits (T,E) f32)."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    top_vals, top_ids = jax.lax.top_k(logits, k)
    weights = jax.nn.softmax(top_vals, axis=-1)
    return weights, top_ids.astype(jnp.int32), logits


def load_balance_loss(logits: jax.Array, ids: jax.Array,
                      num_experts: int) -> jax.Array:
    """Switch-style aux loss: E * Σ_e f_e · p_e."""
    probs = jax.nn.softmax(logits, axis=-1)              # (T, E)
    p_mean = jnp.mean(probs, axis=0)                     # (E,)
    one_hot = jax.nn.one_hot(ids[:, 0], num_experts)     # top-1 dispatch frac
    f_mean = jnp.mean(one_hot, axis=0)
    return num_experts * jnp.sum(f_mean * p_mean)


def moe_ref(x: jax.Array, params: dict, k: int) -> jax.Array:
    """Exact dropless MoE (all experts on all tokens). x: (B, S, d)."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    weights, ids, _ = _route(xf, params["router"], k)
    # (T, E, ff) for every expert — test-scale only.
    g = jnp.einsum("td,edf->tef", xf.astype(jnp.float32),
                   params["w_gate"].astype(jnp.float32))
    u = jnp.einsum("td,edf->tef", xf.astype(jnp.float32),
                   params["w_up"].astype(jnp.float32))
    h = jax.nn.silu(g) * u
    y_all = jnp.einsum("tef,efd->ted", h,
                       params["w_down"].astype(jnp.float32))   # (T, E, d)
    sel = jnp.take_along_axis(y_all, ids[..., None], axis=1)   # (T, k, d)
    out = jnp.sum(weights[..., None] * sel, axis=1)
    return out.reshape(b, s, d).astype(x.dtype)


def _moe_local(x_flat: jax.Array, router_w: jax.Array, w_gate: jax.Array,
               w_up: jax.Array, w_down: jax.Array, *, k: int,
               num_experts: int, shard_idx, num_shards: int,
               capacity_per_expert: int) -> jax.Array:
    """Per-device expert computation (shared by 1-device and EP paths).

    Sort-based capacity dispatch: assignments targeting this shard's
    resident experts are ranked by (local expert, arrival order); each
    expert processes its first C_e rows (overflow dropped — Switch-style),
    giving a static (E_loc, C_e, d) batch for the dense expert einsums.
    """
    t, d = x_flat.shape
    e_loc = num_experts // num_shards
    c_e = capacity_per_expert
    weights, ids, _ = _route(x_flat, router_w, k)

    fid = ids.reshape(-1)                                # (T*k,)
    fw = weights.reshape(-1)
    ftok = jnp.arange(t * k, dtype=jnp.int32) // k

    local_e = fid - shard_idx * e_loc
    mine = (local_e >= 0) & (local_e < e_loc)
    sort_key = jnp.where(mine, local_e, e_loc)           # invalid → tail
    order = jnp.argsort(sort_key, stable=True).astype(jnp.int32)
    counts = jnp.bincount(jnp.where(mine, local_e, e_loc),
                          length=e_loc + 1)[:e_loc]      # (E_loc,)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])

    # (E_loc, C_e) assignment indices into the flat lists (+ validity).
    ranks = jnp.arange(c_e, dtype=jnp.int32)[None, :]    # (1, C_e)
    idx_mat = starts[:, None].astype(jnp.int32) + ranks  # (E_loc, C_e)
    valid = ranks < counts[:, None]
    idx_mat = jnp.minimum(idx_mat, t * k - 1)
    sel = order[idx_mat]                                 # (E_loc, C_e)
    sel_tok = jnp.where(valid, ftok[sel], t)             # t = drop slot
    sel_w = jnp.where(valid, fw[sel], 0.0)

    x_pad = jnp.concatenate(
        [x_flat, jnp.zeros((1, d), x_flat.dtype)], axis=0)
    xs = x_pad[sel_tok]                                  # (E_loc, C_e, d)
    ys = _expert_ffn_batched(xs, w_gate, w_up, w_down)   # f32
    out = jnp.zeros((t + 1, d), jnp.float32)
    out = out.at[sel_tok.reshape(-1)].add(
        (sel_w[..., None] * ys).reshape(-1, d))
    return out[:t].astype(x_flat.dtype)


def moe_ep(x: jax.Array, params: dict, k: int, *,
           capacity_factor: float = 1.25,
           mesh: Optional[Mesh] = None,
           model_axis: str = "model",
           batch_axes: tuple = ("pod", "data")) -> jax.Array:
    """Expert-parallel MoE. x: (B, S, d) (global); params per layer:
    router (d, E), w_gate/w_up (E, d, ff), w_down (E, ff, d)."""
    b, s, d = x.shape
    num_experts = params["router"].shape[1]
    mesh = mesh if mesh is not None else current_mesh()

    if mesh is None or model_axis not in getattr(mesh, "axis_names", ()):
        # Single-device path: shard_idx 0, one shard.
        t = b * s
        c_e = max(int(capacity_factor * t * k / num_experts), 1)
        out = _moe_local(x.reshape(t, d), params["router"],
                         params["w_gate"], params["w_up"],
                         params["w_down"], k=k, num_experts=num_experts,
                         shard_idx=0, num_shards=1,
                         capacity_per_expert=c_e)
        return out.reshape(b, s, d)

    n_shards = mesh.shape[model_axis]
    if num_experts % n_shards:
        raise ValueError(f"E={num_experts} % model={n_shards}")
    manual = _manual_axes()
    baxes = tuple(a for a in batch_axes
                  if a in mesh.axis_names and a not in manual)
    n_batch = 1
    for a in baxes:
        n_batch *= mesh.shape[a]
    if b % n_batch:
        # Tiny decode batches (e.g. long_500k batch=1) cannot shard over
        # the data axes — replicate tokens instead; experts stay sharded.
        baxes = ()
        n_batch = 1
    t_loc = (b // n_batch) * s
    c_e = max(int(capacity_factor * t_loc * k / num_experts), 1)

    def local_fn(x_loc, router_w, w_gate, w_up, w_down):
        tl = x_loc.shape[0] * x_loc.shape[1]
        out = _moe_local(
            x_loc.reshape(tl, d), router_w, w_gate, w_up, w_down,
            k=k, num_experts=num_experts,
            shard_idx=jax.lax.axis_index(model_axis),
            num_shards=n_shards, capacity_per_expert=c_e)
        out = jax.lax.psum(out, model_axis)
        return out.reshape(x_loc.shape)

    pspec_x = P(baxes if len(baxes) > 1 else (baxes[0] if baxes else None),
                None, None)
    # Manualize every not-already-manual mesh axis: partial-manual
    # shard_map (e.g. only {"model"}) trips XLA SPMD-partitioner CHECKs
    # ("invalid binary instruction opcode copy") on this backend.
    fn = jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(pspec_x, P(None, None), P(model_axis, None, None),
                  P(model_axis, None, None), P(model_axis, None, None)),
        out_specs=pspec_x, check_vma=False,
        axis_names=set(mesh.axis_names) - manual)
    return fn(x, params["router"], params["w_gate"], params["w_up"],
              params["w_down"])


def moe_ffn(x: jax.Array, params: dict, k: int, *,
            impl: str = "auto", capacity_factor: float = 1.25) -> jax.Array:
    if impl == "auto":
        impl = "ep" if current_mesh() is not None else "ref"
    if impl == "ref":
        return moe_ref(x, params, k)
    return moe_ep(x, params, k, capacity_factor=capacity_factor)
