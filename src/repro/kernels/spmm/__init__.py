from repro.kernels.spmm.halo_pull import (STREAM_CHUNK_ROWS,
                                          halo_spmm_pallas,
                                          halo_spmm_skip_pallas,
                                          halo_spmm_stream_pallas)
from repro.kernels.spmm.ops import (RESIDENT_STRIPE_MAX_BYTES,
                                    SKIP_OCCUPANCY_MAX, halo_gather,
                                    halo_spmm, spmm)
from repro.kernels.spmm.ref import (halo_spmm_ref, halo_spmm_skip_ref,
                                    spmm_ref)
from repro.kernels.spmm.spmm import BLOCK_ROWS, spmm_pallas

__all__ = ["spmm", "spmm_ref", "spmm_pallas", "BLOCK_ROWS",
           "halo_gather", "halo_spmm", "halo_spmm_ref", "halo_spmm_pallas",
           "halo_spmm_skip_pallas", "halo_spmm_skip_ref",
           "halo_spmm_stream_pallas", "STREAM_CHUNK_ROWS",
           "RESIDENT_STRIPE_MAX_BYTES", "SKIP_OCCUPANCY_MAX"]
