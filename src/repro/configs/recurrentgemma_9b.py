"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attn : 2 rec.

[arXiv:2402.19427] 38L d_model=4096 16H (kv=1, MQA) d_ff=12288
vocab=256000; local attention window 2048; rnn width = d_model.
38 = 12 x (rec, rec, swa) + (rec, rec) tail.
"""
import dataclasses
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    d_ff=12288, vocab_size=256000, head_dim=256,
    pattern=("rec", "rec", "swa"), tail=("rec", "rec"),
    window=2048, rnn_dim=4096, conv_width=4,
    optimizer="adafactor", learning_rate=1.5e-4,
    source="arXiv:2402.19427",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=5, d_model=128, num_heads=4, num_kv_heads=1,
    d_ff=256, vocab_size=512, head_dim=32, window=64, rnn_dim=128,
    dtype="float32", optimizer="adamw")
