"""Shared setup for the paper-replication GNN benchmarks."""
from __future__ import annotations

import time

from repro.core import (AsyncSettings, PredictorConfig, TrainSettings,
                        digest_a_train, digest_train, prepare_graph_data)
from repro.graph import make_dataset
from repro.models.gnn import GNNConfig
from repro.optim import adam

DATASETS = ["arxiv-sim", "flickr-sim", "reddit-sim", "products-sim"]

# Mode → the framework it stands in for in the paper's tables.
MODE_LABEL = {"partition": "Partition-only", "llcg": "LLCG",
              "propagation": "DGL", "digest": "DIGEST",
              "digest_a": "DIGEST-A"}


def setup(dataset: str, model: str = "gcn", num_parts: int = 4,
          scale: float = 0.35, hidden: int = 64, seed: int = 0):
    g = make_dataset(dataset, scale=scale, seed=seed)
    data = prepare_graph_data(g, num_parts, seed=seed)
    cfg = GNNConfig(model=model, num_layers=3 if model == "gcn" else 2,
                    in_dim=g.features.shape[1], hidden_dim=hidden,
                    num_classes=int(g.labels.max()) + 1, heads=4)
    return g, data, cfg


def train_mode(cfg, data, mode: str, epochs: int, interval: int = 10,
               seed: int = 0, predictor: PredictorConfig = None):
    """Returns (history, wall_seconds, per-epoch seconds).

    ``predictor`` threads a SAT staleness-prediction config into the
    DIGEST modes (digest / digest_a); None means raw stale pulls.
    """
    predictor = predictor or PredictorConfig()
    t0 = time.perf_counter()
    if mode == "llcg":
        _, hist = digest_train(
            cfg, adam(5e-3), data,
            TrainSettings(sync_interval=interval, mode="partition",
                          llcg_correction=True),
            epochs=epochs, eval_every=max(epochs // 4, 1), seed=seed)
    elif mode == "digest_a":
        _, hist = digest_a_train(
            cfg, adam(5e-3), data,
            AsyncSettings(sync_interval=interval, predictor=predictor),
            total_rounds=epochs * data["halo_ids"].shape[0],
            eval_every_rounds=max(epochs // 2, 1), seed=seed)
    else:
        _, hist = digest_train(
            cfg, adam(5e-3), data,
            TrainSettings(sync_interval=interval, mode=mode,
                          predictor=predictor),
            epochs=epochs, eval_every=max(epochs // 4, 1), seed=seed)
    wall = time.perf_counter() - t0
    return hist, wall, wall / max(epochs, 1)
