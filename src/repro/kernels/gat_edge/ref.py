"""Pure-jnp oracle for the fused GAT edge-softmax partial."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30
LEAKY_SLOPE = 0.2


def gat_edge_partial_ref(nbr: jax.Array, valid: jax.Array,
                         s_dst: jax.Array, s_src: jax.Array,
                         z: jax.Array
                         ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Dense oracle. Shapes as in gat_edge_partial_pallas."""
    sv = jnp.take(s_src.astype(jnp.float32), nbr, axis=0)   # (rows, deg)
    e = s_dst.astype(jnp.float32)[:, None] + sv
    e = jnp.where(e >= 0, e, LEAKY_SLOPE * e)
    e = jnp.where(valid, e, NEG_INF)
    m = jnp.max(e, axis=1)                                  # (rows,)
    p = jnp.exp(e - m[:, None]) * valid                     # (rows, deg)
    l = jnp.sum(p, axis=1)
    rows = jnp.take(z.astype(jnp.float32), nbr, axis=0)     # (rows,deg,f)
    acc = jnp.einsum("rd,rdf->rf", p, rows)
    return acc, m, l


def merge_partials(parts: list[tuple[jax.Array, jax.Array, jax.Array]]
                   ) -> jax.Array:
    """Merge online-softmax partials from several edge sets (e.g. DIGEST's
    in-subgraph + stale out-of-subgraph) and normalize."""
    acc, m, l = parts[0]
    for acc2, m2, l2 in parts[1:]:
        m_new = jnp.maximum(m, m2)
        c1 = jnp.exp(m - m_new)
        c2 = jnp.exp(m2 - m_new)
        acc = c1[:, None] * acc + c2[:, None] * acc2
        l = c1 * l + c2 * l2
        m = m_new
    return acc / jnp.maximum(l, 1e-16)[:, None]
