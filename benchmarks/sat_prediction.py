"""SAT staleness-prediction regression bench (the CI bench gate).

Small-config Fig. 6 / Theorem-1 style sweep over sync intervals, raw
stale pulls vs the EMA predictor, with two tracked quantities per
interval:

  * residual staleness error: ``measure_error_and_bound`` on the
    predictor run's final state reports ε of the *predicted* rows
    alongside the uncorrected ε the same store would serve raw — the
    gate asserts ``eps_mean <= eps_raw_mean`` (valid-row mean, the
    statistic the online least-squares coefficient actually reduces;
    the single-row max rides along for reporting) at EVERY swept
    interval, i.e. prediction never makes the served halo worse;
  * accuracy: final val F1 of the raw and predictor runs, plus the
    headline claim row — the predictor at interval 2N vs raw at N.

``python -m benchmarks.sat_prediction --out BENCH_sat.json`` writes
the full report as JSON (uploaded as a CI artifact) and exits nonzero
when the gate fails; ``run()`` plugs into benchmarks.run as usual.
"""
from __future__ import annotations

import argparse
import json
import sys

from benchmarks.common import bench_scale
from benchmarks.gnn_common import setup
from repro.core import (PredictorConfig, TrainSettings, digest_train,
                        measure_error_and_bound)
from repro.optim import adam

INTERVALS = (1, 2, 5, 10)

# The gate compares one end-of-run snapshot; the learned coefficient
# needs a few pushes of evidence before it moves off 0, so a hair of
# relative slack keeps warm-up noise from failing an honest run.  A
# predictor that actually hurts blows well past 2% (the fixed-gamma
# ablation this gate retired sat at +30..+60%).
GATE_SLACK = 1.02


def sweep() -> dict:
    scale = bench_scale()
    _, data, cfg = setup("flickr-sim", scale=0.25 * scale)
    epochs = max(int(60 * scale), 24)
    report = {"dataset": "flickr-sim", "epochs": epochs,
              "intervals": [], "holds": True}
    for interval in INTERVALS:
        st_raw, hist_raw = digest_train(
            cfg, adam(5e-3), data, TrainSettings(sync_interval=interval),
            epochs=epochs, eval_every=max(epochs // 2, 1))
        st_sat, hist_sat = digest_train(
            cfg, adam(5e-3), data,
            TrainSettings(sync_interval=interval,
                          predictor=PredictorConfig(kind="ema")),
            epochs=epochs, eval_every=max(epochs // 2, 1))
        res = measure_error_and_bound(cfg, st_sat["params"], data,
                                      st_sat["store"],
                                      pstore=st_sat["pstore"])
        eps, eps_raw = max(res["eps_mean"]), max(res["eps_raw_mean"])
        holds = eps <= eps_raw * GATE_SLACK
        report["holds"] &= holds
        report["intervals"].append({
            "interval": interval,
            "f1_raw": round(hist_raw["val_f1"][-1], 4),
            "f1_sat": round(hist_sat["val_f1"][-1], 4),
            "loss_raw": round(hist_raw["loss"][-1], 6),
            "loss_sat": round(hist_sat["loss"][-1], 6),
            "eps_residual": round(eps, 6),
            "eps_raw": round(eps_raw, 6),
            "eps_residual_max": round(max(res["eps"]), 6),
            "eps_raw_max": round(max(res["eps_raw"]), 6),
            "holds": bool(holds),
        })
    # Headline claim: the predictor at 2N matches raw accuracy at N.
    by_n = {r["interval"]: r for r in report["intervals"]}
    report["claim_2x"] = [
        {"raw_N": n, "sat_N": 2 * n,
         "f1_raw": by_n[n]["f1_raw"], "f1_sat_2x": by_n[2 * n]["f1_sat"]}
        for n in INTERVALS if 2 * n in by_n]
    return report


def run() -> list[dict]:
    report = sweep()
    rows = [{"name": f"sat/N={r['interval']}", "us_per_call": "",
             **{k: v for k, v in r.items() if k != "interval"}}
            for r in report["intervals"]]
    rows.append({"name": "sat/gate", "us_per_call": "",
                 "holds": report["holds"]})
    return rows


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="BENCH_sat.json")
    args = ap.parse_args(argv)
    report = sweep()
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    for r in report["intervals"]:
        print(f"N={r['interval']}: eps_residual={r['eps_residual']} "
              f"eps_raw={r['eps_raw']} f1_raw={r['f1_raw']} "
              f"f1_sat={r['f1_sat']} holds={r['holds']}", flush=True)
    print(f"gate {'OK' if report['holds'] else 'FAILED'}: "
          f"wrote {args.out}")
    return 0 if report["holds"] else 1


if __name__ == "__main__":
    sys.exit(main())
