#!/usr/bin/env python
"""Mini-batch sampled DIGEST training with stale-store control variates.

Each step samples a seed batch plus a fanout-bounded neighborhood per
subgraph; sampled neighbors aggregate fresh, the complement reads the
stale HaloExchange store / local history as a VR-GCN control-variate
baseline — so a fanout-3 step costs a fraction of the full epoch yet its
gradient stays anchored to the full-batch one.  Compare against plain
scaled neighbor sampling at the same fanout to see the baseline working.

  PYTHONPATH=src python examples/train_sampled_gnn.py
"""
from repro.core import TrainSettings, prepare_graph_data, sampled_train
from repro.graph import build_sampler, make_dataset
from repro.models.gnn import GNNConfig
from repro.optim import adam


def main():
    g = make_dataset("flickr-sim", scale=0.3)
    data = prepare_graph_data(g, 4)
    cfg = GNNConfig(model="gcn", num_layers=3,
                    in_dim=g.features.shape[1], hidden_dim=64,
                    num_classes=int(g.labels.max()) + 1)
    sampler = build_sampler(data, fanout=3, batch_seeds=256, seed=0)
    print(f"sampler: fanout=3 (max in-degree {sampler.max_in_degree}), "
          f"256 seeds/subgraph/step")

    for estimator in ("cv", "plain"):
        settings = TrainSettings(sync_interval=5, mode="digest",
                                 sample_estimator=estimator)
        _, hist = sampled_train(cfg, adam(5e-3), data, sampler, settings,
                                steps=120, eval_every=30)
        tail = ", ".join(f"step {e}: {f1:.4f}"
                         for e, f1 in zip(hist["epoch"], hist["val_f1"]))
        print(f"[{estimator:5s}] val F1 — {tail}")


if __name__ == "__main__":
    main()
