"""Logical axis rules + an 8-device lowering test (subprocess)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (DEFAULT_RULES, spec_for_axes)
from repro.launch.mesh import make_host_mesh


def test_resolve_basic():
    mesh = make_host_mesh(1, 1)
    spec = spec_for_axes(("batch", "seq", "embed"), mesh)
    assert isinstance(spec, P)


def test_divisibility_guard():
    """56 heads on a 16-way model axis must fall back to replicated."""
    mesh = make_host_mesh(1, 1)   # 1 device, but rules logic is size-aware
    # emulate a 16-way axis by checking the resolver's math directly
    from repro.distributed.sharding import _resolve
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    spec = _resolve(("embed", "heads", "head_dim"), DEFAULT_RULES,
                    FakeMesh(), shape=(7168, 56, 128))
    assert spec[1] is None            # 56 % 16 != 0 → dropped
    assert spec[0] is None or spec[0] == "data"  # embed: no fsdp by default
    spec2 = _resolve(("embed", "heads", "head_dim"), DEFAULT_RULES,
                     FakeMesh(), shape=(7168, 64, 128))
    assert spec2[1] == "model"        # 64 % 16 == 0 → sharded


def test_no_double_axis_use():
    from repro.distributed.sharding import _resolve
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    rules = dict(DEFAULT_RULES, embed="model")
    spec = _resolve(("embed", "mlp"), rules, FakeMesh(),
                    shape=(4096, 16384))
    # "model" must be used only once across dims
    assert [s for s in spec].count("model") <= 1


SUBPROCESS_TEST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, json
    from repro.configs import get_smoke_arch
    from repro.distributed.sharding import axis_rules, shardings_for_specs
    from repro.launch.mesh import make_host_mesh
    from repro.launch.specs import (abstract_from_specs, input_specs,
                                    train_state_specs, batch_logical_axes)
    from repro.nn.params import ParamSpec
    from repro.train import TrainSettings, make_train_step
    import dataclasses

    cfg = dataclasses.replace(get_smoke_arch("qwen3-0.6b"),
                              num_heads=4, num_kv_heads=2)
    mesh = make_host_mesh(data=2, model=2, pod=2)
    settings = TrainSettings(sync_mode="digest", n_pod=2, sync_interval=5)
    step = make_train_step(cfg, settings)
    with axis_rules(mesh, {"embed": "data"}):
        ss = train_state_specs(cfg, n_pod=2, digest_pods=True)
        state_abs = abstract_from_specs(ss)
        state_sh = shardings_for_specs(ss, mesh, {"embed": "data"})
        batch_abs = {
            "tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
            "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32),
            "mask": jax.ShapeDtypeStruct((8, 16), jnp.float32)}
        batch_sh = {k: shardings_for_specs(
            ParamSpec(tuple(v.shape), ("batch", "seq"), dtype=v.dtype),
            mesh, {}) for k, v in batch_abs.items()}
        lowered = jax.jit(step, in_shardings=(state_sh, batch_sh)).lower(
            state_abs, batch_abs)
        compiled = lowered.compile()
        from repro.launch.dryrun import cost_properties
        cost = cost_properties(compiled)
        print(json.dumps({"ok": True, "flops": cost.get("flops", 0)}))
""")


def test_multi_device_lowering_subprocess():
    """Real 8-device (2 pod x 2 data x 2 model) lowering of the DIGEST
    train step — proves shardings are coherent end to end."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    out = subprocess.run([sys.executable, "-c", SUBPROCESS_TEST], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["ok"] and payload["flops"] > 0
