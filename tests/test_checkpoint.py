"""Checkpoint save/restore."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


def test_roundtrip(tmp_path):
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "layers": [jnp.ones((2,)), jnp.zeros((3,))]},
            "step": jnp.asarray(7)}
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 7
    np.testing.assert_allclose(restored["params"]["w"],
                               tree["params"]["w"])
    np.testing.assert_allclose(restored["params"]["layers"][0],
                               tree["params"]["layers"][0])


def test_latest_of_many(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 5, 3):
        save_checkpoint(str(tmp_path), s, tree)
    assert latest_step(str(tmp_path)) == 5


def test_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"x": jnp.zeros((3,))})


def test_missing_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "nope"), {"x": jnp.zeros(1)})


def test_truncated_npz_falls_back_to_previous(tmp_path):
    """Regression: a torn write of the NEWEST payload (crash mid-save,
    bit rot) must not take resume down — ``latest_step`` skips it and
    returns the previous *valid* checkpoint, while ``verify_checkpoint``
    reports the corruption as a typed error."""
    import os

    from repro.checkpoint import CheckpointCorruptError, verify_checkpoint

    tree = {"x": jnp.arange(4096.0)}
    save_checkpoint(str(tmp_path), 3, tree)
    save_checkpoint(str(tmp_path), 6, tree)
    npz = tmp_path / "ckpt_00000006.npz"
    with open(npz, "r+b") as f:
        f.truncate(os.path.getsize(npz) // 2)

    with pytest.raises(CheckpointCorruptError):
        verify_checkpoint(str(tmp_path), 6)
    assert latest_step(str(tmp_path)) == 3
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 3
    np.testing.assert_array_equal(restored["x"], tree["x"])


def test_checksum_mismatch_detected(tmp_path):
    """Payload bytes that load fine but don't match the manifest's
    CRC32s (e.g. the wrong file restored from backup) are rejected."""
    import shutil

    from repro.checkpoint import CheckpointCorruptError, verify_checkpoint

    save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros((8,))})
    save_checkpoint(str(tmp_path), 2, {"x": jnp.ones((8,))})
    # Same key set, different contents: only the checksums can tell.
    shutil.copy(tmp_path / "ckpt_00000001.npz", tmp_path / "ckpt_00000002.npz")
    with pytest.raises(CheckpointCorruptError, match="CRC32"):
        verify_checkpoint(str(tmp_path), 2)
    assert latest_step(str(tmp_path)) == 1


def test_corrupt_manifest_and_partial_writes_skipped(tmp_path):
    import os

    from repro.checkpoint import CheckpointCorruptError, read_manifest

    tree = {"x": jnp.zeros((4,))}
    save_checkpoint(str(tmp_path), 1, tree)
    # Manifest garbage → typed error, not a JSON traceback.
    save_checkpoint(str(tmp_path), 4, tree)
    (tmp_path / "ckpt_00000004.json").write_text("{not json")
    with pytest.raises(CheckpointCorruptError):
        read_manifest(str(tmp_path), 4)
    # Manifest published but npz missing (crash between the replaces).
    save_checkpoint(str(tmp_path), 5, tree)
    os.unlink(tmp_path / "ckpt_00000005.npz")
    # npz without a manifest (manifest deleted / pre-manifest layout).
    save_checkpoint(str(tmp_path), 6, tree)
    os.unlink(tmp_path / "ckpt_00000006.json")
    assert latest_step(str(tmp_path)) == 1
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 1


def test_bf16_store_roundtrip(tmp_path):
    """bfloat16 leaves (ml_dtypes extension type) survive npz via the f32
    widening path and restore back to bf16 losslessly."""
    from repro.core import halo_exchange as hx

    store = hx.init_store(1, 4, 8, hx.HaloPrecision("bf16"))
    store = hx.push(store, jnp.asarray([[0, 2]]), jnp.ones((1, 2), bool),
                    jnp.asarray(np.random.default_rng(0).normal(
                        size=(1, 1, 2, 8)).astype(np.float32)))
    save_checkpoint(str(tmp_path), 1, {"store": store})
    restored, _ = restore_checkpoint(str(tmp_path), {"store": store})
    assert restored["store"]["data"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        restored["store"]["data"].astype(np.float32),
        np.asarray(store["data"]).astype(np.float32))


def test_compact_halo_store_roundtrip(tmp_path):
    """The quantized HaloExchange store serializes losslessly (int8 data +
    fp32 scales keep their dtypes), with the precision in the manifest."""
    from repro.checkpoint import read_manifest
    from repro.core import halo_exchange as hx

    store = hx.init_store(2, 9, 8, hx.HaloPrecision("int8"))
    slots = jnp.asarray([[0, 4, 8]])
    valid = jnp.asarray([[True, True, False]])
    reps = jnp.asarray(np.random.default_rng(0).normal(
        size=(1, 2, 3, 8)).astype(np.float32))
    store = hx.push(store, slots, valid, reps)
    state = {"store": store, "step": jnp.asarray(5)}

    save_checkpoint(str(tmp_path), 5, state, meta={"halo_storage": "int8"})
    restored, step = restore_checkpoint(str(tmp_path), state)
    assert step == 5
    assert restored["store"]["data"].dtype == np.int8
    np.testing.assert_array_equal(restored["store"]["data"],
                                  np.asarray(store["data"]))
    np.testing.assert_array_equal(restored["store"]["scale"],
                                  np.asarray(store["scale"]))
    assert read_manifest(str(tmp_path), 5)["meta"]["halo_storage"] == "int8"
