"""The paper's own experiment config: distributed GCN under DIGEST.

Mirrors §5.1: Adam, METIS-style partitioning, M=8 subgraphs (8 GPUs),
sync interval N=10 (the paper's best on OGB-Products, Fig. 6).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class GNNExperiment:
    dataset: str = "products-sim"
    model: str = "gcn"
    num_layers: int = 3
    hidden_dim: int = 128
    num_parts: int = 8
    partitioner: str = "greedy"
    sync_interval: int = 10
    learning_rate: float = 5e-3
    epochs: int = 200
    heads: int = 1


CONFIG = GNNExperiment()
SMOKE = dataclasses.replace(CONFIG, dataset="flickr-sim", hidden_dim=32,
                            num_parts=4, epochs=20)
