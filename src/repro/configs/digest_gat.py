"""The paper's GAT experiment config (§5, Table 1 right half)."""
import dataclasses

from repro.configs.digest_gcn import GNNExperiment

CONFIG = GNNExperiment(model="gat", heads=4, hidden_dim=128,
                       learning_rate=5e-3)
SMOKE = dataclasses.replace(CONFIG, dataset="flickr-sim", hidden_dim=32,
                            num_parts=4, epochs=20)
