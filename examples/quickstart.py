#!/usr/bin/env python
"""Quickstart: DIGEST vs the two baseline framework families on a small
synthetic graph — reproduces the paper's core claim in ~a minute on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import TrainSettings, digest_train, prepare_graph_data
from repro.graph import make_dataset
from repro.models.gnn import GNNConfig
from repro.optim import adam


def main():
    g = make_dataset("flickr-sim", scale=0.3)
    print(f"graph: {g.num_nodes} nodes, {g.num_edges} edges")
    data = prepare_graph_data(g, num_parts=4)
    cfg = GNNConfig(model="gcn", num_layers=3,
                    in_dim=g.features.shape[1], hidden_dim=64,
                    num_classes=int(g.labels.max()) + 1)
    print(f"{'mode':14s} {'loss':>8s} {'val F1':>8s} {'test F1':>8s}")
    for mode in ("partition", "propagation", "digest"):
        _, hist = digest_train(cfg, adam(5e-3), data,
                               TrainSettings(sync_interval=5, mode=mode),
                               epochs=80, eval_every=80)
        print(f"{mode:14s} {hist['loss'][-1]:8.4f} "
              f"{hist['val_f1'][-1]:8.4f} {hist['test_f1'][-1]:8.4f}")
    print("\nExpected: digest ≈ propagation (no info loss), both > "
          "partition; digest communicates ~N× less than propagation.")


if __name__ == "__main__":
    main()
