"""Fig. 9 (appendix): out-of-subgraph / in-subgraph node ratio — the
memory overhead of buffering halo representations."""
from benchmarks.common import bench_scale, emit
from repro.graph import build_partitions, make_dataset


def run() -> list[dict]:
    scale = bench_scale()
    rows = []
    for ds in ("arxiv-sim", "flickr-sim", "reddit-sim", "products-sim"):
        g = make_dataset(ds, scale=0.25 * scale)
        sp = build_partitions(g, 4)
        ratio = sp.halo_ratio()
        rows.append({"name": f"fig9/{ds}",
                     "us_per_call": "",
                     "halo_ratio_mean": round(float(ratio.mean()), 4),
                     "halo_ratio_max": round(float(ratio.max()), 4),
                     "avg_degree": round(g.num_edges / g.num_nodes, 2)})
    return rows


if __name__ == "__main__":
    emit(run())
