"""HaloExchange — DIGEST's stale-representation KVS, owner-sharded and
precision-aware.

This subsystem implements the PUSH/PULL lines of Algorithm 1 over a
**compact, owner-sharded** slab that holds only *boundary* nodes — rows
that appear in at least one subgraph's halo — instead of the dense
``(L-1, N+1, hidden)`` array the seed used.

Owner-sharded layout (see ``repro.graph.partition.build_partitions``):
the slot space is M contiguous shards of ``shard_rows`` rows, shard m
holding exactly the boundary rows *owned* (pushed) by part m, with the
last row of every shard a per-owner zero sentinel.  Sharded slot-wise
over the mesh "data" axis, device m therefore stores ``1/M`` of the slab
and every PUSH scatter is shard-local.  Mapping to the paper:

  * Algorithm 1 line 9–10 (``PUSH h_v^(ℓ) for v ∈ V_m``)  →  :func:`push`
    (SPMD scatter; the partitioner routes every row of part m into shard
    m, so writes never cross devices) or :func:`shard_push` (the explicit
    ``shard_map`` form with owner-local offsets).  Non-boundary local
    rows are dropped via the owner's sentinel row — no other subgraph
    ever reads them (this is what shrinks the store from O(N·L·d) to
    O(|boundary|·L·d), the Fig. 9 memory term).
  * Algorithm 1 line 5 (``PULL h̃_u^(ℓ) for u ∈ halo(G_m)``)  →
    :func:`pull_slab` (dense-gather form: under pjit XLA lowers it to an
    all-gather of the shards — the fallback) or :func:`collective_pull`
    (the ragged ``shard_map`` form: an ``all_to_all`` that ships only the
    slots each subgraph's halo actually references, per the
    :class:`~repro.graph.partition.PullPlan`).  Both return a
    **device-local** per-subgraph slab ``(M, L-1, H+1, hidden)`` in
    storage precision — non-pull epochs read this local slice through the
    fused pull+aggregate kernel :func:`repro.kernels.spmm.halo_spmm`, so
    nothing replicated and no ``(M, L-1, H, hidden)`` fp32 cache is ever
    materialized.
  * §3.3 communication terms  →  :meth:`HaloSpec.comm_bytes`: the ragged
    pull ships ``Σ_m |halo(G_m)| · (L-1) · row_bytes`` per sync versus
    ``(M-1) · store_nbytes`` for the replicated snapshot
    (:meth:`HaloSpec.replicated_pull_nbytes`); pushes ship
    ``Σ_m |boundary ∩ V_m| · (L-1) · row_bytes``.
  * Theorem 1's per-layer staleness ε^(ℓ)  →  :func:`staleness_error`,
    measured over the rows actually served to other subgraphs.

Precision (:class:`HaloPrecision`) is pluggable and applies to both the
slab layout (storage) and the §3.3 wire format:

  ======  ==================================  ==========================
  mode    row encoding                        bytes / hidden value
  ======  ==================================  ==========================
  fp32    float32                             4
  bf16    bfloat16                            2
  int8    int8 + one float32 scale per row    1 (+ 4 / hidden amortized)
  ======  ==================================  ==========================

int8 uses symmetric per-row quantization: ``scale = max|row| / 127``,
``q = round(row / scale)``; the absolute dequantization error is bounded
by ``scale / 2 = max|row| / 254`` per element.  With
``HaloPrecision(error_feedback=True)`` the pusher accumulates the per-row
rounding residual (:func:`push_ef`), so repeated pushes of slowly-moving
representations stay unbiased at the same wire cost (Bai et al. 2023).

Second role: control-variate history for sampled training
----------------------------------------------------------

The same store serves the mini-batch regime
(:func:`repro.core.digest.make_sampled_epoch_fn`) as VR-GCN-style
**variance-reduction history** (arXiv 1710.10568): a sampled step
aggregates its fanout-bounded in-batch neighbors *fresh* and lets the
out-of-batch complement read *historical* activations, so the estimate
is ``agg(hist, all nbrs) + agg(scale·(fresh − hist), sampled)`` — the
history term is a control variate, not a dropped edge.  Store contract
per sampled step:

  * **Reads.**  Out-of-subgraph (halo) neighbors read the pulled slab —
    the SAME per-subgraph cache, refreshed by the unchanged PULL at the
    ``sync_interval`` cadence, in storage precision through the same
    ``halo_spmm`` path.  In-subgraph out-of-batch neighbors read the
    device-local fp32 history ``state["hist"]`` (each part's own rows
    from the previous step — never exchanged, never quantized).
  * **Writes.**  The step computes every local row's representation
    anyway (padded SPMD), so it refreshes ``state["hist"]`` wholesale
    every step and runs the unchanged PUSH (boundary rows into the
    owner shard) on the Algorithm-1 schedule.
  * **Communication.**  Byte-identical to the full-batch epoch — the
    pull/push helpers are shared, so the compiled census (zero
    all-gathers, one ragged all_to_all per store tensor) is a pinned
    regression property (tests/test_sampling.py).

``sync_interval`` therefore controls ONLY the halo side's staleness:
local history is at most one step stale, halo history up to
``sync_interval`` steps — exactly the Theorem-1 ε tradeoff, now also
dialing the control variate's residual variance.  When ``fanout >= max
in-degree`` the residual weights are exactly +0.0 and the estimator
collapses bitwise to the full-batch aggregation, whatever the store or
history holds.

Occupancy worklist (the chunk-skipping streamed read path)
----------------------------------------------------------

Non-pull epochs read the pulled per-subgraph slabs through the streamed
``halo_spmm`` kernels, whose DMA schedule can consult a **static
(row-block × chunk) worklist** computed once at partition time
(:func:`repro.graph.partition.build_chunk_worklist` /
``StackedPartitions.chunk_worklist``).  Format — CSR padded to a static
width so it jits as two dense int32 arrays riding in the struct dict
next to the out-ELL they were computed from:

  ``wl_ids`` (M, n_row_blocks, max_chunks_per_block)
      ascending slab-chunk ids row block i of subgraph m must visit;
      entries past the valid prefix REPEAT the last visited chunk (0 for
      empty blocks) so padded grid steps re-address the chunk already in
      VMEM instead of DMA-ing a new one.
  ``wl_cnt`` (M, n_row_blocks)
      valid prefix length; the kernel masks grid steps ``t >= cnt`` out
      of the accumulation, which keeps the skip stream **bitwise equal**
      to the dense stream (skipped chunks contribute exact ±0.0 terms).

Geometry is bound to the kernel tiling: 128-row output blocks
(``kernels.spmm.BLOCK_ROWS``) over the padded S rows, ``chunk_rows``-row
chunks over the (H+1)-row slab — rebuild the worklist when either
changes.  The owner-sharded slot layout is what makes this pay: each
subgraph's halo references cluster in a few owner shards, so measured
occupancy (``ChunkWorklist.occupancy``, the static kernel-selection
signal threaded through ``GNNConfig.halo_occupancy``) sits far below 1
and streamed bytes scale with occupied work, not slab size.

Slab layout under ``build_partitions(order=...)``: every slab is laid
out as contiguous owner runs (the slab-side mirror of the owner-sharded
store), but the row order *within* each owner run is the partitioner's
choice — ascending global id at ``order="none"``, first-referencing
local row at ``order="rcm"`` (so an RCM-ordered row block's references
land in adjacent slab chunks).  Nothing in this module depends on the
within-run order: the :class:`PullPlan` send offsets / recv positions,
``halo_slots`` and the worklist are all derived from the same
``halo_ids`` table after the re-lay, pushes scatter by owner-local slot
(store layout is order-independent), and the per-row ELL edge order is
untouched — so pulled rows, pushed stores and aggregation outputs are
bitwise identical across orders (tests/test_order_invariance.py).

Multi-pod two-stage routing (the ("pod", "data") mesh)
------------------------------------------------------

The collective paths auto-detect the mesh shape
(:func:`exchange_axes`): on a single-pod mesh M is sharded over the
"data" axis alone and a pull is one ragged ``all_to_all``; on the
production multi-pod mesh (axes ``("pod", "data", "model")``) M is
sharded over the **combined** ``("pod", "data")`` axes — device
``(p, d)`` owns the ``k = M/(pods·data)`` shards of combined block
``e = p·data + d`` — and the exchange runs in **two stages**, mirroring
how DistDGL-style systems split inter-machine from intra-machine
traffic:

  1. *intra-pod*: one ragged ``all_to_all`` over "data", routing every
     (owner, requester) block by the requester's **data coordinate**
     d_r within the owner's pod — after this hop, device ``(p, d)``
     holds every block its pod owns that is destined for data-column d
     of *any* pod;
  2. *inter-pod*: ``pods − 1`` shifted ``ppermute`` rounds over "pod"
     (a **single collective-permute per store tensor** on the 2-pod
     production mesh), routing by the requester's pod coordinate p_r —
     only this stage rides the slow inter-pod links, and it ships each
     row exactly once.

No routing table changes: the :class:`~repro.graph.partition.PullPlan`
is the same (M_owner, M_req, K) pair of tables — send offsets owner-
local, recv positions requester-local — and the two-stage kernel merely
*re-blocks* the requester axis as ``(d_r, p_r, b)`` for stage 1 and
``(p_r, d_o, b)`` for stage 2 (b the requester-local shard index,
d_o the owner's data coordinate).  Flattening the owner axis back as
``(p_o, d_o, a)`` reproduces the exact single-axis ordering, which is
why multi-pod pulls/pushes are **bitwise equal** to the single-pod
collective and the dense-gather fallback (gathers, transposes and
scatters only — regression-pinned in tests/test_multipod.py).  Pushes
and the Theorem-1 staleness probe stay owner-local on any mesh shape:
they only need the combined block index ``e``, never a collective.

A store is a plain pytree (dict) so it drops into jitted state, pjit
shardings and npz checkpoints unchanged:

    {"data": (L-1, R, hidden) <storage dtype>}        fp32 / bf16
    {"data": int8 ..., "scale": (L-1, R, 1) float32}  int8

where ``R = M · shard_rows``.  Sentinel rows (one per shard; the global
sentinel is the last row of the last shard) are re-zeroed after every
push, so pulls of padded halo slots are exactly zero.

Read-path / refresh contract (serving)
--------------------------------------

``repro.core.serving`` builds an online query engine on this module, so
the store API doubles as a serving contract:

  * **Reads are layout-pure.**  :func:`collective_pull` /
    :func:`pull_slab` / :func:`layer_table` depend only on the pytree
    shapes above — any leading layer count works (serving uses a
    single-layer all-node slab whose ``shard_rows`` is the padded part
    size + 1), and ``owner = slot // shard_rows`` is the one invariant
    routing relies on.  Extra pytree keys (serving adds an int32
    ``"version"`` scalar) must be stripped before calling in
    (``serving.store_bare``): the exchange paths iterate exactly
    {"data"[, "scale"]}, and :func:`precision_of` keys off ``"scale"``.
  * **Writes go through push, and every refresh is a version bump.**
    :func:`push` / :func:`shard_push` are total-row overwrites of the
    pushed slots (quantize + scatter + sentinel re-zero) — there is no
    partial-row state, so a reader that observed slot s either sees the
    old row or the new row, never a blend.  Serving relies on this plus
    one rule of its own: any refresh that could change a served value
    (new representations OR new top-layer weights) must bump the store
    version, because the hot-row cache invalidates by version equality,
    never by scanning rows.
  * **Donation is safe.**  Push scatters are in-place updates of the
    store operand, so jitting a refresh with ``donate_argnums`` on the
    store reuses its buffers — a serving deployment holds one
    store-sized allocation across refreshes (``serving.make_refresh_fn``).
  * **Reads degrade, never fail.**  A missed push (dropped, corrupted-
    and-rejected, or a crashed owner) leaves last-known-good rows in
    place — see the degraded-pull contract on :func:`pull`; serving's
    analogue is ``serving.refresh_or_degrade`` (a failed refresh keeps
    the old version serving, cache intact, counted in
    ``degraded_refreshes``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

PRECISIONS = ("fp32", "bf16", "int8")

_DTYPES = {"fp32": jnp.float32, "bf16": jnp.bfloat16, "int8": jnp.int8}
_VALUE_BYTES = {"fp32": 4, "bf16": 2, "int8": 1}


@dataclasses.dataclass(frozen=True)
class HaloPrecision:
    """Wire/storage precision of the halo slab (one knob for both)."""

    storage: str = "fp32"          # fp32 | bf16 | int8
    # Accumulate the per-row quantization residual at the pusher
    # (push_ef) so repeated pushes stay unbiased.  Only meaningful for
    # lossy storage (int8 / bf16); a no-op for fp32.
    error_feedback: bool = False

    def __post_init__(self):
        if self.storage not in PRECISIONS:
            raise ValueError(f"storage {self.storage!r} not in {PRECISIONS}")

    @property
    def dtype(self):
        return _DTYPES[self.storage]

    @property
    def has_scale(self) -> bool:
        return self.storage == "int8"

    def row_bytes(self, hidden: int) -> int:
        """Bytes to store/ship one node-layer row of width ``hidden``."""
        extra = 4 if self.has_scale else 0       # one fp32 scale per row
        return hidden * _VALUE_BYTES[self.storage] + extra


@dataclasses.dataclass(frozen=True)
class HaloSpec:
    """Static shape/precision metadata of a compact store (accounting)."""

    num_hidden_layers: int          # L-1
    num_slots: int                  # |boundary| (excl. sentinels/padding)
    hidden: int
    precision: HaloPrecision = HaloPrecision()
    # Owner-sharded layout: R = store_rows slab rows over num_shards
    # devices.  Defaults describe the unsharded (single-sentinel) layout.
    store_rows: Optional[int] = None
    num_shards: int = 1

    @classmethod
    def from_partitions(cls, sp, hidden: int, num_layers: int,
                        precision: HaloPrecision = HaloPrecision()
                        ) -> "HaloSpec":
        return cls(num_hidden_layers=max(num_layers - 1, 1),
                   num_slots=sp.num_boundary, hidden=hidden,
                   precision=precision, store_rows=sp.store_rows,
                   num_shards=sp.num_parts)

    def init(self) -> dict:
        rows = (self.store_rows if self.store_rows is not None
                else self.num_slots + 1)
        return init_store(self.num_hidden_layers, rows - 1,
                          self.hidden, self.precision)

    # -- §3.3 / Fig. 9 accounting ------------------------------------------
    def store_nbytes(self) -> int:
        """Total HBM bytes of the slab (incl. sentinel/padding rows)."""
        rows = (self.store_rows if self.store_rows is not None
                else self.num_slots + 1)
        return (self.num_hidden_layers * rows
                * self.precision.row_bytes(self.hidden))

    def shard_nbytes(self) -> int:
        """Per-device resident bytes under the owner-sharded layout."""
        return self.store_nbytes() // self.num_shards

    def dense_nbytes(self, num_nodes: int) -> int:
        """What the seed's dense fp32 ``(L-1, N+1, hidden)`` store costs."""
        return self.num_hidden_layers * (num_nodes + 1) * self.hidden * 4

    def replicated_pull_nbytes(self) -> int:
        """Wire bytes per sync to replicate the compact slab on every
        device — the PR-1 snapshot layout's all-gather: each of the M
        devices receives the other M-1 shards of the *unpadded*
        (|boundary|+1)-row slab (per-owner shard padding is a storage
        artifact of this layout, not bytes the replicated baseline
        shipped)."""
        return ((self.num_shards - 1) * self.num_hidden_layers
                * (self.num_slots + 1)
                * self.precision.row_bytes(self.hidden))

    def comm_bytes(self, pull_rows: int, push_rows: int) -> dict:
        """Per-sync §3.3 byte counts under the configured wire precision.

        pull_rows: Σ_m |halo(G_m)| — rows gathered by all subgraphs (the
          *information-theoretic* pull cost; the implemented dense
          all_to_all pads per-pair lists to a common width — see
          :meth:`collective_pull_nbytes` for what actually hits the wire).
        push_rows: Σ_m |boundary ∩ V_m| — rows scattered by all subgraphs.
        """
        rb = self.precision.row_bytes(self.hidden)
        pull = int(pull_rows) * self.num_hidden_layers * rb
        push = int(push_rows) * self.num_hidden_layers * rb
        return {"pull_bytes": pull, "push_bytes": push,
                "total_bytes": pull + push}

    def collective_pull_nbytes(self, plan_max_rows: int) -> int:
        """Actual wire bytes of one :func:`collective_pull` sync: the
        all_to_all pads every (owner, requester) pair to the plan's max
        width K, shipping M·M·K rows.  Close to the ragged ideal
        (``comm_bytes``'s pull term) for balanced partitions; a skewed
        pair inflates it — compare both before choosing pull_mode."""
        return (self.num_shards * self.num_shards * int(plan_max_rows)
                * self.num_hidden_layers
                * self.precision.row_bytes(self.hidden))


def precision_of(store: dict) -> HaloPrecision:
    if "scale" in store:
        return HaloPrecision("int8")
    if store["data"].dtype == jnp.bfloat16:
        return HaloPrecision("bf16")
    return HaloPrecision("fp32")


# ---------------------------------------------------------------------------
# Quantization
# ---------------------------------------------------------------------------

def quantize_rows(x: jax.Array, precision: HaloPrecision
                  ) -> tuple[jax.Array, Optional[jax.Array]]:
    """Encode fp32 rows (..., hidden) into (data, scale-or-None)."""
    if precision.storage == "fp32":
        return x.astype(jnp.float32), None
    if precision.storage == "bf16":
        return x.astype(jnp.bfloat16), None
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_rows(data: jax.Array, scale: Optional[jax.Array]
                    ) -> jax.Array:
    out = data.astype(jnp.float32)
    return out if scale is None else out * scale


# ---------------------------------------------------------------------------
# The KVS operations (compact-slot indexed)
# ---------------------------------------------------------------------------

def init_store(num_hidden_layers: int, num_slots: int, hidden: int,
               precision: HaloPrecision = HaloPrecision()) -> dict:
    """Zero slab; (L-1, num_slots+1, hidden).  For the owner-sharded
    layout pass ``num_slots = store_rows - 1`` (sentinel rows included)."""
    store = {"data": jnp.zeros((num_hidden_layers, num_slots + 1, hidden),
                               precision.dtype)}
    if precision.has_scale:
        store["scale"] = jnp.ones((num_hidden_layers, num_slots + 1, 1),
                                  jnp.float32)
    return store


def init_slab(num_parts: int, num_hidden_layers: int, halo_size: int,
              hidden: int, precision: HaloPrecision = HaloPrecision()
              ) -> dict:
    """Zero per-subgraph halo slab — the device-local pull target:
    {"data": (M, L-1, H+1, hidden)} with the zero sentinel row at H."""
    slab = {"data": jnp.zeros(
        (num_parts, num_hidden_layers, halo_size + 1, hidden),
        precision.dtype)}
    if precision.has_scale:
        slab["scale"] = jnp.ones(
            (num_parts, num_hidden_layers, halo_size + 1, 1), jnp.float32)
    return slab


def layer_table(store: dict, ell: int
                ) -> tuple[jax.Array, Optional[jax.Array]]:
    """(data, scale) slab of hidden layer ``ell`` — feeds the fused kernel.

    Works on both the full store (L-1, R, hidden) and one subgraph's
    pulled slab (L-1, H+1, hidden)."""
    return store["data"][ell], (store["scale"][ell] if "scale" in store
                                else None)


def pull(store: dict, slots: jax.Array) -> jax.Array:
    """Gather + dequantize stale halo tables (Algorithm 1 line 5).

    slots: (M, H) compact slot ids (sentinel rows at padding).
    Returns (M, L-1, H, hidden) float32.

    Degraded-pull contract (fault tolerance): a pull NEVER fails — it
    returns whatever rows the store currently holds.  Because pushes
    are total-row overwrites and a dropped/rejected/crashed push simply
    writes nothing (masked rows route to the owner's sentinel slot),
    the rows a faulted owner failed to refresh are its
    *last-known-good* representations, not zeros or torn blends.  Under
    the paper's Theorems 1/3 that degradation is just additional
    staleness; the engines keep it measured (never silent) through the
    per-slot/per-shard ``last_push_*`` age tables
    (:mod:`repro.core.faults`) and bound it with the ``max_staleness``
    watchdog's forced resync.
    """
    out = store["data"][:, slots, :].astype(jnp.float32)   # (L-1, M, H, h)
    if "scale" in store:
        out = out * store["scale"][:, slots, :]
    return jnp.swapaxes(out, 0, 1)


def pull_slab(store: dict, halo_slots: jax.Array) -> dict:
    """Collective PULL, dense-gather form (Algorithm 1 line 5).

    Gathers each subgraph's halo rows into a **device-local** slab in
    storage precision: {"data": (M, L-1, H+1, hidden)[, "scale"]}, slab
    row H the zero sentinel (``out_nbr`` padding).  Under pjit with the
    store sharded slot-wise and the result sharded over "data", XLA
    lowers the gather to an all-gather of the shards — the dense fallback
    of :func:`collective_pull`; on one device it is a plain gather.
    """
    data = jnp.swapaxes(store["data"][:, halo_slots, :], 0, 1)
    out = {"data": jnp.pad(data, ((0, 0), (0, 0), (0, 1), (0, 0)))}
    if "scale" in store:
        sc = jnp.swapaxes(store["scale"][:, halo_slots, :], 0, 1)
        out["scale"] = jnp.pad(sc, ((0, 0), (0, 0), (0, 1), (0, 0)),
                               constant_values=1.0)
    return out


def exchange_axes(mesh, axis: str = "data") -> tuple:
    """Mesh axes the halo exchange shards M over — the auto-detection
    behind ``pull_mode="collective"``.

    Single-pod meshes exchange over ``(axis,)``; a mesh carrying a
    "pod" axis exchanges over the combined ``("pod", axis)`` — device
    ``(p, d)`` then owns combined block ``e = p·mesh[axis] + d`` and
    pulls run the two-stage intra-pod/inter-pod exchange (see the
    module docstring's routing-table section).
    """
    return ("pod", axis) if "pod" in mesh.axis_names else (axis,)


def exchange_size(mesh, axis: str = "data") -> int:
    """Total devices along the exchange axes (pods · data)."""
    num = 1
    for a in exchange_axes(mesh, axis):
        num *= int(mesh.shape[a])
    return num


def _combined_index(mesh, axis: str = "data"):
    """Traced combined block index e = p·data + d of the calling device
    (inside ``shard_map``); plain data index on single-pod meshes."""
    e = jax.lax.axis_index(axis)
    if "pod" in mesh.axis_names:
        e = e + jax.lax.axis_index("pod") * int(mesh.shape[axis])
    return e


def shards_per_device(num_parts: int, mesh, axis: str = "data",
                      what: str = "collective halo exchange") -> int:
    """k = num_parts / (pods · mesh[axis]) — owner shards per device.

    Mesh-facing form of the single authoritative divisibility check,
    :func:`repro.graph.partition.parts_per_device` (see there for why a
    non-multiple M must be rejected loudly).  Counts every exchange
    axis, so the multi-pod mesh needs M to be a multiple of pods·data.
    """
    from repro.graph.partition import parts_per_device

    return parts_per_device(num_parts, exchange_size(mesh, axis), what)


def collective_pull(store: dict, send_offsets: jax.Array,
                    recv_positions: jax.Array, halo_size: int,
                    mesh, axis: str = "data") -> dict:
    """Ragged collective PULL: ship only the referenced slots.

    The ``shard_map`` form of :func:`pull_slab` for a store sharded
    slot-wise over ``axis``: every device owns ``k = M / mesh[axis]``
    contiguous owner shards (k = 1 is the classic one-part-per-device
    case; k > 1 is the M-exceeds-pod-size regime) and gathers from each
    of them the rows every requester's halo references (per the
    :class:`~repro.graph.partition.PullPlan`); a single ``all_to_all``
    routes them.  Per-pair lists are padded to the plan's max width K,
    so the wire carries ``M·M·K`` rows
    (:meth:`HaloSpec.collective_pull_nbytes`) — ≈ ``Σ_m |halo(G_m)|``
    for balanced partitions, vs the ``(M-1)·(B+1)`` rows of replicating
    the slab.

    Args:
      send_offsets:   (M, M, K) PullPlan.send_offsets.
      recv_positions: (M, M, K) PullPlan.recv_positions.
      halo_size: H — per-subgraph halo slots (slab gets H+1 rows).
    Returns the same pytree as :func:`pull_slab`.
    Raises ValueError when M is not a multiple of the exchange axes
    (pods · data on a multi-pod mesh).
    """
    from jax.experimental.shard_map import shard_map

    axes = exchange_axes(mesh, axis)
    num_data = int(mesh.shape[axis])
    pods = int(mesh.shape["pod"]) if len(axes) == 2 else 1
    M, _, K = send_offsets.shape
    k = shards_per_device(M, mesh, axis, "collective_pull")
    l1, rows_total, hidden = store["data"].shape
    shard_rows = rows_total // M
    has_scale = "scale" in store

    def _pod_permute(g1):
        # g1 (p_r, d_o, b, a, K, l1, w): blocks my pod owns, keyed by
        # destination pod p_r.  Route them with pods-1 shifted ppermute
        # rounds over "pod" (ONE collective-permute per tensor on the
        # 2-pod production mesh) into (p_o, d_o, ...): blocks every pod
        # p_o owns that are destined for me.  Only this hop crosses the
        # inter-pod links, and each row ships exactly once.
        my = jax.lax.axis_index("pod")
        out = jax.lax.dynamic_update_index_in_dim(
            jnp.zeros_like(g1),
            jax.lax.dynamic_index_in_dim(g1, my, 0, keepdims=False),
            my, 0)
        for s in range(1, pods):
            dst = jax.lax.rem(my + s, pods)
            send = jax.lax.dynamic_index_in_dim(g1, dst, 0,
                                                keepdims=False)
            perm = [(i, (i + s) % pods) for i in range(pods)]
            rcv = jax.lax.ppermute(send, "pod", perm)
            src = jax.lax.rem(my - s + pods, pods)
            out = jax.lax.dynamic_update_index_in_dim(out, rcv, src, 0)
        return out

    def _exchange(table, send, recv, width, pad_value):
        # table (l1, k·shard_rows, width) — this device's k owner shards,
        # shard a at rows [a·shard_rows, (a+1)·shard_rows).
        # send (k, M, K): owner-local offsets for the k local owners;
        # recv (k, M, K): slab positions for the k local requesters.
        base = (jnp.arange(k, dtype=send.dtype)
                * shard_rows)[:, None, None]
        rows = table[:, (send + base).reshape(-1), :]      # (l1, k·M·K, w)
        # Flattened order is (owner-local a, requester m = e·k + b, K)
        # with the requester's combined block e = p_r·num_data + d_r.
        rows = rows.reshape(l1, k, pods, num_data, k, K, width)
        # Stage 1 (intra-pod): route by the requester's data coordinate.
        buf = jnp.transpose(rows, (3, 2, 4, 1, 5, 0, 6))
        got = jax.lax.all_to_all(buf, axis, 0, 0, tiled=True)
        # got[d_o, p_r, b, a] = rows data-peer d_o of my pod ships toward
        # (pod p_r, my data column), requester-local b, its local shard a.
        got = jnp.swapaxes(got, 0, 1)                  # (p_r, d_o, b, a, …)
        if pods > 1:
            # Stage 2 (inter-pod): route by the requester's pod.
            got = _pod_permute(got)
        # got[p_o, d_o, b, a] = rows device (p_o, d_o) ships from its
        # local shard a to my local requester b — owner part
        # j = (p_o·num_data + d_o)·k + a, matching the (M, K) flattened
        # order of recv[b].
        vals = jnp.transpose(got, (2, 0, 1, 3, 4, 5, 6))
        vals = vals.reshape(k, M * K, l1, width)
        vals = jnp.moveaxis(vals, 1, 2)                    # (k, l1, M·K, w)
        slab = jnp.full((l1, halo_size + 1, width), pad_value, table.dtype)
        # Duplicate positions only occur at the sentinel row H, where
        # every routed value is an owner-sentinel zero row.
        return jax.vmap(
            lambda pos, v: slab.at[:, pos, :].set(v))(
                recv.reshape(k, M * K), vals)              # (k, l1, H+1, w)

    shard = P(None, axes, None)
    plan = P(axes, None, None)
    slab_spec = P(axes, None, None, None)

    if has_scale:
        def _body(data, scale, send, recv):
            return {"data": _exchange(data, send, recv, hidden, 0),
                    "scale": _exchange(scale, send, recv, 1, 1.0)}
        fn = shard_map(_body, mesh=mesh,
                       in_specs=(shard, shard, plan, plan),
                       out_specs={"data": slab_spec, "scale": slab_spec})
        return fn(store["data"], store["scale"], send_offsets,
                  recv_positions)

    def _body(data, send, recv):
        return {"data": _exchange(data, send, recv, hidden, 0)}
    fn = shard_map(_body, mesh=mesh, in_specs=(shard, plan, plan),
                   out_specs={"data": slab_spec})
    return fn(store["data"], send_offsets, recv_positions)


def push(store: dict, local_slots: jax.Array, local_valid: jax.Array,
         reps: jax.Array, sentinels: Optional[jax.Array] = None) -> dict:
    """Quantize + scatter fresh local boundary rows (Algorithm 1 lines 9–10).

    local_slots: (M, S) compact slot ids — part m's *own* sentinel row for
      non-boundary local nodes (the partitioner routes them there so every
      write stays inside the owner shard).
    local_valid: (M, S) bool; reps: (M, L-1, S, hidden) fp32.
    sentinels: (M,) per-part sentinel slots (re-zeroed after the scatter);
      defaults to the single last row for the unsharded layout.
    """
    data = store["data"]
    l1, rows, hidden = data.shape
    if sentinels is None:
        sentinels = jnp.asarray([rows - 1], jnp.int32)
    sentinels = jnp.asarray(sentinels, jnp.int32).reshape(-1)
    m, s = local_slots.shape
    per_part = sentinels if sentinels.size == m else sentinels[:1]
    fallback = jnp.broadcast_to(per_part.reshape(-1, 1), (m, s))
    ids = jnp.where(local_valid, local_slots, fallback).reshape(-1)
    vals = jnp.where(local_valid[:, None, :, None], reps, 0.0)
    q, scale = quantize_rows(vals, precision_of(store))
    q = jnp.swapaxes(q, 0, 1).reshape(l1, m * s, hidden)
    new = {"data": data.at[:, ids, :].set(q).at[:, sentinels, :].set(0)}
    if scale is not None:
        scale = jnp.swapaxes(scale, 0, 1).reshape(l1, m * s, 1)
        new["scale"] = (store["scale"].at[:, ids, :].set(scale)
                        .at[:, sentinels, :].set(1.0))
    return new


def _ef_residual(compensated: jax.Array, valid_mask: jax.Array,
                 precision: HaloPrecision) -> jax.Array:
    """New rounding residual of an error-feedback push: what the wire
    format lost of the (masked) compensated rows.  Invalid rows are 0 →
    residual 0.  Shared by every *_push_ef variant so the EF algebra
    (the telescoping invariant pinned in tests/test_halo_properties.py)
    lives in exactly one place."""
    masked = jnp.where(valid_mask, compensated, 0.0)
    q, scale = quantize_rows(masked, precision)
    return masked - dequantize_rows(q, scale)


def push_ef(store: dict, local_slots: jax.Array, local_valid: jax.Array,
            reps: jax.Array, residual: jax.Array,
            sentinels: Optional[jax.Array] = None) -> tuple[dict, jax.Array]:
    """Error-feedback PUSH: quantize ``reps + residual`` and carry the new
    rounding residual forward at the pusher (Bai et al. 2023 style).

    Deterministic round-to-nearest biases repeated pushes of
    slowly-moving representations; compensating each push with the
    previous rounding error keeps the time-averaged served value unbiased
    at the same wire cost.  ``residual`` has the shape of ``reps``;
    returns (new_store, new_residual).
    """
    compensated = reps + residual
    new_store = push(store, local_slots, local_valid, compensated,
                     sentinels)
    # Same masked tensor push() quantizes internally, so XLA CSEs the two
    # quantize passes under jit.
    return new_store, _ef_residual(compensated,
                                   local_valid[:, None, :, None],
                                   precision_of(store))


def shard_push(store: dict, local_slots: jax.Array, local_valid: jax.Array,
               reps: jax.Array, shard_rows: int, mesh,
               axis: str = "data") -> dict:
    """Explicit shard-local PUSH under ``shard_map``: each device scatters
    the rows of its ``k = M / mesh[axis]`` resident parts with owner-local
    offsets into its own k shards — structurally incapable of writing
    another device's slots.  :func:`push` is the SPMD fallback (same
    math, the partitioner already routes every row into the owner shard,
    but XLA cannot *prove* it and may materialize cross-device traffic).
    Works on single- and multi-pod meshes alike — the scatter is device-
    local on any mesh shape, only the combined block index e = p·data + d
    changes.  Raises ValueError when M is not a multiple of the
    exchange axes."""
    from jax.experimental.shard_map import shard_map

    axes = exchange_axes(mesh, axis)
    M = local_slots.shape[0]
    k = shards_per_device(M, mesh, axis, "shard_push")
    prec = precision_of(store)
    has_scale = "scale" in store

    def _scatter(data, scale, slots, valid, reps_blk):
        # data (l1, k·shard_rows, hid) — this device's k shards; slots /
        # valid (k, S); reps_blk (k, l1, S, hid).  Local part a (global
        # part j = e·k + a) owns rows [a·shard_rows, (a+1)·shard_rows);
        # its slots all lie inside shard j by construction.
        e = _combined_index(mesh, axis)
        sent_local = (jnp.arange(k, dtype=jnp.int32) + 1) * shard_rows - 1
        off = jnp.where(valid, slots - e * (k * shard_rows),
                        sent_local[:, None])               # (k, S)
        vals = jnp.where(valid[:, None, :, None], reps_blk, 0.0)
        q, sc = quantize_rows(vals, prec)
        l1 = data.shape[0]
        qs = jnp.moveaxis(q, 1, 0).reshape(l1, -1, q.shape[-1])
        new = {"data": data.at[:, off.reshape(-1), :].set(qs)
               .at[:, sent_local, :].set(0)}
        if sc is not None:
            scs = jnp.moveaxis(sc, 1, 0).reshape(l1, -1, 1)
            new["scale"] = (scale.at[:, off.reshape(-1), :].set(scs)
                            .at[:, sent_local, :].set(1.0))
        return new

    shard = P(None, axes, None)
    m_spec = P(axes, None)
    reps_spec = P(axes, None, None, None)

    if has_scale:
        fn = shard_map(_scatter, mesh=mesh,
                       in_specs=(shard, shard, m_spec, m_spec, reps_spec),
                       out_specs={"data": shard, "scale": shard})
        return fn(store["data"], store["scale"], local_slots, local_valid,
                  reps)

    def _body(data, slots, valid, reps_blk):
        return _scatter(data, None, slots, valid, reps_blk)

    fn = shard_map(_body, mesh=mesh,
                   in_specs=(shard, m_spec, m_spec, reps_spec),
                   out_specs={"data": shard})
    return fn(store["data"], local_slots, local_valid, reps)


def shard_push_ef(store: dict, local_slots: jax.Array,
                  local_valid: jax.Array, reps: jax.Array,
                  residual: jax.Array, shard_rows: int, mesh,
                  axis: str = "data") -> tuple[dict, jax.Array]:
    """Error-feedback form of :func:`shard_push` (see :func:`push_ef`).

    The scatter goes through the shard-local path; the residual update is
    elementwise over the (M, ...)-sharded ``reps``/``residual`` and needs
    no communication at all.  (The quantize here cannot be CSE'd against
    the one inside the shard_map body, so push epochs pay it twice —
    push epochs are 1-in-N and the pass is elementwise, cheap next to
    the epoch's matmuls.)"""
    compensated = reps + residual
    new_store = shard_push(store, local_slots, local_valid, compensated,
                           shard_rows, mesh, axis)
    return new_store, _ef_residual(compensated,
                                   local_valid[:, None, :, None],
                                   precision_of(store))


def owner_push(store: dict, owner: jax.Array, local_slots: jax.Array,
               local_valid: jax.Array, reps: jax.Array,
               shard_rows: int) -> dict:
    """Single-part PUSH that only ever touches the owner's shard.

    The DIGEST-A worker form of :func:`shard_push`: slice shard ``owner``
    out of the slab, scatter with owner-local offsets, write the shard
    back — a ``dynamic_update_slice`` of exactly ``shard_rows`` rows, so
    the write region is provably inside the owner's shard (no whole-slab
    scatter for the partitioner to reason about).  Addresses the slab by
    owner *part*, never by device, so it is independent of how the M
    shards are laid over mesh axes — the same worker push works whether
    the store is placed on one device, a "data" axis, or the combined
    multi-pod ("pod", "data") axes.

    local_slots: (S,) global store slots of this worker's local rows
      (its own sentinel at non-boundary rows); local_valid: (S,) bool;
    reps: (L-1, S, hidden) fp32.
    """
    data = store["data"]
    l1, _, hidden = data.shape
    start = jnp.asarray(owner, jnp.int32) * shard_rows
    off = jnp.where(local_valid, local_slots - start, shard_rows - 1)
    vals = jnp.where(local_valid[None, :, None], reps, 0.0)
    q, sc = quantize_rows(vals, precision_of(store))
    shard = jax.lax.dynamic_slice(data, (0, start, 0),
                                  (l1, shard_rows, hidden))
    shard = shard.at[:, off, :].set(q).at[:, -1, :].set(0)
    new = {"data": jax.lax.dynamic_update_slice(data, shard,
                                                (0, start, 0))}
    if sc is not None:
        sshard = jax.lax.dynamic_slice(store["scale"], (0, start, 0),
                                       (l1, shard_rows, 1))
        sshard = sshard.at[:, off, :].set(sc).at[:, -1, :].set(1.0)
        new["scale"] = jax.lax.dynamic_update_slice(
            store["scale"], sshard, (0, start, 0))
    return new


def owner_push_ef(store: dict, owner: jax.Array, local_slots: jax.Array,
                  local_valid: jax.Array, reps: jax.Array,
                  residual: jax.Array, shard_rows: int
                  ) -> tuple[dict, jax.Array]:
    """Error-feedback form of :func:`owner_push` (see :func:`push_ef`)."""
    compensated = reps + residual
    new_store = owner_push(store, owner, local_slots, local_valid,
                           compensated, shard_rows)
    return new_store, _ef_residual(compensated,
                                   local_valid[None, :, None],
                                   precision_of(store))


def shard_staleness_error(store: dict, fresh: jax.Array,
                          local_slots: jax.Array, served: jax.Array,
                          shard_rows: int, mesh, axis: str = "data"
                          ) -> jax.Array:
    """:func:`staleness_error` with owner-local reads under ``shard_map``.

    The SPMD form gathers ``store[:, local_slots, :]`` with the slot axis
    sharded — every part only ever reads its *own* shard, but XLA cannot
    prove it and materializes an all-gather of the whole slab each epoch.
    Here each device reads the rows of its k resident parts straight out
    of its local shards; only the final (L-1,)-sized max crosses devices.
    Same numbers as :func:`staleness_error` (max is order-free; the
    gathers do no arithmetic).  Mesh-shape agnostic like
    :func:`shard_push`: reads stay inside the device's own shards on
    single- and multi-pod meshes (combined block index e = p·data + d).
    """
    from jax.experimental.shard_map import shard_map

    axes = exchange_axes(mesh, axis)
    M, S = local_slots.shape
    k = shards_per_device(M, mesh, axis, "shard_staleness_error")
    has_scale = "scale" in store
    l1 = store["data"].shape[0]

    def _body(data, scale, fresh_blk, slots, served_blk):
        # data (l1, k·shard_rows, h); fresh_blk (k, l1, S, h); slots /
        # served_blk (k, S).  Every slot of a resident part lies inside
        # this device's block (non-boundary rows hit the owner sentinel).
        e = _combined_index(mesh, axis)
        off = (slots - e * (k * shard_rows)).reshape(-1)
        stale = data[:, off, :].astype(jnp.float32)        # (l1, k·S, h)
        if scale is not None:
            stale = stale * scale[:, off, :]
        stale = jnp.moveaxis(stale.reshape(l1, k, S, -1), 1, 0)
        diff = jnp.linalg.norm(fresh_blk - stale, axis=-1)  # (k, l1, S)
        diff = jnp.where(served_blk[:, None, :], diff, 0.0)
        return jnp.max(diff, axis=(0, 2))[None]            # (1, l1)

    shard = P(None, axes, None)
    m_spec = P(axes, None)
    reps_spec = P(axes, None, None, None)
    out_spec = P(axes, None)

    if has_scale:
        fn = shard_map(_body, mesh=mesh,
                       in_specs=(shard, shard, reps_spec, m_spec, m_spec),
                       out_specs=out_spec)
        per_dev = fn(store["data"], store["scale"], fresh, local_slots,
                     served)
    else:
        def _nb(data, fresh_blk, slots, served_blk):
            return _body(data, None, fresh_blk, slots, served_blk)
        fn = shard_map(_nb, mesh=mesh,
                       in_specs=(shard, reps_spec, m_spec, m_spec),
                       out_specs=out_spec)
        per_dev = fn(store["data"], fresh, local_slots, served)
    # (num_devices, L-1) sharded partial maxima → tiny all-reduce.
    return jnp.max(per_dev, axis=0)


def staleness_error(store: dict, fresh: jax.Array, local_slots: jax.Array,
                    served: jax.Array) -> jax.Array:
    """ε^(ℓ) = max_v ‖h_v^(ℓ) − h̃_v^(ℓ)‖₂ over *served* (boundary) rows.

    fresh: (M, L-1, S, hidden) this epoch's representations.
    served: (M, S) bool — valid local rows present in the compact store
      (``StackedPartitions.local_boundary``): exactly the rows whose
      staleness other subgraphs can observe (Theorem 1 only involves
      pulled halo rows).
    Returns (L-1,) per-hidden-layer max error.
    """
    stale = pull(store, local_slots)                   # (M, L-1, S, h)
    diff = jnp.linalg.norm(fresh - stale, axis=-1)     # (M, L-1, S)
    diff = jnp.where(served[:, None, :], diff, 0.0)
    return jnp.max(diff, axis=(0, 2))
