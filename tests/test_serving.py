"""Online embedding-serving path: parity, cache semantics, collectives.

The load-bearing invariants:

  * served logits == the offline ``full_graph_forward`` on a frozen
    store — **bitwise** for gcn/sage (the query engine computes the same
    fused ELL sum over the same fp32 rows), ≤ 1e-6 for gat (attention
    softmax reassociation);
  * no stale cache hit survives a store refresh — the version bump
    invalidates every cached row at once;
  * the compiled SPMD query contains **zero all-gathers** — out-of-shard
    rows move only through the serving PullPlan's ragged all_to_all
    (one per store tensor, so two for int8's data+scale);
  * ServeConfig is a static jit-cache key: a new config retraces, a
    reused one never does.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import hlo_utils
from repro.core import serving
from repro.core.digest import (full_graph_forward, prepare_graph_data,
                               top_layer_reps)
from repro.graph import make_dataset
from repro.launch.serving_driver import ServeStats, run_serve_loop
from repro.models.gnn import GNNConfig, gnn_specs
from repro.nn import init_params

pytestmark = pytest.mark.leg("serving-smoke")


@functools.lru_cache(maxsize=None)
def _setup(parts: int = 4):
    g = make_dataset("flickr-sim", scale=0.1, seed=2)
    data = prepare_graph_data(g, parts, seed=0)
    plan = serving.build_serve_plan(data)
    return g, data, plan


@functools.lru_cache(maxsize=None)
def _model(model: str, parts: int = 4, key: int = 0):
    g, data, plan = _setup(parts)
    cfg = GNNConfig(model=model, num_layers=2, in_dim=g.features.shape[1],
                    hidden_dim=32, num_classes=int(g.labels.max()) + 1)
    params = init_params(jax.random.PRNGKey(key), gnn_specs(cfg))
    return cfg, params


def _fresh_store(plan, cfg, params, data,
                 precision=None) -> dict:
    store = serving.init_serve_store(
        plan, cfg.hidden_dim,
        precision or serving.ServeConfig().precision)
    refresh = serving.make_refresh_fn()
    return refresh(store, top_layer_reps(cfg, params, data),
                   plan.refresh_data())


def _serve_all(cfg, scfg, params, store, cache, qdata, num_nodes):
    """Serve every node id in batches; returns (stacked logits, cache)."""
    outs = []
    b = scfg.batch_size
    for lo in range(0, num_nodes, b):
        q = np.full(b, num_nodes, np.int32)
        ids = np.arange(lo, min(lo + b, num_nodes), dtype=np.int32)
        q[:len(ids)] = ids
        logits, cache = serving.serve_query(cfg, scfg, params, store,
                                            cache, qdata, jnp.asarray(q))
        outs.append(np.asarray(logits)[:len(ids)])
    return np.concatenate(outs), cache


# ---------------------------------------------------------------------------
# Parity vs the offline full-graph forward
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", ["gcn", "sage"])
def test_served_logits_bitwise(model):
    g, data, plan = _setup()
    cfg, params = _model(model)
    ref = np.asarray(full_graph_forward(cfg, params, data)[0])[:g.num_nodes]
    store = _fresh_store(plan, cfg, params, data)
    scfg = serving.ServeConfig(batch_size=64, cache_rows=128)
    cache = serving.init_cache(scfg, cfg.num_classes)
    served, cache = _serve_all(cfg, scfg, params, store, cache,
                               plan.query_data(), g.num_nodes)
    np.testing.assert_array_equal(served, ref)
    # Second sweep: hits serve the memoized row — still bitwise.
    served2, cache = _serve_all(cfg, scfg, params, store, cache,
                                plan.query_data(), g.num_nodes)
    np.testing.assert_array_equal(served2, ref)
    assert int(cache["hits"]) > 0


def test_served_logits_gat_tolerance():
    g, data, plan = _setup()
    cfg, params = _model("gat")
    ref = np.asarray(full_graph_forward(cfg, params, data)[0])[:g.num_nodes]
    store = _fresh_store(plan, cfg, params, data)
    scfg = serving.ServeConfig(batch_size=64)
    cache = serving.init_cache(scfg, cfg.num_classes)
    served, _ = _serve_all(cfg, scfg, params, store, cache,
                           plan.query_data(), g.num_nodes)
    assert np.abs(served - ref).max() <= 1e-6


def test_padding_queries_excluded_from_counters():
    g, data, plan = _setup()
    cfg, params = _model("gcn")
    store = _fresh_store(plan, cfg, params, data)
    scfg = serving.ServeConfig(batch_size=32, cache_rows=128)
    cache = serving.init_cache(scfg, cfg.num_classes)
    q = np.full(32, g.num_nodes, np.int32)   # all padding
    q[:5] = np.arange(5)
    _, cache = serving.serve_query(cfg, scfg, params, store, cache,
                                   plan.query_data(), jnp.asarray(q))
    assert int(cache["hits"]) + int(cache["misses"]) == 5


# ---------------------------------------------------------------------------
# Hot-row cache semantics
# ---------------------------------------------------------------------------

def test_cache_counters_and_full_hit_second_pass():
    g, data, plan = _setup()
    cfg, params = _model("gcn")
    store = _fresh_store(plan, cfg, params, data)
    b = 32
    # sets == batch and distinct lines per query -> every miss fills.
    scfg = serving.ServeConfig(batch_size=b, cache_rows=4 * b)
    cache = serving.init_cache(scfg, cfg.num_classes)
    slots = np.asarray(plan.serve_map[:g.num_nodes])
    lines = {}
    ids = [i for i in range(g.num_nodes)
           if lines.setdefault(slots[i] % scfg.cache_sets, i) == i][:b]
    q = jnp.asarray(np.asarray(ids, np.int32))
    out1, cache = serving.serve_query(cfg, scfg, params, store, cache,
                                      plan.query_data(), q)
    assert (int(cache["hits"]), int(cache["misses"])) == (0, b)
    out2, cache = serving.serve_query(cfg, scfg, params, store, cache,
                                      plan.query_data(), q)
    assert (int(cache["hits"]), int(cache["misses"])) == (b, b)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert serving.hit_rate(cache) == pytest.approx(0.5)


def test_no_stale_hit_survives_refresh():
    g, data, plan = _setup()
    cfg, params = _model("gcn")
    _, params2 = _model("gcn", key=7)
    scfg = serving.ServeConfig(batch_size=64, cache_rows=512)
    store = _fresh_store(plan, cfg, params, data)
    refresh = serving.make_refresh_fn()
    qdata, rdata = plan.query_data(), plan.refresh_data()
    cache = serving.init_cache(scfg, cfg.num_classes)
    # Warm the cache hard on the old weights.
    for _ in range(3):
        _, cache = _serve_all(cfg, scfg, params, store, cache, qdata,
                              g.num_nodes)
    assert int(cache["hits"]) > 0
    # Deploy: new reps, one refresh, one version bump.
    store = refresh(store, top_layer_reps(cfg, params2, data), rdata)
    hits_before = int(cache["hits"])
    served, cache = _serve_all(cfg, scfg, params2, store, cache, qdata,
                               g.num_nodes)
    # Every row the warm cache held is invalid: zero post-refresh hits...
    assert int(cache["hits"]) == hits_before
    # ...and the served logits are the NEW model's, bitwise.
    ref2 = np.asarray(full_graph_forward(cfg, params2, data)[0])
    np.testing.assert_array_equal(served, ref2[:g.num_nodes])


def test_cache_disabled_still_counts_misses():
    g, data, plan = _setup()
    cfg, params = _model("gcn")
    store = _fresh_store(plan, cfg, params, data)
    scfg = serving.ServeConfig(batch_size=64, cache_rows=0)
    cache = serving.init_cache(scfg, cfg.num_classes)
    served, cache = _serve_all(cfg, scfg, params, store, cache,
                               plan.query_data(), g.num_nodes)
    assert int(cache["hits"]) == 0
    assert int(cache["misses"]) == g.num_nodes
    ref = np.asarray(full_graph_forward(cfg, params, data)[0])
    np.testing.assert_array_equal(served, ref[:g.num_nodes])


def test_refresh_bumps_version_every_time():
    g, data, plan = _setup()
    cfg, params = _model("gcn")
    store = serving.init_serve_store(plan, cfg.hidden_dim)
    refresh = serving.make_refresh_fn()
    reps = top_layer_reps(cfg, params, data)
    assert int(store["version"]) == 0
    store = refresh(store, reps, plan.refresh_data())
    store = refresh(store, reps, plan.refresh_data())
    assert int(store["version"]) == 2


def test_refresh_failure_keeps_old_version_serving():
    """Degraded mode: a refresh that raises mid-deployment (here: a
    trainer handing over wrong-width reps) must leave the OLD store
    version serving bitwise-identical logits, with every hot-row cache
    entry still valid — the version scalar never bumped, so the
    version-compare cache keeps hitting — and the failure counted in
    ``degraded_refreshes``."""
    g, data, plan = _setup()
    cfg, params = _model("gcn")
    _, params2 = _model("gcn", key=7)
    scfg = serving.ServeConfig(batch_size=64, cache_rows=512)
    store = _fresh_store(plan, cfg, params, data)
    # donate=False: a failed deployment must not have consumed the old
    # store's buffers (see refresh_or_degrade's docstring).
    refresh = serving.make_refresh_fn(donate=False)
    qdata, rdata = plan.query_data(), plan.refresh_data()
    cache = serving.init_cache(scfg, cfg.num_classes)
    ref, cache = _serve_all(cfg, scfg, params, store, cache, qdata,
                            g.num_nodes)
    version = int(store["version"])

    bad_reps = top_layer_reps(cfg, params2, data)[:, :-1]  # wrong width
    store, stats = serving.refresh_or_degrade(refresh, store, bad_reps,
                                              rdata)
    assert stats["degraded_refreshes"] == 1 and stats["refreshes"] == 0
    assert int(store["version"]) == version  # never bumped

    # Old version keeps serving, bitwise, and the warm cache still hits
    # (no invalidation happened).
    hits_before = int(cache["hits"])
    served, cache = _serve_all(cfg, scfg, params, store, cache, qdata,
                               g.num_nodes)
    np.testing.assert_array_equal(served, ref)
    assert int(cache["hits"]) > hits_before

    # The next good deployment goes through and is counted normally.
    good = top_layer_reps(cfg, params2, data)
    store, stats = serving.refresh_or_degrade(refresh, store, good, rdata,
                                              stats)
    assert stats == {"refreshes": 1, "degraded_refreshes": 1}
    assert int(store["version"]) == version + 1
    served2, _ = _serve_all(cfg, scfg, params2, store, cache, qdata,
                            g.num_nodes)
    ref2 = np.asarray(full_graph_forward(cfg, params2, data)[0])
    np.testing.assert_array_equal(served2, ref2[:g.num_nodes])


# ---------------------------------------------------------------------------
# Jit-cache keying (static ServeConfig)
# ---------------------------------------------------------------------------

def test_serve_config_is_static_jit_key():
    g, data, plan = _setup()
    cfg, params = _model("gcn")
    store = _fresh_store(plan, cfg, params, data)
    qdata = plan.query_data()

    def run(scfg):
        cache = serving.init_cache(scfg, cfg.num_classes)
        q = jnp.zeros((scfg.batch_size,), jnp.int32)
        serving.serve_query(cfg, scfg, params, store, cache, qdata, q)

    run(serving.ServeConfig(batch_size=16, cache_rows=64))
    n0 = serving.serve_query._cache_size()
    # Same knobs, fresh (equal) config object: no retrace.
    run(serving.ServeConfig(batch_size=16, cache_rows=64))
    assert serving.serve_query._cache_size() == n0
    # Any knob change is a new executable — sweeps can't alias traces.
    run(serving.ServeConfig(batch_size=16, cache_rows=128))
    assert serving.serve_query._cache_size() == n0 + 1
    run(serving.ServeConfig(batch_size=16, cache_rows=128, cache_ways=8))
    assert serving.serve_query._cache_size() == n0 + 2


def test_batch_size_is_contract_not_bound():
    g, data, plan = _setup()
    cfg, params = _model("gcn")
    store = _fresh_store(plan, cfg, params, data)
    scfg = serving.ServeConfig(batch_size=16)
    cache = serving.init_cache(scfg, cfg.num_classes)
    with pytest.raises(ValueError, match="batch"):
        serving.serve_query(cfg, scfg, params, store, cache,
                            plan.query_data(), jnp.zeros((8,), jnp.int32))


def test_serve_config_validation():
    with pytest.raises(ValueError):
        serving.ServeConfig(cache_rows=6, cache_ways=4)
    with pytest.raises(ValueError):
        serving.ServeConfig(storage="fp64")


# ---------------------------------------------------------------------------
# Plan invariants
# ---------------------------------------------------------------------------

def test_serve_plan_layout():
    g, data, plan = _setup()
    sp = data["_sp"]
    n = g.num_nodes
    # Every node gets exactly one slot, owned by its assigned part.
    slots = plan.serve_map[:n]
    assert len(np.unique(slots)) == n
    np.testing.assert_array_equal(slots // plan.serve_rows,
                                  np.asarray(sp.assign))
    # Sentinels: global id n -> last row; per-shard sentinel rows are
    # never a node's slot.
    assert plan.serve_map[n] == plan.store_rows - 1
    assert not np.isin(plan.sentinel_slots, slots).any()
    assert plan.nbr.shape[0] == n + 1
    assert (plan.nbr[n] == n).all() and (plan.wts[n] == 0).all()


# ---------------------------------------------------------------------------
# Serving-loop driver
# ---------------------------------------------------------------------------

def test_run_serve_loop_stats():
    def step(carry, item):
        return carry + item, item * 2

    carry, outs, stats = run_serve_loop(step, [1, 2, 3, 4], carry=0,
                                        warmup=1, items_per_call=8)
    assert carry == 10 and outs == [2, 4, 6, 8]
    assert len(stats.latencies_s) == 4 and len(stats.steady) == 3
    assert stats.p50_ms <= stats.p99_ms
    assert stats.per_sec > 0
    summary = stats.summary()
    assert summary["items_per_call"] == 8 and summary["calls"] == 4


def test_serve_stats_warmup_clamped():
    stats = ServeStats([0.5], warmup=5)
    assert stats.steady == [0.5]          # never empty


def test_zipf_queries_shape_and_skew():
    q1 = serving.zipf_queries(1000, 64, 10, skew=1.1, seed=3)
    q1b = serving.zipf_queries(1000, 64, 10, skew=1.1, seed=3)
    np.testing.assert_array_equal(q1, q1b)
    assert q1.shape == (10, 64) and q1.min() >= 0 and q1.max() < 1000
    q2 = serving.zipf_queries(1000, 64, 10, skew=1.8, seed=3)
    # Heavier skew concentrates more of the stream on the head.
    assert (q2 < 10).mean() > (q1 < 10).mean()
    hot = np.arange(1000)[::-1].astype(np.int32)
    q3 = serving.zipf_queries(1000, 64, 10, skew=1.8, seed=3, hot_ids=hot)
    assert (q3 >= 990).mean() == (q2 < 10).mean()


# ---------------------------------------------------------------------------
# Multi-device: collective census + SPMD parity + sharded refresh
# ---------------------------------------------------------------------------

def _multi_device_checks():
    from repro.launch.mesh import make_host_mesh

    assert jax.device_count() >= 8, jax.device_count()
    g, data, plan = _setup(parts=8)
    mesh = make_host_mesh(data=8)
    sdata = plan.sharded_data(data)
    M, S = plan.local_ids.shape

    for model, storage, n_tensors in (("gcn", "fp32", 1),
                                      ("sage", "int8", 2)):
        cfg, params = _model(model, parts=8)
        scfg = serving.ServeConfig(batch_size=16, storage=storage)
        store = serving.init_serve_store(plan, cfg.hidden_dim,
                                         scfg.precision)
        reps = top_layer_reps(cfg, params, data)
        # Sharded refresh (shard-local scatter) == the SPMD fallback.
        store_sh, sdata_sh, q_sh = serving.serve_shardings(store, sdata,
                                                           mesh)
        sharded = serving.make_refresh_fn(mesh, plan.serve_rows,
                                          donate=False)(
            jax.device_put(store, store_sh), reps, plan.refresh_data())
        store = serving.make_refresh_fn(donate=False)(
            store, reps, plan.refresh_data())
        for k in store:
            np.testing.assert_array_equal(np.asarray(sharded[k]),
                                          np.asarray(store[k]))

        store_p = jax.device_put(store, store_sh)
        sdata_p = jax.tree.map(jax.device_put, sdata, sdata_sh)
        q_rows = np.full((M, scfg.batch_size), S, np.int32)
        for m in range(M):
            v = np.where(plan.local_valid[m])[0][:scfg.batch_size]
            q_rows[m, :len(v)] = v
        qp = jax.device_put(jnp.asarray(q_rows), q_sh)

        hlo = serving.serve_query_sharded.lower(
            cfg, scfg, mesh, plan.halo_size, params, store_p, sdata_p,
            qp).compile().as_text()
        counts = hlo_utils.collective_counts(hlo)
        # The whole query program moves cross-shard rows through exactly
        # the ragged serving pull — one all_to_all per store tensor.
        assert counts["all-gather"] == 0, counts
        assert counts["reduce-scatter"] == 0, counts
        assert counts["collective-permute"] == 0, counts
        assert counts["all-to-all"] == n_tensors, counts
        census = hlo_utils.collective_axis_census(hlo, mesh)
        assert set(census.get("all-to-all", {})) == {("data",)}, census

        out = np.asarray(serving.serve_query_sharded(
            cfg, scfg, mesh, plan.halo_size, params, store_p, sdata_p,
            qp))
        ref = np.asarray(full_graph_forward(cfg, params, data)[0])
        tol = 2e-6 if storage == "fp32" else 5e-3
        for m in range(M):
            v = np.where(plan.local_valid[m])[0][:scfg.batch_size]
            gids = plan.local_ids[m][v]
            assert np.abs(out[m, :len(v)] - ref[gids]).max() <= tol


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (CI serving-smoke job)")
def test_serving_multidevice_inprocess():
    _multi_device_checks()


def test_serving_multidevice_subprocess():
    """Force an 8-device CPU platform in a subprocess so the serving
    collective census runs even on single-device hosts."""
    if jax.device_count() >= 8:
        pytest.skip("covered by the in-process variant")
    hlo_utils.run_forced_device_subprocess(__file__, "SERVING_OK")


if __name__ == "__main__":
    _multi_device_checks()
    print("SERVING_OK")
