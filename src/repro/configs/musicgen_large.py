"""musicgen-large [audio] — decoder-only over EnCodec tokens.

[arXiv:2306.05284] 48L d_model=2048 32H (kv=32, MHA) d_ff=8192 vocab=2048.
The EnCodec conv codec is a stub: input_specs supplies token ids in the
2048-entry codebook directly (one stream; the 4-codebook delay pattern is
modality-frontend logic). RoPE replaces sinusoidal embeddings (noted
deviation — positional scheme, not capacity).
"""
import dataclasses
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=2048, head_dim=64,
    pattern=("attn",), rope_theta=10000.0,
    optimizer="adamw", learning_rate=3e-4,
    source="arXiv:2306.05284",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=256, head_dim=32, dtype="float32")
