"""Decoder-only transformer family covering the 10 assigned architectures.

One config dataclass + one block registry expresses dense (llama/phi/qwen/
minitron/deepseek), MoE (scout, kimi), hybrid (recurrentgemma), SSM (xlstm),
VLM (llama-3.2-vision) and audio (musicgen) backbones:

  block kinds: "attn"   GQA self-attention + SwiGLU MLP
               "swa"    sliding-window attention + MLP
               "moe"    GQA self-attention + expert-parallel MoE FFN
               "rec"    RG-LRU recurrent block + MLP (Griffin)
               "mlstm"  xLSTM matrix-memory block (internal expansion)
               "slstm"  xLSTM scalar-memory block (sequential)
               "xattn"  cross-attention to vision patch embeddings + MLP

The layer stack is ``pattern × repeats + tail`` and the repeated part runs
under ``lax.scan`` with stacked parameters (one HLO body for 61-layer
models — essential for dry-run compile times), with optional remat.

Decode paths: ``decode_step`` (full KV cache — decode_32k) and
``decode_step_long`` (stale-KV block attention / recurrent state —
long_500k, see repro.models.stale_kv).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_constraint
from repro.models.attention import (cross_attention, decode_attention,
                                    prefill_attention)
from repro.models.moe import load_balance_loss, moe_ffn
from repro.models.recurrent import (mlstm_parallel, mlstm_step, rg_lru,
                                    rg_lru_step, slstm_scan)
from repro.models.stale_kv import StaleKVConfig, stale_kv_decode
from repro.nn import ParamSpec, apply_rope, dense, rms_norm, swiglu

Pytree = Any

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense|moe|ssm|hybrid|vlm|audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    pattern: tuple = ("attn",)
    tail: tuple = ()
    # MoE
    num_experts: int = 0
    experts_per_token: int = 1
    moe_capacity_factor: float = 1.25
    shared_expert: bool = False
    # attention
    qk_norm: bool = False
    rope_theta: float = 500000.0
    window: int = 2048                # for "swa" blocks
    # recurrent
    rnn_dim: int = 0                  # defaults to d_model
    conv_width: int = 4
    mlstm_expansion: int = 2
    # VLM
    vision_dim: int = 0
    num_patches: int = 0
    # long-context (stale-KV)
    long_window: int = 4096
    long_ratio: int = 64
    # numerics / training
    dtype: str = "bfloat16"
    param_dtype: str = "float32"      # matrix weights; norms stay f32
    optimizer: str = "adamw"
    learning_rate: float = 3e-4
    remat: bool = True
    scan_layers: bool = True          # False → unrolled (true HLO costs)
    attn_backend: str = "chunked"     # chunked|pallas|dense
    moe_impl: str = "auto"
    source: str = ""

    def __post_init__(self):
        body = self.num_layers - len(self.tail)
        if body % len(self.pattern):
            raise ValueError(
                f"{self.name}: layers {self.num_layers} != "
                f"pattern {self.pattern} x repeats + tail {self.tail}")

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def repeats(self) -> int:
        return (self.num_layers - len(self.tail)) // len(self.pattern)

    @property
    def rnn(self) -> int:
        return self.rnn_dim or self.d_model

    @property
    def act_dtype(self):
        return DTYPES[self.dtype]


# ---------------------------------------------------------------------------
# Parameter specs per block kind
# ---------------------------------------------------------------------------

def _norm(d: int) -> ParamSpec:
    return ParamSpec((d,), ("embed",), init="zeros")


def _attn_specs(cfg: ArchConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    s = {
        "ln1": _norm(d),
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed"),
                        fan_in_dims=(0, 1)),
    }
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec((hd,), ("head_dim",), init="zeros")
        s["k_norm"] = ParamSpec((hd,), ("head_dim",), init="zeros")
    return s


def _mlp_specs(cfg: ArchConfig, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    return {
        "ln2": _norm(d),
        "w_gate": ParamSpec((d, ff), ("embed", "mlp")),
        "w_up": ParamSpec((d, ff), ("embed", "mlp")),
        "w_down": ParamSpec((ff, d), ("mlp", "embed")),
    }


def _moe_specs(cfg: ArchConfig) -> dict:
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.d_ff
    s = _attn_specs(cfg)
    s.update({
        "ln2": _norm(d),
        "router": ParamSpec((d, e), ("embed", "expert"), init="normal"),
        "w_gate_e": ParamSpec((e, d, ff), ("expert", "embed", "expert_mlp"),
                              fan_in_dims=(1,)),
        "w_up_e": ParamSpec((e, d, ff), ("expert", "embed", "expert_mlp"),
                            fan_in_dims=(1,)),
        "w_down_e": ParamSpec((e, ff, d), ("expert", "expert_mlp", "embed"),
                              fan_in_dims=(1,)),
    })
    if cfg.shared_expert:
        s.update({
            "ws_gate": ParamSpec((d, ff), ("embed", "mlp")),
            "ws_up": ParamSpec((d, ff), ("embed", "mlp")),
            "ws_down": ParamSpec((ff, d), ("mlp", "embed")),
        })
    return s


def _rec_specs(cfg: ArchConfig) -> dict:
    d, r = cfg.d_model, cfg.rnn
    return {
        "ln1": _norm(d),
        "w_y": ParamSpec((d, r), ("embed", "rnn")),
        "w_x": ParamSpec((d, r), ("embed", "rnn")),
        "conv_w": ParamSpec((cfg.conv_width, r), (None, "rnn"),
                            init="normal"),
        "w_gate_x": ParamSpec((d, r), ("embed", "rnn")),
        "w_gate_a": ParamSpec((d, r), ("embed", "rnn")),
        "log_lambda": ParamSpec((r,), ("rnn",), init="normal"),
        "w_out": ParamSpec((r, d), ("rnn", "embed")),
    }


def _mlstm_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    di = cfg.mlstm_expansion * d
    h = cfg.num_heads
    dh = di // h
    return {
        "ln1": _norm(d),
        "w_up": ParamSpec((d, 2 * di), ("embed", "mlp")),
        "wq": ParamSpec((di, h, dh), ("mlp", "heads", "head_dim")),
        "wk": ParamSpec((di, h, dh), ("mlp", "heads", "head_dim")),
        "wv": ParamSpec((di, h, dh), ("mlp", "heads", "head_dim")),
        "w_i": ParamSpec((di, h), ("mlp", "heads"), init="normal"),
        "w_f": ParamSpec((di, h), ("mlp", "heads"), init="normal"),
        "w_down": ParamSpec((di, d), ("mlp", "embed")),
    }


def _slstm_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    return {
        "ln1": _norm(d),
        "w_in": ParamSpec((d, h, 4, dh), ("embed", "heads", None,
                                          "head_dim")),
        "r_z": ParamSpec((h, dh, dh), ("heads", "head_dim", None),
                         fan_in_dims=(1,)),
        "r_i": ParamSpec((h, dh, dh), ("heads", "head_dim", None),
                         fan_in_dims=(1,)),
        "r_f": ParamSpec((h, dh, dh), ("heads", "head_dim", None),
                         fan_in_dims=(1,)),
        "r_o": ParamSpec((h, dh, dh), ("heads", "head_dim", None),
                         fan_in_dims=(1,)),
        "w_out": ParamSpec((d, d), ("embed", "embed_out")),
        **_mlp_specs(cfg, d_ff=2 * d),
    }


def _xattn_specs(cfg: ArchConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    vd = cfg.vision_dim
    return {
        "ln1": _norm(d),
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((vd, kv, hd), (None, "kv_heads", "head_dim")),
        "wv": ParamSpec((vd, kv, hd), (None, "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed"),
                        fan_in_dims=(0, 1)),
        "gate": ParamSpec((1,), (None,), init="zeros"),
        **_mlp_specs(cfg),
    }


def _block_specs(cfg: ArchConfig, kind: str) -> dict:
    if kind == "attn" or kind == "swa":
        return {**_attn_specs(cfg), **_mlp_specs(cfg)}
    if kind == "moe":
        return _moe_specs(cfg)
    if kind == "rec":
        return {**_rec_specs(cfg), **_mlp_specs(cfg)}
    if kind == "mlstm":
        return _mlstm_specs(cfg)
    if kind == "slstm":
        return _slstm_specs(cfg)
    if kind == "xattn":
        return _xattn_specs(cfg)
    raise ValueError(kind)


def _stack_spec(spec: ParamSpec, n: int) -> ParamSpec:
    return ParamSpec((n,) + spec.shape, ("stack",) + spec.axes,
                     init=spec.init, dtype=spec.dtype, scale=spec.scale,
                     fan_in_dims=tuple(d + 1 for d in spec.fan_in_dims))


def arch_specs(cfg: ArchConfig) -> Pytree:
    d = cfg.d_model
    specs: dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab_size, d), ("vocab", "embed"),
                           init="embed", scale=0.02),
        "final_norm": _norm(d),
        "lm_head": ParamSpec((d, cfg.vocab_size), ("embed", "vocab")),
    }
    specs["pattern"] = [
        jax.tree.map(lambda s: _stack_spec(s, cfg.repeats),
                     _block_specs(cfg, kind),
                     is_leaf=lambda x: isinstance(x, ParamSpec))
        for kind in cfg.pattern]
    specs["tail"] = [_block_specs(cfg, kind) for kind in cfg.tail]
    if cfg.param_dtype != "float32":
        # Mixed-precision weight policy: matrix params in bf16 (the
        # §Perf memory/collective lever), 1-D norm scales kept f32.
        pd = DTYPES[cfg.param_dtype]

        def cast(s: ParamSpec) -> ParamSpec:
            if len(s.shape) <= 1:
                return s
            return dataclasses.replace(s, dtype=pd)

        specs = jax.tree.map(cast, specs,
                             is_leaf=lambda x: isinstance(x, ParamSpec))
    return specs


# ---------------------------------------------------------------------------
# Block forward (training / prefill)
# ---------------------------------------------------------------------------

def _qkv(cfg: ArchConfig, p: dict, h: jax.Array, positions: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(h.dtype))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = logical_constraint(q, ("batch", "seq", "heads", "head_dim"))
    k = logical_constraint(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = logical_constraint(v, ("batch", "seq", "kv_heads", "head_dim"))
    return q, k, v


def _attn_out(p: dict, attn: jax.Array, x: jax.Array) -> jax.Array:
    out = jnp.einsum("bshk,hkd->bsd", attn, p["wo"].astype(attn.dtype))
    return x + logical_constraint(out, ("batch", "seq", "embed"))


def _mlp(p: dict, x: jax.Array) -> jax.Array:
    h = rms_norm(x, p["ln2"])
    out = swiglu(h, p["w_gate"].astype(h.dtype), p["w_up"].astype(h.dtype),
                 p["w_down"].astype(h.dtype))
    return x + logical_constraint(out, ("batch", "seq", "embed"))


def _fwd_attn(cfg, p, x, ctx, *, window=0):
    h = rms_norm(x, p["ln1"])
    q, k, v = _qkv(cfg, p, h, ctx["positions"])
    attn = prefill_attention(q, k, v, window=window,
                             backend=cfg.attn_backend)
    x = _attn_out(p, attn, x)
    return _mlp(p, x)


def _fwd_moe(cfg, p, x, ctx):
    h = rms_norm(x, p["ln1"])
    q, k, v = _qkv(cfg, p, h, ctx["positions"])
    attn = prefill_attention(q, k, v, backend=cfg.attn_backend)
    x = _attn_out(p, attn, x)
    h2 = rms_norm(x, p["ln2"])
    moe_params = {"router": p["router"], "w_gate": p["w_gate_e"],
                  "w_up": p["w_up_e"], "w_down": p["w_down_e"]}
    out = moe_ffn(h2, moe_params, cfg.experts_per_token,
                  impl=cfg.moe_impl,
                  capacity_factor=cfg.moe_capacity_factor)
    if cfg.shared_expert:
        out = out + swiglu(h2, p["ws_gate"].astype(h2.dtype),
                           p["ws_up"].astype(h2.dtype),
                           p["ws_down"].astype(h2.dtype))
    return x + logical_constraint(out, ("batch", "seq", "embed"))


def _conv1d_causal(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, S, D); w: (W, D)."""
    width = w.shape[0]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :x.shape[1]]
        out = out + shifted.astype(jnp.float32) * w[i].astype(jnp.float32)
    return out.astype(x.dtype)


def _fwd_rec(cfg, p, x, ctx):
    h = rms_norm(x, p["ln1"])
    y = jax.nn.gelu(dense(h, p["w_y"].astype(h.dtype)))
    bx = dense(h, p["w_x"].astype(h.dtype))
    bx = _conv1d_causal(bx, p["conv_w"])
    gx = dense(h, p["w_gate_x"].astype(h.dtype))
    ga = dense(h, p["w_gate_a"].astype(h.dtype))
    lru, _ = rg_lru(bx, gx, ga, p["log_lambda"])
    out = dense(y * lru, p["w_out"].astype(h.dtype))
    x = x + logical_constraint(out, ("batch", "seq", "embed"))
    return _mlp(p, x)


def _fwd_mlstm(cfg, p, x, ctx):
    h = rms_norm(x, p["ln1"])
    up = dense(h, p["w_up"].astype(h.dtype))
    di = up.shape[-1] // 2
    xi, gate = up[..., :di], up[..., di:]
    heads = cfg.num_heads
    dh = di // heads
    b, s, _ = xi.shape
    q = jnp.einsum("bsd,dhk->bhsk", xi, p["wq"].astype(xi.dtype))
    k = jnp.einsum("bsd,dhk->bhsk", xi, p["wk"].astype(xi.dtype))
    v = jnp.einsum("bsd,dhk->bhsk", xi, p["wv"].astype(xi.dtype))
    i_pre = jnp.einsum("bsd,dh->bhs", xi, p["w_i"].astype(xi.dtype))
    f_pre = jnp.einsum("bsd,dh->bhs", xi, p["w_f"].astype(xi.dtype))
    core = mlstm_parallel(q, k, v, i_pre, f_pre)          # (B,H,S,dh)
    core = jnp.swapaxes(core, 1, 2).reshape(b, s, di)
    out = dense(core * jax.nn.silu(gate), p["w_down"].astype(h.dtype))
    return x + logical_constraint(out, ("batch", "seq", "embed"))


def _fwd_slstm(cfg, p, x, ctx):
    h = rms_norm(x, p["ln1"])
    wx = jnp.einsum("bsd,dhgk->bshgk", h, p["w_in"].astype(h.dtype))
    hs, _ = slstm_scan(wx, {"z": p["r_z"], "i": p["r_i"], "f": p["r_f"],
                            "o": p["r_o"]})
    b, s = h.shape[:2]
    out = dense(hs.reshape(b, s, -1), p["w_out"].astype(h.dtype))
    x = x + logical_constraint(out, ("batch", "seq", "embed"))
    return _mlp(p, x)


def _fwd_xattn(cfg, p, x, ctx):
    vis = ctx["vision"]
    h = rms_norm(x, p["ln1"])
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(h.dtype))
    k = jnp.einsum("bpv,vhk->bphk", vis, p["wk"].astype(h.dtype))
    v = jnp.einsum("bpv,vhk->bphk", vis, p["wv"].astype(h.dtype))
    attn = cross_attention(q, k, v)
    out = jnp.einsum("bshk,hkd->bsd", attn, p["wo"].astype(attn.dtype))
    gate = jnp.tanh(p["gate"].astype(jnp.float32))[0]
    x = x + (gate * logical_constraint(
        out, ("batch", "seq", "embed"))).astype(x.dtype)
    return _mlp(p, x)


_FWD = {"attn": _fwd_attn, "swa": None, "moe": _fwd_moe, "rec": _fwd_rec,
        "mlstm": _fwd_mlstm, "slstm": _fwd_slstm, "xattn": _fwd_xattn}


def _apply_block(kind: str, cfg, p, x, ctx):
    if kind == "swa":
        return _fwd_attn(cfg, p, x, ctx, window=cfg.window)
    return _FWD[kind](cfg, p, x, ctx)


# ---------------------------------------------------------------------------
# Full forward (training / prefill-for-logits)
# ---------------------------------------------------------------------------

def forward(cfg: ArchConfig, params: Pytree, tokens: jax.Array,
            vision: Optional[jax.Array] = None) -> jax.Array:
    """tokens: (B, S) int32 → logits (B, S, vocab) f32."""
    dt = cfg.act_dtype
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    x = logical_constraint(x, ("batch", "seq", "embed"))
    ctx = {"positions": jnp.arange(tokens.shape[1]),
           "vision": None if vision is None else vision.astype(dt)}

    def body(x, rep_params):
        for j, kind in enumerate(cfg.pattern):
            x = _apply_block(kind, cfg, rep_params[j], x, ctx)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["pattern"])
    else:
        for r in range(cfg.repeats):
            rep = jax.tree.map(lambda a: a[r], params["pattern"])
            x, _ = body(x, rep)
    for j, kind in enumerate(cfg.tail):
        x = _apply_block(kind, cfg, params["tail"][j], x, ctx)

    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32))
    return logical_constraint(logits, ("batch", "seq", "vocab"))


def aux_moe_loss(cfg: ArchConfig, params: Pytree, tokens: jax.Array,
                 x_embed: Optional[jax.Array] = None) -> jax.Array:
    """Router load-balance loss, computed from first-pattern MoE routers."""
    if cfg.num_experts == 0:
        return jnp.asarray(0.0, jnp.float32)
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.float32)
    total = jnp.asarray(0.0, jnp.float32)
    count = 0
    for j, kind in enumerate(cfg.pattern):
        if kind != "moe":
            continue
        router = params["pattern"][j]["router"][0]       # first repeat
        xf = x.reshape(-1, cfg.d_model)
        logits = xf @ router.astype(jnp.float32)
        _, ids = jax.lax.top_k(logits, cfg.experts_per_token)
        total = total + load_balance_loss(logits, ids.astype(jnp.int32),
                                          cfg.num_experts)
        count += 1
    return total / max(count, 1)


# ---------------------------------------------------------------------------
# Decode: caches + single-token step
# ---------------------------------------------------------------------------

def _cache_block_specs(cfg: ArchConfig, kind: str, batch: int, max_seq: int,
                       long: bool, dtype) -> dict:
    """ParamSpec pytree for one block's decode cache (shape + logical axes,
    used both to allocate zeros and to derive dry-run shardings)."""
    kv, hd = cfg.num_kv_heads, cfg.hd
    kvh = ("batch", "kv_seq", "kv_heads", "head_dim")

    def sp(shape, axes, dt=dtype):
        return ParamSpec(shape, axes, init="zeros", dtype=dt)

    if kind in ("attn", "moe"):
        if long:
            skv = StaleKVConfig(max_seq, cfg.long_window, cfg.long_ratio)
            return {
                "k_win": sp((batch, skv.window, kv, hd),
                            ("batch", None, "kv_heads", "head_dim")),
                "v_win": sp((batch, skv.window, kv, hd),
                            ("batch", None, "kv_heads", "head_dim")),
                "k_sum": sp((batch, skv.num_slots, kv, hd), kvh),
                "v_sum": sp((batch, skv.num_slots, kv, hd), kvh),
                "k_pend": sp((batch, skv.ratio, kv, hd),
                             ("batch", None, "kv_heads", "head_dim")),
                "v_pend": sp((batch, skv.ratio, kv, hd),
                             ("batch", None, "kv_heads", "head_dim")),
            }
        return {"k": sp((batch, max_seq, kv, hd), kvh),
                "v": sp((batch, max_seq, kv, hd), kvh)}
    if kind == "swa":
        w = min(cfg.window, max_seq)
        return {"k": sp((batch, w, kv, hd),
                        ("batch", None, "kv_heads", "head_dim")),
                "v": sp((batch, w, kv, hd),
                        ("batch", None, "kv_heads", "head_dim"))}
    if kind == "xattn":
        return {"k": sp((batch, cfg.num_patches, kv, hd),
                        ("batch", "patches", "kv_heads", "head_dim")),
                "v": sp((batch, cfg.num_patches, kv, hd),
                        ("batch", "patches", "kv_heads", "head_dim"))}
    if kind == "rec":
        r = cfg.rnn
        return {"h": sp((batch, r), ("batch", "rnn"), jnp.float32),
                "conv": sp((batch, cfg.conv_width - 1, r),
                           ("batch", None, "rnn"))}
    if kind == "mlstm":
        di = cfg.mlstm_expansion * cfg.d_model
        h = cfg.num_heads
        dh = di // h
        return {"C": sp((batch, h, dh, dh),
                        ("batch", "heads", "head_dim", None), jnp.float32),
                "n": sp((batch, h, dh), ("batch", "heads", "head_dim"),
                        jnp.float32),
                "m": sp((batch, h), ("batch", "heads"), jnp.float32)}
    if kind == "slstm":
        h = cfg.num_heads
        dh = cfg.d_model // h
        ax = ("batch", "heads", "head_dim")
        return {"c": sp((batch, h, dh), ax, jnp.float32),
                "n": sp((batch, h, dh), ax, jnp.float32),
                "m": sp((batch, h, dh), ax, jnp.float32),
                "h": sp((batch, h, dh), ax, jnp.float32)}
    raise ValueError(kind)


def cache_specs(cfg: ArchConfig, batch: int, max_seq: int,
                long: bool = False) -> dict:
    dt = cfg.act_dtype

    def stack(tree):
        return jax.tree.map(
            lambda s: _stack_spec(s, cfg.repeats), tree,
            is_leaf=lambda x: isinstance(x, ParamSpec))

    return {
        "pattern": [stack(_cache_block_specs(cfg, kind, batch, max_seq,
                                             long, dt))
                    for kind in cfg.pattern],
        "tail": [_cache_block_specs(cfg, kind, batch, max_seq, long, dt)
                 for kind in cfg.tail],
        "pos": ParamSpec((batch,), ("batch",), init="zeros",
                         dtype=jnp.int32),
    }


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               long: bool = False) -> dict:
    specs = cache_specs(cfg, batch, max_seq, long)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def _dec_attn(cfg, p, x, cache, pos, ctx, *, window=0, long=False):
    """x: (B, 1, d). Returns (new_x, new_cache)."""
    h = rms_norm(x, p["ln1"])
    positions = pos[:, None]                              # (B, 1)
    q, k, v = _qkv(cfg, p, h, positions)
    if long:
        attn, cache = stale_kv_decode(ctx["skv_cfg"], cache, q, k, v, pos)
    elif window > 0:
        slot = pos[0] % window
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice(cache["k"], k,
                                                  (0, slot, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(cache["v"], v,
                                                  (0, slot, 0, 0))
        # Ring buffer: positions are implicit; mask handled via abs pos.
        idx = jnp.arange(cache["k"].shape[1])
        p0 = pos[0]
        abs_pos = jnp.where(idx <= slot, p0 - slot + idx,
                            p0 - slot + idx - cache["k"].shape[1])
        # decode over ring with explicit mask via big-cache path:
        from repro.models.attention import repeat_kv as _rep
        rep = cfg.num_heads // cfg.num_kv_heads
        q32 = q[:, 0].astype(jnp.float32) * (cfg.hd ** -0.5)
        kf = _rep(cache["k"], rep).astype(jnp.float32)
        vf = _rep(cache["v"], rep).astype(jnp.float32)
        logits = jnp.einsum("bhd,bshd->bhs", q32, kf)
        mask = (abs_pos >= 0) & (abs_pos <= p0)
        logits = jnp.where(mask[None, None, :], logits, -1e30)
        pa = jax.nn.softmax(logits, axis=-1)
        attn = jnp.einsum("bhs,bshd->bhd", pa, vf)[:, None].astype(q.dtype)
    else:
        slot = pos[0]
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice(cache["k"], k,
                                                  (0, slot, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(cache["v"], v,
                                                  (0, slot, 0, 0))
        attn = decode_attention(q, cache["k"], cache["v"], pos)
    x = _attn_out(p, attn, x)
    return x, cache


def _dec_block(kind, cfg, p, x, cache, pos, ctx):
    long = ctx["long"]
    if kind in ("attn", "moe"):
        x, cache = _dec_attn(cfg, p, x, cache, pos, ctx, long=long)
        if kind == "attn":
            return _mlp(p, x), cache
        h2 = rms_norm(x, p["ln2"])
        moe_params = {"router": p["router"], "w_gate": p["w_gate_e"],
                      "w_up": p["w_up_e"], "w_down": p["w_down_e"]}
        out = moe_ffn(h2, moe_params, cfg.experts_per_token,
                      impl=cfg.moe_impl,
                      capacity_factor=cfg.moe_capacity_factor)
        if cfg.shared_expert:
            out = out + swiglu(h2, p["ws_gate"].astype(h2.dtype),
                               p["ws_up"].astype(h2.dtype),
                               p["ws_down"].astype(h2.dtype))
        return x + out, cache
    if kind == "swa":
        x, cache = _dec_attn(cfg, p, x, cache, pos, ctx,
                             window=cfg.window)
        return _mlp(p, x), cache
    if kind == "xattn":
        h = rms_norm(x, p["ln1"])
        q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(h.dtype))
        attn = cross_attention(q, cache["k"], cache["v"])
        out = jnp.einsum("bshk,hkd->bsd", attn, p["wo"].astype(attn.dtype))
        gate = jnp.tanh(p["gate"].astype(jnp.float32))[0]
        x = x + (gate * out).astype(x.dtype)
        return _mlp(p, x), cache
    if kind == "rec":
        h = rms_norm(x, p["ln1"])[:, 0]                   # (B, d)
        y = jax.nn.gelu(h @ p["w_y"].astype(h.dtype))
        bx = h @ p["w_x"].astype(h.dtype)
        conv = cache["conv"]
        w = p["conv_w"].astype(jnp.float32)
        acc = bx.astype(jnp.float32) * w[0]
        for i in range(1, cfg.conv_width):
            acc = acc + conv[:, -i].astype(jnp.float32) * w[i]
        bx = acc.astype(h.dtype)
        new_conv = jnp.concatenate(
            [conv[:, 1:], (h @ p["w_x"].astype(h.dtype))[:, None]], axis=1)
        gx = h @ p["w_gate_x"].astype(h.dtype)
        ga = h @ p["w_gate_a"].astype(h.dtype)
        lru, h_new = rg_lru_step(bx, gx, ga, p["log_lambda"], cache["h"])
        out = (y * lru) @ p["w_out"].astype(h.dtype)
        x = x + out[:, None]
        return _mlp(p, x), {"h": h_new, "conv": new_conv}
    if kind == "mlstm":
        h = rms_norm(x, p["ln1"])[:, 0]
        up = h @ p["w_up"].astype(h.dtype)
        di = up.shape[-1] // 2
        xi, gate = up[..., :di], up[..., di:]
        q = jnp.einsum("bd,dhk->bhk", xi, p["wq"].astype(xi.dtype))
        k = jnp.einsum("bd,dhk->bhk", xi, p["wk"].astype(xi.dtype))
        v = jnp.einsum("bd,dhk->bhk", xi, p["wv"].astype(xi.dtype))
        i_pre = jnp.einsum("bd,dh->bh", xi, p["w_i"].astype(xi.dtype))
        f_pre = jnp.einsum("bd,dh->bh", xi, p["w_f"].astype(xi.dtype))
        core, new_state = mlstm_step(q, k, v, i_pre, f_pre, cache)
        core = core.reshape(core.shape[0], -1)
        out = (core.astype(h.dtype) * jax.nn.silu(gate)) @ \
            p["w_down"].astype(h.dtype)
        return x + out[:, None], new_state
    if kind == "slstm":
        h = rms_norm(x, p["ln1"])
        wx = jnp.einsum("bsd,dhgk->bshgk", h, p["w_in"].astype(h.dtype))
        hs, new_state = slstm_scan(wx, {"z": p["r_z"], "i": p["r_i"],
                                        "f": p["r_f"], "o": p["r_o"]},
                                   state=cache)
        b = h.shape[0]
        out = dense(hs.reshape(b, 1, -1), p["w_out"].astype(h.dtype))
        x = x + out
        return _mlp(p, x), new_state
    raise ValueError(kind)


def precompute_vision_cache(cfg: ArchConfig, params: Pytree,
                            cache: dict, vision: jax.Array) -> dict:
    """Fill xattn cache entries with projected vision K/V."""
    vis = vision.astype(cfg.act_dtype)
    cache = dict(cache)
    new_pattern = []
    for j, kind in enumerate(cfg.pattern):
        entry = cache["pattern"][j]
        if kind == "xattn":
            p = params["pattern"][j]
            k = jnp.einsum("bpv,rvhk->rbphk", vis, p["wk"].astype(vis.dtype))
            v = jnp.einsum("bpv,rvhk->rbphk", vis, p["wv"].astype(vis.dtype))
            entry = {"k": k, "v": v}
        new_pattern.append(entry)
    cache["pattern"] = new_pattern
    return cache


def decode_step(cfg: ArchConfig, params: Pytree, cache: dict,
                tokens: jax.Array, long: bool = False
                ) -> tuple[jax.Array, dict]:
    """tokens: (B, 1) → (logits (B, 1, vocab), new cache)."""
    dt = cfg.act_dtype
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    pos = cache["pos"]
    max_seq = None
    ctx = {"long": long, "skv_cfg": None}
    if long:
        # Infer S from the summary table of the first attn-ish block.
        for j, kind in enumerate(cfg.pattern):
            if kind in ("attn", "moe"):
                n_slots = cache["pattern"][j]["k_sum"].shape[2]
                ctx["skv_cfg"] = StaleKVConfig(
                    n_slots * cfg.long_ratio, cfg.long_window,
                    cfg.long_ratio)
                break

    def body(x, xs):
        rep_params, rep_cache = xs
        new_cache = []
        for j, kind in enumerate(cfg.pattern):
            x, c = _dec_block(kind, cfg, rep_params[j], x,
                              rep_cache[j], pos, ctx)
            new_cache.append(c)
        return x, new_cache

    if cfg.scan_layers:
        x, new_pattern_cache = jax.lax.scan(
            body, x, (params["pattern"], cache["pattern"]))
    else:
        per_rep = []
        for r in range(cfg.repeats):
            xs = jax.tree.map(lambda a: a[r],
                              (params["pattern"], cache["pattern"]))
            x, c = body(x, xs)
            per_rep.append(c)
        new_pattern_cache = jax.tree.map(
            lambda *cs: jnp.stack(cs), *per_rep)
    new_tail = []
    for j, kind in enumerate(cfg.tail):
        x, c = _dec_block(kind, cfg, params["tail"][j], x,
                          cache["tail"][j], pos, ctx)
        new_tail.append(c)

    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32))
    new_cache = {"pattern": new_pattern_cache, "tail": new_tail,
                 "pos": pos + 1}
    return logits, new_cache
