"""Mini-batch sampled training: determinism, estimator exactness,
variance reduction and compiled-HLO census.

The sampled regime's defining properties, each pinned here:

  * **Determinism** — batches are a pure function of ``(seed, step)``:
    rebuilding the sampler (a fresh process, another device count, a
    re-run) reproduces every batch bitwise.
  * **Full-fanout exactness** — with ``fanout >= max_in_degree`` the
    control-variate estimator collapses to the full-batch aggregation
    *bitwise* for gcn/sage (the residual history weight is exactly
    +0.0), regardless of what garbage sits in the history; gat (full
    in-batch attention over sampled rows) matches to fp tolerance.
  * **Variance reduction** — at a reduced fanout the CV estimator's
    one-step parameter update deviates less (in mean squared error,
    across batch draws) from the exact full-batch update than plain
    scaled neighbor sampling does.  Measured with SGD so the update IS
    the gradient (times -lr).
  * **Census invariance** — the compiled sampled step emits ZERO
    all-gathers / collective-permutes / reduce-scatters and exactly the
    full-batch epoch's all_to_all count per store tensor: sampling
    changes the math, never the communication (the stale term rides the
    unchanged pull/push helpers).
"""
import functools
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (TrainSettings, digest_train,
                        init_sampled_state, make_sampled_epoch_fn,
                        prepare_graph_data, sampled_train)
from repro.graph import build_sampler, make_dataset
from repro.models.gnn import GNNConfig
from repro.optim import adam, sgd

pytestmark = pytest.mark.leg("sampling-smoke")


@functools.lru_cache(maxsize=None)
def _graph(seed: int = 0):
    return make_dataset("flickr-sim", scale=0.12, seed=seed)


@functools.lru_cache(maxsize=None)
def _data(num_parts: int = 4, seed: int = 0):
    return prepare_graph_data(_graph(seed), num_parts)


def _cfg(g, model="gcn", num_layers=2, hidden=32):
    return GNNConfig(model=model, num_layers=num_layers,
                     in_dim=g.features.shape[1], hidden_dim=hidden,
                     num_classes=int(g.labels.max()) + 1, heads=2)


def _settings(**kw):
    kw.setdefault("sync_interval", 2)
    kw.setdefault("mode", "digest")
    kw.setdefault("pull_mode", "gather")
    return TrainSettings(**kw)


# ---------------------------------------------------------------------------
# Sampler determinism + batch well-formedness
# ---------------------------------------------------------------------------

def test_sampler_deterministic_across_rebuilds():
    data = _data()
    a = build_sampler(data, fanout=3, batch_seeds=16, seed=7)
    b = build_sampler(data, fanout=3, batch_seeds=16, seed=7)
    for t in (0, 1, 17):
        ba, bb = a.sample(t), b.sample(t)
        for k in ("seed_mask", "edge_scale", "edge_keep"):
            assert np.array_equal(ba[k], bb[k]), (t, k)
    # step and seed both perturb the draw
    assert not np.array_equal(a.sample(0)["edge_keep"],
                              a.sample(1)["edge_keep"])
    c = build_sampler(data, fanout=3, batch_seeds=16, seed=8)
    assert not np.array_equal(a.sample(0)["edge_keep"],
                              c.sample(0)["edge_keep"])


def test_sampler_batch_wellformed():
    data = _data()
    s = build_sampler(data, fanout=3, batch_seeds=16, seed=0)
    train_mask = np.asarray(data["train_mask"]).astype(bool)
    for t in range(3):
        b = s.sample(t)
        # seeds: subset of the train mask, at most batch_seeds per part
        assert not (b["seed_mask"] & ~train_mask).any()
        assert (b["seed_mask"].sum(axis=1) <= 16).all()
        # edges: keep only valid entries, exactly min(deg, fanout) each
        assert not (b["edge_keep"] & ~s.in_valid).any()
        n = b["edge_keep"].sum(axis=-1)
        assert np.array_equal(n, np.minimum(s.in_deg, 3))
        # scale: zero off-sample, exactly 1.0 where deg <= fanout
        assert (b["edge_scale"][~b["edge_keep"]] == 0).all()
        small = (s.in_deg <= 3) & (s.in_deg > 0)
        kept = b["edge_keep"] & small[..., None]
        assert (b["edge_scale"][kept] == np.float32(1.0)).all()
        # unbiasedness factor elsewhere: deg / fanout
        big = s.in_deg > 3
        kept = b["edge_keep"] & big[..., None]
        want = (s.in_deg.astype(np.float32) / 3.0)[..., None]
        assert np.allclose(b["edge_scale"][kept],
                           np.broadcast_to(want, b["edge_scale"].shape)[kept])


def test_full_batch_draw_covers_everything():
    data = _data()
    s = build_sampler(data, fanout=2, batch_seeds=4, seed=0)
    fb = s.full_batch()
    assert np.array_equal(fb["seed_mask"], s.train_mask)
    assert np.array_equal(fb["edge_keep"], s.in_valid)
    assert np.array_equal(fb["edge_scale"], s.in_valid.astype(np.float32))


def test_build_sampler_validates():
    data = _data()
    with pytest.raises(ValueError, match="fanout"):
        build_sampler(data, fanout=0, batch_seeds=4)
    with pytest.raises(ValueError, match="batch_seeds"):
        build_sampler(data, fanout=2, batch_seeds=0)


# ---------------------------------------------------------------------------
# Full-fanout exactness: sampled == full-batch
# ---------------------------------------------------------------------------

def _full_coverage_sampler(data):
    s = build_sampler(data, fanout=1, batch_seeds=1 << 30, seed=0)
    return build_sampler(data, fanout=max(s.max_in_degree, 1),
                         batch_seeds=1 << 30, seed=0)


@pytest.mark.parametrize("model", ["gcn", "sage", "gat"])
def test_full_fanout_sampled_matches_full_batch(model):
    """fanout >= max in-degree + every train row a seed ==> the sampled
    trajectory reproduces the full-batch trajectory (bitwise for
    gcn/sage; gat runs full in-batch attention over all-sampled rows and
    must agree to fp tolerance)."""
    g = _graph()
    data = _data()
    cfg = _cfg(g, model=model)
    settings = _settings()
    epochs = 5

    st_full, hist_full = digest_train(cfg, adam(5e-3), data, settings,
                                      epochs=epochs, eval_every=1)
    sampler = _full_coverage_sampler(data)
    assert sampler.fanout >= sampler.max_in_degree
    st_samp, hist_samp = sampled_train(cfg, adam(5e-3), data, sampler,
                                       settings, steps=epochs,
                                       eval_every=1)

    flat_f = jax.tree.leaves(st_full["params"])
    flat_s = jax.tree.leaves(st_samp["params"])
    for pf, ps in zip(flat_f, flat_s):
        if model == "gat":
            assert jnp.allclose(pf, ps, atol=1e-6, rtol=1e-6)
        else:
            assert jnp.array_equal(pf, ps)
    for k in st_full["store"]:
        if model == "gat":
            assert jnp.allclose(st_full["store"][k], st_samp["store"][k],
                                atol=1e-6, rtol=1e-6), k
        else:
            assert jnp.array_equal(st_full["store"][k],
                                   st_samp["store"][k]), k
    if model != "gat":
        assert hist_full["loss"] == hist_samp["loss"]


def test_full_fanout_exact_under_random_history():
    """The bitwise collapse cannot depend on the history's contents: the
    residual weight is exactly +0.0 at full fanout, so one CV step from
    a RANDOM history equals one step from the zero history (gcn)."""
    g = _graph()
    data = _data()
    cfg = _cfg(g)
    settings = _settings()
    tdata = {k: v for k, v in data.items() if not k.startswith("_")}
    sampler = _full_coverage_sampler(data)
    batch = {k: jnp.asarray(v) for k, v in sampler.sample(0).items()}
    step_fn = jax.jit(make_sampled_epoch_fn(cfg, adam(5e-3), settings))

    opt = adam(5e-3)
    state = init_sampled_state(cfg, opt, data)
    s1, m1 = step_fn(state, tdata, batch)

    noisy = dict(state)
    noisy["hist"] = jax.random.normal(jax.random.PRNGKey(3),
                                      state["hist"].shape)
    s2, m2 = step_fn(noisy, tdata, batch)

    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        assert jnp.array_equal(a, b)
    assert jnp.array_equal(m1["loss"], m2["loss"])


# ---------------------------------------------------------------------------
# Variance reduction: CV beats plain neighbor sampling
# ---------------------------------------------------------------------------

def test_cv_variance_below_plain():
    """At a reduced fanout, the CV estimator's one-step SGD update is
    closer (MSE over draws) to the exact full-batch update than plain
    scaled sampling — the VR-GCN claim, on the stale-store history."""
    g = _graph()
    data = _data()
    cfg = _cfg(g)
    opt = sgd(0.1)
    tdata = {k: v for k, v in data.items() if not k.startswith("_")}
    full = _full_coverage_sampler(data)

    # Warm the history + store with a few exact full-coverage steps.
    state, _ = sampled_train(cfg, opt, data, full,
                             _settings(sample_estimator="cv"), steps=6,
                             eval_every=6)

    step_cv = jax.jit(make_sampled_epoch_fn(
        cfg, opt, _settings(sample_estimator="cv")))
    step_plain = jax.jit(make_sampled_epoch_fn(
        cfg, opt, _settings(sample_estimator="plain")))

    # Exact reference update from the warmed state (full coverage draw).
    ref_batch = {k: jnp.asarray(v) for k, v in full.full_batch().items()}
    ref_state, _ = step_cv(state, tdata, ref_batch)
    ref = jax.tree.leaves(ref_state["params"])

    def mse(st):
        return float(sum(jnp.sum((a - b) ** 2)
                         for a, b in zip(jax.tree.leaves(st["params"]),
                                         ref)))

    sampler = build_sampler(data, fanout=2, batch_seeds=1 << 30, seed=11)
    draws = 8
    err_cv, err_plain = 0.0, 0.0
    for t in range(draws):
        batch = {k: jnp.asarray(v) for k, v in sampler.sample(t).items()}
        s_cv, _ = step_cv(state, tdata, batch)
        s_pl, _ = step_plain(state, tdata, batch)
        err_cv += mse(s_cv)
        err_plain += mse(s_pl)
    assert err_cv < err_plain, (err_cv, err_plain)


# ---------------------------------------------------------------------------
# Compiled-HLO census: sampling must not change the communication
# ---------------------------------------------------------------------------

def _sampled_hlo_checks():
    import hlo_utils
    from repro.launch.mesh import make_host_mesh

    D = 8
    assert jax.device_count() >= D, jax.device_count()
    mesh = make_host_mesh(data=D)
    g = make_dataset("flickr-sim", scale=0.1, seed=5)

    for model in ("gcn", "gat"):
        for storage in ("fp32", "int8"):
            compiled = hlo_utils.compile_sampled_epoch(
                g, D, mesh, storage=storage, pull_mode="collective",
                model=model)
            c = hlo_utils.collective_counts(compiled.as_text())
            label = f"sampled {model} {storage}"
            # Sampling adds ZERO communication: no gathers of the halo
            # slab, no permutes, no scatter fallback...
            assert c["all-gather"] == 0, (label, c)
            assert c["collective-permute"] == 0, (label, c)
            assert c["reduce-scatter"] == 0, (label, c)
            # ...and exactly the full-batch epoch's ragged pulls.
            want = hlo_utils.expected_all_to_all(storage, model=model)
            assert c["all-to-all"] == want, (label, c)
            assert c["all-reduce"] > 0, (label, c)


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (CI REPRO_HOST_DEVICES=8 job)")
def test_sampled_hlo_census_inprocess():
    _sampled_hlo_checks()


def test_sampled_hlo_census_subprocess():
    """Force an 8-device CPU platform in a subprocess so the sampled-step
    census is checked even on single-device hosts."""
    if jax.device_count() >= 8:
        pytest.skip("covered by the in-process variant")
    import hlo_utils
    hlo_utils.run_forced_device_subprocess(__file__, "SAMPLED_HLO_OK")


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    _sampled_hlo_checks()
    print("SAMPLED_HLO_OK")
