"""Production mesh builders (TPU v5e pod topology).

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax
import jax.sharding

# jax < 0.5 has no jax.sharding.AxisType (and make_mesh takes no axis_types
# kwarg); fall back to plain meshes there so imports stay version-portable.
AxisType = getattr(jax.sharding, "AxisType", None)


def _make_mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(shape))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False, pods: int = None):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (pods, 16, 16) chips, axes (pod, data, model) — the
    default ``pods=2`` is the 512-chip production dry-run; ``pods``
    overrides the pod count (>1 implies multi-pod)."""
    if pods is None:
        pods = 2 if multi_pod else 1
    if pods < 1 or (multi_pod and pods < 2):
        # A single-pod mesh under --multi-pod would silently validate
        # the wrong program (the census record only names the shape).
        raise ValueError(f"pods={pods} contradicts multi_pod={multi_pod}"
                         f" — multi-pod needs pods >= 2, single-pod "
                         f"exactly pods=1 (or omit pods)")
    if pods > 1:
        return _make_mesh((pods, 16, 16), ("pod", "data", "model"))
    return _make_mesh((16, 16), ("data", "model"))


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    shape, axes = [], []
    if pod > 1:
        shape.append(pod)
        axes.append("pod")
    shape += [data, model]
    axes += ["data", "model"]
    return _make_mesh(tuple(shape), tuple(axes))


# Hardware constants (TPU v5e) for the roofline report.
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
