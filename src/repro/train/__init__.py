from repro.train.trainer import (TrainSettings, abstract_train_state,
                                 init_train_state, make_arch_optimizer,
                                 make_serve_step, make_train_step)

__all__ = ["TrainSettings", "abstract_train_state", "init_train_state",
           "make_arch_optimizer", "make_serve_step", "make_train_step"]
