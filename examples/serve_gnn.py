#!/usr/bin/env python
"""Embedding serving from the DIGEST store, end to end on one host.

The stale-representation store training maintains is also a read path:
h^(L-1) rows plus one top-layer application answer any node-prediction
query.  This example walks the whole serving lifecycle —

  1. refresh the all-node serving store from the model (donated,
     in-place),
  2. answer batched queries through the hot-row cache (repeat traffic
     hits the cache, never the store),
  3. check served logits against the offline ``full_graph_forward``,
  4. "deploy" updated weights: one refresh bumps the store version and
     invalidates every cached row at once.

  PYTHONPATH=src python examples/serve_gnn.py --model gcn
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import serving
from repro.core.digest import (full_graph_forward, prepare_graph_data,
                               top_layer_reps)
from repro.graph import make_dataset
from repro.launch.serving_driver import run_serve_loop
from repro.models.gnn import GNNConfig, gnn_specs
from repro.nn import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="gcn",
                    choices=("gcn", "sage", "gat"))
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--batches", type=int, default=32)
    ap.add_argument("--cache-rows", type=int, default=256)
    args = ap.parse_args()

    g = make_dataset("flickr-sim", scale=0.25, seed=0)
    data = prepare_graph_data(g, 4, seed=0)
    cfg = GNNConfig(model=args.model, num_layers=2,
                    in_dim=g.features.shape[1], hidden_dim=64,
                    num_classes=int(g.labels.max()) + 1)
    params = init_params(jax.random.PRNGKey(0), gnn_specs(cfg))

    plan = serving.build_serve_plan(data)
    scfg = serving.ServeConfig(batch_size=args.batch,
                               cache_rows=args.cache_rows)
    store = serving.init_serve_store(plan, cfg.hidden_dim)
    refresh = serving.make_refresh_fn()
    rdata, qdata = plan.refresh_data(), plan.query_data()
    store = refresh(store, top_layer_reps(cfg, params, data), rdata)

    queries = serving.zipf_queries(g.num_nodes, args.batch, args.batches,
                                   skew=1.1, seed=1)
    cache = serving.init_cache(scfg, cfg.num_classes)

    def step(cache, q):
        logits, cache = serving.serve_query(cfg, scfg, params, store,
                                            cache, qdata, jnp.asarray(q))
        return cache, logits

    cache, outs, stats = run_serve_loop(step, queries, carry=cache,
                                        warmup=2,
                                        items_per_call=args.batch)
    print(f"{args.batches} batches x{args.batch} [{args.model}]: "
          f"p50 {stats.p50_ms:.2f} ms  {stats.per_sec:,.0f} q/s  "
          f"hit-rate {serving.hit_rate(cache):.3f}")

    ref = np.asarray(full_graph_forward(cfg, params, data)[0])
    err = max(float(np.abs(np.asarray(o) - ref[q]).max())
              for o, q in zip(outs, queries))
    print(f"served vs full_graph_forward: max |diff| = {err:.2e}")

    # Deploy new weights: one refresh, every cached row invalid at once.
    params2 = init_params(jax.random.PRNGKey(7), gnn_specs(cfg))
    store = refresh(store, top_layer_reps(cfg, params2, data), rdata)
    hits_before = int(cache["hits"])
    logits2, cache = serving.serve_query(cfg, scfg, params2, store, cache,
                                         qdata, jnp.asarray(queries[0]))
    ref2 = np.asarray(full_graph_forward(cfg, params2, data)[0])
    err2 = float(np.abs(np.asarray(logits2) - ref2[queries[0]]).max())
    print(f"post-refresh (store v{int(store['version'])}): stale hits "
          f"{int(cache['hits']) - hits_before}, max |diff| = {err2:.2e}")


if __name__ == "__main__":
    main()
