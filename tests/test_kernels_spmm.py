"""Pallas ELL SpMM kernel vs pure-jnp oracle: shape/dtype sweeps, plus the
fused HaloExchange pull+aggregate variant (precision-aware slab gather)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import halo_exchange as hx
from repro.kernels.spmm import (halo_spmm, halo_spmm_ref,
                                halo_spmm_stream_pallas, spmm, spmm_ref)


def _case(rng, rows, deg, ncols, feat, dtype):
    nbr = rng.integers(0, ncols + 1, size=(rows, deg)).astype(np.int32)
    wts = (rng.random((rows, deg)) * (nbr < ncols)).astype(np.float32)
    table = rng.normal(size=(ncols + 1, feat)).astype(dtype)
    table[-1] = 0
    return jnp.asarray(nbr), jnp.asarray(wts), jnp.asarray(table)


@pytest.mark.parametrize("rows,deg,ncols,feat", [
    (128, 4, 64, 128), (256, 16, 300, 128), (128, 1, 5, 256),
    (384, 9, 57, 70), (17, 3, 9, 33),
])
def test_spmm_matches_ref(rows, deg, ncols, feat):
    rng = np.random.default_rng(rows + deg)
    nbr, wts, table = _case(rng, rows, deg, ncols, feat, np.float32)
    out = spmm(nbr, wts, table, backend="pallas_interpret")
    ref = spmm_ref(nbr, wts, table)
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_spmm_dtypes(dtype):
    rng = np.random.default_rng(7)
    nbr, wts, table = _case(rng, 128, 8, 100, 128, np.float32)
    table = table.astype(dtype)
    out = spmm(nbr, wts, table, backend="pallas_interpret")
    ref = spmm_ref(nbr, wts, table)
    np.testing.assert_allclose(out, np.asarray(ref, np.float32),
                               atol=2e-2, rtol=2e-2)


@settings(max_examples=25, deadline=None)
@given(rows=st.integers(1, 200), deg=st.integers(1, 12),
       ncols=st.integers(1, 150), feat=st.integers(1, 160),
       seed=st.integers(0, 2**31 - 1))
def test_spmm_property(rows, deg, ncols, feat, seed):
    rng = np.random.default_rng(seed)
    nbr, wts, table = _case(rng, rows, deg, ncols, feat, np.float32)
    out = spmm(nbr, wts, table, backend="pallas_interpret")
    ref = spmm_ref(nbr, wts, table)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("storage", ["fp32", "bf16", "int8"])
def test_halo_spmm_fused_dequant(storage):
    """Fused pull+aggregate == dequantize-then-spmm, at every precision."""
    rng = np.random.default_rng(11)
    nbr, wts, table = _case(rng, 64, 6, 50, 48, np.float32)
    data, scale = hx.quantize_rows(table, hx.HaloPrecision(storage))
    # the sentinel row stays representable as exact zero
    data = data.at[-1].set(0)
    deq = hx.dequantize_rows(data, scale)
    want = spmm_ref(nbr, wts, deq)
    got_ref = halo_spmm_ref(nbr, wts, data, scale)
    got_pl = halo_spmm(nbr, wts, data, scale, backend="pallas_interpret")
    np.testing.assert_allclose(got_ref, want, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(got_pl, want, atol=1e-5, rtol=1e-5)


def test_halo_spmm_fp32_equals_spmm():
    """With an fp32 slab and no scales the fused kernel IS plain spmm."""
    rng = np.random.default_rng(13)
    nbr, wts, table = _case(rng, 128, 8, 100, 64, np.float32)
    np.testing.assert_array_equal(
        np.asarray(halo_spmm(nbr, wts, table, None, backend="jnp")),
        np.asarray(spmm(nbr, wts, table, backend="jnp")))


@pytest.mark.parametrize("storage", ["fp32", "bf16", "int8"])
def test_halo_spmm_streaming_matches_resident(storage):
    """The chunked double-buffered variant == the resident kernel within
    dtype tolerance, on a slab spanning several chunks (incl. a ragged
    final chunk)."""
    rng = np.random.default_rng(17)
    ncols, feat, chunk = 300, 64, 128       # 3 chunks: 128+128+45
    nbr, wts, table = _case(rng, 128, 6, ncols, feat, np.float32)
    data, scale = hx.quantize_rows(table, hx.HaloPrecision(storage))
    data = data.at[-1].set(0)
    want = halo_spmm(nbr, wts, data, scale, backend="pallas_interpret")
    got = halo_spmm_stream_pallas(nbr, wts, data, scale,
                                  chunk_rows=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_halo_spmm_stream_single_chunk_exact():
    """One chunk covering the whole slab: no reassociation — bitwise equal
    to the resident scaled kernel."""
    rng = np.random.default_rng(19)
    nbr, wts, table = _case(rng, 128, 4, 60, 128, np.float32)
    data, scale = hx.quantize_rows(table, hx.HaloPrecision("int8"))
    data = data.at[-1].set(0)
    want = halo_spmm(nbr, wts, data, scale, backend="pallas_interpret")
    got = halo_spmm_stream_pallas(nbr, wts, data, scale, chunk_rows=64,
                                  interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_halo_spmm_auto_streams_above_threshold():
    """ops.halo_spmm flips to the streaming kernel once the slab stripe
    outgrows the VMEM-resident budget.  The threshold is passed as a
    static argument (part of the jit cache key), so the shrunken value
    genuinely retraces — a monkeypatched module global would be invisible
    to an already-cached executable."""
    rng = np.random.default_rng(23)
    nbr, wts, table = _case(rng, 128, 5, 900, 64, np.float32)
    data, scale = hx.quantize_rows(table, hx.HaloPrecision("int8"))
    data = data.at[-1].set(0)
    want = halo_spmm_ref(nbr, wts, data, scale)
    # stripe = 901 rows · (64 B + 4 B scale) ≈ 61 KiB > 1 KiB → streams
    got = halo_spmm(nbr, wts, data, scale, backend="pallas_interpret",
                    resident_max_bytes=1024)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
    # streamed result == the explicitly-forced streaming backend, bitwise
    got_forced = halo_spmm(nbr, wts, data, scale,
                           backend="pallas_stream_interpret")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got_forced))


def test_spmm_dense_oracle():
    """ELL result == dense P @ H for a real partition matrix."""
    from repro.graph import make_dataset, build_partitions
    g = make_dataset("flickr-sim", scale=0.1)
    sp = build_partitions(g, 2)
    m = 0
    x = np.random.default_rng(0).normal(
        size=(sp.part_size + 1, 64)).astype(np.float32)
    x[-1] = 0
    out = spmm(jnp.asarray(sp.in_nbr[m]), jnp.asarray(sp.in_wts[m]),
               jnp.asarray(x), backend="pallas_interpret")
    # dense reconstruction
    S = sp.part_size
    P = np.zeros((S, S + 1))
    for i in range(S):
        for kk in range(sp.in_nbr.shape[-1]):
            P[i, sp.in_nbr[m, i, kk]] += sp.in_wts[m, i, kk]
    np.testing.assert_allclose(out, P @ x, atol=1e-4)
