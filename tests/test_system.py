"""End-to-end behaviour of the paper's system (DIGEST vs baselines).

These mirror the paper's empirical claims at CPU scale:
  * §5.2/Fig.3: digest ≈ propagation > partition in final quality;
  * Fig. 6: very large sync interval hurts vs moderate;
  * Thm 1: staleness error within the analytic bound;
  * Fig. 7: async (DIGEST-A) beats sync wall-clock under a straggler.
"""
import numpy as np
import pytest

from repro.core import (AsyncSettings, TrainSettings, digest_a_train,
                        digest_train, measure_error_and_bound,
                        prepare_graph_data, sync_time_per_round)
from repro.graph import make_dataset
from repro.models.gnn import GNNConfig
from repro.optim import adam


@pytest.fixture(scope="module")
def setup():
    g = make_dataset("flickr-sim", scale=0.3, seed=1)
    data = prepare_graph_data(g, 4)
    cfg = GNNConfig(model="gcn", num_layers=3,
                    in_dim=g.features.shape[1], hidden_dim=64,
                    num_classes=int(g.labels.max()) + 1)
    return g, data, cfg


def _train(cfg, data, mode, epochs=80, interval=5, seed=0):
    _, hist = digest_train(cfg, adam(5e-3), data,
                           TrainSettings(sync_interval=interval, mode=mode),
                           epochs=epochs, eval_every=epochs, seed=seed)
    return hist


def test_digest_beats_partition(setup):
    _, data, cfg = setup
    h_dig = _train(cfg, data, "digest")
    h_par = _train(cfg, data, "partition")
    h_pro = _train(cfg, data, "propagation")
    assert h_dig["val_f1"][-1] > h_par["val_f1"][-1]
    # digest must be close to the no-information-loss upper bound
    assert h_dig["val_f1"][-1] > h_pro["val_f1"][-1] - 0.05


def test_training_reduces_loss(setup):
    _, data, cfg = setup
    h = _train(cfg, data, "digest", epochs=60)
    assert h["loss"][-1] < 2.0
    assert h["train_f1"][-1] > 0.3


def test_sync_interval_sensitivity(setup):
    """Fig. 6: staleness grows with N; N=1 has the least staleness error."""
    _, data, cfg = setup
    eps = {}
    for interval in (1, 20):
        h = _train(cfg, data, "digest", epochs=60, interval=interval)
        eps[interval] = np.mean(h["staleness_eps"][-1])
    assert eps[1] <= eps[20] + 1e-3


def test_error_bound_holds(setup):
    _, data, cfg = setup
    st, _ = digest_train(cfg, adam(5e-3), data,
                         TrainSettings(sync_interval=10), epochs=25,
                         eval_every=25)
    res = measure_error_and_bound(cfg, st["params"], data, st["store"])
    assert res["err_measured"] <= res["bound"]
    assert np.isfinite(res["err_measured"])
    # fp32 storage: no quantization term, corrected bound degenerates
    assert res["eps_quant"] == [0.0] * (cfg.num_layers - 1)
    assert res["bound_with_quant"] == res["bound"]


def test_error_bound_quantization_term(setup):
    """int8 storage surfaces the explicit scale/2·√d term: ε_quant > 0,
    the corrected bound dominates the plain one, and the measured error
    still sits under it."""
    from repro.core.halo_exchange import HaloPrecision

    _, data, cfg = setup
    st, _ = digest_train(cfg, adam(5e-3), data,
                         TrainSettings(sync_interval=10,
                                       precision=HaloPrecision("int8")),
                         epochs=25, eval_every=25)
    res = measure_error_and_bound(cfg, st["params"], data, st["store"])
    assert res["storage"] == "int8"
    assert all(e > 0 for e in res["eps_quant"])
    assert res["bound_with_quant"] > res["bound"]
    assert res["err_measured"] <= res["bound_with_quant"]
    # the int8 term really is scale/2·√d of the served rows
    d = cfg.hidden_dim
    max_scale = 2 * max(res["eps_quant"]) / np.sqrt(d)
    assert max_scale <= float(np.asarray(st["store"]["scale"]).max())


def test_async_straggler_advantage(setup):
    """DIGEST-A's simulated wall-clock per round beats the synchronous
    barrier when one worker is an 8-10s straggler (paper Fig. 7)."""
    _, data, cfg = setup
    settings = AsyncSettings(sync_interval=5, straggler=0, seed=3)
    _, hist = digest_a_train(cfg, adam(5e-3), data, settings,
                             total_rounds=40, eval_every_rounds=40)
    async_time_per_round = hist["sim_time"][-1] / hist["round"][-1]
    sync_time = sync_time_per_round(settings, 4)
    assert async_time_per_round < sync_time / 2
    assert np.isfinite(hist["val_f1"][-1])
    assert max(hist["delay"]) >= 1      # bounded-delay async really async


def test_async_converges(setup):
    _, data, cfg = setup
    _, hist = digest_a_train(cfg, adam(5e-3), data,
                             AsyncSettings(sync_interval=5),
                             total_rounds=160, eval_every_rounds=160)
    assert hist["val_f1"][-1] > 0.3
