"""Fig. 9 (appendix): out-of-subgraph / in-subgraph node ratio — the memory
overhead of buffering halo representations — plus the compact-vs-dense
HaloExchange store footprint under the owner-sharded layout.  The slab is
O(|boundary|·L·d) (boundary = union of subgraph halos) vs the dense
O(N·L·d) array, sharded 1/M per device; pull bytes compare the ragged
collective (Σ_m |halo(G_m)| rows per sync) against replicating the slab
(the PR-1 snapshot layout).  Partition quality is scored by what the
store actually pays for: edge cut, Σ_m |halo|, and |boundary| side by
side, plus the locality columns — worklist occupancy and the estimated
per-layer slab bytes the chunk-skipping stream moves vs the dense
stream (``partition_report``'s wl_* / stream_bytes_* keys)."""
from benchmarks.common import bench_scale, emit
from repro.core import HaloPrecision, HaloSpec
from repro.graph import build_partitions, make_dataset, partition_report

HIDDEN = 64
LAYERS = 3


def run() -> list[dict]:
    scale = bench_scale()
    rows = []
    for ds in ("arxiv-sim", "flickr-sim", "reddit-sim", "products-sim"):
        g = make_dataset(ds, scale=0.25 * scale)
        sp = build_partitions(g, 4)
        ratio = sp.halo_ratio()
        quality = partition_report(g, sp)
        spec = HaloSpec.from_partitions(sp, HIDDEN, LAYERS)
        spec8 = HaloSpec.from_partitions(sp, HIDDEN, LAYERS,
                                         HaloPrecision("int8"))
        dense = spec.dense_nbytes(g.num_nodes)
        sync = spec.comm_bytes(sp.pull_rows(), sp.push_rows())
        rows.append({"name": f"fig9/{ds}",
                     "us_per_call": "",
                     "halo_ratio_mean": round(float(ratio.mean()), 4),
                     "halo_ratio_max": round(float(ratio.max()), 4),
                     "avg_degree": round(g.num_edges / g.num_nodes, 2),
                     "boundary_frac": round(sp.boundary_fraction(), 4),
                     # partition quality: the §3.3 cost drivers next to
                     # the classic edge-cut objective
                     "edge_cut": quality["edge_cut"],
                     "halo_rows": quality["halo_rows"],
                     "boundary": quality["boundary"],
                     "balance": round(quality["balance"], 4),
                     # locality: streamed-kernel worklist occupancy and
                     # estimated bytes moved (skip vs dense stream)
                     "wl_occupancy": round(quality["wl_occupancy"], 4),
                     "wl_visited": quality["wl_visited"],
                     "stream_mb_skip": round(
                         quality["stream_bytes_skip"] / 1e6, 4),
                     "stream_mb_dense": round(
                         quality["stream_bytes_dense"] / 1e6, 4),
                     "dense_store_mb": round(dense / 1e6, 4),
                     "compact_fp32_mb": round(spec.store_nbytes() / 1e6, 4),
                     "compact_int8_mb": round(spec8.store_nbytes() / 1e6,
                                              4),
                     # owner-sharded residency: bytes each device keeps
                     "per_device_fp32_mb": round(spec.shard_nbytes() / 1e6,
                                                 4),
                     "per_device_int8_mb": round(spec8.shard_nbytes() / 1e6,
                                                 4),
                     # pull wire: ragged collective vs replicating the slab
                     "pull_sharded_mb": round(sync["pull_bytes"] / 1e6, 4),
                     "pull_replicated_mb": round(
                         spec.replicated_pull_nbytes() / 1e6, 4),
                     "mem_ratio_fp32": round(spec.store_nbytes() / dense,
                                             4),
                     "mem_ratio_int8": round(spec8.store_nbytes() / dense,
                                             4)})
    return rows


if __name__ == "__main__":
    emit(run())
