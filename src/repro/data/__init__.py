from repro.data.pipeline import (SyntheticLMDataset, TokenBatch,
                                 make_lm_pipeline)

__all__ = ["SyntheticLMDataset", "TokenBatch", "make_lm_pipeline"]
