"""Jitted public entry point for neighbor aggregation.

Dispatch: ``backend="auto"`` uses the Pallas kernel on TPU and the pure-jnp
reference on CPU (interpret-mode Pallas is Python-slow; the oracle is the
same math).  Tests pin ``backend="pallas_interpret"`` to validate the kernel
body itself.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.spmm.halo_pull import halo_spmm_pallas
from repro.kernels.spmm.ref import halo_spmm_ref, spmm_ref
from repro.kernels.spmm.spmm import spmm_pallas


def _pad_dim(x: jax.Array, axis: int, multiple: int,
             value=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("backend",))
def spmm(nbr: jax.Array, wts: jax.Array, table: jax.Array,
         backend: str = "auto") -> jax.Array:
    """Neighbor aggregation out[i] = Σ_k wts[i,k]·table[nbr[i,k]].

    Handles arbitrary (unpadded) shapes by padding to kernel block sizes.
    """
    if backend == "auto":
        backend = ("pallas" if jax.default_backend() == "tpu" else "jnp")
    if backend == "jnp":
        return spmm_ref(nbr, wts, table)

    interpret = backend != "pallas"
    rows, feat = nbr.shape[0], table.shape[1]
    nbr_p = _pad_dim(nbr, 0, 128, value=table.shape[0] - 1)
    wts_p = _pad_dim(wts, 0, 128, value=0)
    tab_p = _pad_dim(table, 1, 128, value=0)
    out = spmm_pallas(nbr_p, wts_p, tab_p, interpret=interpret)
    return out[:rows, :feat]


@functools.partial(jax.jit, static_argnames=("backend",))
def halo_spmm(nbr: jax.Array, wts: jax.Array, data: jax.Array,
              scale: jax.Array = None, backend: str = "auto") -> jax.Array:
    """Fused halo pull+aggregate against the compact HaloExchange slab.

    out[i] = Σ_k wts[i,k] · dequant(data[nbr[i,k]]) with optional per-row
    int8 scales — the out-of-subgraph side of Eq. 5 read directly from
    storage precision (no materialized per-subgraph halo table).
    """
    if backend == "auto":
        backend = ("pallas" if jax.default_backend() == "tpu" else "jnp")
    if backend == "jnp":
        return halo_spmm_ref(nbr, wts, data, scale)

    interpret = backend != "pallas"
    rows, feat = nbr.shape[0], data.shape[1]
    nbr_p = _pad_dim(nbr, 0, 128, value=data.shape[0] - 1)
    wts_p = _pad_dim(wts, 0, 128, value=0)
    dat_p = _pad_dim(data, 1, 128, value=0)
    out = halo_spmm_pallas(nbr_p, wts_p, dat_p, scale, interpret=interpret)
    return out[:rows, :feat]
