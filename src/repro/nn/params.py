"""Parameter specification, initialization, and logical-axis metadata.

The framework is deliberately functional (no flax): a model is described by a
pytree of :class:`ParamSpec` leaves.  ``init_params`` turns the spec tree into
an array pytree; ``param_axes`` extracts the parallel pytree of logical axis
names used by the distribution layer to derive shardings (MaxText-style
logical axis rules, see ``repro.distributed.sharding``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative description of one parameter tensor."""

    shape: tuple[int, ...]
    # Logical axis name per dim (None = replicated / unnamed dim).
    axes: tuple[Optional[str], ...]
    init: str = "lecun"  # lecun | normal | zeros | ones | embed
    dtype: Any = jnp.float32
    scale: float = 1.0
    # Dims treated as fan-in for variance-scaling inits.
    fan_in_dims: tuple[int, ...] = (0,)

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(
                f"shape {self.shape} and axes {self.axes} rank mismatch")


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_leaf(key: jax.Array, spec: ParamSpec) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    fan_in = max(1, int(np.prod([spec.shape[d] for d in spec.fan_in_dims])))
    if spec.init == "lecun":
        std = spec.scale * math.sqrt(1.0 / fan_in)
    elif spec.init == "normal":
        std = spec.scale * 0.02
    elif spec.init == "embed":
        std = spec.scale * 1.0
    else:
        raise ValueError(f"unknown init {spec.init!r}")
    return (std * jax.random.normal(key, spec.shape, jnp.float32)).astype(
        spec.dtype)


def init_params(key: jax.Array, specs: Pytree) -> Pytree:
    """Initialize an array pytree from a ParamSpec pytree."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    arrays = [_init_leaf(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrays)


def param_axes(specs: Pytree) -> Pytree:
    """Pytree of logical-axis tuples, parallel to init_params output."""
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def abstract_params(specs: Pytree) -> Pytree:
    """ShapeDtypeStruct pytree (for dry-run lowering, no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs,
        is_leaf=is_spec)


def param_count(specs: Pytree) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))


def param_bytes(specs: Pytree) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return int(sum(np.prod(s.shape) * jnp.dtype(s.dtype).itemsize
                   for s in leaves))
