"""HaloExchange compact store: push/pull semantics, precision, and parity
with the dense reference store (repro.core.stale_store)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import halo_exchange as hx
from repro.core import stale_store
from repro.graph import build_partitions, make_dataset


def test_push_pull_roundtrip_compact():
    store = hx.init_store(2, 10, 4)
    slots = jnp.asarray([[0, 3, 10], [5, 7, 10]])       # 10 = sentinel pad
    valid = jnp.asarray([[True, True, False], [True, True, False]])
    reps = jnp.arange(2 * 2 * 3 * 4, dtype=jnp.float32).reshape(2, 2, 3, 4)
    store = hx.push(store, slots, valid, reps)
    pulled = hx.pull(store, slots)
    np.testing.assert_allclose(np.asarray(pulled)[:, :, :2],
                               np.asarray(reps)[:, :, :2])
    # sentinel row must stay zero (padding reads are zeros)
    assert float(jnp.abs(store["data"][:, 10]).max()) == 0.0


@pytest.mark.parametrize("storage", ["fp32", "bf16", "int8"])
def test_sentinel_stays_zero_all_precisions(storage):
    store = hx.init_store(1, 6, 8, hx.HaloPrecision(storage))
    slots = jnp.asarray([[0, 2, 6, 6]])
    valid = jnp.asarray([[True, True, True, False]])   # valid row → sentinel
    reps = jnp.full((1, 1, 4, 8), 3.7, jnp.float32)
    store = hx.push(store, slots, valid, reps)
    assert float(jnp.abs(store["data"][:, 6].astype(jnp.float32)).max()) == 0
    pulled = hx.pull(store, jnp.asarray([[6, 6]]))
    assert float(jnp.abs(pulled).max()) == 0.0


def test_pull_shape():
    store = hx.init_store(3, 20, 8)
    slots = jnp.asarray([[1, 2, 20], [4, 20, 20]])
    assert hx.pull(store, slots).shape == (2, 3, 3, 8)


def test_int8_quantization_error_bound():
    rng = np.random.default_rng(0)
    reps = rng.normal(size=(2, 2, 5, 16)).astype(np.float32) * 3.0
    store = hx.init_store(2, 10, 16, hx.HaloPrecision("int8"))
    slots = jnp.asarray([[0, 1, 2, 3, 4], [5, 6, 7, 8, 9]])
    valid = jnp.ones((2, 5), bool)
    store = hx.push(store, slots, valid, jnp.asarray(reps))
    pulled = np.asarray(hx.pull(store, slots))
    # symmetric per-row int8: |err| <= scale/2 = max|row| / 254, use /127
    bound = np.abs(reps).max(axis=-1, keepdims=True) / 127.0
    assert (np.abs(pulled - reps) <= bound + 1e-6).all()
    # and int8 really is the storage dtype
    assert store["data"].dtype == jnp.int8
    assert "scale" in store


def test_bf16_roundtrip_error():
    rng = np.random.default_rng(1)
    reps = rng.normal(size=(1, 1, 4, 8)).astype(np.float32)
    store = hx.init_store(1, 8, 8, hx.HaloPrecision("bf16"))
    slots = jnp.asarray([[0, 1, 2, 3]])
    store = hx.push(store, slots, jnp.ones((1, 4), bool), jnp.asarray(reps))
    pulled = np.asarray(hx.pull(store, slots))
    # bf16 has 8 significand bits → relative error ≤ 2^-8
    assert (np.abs(pulled - reps) <= np.abs(reps) * 2.0 ** -8 + 1e-7).all()


def test_error_feedback_unbiases_repeated_pushes():
    """Deterministic int8 rounding biases the served value of a constant
    rep; error feedback makes the time-average of repeated pushes
    converge to the true value (residual compensation)."""
    rng = np.random.default_rng(7)
    rows, hid, T = 6, 32, 64
    reps = jnp.asarray(rng.normal(size=(1, 1, rows, hid)), jnp.float32)
    slots = jnp.arange(rows, dtype=jnp.int32)[None]
    valid = jnp.ones((1, rows), bool)

    store = hx.init_store(1, rows, hid, hx.HaloPrecision("int8"))
    plain_store = hx.push(store, slots, valid, reps)
    plain = hx.pull(plain_store, slots)          # constant every push
    bias_plain = np.abs(np.asarray(jnp.mean(plain - reps, axis=-1))).max()

    residual = jnp.zeros_like(reps)
    acc = np.zeros(reps.shape, np.float64)
    ef_store = store
    for _ in range(T):
        ef_store, residual = hx.push_ef(ef_store, slots, valid, reps,
                                        residual)
        acc += np.asarray(hx.pull(ef_store, slots), np.float64)
    bias_ef = np.abs((acc / T - np.asarray(reps)).mean(axis=-1)).max()
    # residual stays bounded by one quantization step per element
    step = np.asarray(plain_store["scale"]).max()
    assert np.abs(np.asarray(residual)).max() <= step
    assert bias_ef < bias_plain * 0.5
    # single-shot error is still within the per-row quantization bound
    bound = np.abs(np.asarray(reps)).max(axis=-1, keepdims=True) / 127.0
    last = np.asarray(hx.pull(ef_store, slots))
    assert (np.abs(last - np.asarray(reps)) <= 2 * bound + 1e-6).all()


def test_error_feedback_fp32_is_identity():
    """With lossless storage the residual is exactly zero and push_ef
    degenerates to push."""
    rng = np.random.default_rng(9)
    reps = jnp.asarray(rng.normal(size=(1, 1, 4, 8)), jnp.float32)
    slots = jnp.asarray([[0, 1, 2, 3]])
    valid = jnp.ones((1, 4), bool)
    store = hx.init_store(1, 4, 8)
    s1 = hx.push(store, slots, valid, reps)
    s2, res = hx.push_ef(store, slots, valid, reps,
                         jnp.zeros_like(reps))
    np.testing.assert_array_equal(np.asarray(s1["data"]),
                                  np.asarray(s2["data"]))
    assert float(jnp.abs(res).max()) == 0.0


@pytest.fixture(scope="module")
def parts():
    g = make_dataset("flickr-sim", scale=0.1, seed=2)
    return g, build_partitions(g, 3)


def test_fp32_parity_with_dense_reference(parts):
    """Compact fp32 pull/push/staleness must agree with the dense seed
    store on every row it serves (boundary rows)."""
    g, sp = parts
    L1, hid = 2, 16
    rng = np.random.default_rng(3)
    reps = rng.normal(size=(sp.num_parts, L1, sp.part_size, hid)) \
        .astype(np.float32)
    lid = jnp.asarray(sp.local_ids)
    lval = jnp.asarray(sp.local_valid)

    dense = stale_store.init_store(L1, g.num_nodes, hid)
    dense = stale_store.push(dense, lid, lval, jnp.asarray(reps))
    compact = hx.init_store(L1, sp.store_rows - 1, hid)
    compact = hx.push(compact, jnp.asarray(sp.local_slots), lval,
                      jnp.asarray(reps), jnp.asarray(sp.sentinel_slots))

    # Every halo pull identical (halo rows are boundary by construction).
    want = stale_store.pull(dense, jnp.asarray(sp.halo_ids))
    got = hx.pull(compact, jnp.asarray(sp.halo_slots))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    # staleness_error identical when the dense one is masked to the rows
    # the compact store serves.
    fresh = jnp.asarray(reps + rng.normal(size=reps.shape)
                        .astype(np.float32) * 0.1)
    served = jnp.asarray(sp.local_boundary)
    eps_dense = stale_store.staleness_error(dense, fresh, lid, served)
    eps_compact = hx.staleness_error(compact, fresh,
                                     jnp.asarray(sp.local_slots), served)
    np.testing.assert_allclose(np.asarray(eps_compact),
                               np.asarray(eps_dense), rtol=1e-6)


def test_boundary_map_consistency(parts):
    """store_map / store_ids / slot views agree with the id views under
    the owner-sharded layout."""
    g, sp = parts
    M, sr = sp.num_parts, sp.shard_rows
    R = sp.store_rows
    assert R == M * sr
    B = sp.num_boundary
    assert (sp.store_ids != g.num_nodes).sum() == B
    # round-trip: slot → global → slot on real rows
    real = np.where(sp.store_ids != g.num_nodes)[0]
    assert (sp.store_map[sp.store_ids[real]] == real).all()
    # ownership: every real slot lies inside its owner's shard, and the
    # owner is the part the node is local to
    assert (sp.store_owner[real] == real // sr).all()
    assert (sp.assign[sp.store_ids[real]] == sp.store_owner[real]).all()
    # per-part sentinels are the last row of each shard, and unowned
    assert (sp.sentinel_slots == (np.arange(M) + 1) * sr - 1).all()
    assert (sp.store_ids[sp.sentinel_slots] == g.num_nodes).all()
    # every valid halo entry maps to a real slot, padding to the sentinel
    assert (sp.store_ids[sp.halo_slots[sp.halo_valid]] != g.num_nodes).all()
    assert (sp.halo_slots[~sp.halo_valid] == R - 1).all()
    # local views: boundary rows map into the part's own shard, the rest
    # to the part's sentinel row
    for m in range(M):
        b = sp.local_boundary[m]
        assert (sp.local_slots[m][b] // sr == m).all()
        assert (sp.local_slots[m][~b] == sp.sentinel_slots[m]).all()
    # out-ELL remaps are consistent with the halo-slot view
    ext_s = np.concatenate([sp.halo_slots, np.full((sp.num_parts, 1),
                                                   R - 1, np.int32)],
                           axis=1)
    ext_g = np.concatenate([sp.halo_ids, np.full((sp.num_parts, 1),
                                                 g.num_nodes, np.int32)],
                           axis=1)
    for m in range(sp.num_parts):
        np.testing.assert_array_equal(sp.out_nbr_store[m],
                                      ext_s[m][sp.out_nbr[m]])
        np.testing.assert_array_equal(sp.out_nbr_global[m],
                                      ext_g[m][sp.out_nbr[m]])


def test_pull_plan_routes_every_halo_entry(parts):
    """PullPlan send/recv lists cover each valid halo slot exactly once,
    with owner-local offsets inside the owner's shard."""
    g, sp = parts
    plan = sp.pull_plan()
    M, sr, H = sp.num_parts, sp.shard_rows, sp.halo_size
    covered = [set() for _ in range(M)]
    for m in range(M):                       # requester
        for j in range(M):                   # owner
            for k in range(plan.max_rows):
                pos = plan.recv_positions[m, j, k]
                off = plan.send_offsets[j, m, k]
                assert 0 <= off < sr
                if pos == H:                 # padding → sentinel row
                    assert off == sr - 1
                    continue
                slot = j * sr + off
                assert slot == sp.halo_slots[m, pos]
                assert pos not in covered[m]
                covered[m].add(pos)
    for m in range(M):
        assert covered[m] == set(np.where(sp.halo_valid[m])[0])


def test_partition_report_metrics(parts):
    from repro.graph import partition_report
    g, sp = parts
    rep = partition_report(g, sp)
    assert rep["boundary"] == sp.num_boundary
    assert rep["halo_rows"] == sp.pull_rows()
    # |boundary| ≤ Σ|halo| (union vs multiset) and both positive here
    assert 0 < rep["boundary"] <= rep["halo_rows"]
    assert rep["edge_cut"] > 0
    assert rep["balance"] >= 1.0


def test_comm_and_memory_accounting(parts):
    g, sp = parts
    spec32 = hx.HaloSpec.from_partitions(sp, 64, 3)
    spec8 = hx.HaloSpec.from_partitions(sp, 64, 3, hx.HaloPrecision("int8"))
    # compact store is O(|boundary|) (plus per-shard padding), not O(N)
    assert spec32.store_nbytes() == 2 * sp.store_rows * 64 * 4
    # owner-sharded: per-device residency is exactly 1/M of the slab and
    # beats the dense layout even on this tiny near-total-boundary
    # fixture; the ragged pull ships less than replicating the slab would
    assert spec32.shard_nbytes() == spec32.store_nbytes() // sp.num_parts
    assert spec32.shard_nbytes() < spec32.dense_nbytes(g.num_nodes)
    c32 = spec32.comm_bytes(sp.pull_rows(), sp.push_rows())
    # the replicated baseline is the unpadded compact slab, not the
    # owner-sharded (padded) storage layout.  (On this tiny near-total-
    # boundary fixture the ragged pull can exceed it — Σ|halo| ≈ M·B —
    # so no directional assert here; fig9 reports both at real scale.)
    assert (spec32.replicated_pull_nbytes()
            == (sp.num_parts - 1) * 2 * (sp.num_boundary + 1) * 64 * 4)
    # int8 wire bytes ≈ 4× less than fp32 (modulo the per-row scale)
    c8 = spec8.comm_bytes(sp.pull_rows(), sp.push_rows())
    assert c8["total_bytes"] < c32["total_bytes"] / 3
    ratio = c32["pull_bytes"] / c8["pull_bytes"]
    assert 3.0 < ratio <= 4.0
