from repro.models.gnn import GNNConfig, gnn_forward, gnn_specs

__all__ = ["GNNConfig", "gnn_forward", "gnn_specs"]
