#!/usr/bin/env python
"""Build the EXPERIMENTS.md §Roofline table from the dry-run JSONL output.

MODEL_FLOPS convention (documented in EXPERIMENTS.md):
  train    6 · (N_active_body + d·V) · D      (fwd+bwd, remat-free ideal)
  prefill  2 · (N_active_body + d·V) · D
  decode   2 · (N_active_body + d·V) · D_step (D_step = batch·1 token)
divided by 256 chips to match the per-device HLO numbers.
N_active_body excludes embeddings and, for MoE, counts only the top-k
(+shared) experts per token. Attention score FLOPs are excluded from
MODEL_FLOPS (convention), which makes long-prefill ratios read high.
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.configs import ALIASES, get_arch               # noqa: E402
from repro.models.transformer import arch_specs           # noqa: E402
from repro.nn import param_count                          # noqa: E402
from repro.launch.specs import SHAPES                     # noqa: E402

NAME_TO_ID = {get_arch(a).name: a for a in ALIASES.values()}


def model_flops_per_chip(arch_name: str, shape: str, chips: int) -> float:
    cfg = get_arch(NAME_TO_ID[arch_name])
    total = param_count(arch_specs(cfg))
    embed = cfg.vocab_size * cfg.d_model * 2          # embed + lm_head
    body = total - embed
    if cfg.num_experts:
        n_moe_layers = sum(k == "moe" for k in cfg.pattern) * cfg.repeats
        per_expert = 3 * cfg.d_model * cfg.d_ff
        inactive = (cfg.num_experts - cfg.experts_per_token) * per_expert
        body -= inactive * n_moe_layers
    n_eff = body + cfg.d_model * cfg.vocab_size       # + lm_head matmul
    sh = SHAPES[shape]
    if sh["kind"] == "train":
        toks, mult = sh["batch"] * sh["seq"], 6
    elif sh["kind"] == "prefill":
        toks, mult = sh["batch"] * sh["seq"], 2
    else:
        toks, mult = sh["batch"], 2
    return mult * n_eff * toks / chips


def suggest(dom: str, row: dict) -> str:
    if dom == "memory":
        return ("cut HLO traffic: fewer remat recomputes / bf16 "
                "master-cast / fuse gather chains")
    if dom == "collective":
        return ("reduce all-gather volume: FSDP prefetch reuse, or shard "
                "weights less aggressively on the slow axis")
    return "raise MXU utilization: larger per-chip tiles, fewer pad lanes"


def emit_table(path: str, inter_pod: bool = False):
    rows = [json.loads(l) for l in open(path)]
    # keep the last record per (arch, shape, mesh)
    dedup = {}
    for r in rows:
        dedup[(r["arch"], r["shape"], r["mesh"])] = r
    extra = " inter-pod GB |" if inter_pod else ""
    print("| arch | shape | mesh | compute s | memory s | collective s |"
          f" dominant | MODEL_TFLOP/chip | MF/HLO | fits (GB/chip) |{extra}")
    print("|---|---|---|---|---|---|---|---|---|---|"
          + ("---|" if inter_pod else ""))
    for (arch, shape, mesh), r in sorted(dedup.items()):
        terms = {"compute": r["compute_term_s"],
                 "memory": r["memory_term_s"],
                 "collective": r["collective_term_s"]}
        dom = max(terms, key=terms.get)
        mf = model_flops_per_chip(arch, shape, r["chips"])
        ratio = mf / r["hlo_flops"] if r["hlo_flops"] else float("nan")
        fit = (r.get("mem_temp_size_in_bytes", 0)
               + r.get("mem_argument_size_in_bytes", 0)) / 1e9
        tail = (f" {r.get('inter_pod_bytes', 0)/1e9:.3f} |"
                if inter_pod else "")
        print(f"| {arch} | {shape} | {mesh} "
              f"| {terms['compute']:.3g} | {terms['memory']:.3g} "
              f"| {terms['collective']:.3g} | **{dom}** "
              f"| {mf/1e12:.2f} | {ratio:.2f} | {fit:.1f} |{tail}")


def main():
    paths = sys.argv[1:] or ["results/dryrun_single.jsonl"]
    for i, path in enumerate(paths):
        if i:
            print()
        print(f"### {os.path.basename(path)}\n")
        emit_table(path, inter_pod="multi" in path)


if __name__ == "__main__":
    main()
