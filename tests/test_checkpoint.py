"""Checkpoint save/restore."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


def test_roundtrip(tmp_path):
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "layers": [jnp.ones((2,)), jnp.zeros((3,))]},
            "step": jnp.asarray(7)}
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 7
    np.testing.assert_allclose(restored["params"]["w"],
                               tree["params"]["w"])
    np.testing.assert_allclose(restored["params"]["layers"][0],
                               tree["params"]["layers"][0])


def test_latest_of_many(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 5, 3):
        save_checkpoint(str(tmp_path), s, tree)
    assert latest_step(str(tmp_path)) == 5


def test_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"x": jnp.zeros((3,))})


def test_missing_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "nope"), {"x": jnp.zeros(1)})
