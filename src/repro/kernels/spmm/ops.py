"""Jitted public entry point for neighbor aggregation.

Dispatch: ``backend="auto"`` uses the Pallas kernel on TPU and the pure-jnp
reference on CPU (interpret-mode Pallas is Python-slow; the oracle is the
same math).  Tests pin ``backend="pallas_interpret"`` to validate the kernel
body itself.

``halo_spmm``'s Pallas path picks between the VMEM-resident kernel and the
streaming double-buffered one automatically: if the slab's 128-wide
feature stripe would exceed ``RESIDENT_STRIPE_MAX_BYTES`` of VMEM it
streams in ``STREAM_CHUNK_ROWS`` tiles instead.  Pin
``backend="pallas_stream"`` / ``"pallas_stream_interpret"`` to force the
streaming variant (tests / benchmarks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.spmm.halo_pull import (STREAM_CHUNK_ROWS,
                                          halo_spmm_pallas,
                                          halo_spmm_stream_pallas)
from repro.kernels.spmm.ref import halo_spmm_ref, spmm_ref
from repro.kernels.spmm.spmm import BLOCK_F, spmm_pallas

# Largest slab stripe the resident kernel may carry whole into VMEM; a
# 128-wide fp32 stripe hits this at 8k rows (int8: 32k rows).  Above it,
# halo_spmm streams the slab through chunked double-buffered DMA.
RESIDENT_STRIPE_MAX_BYTES = 4 * 1024 * 1024


def _pad_dim(x: jax.Array, axis: int, multiple: int,
             value=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("backend",))
def spmm(nbr: jax.Array, wts: jax.Array, table: jax.Array,
         backend: str = "auto") -> jax.Array:
    """Neighbor aggregation out[i] = Σ_k wts[i,k]·table[nbr[i,k]].

    Handles arbitrary (unpadded) shapes by padding to kernel block sizes.
    """
    if backend == "auto":
        backend = ("pallas" if jax.default_backend() == "tpu" else "jnp")
    if backend == "jnp":
        return spmm_ref(nbr, wts, table)

    interpret = backend != "pallas"
    rows, feat = nbr.shape[0], table.shape[1]
    nbr_p = _pad_dim(nbr, 0, 128, value=table.shape[0] - 1)
    wts_p = _pad_dim(wts, 0, 128, value=0)
    tab_p = _pad_dim(table, 1, 128, value=0)
    out = spmm_pallas(nbr_p, wts_p, tab_p, interpret=interpret)
    return out[:rows, :feat]


@functools.partial(jax.jit,
                   static_argnames=("backend", "resident_max_bytes"))
def halo_spmm(nbr: jax.Array, wts: jax.Array, data: jax.Array,
              scale: jax.Array = None, backend: str = "auto",
              resident_max_bytes: int = None) -> jax.Array:
    """Fused halo pull+aggregate against the compact HaloExchange slab.

    out[i] = Σ_k wts[i,k] · dequant(data[nbr[i,k]]) with optional per-row
    int8 scales — the out-of-subgraph side of Eq. 5 read directly from
    storage precision (no materialized per-subgraph halo table).

    ``resident_max_bytes`` overrides the module-level auto-stream
    threshold; it is a static (jit-cache-keyed) argument, so an explicit
    override never aliases executables traced with the default.
    """
    if backend == "auto":
        backend = ("pallas" if jax.default_backend() == "tpu" else "jnp")
    if backend == "jnp":
        return halo_spmm_ref(nbr, wts, data, scale)

    interpret = backend not in ("pallas", "pallas_stream")
    stream = backend.startswith("pallas_stream")
    if not stream:
        # Auto-select: stream once the per-feature-block slab stripe
        # (data + scale column) outgrows the VMEM-resident budget.
        if resident_max_bytes is None:
            resident_max_bytes = RESIDENT_STRIPE_MAX_BYTES
        stripe = data.shape[0] * (min(BLOCK_F, data.shape[1])
                                  * data.dtype.itemsize
                                  + (4 if scale is not None else 0))
        stream = stripe > resident_max_bytes
    rows, feat = nbr.shape[0], data.shape[1]
    nbr_p = _pad_dim(nbr, 0, 128, value=data.shape[0] - 1)
    wts_p = _pad_dim(wts, 0, 128, value=0)
    dat_p = _pad_dim(data, 1, 128, value=0)
    if stream:
        out = halo_spmm_stream_pallas(nbr_p, wts_p, dat_p, scale,
                                      chunk_rows=STREAM_CHUNK_ROWS,
                                      interpret=interpret)
    else:
        out = halo_spmm_pallas(nbr_p, wts_p, dat_p, scale,
                               interpret=interpret)
    return out[:rows, :feat]
