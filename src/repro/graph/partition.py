"""Graph partitioning and the stacked per-subgraph ELL views DIGEST trains on.

The paper partitions with METIS; offline we implement a deterministic
multilevel-flavored greedy (LDG/Fennel-style streaming over a BFS order),
which like METIS optimizes edge cut under balance constraints, plus random
partitioning as the ablation baseline.

``build_partitions`` produces a :class:`StackedPartitions`: every subgraph
padded to identical (S, H, deg) sizes so the whole structure stacks into
(M, ...) arrays — directly shardable over the mesh "data" axis with one
subgraph per device slice, and vmap-able on CPU.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.graph import EllMatrix, Graph, coo_to_ell, gcn_norm_weights


# ---------------------------------------------------------------------------
# Partitioners
# ---------------------------------------------------------------------------

def random_partition(g: Graph, num_parts: int, seed: int = 0,
                     halo_weight: float = 0.0) -> np.ndarray:
    # halo_weight accepted (and ignored) so every PARTITIONERS entry has
    # the same signature under build_partitions.
    rng = np.random.default_rng(seed)
    assign = np.arange(g.num_nodes) % num_parts
    rng.shuffle(assign)
    return assign.astype(np.int32)


def greedy_partition(g: Graph, num_parts: int, seed: int = 0,
                     slack: float = 1.05,
                     halo_weight: float = 0.0) -> np.ndarray:
    """LDG-style streaming partition over a BFS order (METIS stand-in).

    ``halo_weight`` adds a boundary-aware term to the streaming score: the
    classic LDG objective minimizes *edge cut*, but the compact store's
    residency and §3.3's wire cost both scale with ``Σ_m |halo(G_m)|``
    (vertex replication), which equal-cut partitions can differ a lot on.
    With a positive weight each candidate part is charged the *marginal
    new halo rows* its assignment would create — v replicated into every
    other adjacent part, plus every out-of-part neighbor that is not yet
    a halo row of the candidate (tracked exactly during the stream) —
    and parts at capacity are masked out so the penalty cannot trade
    balance for halo (the additive term would otherwise defeat the
    multiplicative balance factor).  ``halo_weight=0`` reproduces the
    original assignments bit-for-bit; 0.1–0.25 trims Σ|halo| a few
    percent on the test graphs at unchanged balance (edge cut drifts up
    slightly — the point is that cut is the wrong cost proxy).

    Cost note: the exact tracking keeps a dense (num_parts, num_nodes)
    bool matrix and does O(num_parts · deg(v)) penalty work per vertex —
    fine for this offline host-side partitioner at the repo's graph
    sizes (≲ 1e5 nodes, M ≲ 64), but a per-node replica-set/bitmap
    variant is needed before pointing it at the 1M-node × 256-part
    dry-run regime (see ROADMAP).
    """
    n = g.num_nodes
    rng = np.random.default_rng(seed)
    capacity = slack * n / num_parts
    assign = np.full(n, -1, np.int32)
    sizes = np.zeros(num_parts, np.int64)

    # BFS order from random seeds → locality in the stream.
    order = np.empty(n, np.int64)
    seen = np.zeros(n, bool)
    pos = 0
    for root in rng.permutation(n):
        if seen[root]:
            continue
        queue = [root]
        seen[root] = True
        while queue:
            v = queue.pop()
            order[pos] = v
            pos += 1
            for u in g.neighbors(v):
                if not seen[u]:
                    seen[u] = True
                    queue.append(u)
    assert pos == n

    # is_halo[p, u]: u is already a halo row of part p under the partial
    # assignment — lets the halo term charge only *new* replicas.
    is_halo = np.zeros((num_parts, n), bool) if halo_weight else None

    for v in order:
        nbrs = g.neighbors(v)
        counts = np.zeros(num_parts, np.float64)
        assigned = assign[nbrs]
        valid = assigned >= 0
        anbrs = nbrs[valid]
        if valid.any():
            np.add.at(counts, assigned[valid], 1.0)
        score = counts * (1.0 - sizes / capacity)
        if halo_weight:
            present = counts > 0
            # Marginal Σ_m |halo| of assigning v to p: v becomes a halo
            # row of every other adjacent part, and each assigned
            # neighbor outside p becomes a halo row of p unless it
            # already is one.
            pen = np.full(num_parts, float(present.sum()))
            pen -= present
            if len(anbrs):
                au = assign[anbrs]
                fresh = ~is_halo[:, anbrs]               # (M, |anbrs|)
                out_of_p = au[None, :] != np.arange(num_parts)[:, None]
                pen += (fresh & out_of_p).sum(axis=1)
            score = score - halo_weight * pen
            score[sizes >= capacity] = -np.inf
        # Tie-break toward the emptiest part for balance.
        score += 1e-9 * (capacity - sizes)
        best = int(np.argmax(score))
        assign[v] = best
        sizes[best] += 1
        if halo_weight and len(anbrs):
            au = assign[anbrs]
            other = au != best
            is_halo[au[other], v] = True
            is_halo[best, anbrs[other]] = True
    return assign


def edge_cut(g: Graph, assign: np.ndarray) -> int:
    rows = np.repeat(np.arange(g.num_nodes), g.degrees().astype(np.int64))
    cols = g.indices
    return int(np.sum(assign[rows] != assign[cols]) // 2)


PARTITIONERS = {"greedy": greedy_partition, "random": random_partition,
                "metis": greedy_partition}


def parts_per_device(num_parts: int, num_devices: int,
                     what: str = "collective halo exchange") -> int:
    """k = num_parts / num_devices — owner shards (and subgraphs) on each
    exchange-axis device under the collective halo paths.

    ``num_devices`` counts every mesh axis the exchange shards M over:
    the "data" axis alone on a single-pod mesh, pods · data on the
    multi-pod ("pod", "data") mesh (see
    ``halo_exchange.exchange_axes``).  The collective pull/push block
    the owner-sharded slot space (and the PullPlan) into k contiguous
    shards per device, so any M that is a *multiple* of the device
    count works (M > pod size = parts-per-device > 1).  A non-multiple
    M would silently corrupt the owner-local slot math (a device could
    not tell where its shards start), so it is rejected loudly instead
    — this is the single authoritative check;
    ``halo_exchange.shards_per_device`` and
    ``StackedPartitions.shards_per_device`` both delegate here.
    """
    if num_devices <= 0 or num_parts % num_devices != 0:
        raise ValueError(
            f"{what}: num_parts={num_parts} must be a whole multiple of "
            f"the mesh exchange axes ({num_devices} devices — the "
            f"\"data\" axis, times \"pod\" on a multi-pod mesh) — each "
            f"device owns k = num_parts/{num_devices} contiguous "
            f"shards, but {num_parts} % {max(num_devices, 1)} = "
            f"{num_parts % num_devices if num_devices > 0 else num_parts}"
            f".  Use a part count divisible by the device count, or the "
            f"dense-gather fallback (pull_slab / push / "
            f"pull_mode='gather'), which is correct on any device count.")
    return num_parts // num_devices


def partition_report(g: Graph, sp: "StackedPartitions") -> dict:
    """Partition quality by what the compact store actually pays for.

    Edge cut is the classic METIS objective, but §3.3's wire cost scales
    with Σ_m |halo(G_m)| (rows pulled per sync) and the store residency
    with |boundary| (union of halos) — two partitions with equal cut can
    differ a lot on both.  Reported side by side so fig9 scores the real
    cost drivers.
    """
    sizes = sp.local_valid.sum(axis=1).astype(np.float64)
    return {
        "edge_cut": edge_cut(g, sp.assign),
        "halo_rows": sp.pull_rows(),              # Σ_m |halo(G_m)|
        "boundary": sp.num_boundary,              # |∪_m halo(G_m)|
        "boundary_frac": sp.boundary_fraction(),
        "balance": float(sizes.max() / max(sizes.mean(), 1.0)),
    }


# ---------------------------------------------------------------------------
# Streamed-kernel occupancy worklist
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ChunkWorklist:
    """Static (row-block × slab-chunk) occupancy of a streamed halo SpMM.

    The chunk-skipping kernel (``repro.kernels.spmm.halo_spmm_skip_pallas``)
    re-indexes the innermost grid dimension of the streamed pull+aggregate
    through this CSR-style worklist: row block i visits exactly the chunks
    ``ids[..., i, :cnt[..., i]]`` (ascending), instead of all
    ``n_chunks`` — owner-sharded halo references are strongly clustered
    by owner, so most (row_block, chunk) pairs reference nothing and DMA-
    ing them is pure waste.  ``ids`` is padded to the static
    ``max_chunks`` width with a *repeat of the last visited chunk* (0 for
    empty blocks), so padded grid steps re-address the block already in
    VMEM (no new DMA) and are masked out of the FMA by ``t >= cnt``.

    Computed once at partition time from the halo tables (numpy, host
    side); geometry must match the kernel call: ``block_rows`` rows per
    row block after the caller pads rows up to a ``block_rows`` multiple,
    ``chunk_rows``-row slab chunks over the (H+1)-row slab.
    """

    chunk_rows: int          # slab rows per streamed chunk
    block_rows: int          # output rows per row block (kernel BLOCK_ROWS)
    n_chunks: int            # ceil(slab_rows / chunk_rows)
    max_chunks: int          # static padded worklist width (grid dim)
    ids: np.ndarray          # (..., n_row_blocks, max_chunks) int32
    cnt: np.ndarray          # (..., n_row_blocks) int32 — valid prefix len

    @property
    def visited_chunks(self) -> int:
        """Σ chunk visits — what the skip kernel actually streams."""
        return int(self.cnt.sum())

    @property
    def total_pairs(self) -> int:
        """row_blocks × n_chunks (× M) — what the dense stream pays."""
        return int(np.prod(self.cnt.shape) * self.n_chunks)

    @property
    def occupancy(self) -> float:
        """visited / total — the static kernel-selection signal."""
        return self.visited_chunks / max(self.total_pairs, 1)


def build_chunk_worklist(nbr: np.ndarray, n_slab_rows: int,
                         chunk_rows: int, block_rows: int = 128
                         ) -> ChunkWorklist:
    """Occupancy worklist of an ELL adjacency against a slab.

    Args:
      nbr: (rows, deg) or (M, rows, deg) slab-row indices; the sentinel
        row ``n_slab_rows - 1`` (the zero row every padding entry points
        at) is excluded — chunks referenced only through it contribute
        exactly zero and are skipped.
      n_slab_rows: gather-table rows *before* chunk padding (H+1).
      chunk_rows / block_rows: streamed-kernel tile geometry; rows are
        assumed padded up to a ``block_rows`` multiple by the caller
        (``repro.kernels.spmm.ops`` pads to 128 = BLOCK_ROWS), extra rows
        referencing nothing.
    """
    nbr = np.asarray(nbr)
    stacked = nbr.ndim == 3
    batch = nbr.shape[0] if stacked else 1
    rows = nbr.shape[-2]
    n_blocks = max(-(-rows // block_rows), 1)
    n_chunks = max(-(-n_slab_rows // chunk_rows), 1)
    sentinel = n_slab_rows - 1

    flat = nbr.reshape(batch, rows, -1)
    block_of = np.minimum(np.arange(rows) // block_rows, n_blocks - 1)
    occ = np.zeros((batch, n_blocks, n_chunks), bool)
    for m in range(batch):
        valid = flat[m] < sentinel
        b = np.broadcast_to(block_of[:, None], flat[m].shape)[valid]
        occ[m, b, flat[m][valid] // chunk_rows] = True

    cnt = occ.sum(axis=2).astype(np.int32)
    max_chunks = max(int(cnt.max()), 1)
    ids = np.zeros((batch, n_blocks, max_chunks), np.int32)
    for m in range(batch):
        for i in range(n_blocks):
            ch = np.where(occ[m, i])[0]
            ids[m, i, :len(ch)] = ch
            # Pad with the last visited chunk: the pipeline re-addresses
            # the resident block instead of DMA-ing a fresh one.
            ids[m, i, len(ch):] = ch[-1] if len(ch) else 0
    if not stacked:
        ids, cnt = ids[0], cnt[0]
    return ChunkWorklist(chunk_rows=chunk_rows, block_rows=block_rows,
                         n_chunks=n_chunks, max_chunks=max_chunks,
                         ids=ids, cnt=cnt)


# ---------------------------------------------------------------------------
# Stacked per-subgraph views
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PullPlan:
    """Ragged per-(owner, requester) routing of the collective halo pull.

    For every requester m and owner j, the plan lists which rows of owner
    j's store *shard* feed subgraph m's halo slab, padded to a common
    width ``max_rows`` so the exchange is one dense ``all_to_all``:

      send_offsets[j, m, k]   owner-local row offset (< shard_rows) of the
                              k-th row owner j ships to requester m;
                              padding points at owner j's zero sentinel.
      recv_positions[m, j, k] halo-slab position (< H+1) where requester m
                              lands that row; padding points at slab row H
                              (the slab's zero sentinel).

    Both tables are **device-blockable**: offsets are owner-local and
    positions requester-local, so sharding the leading axis over a mesh
    data axis of D devices hands each device the k = M/D contiguous
    (owner-block, requester-block) slices it needs — this is what lets
    ``collective_pull``/``shard_push`` run with parts-per-device > 1
    (M exceeding the pod size) without rebuilding the plan.
    """

    max_rows: int                 # K — padded per-pair row count
    send_offsets: np.ndarray      # (M_owner, M_req, K) int32
    recv_positions: np.ndarray    # (M_req, M_owner, K) int32


@dataclasses.dataclass
class StackedPartitions:
    """All M subgraphs padded to identical sizes and stacked on axis 0.

    Sentinel id == num_nodes (a zero row is appended to every global table).

    Boundary / compact-store views: the **boundary set** is the union of
    all subgraph halos — the only rows the stale store ever serves.  Slots
    are **owner-sharded**: every boundary node is owned by the part it is
    local to, and the slot space is laid out as M contiguous shards of
    ``shard_rows`` rows each (``slot = owner · shard_rows + rank``), the
    last row of every shard a per-owner zero sentinel.  Device m of a
    "data"-sharded mesh therefore holds exactly the rows it pushes, and a
    pull is a collective gather of each subgraph's halo slots from the
    owner shards (see ``repro.core.halo_exchange``).  ``store_map`` sends
    non-boundary ids (and the global sentinel id N) to the *global*
    sentinel slot ``M·shard_rows − 1``.
    """

    num_nodes: int
    num_parts: int
    num_boundary: int        # |boundary| — true boundary nodes, no padding
    shard_rows: int          # rows per owner shard (incl. its sentinel row)
    assign: np.ndarray       # (N,) int32 node → owning part
    local_ids: np.ndarray    # (M, S) int32, global node id or sentinel
    local_valid: np.ndarray  # (M, S) bool
    halo_ids: np.ndarray     # (M, H) int32, global node id or sentinel
    halo_valid: np.ndarray   # (M, H) bool
    in_nbr: np.ndarray       # (M, S, Din) int32 → local slot index or S
    in_wts: np.ndarray       # (M, S, Din) float32
    out_nbr: np.ndarray      # (M, S, Dout) int32 → halo slot index or H
    out_wts: np.ndarray      # (M, S, Dout) float32
    labels: np.ndarray       # (M, S) int32
    train_mask: np.ndarray   # (M, S) bool (False at padding)
    val_mask: np.ndarray     # (M, S) bool
    test_mask: np.ndarray    # (M, S) bool
    # Owner-sharded compact-store indexing, emitted for HaloExchange.
    store_map: np.ndarray    # (N+1,) int32 global id → slot (sentinel: R-1)
    store_ids: np.ndarray    # (R,) int32 slot → global id, N at pad rows
    store_owner: np.ndarray  # (R,) int32 slot → owner part
    sentinel_slots: np.ndarray  # (M,) int32 per-part sentinel slot
    halo_slots: np.ndarray   # (M, H) int32 store slot of each halo entry
    local_slots: np.ndarray  # (M, S) int32 store slot of each local row
                             #   (part m's sentinel where not boundary)
    local_boundary: np.ndarray  # (M, S) bool valid AND boundary (served)
    out_nbr_store: np.ndarray   # (M, S, Dout) int32 → store slot or R-1
    out_nbr_global: np.ndarray  # (M, S, Dout) int32 → global id or N

    @property
    def part_size(self) -> int:
        return self.local_ids.shape[1]

    @property
    def halo_size(self) -> int:
        return self.halo_ids.shape[1]

    @property
    def store_rows(self) -> int:
        """Total slab rows R = num_parts · shard_rows (incl. sentinels)."""
        return len(self.store_ids)

    def halo_ratio(self) -> np.ndarray:
        """Paper Fig. 9 metric: |out-of-subgraph| / |in-subgraph| per part."""
        return (self.halo_valid.sum(axis=1)
                / np.maximum(self.local_valid.sum(axis=1), 1))

    def boundary_fraction(self) -> float:
        """|boundary| / N — the compact-vs-dense store row ratio."""
        return self.num_boundary / max(self.num_nodes, 1)

    def push_rows(self) -> int:
        """Σ_m |boundary ∩ V_m| — rows shipped per PUSH sync (§3.3)."""
        return int(self.local_boundary.sum())

    def pull_rows(self) -> int:
        """Σ_m |halo(G_m)| — rows shipped per PULL sync (§3.3)."""
        return int(self.halo_valid.sum())

    def shards_per_device(self, num_devices: int) -> int:
        """k = M / num_devices under the collective paths; raises the
        spelled-out ValueError of :func:`parts_per_device` when M is not
        a multiple (the collective slot math would silently be wrong;
        the dense-gather fallback is the correct choice there)."""
        return parts_per_device(self.num_parts, num_devices)

    def chunk_worklist(self, chunk_rows: int, block_rows: int = 128
                       ) -> ChunkWorklist:
        """Per-subgraph (row_block × chunk) occupancy of the out-ELL
        against the (H+1)-row pulled halo slab (see
        :class:`ChunkWorklist`): ids (M, n_blocks, max_chunks),
        cnt (M, n_blocks)."""
        return build_chunk_worklist(self.out_nbr, self.halo_size + 1,
                                    chunk_rows, block_rows)

    def pull_plan(self) -> PullPlan:
        """Ragged collective-pull routing (see :class:`PullPlan`)."""
        M, sr = self.num_parts, self.shard_rows
        owner_of = self.halo_slots // sr                  # (M, H)
        counts = np.zeros((M, M), np.int64)
        for m in range(M):
            np.add.at(counts[m], owner_of[m][self.halo_valid[m]], 1)
        K = max(int(counts.max()), 1)
        send_off = np.full((M, M, K), sr - 1, np.int32)
        recv_pos = np.full((M, M, K), self.halo_size, np.int32)
        for m in range(M):                                # requester
            for j in range(M):                            # owner
                sel = np.where(self.halo_valid[m] & (owner_of[m] == j))[0]
                send_off[j, m, :len(sel)] = (
                    self.halo_slots[m, sel] - j * sr)
                recv_pos[m, j, :len(sel)] = sel
        return PullPlan(max_rows=K, send_offsets=send_off,
                        recv_positions=recv_pos)


def build_partitions(g: Graph, num_parts: int, method: str = "greedy",
                     seed: int = 0, pad_multiple: int = 8,
                     halo_weight: float = 0.0) -> StackedPartitions:
    assign = PARTITIONERS[method](g, num_parts, seed=seed,
                                  halo_weight=halo_weight)
    n = g.num_nodes
    rows, cols, wts = gcn_norm_weights(g)

    def _pad_to(x: int) -> int:
        return max(((x + pad_multiple - 1) // pad_multiple) * pad_multiple,
                   pad_multiple)

    parts_local = [np.where(assign == m)[0].astype(np.int32)
                   for m in range(num_parts)]
    # Halo = out-of-subgraph endpoints of P rows owned by the part,
    # ordered by (owner, id): each subgraph's halo slab is then laid out
    # as contiguous owner runs — the slab-side mirror of the owner-
    # sharded store.  Local rows referencing few owners touch few slab
    # ranges, which is what makes the streamed kernel's (row_block ×
    # chunk) worklist sparse (gathers do no arithmetic, and the per-row
    # ELL edge order is untouched, so results are bitwise identical to
    # the id-sorted layout).
    parts_halo = []
    for m in range(num_parts):
        sel = assign[rows] == m
        out = assign[cols[sel]] != m
        halo = np.unique(cols[sel][out]).astype(np.int32)
        halo = halo[np.lexsort((halo, assign[halo]))]
        parts_halo.append(halo)

    S = _pad_to(max(len(p) for p in parts_local))
    H = _pad_to(max((len(h) for h in parts_halo), default=1))

    local_ids = np.full((num_parts, S), n, np.int32)
    local_valid = np.zeros((num_parts, S), bool)
    halo_ids = np.full((num_parts, H), n, np.int32)
    halo_valid = np.zeros((num_parts, H), bool)
    in_ells, out_ells = [], []
    max_din, max_dout = 1, 1

    for m in range(num_parts):
        loc, halo = parts_local[m], parts_halo[m]
        local_ids[m, :len(loc)] = loc
        local_valid[m, :len(loc)] = True
        halo_ids[m, :len(halo)] = halo
        halo_valid[m, :len(halo)] = True

        g2l = np.full(n + 1, S, np.int64)   # global → local slot
        g2l[loc] = np.arange(len(loc))
        g2h = np.full(n + 1, H, np.int64)   # global → halo slot
        g2h[halo] = np.arange(len(halo))

        sel = assign[rows] == m
        r_m, c_m, w_m = rows[sel], cols[sel], wts[sel]
        local_rows = g2l[r_m].astype(np.int32)
        is_in = assign[c_m] == m

        ell_in = coo_to_ell(local_rows[is_in],
                            g2l[c_m[is_in]].astype(np.int32),
                            w_m[is_in], S, S)
        ell_out = coo_to_ell(local_rows[~is_in],
                             g2h[c_m[~is_in]].astype(np.int32),
                             w_m[~is_in], S, H)
        in_ells.append(ell_in)
        out_ells.append(ell_out)
        max_din = max(max_din, ell_in.max_degree)
        max_dout = max(max_dout, ell_out.max_degree)

    max_din, max_dout = _pad_to(max_din), _pad_to(max_dout)

    def _stack(ells: list[EllMatrix], deg: int, n_cols: int):
        nbr = np.full((num_parts, S, deg), n_cols, np.int32)
        w = np.zeros((num_parts, S, deg), np.float32)
        for m, e in enumerate(ells):
            nbr[m, :, :e.max_degree] = e.nbr
            w[m, :, :e.max_degree] = e.wts
        return nbr, w

    in_nbr, in_wts = _stack(in_ells, max_din, S)
    out_nbr, out_wts = _stack(out_ells, max_dout, H)

    labels = np.zeros((num_parts, S), np.int32)
    tr = np.zeros((num_parts, S), bool)
    va = np.zeros((num_parts, S), bool)
    te = np.zeros((num_parts, S), bool)
    for m, loc in enumerate(parts_local):
        labels[m, :len(loc)] = g.labels[loc]
        tr[m, :len(loc)] = g.train_mask[loc]
        va[m, :len(loc)] = g.val_mask[loc]
        te[m, :len(loc)] = g.test_mask[loc]

    # Boundary set = union of all halos, laid out **owner-sharded**: part
    # m's locally-owned boundary nodes occupy the contiguous slot range
    # [m·shard_rows, m·shard_rows + |owned_m|), the last row of each shard
    # is that owner's zero sentinel, and the global sentinel (non-boundary
    # ids and id n) is the last row of the last shard.  Sharding the slab
    # slot-wise over the mesh "data" axis then gives every device exactly
    # the rows it pushes; pulls gather from the owner shards.
    boundary = (np.unique(np.concatenate(parts_halo))
                if any(len(h) for h in parts_halo)
                else np.empty(0, np.int32)).astype(np.int32)
    B = len(boundary)
    owned = [np.sort(boundary[assign[boundary] == m])
             for m in range(num_parts)]
    shard_rows = _pad_to(max((len(o) for o in owned), default=0) + 1)
    R = num_parts * shard_rows
    store_map = np.full(n + 1, R - 1, np.int32)
    store_ids = np.full(R, n, np.int32)
    store_owner = np.repeat(np.arange(num_parts, dtype=np.int32),
                            shard_rows)
    for m, o in enumerate(owned):
        slots = m * shard_rows + np.arange(len(o), dtype=np.int32)
        store_map[o] = slots
        store_ids[slots] = o
    sentinel_slots = ((np.arange(num_parts, dtype=np.int32) + 1)
                      * shard_rows - 1)
    halo_slots = store_map[halo_ids]
    raw_slots = store_map[local_ids]
    local_boundary = local_valid & (raw_slots != R - 1)
    # Non-boundary / padding local rows push into the *owner's* sentinel
    # row so scatters never leave the device-local shard.
    local_slots = np.where(local_boundary, raw_slots,
                           sentinel_slots[:, None]).astype(np.int32)

    # Per-part remaps of the out-ELL: halo-slot → store-slot / global id,
    # so the out-of-subgraph product can gather straight from the shared
    # compact slab (or from x_global for layer 0) with no per-part table.
    out_nbr_store = np.empty_like(out_nbr)
    out_nbr_global = np.empty_like(out_nbr)
    for m in range(num_parts):
        ext_s = np.concatenate([halo_slots[m], [R - 1]]).astype(np.int32)
        ext_g = np.concatenate([halo_ids[m], [n]]).astype(np.int32)
        out_nbr_store[m] = ext_s[out_nbr[m]]
        out_nbr_global[m] = ext_g[out_nbr[m]]

    return StackedPartitions(
        num_nodes=n, num_parts=num_parts, num_boundary=B,
        shard_rows=shard_rows, assign=assign,
        local_ids=local_ids, local_valid=local_valid,
        halo_ids=halo_ids, halo_valid=halo_valid,
        in_nbr=in_nbr, in_wts=in_wts, out_nbr=out_nbr, out_wts=out_wts,
        labels=labels, train_mask=tr, val_mask=va, test_mask=te,
        store_map=store_map, store_ids=store_ids, store_owner=store_owner,
        sentinel_slots=sentinel_slots,
        halo_slots=halo_slots, local_slots=local_slots,
        local_boundary=local_boundary,
        out_nbr_store=out_nbr_store, out_nbr_global=out_nbr_global)
