"""DIGEST core: the paper's contribution as a composable JAX module."""
from repro.core.digest import (MODES, TrainSettings,
                               check_collective_geometry, digest_train,
                               evaluate, full_graph_forward, gat_projected,
                               init_sampled_state, init_state,
                               make_epoch_fn, make_sampled_epoch_fn,
                               prepare_graph_data, project_store_tables,
                               sampled_train)
from repro.core.async_engine import (AsyncSettings, digest_a_train,
                                     store_geometry, sync_time_per_round)
from repro.core import faults
from repro.core.faults import (FaultConfig, FaultSchedule,
                               attach_fault_state, measured_staleness)
from repro.core.error_bound import measure_error_and_bound, quantization_eps
from repro.core.comm_model import (CommConstants, epoch_comm_bytes,
                                   epoch_time_model, khop_halo_sizes)
from repro.core import halo_exchange
from repro.core.halo_exchange import HaloPrecision, HaloSpec
from repro.core import serving
from repro.core.serving import (ServeConfig, ServePlan, build_serve_plan,
                                init_serve_store, make_refresh_fn,
                                refresh_or_degrade, serve_query,
                                serve_query_sharded)
from repro.core import stale_store
from repro.core import predictor
from repro.core.predictor import PredictorConfig

__all__ = [
    "MODES", "TrainSettings", "check_collective_geometry",
    "digest_train", "evaluate",
    "full_graph_forward", "gat_projected", "init_state", "make_epoch_fn",
    "prepare_graph_data", "project_store_tables",
    "init_sampled_state", "make_sampled_epoch_fn", "sampled_train",
    "AsyncSettings", "digest_a_train", "store_geometry",
    "sync_time_per_round",
    "faults", "FaultConfig", "FaultSchedule", "attach_fault_state",
    "measured_staleness",
    "measure_error_and_bound", "quantization_eps",
    "CommConstants",
    "epoch_comm_bytes", "epoch_time_model", "khop_halo_sizes",
    "halo_exchange", "HaloPrecision", "HaloSpec", "stale_store",
    "serving", "ServeConfig", "ServePlan", "build_serve_plan",
    "init_serve_store", "make_refresh_fn", "refresh_or_degrade",
    "serve_query", "serve_query_sharded",
    "predictor", "PredictorConfig",
]
