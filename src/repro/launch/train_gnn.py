#!/usr/bin/env python
"""SPMD DIGEST GNN training launcher.

The DIGEST epoch function is written over stacked (M, ...) subgraph arrays;
under pjit we shard that leading M axis over the mesh "data" axis — one
subgraph per device slice, which *is* Algorithm 1's `for m in parallel`.
On CPU (1 device) the same program runs vmapped; on a fleet, identical code.

  PYTHONPATH=src python -m repro.launch.train_gnn --dataset flickr-sim \
      --parts 4 --epochs 40
"""
from __future__ import annotations

import argparse
import time

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import HaloPrecision, TrainSettings, evaluate, init_state, \
    make_epoch_fn, prepare_graph_data
from repro.graph import make_dataset
from repro.launch.mesh import make_host_mesh
from repro.models.gnn import GNNConfig
from repro.optim import adam


def subgraph_shardings(data: dict, state: dict, mesh) -> tuple[dict, dict]:
    """Shard every stacked (M, ...) array over 'data'.  The compact
    HaloExchange store is sharded slot-wise (each device owns the boundary
    rows it pushes; pulls pay the wire, matching §3.3), while the pulled
    snapshot slab is replicated — every subgraph gathers arbitrary slots
    from it on non-pull epochs.  Params/opt replicated (GNN weights are
    tiny)."""
    rep = NamedSharding(mesh, P())
    m_shard = NamedSharding(mesh, P("data"))
    slot_shard = NamedSharding(mesh, P(None, "data", None))

    data_sh = {}
    for k, v in data.items():
        if k.startswith("_"):
            continue
        if k in ("x_global", "store_ids") or k.startswith("full_"):
            data_sh[k] = jax.tree.map(lambda _: rep, v)
        elif k == "struct":
            data_sh[k] = {kk: m_shard for kk in v}
        else:
            data_sh[k] = m_shard
    state_sh = {
        "params": jax.tree.map(lambda _: rep, state["params"]),
        "opt_state": jax.tree.map(lambda _: rep, state["opt_state"]),
        "store": jax.tree.map(lambda _: slot_shard, state["store"]),
        "cache": jax.tree.map(lambda _: rep, state["cache"]),
        "epoch": rep, "step": rep,
    }
    return data_sh, state_sh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="flickr-sim")
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--model", default="gcn")
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=40)
    ap.add_argument("--interval", type=int, default=10)
    ap.add_argument("--precision", default="fp32",
                    choices=("fp32", "bf16", "int8"),
                    help="HaloExchange wire/storage precision")
    ap.add_argument("--data-axis", type=int, default=1,
                    help="mesh data-axis size (1 on CPU)")
    args = ap.parse_args()

    g = make_dataset(args.dataset, scale=args.scale)
    data = prepare_graph_data(g, args.parts)
    cfg = GNNConfig(model=args.model, num_layers=3,
                    in_dim=g.features.shape[1], hidden_dim=64,
                    num_classes=int(g.labels.max()) + 1)
    opt = adam(5e-3)
    settings = TrainSettings(sync_interval=args.interval, mode="digest",
                             precision=HaloPrecision(args.precision))
    mesh = make_host_mesh(data=args.data_axis, model=1)

    state = init_state(cfg, opt, data, precision=settings.precision)
    tdata = {k: v for k, v in data.items() if not k.startswith("_")}
    data_sh, state_sh = subgraph_shardings(tdata, state, mesh)
    epoch_fn = jax.jit(make_epoch_fn(cfg, opt, settings),
                       in_shardings=(state_sh, data_sh))
    t0 = time.perf_counter()
    for e in range(args.epochs):
        state, m = epoch_fn(state, tdata)
    ev = evaluate(cfg, state["params"], tdata)
    print(f"mesh={dict(mesh.shape)} epochs={args.epochs} "
          f"loss={float(m['loss']):.4f} val_f1={float(ev['val_f1']):.4f} "
          f"({(time.perf_counter()-t0)/args.epochs:.3f}s/epoch)")


if __name__ == "__main__":
    main()
