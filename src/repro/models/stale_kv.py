"""Stale-KV block attention — DIGEST's mechanism applied to long context.

A token attending over a 524k-token history is the transformer analogue of
a GNN node aggregating over a huge neighborhood.  Following Eq. 4 of the
paper we split the "neighbors":

  * in-subgraph  → the local window (last W positions): attended exactly,
    from a ring-buffer KV cache.
  * out-of-subgraph → everything older: attended through a **stale summary
    table** (mean-pooled KV per R-token block) that is only updated
    ("pushed") once per R decode steps — periodic stale synchronization.

Cost per decode step: O(W + S/R) instead of O(S); for S=524288, W=4096,
R=64 that is 4096 + 8192 ≈ 12k keys — sub-quadratic end to end.

The two partial attentions are merged with the standard online-softmax
combine, so the local part is *exact* and only the far field is
approximated — mirroring DIGEST's fresh-in/stale-out split.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.attention import repeat_kv

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class StaleKVConfig:
    max_seq: int          # S (e.g. 524288)
    window: int = 4096    # W — exact local span
    ratio: int = 64       # R — tokens per stale summary slot

    @property
    def num_slots(self) -> int:
        return self.max_seq // self.ratio


def init_stale_kv_cache(cfg: StaleKVConfig, batch: int, kv_heads: int,
                        head_dim: int, dtype=jnp.bfloat16) -> dict:
    return {
        "k_win": jnp.zeros((batch, cfg.window, kv_heads, head_dim), dtype),
        "v_win": jnp.zeros((batch, cfg.window, kv_heads, head_dim), dtype),
        "k_sum": jnp.zeros((batch, cfg.num_slots, kv_heads, head_dim),
                           dtype),
        "v_sum": jnp.zeros((batch, cfg.num_slots, kv_heads, head_dim),
                           dtype),
        # Pending block accumulator (the not-yet-pushed fresh rows).
        "k_pend": jnp.zeros((batch, cfg.ratio, kv_heads, head_dim), dtype),
        "v_pend": jnp.zeros((batch, cfg.ratio, kv_heads, head_dim), dtype),
    }


def _partial_attn(q32: jax.Array, k: jax.Array, v: jax.Array,
                  mask: jax.Array) -> tuple[jax.Array, ...]:
    """Returns (m, l, acc) online-softmax partials.

    q32: (B, H, D) f32 (pre-scaled); k, v: (B, T, H, D); mask: (B, T)."""
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhd,bthd->bht", q32, kf)
    s = jnp.where(mask[:, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                               # (B, H)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bht,bthd->bhd", p, vf)
    return m, l, acc


def _merge(p1, p2):
    m1, l1, a1 = p1
    m2, l2, a2 = p2
    m = jnp.maximum(m1, m2)
    c1 = jnp.exp(m1 - m)
    c2 = jnp.exp(m2 - m)
    return m, c1 * l1 + c2 * l2, c1[..., None] * a1 + c2[..., None] * a2


def stale_kv_decode(cfg: StaleKVConfig, cache: dict, q: jax.Array,
                    k_new: jax.Array, v_new: jax.Array, pos: jax.Array
                    ) -> tuple[jax.Array, dict]:
    """One decode step with stale far-field attention.

    q: (B, 1, H, D); k_new, v_new: (B, 1, KV, D); pos: (B,) current index
    (same for all rows in SPMD use — we use pos[0] for control flow).
    Returns (attn_out (B,1,H,D), new cache).
    """
    b, _, h, d = q.shape
    kv = k_new.shape[2]
    rep = h // kv
    p = pos[0]

    # --- cache writes -----------------------------------------------------
    win_slot = p % cfg.window
    pend_slot = p % cfg.ratio
    cache = dict(cache)
    cache["k_win"] = jax.lax.dynamic_update_slice(
        cache["k_win"], k_new, (0, win_slot, 0, 0))
    cache["v_win"] = jax.lax.dynamic_update_slice(
        cache["v_win"], v_new, (0, win_slot, 0, 0))
    cache["k_pend"] = jax.lax.dynamic_update_slice(
        cache["k_pend"], k_new, (0, pend_slot, 0, 0))
    cache["v_pend"] = jax.lax.dynamic_update_slice(
        cache["v_pend"], v_new, (0, pend_slot, 0, 0))

    # Periodic PUSH: completed R-block → mean-pooled stale summary.
    def push(c):
        slot = p // cfg.ratio
        ks = jnp.mean(c["k_pend"].astype(jnp.float32), axis=1,
                      keepdims=True).astype(c["k_sum"].dtype)
        vs = jnp.mean(c["v_pend"].astype(jnp.float32), axis=1,
                      keepdims=True).astype(c["v_sum"].dtype)
        c = dict(c)
        c["k_sum"] = jax.lax.dynamic_update_slice(c["k_sum"], ks,
                                                  (0, slot, 0, 0))
        c["v_sum"] = jax.lax.dynamic_update_slice(c["v_sum"], vs,
                                                  (0, slot, 0, 0))
        return c

    cache = jax.lax.cond(pend_slot == cfg.ratio - 1, push, lambda c: c,
                         cache)

    # --- attention ---------------------------------------------------------
    q32 = q[:, 0].astype(jnp.float32) * (d ** -0.5)

    # Local window (exact). Ring positions: index i holds absolute position
    # i + window*floor(...) — valid iff abs_pos in (p-window, p].
    idx = jnp.arange(cfg.window)
    # Absolute position stored at ring index i:
    abs_pos = jnp.where(idx <= win_slot, p - win_slot + idx,
                        p - win_slot + idx - cfg.window)
    win_mask = (abs_pos >= 0) & (abs_pos > p - cfg.window) & (abs_pos <= p)
    part_local = _partial_attn(
        q32, repeat_kv(cache["k_win"], rep), repeat_kv(cache["v_win"], rep),
        jnp.broadcast_to(win_mask[None], (b, cfg.window)))

    # Stale far field: only slots fully outside the local window.
    slots = jnp.arange(cfg.num_slots)
    slot_end = (slots + 1) * cfg.ratio - 1
    sum_mask = slot_end < jnp.maximum(p - cfg.window + 1, 0)
    part_far = _partial_attn(
        q32, repeat_kv(cache["k_sum"], rep), repeat_kv(cache["v_sum"], rep),
        jnp.broadcast_to(sum_mask[None], (b, cfg.num_slots)))
    # Weight each summary slot by the R tokens it stands for.
    m_f, l_f, a_f = part_far
    part_far = (m_f, l_f * cfg.ratio, a_f * cfg.ratio)

    m, l, acc = _merge(part_local, part_far)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out[:, None].astype(q.dtype), cache


def summaries_from_full_kv(cfg: StaleKVConfig, k_full: jax.Array,
                           v_full: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Prefill→decode transition: pool an existing (B,S,KV,D) cache into
    the stale summary table."""
    b, s, kv, d = k_full.shape
    n = s // cfg.ratio
    ks = jnp.mean(k_full[:, :n * cfg.ratio].reshape(
        b, n, cfg.ratio, kv, d).astype(jnp.float32), axis=2)
    vs = jnp.mean(v_full[:, :n * cfg.ratio].reshape(
        b, n, cfg.ratio, kv, d).astype(jnp.float32), axis=2)
    return ks.astype(k_full.dtype), vs.astype(v_full.dtype)
