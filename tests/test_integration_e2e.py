"""End-to-end integration: full train state checkpoint round-trips, and a
short DIGEST LM training run with checkpoint/resume equivalence."""
import dataclasses

import jax
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import get_smoke_arch
from repro.data import make_lm_pipeline
from repro.train import TrainSettings, init_train_state, make_train_step


def _batches(n, vocab=64, batch=4, seq=16, seed=0):
    it = make_lm_pipeline(vocab, batch, seq, seed=seed)
    out = []
    for _ in range(n):
        b = next(it)
        out.append({"tokens": b.tokens, "labels": b.labels,
                    "mask": b.mask})
    return out


def test_train_state_checkpoint_roundtrip(tmp_path):
    cfg = dataclasses.replace(get_smoke_arch("qwen3-0.6b"), vocab_size=64)
    settings = TrainSettings(total_steps=20, warmup_steps=2)
    state = init_train_state(cfg, settings)
    step = jax.jit(make_train_step(cfg, settings))
    for b in _batches(3):
        state, _ = step(state, b)
    save_checkpoint(str(tmp_path), int(state["step"]), state)
    restored, s = restore_checkpoint(str(tmp_path), state)
    assert s == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6)


def test_resume_equivalence(tmp_path):
    """train(5) == train(3) → checkpoint → restore → train(2)."""
    cfg = dataclasses.replace(get_smoke_arch("musicgen-large"),
                              vocab_size=64)
    settings = TrainSettings(total_steps=20, warmup_steps=2)
    step = jax.jit(make_train_step(cfg, settings))
    batches = _batches(5)

    state_a = init_train_state(cfg, settings)
    for b in batches:
        state_a, _ = step(state_a, b)

    state_b = init_train_state(cfg, settings)
    for b in batches[:3]:
        state_b, _ = step(state_b, b)
    save_checkpoint(str(tmp_path), 3, state_b)
    state_b, _ = restore_checkpoint(str(tmp_path), state_b)
    for b in batches[3:]:
        state_b, _ = step(state_b, b)

    la = jax.tree.leaves(state_a["params"])
    lb = jax.tree.leaves(state_b["params"])
    for a, b in zip(la, lb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)
