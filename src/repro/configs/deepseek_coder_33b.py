"""deepseek-coder-33b [dense] — llama-arch code model.

[arXiv:2401.14196] 62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.
"""
import dataclasses
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b", family="dense",
    num_layers=62, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=19200, vocab_size=32256, head_dim=128,
    pattern=("attn",), rope_theta=100000.0,
    optimizer="adafactor", learning_rate=1.2e-4,
    source="arXiv:2401.14196",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=8, num_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=16,
    dtype="float32", optimizer="adamw")
