"""Fig. 9 (appendix): out-of-subgraph / in-subgraph node ratio — the memory
overhead of buffering halo representations — plus the compact-vs-dense
HaloExchange store footprint.  The compact slab is O(|boundary|·L·d)
(boundary = union of subgraph halos) vs the dense O(N·L·d) array, so the
reported bytes measure the algorithm, not an implementation artifact."""
from benchmarks.common import bench_scale, emit
from repro.core import HaloPrecision, HaloSpec
from repro.graph import build_partitions, make_dataset

HIDDEN = 64
LAYERS = 3


def run() -> list[dict]:
    scale = bench_scale()
    rows = []
    for ds in ("arxiv-sim", "flickr-sim", "reddit-sim", "products-sim"):
        g = make_dataset(ds, scale=0.25 * scale)
        sp = build_partitions(g, 4)
        ratio = sp.halo_ratio()
        spec = HaloSpec.from_partitions(sp, HIDDEN, LAYERS)
        spec8 = HaloSpec.from_partitions(sp, HIDDEN, LAYERS,
                                         HaloPrecision("int8"))
        dense = spec.dense_nbytes(g.num_nodes)
        rows.append({"name": f"fig9/{ds}",
                     "us_per_call": "",
                     "halo_ratio_mean": round(float(ratio.mean()), 4),
                     "halo_ratio_max": round(float(ratio.max()), 4),
                     "avg_degree": round(g.num_edges / g.num_nodes, 2),
                     "boundary_frac": round(sp.boundary_fraction(), 4),
                     "dense_store_mb": round(dense / 1e6, 4),
                     "compact_fp32_mb": round(spec.store_nbytes() / 1e6, 4),
                     "compact_int8_mb": round(spec8.store_nbytes() / 1e6,
                                              4),
                     "mem_ratio_fp32": round(spec.store_nbytes() / dense,
                                             4),
                     "mem_ratio_int8": round(spec8.store_nbytes() / dense,
                                             4)})
    return rows


if __name__ == "__main__":
    emit(run())
