"""DIGEST — synchronous distributed GNN training with periodic stale sync.

One code path implements all three framework families the paper compares
(§2, Fig. 1) by swapping what the out-of-subgraph halo tables contain:

  mode="digest"       stale reps pulled from the store every N epochs (ours)
  mode="partition"    nothing — cross-subgraph edges dropped (LLCG-family)
  mode="propagation"  fresh reps recomputed and exchanged every epoch
                      (DistDGL-family; exact but communication-heavy)

The epoch function is a single jitted SPMD program: subgraphs are vmapped on
CPU and sharded over the mesh "data" axis under pjit (see
repro.launch.train_gnn), which is the Algorithm-1 `for m in parallel` loop.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stale_store
from repro.graph.graph import Graph
from repro.graph.partition import StackedPartitions, build_partitions
from repro.models.gnn import GNNConfig, gnn_forward, gnn_specs
from repro.nn import init_params, micro_f1, softmax_cross_entropy
from repro.optim import Optimizer

Pytree = Any

MODES = ("digest", "partition", "propagation")


# ---------------------------------------------------------------------------
# Data preparation
# ---------------------------------------------------------------------------

def prepare_graph_data(g: Graph, num_parts: int, method: str = "greedy",
                       seed: int = 0) -> dict:
    """Build the jnp data dict consumed by the epoch function."""
    sp = build_partitions(g, num_parts, method=method, seed=seed)
    full = build_partitions(g, 1, method="random", seed=seed)
    x_global = np.concatenate(
        [g.features, np.zeros((1, g.features.shape[1]), np.float32)], axis=0)

    def _struct(s: StackedPartitions) -> dict:
        return {"in_nbr": jnp.asarray(s.in_nbr),
                "in_wts": jnp.asarray(s.in_wts),
                "out_nbr": jnp.asarray(s.out_nbr),
                "out_wts": jnp.asarray(s.out_wts)}

    return {
        "x_global": jnp.asarray(x_global),
        "struct": _struct(sp),
        "local_ids": jnp.asarray(sp.local_ids),
        "local_valid": jnp.asarray(sp.local_valid),
        "halo_ids": jnp.asarray(sp.halo_ids),
        "labels": jnp.asarray(sp.labels),
        "train_mask": jnp.asarray(sp.train_mask),
        "val_mask": jnp.asarray(sp.val_mask),
        "test_mask": jnp.asarray(sp.test_mask),
        # Full-graph (M=1) view for exact eval / propagation mode.
        "full_struct": _struct(full),
        "full_ids": jnp.asarray(full.local_ids),
        "full_valid": jnp.asarray(full.local_valid),
        "full_labels": jnp.asarray(full.labels),
        "full_train_mask": jnp.asarray(full.train_mask),
        "full_val_mask": jnp.asarray(full.val_mask),
        "full_test_mask": jnp.asarray(full.test_mask),
        # Host-side metadata (not traced).
        "_sp": sp,
        "_graph": g,
    }


def _subgraph_features(x_global: jax.Array, ids: jax.Array) -> jax.Array:
    return x_global[ids]


# ---------------------------------------------------------------------------
# Single-subgraph loss (shared by every mode and by DIGEST-A)
# ---------------------------------------------------------------------------

def make_subgraph_loss(cfg: GNNConfig):
    def loss_fn(params, x_local, halo_tables, struct, labels, mask):
        tables = [jax.lax.stop_gradient(t) for t in halo_tables]
        logits, push = gnn_forward(cfg, params, x_local, tables, struct)
        loss = softmax_cross_entropy(logits, labels, mask)
        return loss, (jnp.stack(push) if push else
                      jnp.zeros((0,) + x_local.shape), logits)
    return loss_fn


def full_graph_forward(cfg: GNNConfig, params: Pytree, data: dict
                       ) -> jax.Array:
    """Exact (no staleness, no partition) forward; returns (N_pad, classes)."""
    x = _subgraph_features(data["x_global"], data["full_ids"][0])
    # Halo is empty in the M=1 view: all out_nbr are sentinels. Supply
    # small correctly-shaped zero tables and remap sentinels into them.
    struct = {k: v[0] for k, v in data["full_struct"].items()}
    H = 8
    tables = [jnp.zeros((H, cfg.in_dim), jnp.float32)]
    tables += [jnp.zeros((H, cfg.hidden_dim), jnp.float32)
               for _ in range(cfg.num_layers - 1)]
    # Remap sentinel halo ids to the small dummy table's sentinel.
    struct = dict(struct)
    struct["out_nbr"] = jnp.minimum(struct["out_nbr"], H)
    logits, reps = gnn_forward(cfg, params, x, tables, struct)
    return logits, reps


# ---------------------------------------------------------------------------
# The DIGEST epoch (Algorithm 1, one global round r)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainSettings:
    sync_interval: int = 10          # N of Algorithm 1
    mode: str = "digest"
    pull_on_first_epoch: bool = False  # paper pulls only at r % N == 0
    # LLCG-style server correction (for the partition-based baseline): one
    # extra server-side gradient step per round on a sampled node batch
    # with FULL neighbor information [Ramezani et al. 2021].
    llcg_correction: bool = False
    correction_frac: float = 0.1
    correction_lr: float = 1e-3


def make_epoch_fn(cfg: GNNConfig, opt: Optimizer, settings: TrainSettings
                  ) -> Callable:
    if settings.mode not in MODES:
        raise ValueError(settings.mode)
    loss_fn = make_subgraph_loss(cfg)

    def epoch_fn(state: dict, data: dict) -> tuple[dict, dict]:
        r = state["epoch"] + 1            # 1-indexed, as in Algorithm 1
        x_halo0 = data["x_global"][data["halo_ids"]]        # (M, H, d)
        M = data["halo_ids"].shape[0]
        H = data["halo_ids"].shape[1]

        if settings.mode == "partition":
            halo_cache = jnp.zeros_like(state["halo_cache"])
            x_halo0 = jnp.zeros_like(x_halo0)
        elif settings.mode == "propagation":
            # Fresh exchange every epoch: exact reps at current params.
            _, reps = full_graph_forward(cfg, state["params"], data)
            fresh = jnp.stack(
                [jnp.concatenate(
                    [rep, jnp.zeros((1, rep.shape[-1]), rep.dtype)], 0)
                 for rep in reps])                        # (L-1, N+1, hid)
            halo_cache = jnp.swapaxes(
                fresh[:, data["halo_ids"], :], 0, 1)      # (M, L-1, H, hid)
        else:  # digest
            do_pull = (r % settings.sync_interval == 0)
            if settings.pull_on_first_epoch:
                do_pull = do_pull | (r == 1)
            halo_cache = jax.lax.cond(
                do_pull,
                lambda: stale_store.pull(state["store"], data["halo_ids"]),
                lambda: state["halo_cache"])

        x_local = data["x_global"][data["local_ids"]]       # (M, S, d)

        def per_subgraph_tables(m_cache):
            # m_cache: (L-1, H, hid) → list of per-layer tables
            return [m_cache[i] for i in range(cfg.num_layers - 1)]

        def sub_loss(params, x_loc, x_h0, m_cache, struct, labels, mask):
            tables = [x_h0] + per_subgraph_tables(m_cache)
            return loss_fn(params, x_loc, tables, struct, labels, mask)

        vg = jax.vmap(jax.value_and_grad(sub_loss, has_aux=True),
                      in_axes=(None, 0, 0, 0, 0, 0, 0))
        (losses, (push_reps, logits)), grads = vg(
            state["params"], x_local, x_halo0, halo_cache,
            data["struct"], data["labels"], data["train_mask"])

        # Global AGG (Algorithm 1 line 13): uniform average over subgraphs.
        mean_grads = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
        params, opt_state = opt.update(mean_grads, state["opt_state"],
                                       state["params"], state["step"])

        if settings.llcg_correction:
            # LLCG server correction: full-neighbor gradient on a sampled
            # node mini-batch, plain SGD on the server.
            key = jax.random.fold_in(jax.random.PRNGKey(17), r)
            sample = (jax.random.uniform(key, data["full_train_mask"][0]
                                         .shape)
                      < settings.correction_frac)
            corr_mask = data["full_train_mask"][0] & sample

            def server_loss(p):
                logits, _ = full_graph_forward(cfg, p, data)
                return softmax_cross_entropy(
                    logits, data["full_labels"][0],
                    corr_mask.astype(jnp.float32))

            corr_grads = jax.grad(server_loss)(params)
            params = jax.tree.map(
                lambda p, g: p - settings.correction_lr * g, params,
                corr_grads)

        # Periodic PUSH (lines 9–10): epochs r = 1, N+1, 2N+1, ...
        new_store = state["store"]
        eps = jnp.zeros((max(cfg.num_layers - 1, 1),), jnp.float32)
        if settings.mode == "digest" and cfg.num_layers > 1:
            do_push = ((r - 1) % settings.sync_interval == 0)
            eps = stale_store.staleness_error(
                state["store"], push_reps, data["local_ids"],
                data["local_valid"])
            new_store = jax.lax.cond(
                do_push,
                lambda: stale_store.push(state["store"], data["local_ids"],
                                         data["local_valid"], push_reps),
                lambda: state["store"])

        train_acc = micro_f1(logits, data["labels"],
                             data["train_mask"].astype(jnp.float32))
        new_state = {"params": params, "opt_state": opt_state,
                     "store": new_store, "halo_cache": halo_cache,
                     "epoch": r, "step": state["step"] + 1}
        metrics = {"loss": jnp.mean(losses), "train_f1": train_acc,
                   "staleness_eps": eps}
        return new_state, metrics

    return epoch_fn


# ---------------------------------------------------------------------------
# State init + high-level training loop
# ---------------------------------------------------------------------------

def init_state(cfg: GNNConfig, opt: Optimizer, data: dict, seed: int = 0
               ) -> dict:
    params = init_params(jax.random.PRNGKey(seed), gnn_specs(cfg))
    num_nodes = int(data["x_global"].shape[0] - 1)
    M, H = data["halo_ids"].shape
    store = stale_store.init_store(max(cfg.num_layers - 1, 1), num_nodes,
                                   cfg.hidden_dim)
    return {
        "params": params,
        "opt_state": opt.init(params),
        "store": store,
        "halo_cache": jnp.zeros((M, max(cfg.num_layers - 1, 1), H,
                                 cfg.hidden_dim), jnp.float32),
        "epoch": jnp.asarray(0, jnp.int32),
        "step": jnp.asarray(0, jnp.int32),
    }


@functools.partial(jax.jit, static_argnames=("cfg",))
def evaluate(cfg: GNNConfig, params: Pytree, data: dict) -> dict:
    logits, _ = full_graph_forward(cfg, params, data)
    out = {}
    for split in ("train", "val", "test"):
        mask = data[f"full_{split}_mask"][0].astype(jnp.float32)
        out[f"{split}_f1"] = micro_f1(logits, data["full_labels"][0], mask)
        out[f"{split}_loss"] = softmax_cross_entropy(
            logits, data["full_labels"][0], mask)
    return out


def digest_train(cfg: GNNConfig, opt: Optimizer, data: dict,
                 settings: TrainSettings, epochs: int,
                 eval_every: int = 10, seed: int = 0,
                 verbose: bool = False) -> tuple[dict, dict]:
    """Run training; returns (final_state, history dict of lists)."""
    state = init_state(cfg, opt, data, seed=seed)
    epoch_fn = jax.jit(make_epoch_fn(cfg, opt, settings))
    tdata = {k: v for k, v in data.items() if not k.startswith("_")}
    hist: dict[str, list] = {"epoch": [], "loss": [], "train_f1": [],
                             "val_f1": [], "test_f1": [], "time": [],
                             "staleness_eps": []}
    t0 = time.perf_counter()
    for e in range(epochs):
        state, m = epoch_fn(state, tdata)
        if (e + 1) % eval_every == 0 or e == epochs - 1:
            ev = evaluate(cfg, state["params"], tdata)
            hist["epoch"].append(e + 1)
            hist["loss"].append(float(m["loss"]))
            hist["train_f1"].append(float(m["train_f1"]))
            hist["val_f1"].append(float(ev["val_f1"]))
            hist["test_f1"].append(float(ev["test_f1"]))
            hist["staleness_eps"].append(
                np.asarray(m["staleness_eps"]).tolist())
            hist["time"].append(time.perf_counter() - t0)
            if verbose:
                print(f"[{settings.mode}] epoch {e+1:4d} "
                      f"loss {float(m['loss']):.4f} "
                      f"val_f1 {float(ev['val_f1']):.4f}")
    return state, hist
