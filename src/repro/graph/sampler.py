"""Seed-batched, fanout-bounded neighbor sampling for the mini-batch
training regime (VR-GCN-style control variates, arXiv 1710.10568).

The sampler is the *host-side* half of sampled DIGEST training: built once
at partition time from the stacked per-subgraph in-ELL
(:class:`repro.graph.partition.StackedPartitions` via the prepared data
dict), it draws one batch per optimizer step —

  * a **seed set** per subgraph: up to ``batch_seeds`` training rows whose
    loss terms make up this step's objective;
  * a **fanout-bounded edge sample** per local row: ``min(fanout, deg)``
    of the row's in-subgraph ELL entries, uniform without replacement,
    with the inverse-inclusion scale ``deg / n_sampled`` that makes the
    scaled sampled sum an unbiased estimator of the full neighbor sum.

The device-side estimator (``repro.models.gnn.gnn_forward_sampled``)
consumes the batch as *weight masks over the existing ELL*: sampled
entries aggregate fresh representations at ``in_wts · edge_scale``, the
complement reads the historical activations at the residual weight
``in_wts − in_wts · edge_scale`` — so when ``fanout >= deg`` the scale is
exactly 1.0, the residual weight is exactly 0.0, and the estimator
collapses bitwise to the full-batch aggregation (the property the parity
tests pin).

Determinism contract: batches are a pure function of ``(seed, step)`` —
drawn from a fresh ``np.random.default_rng([seed, step])`` per step, with
no dependence on call history, process state, or jax device count — so
any two runs (and any two mesh shapes) consume bitwise-identical batches.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class NeighborSampler:
    """Per-subgraph neighbor sampler over the stacked in-ELL.

    Build with :func:`build_sampler`; ``sample(step)`` returns the numpy
    batch dict the sampled epoch converts to device arrays:

      seed_mask   (M, S)       bool — sampled training rows (loss mask)
      edge_scale  (M, S, Din)  f32 — deg/n_sampled at sampled entries,
                               0.0 elsewhere (multiplies ``in_wts`` into
                               the fresh-term weights)
      edge_keep   (M, S, Din)  bool — sampled-entry indicator (drives the
                               GAT masked-attention fallback)
    """
    fanout: int
    batch_seeds: int
    seed: int
    in_valid: np.ndarray     # (M, S, Din) bool — real (non-sentinel) entries
    in_deg: np.ndarray       # (M, S) int64 — valid entries per row
    train_mask: np.ndarray   # (M, S) bool
    num_parts: int
    part_rows: int
    ell_width: int

    @property
    def max_in_degree(self) -> int:
        """Largest in-ELL degree; ``fanout >= max_in_degree`` makes the
        control-variate estimator exact (full-batch parity)."""
        return int(self.in_deg.max()) if self.in_deg.size else 0

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng([self.seed, int(step)])

    def sample(self, step: int) -> dict:
        rng = self._rng(step)
        M, S, Din = self.in_valid.shape

        # Seeds: up to batch_seeds train rows per part, uniform without
        # replacement (all of them when the part has fewer).
        seed_mask = np.zeros((M, S), bool)
        for m in range(M):
            rows = np.flatnonzero(self.train_mask[m])
            if rows.size > self.batch_seeds:
                rows = rng.choice(rows, size=self.batch_seeds,
                                  replace=False)
            seed_mask[m, rows] = True

        # Edges: rank i.i.d. uniforms over each row's valid entries; the
        # n_sampled smallest are the sample — uniform without replacement,
        # fully vectorized over the stacked ELL.
        n_samp = np.minimum(self.in_deg, self.fanout)          # (M, S)
        key = np.where(self.in_valid, rng.random((M, S, Din)), 2.0)
        order = np.argsort(key, axis=-1, kind="stable")
        ranks = np.empty_like(order)
        np.put_along_axis(ranks, order,
                          np.broadcast_to(np.arange(Din), (M, S, Din)),
                          axis=-1)
        edge_keep = (ranks < n_samp[..., None]) & self.in_valid

        # Inverse-inclusion scale, pinned to exactly 1.0 when the whole
        # neighborhood is sampled (deg <= fanout) so the residual weight
        # in_wts − in_wts·scale is exactly +0.0 — the bitwise-parity case.
        deg_f = self.in_deg.astype(np.float32)
        scale = np.where(self.in_deg <= self.fanout, np.float32(1.0),
                         deg_f / np.maximum(n_samp, 1).astype(np.float32))
        edge_scale = np.where(edge_keep, scale[..., None],
                              np.float32(0.0)).astype(np.float32)
        return {"seed_mask": seed_mask, "edge_scale": edge_scale,
                "edge_keep": edge_keep}

    def full_batch(self) -> dict:
        """The deterministic full-coverage batch: every train row a seed,
        every valid edge sampled at scale 1.0 — the sampled epoch then
        reproduces the full-batch epoch bitwise (gcn/sage)."""
        return {
            "seed_mask": self.train_mask.copy(),
            "edge_scale": self.in_valid.astype(np.float32),
            "edge_keep": self.in_valid.copy(),
        }


def build_sampler(data: dict, fanout: int, batch_seeds: int,
                  seed: int = 0) -> NeighborSampler:
    """Build the sampler from a prepared data dict
    (:func:`repro.core.digest.prepare_graph_data`) — partition time, host
    side, numpy only."""
    if fanout < 1:
        raise ValueError(f"fanout must be >= 1, got {fanout}")
    if batch_seeds < 1:
        raise ValueError(f"batch_seeds must be >= 1, got {batch_seeds}")
    in_nbr = np.asarray(data["struct"]["in_nbr"])
    M, S, Din = in_nbr.shape
    in_valid = in_nbr < S                       # sentinel == S
    return NeighborSampler(
        fanout=int(fanout), batch_seeds=int(batch_seeds), seed=int(seed),
        in_valid=in_valid, in_deg=in_valid.sum(axis=-1),
        train_mask=np.asarray(data["train_mask"]).astype(bool),
        num_parts=M, part_rows=S, ell_width=Din)
