"""DIGEST — synchronous distributed GNN training with periodic stale sync.

One code path implements all three framework families the paper compares
(§2, Fig. 1) by swapping what the out-of-subgraph halo tables contain:

  mode="digest"       stale reps pulled from the store every N epochs (ours)
  mode="partition"    nothing — cross-subgraph edges dropped (LLCG-family)
  mode="propagation"  fresh reps recomputed and exchanged every epoch
                      (DistDGL-family; exact but communication-heavy)

The epoch function is a single jitted SPMD program: subgraphs are vmapped on
CPU and sharded over the mesh "data" axis under pjit (see
repro.launch.train_gnn), which is the Algorithm-1 `for m in parallel` loop.

Stale state lives in the compact **owner-sharded** HaloExchange store
(boundary rows only, grouped by owning part, pluggable fp32/bf16/int8
precision — see repro.core.halo_exchange).  A PULL epoch gathers each
subgraph's halo rows into a device-local slab ``(M, L-1, H+1, hidden)``
— via the XLA-partitioned dense gather (all-gather fallback) or the
explicit ragged ``collective_pull`` when a mesh is supplied (any M that
is a multiple of the mesh "data" axis: each device then carries
k = M/devices subgraphs and owner shards) — and non-pull epochs read
that local slice *directly* through the fused pull+aggregate kernel:
nothing is replicated and no fp32 halo cache is ever materialized.

Under ``pull_mode="collective"`` the epoch is fully SPMD end to end:
PULL is the ragged ``all_to_all``, PUSH goes through the shard-local
``shard_push`` (owner-local offsets — structurally incapable of
cross-device writes), and the Theorem-1 staleness probe reads each
device's own shards (``shard_staleness_error``).  The compiled epoch
then contains *no* cross-device scatter/gather for the halo state at
all — a regression-tested invariant (tests/test_hlo_collectives.py),
not a partitioner heuristic.

The same ``pull_mode="collective"`` covers the multi-pod production
mesh: when the supplied mesh carries a "pod" axis, the halo-exchange
paths auto-detect it, shard M over the combined ("pod", "data") axes
(k = M/(pods·data) subgraphs and owner shards per device) and run the
PULL as the two-stage intra-pod ``all_to_all`` + inter-pod ``ppermute``
exchange — bitwise-equal to the single-pod collective and the dense
gather (tests/test_multipod.py; see the routing-table section of
``repro.core.halo_exchange``).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt_io
from repro.core import faults as faults_mod
from repro.core import halo_exchange
from repro.core import predictor as predictor_mod
from repro.core.halo_exchange import HaloPrecision
from repro.core.predictor import PredictorConfig
from repro.graph.graph import Graph
from repro.graph.partition import StackedPartitions, build_partitions
from repro.kernels.spmm import BLOCK_ROWS, STREAM_CHUNK_ROWS
from repro.models.gnn import (GNNConfig, gnn_forward, gnn_forward_sampled,
                              gnn_specs, halo_ref, projected_halo_ref)
from repro.nn import init_params, micro_f1, softmax_cross_entropy
from repro.optim import Optimizer

Pytree = Any

MODES = ("digest", "partition", "propagation")


def gat_projected(cfg: GNNConfig) -> bool:
    """True when the epoch runs GAT with the owner-shard projection dedup:
    the pulled cache then holds *projected* rows (z = W·h̃ per hidden
    layer, flat ``z{ell}``/``z{ell}_scale`` slabs) instead of raw stale
    representations.  Must agree between :func:`init_state` and
    :func:`make_epoch_fn` — hence one predicate."""
    return (cfg.model == "gat" and cfg.gat_halo_dedup
            and cfg.num_layers > 1)


# ---------------------------------------------------------------------------
# Data preparation
# ---------------------------------------------------------------------------

def prepare_graph_data(g: Graph, num_parts: int, method: str = "greedy",
                       seed: int = 0, halo_weight: float = 0.0,
                       stream_chunk_rows: int = None,
                       order: str = "none") -> dict:
    """Build the jnp data dict consumed by the epoch function.

    ``halo_weight`` enables the boundary-aware partitioning score (see
    :func:`repro.graph.partition.greedy_partition`); ``stream_chunk_rows``
    sets the chunk geometry of the precomputed halo worklists (defaults
    to the kernel's ``STREAM_CHUNK_ROWS``).  ``order="rcm"`` applies the
    locality-aware local-row reorder (``build_partitions(order=...)``),
    guarded at the same chunk geometry the epoch streams with so the
    worklist occupancy can only drop; the full M=1 eval view always
    stays at ``order="none"`` — ``evaluate``/``full_graph_forward`` are
    untouched by the knob.
    """
    chunk_rows = (STREAM_CHUNK_ROWS if stream_chunk_rows is None
                  else stream_chunk_rows)
    sp = build_partitions(g, num_parts, method=method, seed=seed,
                          halo_weight=halo_weight, order=order,
                          order_chunk_rows=chunk_rows)
    full = build_partitions(g, 1, method="random", seed=seed)
    x_global = np.concatenate(
        [g.features, np.zeros((1, g.features.shape[1]), np.float32)], axis=0)

    def _struct(s: StackedPartitions) -> tuple:
        # The out-ELL in per-subgraph halo-slot space addresses the
        # device-local pulled slabs directly; the store-slot / global-id
        # remaps live on StackedPartitions for whole-slab consumers.
        # The chunk worklist rides along with the adjacency it was
        # computed from: the streamed halo_spmm skips every
        # (row_block, chunk) pair it proves empty (geometry: the kernels'
        # 128-row blocks over the BLOCK_ROWS-padded S rows, chunk_rows-
        # row chunks over the (H+1)-row slab).
        wl = s.chunk_worklist(chunk_rows, BLOCK_ROWS)
        return {"in_nbr": jnp.asarray(s.in_nbr),
                "in_wts": jnp.asarray(s.in_wts),
                "out_nbr": jnp.asarray(s.out_nbr),
                "out_wts": jnp.asarray(s.out_wts),
                "wl_ids": jnp.asarray(wl.ids),
                "wl_cnt": jnp.asarray(wl.cnt)}, wl

    struct, worklist = _struct(sp)
    full_struct, _ = _struct(full)

    plan = sp.pull_plan()
    # halo_ids extended with a sentinel column: gathering x_global (or the
    # full-graph reps) at these ids yields the per-subgraph (H+1)-row halo
    # slab directly, row H the zero sentinel.
    halo_ids_x = np.concatenate(
        [sp.halo_ids, np.full((sp.num_parts, 1), g.num_nodes, np.int32)],
        axis=1)
    return {
        "x_global": jnp.asarray(x_global),
        "struct": struct,
        "local_ids": jnp.asarray(sp.local_ids),
        "local_valid": jnp.asarray(sp.local_valid),
        "halo_ids": jnp.asarray(sp.halo_ids),
        "halo_valid": jnp.asarray(sp.halo_valid),
        "halo_ids_x": jnp.asarray(halo_ids_x),
        # Owner-sharded compact-store views (HaloExchange slot space).
        "local_slots": jnp.asarray(sp.local_slots),
        "local_boundary": jnp.asarray(sp.local_boundary),
        "halo_slots": jnp.asarray(sp.halo_slots),
        "store_ids": jnp.asarray(sp.store_ids),
        "sentinel_slots": jnp.asarray(sp.sentinel_slots),
        # Ragged collective-pull routing (PullPlan).
        "pull_send": jnp.asarray(plan.send_offsets),
        "pull_recv": jnp.asarray(plan.recv_positions),
        "labels": jnp.asarray(sp.labels),
        "train_mask": jnp.asarray(sp.train_mask),
        "val_mask": jnp.asarray(sp.val_mask),
        "test_mask": jnp.asarray(sp.test_mask),
        # Full-graph (M=1) view for exact eval / propagation mode.
        "full_struct": full_struct,
        "full_ids": jnp.asarray(full.local_ids),
        "full_valid": jnp.asarray(full.local_valid),
        "full_labels": jnp.asarray(full.labels),
        "full_train_mask": jnp.asarray(full.train_mask),
        "full_val_mask": jnp.asarray(full.val_mask),
        "full_test_mask": jnp.asarray(full.test_mask),
        # Host-side metadata (not traced).  _worklist carries the static
        # occupancy the launchers copy into GNNConfig.halo_occupancy for
        # the skip-vs-dense stream selection.
        "_sp": sp,
        "_graph": g,
        "_worklist": worklist,
    }


def _subgraph_features(x_global: jax.Array, ids: jax.Array) -> jax.Array:
    return x_global[ids]


def check_worklist_geometry(cfg: GNNConfig, data: dict) -> None:
    """Reject a chunk worklist built at a different ``chunk_rows`` than
    the epoch's kernels will stream with — a coarser worklist silently
    drops referenced slab rows (a finer one the kernel catches itself),
    so the build knob (``prepare_graph_data(stream_chunk_rows=...)``)
    and the call knob (``GNNConfig.stream_chunk_rows``) must agree.
    No-op when the host-side ``_worklist`` meta was stripped."""
    wl = data.get("_worklist")
    if wl is None:
        return
    want = (cfg.stream_chunk_rows if cfg.stream_chunk_rows is not None
            else STREAM_CHUNK_ROWS)
    if wl.chunk_rows != want:
        raise ValueError(
            f"chunk worklist was built with chunk_rows={wl.chunk_rows} "
            f"but the epoch streams with chunk_rows={want} — pass the "
            f"same value to prepare_graph_data(stream_chunk_rows=...) "
            f"and GNNConfig.stream_chunk_rows (a mismatched worklist "
            f"would silently skip referenced slab rows)")


def check_collective_geometry(data: dict, mesh, axis: str = "data") -> int:
    """Fail fast — before trace time — when the partition count cannot be
    laid over the mesh's halo-exchange axes; returns k = parts/device.

    The collective paths shard M over *every* exchange axis
    (``halo_exchange.exchange_axes``: the "data" axis alone, or the
    combined ("pod", "data") axes on a multi-pod mesh), so M must be a
    whole multiple of pods·data.  The shard_map bodies would raise the
    same spelled-out ValueError at trace time; calling this at launch /
    train start surfaces it before any compilation work.  Works on real
    and abstract (ShapeDtypeStruct) data dicts alike — only shapes are
    read.
    """
    num_parts = int(data["local_slots"].shape[0])
    return halo_exchange.shards_per_device(num_parts, mesh, axis,
                                           "pull_mode='collective'")


def project_store_tables(store: dict, params: Pytree, cfg: GNNConfig,
                         precision: HaloPrecision, pstore: dict = None,
                         gamma: float = 1.0) -> dict:
    """GAT owner-shard projection dedup: project the *store*, not the slabs.

    For every hidden layer ℓ, computes ``z{ℓ} = dequant(store[ℓ]) · W_{ℓ+1}``
    over the R owner-sharded slot rows — ONCE per owner shard per layer —
    and re-encodes it in the wire precision, returning pull-ready
    single-layer stores ``{"z{ℓ}": {"data": (1, R, heads·dh)[, "scale"]}}``
    for :func:`halo_exchange.pull_slab` / ``collective_pull``.  The legacy
    path instead re-projected every subgraph's pulled ``(H+1, d)`` slab
    every epoch — ~M× the FLOPs, since each boundary row appears in many
    subgraphs' halos.  The einsum and the per-row quantization are
    row-wise over the slot axis, so under pjit with the store sharded
    slot-wise they stay inside each device's shards (no collectives); the
    projected rows then ship through the *same* pull routing as raw rows.
    Shipping ``heads·dh``-wide projected rows also shrinks pull bytes
    whenever ``heads·head_dim < hidden``.

    With a SAT predictor history (``pstore``/``gamma`` — see
    ``repro.core.predictor``) the rows are staleness-alleviated BEFORE
    the projection: ``(h̃ + γ·δ)·W = h̃·W + γ·δ·W`` by linearity, so the
    dedup path gets prediction at zero extra wire tensors — the z-cache
    structure (and the pull census) is unchanged.
    """
    out = {}
    for ell in range(cfg.num_layers - 1):
        w = params[f"layer_{ell + 1}"]["w"]        # (hidden, heads, dh)
        tab, sc = halo_exchange.layer_table(store, ell)
        rows = halo_exchange.dequantize_rows(tab, sc)       # (R, hidden)
        if pstore is not None:
            ptab, psc = halo_exchange.layer_table(pstore, ell)
            rows = rows + (jnp.float32(gamma)
                           * halo_exchange.dequantize_rows(ptab, psc))
        z = jnp.einsum("rd,dhk->rhk", rows, w)
        z = z.reshape(z.shape[0], -1)                       # (R, heads·dh)
        q, qs = halo_exchange.quantize_rows(z, precision)
        zs = {"data": q[None]}
        if qs is not None:
            zs["scale"] = qs[None]
        out[f"z{ell}"] = zs
    return out


# ---------------------------------------------------------------------------
# Single-subgraph loss (shared by every mode and by DIGEST-A)
# ---------------------------------------------------------------------------

def make_subgraph_loss(cfg: GNNConfig):
    def loss_fn(params, x_local, halo_tables, struct, labels, mask):
        tables = [jax.lax.stop_gradient(t) for t in halo_tables]
        logits, push = gnn_forward(cfg, params, x_local, tables, struct)
        loss = softmax_cross_entropy(logits, labels, mask)
        return loss, (jnp.stack(push) if push else
                      jnp.zeros((0,) + x_local.shape), logits)
    return loss_fn


def empty_halo_struct(cfg: GNNConfig, struct: dict, rows: int = 8
                      ) -> tuple[list, dict]:
    """Per-layer all-zero halo tables + a struct whose out-ELL is remapped
    into them — the "no out-of-subgraph information" view a single-
    subgraph forward needs when every ``out_nbr`` entry is a sentinel
    (the M=1 full-graph view, and the serving per-part top layer when
    the halo side is supplied separately).  The zero tables contribute
    exact ±0.0 terms, so consumers stay bitwise-comparable with paths
    that drop the halo side entirely."""
    tables = [jnp.zeros((rows, cfg.in_dim), jnp.float32)]
    tables += [jnp.zeros((rows, cfg.hidden_dim), jnp.float32)
               for _ in range(cfg.num_layers - 1)]
    struct = dict(struct)
    struct["out_nbr"] = jnp.minimum(struct["out_nbr"], rows)
    return tables, struct


def full_graph_forward(cfg: GNNConfig, params: Pytree, data: dict
                       ) -> jax.Array:
    """Exact (no staleness, no partition) forward; returns (N_pad, classes)."""
    x = _subgraph_features(data["x_global"], data["full_ids"][0])
    # Halo is empty in the M=1 view: all out_nbr are sentinels. Supply
    # small correctly-shaped zero tables and remap sentinels into them.
    struct = {k: v[0] for k, v in data["full_struct"].items()}
    tables, struct = empty_halo_struct(cfg, struct)
    logits, reps = gnn_forward(cfg, params, x, tables, struct)
    return logits, reps


def top_layer_reps(cfg: GNNConfig, params: Pytree, data: dict) -> jax.Array:
    """h^(L-1) for every node — the exact full-graph input rows of the
    top GNN layer, in the full view's global-id row order (N_pad, hidden).

    This is what a serving-store refresh pushes (``repro.core.serving``):
    the store then answers any node's prediction by gathering these rows
    and running only layer L-1.  It is byte-for-byte ``reps[-1]`` of
    :func:`full_graph_forward` — the same tensor the training epoch
    PUSHes for layer L-2 — so serving parity against ``evaluate()`` is
    exact rather than approximate."""
    if cfg.num_layers < 2:
        raise ValueError("serving from stored representations needs "
                         "num_layers >= 2 (a 1-layer GNN reads raw "
                         "features; there is no (L-1)-layer row to store)")
    _, reps = full_graph_forward(cfg, params, data)
    return reps[-1]


# ---------------------------------------------------------------------------
# The DIGEST epoch (Algorithm 1, one global round r)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainSettings:
    sync_interval: int = 10          # N of Algorithm 1
    mode: str = "digest"
    pull_on_first_epoch: bool = False  # paper pulls only at r % N == 0
    # Wire/storage precision of the HaloExchange store (§3.3 byte counts).
    precision: HaloPrecision = HaloPrecision()
    # PULL transport: "gather" = dense gather (XLA inserts the all-gather
    # under pjit; exact on any device count), "collective" = the fully
    # SPMD shard_map epoch — ragged all_to_all pulls of only the
    # referenced slots, shard-local pushes and staleness reads (pass the
    # mesh to make_epoch_fn; needs num_parts to be a multiple of the
    # exchange axes — the "data" axis, times "pod" on a multi-pod mesh
    # where the pull runs the two-stage intra-pod/inter-pod exchange:
    # k = parts/devices subgraphs + owner shards per device).
    pull_mode: str = "gather"
    # LLCG-style server correction (for the partition-based baseline): one
    # extra server-side gradient step per round on a sampled node batch
    # with FULL neighbor information [Ramezani et al. 2021].
    llcg_correction: bool = False
    correction_frac: float = 0.1
    correction_lr: float = 1e-3
    # Mini-batch sampled regime (make_sampled_epoch_fn): "cv" aggregates
    # unsampled neighbors from the stale history (VR-GCN control
    # variates); "plain" drops the history term — classic scaled neighbor
    # sampling, the variance-benchmark baseline.
    sample_estimator: str = "cv"
    # Bounded-staleness watchdog: when a shard's last successful push is
    # >= max_staleness rounds old, its push is forced on the next round
    # regardless of the sync cadence or the fault mask — Theorems 1/3
    # assume bounded staleness, so the watchdog converts "arbitrarily
    # stale under faults" back into the regime the analysis covers.
    # Requires the fault-aware state leaves (faults.attach_fault_state);
    # None disables the watchdog.
    max_staleness: Optional[int] = None
    # Staleness-alleviated embedding prediction (SAT; see
    # repro.core.predictor): consumers read ``dequant(store row) +
    # γ·dequant(pstore row)`` where the pstore carries each row's
    # last-sync delta (or its β-EMA), maintained shard-locally at push
    # time and exchanged through the exact same pull routing as the
    # store.  ``kind="none"`` creates NO extra leaves and compiles the
    # bitwise-identical predictor-free program.
    predictor: PredictorConfig = PredictorConfig()


def _digest_pull(cfg: GNNConfig, settings: TrainSettings, state: dict,
                 data: dict, mesh, r) -> dict:
    """Algorithm-1 PULL (line 5): gather each subgraph's halo slots from
    the owner shards into the device-local cache slab every
    ``sync_interval`` epochs.  ONE implementation shared by the
    full-batch epoch and the sampled step — both therefore compile to
    the identical collective routing (the ragged all_to_all census the
    HLO tests pin is a property of this function, not of the caller).

    Returns ``(cache, pcache)``: the stale slab plus the pulled SAT
    predictor slab (``None`` unless the predictor is enabled on a
    non-dedup model — the pstore rides the same routing, one extra
    exchange per store tensor).  Under the GAT dedup the prediction is
    folded into :func:`project_store_tables` *before* projection, so
    the z-cache and the pull census stay exactly as without it."""
    halo_size = data["halo_ids"].shape[1]
    do_pull = (r % settings.sync_interval == 0)
    if settings.pull_on_first_epoch:
        do_pull = do_pull | (r == 1)
    pred = settings.predictor.enabled and "pstore" in state
    if settings.pull_mode == "collective":
        def _pull_store(zs):
            return halo_exchange.collective_pull(
                zs, data["pull_send"], data["pull_recv"],
                halo_size, mesh)
    else:
        def _pull_store(zs):
            return halo_exchange.pull_slab(zs, data["halo_slots"])
    if gat_projected(cfg):
        def _pull():
            # Owner-shard projection (once per layer) + the same
            # ragged routing, one exchange per z tensor.
            new_cache = {}
            for key, zs in project_store_tables(
                    state["store"], state["params"], cfg,
                    settings.precision,
                    pstore=state["pstore"] if pred else None,
                    gamma=settings.predictor.gamma).items():
                slab = _pull_store(zs)
                new_cache[key] = slab["data"]
                if "scale" in slab:
                    new_cache[f"{key}_scale"] = slab["scale"]
            return new_cache, state.get("pcache")
    elif pred:
        def _pull():
            return _pull_store(state["store"]), _pull_store(state["pstore"])
    else:
        def _pull():
            return _pull_store(state["store"]), None
    return jax.lax.cond(do_pull, _pull,
                        lambda: (state["cache"], state.get("pcache")))


def _digest_push(cfg: GNNConfig, settings: TrainSettings, state: dict,
                 data: dict, push_reps, mesh, r) -> tuple:
    """Periodic PUSH (Algorithm 1 lines 9–10; epochs r = 1, N+1, 2N+1,
    ...) + the Theorem-1 staleness probe; shared by the full-batch epoch
    and the sampled step.  Owner-sharded scatter: every row of part m
    lands in shard m.  Collective mode routes it through the explicit
    shard-local forms (shard_push / shard_staleness_error) so the
    compiled epoch carries ZERO cross-device push traffic — the SPMD
    scatter/gather fallback is the partitioner-dependent path (same
    math, but XLA cannot prove writes stay in-shard and materializes
    collectives around them).

    Fault-aware when ``state`` carries the ``faults.attach_fault_state``
    leaves: the host-refreshed per-shard ``push_ok`` mask AND-gates each
    shard's rows into the *same* compiled scatter (masked rows route to
    the shard's sentinel slot, so the store keeps last-known-good
    contents — no program change, census identical), and the per-shard
    ``last_push_round`` age table records successful pushes so
    fault-induced staleness is measured rather than silent.  With
    ``settings.max_staleness`` set, shards whose age reaches the bound
    are force-pushed on the next round even off-cadence (the blocking
    resync the Theorem-1/3 bounded-staleness analysis needs).  Without
    the fault leaves the exact pre-fault program compiles.

    With the SAT predictor enabled this also advances the push-side
    history (``state["predictor"]``, gated by the SAME per-part ok mask
    as the store push, so fault-masked shards freeze and degraded pulls
    extrapolate from the last-known-good delta), scatters the resulting
    delta rows into the pstore through the identical push path, and
    measures eps against the *predicted* rows — the residual staleness
    error consumers actually see — via a virtual fp32 store
    ``dequant(store) + γ·dequant(pstore)`` (elementwise, so the probe's
    shard-local reads are untouched).

    Returns (store, push_residual, eps, last_push_round, pstore,
    predictor_history)."""
    new_store = state["store"]
    new_residual = state.get("push_residual")
    new_last = state.get("last_push_round")
    new_pstore = state.get("pstore")
    new_hist = state.get("predictor")
    eps = jnp.zeros((max(cfg.num_layers - 1, 1),), jnp.float32)
    if settings.mode == "digest" and cfg.num_layers > 1:
        do_push = ((r - 1) % settings.sync_interval == 0)
        num_parts = data["local_slots"].shape[0]
        shard_rows = state["store"]["data"].shape[1] // num_parts
        local_valid = data["local_valid"]
        ok = jnp.broadcast_to(do_push, (num_parts,))          # (M,)
        if new_last is not None:
            ok = do_push & state["push_ok"]                    # (M,)
            if settings.max_staleness is not None:
                ok = ok | ((r - new_last) >= settings.max_staleness)
            do_push = jnp.any(ok)
            local_valid = local_valid & ok[:, None]
            new_last = jnp.where(ok, jnp.asarray(r, new_last.dtype),
                                 new_last)
        pred = settings.predictor.enabled and new_pstore is not None
        eps_store = state["store"]
        if pred:
            eps_store = {"data": (
                halo_exchange.dequantize_rows(
                    state["store"]["data"], state["store"].get("scale"))
                + jnp.float32(settings.predictor.gamma)
                * halo_exchange.dequantize_rows(
                    state["pstore"]["data"], state["pstore"].get("scale")))}
        if settings.pull_mode == "collective":
            eps = halo_exchange.shard_staleness_error(
                eps_store, push_reps, data["local_slots"],
                data["local_boundary"], shard_rows, mesh)

            def _push():
                return halo_exchange.shard_push(
                    state["store"], data["local_slots"],
                    local_valid, push_reps, shard_rows, mesh)

            def _push_ef():
                return halo_exchange.shard_push_ef(
                    state["store"], data["local_slots"],
                    local_valid, push_reps,
                    state["push_residual"], shard_rows, mesh)
        else:
            eps = halo_exchange.staleness_error(
                eps_store, push_reps, data["local_slots"],
                data["local_boundary"])

            def _push():
                return halo_exchange.push(
                    state["store"], data["local_slots"],
                    local_valid, push_reps,
                    data["sentinel_slots"])

            def _push_ef():
                return halo_exchange.push_ef(
                    state["store"], data["local_slots"],
                    local_valid, push_reps,
                    state["push_residual"], data["sentinel_slots"])
        if settings.precision.error_feedback:
            new_store, new_residual = jax.lax.cond(
                do_push, _push_ef,
                lambda: (state["store"], state["push_residual"]))
            if new_last is not None:
                # A masked shard wrote nothing, so its EF residual must
                # not absorb this round's quantization error either.
                new_residual = jnp.where(ok[:, None, None, None],
                                         new_residual,
                                         state["push_residual"])
        else:
            new_store = jax.lax.cond(do_push, _push,
                                     lambda: state["store"])
        if pred:
            # History transition + pstore scatter, gated exactly like
            # the store push (pure in the accepted-push sequence; no EF
            # on the pstore — deltas do not telescope across pushes).
            new_hist, prows = predictor_mod.update_history(
                state["predictor"], push_reps, ok, settings.predictor)
            if settings.pull_mode == "collective":
                def _ppush():
                    return halo_exchange.shard_push(
                        state["pstore"], data["local_slots"],
                        local_valid, prows, shard_rows, mesh)
            else:
                def _ppush():
                    return halo_exchange.push(
                        state["pstore"], data["local_slots"],
                        local_valid, prows, data["sentinel_slots"])
            new_pstore = jax.lax.cond(do_push, _ppush,
                                      lambda: state["pstore"])
    return new_store, new_residual, eps, new_last, new_pstore, new_hist


def make_epoch_fn(cfg: GNNConfig, opt: Optimizer, settings: TrainSettings,
                  mesh=None) -> Callable:
    if settings.mode not in MODES:
        raise ValueError(settings.mode)
    if settings.pull_mode not in ("gather", "collective"):
        raise ValueError(settings.pull_mode)
    if settings.pull_mode == "collective" and mesh is None:
        raise ValueError("pull_mode='collective' needs the mesh")
    if settings.predictor.enabled and settings.mode != "digest":
        raise ValueError("the SAT predictor rides the stale store — "
                         f"mode must be 'digest', got {settings.mode!r}")
    loss_fn = make_subgraph_loss(cfg)

    def epoch_fn(state: dict, data: dict) -> tuple[dict, dict]:
        r = state["epoch"] + 1            # 1-indexed, as in Algorithm 1
        x_global = data["x_global"]                         # (N+1, d)
        struct = data["struct"]
        halo_size = data["halo_ids"].shape[1]
        # Layer-0 halo features as device-local per-subgraph slabs
        # (M, H+1, d), row H the zero sentinel (x_global[N]).  The
        # partition baseline drops cross-subgraph information by zeroing
        # the halo *tables* (this slab; the stale slab below stays at its
        # zero init), NOT the ELL weights — GAT's attention denominator
        # and SAGE's mean still see the dropped neighbors as zero
        # vectors, matching the seed semantics exactly.
        x_halo0 = x_global[data["halo_ids_x"]]              # (M, H+1, d)
        if settings.mode == "partition":
            x_halo0 = jnp.zeros_like(x_halo0)

        # GAT owner-shard dedup: the cache holds *projected* rows
        # (z{ell} = W·h̃, projected once per owner shard per layer at
        # pull time) instead of raw stale reps — see
        # project_store_tables.  The projection rides the staleness
        # contract the representations already have: frozen between
        # syncs at the pull-time W, and under the same stop_gradient as
        # the stale rows (the legacy path differentiated W through the
        # halo einsum; here that term is dropped with the rest of the
        # stale branch — pull epochs still see the identical forward,
        # and gat_halo_dedup=False restores the legacy semantics).
        use_projected = gat_projected(cfg)

        # The stale slab feeding this epoch's out-of-subgraph products —
        # device-local (M, L-1, H+1, hid) in storage precision: each
        # subgraph's slice holds only the halo rows it references, so
        # per-device residency scales with |halo(G_m)|, not |boundary|.
        if settings.mode == "propagation" and cfg.num_layers > 1:
            # Fresh exchange every epoch: exact reps at current params,
            # gathered down to the per-subgraph halo slabs.
            _, reps = full_graph_forward(cfg, state["params"], data)
            ids = jnp.clip(data["halo_ids_x"], 0, reps[0].shape[0] - 1)
            hv = jnp.pad(data["halo_valid"], ((0, 0), (0, 1)))
            if use_projected:
                # Fresh rows projected once over the full-graph table (N
                # rows per layer) rather than per-subgraph slabs.
                cache = {}
                for ell in range(cfg.num_layers - 1):
                    w = state["params"][f"layer_{ell + 1}"]["w"]
                    z = jnp.einsum("nd,dhk->nhk", reps[ell], w)
                    z = z.reshape(z.shape[0], -1)[ids]      # (M, H+1, w)
                    z = jnp.where(hv[:, :, None], z, 0.0)
                    q, sc = halo_exchange.quantize_rows(
                        z, settings.precision)
                    cache[f"z{ell}"] = q[:, None]
                    if sc is not None:
                        cache[f"z{ell}_scale"] = sc[:, None]
            else:
                slab = jnp.stack([rep[ids] for rep in reps], axis=1)
                slab = jnp.where(hv[:, None, :, None], slab, 0.0)
                q, sc = halo_exchange.quantize_rows(slab,
                                                    settings.precision)
                cache = ({"data": q} if sc is None
                         else {"data": q, "scale": sc})
            pcache = None
        elif settings.mode == "digest":
            cache, pcache = _digest_pull(cfg, settings, state, data,
                                         mesh, r)
        else:
            cache = state["cache"]
            pcache = None

        x_local = x_global[data["local_ids"]]               # (M, S, d)
        n_hidden = cfg.num_layers - 1
        pred_tables = pcache is not None

        def sub_loss(params, x_loc, x_h0, cache_m, pcache_m, struct_m,
                     labels, mask):
            # Layer 0 gathers raw halo features from this subgraph's
            # feature slab; layers ℓ≥1 gather stale reps straight from its
            # pulled storage-precision slab — both via the fused
            # pull+aggregate path with the per-subgraph halo-slot ELL and
            # its precomputed chunk worklist.  Under GAT dedup the slab
            # rows are pre-projected (projected_halo_ref) so the layer
            # skips its per-subgraph W·h̃ einsum.
            wl = (struct_m.get("wl_ids"), struct_m.get("wl_cnt"))
            tables = [halo_ref(x_h0, None, struct_m["out_nbr"],
                               struct_m["out_wts"], *wl)]
            for ell in range(n_hidden):
                if use_projected:
                    zsc = cache_m.get(f"z{ell}_scale")
                    tables.append(projected_halo_ref(
                        cache_m[f"z{ell}"][0],
                        zsc[0] if zsc is not None else None,
                        struct_m["out_nbr"], struct_m["out_wts"]))
                else:
                    pk = {}
                    if pred_tables:
                        # Fused SAT epilogue: the kernel reads
                        # dequant(stale) + γ·dequant(delta) per row.
                        ptab, psc = halo_exchange.layer_table(pcache_m,
                                                              ell)
                        pk = dict(pdata=ptab, pscale=psc,
                                  gamma=settings.predictor.gamma)
                    tables.append(halo_ref(
                        *halo_exchange.layer_table(cache_m, ell),
                        struct_m["out_nbr"], struct_m["out_wts"], *wl,
                        **pk))
            return loss_fn(params, x_loc, tables, struct_m, labels, mask)

        vg = jax.vmap(jax.value_and_grad(sub_loss, has_aux=True),
                      in_axes=(None, 0, 0, 0, 0, 0, 0, 0))
        (losses, (push_reps, logits)), grads = vg(
            state["params"], x_local, x_halo0, cache, pcache, struct,
            data["labels"], data["train_mask"])

        # Global AGG (Algorithm 1 line 13): uniform average over subgraphs.
        mean_grads = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
        params, opt_state = opt.update(mean_grads, state["opt_state"],
                                       state["params"], state["step"])

        if settings.llcg_correction:
            # LLCG server correction: full-neighbor gradient on a sampled
            # node mini-batch, plain SGD on the server.
            key = jax.random.fold_in(jax.random.PRNGKey(17), r)
            sample = (jax.random.uniform(key, data["full_train_mask"][0]
                                         .shape)
                      < settings.correction_frac)
            corr_mask = data["full_train_mask"][0] & sample

            def server_loss(p):
                logits, _ = full_graph_forward(cfg, p, data)
                return softmax_cross_entropy(
                    logits, data["full_labels"][0],
                    corr_mask.astype(jnp.float32))

            corr_grads = jax.grad(server_loss)(params)
            params = jax.tree.map(
                lambda p, g: p - settings.correction_lr * g, params,
                corr_grads)

        (new_store, new_residual, eps, new_last, new_pstore,
         new_hist) = _digest_push(cfg, settings, state, data, push_reps,
                                  mesh, r)

        train_acc = micro_f1(logits, data["labels"],
                             data["train_mask"].astype(jnp.float32))
        new_state = {"params": params, "opt_state": opt_state,
                     "store": new_store, "cache": cache,
                     "epoch": r, "step": state["step"] + 1}
        if new_residual is not None:
            new_state["push_residual"] = new_residual
        if new_pstore is not None:
            new_state["pstore"] = new_pstore
            new_state["predictor"] = new_hist
        if pcache is not None:
            new_state["pcache"] = pcache
        metrics = {"loss": jnp.mean(losses), "train_f1": train_acc,
                   "staleness_eps": eps}
        if new_last is not None:
            new_state["push_ok"] = state["push_ok"]
            new_state["last_push_round"] = new_last
            metrics["push_age"] = faults_mod.measured_staleness(new_last, r)
        return new_state, metrics

    return epoch_fn


# ---------------------------------------------------------------------------
# State init + high-level training loop
# ---------------------------------------------------------------------------

def init_state(cfg: GNNConfig, opt: Optimizer, data: dict, seed: int = 0,
               precision: HaloPrecision = HaloPrecision(),
               predictor: PredictorConfig = PredictorConfig()) -> dict:
    check_worklist_geometry(cfg, data)
    params = init_params(jax.random.PRNGKey(seed), gnn_specs(cfg))
    num_slots = int(data["store_ids"].shape[0]) - 1
    l1 = max(cfg.num_layers - 1, 1)
    num_parts, s = data["local_ids"].shape
    halo_size = int(data["halo_ids"].shape[1])
    if gat_projected(cfg):
        # GAT dedup: the pulled cache holds per-layer *projected* slabs
        # z{ell} = W_{ell+1}·h̃ of width heads·head_dim (= the consuming
        # layer's dout), flat keys so the pytree stays one level deep for
        # shardings/checkpoints.  Leading (M, 1, H+1, ·) matches the
        # per-layer pull_slab/collective_pull output.
        cache = {}
        for ell in range(l1):
            w_ell = cfg.layer_dims[ell + 1][1]
            cache[f"z{ell}"] = jnp.zeros(
                (num_parts, 1, halo_size + 1, w_ell), precision.dtype)
            if precision.has_scale:
                cache[f"z{ell}_scale"] = jnp.ones(
                    (num_parts, 1, halo_size + 1, 1), jnp.float32)
    else:
        cache = halo_exchange.init_slab(num_parts, l1, halo_size,
                                        cfg.hidden_dim, precision)
    state = {
        "params": params,
        "opt_state": opt.init(params),
        # Authoritative owner-sharded compact store (O(|boundary|·L·d)
        # total, 1/M per device) + the device-local pulled halo slabs
        # (O(Σ_m |halo(G_m)|·L·d) total; the seed kept a replicated
        # O(M·H·L·d) fp32 cache).
        "store": halo_exchange.init_store(l1, num_slots, cfg.hidden_dim,
                                          precision),
        "cache": cache,
        "epoch": jnp.asarray(0, jnp.int32),
        "step": jnp.asarray(0, jnp.int32),
    }
    if precision.error_feedback:
        state["push_residual"] = jnp.zeros((num_parts, l1, s,
                                            cfg.hidden_dim), jnp.float32)
    if predictor.enabled and cfg.num_layers > 1:
        # SAT leaves (see repro.core.predictor): the pstore mirrors the
        # store's slot geometry/precision exactly, so every exchange
        # helper and the checkpoint layout apply verbatim; the history
        # rides the push buffers' shape.  The dedup GAT path folds the
        # prediction before projection and needs no pulled pcache slab.
        state["pstore"] = halo_exchange.init_store(
            l1, num_slots, cfg.hidden_dim, precision)
        state["predictor"] = predictor_mod.init_history(
            num_parts, l1, s, cfg.hidden_dim)
        if not gat_projected(cfg):
            state["pcache"] = halo_exchange.init_slab(
                num_parts, l1, halo_size, cfg.hidden_dim, precision)
    return state


@functools.partial(jax.jit, static_argnames=("cfg",))
def evaluate(cfg: GNNConfig, params: Pytree, data: dict) -> dict:
    logits, _ = full_graph_forward(cfg, params, data)
    out = {}
    for split in ("train", "val", "test"):
        mask = data[f"full_{split}_mask"][0].astype(jnp.float32)
        out[f"{split}_f1"] = micro_f1(logits, data["full_labels"][0], mask)
        out[f"{split}_loss"] = softmax_cross_entropy(
            logits, data["full_labels"][0], mask)
    return out


def digest_train(cfg: GNNConfig, opt: Optimizer, data: dict,
                 settings: TrainSettings, epochs: int,
                 eval_every: int = 10, seed: int = 0,
                 verbose: bool = False, mesh=None, faults=None,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
                 resume: bool = False) -> tuple[dict, dict]:
    """Run training; returns (final_state, history dict of lists).

    ``mesh`` is required for ``pull_mode="collective"`` (the explicit
    shard_map pull/push paths — single- or multi-pod; the exchange
    auto-detects a "pod" axis); the default gather mode ignores it.

    ``faults`` (a :class:`repro.core.faults.FaultConfig` or
    ``FaultSchedule``) injects deterministic push faults through the
    per-shard ``push_ok`` mask — see ``_digest_push``; combined with
    ``settings.max_staleness`` the watchdog bounds the resulting
    staleness.  A ``None``/zero-rate schedule leaves the trajectory
    bitwise identical to a run without fault state.

    ``ckpt_dir`` + ``ckpt_every`` save an atomic, checksummed
    checkpoint of the full training state every ``ckpt_every`` epochs;
    ``resume=True`` restores the newest *valid* checkpoint (corrupt or
    partial ones are skipped) and continues to ``epochs`` — the epoch
    function is deterministic in its state, so a killed-and-resumed
    run finishes bitwise equal to an uninterrupted one (gcn/sage;
    gat ≤ 1e-6)."""
    if settings.pull_mode == "collective" and mesh is not None:
        check_collective_geometry(data, mesh)
    schedule = faults_mod.check_schedule(faults)
    num_parts = int(data["local_ids"].shape[0])
    fault_aware = (schedule is not None
                   or settings.max_staleness is not None)
    state = init_state(cfg, opt, data, seed=seed,
                       precision=settings.precision,
                       predictor=settings.predictor)
    if fault_aware:
        state = faults_mod.attach_fault_state(state, num_parts)
    start = 0
    if resume:
        if ckpt_dir is None:
            raise ValueError("resume=True needs ckpt_dir")
        step = ckpt_io.latest_step(ckpt_dir)
        if step is not None:
            state, _ = ckpt_io.restore_checkpoint(ckpt_dir, state,
                                                  step=step)
            start = int(np.asarray(state["epoch"]))
    epoch_fn = jax.jit(make_epoch_fn(cfg, opt, settings, mesh=mesh))
    tdata = {k: v for k, v in data.items() if not k.startswith("_")}
    hist: dict[str, list] = {"epoch": [], "loss": [], "train_f1": [],
                             "val_f1": [], "test_f1": [], "time": [],
                             "staleness_eps": []}
    if fault_aware:
        hist["push_age"] = []
    t0 = time.perf_counter()
    for e in range(start, epochs):
        if fault_aware:
            ok = (schedule.push_ok(e + 1, num_parts) if schedule is not None
                  else np.ones(num_parts, dtype=bool))
            state["push_ok"] = jnp.asarray(ok)
        state, m = epoch_fn(state, tdata)
        if (e + 1) % eval_every == 0 or e == epochs - 1:
            ev = evaluate(cfg, state["params"], tdata)
            hist["epoch"].append(e + 1)
            hist["loss"].append(float(m["loss"]))
            hist["train_f1"].append(float(m["train_f1"]))
            hist["val_f1"].append(float(ev["val_f1"]))
            hist["test_f1"].append(float(ev["test_f1"]))
            hist["staleness_eps"].append(
                np.asarray(m["staleness_eps"]).tolist())
            hist["time"].append(time.perf_counter() - t0)
            if fault_aware:
                hist["push_age"].append(int(m["push_age"]))
            if verbose:
                print(f"[{settings.mode}] epoch {e+1:4d} "
                      f"loss {float(m['loss']):.4f} "
                      f"val_f1 {float(ev['val_f1']):.4f}")
        if ckpt_dir and ckpt_every and (e + 1) % ckpt_every == 0:
            ckpt_io.save_checkpoint(ckpt_dir, e + 1, state)
    return state, hist


# ---------------------------------------------------------------------------
# Mini-batch sampled training (stale-store control variates)
# ---------------------------------------------------------------------------

def make_sampled_epoch_fn(cfg: GNNConfig, opt: Optimizer,
                          settings: TrainSettings, mesh=None) -> Callable:
    """Build the jitted sampled step ``(state, data, batch) -> (state,
    metrics)`` — the mini-batch regime over the SAME stale store.

    ``batch`` is one :class:`repro.graph.sampler.NeighborSampler` draw
    (``seed_mask``/``edge_scale``/``edge_keep``, jnp-converted).  Per
    step: in-subgraph sampled neighbors aggregate fresh, their complement
    reads the **control-variate history** — the device-local last-step
    representations (``state["hist"]``) for local rows, the pulled stale
    slab (refreshed by the unchanged ``_digest_pull`` at
    ``sync_interval`` cadence) for out-of-subgraph rows — and the loss is
    masked to the seed set.  PUSH, staleness probe and collective routing
    are byte-identical to the full-batch epoch (shared helpers), so the
    compiled-HLO census is unchanged: zero all-gathers, the same ragged
    all_to_all count per store tensor.

    ``settings.sample_estimator``: "cv" (VR-GCN) or "plain" — plain
    neighbor sampling is exactly the CV estimator against an all-zero
    history, so it is implemented by feeding zeros as the baseline (the
    variance benchmark's control).
    """
    if settings.mode != "digest":
        raise ValueError("sampled training rides the stale store — "
                         f"mode must be 'digest', got {settings.mode!r}")
    if settings.pull_mode not in ("gather", "collective"):
        raise ValueError(settings.pull_mode)
    if settings.pull_mode == "collective" and mesh is None:
        raise ValueError("pull_mode='collective' needs the mesh")
    if settings.sample_estimator not in ("cv", "plain"):
        raise ValueError(f"sample_estimator must be 'cv' or 'plain', "
                         f"got {settings.sample_estimator!r}")
    use_projected = gat_projected(cfg)
    n_hidden = cfg.num_layers - 1
    pred_tables = settings.predictor.enabled and not use_projected

    def sub_loss(params, x_loc, x_h0, cache_m, pcache_m, hist_m, struct_m,
                 labels, smask, escale, ekeep):
        # Same per-layer halo tables as the full-batch sub_loss; the
        # sampled forward additionally reads the local history rows.
        wl = (struct_m.get("wl_ids"), struct_m.get("wl_cnt"))
        tables = [halo_ref(x_h0, None, struct_m["out_nbr"],
                           struct_m["out_wts"], *wl)]
        for ell in range(n_hidden):
            if use_projected:
                zsc = cache_m.get(f"z{ell}_scale")
                tables.append(projected_halo_ref(
                    cache_m[f"z{ell}"][0],
                    zsc[0] if zsc is not None else None,
                    struct_m["out_nbr"], struct_m["out_wts"]))
            else:
                pk = {}
                if pred_tables and pcache_m is not None:
                    ptab, psc = halo_exchange.layer_table(pcache_m, ell)
                    pk = dict(pdata=ptab, pscale=psc,
                              gamma=settings.predictor.gamma)
                tables.append(halo_ref(
                    *halo_exchange.layer_table(cache_m, ell),
                    struct_m["out_nbr"], struct_m["out_wts"], *wl, **pk))
        tables = [jax.lax.stop_gradient(t) for t in tables]
        hist_tables = [jax.lax.stop_gradient(hist_m[i])
                       for i in range(n_hidden)]
        samp = {"edge_scale": escale, "edge_keep": ekeep}
        logits, push = gnn_forward_sampled(cfg, params, x_loc, tables,
                                           hist_tables, struct_m, samp)
        loss = softmax_cross_entropy(logits, labels, smask)
        return loss, (jnp.stack(push) if push else
                      jnp.zeros((0,) + x_loc.shape), logits)

    def step_fn(state: dict, data: dict, batch: dict) -> tuple[dict, dict]:
        r = state["epoch"] + 1
        x_global = data["x_global"]
        x_halo0 = x_global[data["halo_ids_x"]]
        cache, pcache = _digest_pull(cfg, settings, state, data, mesh, r)
        x_local = x_global[data["local_ids"]]
        if settings.sample_estimator == "cv":
            hist = state["hist"]
        else:
            hist = jnp.zeros_like(state["hist"])

        vg = jax.vmap(jax.value_and_grad(sub_loss, has_aux=True),
                      in_axes=(None, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0))
        (losses, (push_reps, logits)), grads = vg(
            state["params"], x_local, x_halo0, cache, pcache, hist,
            data["struct"], data["labels"], batch["seed_mask"],
            batch["edge_scale"], batch["edge_keep"])

        mean_grads = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
        params, opt_state = opt.update(mean_grads, state["opt_state"],
                                       state["params"], state["step"])

        (new_store, new_residual, eps, new_last, new_pstore,
         new_hist) = _digest_push(cfg, settings, state, data, push_reps,
                                  mesh, r)

        train_acc = micro_f1(logits, data["labels"],
                             batch["seed_mask"].astype(jnp.float32))
        # The CV history refreshes every step: the padded SPMD step
        # computes every local row's representation anyway, so the CV
        # baseline for in-subgraph rows is at most one step stale (the
        # halo side keeps the sync_interval staleness of the store).
        new_state = {"params": params, "opt_state": opt_state,
                     "store": new_store, "cache": cache,
                     "hist": push_reps if n_hidden > 0 else state["hist"],
                     "epoch": r, "step": state["step"] + 1}
        if new_residual is not None:
            new_state["push_residual"] = new_residual
        if new_pstore is not None:
            new_state["pstore"] = new_pstore
            new_state["predictor"] = new_hist
        if pcache is not None:
            new_state["pcache"] = pcache
        metrics = {"loss": jnp.mean(losses), "train_f1": train_acc,
                   "staleness_eps": eps}
        if new_last is not None:
            new_state["push_ok"] = state["push_ok"]
            new_state["last_push_round"] = new_last
            metrics["push_age"] = faults_mod.measured_staleness(new_last, r)
        return new_state, metrics

    return step_fn


def init_sampled_state(cfg: GNNConfig, opt: Optimizer, data: dict,
                       seed: int = 0,
                       precision: HaloPrecision = HaloPrecision(),
                       predictor: PredictorConfig = PredictorConfig()
                       ) -> dict:
    """:func:`init_state` + the device-local control-variate history
    ``hist`` (M, L-1, S, hidden) fp32 — each subgraph's own-row
    representations from the previous step, zero-initialized like the
    store (unused rows: the in-ELL's padding entries point at the zero
    sentinel, and their residual weights are zero anyway)."""
    state = init_state(cfg, opt, data, seed=seed, precision=precision,
                       predictor=predictor)
    num_parts, s = data["local_ids"].shape
    state["hist"] = jnp.zeros(
        (num_parts, cfg.num_layers - 1, s, cfg.hidden_dim), jnp.float32)
    return state


def sampled_train(cfg: GNNConfig, opt: Optimizer, data: dict, sampler,
                  settings: TrainSettings, steps: int, eval_every: int = 10,
                  seed: int = 0, verbose: bool = False, mesh=None,
                  faults=None, ckpt_dir: Optional[str] = None,
                  ckpt_every: int = 0, resume: bool = False
                  ) -> tuple[dict, dict]:
    """Run mini-batch sampled training; returns (final_state, history).

    ``sampler`` is a :class:`repro.graph.sampler.NeighborSampler`; step t
    consumes the deterministic ``sampler.sample(t)`` batch.  ``faults``
    and ``ckpt_dir``/``ckpt_every``/``resume`` behave exactly as in
    :func:`digest_train` — both the sampler and the fault schedule are
    pure functions of the step index, so a resumed run replays the
    identical batch and fault sequence."""
    if settings.pull_mode == "collective" and mesh is not None:
        check_collective_geometry(data, mesh)
    schedule = faults_mod.check_schedule(faults)
    num_parts = int(data["local_ids"].shape[0])
    fault_aware = (schedule is not None
                   or settings.max_staleness is not None)
    state = init_sampled_state(cfg, opt, data, seed=seed,
                               precision=settings.precision,
                               predictor=settings.predictor)
    if fault_aware:
        state = faults_mod.attach_fault_state(state, num_parts)
    start = 0
    if resume:
        if ckpt_dir is None:
            raise ValueError("resume=True needs ckpt_dir")
        step = ckpt_io.latest_step(ckpt_dir)
        if step is not None:
            state, _ = ckpt_io.restore_checkpoint(ckpt_dir, state,
                                                  step=step)
            start = int(np.asarray(state["epoch"]))
    step_fn = jax.jit(make_sampled_epoch_fn(cfg, opt, settings, mesh=mesh))
    tdata = {k: v for k, v in data.items() if not k.startswith("_")}
    hist: dict[str, list] = {"epoch": [], "loss": [], "train_f1": [],
                             "val_f1": [], "test_f1": [], "time": [],
                             "staleness_eps": []}
    if fault_aware:
        hist["push_age"] = []
    t0 = time.perf_counter()
    for t in range(start, steps):
        if fault_aware:
            ok = (schedule.push_ok(t + 1, num_parts) if schedule is not None
                  else np.ones(num_parts, dtype=bool))
            state["push_ok"] = jnp.asarray(ok)
        batch = {k: jnp.asarray(v) for k, v in sampler.sample(t).items()}
        state, m = step_fn(state, tdata, batch)
        if (t + 1) % eval_every == 0 or t == steps - 1:
            ev = evaluate(cfg, state["params"], tdata)
            hist["epoch"].append(t + 1)
            hist["loss"].append(float(m["loss"]))
            hist["train_f1"].append(float(m["train_f1"]))
            hist["val_f1"].append(float(ev["val_f1"]))
            hist["test_f1"].append(float(ev["test_f1"]))
            hist["staleness_eps"].append(
                np.asarray(m["staleness_eps"]).tolist())
            hist["time"].append(time.perf_counter() - t0)
            if fault_aware:
                hist["push_age"].append(int(m["push_age"]))
            if verbose:
                print(f"[sampled/{settings.sample_estimator}] "
                      f"step {t+1:4d} loss {float(m['loss']):.4f} "
                      f"val_f1 {float(ev['val_f1']):.4f}")
        if ckpt_dir and ckpt_every and (t + 1) % ckpt_every == 0:
            ckpt_io.save_checkpoint(ckpt_dir, t + 1, state)
    return state, hist
