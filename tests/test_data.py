"""Synthetic LM data pipeline."""
import numpy as np

from repro.data import SyntheticLMDataset, make_lm_pipeline


def test_dataset_learnable_structure():
    ds = SyntheticLMDataset(vocab_size=64, seed=0)
    rng = np.random.default_rng(0)
    toks, labels = ds.sample(rng, 8, 128)
    assert toks.shape == (8, 128) and labels.shape == (8, 128)
    assert toks.min() >= 0 and toks.max() < 64
    np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])


def test_dataset_deterministic():
    a = SyntheticLMDataset(32, seed=1).sample(np.random.default_rng(5), 2, 16)
    b = SyntheticLMDataset(32, seed=1).sample(np.random.default_rng(5), 2, 16)
    np.testing.assert_array_equal(a[0], b[0])


def test_pipeline_yields_batches():
    it = make_lm_pipeline(vocab_size=100, batch=4, seq=32, seed=0)
    b = next(it)
    assert b.tokens.shape == (4, 32)
    assert b.labels.shape == (4, 32)
    assert float(b.mask.sum()) == 4 * 32
