"""MaxText-style logical axis rules → mesh shardings.

Models annotate parameters (via ParamSpec.axes) and activations (via
``logical_constraint``) with *logical* names; a rule table maps logical names
to mesh axes.  Swapping the rule table is how §Perf iterations change the
sharding scheme without touching model code.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any

# Default rules: megatron-style tensor parallelism on "model", batch over
# ("pod","data"), FSDP sharding of big params over "data".
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "embed_out": None,
    "vocab": "model",
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "expert": "model",
    "expert_mlp": None,
    "fsdp": "data",          # applied to the *largest* dim of big params
    "kv_seq": None,
    "patches": None,
    "rnn": "model",
    "stack": None,           # stacked-layer leading dim
    "pod_stack": "pod",      # per-pod parameter copies (DIGEST local SGD)
}

_state = threading.local()


def current_rules() -> Optional[dict]:
    return getattr(_state, "rules", None)


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def axis_rules(mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Activate a mesh + logical rule table for model tracing."""
    prev = (current_mesh(), current_rules())
    _state.mesh = mesh
    _state.rules = dict(DEFAULT_RULES, **(rules or {}))
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def _resolve(axes: Sequence[Optional[str]], rules: dict, mesh: Mesh,
             shape: Optional[Sequence[int]] = None) -> P:
    """Logical axes tuple → PartitionSpec.

    Drops mesh axes that are absent, already used by an earlier dim, or —
    when ``shape`` is given — do not divide the dim size (jit boundaries
    reject uneven shardings; e.g. deepseek's 56 heads on a 16-way model
    axis fall back to replicated heads, with FSDP still sharding the
    embed dim)."""
    used: set[str] = set()
    spec = []
    for i, name in enumerate(axes):
        entry = rules.get(name) if name else None
        if entry is None:
            spec.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        keep: list[str] = []
        size = None if shape is None else int(shape[i])
        for n in names:
            if n not in mesh.axis_names or n in used:
                continue
            if size is not None and size % (mesh.shape[n]) != 0:
                continue
            keep.append(n)
            used.add(n)
            if size is not None:
                size //= mesh.shape[n]
        if not keep:
            spec.append(None)
        elif len(keep) == 1:
            spec.append(keep[0])
        else:
            spec.append(tuple(keep))
    return P(*spec)


def _manual_axes() -> set:
    """Mesh axes currently under manual (shard_map) control — they must be
    dropped from auto sharding constraints."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is None or not am.axis_names:
            return set()
        return {n for n, t in zip(am.axis_names, am.axis_types)
                if "Manual" in str(t)}
    except Exception:
        return set()


def logical_constraint(x: jax.Array,
                       axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a mesh."""
    mesh = current_mesh()
    rules = current_rules()
    if mesh is None or rules is None or len(mesh.axis_names) == 0:
        return x
    manual = _manual_axes()
    if manual:
        rules = {k: (tuple(a for a in v if a not in manual)
                     if isinstance(v, tuple)
                     else (None if v in manual else v))
                 for k, v in rules.items()}
    spec = _resolve(axes, rules, mesh, shape=x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def spec_for_axes(axes: Sequence[Optional[str]], mesh: Mesh,
                  rules: Optional[dict] = None,
                  shape: Optional[Sequence[int]] = None) -> P:
    return _resolve(axes, dict(DEFAULT_RULES, **(rules or {})), mesh, shape)


def shardings_for_specs(specs_tree: Pytree, mesh: Mesh,
                        rules: Optional[dict] = None,
                        extra_leading: tuple = ()) -> Pytree:
    """NamedSharding pytree from a ParamSpec pytree (shape-aware).

    ``extra_leading`` prepends (logical_axis_name, dim_size) pairs — e.g.
    (("pod_stack", 2),) for the local-SGD per-pod parameter copies."""
    from repro.nn.params import ParamSpec, is_spec
    merged = dict(DEFAULT_RULES, **(rules or {}))
    lead_axes = tuple(a for a, _ in extra_leading)
    lead_shape = tuple(s for _, s in extra_leading)

    def leaf(spec: ParamSpec):
        axes = lead_axes + tuple(spec.axes)
        shape = lead_shape + tuple(spec.shape)
        return NamedSharding(mesh, _resolve(axes, merged, mesh, shape))

    return jax.tree.map(leaf, specs_tree, is_leaf=is_spec)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
