"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks, 7:1 ratio.

[arXiv:2405.04517] 48L d_model=2048 4H (kv=4) d_ff=0 (blocks carry their
own expansions: mLSTM pf=2 up-projection, sLSTM block has a 2x MLP).
"""
import dataclasses
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    pattern=("mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "mlstm",
             "mlstm", "slstm"),
    mlstm_expansion=2,
    optimizer="adamw", learning_rate=3e-4,
    source="arXiv:2405.04517",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=512, pattern=("mlstm", "slstm"),
    dtype="float32")
