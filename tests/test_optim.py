"""Optimizers + schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adafactor, adamw, clip_by_global_norm, sgd,
                         warmup_cosine_schedule)


@pytest.mark.parametrize("make_opt", [
    lambda: sgd(0.1), lambda: sgd(0.05, momentum=0.9),
    lambda: adamw(0.05), lambda: adafactor(0.5),
])
def test_optimizers_minimize_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.asarray([[3.0, -2.0], [1.5, 4.0]]),
              "b": jnp.asarray([1.0, -1.0])}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)
    l0 = float(loss(params))
    for step in range(60):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params,
                                   jnp.asarray(step))
    assert float(loss(params)) < 0.2 * l0


def test_adafactor_state_is_factored():
    opt = adafactor(1e-2)
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
    state = opt.init(params)
    assert state["w"]["row"].shape == (64,)
    assert state["w"]["col"].shape == (32,)
    assert state["b"]["v"].shape == (32,)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    c = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(c["a"])) - 1.0) < 1e-5
    g2 = {"a": jnp.full((4,), 0.01)}
    c2 = clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(c2["a"], g2["a"])


def test_warmup_cosine():
    s = warmup_cosine_schedule(1.0, 10, 100)
    assert 0.0 < float(s(jnp.asarray(0))) <= 0.2
    assert abs(float(s(jnp.asarray(10))) - 1.0) < 0.11
    assert float(s(jnp.asarray(100))) < 0.2
    assert float(s(jnp.asarray(5))) < float(s(jnp.asarray(10)))
