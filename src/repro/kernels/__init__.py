"""Pallas TPU kernels for DIGEST's compute hot-spots.

* ``spmm``: blocked ELL neighbor aggregation — the P_in·H / P_out·H̃ product
  of Eq. 5 (the per-layer hotspot the paper's GPU implementation spends its
  compute on).
* ``flash_attention``: blocked online-softmax attention — the prefill
  hotspot of the assigned transformer architectures.
* ``gat_edge``: fused GAT edge-softmax + aggregation emitting online-
  softmax partials that merge exactly across DIGEST's in-subgraph /
  stale-out-of-subgraph edge split.
"""
from repro.kernels.spmm import spmm, spmm_pallas, spmm_ref
from repro.kernels.flash_attention import (attention_ref,
                                           flash_attention_pallas,
                                           multi_head_attention)
from repro.kernels.gat_edge import (gat_aggregate, gat_edge_partial_pallas,
                                    gat_edge_partial_ref, merge_partials)

__all__ = ["spmm", "spmm_pallas", "spmm_ref", "attention_ref",
           "flash_attention_pallas", "multi_head_attention",
           "gat_aggregate", "gat_edge_partial_pallas",
           "gat_edge_partial_ref", "merge_partials"]
