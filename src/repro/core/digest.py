"""DIGEST — synchronous distributed GNN training with periodic stale sync.

One code path implements all three framework families the paper compares
(§2, Fig. 1) by swapping what the out-of-subgraph halo tables contain:

  mode="digest"       stale reps pulled from the store every N epochs (ours)
  mode="partition"    nothing — cross-subgraph edges dropped (LLCG-family)
  mode="propagation"  fresh reps recomputed and exchanged every epoch
                      (DistDGL-family; exact but communication-heavy)

The epoch function is a single jitted SPMD program: subgraphs are vmapped on
CPU and sharded over the mesh "data" axis under pjit (see
repro.launch.train_gnn), which is the Algorithm-1 `for m in parallel` loop.

Stale state lives in the compact HaloExchange store (boundary rows only,
pluggable fp32/bf16/int8 precision — see repro.core.halo_exchange).  On
non-pull epochs the out-of-subgraph aggregation reads the cached compact
slab *directly* through the fused pull+aggregate kernel; the seed's
materialized ``(M, L-1, H, hidden)`` per-epoch halo cache is gone.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import halo_exchange
from repro.core.halo_exchange import HaloPrecision
from repro.graph.graph import Graph
from repro.graph.partition import StackedPartitions, build_partitions
from repro.models.gnn import (GNNConfig, gnn_forward, gnn_specs, halo_ref)
from repro.nn import init_params, micro_f1, softmax_cross_entropy
from repro.optim import Optimizer

Pytree = Any

MODES = ("digest", "partition", "propagation")


# ---------------------------------------------------------------------------
# Data preparation
# ---------------------------------------------------------------------------

def prepare_graph_data(g: Graph, num_parts: int, method: str = "greedy",
                       seed: int = 0) -> dict:
    """Build the jnp data dict consumed by the epoch function."""
    sp = build_partitions(g, num_parts, method=method, seed=seed)
    full = build_partitions(g, 1, method="random", seed=seed)
    x_global = np.concatenate(
        [g.features, np.zeros((1, g.features.shape[1]), np.float32)], axis=0)

    def _struct(s: StackedPartitions) -> dict:
        return {"in_nbr": jnp.asarray(s.in_nbr),
                "in_wts": jnp.asarray(s.in_wts),
                "out_nbr": jnp.asarray(s.out_nbr),
                "out_wts": jnp.asarray(s.out_wts),
                # Same out-ELL remapped to compact-store slots / global
                # ids, so aggregation can gather from shared slabs.
                "out_nbr_s": jnp.asarray(s.out_nbr_store),
                "out_nbr_g": jnp.asarray(s.out_nbr_global)}

    return {
        "x_global": jnp.asarray(x_global),
        "struct": _struct(sp),
        "local_ids": jnp.asarray(sp.local_ids),
        "local_valid": jnp.asarray(sp.local_valid),
        "halo_ids": jnp.asarray(sp.halo_ids),
        # Compact-store views (HaloExchange slot space).
        "local_slots": jnp.asarray(sp.local_slots),
        "halo_slots": jnp.asarray(sp.halo_slots),
        "store_ids": jnp.asarray(sp.store_ids),
        "labels": jnp.asarray(sp.labels),
        "train_mask": jnp.asarray(sp.train_mask),
        "val_mask": jnp.asarray(sp.val_mask),
        "test_mask": jnp.asarray(sp.test_mask),
        # Full-graph (M=1) view for exact eval / propagation mode.
        "full_struct": _struct(full),
        "full_ids": jnp.asarray(full.local_ids),
        "full_valid": jnp.asarray(full.local_valid),
        "full_labels": jnp.asarray(full.labels),
        "full_train_mask": jnp.asarray(full.train_mask),
        "full_val_mask": jnp.asarray(full.val_mask),
        "full_test_mask": jnp.asarray(full.test_mask),
        # Host-side metadata (not traced).
        "_sp": sp,
        "_graph": g,
    }


def _subgraph_features(x_global: jax.Array, ids: jax.Array) -> jax.Array:
    return x_global[ids]


# ---------------------------------------------------------------------------
# Single-subgraph loss (shared by every mode and by DIGEST-A)
# ---------------------------------------------------------------------------

def make_subgraph_loss(cfg: GNNConfig):
    def loss_fn(params, x_local, halo_tables, struct, labels, mask):
        tables = [jax.lax.stop_gradient(t) for t in halo_tables]
        logits, push = gnn_forward(cfg, params, x_local, tables, struct)
        loss = softmax_cross_entropy(logits, labels, mask)
        return loss, (jnp.stack(push) if push else
                      jnp.zeros((0,) + x_local.shape), logits)
    return loss_fn


def full_graph_forward(cfg: GNNConfig, params: Pytree, data: dict
                       ) -> jax.Array:
    """Exact (no staleness, no partition) forward; returns (N_pad, classes)."""
    x = _subgraph_features(data["x_global"], data["full_ids"][0])
    # Halo is empty in the M=1 view: all out_nbr are sentinels. Supply
    # small correctly-shaped zero tables and remap sentinels into them.
    struct = {k: v[0] for k, v in data["full_struct"].items()}
    H = 8
    tables = [jnp.zeros((H, cfg.in_dim), jnp.float32)]
    tables += [jnp.zeros((H, cfg.hidden_dim), jnp.float32)
               for _ in range(cfg.num_layers - 1)]
    # Remap sentinel halo ids to the small dummy table's sentinel.
    struct = dict(struct)
    struct["out_nbr"] = jnp.minimum(struct["out_nbr"], H)
    logits, reps = gnn_forward(cfg, params, x, tables, struct)
    return logits, reps


# ---------------------------------------------------------------------------
# The DIGEST epoch (Algorithm 1, one global round r)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainSettings:
    sync_interval: int = 10          # N of Algorithm 1
    mode: str = "digest"
    pull_on_first_epoch: bool = False  # paper pulls only at r % N == 0
    # Wire/storage precision of the HaloExchange store (§3.3 byte counts).
    precision: HaloPrecision = HaloPrecision()
    # LLCG-style server correction (for the partition-based baseline): one
    # extra server-side gradient step per round on a sampled node batch
    # with FULL neighbor information [Ramezani et al. 2021].
    llcg_correction: bool = False
    correction_frac: float = 0.1
    correction_lr: float = 1e-3


def make_epoch_fn(cfg: GNNConfig, opt: Optimizer, settings: TrainSettings
                  ) -> Callable:
    if settings.mode not in MODES:
        raise ValueError(settings.mode)
    loss_fn = make_subgraph_loss(cfg)

    def epoch_fn(state: dict, data: dict) -> tuple[dict, dict]:
        r = state["epoch"] + 1            # 1-indexed, as in Algorithm 1
        x_global = data["x_global"]                         # (N+1, d)
        struct = data["struct"]
        # Layer-0 halo features as a compact boundary slab (B+1, d): every
        # out-edge target is a boundary node, so out_nbr_s addresses this
        # slab too and table-wide work (e.g. GAT's projection) stays
        # O(|boundary|), not O(N).  Row B inherits x_global's zero
        # sentinel.  The partition baseline drops cross-subgraph
        # information by zeroing the halo *tables* (this slab; the stale
        # slab below stays at its zero init), NOT the ELL weights — GAT's
        # attention denominator and SAGE's mean still see the dropped
        # neighbors as zero vectors, matching the seed semantics exactly.
        x_halo_slab = x_global[data["store_ids"]]           # (B+1, d)
        if settings.mode == "partition":
            x_halo_slab = jnp.zeros_like(x_halo_slab)

        # The stale slab feeding this epoch's out-of-subgraph products —
        # compact (L-1, B+1, hid) in storage precision, never expanded to
        # a per-subgraph (M, L-1, H, hid) cache.
        if settings.mode == "propagation" and cfg.num_layers > 1:
            # Fresh exchange every epoch: exact reps at current params,
            # gathered down to the boundary slab.
            _, reps = full_graph_forward(cfg, state["params"], data)
            ids = jnp.clip(data["store_ids"], 0, reps[0].shape[0] - 1)
            slab = jnp.stack([rep[ids] for rep in reps])  # (L-1, B+1, hid)
            slab = slab.at[:, -1, :].set(0.0)             # zero sentinel
            q, sc = halo_exchange.quantize_rows(slab, settings.precision)
            cache = {"data": q} if sc is None else {"data": q, "scale": sc}
        elif settings.mode == "digest":
            do_pull = (r % settings.sync_interval == 0)
            if settings.pull_on_first_epoch:
                do_pull = do_pull | (r == 1)
            # PULL = snapshot the compact store (O(B·L·d) copy).
            cache = jax.lax.cond(do_pull, lambda: state["store"],
                                 lambda: state["cache"])
        else:
            cache = state["cache"]

        x_local = x_global[data["local_ids"]]               # (M, S, d)
        n_hidden = cfg.num_layers - 1

        def sub_loss(params, x_loc, struct_m, labels, mask):
            # Layer 0 gathers raw halo features from the boundary feature
            # slab; layers ℓ≥1 gather stale reps straight from the compact
            # store slab — both via the fused pull+aggregate path.
            tables = [halo_ref(x_halo_slab, None, struct_m["out_nbr_s"],
                               struct_m["out_wts"])]
            for ell in range(n_hidden):
                tables.append(halo_ref(
                    *halo_exchange.layer_table(cache, ell),
                    struct_m["out_nbr_s"], struct_m["out_wts"]))
            return loss_fn(params, x_loc, tables, struct_m, labels, mask)

        vg = jax.vmap(jax.value_and_grad(sub_loss, has_aux=True),
                      in_axes=(None, 0, 0, 0, 0))
        (losses, (push_reps, logits)), grads = vg(
            state["params"], x_local, struct,
            data["labels"], data["train_mask"])

        # Global AGG (Algorithm 1 line 13): uniform average over subgraphs.
        mean_grads = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
        params, opt_state = opt.update(mean_grads, state["opt_state"],
                                       state["params"], state["step"])

        if settings.llcg_correction:
            # LLCG server correction: full-neighbor gradient on a sampled
            # node mini-batch, plain SGD on the server.
            key = jax.random.fold_in(jax.random.PRNGKey(17), r)
            sample = (jax.random.uniform(key, data["full_train_mask"][0]
                                         .shape)
                      < settings.correction_frac)
            corr_mask = data["full_train_mask"][0] & sample

            def server_loss(p):
                logits, _ = full_graph_forward(cfg, p, data)
                return softmax_cross_entropy(
                    logits, data["full_labels"][0],
                    corr_mask.astype(jnp.float32))

            corr_grads = jax.grad(server_loss)(params)
            params = jax.tree.map(
                lambda p, g: p - settings.correction_lr * g, params,
                corr_grads)

        # Periodic PUSH (lines 9–10): epochs r = 1, N+1, 2N+1, ...
        new_store = state["store"]
        eps = jnp.zeros((max(cfg.num_layers - 1, 1),), jnp.float32)
        if settings.mode == "digest" and cfg.num_layers > 1:
            do_push = ((r - 1) % settings.sync_interval == 0)
            eps = halo_exchange.staleness_error(
                state["store"], push_reps, data["local_slots"],
                data["local_valid"])
            new_store = jax.lax.cond(
                do_push,
                lambda: halo_exchange.push(
                    state["store"], data["local_slots"],
                    data["local_valid"], push_reps),
                lambda: state["store"])

        train_acc = micro_f1(logits, data["labels"],
                             data["train_mask"].astype(jnp.float32))
        new_state = {"params": params, "opt_state": opt_state,
                     "store": new_store, "cache": cache,
                     "epoch": r, "step": state["step"] + 1}
        metrics = {"loss": jnp.mean(losses), "train_f1": train_acc,
                   "staleness_eps": eps}
        return new_state, metrics

    return epoch_fn


# ---------------------------------------------------------------------------
# State init + high-level training loop
# ---------------------------------------------------------------------------

def init_state(cfg: GNNConfig, opt: Optimizer, data: dict, seed: int = 0,
               precision: HaloPrecision = HaloPrecision()) -> dict:
    params = init_params(jax.random.PRNGKey(seed), gnn_specs(cfg))
    num_slots = int(data["store_ids"].shape[0]) - 1
    l1 = max(cfg.num_layers - 1, 1)
    return {
        "params": params,
        "opt_state": opt.init(params),
        # Authoritative compact store + the last pulled snapshot of it
        # (both O(|boundary|·L·d); the seed kept an O(M·H·L·d) cache).
        "store": halo_exchange.init_store(l1, num_slots, cfg.hidden_dim,
                                          precision),
        "cache": halo_exchange.init_store(l1, num_slots, cfg.hidden_dim,
                                          precision),
        "epoch": jnp.asarray(0, jnp.int32),
        "step": jnp.asarray(0, jnp.int32),
    }


@functools.partial(jax.jit, static_argnames=("cfg",))
def evaluate(cfg: GNNConfig, params: Pytree, data: dict) -> dict:
    logits, _ = full_graph_forward(cfg, params, data)
    out = {}
    for split in ("train", "val", "test"):
        mask = data[f"full_{split}_mask"][0].astype(jnp.float32)
        out[f"{split}_f1"] = micro_f1(logits, data["full_labels"][0], mask)
        out[f"{split}_loss"] = softmax_cross_entropy(
            logits, data["full_labels"][0], mask)
    return out


def digest_train(cfg: GNNConfig, opt: Optimizer, data: dict,
                 settings: TrainSettings, epochs: int,
                 eval_every: int = 10, seed: int = 0,
                 verbose: bool = False) -> tuple[dict, dict]:
    """Run training; returns (final_state, history dict of lists)."""
    state = init_state(cfg, opt, data, seed=seed,
                       precision=settings.precision)
    epoch_fn = jax.jit(make_epoch_fn(cfg, opt, settings))
    tdata = {k: v for k, v in data.items() if not k.startswith("_")}
    hist: dict[str, list] = {"epoch": [], "loss": [], "train_f1": [],
                             "val_f1": [], "test_f1": [], "time": [],
                             "staleness_eps": []}
    t0 = time.perf_counter()
    for e in range(epochs):
        state, m = epoch_fn(state, tdata)
        if (e + 1) % eval_every == 0 or e == epochs - 1:
            ev = evaluate(cfg, state["params"], tdata)
            hist["epoch"].append(e + 1)
            hist["loss"].append(float(m["loss"]))
            hist["train_f1"].append(float(m["train_f1"]))
            hist["val_f1"].append(float(ev["val_f1"]))
            hist["test_f1"].append(float(ev["test_f1"]))
            hist["staleness_eps"].append(
                np.asarray(m["staleness_eps"]).tolist())
            hist["time"].append(time.perf_counter() - t0)
            if verbose:
                print(f"[{settings.mode}] epoch {e+1:4d} "
                      f"loss {float(m['loss']):.4f} "
                      f"val_f1 {float(ev['val_f1']):.4f}")
    return state, hist
