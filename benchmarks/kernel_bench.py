"""Kernel micro-benchmarks (CPU host timings of the jnp paths; the Pallas
TPU kernels are validated in interpret mode and characterized structurally
in the roofline — wall-clock kernel timing needs real hardware).

The resident-vs-streaming halo_spmm pair runs both Pallas variants in
interpret mode on an identical int8 slab: the numbers are Python-
interpreter timings (not TPU wall clock) but pin the structural cost of
chunking — and, more importantly, that the streaming path handles a slab
several chunks long while the resident path parks it whole in VMEM."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import halo_exchange as hx
from repro.kernels.flash_attention import multi_head_attention
from repro.kernels.spmm import (halo_spmm_pallas, halo_spmm_stream_pallas,
                                spmm)
from repro.models.attention import chunked_attention


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    rows = []
    # SpMM: aggregation for a 4096-node subgraph, deg 16, d=128.
    nbr = jnp.asarray(rng.integers(0, 4097, (4096, 16)), jnp.int32)
    wts = jnp.asarray(rng.random((4096, 16)), jnp.float32)
    tab = jnp.asarray(rng.normal(size=(4097, 128)), jnp.float32)
    f = jax.jit(lambda a, b, c: spmm(a, b, c, backend="jnp"))
    rows.append({"name": "kernel/spmm_4096x16x128",
                 "us_per_call": round(time_call(f, nbr, wts, tab), 1)})
    # Resident vs streaming fused halo pull+aggregate (interpret mode)
    # over a 2048-row int8 slab — 4 chunks of 512 for the streaming path.
    h_nbr = jnp.asarray(rng.integers(0, 2048, (128, 8)), jnp.int32)
    h_wts = jnp.asarray(rng.random((128, 8)), jnp.float32)
    slab = jnp.asarray(rng.normal(size=(2048, 128)), jnp.float32)
    data, scale = hx.quantize_rows(slab, hx.HaloPrecision("int8"))
    data = data.at[-1].set(0)
    res = jax.jit(lambda a, b, c, d: halo_spmm_pallas(
        a, b, c, d, interpret=True))
    stm = jax.jit(lambda a, b, c, d: halo_spmm_stream_pallas(
        a, b, c, d, chunk_rows=512, interpret=True))
    rows.append({"name": "kernel/halo_spmm_resident_2048x128_int8",
                 "us_per_call": round(time_call(res, h_nbr, h_wts, data,
                                                scale), 1)})
    rows.append({"name": "kernel/halo_spmm_stream_2048x128_int8",
                 "us_per_call": round(time_call(stm, h_nbr, h_wts, data,
                                                scale), 1)})
    # Attention 2x1024x8x64.
    q = jnp.asarray(rng.normal(size=(2, 1024, 8, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(2, 1024, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(2, 1024, 2, 64)), jnp.bfloat16)
    g = jax.jit(lambda a, b, c: multi_head_attention(a, b, c,
                                                     backend="jnp"))
    rows.append({"name": "kernel/attn_dense_1k",
                 "us_per_call": round(time_call(g, q, k, v), 1)})
    h = jax.jit(lambda a, b, c: chunked_attention(a, b, c, chunk=256))
    rows.append({"name": "kernel/attn_chunked_1k",
                 "us_per_call": round(time_call(h, q, k, v), 1)})
    return rows


if __name__ == "__main__":
    emit(run())
