"""llama4-scout-17b-a16e [moe] — 16 experts, top-1, shared expert,
early-fusion token stream.

[hf:meta-llama/Llama-4-Scout-17B-16E] 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 16e top-1.
"""
import dataclasses
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    pattern=("moe",), num_experts=16, experts_per_token=1,
    shared_expert=True, rope_theta=500000.0,
    optimizer="adafactor", learning_rate=1.5e-4,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, head_dim=32, num_experts=4,
    dtype="float32", optimizer="adamw", moe_impl="ref")
