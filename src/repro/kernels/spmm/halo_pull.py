"""Pallas TPU kernel: fused halo pull + aggregate over the compact slab.

Computes the out-of-subgraph side of DIGEST's Eq. 5

    out[i] = sum_k wts[i, k] * dequant(slab[nbr[i, k]])

where ``slab`` is the HaloExchange compact store layer — fp32, bf16, or
int8 with per-row fp32 scales — and ``nbr`` holds *compact-store slot*
indices (sentinel == slab.shape[0]-1, a zero row).  Fusing the gather into
the ELL product means the non-pull epochs of Algorithm 1 never materialize
the ``(M, L-1, H, hidden)`` halo cache the seed implementation kept: each
row block reads exactly the slab rows its edges touch, and int8 rows are
dequantized in-register (VMEM traffic shrinks by the same 2–4× as the
§3.3 wire format).

Three grid/block designs share one inner loop:

  * **Resident** (:func:`halo_spmm_pallas`): grid = (row_blocks,
    feature_blocks), the slab carried whole per feature-block into VMEM —
    int8 slabs fit 4× more rows in the same VMEM budget.  Right while the
    128-wide slab stripe is ≲ a few MiB (B ≲ 8k fp32 rows).
  * **Streaming** (:func:`halo_spmm_stream_pallas`): grid = (row_blocks,
    feature_blocks, slab_chunks) under a ``PrefetchScalarGridSpec`` whose
    scalar-prefetch argument carries the per-chunk base rows.  The slab
    enters in ``chunk_rows``-row tiles; because the chunk axis is the
    innermost grid dimension and the output block index is chunk-
    invariant, Pallas keeps the accumulator tile resident in VMEM and its
    pipeline double-buffers the HBM→VMEM DMA of chunk c+1 behind the
    gather/FMA of chunk c.  VMEM residency is O(chunk) instead of O(B),
    so web-scale boundary slabs stream at full DMA bandwidth.  Each chunk
    contributes only the edges whose slot falls inside it (out-of-chunk
    gathers are masked to weight 0), and partial sums accumulate in fp32
    across chunks — bitwise-reassociated vs. the resident kernel, equal
    within dtype tolerance.

  * **Chunk-skipping streamed** (:func:`halo_spmm_skip_pallas`): same
    tiling as the streaming kernel, but the innermost grid dimension is
    re-indexed through a precomputed CSR-style worklist
    (:class:`repro.graph.partition.ChunkWorklist`): grid = (row_blocks,
    feature_blocks, ``max_chunks_per_block``), and the data BlockSpec's
    index map reads ``wl_ids[i, t]`` from the scalar-prefetch argument —
    row block i streams *only the chunks its edges reference* through
    the same double-buffered pipeline.  Under owner-sharded slot layout
    halo references cluster by owner, so measured occupancy is far below
    1 and DMA bytes scale with occupied work, not slab size.  Padded
    worklist entries repeat the last visited chunk (the resident block is
    re-addressed, no DMA) and are masked out of the FMA (``t >= cnt``),
    so the result is **bitwise identical** to the dense stream with the
    same ``chunk_rows``: skipped chunks contribute exact ±0.0 terms,
    which never perturb an fp32 accumulator.

Per-row scales ride along as a (rows, 1) fp32 column and are folded into
the edge weight (``w · scale[idx]``) before the FMA, so the inner loop
stays a gather + single fused multiply-add in all three designs.

**Staleness-alleviated prediction epilogue** (``pdata`` / ``pscale`` /
``gamma``): when the SAT predictor is on (see ``repro.core.predictor``),
the history slab rides beside the data slab through the SAME BlockSpecs
and the gathered row becomes ``dequant(data[s]) + γ·dequant(pdata[s])``
inside the existing inner loop — one extra gather+FMA per edge in all
three designs, never a second aggregation pass.  ``gamma`` is a static
(jit-cache-keyed) float; with ``pdata=None`` the emitted kernels are
exactly the predictor-free ones.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.spmm.spmm import BLOCK_F, BLOCK_ROWS, spmm_pallas

# Streaming-variant tile height: 512 fp32 rows × 128-wide stripe = 256 KiB
# per buffer (×2 for the double buffer) — far under the 16 MiB VMEM budget
# while long enough to amortize DMA issue latency.
STREAM_CHUNK_ROWS = 512


def _halo_kernel_scaled(nbr_ref, wts_ref, data_ref, scale_ref, out_ref):
    deg = nbr_ref.shape[1]
    table = data_ref[...]                        # (rows_tab, BF) int8
    scale = scale_ref[...][:, 0]                 # (rows_tab,) fp32

    def body(k, acc):
        idx = nbr_ref[:, k]
        gathered = jnp.take(table, idx, axis=0).astype(jnp.float32)
        # Fold the per-row dequant scale into the edge weight: one FMA.
        w = wts_ref[:, k].astype(jnp.float32) * jnp.take(scale, idx, axis=0)
        return acc + w[:, None] * gathered

    acc = jnp.zeros(out_ref.shape, jnp.float32)
    acc = jax.lax.fori_loop(0, deg, body, acc)
    out_ref[...] = acc.astype(out_ref.dtype)


def _make_resident_pred_kernel(gamma: float):
    """Resident kernel with the SAT epilogue: each gathered row is
    ``dequant(data[s]) + gamma * dequant(pdata[s])`` — the prediction
    rides the same gather loop, one extra gather+FMA per edge."""
    def kernel(nbr_ref, wts_ref, data_ref, scale_ref, pdata_ref,
               pscale_ref, out_ref):
        deg = nbr_ref.shape[1]
        table = data_ref[...]
        scale = scale_ref[...][:, 0]
        ptable = pdata_ref[...]
        pscale = pscale_ref[...][:, 0]

        def body(k, acc):
            idx = nbr_ref[:, k]
            w = wts_ref[:, k].astype(jnp.float32)
            gathered = jnp.take(table, idx, axis=0).astype(jnp.float32)
            pgathered = jnp.take(ptable, idx, axis=0).astype(jnp.float32)
            ws = w * jnp.take(scale, idx, axis=0)
            wp = w * jnp.float32(gamma) * jnp.take(pscale, idx, axis=0)
            return acc + ws[:, None] * gathered + wp[:, None] * pgathered

        acc = jnp.zeros(out_ref.shape, jnp.float32)
        acc = jax.lax.fori_loop(0, deg, body, acc)
        out_ref[...] = acc.astype(out_ref.dtype)

    return kernel


@functools.partial(jax.jit, static_argnames=("gamma", "interpret"))
def halo_spmm_pallas(nbr: jax.Array, wts: jax.Array, data: jax.Array,
                     scale: jax.Array = None, pdata: jax.Array = None,
                     pscale: jax.Array = None, gamma: float = 1.0,
                     interpret: bool = True) -> jax.Array:
    """Fused pull+aggregate via pallas_call.

    Args:
      nbr:   (rows, deg) int32 — compact-store slot ids (< data.shape[0]).
      wts:   (rows, deg) float — 0 at padding slots.
      data:  (n_slots_padded, feat) slab incl. sentinel row (fp32/bf16/int8).
      scale: optional (n_slots_padded, 1) fp32 per-row dequant scales.
      pdata/pscale: optional predictor-history slab in the same layout;
        gathered rows become dequant(data) + gamma·dequant(pdata).
      gamma: static extrapolation coefficient (jit-cache-keyed).
    Returns:
      (rows, feat) float32 result.
    """
    if scale is None and pdata is None:
        # Unscaled fp32/bf16 slabs are exactly the ELL SpMM (its inner
        # loop already upcasts gathered rows to f32); one kernel body to
        # keep in sync for future block/DMA changes.
        return spmm_pallas(nbr, wts, data, interpret=interpret)
    rows, deg = nbr.shape
    n_tab, feat = data.shape
    br = min(BLOCK_ROWS, rows)
    bf = min(BLOCK_F, feat)
    if rows % br or feat % bf:
        raise ValueError(f"rows={rows} feat={feat} must be divisible by "
                         f"block ({br},{bf}); pad upstream")
    grid = (rows // br, feat // bf)
    specs = [
        pl.BlockSpec((br, deg), lambda i, j: (i, 0)),
        pl.BlockSpec((br, deg), lambda i, j: (i, 0)),
        pl.BlockSpec((n_tab, bf), lambda i, j: (0, j)),
        pl.BlockSpec((n_tab, 1), lambda i, j: (0, 0)),
    ]
    if scale is None:
        scale = jnp.ones((n_tab, 1), jnp.float32)
    if pdata is None:
        return pl.pallas_call(
            _halo_kernel_scaled,
            grid=grid,
            in_specs=specs,
            out_specs=pl.BlockSpec((br, bf), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((rows, feat), jnp.float32),
            interpret=interpret,
        )(nbr, wts, data, scale)
    if pscale is None:
        pscale = jnp.ones((n_tab, 1), jnp.float32)
    specs += [
        pl.BlockSpec((n_tab, bf), lambda i, j: (0, j)),
        pl.BlockSpec((n_tab, 1), lambda i, j: (0, 0)),
    ]
    return pl.pallas_call(
        _make_resident_pred_kernel(gamma),
        grid=grid,
        in_specs=specs,
        out_specs=pl.BlockSpec((br, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, feat), jnp.float32),
        interpret=interpret,
    )(nbr, wts, data, scale, pdata, pscale)


def _chunk_contrib(base, chunk_rows: int, nbr_ref, wts_ref, data_ref,
                   scale_ref, out_shape, pdata_ref=None, pscale_ref=None,
                   gamma: float = 1.0):
    """One chunk's masked gather/dequant/FMA partial sum — the single
    inner loop both streamed kernels (dense and chunk-skipping) run, so
    their bitwise-equality invariant has one source of truth.  Edges
    whose slot falls outside [base, base + chunk_rows) contribute exact
    ±0.0.  With a predictor tile (``pdata_ref``/``pscale_ref``) the
    gathered row is the SAT prediction dequant(data) + γ·dequant(pdata)
    — one extra gather+FMA inside the same loop, again for both streamed
    kernels at once."""
    deg = nbr_ref.shape[1]
    table = data_ref[...]                        # (chunk_rows, BF) tile
    scale = scale_ref[...][:, 0]                 # (chunk_rows,)
    if pdata_ref is not None:
        ptable = pdata_ref[...]
        pscale = pscale_ref[...][:, 0]

    def body(k, acc):
        idx = nbr_ref[:, k] - base
        hit = (idx >= 0) & (idx < chunk_rows)
        idx = jnp.where(hit, idx, 0)
        gathered = jnp.take(table, idx, axis=0).astype(jnp.float32)
        w = (wts_ref[:, k].astype(jnp.float32)
             * jnp.take(scale, idx, axis=0)
             * hit.astype(jnp.float32))
        acc = acc + w[:, None] * gathered
        if pdata_ref is not None:
            pgathered = jnp.take(ptable, idx, axis=0).astype(jnp.float32)
            wp = (wts_ref[:, k].astype(jnp.float32) * jnp.float32(gamma)
                  * jnp.take(pscale, idx, axis=0)
                  * hit.astype(jnp.float32))
            acc = acc + wp[:, None] * pgathered
        return acc

    return jax.lax.fori_loop(0, deg, body,
                             jnp.zeros(out_shape, jnp.float32))


def _make_stream_kernel(chunk_rows: int, pred: bool = False,
                        gamma: float = 1.0):
    def kernel(base_ref, nbr_ref, wts_ref, data_ref, scale_ref, *rest):
        pdata_ref, pscale_ref = (rest[0], rest[1]) if pred else (None, None)
        out_ref = rest[-1]
        c = pl.program_id(2)

        @pl.when(c == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        out_ref[...] += _chunk_contrib(base_ref[c], chunk_rows, nbr_ref,
                                       wts_ref, data_ref, scale_ref,
                                       out_ref.shape, pdata_ref,
                                       pscale_ref, gamma)

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("chunk_rows", "gamma", "interpret"))
def halo_spmm_stream_pallas(nbr: jax.Array, wts: jax.Array,
                            data: jax.Array, scale: jax.Array = None,
                            pdata: jax.Array = None,
                            pscale: jax.Array = None, gamma: float = 1.0,
                            chunk_rows: int = STREAM_CHUNK_ROWS,
                            interpret: bool = True) -> jax.Array:
    """Streaming fused pull+aggregate: the slab never resides in VMEM.

    Same contract as :func:`halo_spmm_pallas`, but the slab is tiled into
    ``chunk_rows``-row chunks streamed through VMEM by the Pallas
    pipeline (double-buffered HBM→VMEM DMA on TPU) while the output tile
    accumulates in place.  Handles slabs far beyond the VMEM-resident
    limit; fp32 accumulation is reassociated across chunks, so results
    match the resident kernel within dtype tolerance (exactly for the
    sub-sums inside one chunk).
    """
    rows, deg = nbr.shape
    n_tab, feat = data.shape
    br = min(BLOCK_ROWS, rows)
    bf = min(BLOCK_F, feat)
    if rows % br or feat % bf:
        raise ValueError(f"rows={rows} feat={feat} must be divisible by "
                         f"block ({br},{bf}); pad upstream")
    if scale is None:
        scale = jnp.ones((n_tab, 1), jnp.float32)
    pred = pdata is not None
    if pred and pscale is None:
        pscale = jnp.ones((n_tab, 1), jnp.float32)
    # Pad the slab (and scales) to a whole number of chunks; padding rows
    # are all-zero and no index ever reaches them.
    pad = (-n_tab) % chunk_rows
    if pad:
        data = jnp.pad(data, ((0, pad), (0, 0)))
        scale = jnp.pad(scale, ((0, pad), (0, 0)), constant_values=1.0)
        if pred:
            pdata = jnp.pad(pdata, ((0, pad), (0, 0)))
            pscale = jnp.pad(pscale, ((0, pad), (0, 0)),
                             constant_values=1.0)
    n_chunks = (n_tab + pad) // chunk_rows
    chunk_base = jnp.arange(n_chunks, dtype=jnp.int32) * chunk_rows

    in_specs = [
        pl.BlockSpec((br, deg), lambda i, j, c, b: (i, 0)),
        pl.BlockSpec((br, deg), lambda i, j, c, b: (i, 0)),
        pl.BlockSpec((chunk_rows, bf), lambda i, j, c, b: (c, j)),
        pl.BlockSpec((chunk_rows, 1), lambda i, j, c, b: (c, 0)),
    ]
    operands = [chunk_base, nbr, wts, data, scale]
    if pred:
        # The history slab streams chunk-for-chunk beside the data slab.
        in_specs += [
            pl.BlockSpec((chunk_rows, bf), lambda i, j, c, b: (c, j)),
            pl.BlockSpec((chunk_rows, 1), lambda i, j, c, b: (c, 0)),
        ]
        operands += [pdata, pscale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        # Chunk axis innermost: the output block index is chunk-invariant,
        # so the accumulator tile stays in VMEM while slab chunks stream
        # past it (the pipeline prefetches chunk c+1 during chunk c).
        grid=(rows // br, feat // bf, n_chunks),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((br, bf), lambda i, j, c, b: (i, j)),
    )
    return pl.pallas_call(
        _make_stream_kernel(chunk_rows, pred, gamma),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows, feat), jnp.float32),
        interpret=interpret,
    )(*operands)


def _make_skip_kernel(chunk_rows: int, count_visits: bool,
                      pred: bool = False, gamma: float = 1.0):
    def kernel(ids_ref, cnt_ref, nbr_ref, wts_ref, data_ref, scale_ref,
               *rest):
        pdata_ref, pscale_ref = (rest[0], rest[1]) if pred else (None, None)
        out_refs = rest[2:] if pred else rest
        out_ref = out_refs[0]
        i = pl.program_id(0)
        t = pl.program_id(2)

        @pl.when(t == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        # Worklist lookup: this grid step carries slab chunk ids[i, t]
        # (the data/scale BlockSpecs below used the same entry, so that
        # chunk's tile is what sits in VMEM).  Entries at t >= cnt[i]
        # repeat the previous chunk — already resident, no DMA — and are
        # masked out of the accumulation here.
        base = ids_ref[i, t] * chunk_rows
        active = t < cnt_ref[i]

        @pl.when(active)
        def _accumulate():
            out_ref[...] += _chunk_contrib(base, chunk_rows, nbr_ref,
                                           wts_ref, data_ref, scale_ref,
                                           out_ref.shape, pdata_ref,
                                           pscale_ref, gamma)

        if count_visits:
            visit_ref = out_refs[1]

            @pl.when(pl.program_id(1) == 0)
            def _log():
                visit_ref[0, 0] = jnp.where(active, ids_ref[i, t],
                                            jnp.int32(-1))

    return kernel


@functools.partial(jax.jit, static_argnames=("chunk_rows", "gamma",
                                             "interpret", "count_visits"))
def halo_spmm_skip_pallas(nbr: jax.Array, wts: jax.Array, data: jax.Array,
                          scale: jax.Array = None,
                          wl_ids: jax.Array = None,
                          wl_cnt: jax.Array = None,
                          pdata: jax.Array = None,
                          pscale: jax.Array = None, gamma: float = 1.0,
                          chunk_rows: int = STREAM_CHUNK_ROWS,
                          interpret: bool = True,
                          count_visits: bool = False):
    """Chunk-skipping streamed pull+aggregate: occupancy-proportional DMA.

    Same contract as :func:`halo_spmm_stream_pallas`, plus a precomputed
    worklist (``repro.graph.partition.build_chunk_worklist`` with the
    same ``chunk_rows`` and the kernel's 128-row blocks):

      wl_ids: (row_blocks, max_chunks) int32 — ascending chunk ids each
        row block must visit, padded by repeating the last entry.
      wl_cnt: (row_blocks,) int32 — valid prefix length per block.

    The innermost grid dimension runs over the *worklist position* t, and
    the slab BlockSpec resolves it to chunk ``wl_ids[i, t]`` via scalar
    prefetch — so the pipeline DMAs exactly the occupied chunks (padded
    steps re-address the resident block) while keeping the streaming
    kernel's double-buffered overlap and in-VMEM accumulator.  Bitwise
    equal to the dense stream at the same ``chunk_rows``.

    With ``count_visits=True`` a second output (row_blocks, max_chunks)
    int32 records the chunk id processed at each (block, t) — ``-1`` at
    masked padding steps — so tests can assert visited chunks ==
    worklist entries.  Debug/interpret-mode only: the (1, 1) block shape
    is not a legal TPU tile.
    """
    rows, deg = nbr.shape
    n_tab, feat = data.shape
    br = min(BLOCK_ROWS, rows)
    bf = min(BLOCK_F, feat)
    if rows % br or feat % bf:
        raise ValueError(f"rows={rows} feat={feat} must be divisible by "
                         f"block ({br},{bf}); pad upstream")
    if wl_ids is None or wl_cnt is None:
        raise ValueError("halo_spmm_skip_pallas needs the (wl_ids, wl_cnt)"
                         " worklist; build it with "
                         "repro.graph.partition.build_chunk_worklist")
    n_blocks, max_chunks = wl_ids.shape
    n_chunks = max(-(-n_tab // chunk_rows), 1)
    if n_blocks != rows // br or wl_cnt.shape != (n_blocks,):
        raise ValueError(
            f"worklist geometry mismatch: wl_ids {wl_ids.shape} / wl_cnt "
            f"{wl_cnt.shape} vs {rows // br} row blocks of {br} rows — "
            f"rebuild the worklist with block_rows={br}")
    if max_chunks > n_chunks:
        # A well-formed worklist never lists more distinct chunks than
        # the slab tiling has — a wider one means it was built with a
        # smaller chunk_rows than this call's.  (The converse mismatch —
        # a coarser worklist — is undetectable from the traced arrays;
        # keep the build chunk_rows and the call chunk_rows wired to the
        # same knob, as GNNConfig.stream_chunk_rows does.)
        raise ValueError(
            f"worklist chunk-geometry mismatch: wl_ids lists up to "
            f"{max_chunks} chunks per block but a {n_tab}-row slab at "
            f"chunk_rows={chunk_rows} has only {n_chunks} — rebuild the "
            f"worklist with this chunk_rows")
    if scale is None:
        scale = jnp.ones((n_tab, 1), jnp.float32)
    pred = pdata is not None
    if pred and pscale is None:
        pscale = jnp.ones((n_tab, 1), jnp.float32)
    pad = (-n_tab) % chunk_rows
    if pad:
        data = jnp.pad(data, ((0, pad), (0, 0)))
        scale = jnp.pad(scale, ((0, pad), (0, 0)), constant_values=1.0)
        if pred:
            pdata = jnp.pad(pdata, ((0, pad), (0, 0)))
            pscale = jnp.pad(pscale, ((0, pad), (0, 0)),
                             constant_values=1.0)

    out_shape = [jax.ShapeDtypeStruct((rows, feat), jnp.float32)]
    out_specs = [pl.BlockSpec((br, bf), lambda i, j, t, ids, cnt: (i, j))]
    if count_visits:
        out_shape.append(jax.ShapeDtypeStruct((n_blocks, max_chunks),
                                              jnp.int32))
        out_specs.append(pl.BlockSpec((1, 1),
                                      lambda i, j, t, ids, cnt: (i, t)))

    in_specs = [
        pl.BlockSpec((br, deg), lambda i, j, t, ids, cnt: (i, 0)),
        pl.BlockSpec((br, deg), lambda i, j, t, ids, cnt: (i, 0)),
        pl.BlockSpec((chunk_rows, bf),
                     lambda i, j, t, ids, cnt: (ids[i, t], j)),
        pl.BlockSpec((chunk_rows, 1),
                     lambda i, j, t, ids, cnt: (ids[i, t], 0)),
    ]
    operands = [wl_ids, wl_cnt, nbr, wts, data, scale]
    if pred:
        # History slab tiles resolve through the same worklist entry, so
        # skipped chunks stay skipped with the predictor on.
        in_specs += [
            pl.BlockSpec((chunk_rows, bf),
                         lambda i, j, t, ids, cnt: (ids[i, t], j)),
            pl.BlockSpec((chunk_rows, 1),
                         lambda i, j, t, ids, cnt: (ids[i, t], 0)),
        ]
        operands += [pdata, pscale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        # Worklist position innermost: the output block index is
        # t-invariant (accumulator stays in VMEM) and the slab BlockSpec
        # resolves t through the prefetched worklist, so the pipeline
        # prefetches chunk ids[i, t+1] during chunk ids[i, t].
        grid=(rows // br, feat // bf, max_chunks),
        in_specs=in_specs,
        out_specs=out_specs if count_visits else out_specs[0],
    )
    out = pl.pallas_call(
        _make_skip_kernel(chunk_rows, count_visits, pred, gamma),
        grid_spec=grid_spec,
        out_shape=out_shape if count_visits else out_shape[0],
        interpret=interpret,
    )(*operands)
    return out
