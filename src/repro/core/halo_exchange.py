"""HaloExchange — DIGEST's stale-representation KVS, owner-sharded and
precision-aware.

This subsystem implements the PUSH/PULL lines of Algorithm 1 over a
**compact, owner-sharded** slab that holds only *boundary* nodes — rows
that appear in at least one subgraph's halo — instead of the dense
``(L-1, N+1, hidden)`` array the seed used.

Owner-sharded layout (see ``repro.graph.partition.build_partitions``):
the slot space is M contiguous shards of ``shard_rows`` rows, shard m
holding exactly the boundary rows *owned* (pushed) by part m, with the
last row of every shard a per-owner zero sentinel.  Sharded slot-wise
over the mesh "data" axis, device m therefore stores ``1/M`` of the slab
and every PUSH scatter is shard-local.  Mapping to the paper:

  * Algorithm 1 line 9–10 (``PUSH h_v^(ℓ) for v ∈ V_m``)  →  :func:`push`
    (SPMD scatter; the partitioner routes every row of part m into shard
    m, so writes never cross devices) or :func:`shard_push` (the explicit
    ``shard_map`` form with owner-local offsets).  Non-boundary local
    rows are dropped via the owner's sentinel row — no other subgraph
    ever reads them (this is what shrinks the store from O(N·L·d) to
    O(|boundary|·L·d), the Fig. 9 memory term).
  * Algorithm 1 line 5 (``PULL h̃_u^(ℓ) for u ∈ halo(G_m)``)  →
    :func:`pull_slab` (dense-gather form: under pjit XLA lowers it to an
    all-gather of the shards — the fallback) or :func:`collective_pull`
    (the ragged ``shard_map`` form: an ``all_to_all`` that ships only the
    slots each subgraph's halo actually references, per the
    :class:`~repro.graph.partition.PullPlan`).  Both return a
    **device-local** per-subgraph slab ``(M, L-1, H+1, hidden)`` in
    storage precision — non-pull epochs read this local slice through the
    fused pull+aggregate kernel :func:`repro.kernels.spmm.halo_spmm`, so
    nothing replicated and no ``(M, L-1, H, hidden)`` fp32 cache is ever
    materialized.
  * §3.3 communication terms  →  :meth:`HaloSpec.comm_bytes`: the ragged
    pull ships ``Σ_m |halo(G_m)| · (L-1) · row_bytes`` per sync versus
    ``(M-1) · store_nbytes`` for the replicated snapshot
    (:meth:`HaloSpec.replicated_pull_nbytes`); pushes ship
    ``Σ_m |boundary ∩ V_m| · (L-1) · row_bytes``.
  * Theorem 1's per-layer staleness ε^(ℓ)  →  :func:`staleness_error`,
    measured over the rows actually served to other subgraphs.

Precision (:class:`HaloPrecision`) is pluggable and applies to both the
slab layout (storage) and the §3.3 wire format:

  ======  ==================================  ==========================
  mode    row encoding                        bytes / hidden value
  ======  ==================================  ==========================
  fp32    float32                             4
  bf16    bfloat16                            2
  int8    int8 + one float32 scale per row    1 (+ 4 / hidden amortized)
  ======  ==================================  ==========================

int8 uses symmetric per-row quantization: ``scale = max|row| / 127``,
``q = round(row / scale)``; the absolute dequantization error is bounded
by ``scale / 2 = max|row| / 254`` per element.  With
``HaloPrecision(error_feedback=True)`` the pusher accumulates the per-row
rounding residual (:func:`push_ef`), so repeated pushes of slowly-moving
representations stay unbiased at the same wire cost (Bai et al. 2023).

A store is a plain pytree (dict) so it drops into jitted state, pjit
shardings and npz checkpoints unchanged:

    {"data": (L-1, R, hidden) <storage dtype>}        fp32 / bf16
    {"data": int8 ..., "scale": (L-1, R, 1) float32}  int8

where ``R = M · shard_rows``.  Sentinel rows (one per shard; the global
sentinel is the last row of the last shard) are re-zeroed after every
push, so pulls of padded halo slots are exactly zero.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

PRECISIONS = ("fp32", "bf16", "int8")

_DTYPES = {"fp32": jnp.float32, "bf16": jnp.bfloat16, "int8": jnp.int8}
_VALUE_BYTES = {"fp32": 4, "bf16": 2, "int8": 1}


@dataclasses.dataclass(frozen=True)
class HaloPrecision:
    """Wire/storage precision of the halo slab (one knob for both)."""

    storage: str = "fp32"          # fp32 | bf16 | int8
    # Accumulate the per-row quantization residual at the pusher
    # (push_ef) so repeated pushes stay unbiased.  Only meaningful for
    # lossy storage (int8 / bf16); a no-op for fp32.
    error_feedback: bool = False

    def __post_init__(self):
        if self.storage not in PRECISIONS:
            raise ValueError(f"storage {self.storage!r} not in {PRECISIONS}")

    @property
    def dtype(self):
        return _DTYPES[self.storage]

    @property
    def has_scale(self) -> bool:
        return self.storage == "int8"

    def row_bytes(self, hidden: int) -> int:
        """Bytes to store/ship one node-layer row of width ``hidden``."""
        extra = 4 if self.has_scale else 0       # one fp32 scale per row
        return hidden * _VALUE_BYTES[self.storage] + extra


@dataclasses.dataclass(frozen=True)
class HaloSpec:
    """Static shape/precision metadata of a compact store (accounting)."""

    num_hidden_layers: int          # L-1
    num_slots: int                  # |boundary| (excl. sentinels/padding)
    hidden: int
    precision: HaloPrecision = HaloPrecision()
    # Owner-sharded layout: R = store_rows slab rows over num_shards
    # devices.  Defaults describe the unsharded (single-sentinel) layout.
    store_rows: Optional[int] = None
    num_shards: int = 1

    @classmethod
    def from_partitions(cls, sp, hidden: int, num_layers: int,
                        precision: HaloPrecision = HaloPrecision()
                        ) -> "HaloSpec":
        return cls(num_hidden_layers=max(num_layers - 1, 1),
                   num_slots=sp.num_boundary, hidden=hidden,
                   precision=precision, store_rows=sp.store_rows,
                   num_shards=sp.num_parts)

    def init(self) -> dict:
        rows = (self.store_rows if self.store_rows is not None
                else self.num_slots + 1)
        return init_store(self.num_hidden_layers, rows - 1,
                          self.hidden, self.precision)

    # -- §3.3 / Fig. 9 accounting ------------------------------------------
    def store_nbytes(self) -> int:
        """Total HBM bytes of the slab (incl. sentinel/padding rows)."""
        rows = (self.store_rows if self.store_rows is not None
                else self.num_slots + 1)
        return (self.num_hidden_layers * rows
                * self.precision.row_bytes(self.hidden))

    def shard_nbytes(self) -> int:
        """Per-device resident bytes under the owner-sharded layout."""
        return self.store_nbytes() // self.num_shards

    def dense_nbytes(self, num_nodes: int) -> int:
        """What the seed's dense fp32 ``(L-1, N+1, hidden)`` store costs."""
        return self.num_hidden_layers * (num_nodes + 1) * self.hidden * 4

    def replicated_pull_nbytes(self) -> int:
        """Wire bytes per sync to replicate the compact slab on every
        device — the PR-1 snapshot layout's all-gather: each of the M
        devices receives the other M-1 shards of the *unpadded*
        (|boundary|+1)-row slab (per-owner shard padding is a storage
        artifact of this layout, not bytes the replicated baseline
        shipped)."""
        return ((self.num_shards - 1) * self.num_hidden_layers
                * (self.num_slots + 1)
                * self.precision.row_bytes(self.hidden))

    def comm_bytes(self, pull_rows: int, push_rows: int) -> dict:
        """Per-sync §3.3 byte counts under the configured wire precision.

        pull_rows: Σ_m |halo(G_m)| — rows gathered by all subgraphs (the
          *information-theoretic* pull cost; the implemented dense
          all_to_all pads per-pair lists to a common width — see
          :meth:`collective_pull_nbytes` for what actually hits the wire).
        push_rows: Σ_m |boundary ∩ V_m| — rows scattered by all subgraphs.
        """
        rb = self.precision.row_bytes(self.hidden)
        pull = int(pull_rows) * self.num_hidden_layers * rb
        push = int(push_rows) * self.num_hidden_layers * rb
        return {"pull_bytes": pull, "push_bytes": push,
                "total_bytes": pull + push}

    def collective_pull_nbytes(self, plan_max_rows: int) -> int:
        """Actual wire bytes of one :func:`collective_pull` sync: the
        all_to_all pads every (owner, requester) pair to the plan's max
        width K, shipping M·M·K rows.  Close to the ragged ideal
        (``comm_bytes``'s pull term) for balanced partitions; a skewed
        pair inflates it — compare both before choosing pull_mode."""
        return (self.num_shards * self.num_shards * int(plan_max_rows)
                * self.num_hidden_layers
                * self.precision.row_bytes(self.hidden))


def precision_of(store: dict) -> HaloPrecision:
    if "scale" in store:
        return HaloPrecision("int8")
    if store["data"].dtype == jnp.bfloat16:
        return HaloPrecision("bf16")
    return HaloPrecision("fp32")


# ---------------------------------------------------------------------------
# Quantization
# ---------------------------------------------------------------------------

def quantize_rows(x: jax.Array, precision: HaloPrecision
                  ) -> tuple[jax.Array, Optional[jax.Array]]:
    """Encode fp32 rows (..., hidden) into (data, scale-or-None)."""
    if precision.storage == "fp32":
        return x.astype(jnp.float32), None
    if precision.storage == "bf16":
        return x.astype(jnp.bfloat16), None
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_rows(data: jax.Array, scale: Optional[jax.Array]
                    ) -> jax.Array:
    out = data.astype(jnp.float32)
    return out if scale is None else out * scale


# ---------------------------------------------------------------------------
# The KVS operations (compact-slot indexed)
# ---------------------------------------------------------------------------

def init_store(num_hidden_layers: int, num_slots: int, hidden: int,
               precision: HaloPrecision = HaloPrecision()) -> dict:
    """Zero slab; (L-1, num_slots+1, hidden).  For the owner-sharded
    layout pass ``num_slots = store_rows - 1`` (sentinel rows included)."""
    store = {"data": jnp.zeros((num_hidden_layers, num_slots + 1, hidden),
                               precision.dtype)}
    if precision.has_scale:
        store["scale"] = jnp.ones((num_hidden_layers, num_slots + 1, 1),
                                  jnp.float32)
    return store


def init_slab(num_parts: int, num_hidden_layers: int, halo_size: int,
              hidden: int, precision: HaloPrecision = HaloPrecision()
              ) -> dict:
    """Zero per-subgraph halo slab — the device-local pull target:
    {"data": (M, L-1, H+1, hidden)} with the zero sentinel row at H."""
    slab = {"data": jnp.zeros(
        (num_parts, num_hidden_layers, halo_size + 1, hidden),
        precision.dtype)}
    if precision.has_scale:
        slab["scale"] = jnp.ones(
            (num_parts, num_hidden_layers, halo_size + 1, 1), jnp.float32)
    return slab


def layer_table(store: dict, ell: int
                ) -> tuple[jax.Array, Optional[jax.Array]]:
    """(data, scale) slab of hidden layer ``ell`` — feeds the fused kernel.

    Works on both the full store (L-1, R, hidden) and one subgraph's
    pulled slab (L-1, H+1, hidden)."""
    return store["data"][ell], (store["scale"][ell] if "scale" in store
                                else None)


def pull(store: dict, slots: jax.Array) -> jax.Array:
    """Gather + dequantize stale halo tables (Algorithm 1 line 5).

    slots: (M, H) compact slot ids (sentinel rows at padding).
    Returns (M, L-1, H, hidden) float32.
    """
    out = store["data"][:, slots, :].astype(jnp.float32)   # (L-1, M, H, h)
    if "scale" in store:
        out = out * store["scale"][:, slots, :]
    return jnp.swapaxes(out, 0, 1)


def pull_slab(store: dict, halo_slots: jax.Array) -> dict:
    """Collective PULL, dense-gather form (Algorithm 1 line 5).

    Gathers each subgraph's halo rows into a **device-local** slab in
    storage precision: {"data": (M, L-1, H+1, hidden)[, "scale"]}, slab
    row H the zero sentinel (``out_nbr`` padding).  Under pjit with the
    store sharded slot-wise and the result sharded over "data", XLA
    lowers the gather to an all-gather of the shards — the dense fallback
    of :func:`collective_pull`; on one device it is a plain gather.
    """
    data = jnp.swapaxes(store["data"][:, halo_slots, :], 0, 1)
    out = {"data": jnp.pad(data, ((0, 0), (0, 0), (0, 1), (0, 0)))}
    if "scale" in store:
        sc = jnp.swapaxes(store["scale"][:, halo_slots, :], 0, 1)
        out["scale"] = jnp.pad(sc, ((0, 0), (0, 0), (0, 1), (0, 0)),
                               constant_values=1.0)
    return out


def collective_pull(store: dict, send_offsets: jax.Array,
                    recv_positions: jax.Array, halo_size: int,
                    mesh, axis: str = "data") -> dict:
    """Ragged collective PULL: ship only the referenced slots.

    The ``shard_map`` form of :func:`pull_slab` for a store sharded
    slot-wise over ``axis`` with one subgraph per device: every owner
    gathers from its local shard the rows each requester's halo
    references (per the :class:`~repro.graph.partition.PullPlan`) and a
    single ``all_to_all`` routes them.  Per-pair lists are padded to the
    plan's max width K, so the wire carries ``M·M·K`` rows
    (:meth:`HaloSpec.collective_pull_nbytes`) — ≈ ``Σ_m |halo(G_m)|``
    for balanced partitions, vs the ``(M-1)·(B+1)`` rows of replicating
    the slab.

    Args:
      send_offsets:   (M, M, K) PullPlan.send_offsets.
      recv_positions: (M, M, K) PullPlan.recv_positions.
      halo_size: H — per-subgraph halo slots (slab gets H+1 rows).
    Returns the same pytree as :func:`pull_slab`.
    """
    from jax.experimental.shard_map import shard_map

    num = mesh.shape[axis]
    M, _, K = send_offsets.shape
    if num != M:
        raise ValueError(f"collective_pull needs one part per device "
                         f"(mesh {axis}={num}, parts={M}); use pull_slab")
    l1, _, hidden = store["data"].shape
    has_scale = "scale" in store

    def _exchange(table, send, recv, width, pad_value):
        # table (l1, shard_rows, width) — this owner's shard.
        rows = table[:, send[0].reshape(-1), :]            # (l1, M*K, w)
        rows = rows.reshape(l1, M, K, width)
        buf = jnp.transpose(rows, (1, 2, 0, 3))            # (M, K, l1, w)
        got = jax.lax.all_to_all(buf, axis, 0, 0, tiled=True)
        slab = jnp.full((l1, halo_size + 1, width), pad_value, table.dtype)
        vals = jnp.moveaxis(got.reshape(M * K, l1, width), 0, 1)
        # Duplicate positions only occur at the sentinel row H, where
        # every routed value is an owner-sentinel zero row.
        return slab.at[:, recv[0].reshape(-1), :].set(vals)[None]

    shard = P(None, axis, None)
    plan = P(axis, None, None)
    slab_spec = P(axis, None, None, None)

    if has_scale:
        def _body(data, scale, send, recv):
            return {"data": _exchange(data, send, recv, hidden, 0),
                    "scale": _exchange(scale, send, recv, 1, 1.0)}
        fn = shard_map(_body, mesh=mesh,
                       in_specs=(shard, shard, plan, plan),
                       out_specs={"data": slab_spec, "scale": slab_spec})
        return fn(store["data"], store["scale"], send_offsets,
                  recv_positions)

    def _body(data, send, recv):
        return {"data": _exchange(data, send, recv, hidden, 0)}
    fn = shard_map(_body, mesh=mesh, in_specs=(shard, plan, plan),
                   out_specs={"data": slab_spec})
    return fn(store["data"], send_offsets, recv_positions)


def push(store: dict, local_slots: jax.Array, local_valid: jax.Array,
         reps: jax.Array, sentinels: Optional[jax.Array] = None) -> dict:
    """Quantize + scatter fresh local boundary rows (Algorithm 1 lines 9–10).

    local_slots: (M, S) compact slot ids — part m's *own* sentinel row for
      non-boundary local nodes (the partitioner routes them there so every
      write stays inside the owner shard).
    local_valid: (M, S) bool; reps: (M, L-1, S, hidden) fp32.
    sentinels: (M,) per-part sentinel slots (re-zeroed after the scatter);
      defaults to the single last row for the unsharded layout.
    """
    data = store["data"]
    l1, rows, hidden = data.shape
    if sentinels is None:
        sentinels = jnp.asarray([rows - 1], jnp.int32)
    sentinels = jnp.asarray(sentinels, jnp.int32).reshape(-1)
    m, s = local_slots.shape
    per_part = sentinels if sentinels.size == m else sentinels[:1]
    fallback = jnp.broadcast_to(per_part.reshape(-1, 1), (m, s))
    ids = jnp.where(local_valid, local_slots, fallback).reshape(-1)
    vals = jnp.where(local_valid[:, None, :, None], reps, 0.0)
    q, scale = quantize_rows(vals, precision_of(store))
    q = jnp.swapaxes(q, 0, 1).reshape(l1, m * s, hidden)
    new = {"data": data.at[:, ids, :].set(q).at[:, sentinels, :].set(0)}
    if scale is not None:
        scale = jnp.swapaxes(scale, 0, 1).reshape(l1, m * s, 1)
        new["scale"] = (store["scale"].at[:, ids, :].set(scale)
                        .at[:, sentinels, :].set(1.0))
    return new


def push_ef(store: dict, local_slots: jax.Array, local_valid: jax.Array,
            reps: jax.Array, residual: jax.Array,
            sentinels: Optional[jax.Array] = None) -> tuple[dict, jax.Array]:
    """Error-feedback PUSH: quantize ``reps + residual`` and carry the new
    rounding residual forward at the pusher (Bai et al. 2023 style).

    Deterministic round-to-nearest biases repeated pushes of
    slowly-moving representations; compensating each push with the
    previous rounding error keeps the time-averaged served value unbiased
    at the same wire cost.  ``residual`` has the shape of ``reps``;
    returns (new_store, new_residual).
    """
    compensated = reps + residual
    new_store = push(store, local_slots, local_valid, compensated,
                     sentinels)
    # Same masked tensor push() quantizes internally, so XLA CSEs the two
    # quantize passes under jit; invalid rows are 0 → residual 0.
    masked = jnp.where(local_valid[:, None, :, None], compensated, 0.0)
    q, scale = quantize_rows(masked, precision_of(store))
    return new_store, masked - dequantize_rows(q, scale)


def shard_push(store: dict, local_slots: jax.Array, local_valid: jax.Array,
               reps: jax.Array, shard_rows: int, mesh,
               axis: str = "data") -> dict:
    """Explicit shard-local PUSH under ``shard_map``: device m scatters its
    rows with owner-local offsets into its own shard — structurally
    incapable of writing another device's slots.  Requires one part per
    device; :func:`push` is the SPMD fallback (same math, the partitioner
    already routes every row into the owner shard)."""
    from jax.experimental.shard_map import shard_map

    num = mesh.shape[axis]
    M = local_slots.shape[0]
    if num != M:
        raise ValueError(f"shard_push needs one part per device "
                         f"(mesh {axis}={num}, parts={M}); use push")
    prec = precision_of(store)
    has_scale = "scale" in store

    def _scatter(data, scale, slots, valid, reps_blk):
        # data (l1, shard_rows, hid) — this device's shard; reps_blk
        # (1, l1, S, hid); every slot of part j lies inside shard j.
        j = jax.lax.axis_index(axis)
        off = jnp.where(valid[0], slots[0] - j * shard_rows,
                        shard_rows - 1)
        vals = jnp.where(valid[0][None, :, None], reps_blk[0], 0.0)
        q, sc = quantize_rows(vals, prec)
        new = {"data": data.at[:, off, :].set(q).at[:, -1, :].set(0)}
        if sc is not None:
            new["scale"] = (scale.at[:, off, :].set(sc)
                            .at[:, -1, :].set(1.0))
        return new

    shard = P(None, axis, None)
    m_spec = P(axis, None)
    reps_spec = P(axis, None, None, None)

    if has_scale:
        fn = shard_map(_scatter, mesh=mesh,
                       in_specs=(shard, shard, m_spec, m_spec, reps_spec),
                       out_specs={"data": shard, "scale": shard})
        return fn(store["data"], store["scale"], local_slots, local_valid,
                  reps)

    def _body(data, slots, valid, reps_blk):
        return _scatter(data, None, slots, valid, reps_blk)

    fn = shard_map(_body, mesh=mesh,
                   in_specs=(shard, m_spec, m_spec, reps_spec),
                   out_specs={"data": shard})
    return fn(store["data"], local_slots, local_valid, reps)


def staleness_error(store: dict, fresh: jax.Array, local_slots: jax.Array,
                    served: jax.Array) -> jax.Array:
    """ε^(ℓ) = max_v ‖h_v^(ℓ) − h̃_v^(ℓ)‖₂ over *served* (boundary) rows.

    fresh: (M, L-1, S, hidden) this epoch's representations.
    served: (M, S) bool — valid local rows present in the compact store
      (``StackedPartitions.local_boundary``): exactly the rows whose
      staleness other subgraphs can observe (Theorem 1 only involves
      pulled halo rows).
    Returns (L-1,) per-hidden-layer max error.
    """
    stale = pull(store, local_slots)                   # (M, L-1, S, h)
    diff = jnp.linalg.norm(fresh - stale, axis=-1)     # (M, L-1, S)
    diff = jnp.where(served[:, None, :], diff, 0.0)
    return jnp.max(diff, axis=(0, 2))
