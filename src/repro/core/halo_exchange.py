"""HaloExchange — DIGEST's stale-representation KVS, compact and precision-aware.

This subsystem implements the PUSH/PULL lines of Algorithm 1 over a
**compact** slab that holds only *boundary* nodes — rows that appear in at
least one subgraph's halo — instead of the dense ``(L-1, N+1, hidden)``
array the seed used.  Mapping to the paper:

  * Algorithm 1 line 9–10 (``PUSH h_v^(ℓ) for v ∈ V_m``)  →  :func:`push`:
    quantize + scatter of locally-owned *boundary* rows into the slab.
    Non-boundary local rows are dropped — no other subgraph ever reads
    them, so storing them is pure overhead (this is what shrinks the store
    from O(N·L·d) to O(|boundary|·L·d), the Fig. 9 memory term).
  * Algorithm 1 line 5 (``PULL h̃_u^(ℓ) for u ∈ halo(G_m)``)  →
    :func:`pull` (dense gather + dequantize), or — on the TPU hot path —
    the fused pull+aggregate kernel :func:`repro.kernels.spmm.halo_spmm`,
    which gathers slab rows directly inside the out-of-subgraph ELL
    product so no ``(M, L-1, H, hidden)`` halo cache is ever materialized.
  * §3.3 communication terms  →  :meth:`HaloSpec.comm_bytes`: the per-sync
    pull cost is ``Σ_m |halo(G_m)| · (L-1) · row_bytes`` and the push cost
    ``Σ_m |boundary ∩ V_m| · (L-1) · row_bytes`` where ``row_bytes``
    depends on the wire/storage precision below.
  * Theorem 1's per-layer staleness ε^(ℓ)  →  :func:`staleness_error`,
    measured over the rows actually served to other subgraphs.

Precision (:class:`HaloPrecision`) is pluggable and applies to both the
slab layout (storage) and the §3.3 wire format:

  ======  ==================================  ==========================
  mode    row encoding                        bytes / hidden value
  ======  ==================================  ==========================
  fp32    float32                             4
  bf16    bfloat16                            2
  int8    int8 + one float32 scale per row    1 (+ 4 / hidden amortized)
  ======  ==================================  ==========================

int8 uses symmetric per-row quantization: ``scale = max|row| / 127``,
``q = round(row / scale)``; the absolute dequantization error is bounded
by ``scale / 2 = max|row| / 254`` per element.

A store is a plain pytree (dict) so it drops into jitted state, pjit
shardings and npz checkpoints unchanged:

    {"data": (L-1, B+1, hidden) <storage dtype>}        fp32 / bf16
    {"data": int8 ..., "scale": (L-1, B+1, 1) float32}  int8

Row ``B`` is the zero sentinel: pushes of padding (and of non-boundary
local rows, whose slot index is ``B``) are routed there and the row is
re-zeroed, so pulls of padded halo slots are exactly zero.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

PRECISIONS = ("fp32", "bf16", "int8")

_DTYPES = {"fp32": jnp.float32, "bf16": jnp.bfloat16, "int8": jnp.int8}
_VALUE_BYTES = {"fp32": 4, "bf16": 2, "int8": 1}


@dataclasses.dataclass(frozen=True)
class HaloPrecision:
    """Wire/storage precision of the halo slab (one knob for both)."""

    storage: str = "fp32"          # fp32 | bf16 | int8

    def __post_init__(self):
        if self.storage not in PRECISIONS:
            raise ValueError(f"storage {self.storage!r} not in {PRECISIONS}")

    @property
    def dtype(self):
        return _DTYPES[self.storage]

    @property
    def has_scale(self) -> bool:
        return self.storage == "int8"

    def row_bytes(self, hidden: int) -> int:
        """Bytes to store/ship one node-layer row of width ``hidden``."""
        extra = 4 if self.has_scale else 0       # one fp32 scale per row
        return hidden * _VALUE_BYTES[self.storage] + extra


@dataclasses.dataclass(frozen=True)
class HaloSpec:
    """Static shape/precision metadata of a compact store (accounting)."""

    num_hidden_layers: int          # L-1
    num_slots: int                  # |boundary| (excl. sentinel)
    hidden: int
    precision: HaloPrecision = HaloPrecision()

    @classmethod
    def from_partitions(cls, sp, hidden: int, num_layers: int,
                        precision: HaloPrecision = HaloPrecision()
                        ) -> "HaloSpec":
        return cls(num_hidden_layers=max(num_layers - 1, 1),
                   num_slots=sp.num_boundary, hidden=hidden,
                   precision=precision)

    def init(self) -> dict:
        return init_store(self.num_hidden_layers, self.num_slots,
                          self.hidden, self.precision)

    # -- §3.3 / Fig. 9 accounting ------------------------------------------
    def store_nbytes(self) -> int:
        """HBM bytes of the compact slab (incl. sentinel row)."""
        return (self.num_hidden_layers * (self.num_slots + 1)
                * self.precision.row_bytes(self.hidden))

    def dense_nbytes(self, num_nodes: int) -> int:
        """What the seed's dense fp32 ``(L-1, N+1, hidden)`` store costs."""
        return self.num_hidden_layers * (num_nodes + 1) * self.hidden * 4

    def comm_bytes(self, pull_rows: int, push_rows: int) -> dict:
        """Per-sync §3.3 byte counts under the configured wire precision.

        pull_rows: Σ_m |halo(G_m)| — rows gathered by all subgraphs.
        push_rows: Σ_m |boundary ∩ V_m| — rows scattered by all subgraphs.
        """
        rb = self.precision.row_bytes(self.hidden)
        pull = int(pull_rows) * self.num_hidden_layers * rb
        push = int(push_rows) * self.num_hidden_layers * rb
        return {"pull_bytes": pull, "push_bytes": push,
                "total_bytes": pull + push}


def precision_of(store: dict) -> HaloPrecision:
    if "scale" in store:
        return HaloPrecision("int8")
    if store["data"].dtype == jnp.bfloat16:
        return HaloPrecision("bf16")
    return HaloPrecision("fp32")


# ---------------------------------------------------------------------------
# Quantization
# ---------------------------------------------------------------------------

def quantize_rows(x: jax.Array, precision: HaloPrecision
                  ) -> tuple[jax.Array, Optional[jax.Array]]:
    """Encode fp32 rows (..., hidden) into (data, scale-or-None)."""
    if precision.storage == "fp32":
        return x.astype(jnp.float32), None
    if precision.storage == "bf16":
        return x.astype(jnp.bfloat16), None
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_rows(data: jax.Array, scale: Optional[jax.Array]
                    ) -> jax.Array:
    out = data.astype(jnp.float32)
    return out if scale is None else out * scale


# ---------------------------------------------------------------------------
# The KVS operations (compact-slot indexed)
# ---------------------------------------------------------------------------

def init_store(num_hidden_layers: int, num_slots: int, hidden: int,
               precision: HaloPrecision = HaloPrecision()) -> dict:
    """Zero slab; (L-1, B+1, hidden) with the sentinel row at B."""
    store = {"data": jnp.zeros((num_hidden_layers, num_slots + 1, hidden),
                               precision.dtype)}
    if precision.has_scale:
        store["scale"] = jnp.ones((num_hidden_layers, num_slots + 1, 1),
                                  jnp.float32)
    return store


def layer_table(store: dict, ell: int
                ) -> tuple[jax.Array, Optional[jax.Array]]:
    """(data, scale) slab of hidden layer ``ell`` — feeds the fused kernel."""
    return store["data"][ell], (store["scale"][ell] if "scale" in store
                                else None)


def pull(store: dict, slots: jax.Array) -> jax.Array:
    """Gather + dequantize stale halo tables (Algorithm 1 line 5).

    slots: (M, H) compact slot ids (sentinel B at padding).
    Returns (M, L-1, H, hidden) float32.
    """
    out = store["data"][:, slots, :].astype(jnp.float32)   # (L-1, M, H, h)
    if "scale" in store:
        out = out * store["scale"][:, slots, :]
    return jnp.swapaxes(out, 0, 1)


def push(store: dict, local_slots: jax.Array, local_valid: jax.Array,
         reps: jax.Array) -> dict:
    """Quantize + scatter fresh local boundary rows (Algorithm 1 lines 9–10).

    local_slots: (M, S) compact slot ids — ``B`` for padding *and* for
      non-boundary local nodes (both are dropped via the sentinel row).
    local_valid: (M, S) bool; reps: (M, L-1, S, hidden) fp32.
    """
    data = store["data"]
    l1, rows, hidden = data.shape
    b = rows - 1
    m, s = local_slots.shape
    ids = jnp.where(local_valid, local_slots, b).reshape(-1)
    vals = jnp.where(local_valid[:, None, :, None], reps, 0.0)
    q, scale = quantize_rows(vals, precision_of(store))
    q = jnp.swapaxes(q, 0, 1).reshape(l1, m * s, hidden)
    new = {"data": data.at[:, ids, :].set(q).at[:, b, :].set(0)}
    if scale is not None:
        scale = jnp.swapaxes(scale, 0, 1).reshape(l1, m * s, 1)
        new["scale"] = (store["scale"].at[:, ids, :].set(scale)
                        .at[:, b, :].set(1.0))
    return new


def staleness_error(store: dict, fresh: jax.Array, local_slots: jax.Array,
                    local_valid: jax.Array) -> jax.Array:
    """ε^(ℓ) = max_v ‖h_v^(ℓ) − h̃_v^(ℓ)‖₂ over *served* (boundary) rows.

    fresh: (M, L-1, S, hidden) this epoch's representations.
    Returns (L-1,) per-hidden-layer max error.  Only rows present in the
    compact store participate — exactly the rows whose staleness other
    subgraphs can observe (Theorem 1 only involves pulled halo rows).
    """
    b = store["data"].shape[1] - 1
    stale = pull(store, local_slots)                   # (M, L-1, S, h)
    diff = jnp.linalg.norm(fresh - stale, axis=-1)     # (M, L-1, S)
    served = local_valid & (local_slots < b)
    diff = jnp.where(served[:, None, :], diff, 0.0)
    return jnp.max(diff, axis=(0, 2))
