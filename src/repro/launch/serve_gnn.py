#!/usr/bin/env python
"""Online GNN embedding-serving launcher.

Stands up the DIGEST serving path end to end: build + partition the
graph, refresh the all-node owner-sharded serving store from the
top-layer representations, then drive a Zipf query stream through the
jitted batched query engine (``repro.core.serving``) behind the hot-row
cache, reporting p50/p99 latency, queries/sec and cache hit-rate.

  PYTHONPATH=src python -m repro.launch.serve_gnn --dataset flickr-sim \
      --scale 0.5 --parts 8 --model gcn --batch 256 --cache-rows 2048

``--sharded`` additionally compiles the SPMD engine over the host mesh
(store sharded slot-wise, halo rows through the ragged collective pull)
and times per-part local-row batches — the multi-device deployment
shape.  Weights are randomly initialized: serving cost is independent
of training state; point ``--refreshes`` at >1 to also measure the
donation-friendly in-place store refresh.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import serving
from repro.core.digest import prepare_graph_data, top_layer_reps
from repro.graph import make_dataset
from repro.launch.mesh import make_host_mesh
from repro.launch.serving_driver import run_serve_loop
from repro.models.gnn import GNNConfig, gnn_specs
from repro.nn import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="flickr-sim")
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--model", default="gcn",
                    choices=("gcn", "sage", "gat"))
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--batches", type=int, default=64)
    ap.add_argument("--warmup", type=int, default=4)
    ap.add_argument("--skew", type=float, default=1.1,
                    help="Zipf exponent of the query stream")
    ap.add_argument("--cache-rows", type=int, default=0,
                    help="hot-row cache capacity (0 disables)")
    ap.add_argument("--cache-ways", type=int, default=4)
    ap.add_argument("--storage", default="fp32",
                    choices=("fp32", "bf16", "int8"))
    ap.add_argument("--refreshes", type=int, default=1,
                    help="store refreshes to run (donated in-place)")
    ap.add_argument("--sharded", action="store_true",
                    help="also time the SPMD engine over the host mesh")
    args = ap.parse_args()

    g = make_dataset(args.dataset, scale=args.scale, seed=0)
    data = prepare_graph_data(g, args.parts, seed=0)
    cfg = GNNConfig(model=args.model, num_layers=args.layers,
                    in_dim=g.features.shape[1], hidden_dim=args.hidden,
                    num_classes=int(g.labels.max()) + 1)
    params = init_params(jax.random.PRNGKey(0), gnn_specs(cfg))

    plan = serving.build_serve_plan(data)
    scfg = serving.ServeConfig(batch_size=args.batch,
                               cache_rows=args.cache_rows,
                               cache_ways=args.cache_ways,
                               storage=args.storage)
    store = serving.init_serve_store(plan, cfg.hidden_dim, scfg.precision)
    refresh = serving.make_refresh_fn()
    rdata = plan.refresh_data()
    reps = top_layer_reps(cfg, params, data)
    for _ in range(max(args.refreshes, 1)):
        store = refresh(store, reps, rdata)
    print(f"store: {plan.store_rows} slots x{cfg.hidden_dim} "
          f"({args.storage}), {args.parts} shards, "
          f"version {int(store['version'])}")

    # Zipf traffic, hubs hottest (popularity rank = descending degree).
    hot = np.argsort(-g.degrees()).astype(np.int32)
    queries = serving.zipf_queries(g.num_nodes, args.batch, args.batches,
                                   args.skew, seed=1, hot_ids=hot)
    qdata = plan.query_data()
    cache = serving.init_cache(scfg, cfg.num_classes)

    def step(cache, q):
        logits, cache = serving.serve_query(cfg, scfg, params, store,
                                            cache, qdata, jnp.asarray(q))
        return cache, logits

    cache, _, stats = run_serve_loop(step, queries, carry=cache,
                                     warmup=args.warmup,
                                     items_per_call=args.batch)
    print(f"query[{args.model}] batch={args.batch} skew={args.skew}: "
          f"p50 {stats.p50_ms:.2f} ms  p99 {stats.p99_ms:.2f} ms  "
          f"{stats.per_sec:,.0f} q/s  "
          f"cache hit-rate {serving.hit_rate(cache):.3f} "
          f"({args.cache_rows} rows, {args.cache_ways}-way)")

    if args.sharded:
        mesh = make_host_mesh(data=jax.device_count())
        sdata = plan.sharded_data(data)
        store_sh, sdata_sh, q_sh = serving.serve_shardings(store, sdata,
                                                           mesh)
        store_p = jax.device_put(store, store_sh)
        sdata_p = jax.tree.map(jax.device_put, sdata, sdata_sh)
        rng = np.random.default_rng(2)
        rows = rng.integers(0, plan.part_rows,
                            (args.batches, args.parts, args.batch))

        def sstep(carry, q_rows):
            out = serving.serve_query_sharded(
                cfg, scfg, mesh, plan.halo_size, params, store_p, sdata_p,
                jax.device_put(jnp.asarray(q_rows, jnp.int32), q_sh))
            return carry, out

        _, _, sstats = run_serve_loop(
            sstep, rows, warmup=args.warmup,
            items_per_call=args.parts * args.batch)
        print(f"sharded[{jax.device_count()} dev] "
              f"{args.parts}x{args.batch} rows/call: "
              f"p50 {sstats.p50_ms:.2f} ms  p99 {sstats.p99_ms:.2f} ms  "
              f"{sstats.per_sec:,.0f} q/s")


if __name__ == "__main__":
    main()
