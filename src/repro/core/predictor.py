"""Staleness-alleviated embedding prediction (SAT) for the halo store.

DIGEST's Theorem-1 error grows linearly with the sync interval because
consumers read *raw* stale representations.  The authors' follow-up
(Staleness-Alleviated Distributed GNN Training via Online Dynamic-
Embedding Prediction, arXiv 2308.13466) predicts the *current* embedding
from the stale history, recovering accuracy at much wider intervals.

This module is the engine-agnostic core of that predictor:

  * :class:`PredictorConfig` — a frozen, hashable knob (jit-cache key)
    selecting the history model.  ``kind="delta"`` keeps the last-two-
    syncs delta (γ = 1 is linear extrapolation of the embedding
    trajectory; other γ scale the extrapolation step); ``kind="ema"``
    keeps an exponential moving average of per-sync deltas (β-weighted),
    which damps oscillating coordinates.  ``kind="none"`` is the
    contract that matters most: NO predictor leaves exist anywhere, so
    every compiled program collapses bitwise to the predictor-free one
    (the fault-leaf pattern from ``repro.core.faults``).

  * :func:`init_history` / :func:`update_history` — the pusher-side
    history state and its transition.  ``update_history`` is a PURE
    function of the accepted-push sequence (no store reads, no RNG, no
    round numbers), so SPMD shard-local pushes, the async simulator's
    owner pushes and a checkpoint-resumed run all agree exactly; the
    property test in ``tests/test_predictor.py`` pins this.

Storage/wire layout: the predicted-delta rows live in a SECOND store-
shaped pytree (``pstore`` — ``{"data"[, "scale"]}`` with the exact slot
geometry and precision of the halo store), so every existing exchange
helper (``push`` / ``shard_push`` / ``owner_push`` / ``pull_slab`` /
``collective_pull``) and the manifest+CRC checkpoint layout work on it
verbatim — the same extra-leaves discipline as serving's ``store_bare``.
Consumers apply the prediction as a fused epilogue in ``halo_spmm``'s
dequant step:

    predicted(row) = dequant(store row) + γ · dequant(pstore row)

which costs one extra gather+FMA per edge, not a second aggregation
pass.  Fault-masked shards skip both the store push and the history
update, so degraded pulls extrapolate from the last-known-good delta.

The "online" in SAT is a learned scaling, not a fixed extrapolation:
raw per-sync deltas anti-correlate with the next interval's change
whenever training oscillates (small graphs, Adam), and a fixed γ = 1
step then *increases* staleness error.  ``update_history`` therefore
fits, at every accepted push and per (part, layer), the scalar least-
squares coefficient of the realized representation change against the
previously pushed history rows, EMA-smooths it, and scales the emitted
pstore rows by it.  The coefficient starts at 0 — prediction is
exactly raw-stale until the history has demonstrably explained past
motion — and decays back toward 0 whenever the fit stops holding, so
the predictor can approach the raw-stale error from below instead of
gambling on linearity (the bench-regression gate in
``benchmarks/sat_prediction.py`` holds because of this).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

KINDS = ("none", "delta", "ema")

# Clip range of the online-learned scaling coefficient: negative fits
# damp oscillation (the realized change opposing the pushed rows) but
# are bounded at -1; >1 fits extrapolate past linear but are bounded
# well short of runaway feedback.
COEF_MIN = -1.0
COEF_MAX = 1.5


@dataclasses.dataclass(frozen=True)
class PredictorConfig:
    """Frozen predictor knob — hashable, safe to close over in jit.

    kind:  "none" (no predictor leaves at all), "delta" (last-two-syncs
           delta), or "ema" (β-EMA of per-sync deltas).
    gamma: pull-time extrapolation coefficient — predicted = stale +
           γ·history.  γ=1 with kind="delta" is linear extrapolation.
    beta:  EMA weight of the newest delta (kind="ema" only).
    """
    kind: str = "none"
    gamma: float = 1.0
    beta: float = 0.5

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"predictor kind {self.kind!r} not in {KINDS}")
        if not (0.0 < self.beta <= 1.0):
            raise ValueError(f"predictor beta {self.beta} must be in (0, 1]")

    @property
    def enabled(self) -> bool:
        return self.kind != "none"


def init_history(num_parts: int, num_hidden_layers: int, rows: int,
                 hidden: int) -> dict:
    """Device-local fp32 history state, shaped like the push buffers.

    prev:  (M, L-1, S, hidden) — last representations each part pushed.
    ema:   (M, L-1, S, hidden) — last emitted *base* rows: the delta
           (kind="delta") or the running β-EMA of deltas (kind="ema"),
           BEFORE the learned coefficient — both the EMA recursion and
           the next push's least-squares fit read it.
    coef:  (M, L-1) f32 — the online-learned scaling of the base rows
           (starts at 0: no prediction until the history has explained
           past motion).
    count: (M,) int32 — completed pushes per part (gates the first
           delta, which has no previous push to difference against).
    """
    shape = (num_parts, num_hidden_layers, rows, hidden)
    return {"prev": jnp.zeros(shape, jnp.float32),
            "ema": jnp.zeros(shape, jnp.float32),
            "coef": jnp.zeros((num_parts, num_hidden_layers),
                              jnp.float32),
            "count": jnp.zeros((num_parts,), jnp.int32)}


def update_history(hist: dict, reps, ok, cfg: PredictorConfig):
    """One push event: advance the history and emit the pstore rows.

    Args:
      hist: the :func:`init_history` dict (leading part axis M).
      reps: (M, L-1, S, hidden) fp32 — the representations being pushed
        this event (same buffer the store push consumes).
      ok:   (M,) bool — which parts' pushes take effect (push cadence ∧
        fault mask ∧ watchdog, exactly the store-push gate).  Masked
        parts keep their history frozen, so a later degraded pull
        extrapolates from the last-known-good delta.
      cfg:  static :class:`PredictorConfig` (kind != "none").

    Returns ``(new_hist, push_rows)`` where push_rows (M, L-1, S,
    hidden) fp32 is what belongs in the pstore for the gated parts (the
    caller routes masked parts' rows to the shard sentinel via the same
    ``local_valid & ok`` mask as the store push).  Pure: depends only on
    (hist, reps, ok, cfg).

    The emitted rows are ``coef · base``: per (part, layer) the scalar
    least-squares fit of this push's realized change against the
    previously pushed base rows, β-EMA-smoothed across pushes and
    clipped to [COEF_MIN, COEF_MAX].  Until the previous base rows have
    any energy (the first two pushes) the coefficient stays put, so
    early predictions are exactly zero — bitwise raw-stale pulls.
    """
    gate = ok[:, None, None, None]
    seen = (hist["count"] > 0)[:, None, None, None]
    delta = jnp.where(seen, reps - hist["prev"], 0.0)
    if cfg.kind == "ema":
        base = cfg.beta * delta + (1.0 - cfg.beta) * hist["ema"]
    elif cfg.kind == "delta":
        base = delta
    else:
        raise ValueError(f"update_history with kind={cfg.kind!r}")
    # Online fit: how much of the realized change ``delta`` did the rows
    # we pushed LAST sync (hist["ema"], pre-coefficient) explain?
    num = jnp.sum(delta * hist["ema"], axis=(2, 3))          # (M, L-1)
    den = jnp.sum(jnp.square(hist["ema"]), axis=(2, 3))     # (M, L-1)
    fit = jnp.clip(num / jnp.maximum(den, 1e-12), COEF_MIN, COEF_MAX)
    have_fit = ok[:, None] & (den > 1e-12)
    coef = jnp.where(have_fit,
                     cfg.beta * fit + (1.0 - cfg.beta) * hist["coef"],
                     hist["coef"])
    rows = coef[:, :, None, None] * base
    new_hist = {"prev": jnp.where(gate, reps, hist["prev"]),
                "ema": jnp.where(gate, base, hist["ema"]),
                "coef": coef,
                "count": hist["count"] + ok.astype(jnp.int32)}
    return new_hist, rows
