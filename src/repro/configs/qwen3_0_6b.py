"""qwen3-0.6b [dense] — qk_norm + GQA.

[hf:Qwen/Qwen3-8B family] 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936, head_dim=128, qk-norm.
"""
import dataclasses
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-0.6b", family="dense",
    num_layers=28, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=3072, vocab_size=151936, head_dim=128, qk_norm=True,
    pattern=("attn",), rope_theta=1000000.0,
    optimizer="adamw", learning_rate=3e-4,
    source="hf:Qwen/Qwen3-8B",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=32, dtype="float32")
