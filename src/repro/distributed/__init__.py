from repro.distributed.sharding import (DEFAULT_RULES, axis_rules,
                                        current_mesh, current_rules,
                                        logical_constraint, replicated,
                                        shardings_for_specs, spec_for_axes)

__all__ = ["DEFAULT_RULES", "axis_rules", "current_mesh", "current_rules",
           "logical_constraint", "replicated", "shardings_for_specs",
           "spec_for_axes"]
