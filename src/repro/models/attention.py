"""Attention for the assigned architectures: GQA + RoPE + qk-norm, full /
sliding-window / chunked (flash-style) variants, KV-cache decode, and
cross-attention (VLM).

Backend policy:
  * TPU prefill → Pallas flash kernel (repro.kernels.flash_attention).
  * CPU / dry-run lowering → ``chunked_attention``: a lax.scan over KV
    chunks with online softmax — the same O(S) memory behaviour as flash,
    so the roofline's memory term reflects the real kernel, not a dense
    S² materialization.
  * decode (1 token) → plain einsum over the cache.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import multi_head_attention

NEG_INF = -1e30


def repeat_kv(k: jax.Array, rep: int) -> jax.Array:
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      *, causal: bool = True, window: int = 0,
                      chunk: int = 1024,
                      q_offset: int = 0) -> jax.Array:
    """Flash-style attention as a scan over KV chunks (pure jnp).

    q: (B, Sq, H, D); k, v: (B, Sk, KV, D). ``window`` > 0 limits attention
    to the last `window` positions (sliding window). ``q_offset`` is the
    absolute position of q[0] (for decode/cross-block use).
    """
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    rep = h // kv
    scale = d ** -0.5
    chunk = min(chunk, sk)
    if sk % chunk:
        pad = chunk - sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        sk_pad = sk + pad
    else:
        sk_pad = sk
    n_chunks = sk_pad // chunk

    kc = k.reshape(b, n_chunks, chunk, kv, d)
    vc = v.reshape(b, n_chunks, chunk, kv, d)
    # Keep K/V in their storage dtype end to end and fuse the f32 upcast
    # into the matmuls (preferred_element_type): an explicit .astype(f32)
    # inside the scan gets hoisted by XLA into a full-size f32 buffer,
    # doubling the gather/HBM volume when the stream is sharded.
    q_s = (q * scale).astype(q.dtype)
    q_pos = q_offset + jnp.arange(sq)

    def step(carry, inputs):
        m_prev, l_prev, acc = carry
        kci, vci, ci = inputs
        kci = repeat_kv(kci, rep)                       # (B, C, H, D)
        vci = repeat_kv(vci, rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", q_s, kci,
                       preferred_element_type=jnp.float32)
        k_pos = ci * chunk + jnp.arange(chunk)
        mask = k_pos[None, :] < sk                       # in-bounds
        if causal:
            mask = mask & (q_pos[:, None] >= k_pos[None, :])
        if window > 0:
            mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vci.dtype), vci,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    init = (jnp.full((b, h, sq), NEG_INF, jnp.float32),
            jnp.zeros((b, h, sq), jnp.float32),
            jnp.zeros((b, h, sq, d), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        step, init, (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
                     jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.swapaxes(out, 1, 2).astype(q.dtype)      # (B, Sq, H, D)


def prefill_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      *, window: int = 0, backend: str = "auto",
                      chunk: int = 1024) -> jax.Array:
    """Training/prefill attention with backend dispatch."""
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "chunked"
    if backend == "pallas" and window == 0:
        return multi_head_attention(q, k, v, causal=True, backend="pallas")
    if backend == "dense" and window == 0:
        return multi_head_attention(q, k, v, causal=True, backend="jnp")
    return chunked_attention(q, k, v, causal=True, window=window,
                             chunk=chunk)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, window: int = 0) -> jax.Array:
    """One-token decode: q (B, 1, H, D) vs cache (B, S, KV, D).

    ``pos`` is the index of the new token (cache entries > pos are invalid).
    """
    b, _, h, d = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    rep = h // kv
    scale = d ** -0.5
    q32 = q[:, 0].astype(jnp.float32) * scale            # (B, H, D)
    kf = repeat_kv(k_cache, rep).astype(jnp.float32)     # (B, S, H, D)
    vf = repeat_kv(v_cache, rep).astype(jnp.float32)
    logits = jnp.einsum("bhd,bshd->bhs", q32, kf)
    k_pos = jnp.arange(s)
    mask = k_pos[None, :] <= pos[:, None]                # (B, S)
    if window > 0:
        mask = mask & (pos[:, None] - k_pos[None, :] < window)
    logits = jnp.where(mask[:, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p, vf)
    return out[:, None].astype(q.dtype)                  # (B, 1, H, D)


def cross_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Text-to-vision cross attention (no mask). q: (B,S,H,D);
    k, v: (B,P,KV,D)."""
    rep = q.shape[2] // k.shape[2]
    kf = repeat_kv(k, rep).astype(jnp.float32)
    vf = repeat_kv(v, rep).astype(jnp.float32)
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale, kf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return out.astype(q.dtype)
