"""Occupancy-aware chunk-skipping halo_spmm: worklist + kernel + wiring.

Covers the PR-4 perf surfaces end to end:

  * the (row_block × chunk) worklist builder (coverage-exactness via the
    masked oracle, sentinel exclusion, padding-by-repeat, geometry guard);
  * ``halo_spmm_skip_pallas`` — **bitwise** equal to the dense stream at
    every storage precision (skipped chunks contribute exact ±0.0 terms),
    tolerance-equal to the resident kernel / jnp oracle, and an
    interpret-mode visit log proving visited chunks == worklist entries,
    strictly fewer than ``row_blocks × n_chunks`` on clustered fixtures
    (synthetic and a real partition);
  * ops-level selection (occupancy threshold, forced backends);
  * the boundary-aware ``greedy_partition`` halo term (weight-0 identity,
    positive weight reduces Σ|halo| at unchanged balance);
  * the GAT owner-shard projection dedup (pull-epoch forward equality vs
    the legacy per-subgraph projection, once-per-layer probe, strictly
    lower compiled-epoch FLOPs, projected cache layout).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import halo_exchange as hx
from repro.core import (TrainSettings, gat_projected, init_state,
                        make_epoch_fn, prepare_graph_data,
                        project_store_tables)
from repro.core.halo_exchange import HaloPrecision
from repro.graph import build_partitions, make_dataset
from repro.graph.partition import build_chunk_worklist, greedy_partition
from repro.kernels.spmm import (halo_spmm, halo_spmm_ref,
                                halo_spmm_skip_pallas, halo_spmm_skip_ref)
from repro.models.gnn import GNNConfig
from repro.optim import adam

pytestmark = pytest.mark.leg("m16-ppd2-hlo")


def _clustered_case(rng, rows, deg, ntab, feat, dtype=np.float32):
    """ELL refs clustered per 128-row block: block b references only a
    narrow slot band, so most (row_block, chunk) pairs are empty."""
    n_blocks = max(-(-rows // 128), 1)
    band = max((ntab - 1) // (2 * n_blocks), deg)
    lo = (rng.integers(0, 2, n_blocks) * (ntab - 1 - band)
          ).astype(np.int64)                   # band at the slab's ends
    nbr = np.empty((rows, deg), np.int64)
    for b in range(n_blocks):
        r0, r1 = b * 128, min((b + 1) * 128, rows)
        nbr[r0:r1] = rng.integers(lo[b], lo[b] + band, (r1 - r0, deg))
    nbr = nbr.astype(np.int32)
    wts = (rng.random((rows, deg)) * (nbr < ntab - 1)).astype(np.float32)
    table = rng.normal(size=(ntab, feat)).astype(dtype)
    table[-1] = 0
    return jnp.asarray(nbr), jnp.asarray(wts), jnp.asarray(table)


def _quantized(table, storage):
    data, scale = hx.quantize_rows(table, HaloPrecision(storage))
    data = np.asarray(data).copy()
    data[-1] = 0
    return jnp.asarray(data), scale


# ---------------------------------------------------------------------------
# Worklist builder
# ---------------------------------------------------------------------------

def test_worklist_covers_every_referenced_slot():
    """The masked oracle (only visited chunks accumulate) == the full
    oracle — i.e. the worklist misses nothing; a truncated worklist
    diverges, so the check has teeth."""
    rng = np.random.default_rng(0)
    for rows, deg, ntab, chunk in ((300, 7, 700, 128), (129, 3, 90, 32),
                                   (64, 5, 1000, 256)):
        nbr, wts, table = _clustered_case(rng, rows, deg, ntab, 48)
        wl = build_chunk_worklist(np.asarray(nbr), ntab, chunk)
        want = halo_spmm_ref(nbr, wts, table)
        got = halo_spmm_skip_ref(nbr, wts, table, None, wl.ids, wl.cnt,
                                 chunk)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # teeth: drop each block's last chunk → the oracle must change
    cut = halo_spmm_skip_ref(nbr, wts, table, None, wl.ids,
                             np.maximum(wl.cnt - 1, 0), chunk)
    assert not np.array_equal(np.asarray(cut), np.asarray(want))


def test_worklist_excludes_sentinel_and_pads_by_repeat():
    ntab, chunk = 512, 64
    nbr = np.full((128, 4), ntab - 1, np.int32)    # all sentinel
    nbr[0, 0] = 3
    nbr[5, 1] = 130                                 # chunks {0, 2}
    wl = build_chunk_worklist(nbr, ntab, chunk)
    assert wl.cnt.tolist() == [2]
    assert wl.ids[0, :2].tolist() == [0, 2]
    # padding repeats the last visited chunk (re-addresses resident VMEM)
    assert (wl.ids[0, 2:] == 2).all()
    # sentinel-only block → empty worklist
    wl0 = build_chunk_worklist(np.full((128, 4), ntab - 1, np.int32),
                               ntab, chunk)
    assert wl0.cnt.tolist() == [0] and wl0.max_chunks == 1
    assert wl0.occupancy == 0.0


def test_worklist_stacked_matches_per_subgraph():
    rng = np.random.default_rng(1)
    nbr = rng.integers(0, 200, (3, 256, 5)).astype(np.int32)
    wl = build_chunk_worklist(nbr, 201, 64)
    assert wl.ids.shape[0] == 3 and wl.cnt.shape == (3, 2)
    for m in range(3):
        wlm = build_chunk_worklist(nbr[m], 201, 64)
        assert wlm.cnt.tolist() == wl.cnt[m].tolist()
        np.testing.assert_array_equal(
            wlm.ids, wl.ids[m, :, :wlm.max_chunks])


# ---------------------------------------------------------------------------
# The skip kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("storage", ["fp32", "bf16", "int8"])
def test_skip_bitwise_equals_dense_stream(storage):
    """Chunk skipping == the dense stream, BITWISE, at every precision
    and ragged shapes: skipped chunks only ever contributed exact ±0.0."""
    rng = np.random.default_rng(11)
    for rows, deg, ntab, feat, chunk in ((300, 7, 700, 70, 128),
                                         (17, 3, 130, 33, 32)):
        nbr, wts, table = _clustered_case(rng, rows, deg, ntab, feat)
        data, scale = _quantized(table, storage)
        wl = build_chunk_worklist(np.asarray(nbr), ntab, chunk)
        skip = halo_spmm(nbr, wts, data, scale,
                         wl_ids=jnp.asarray(wl.ids),
                         wl_cnt=jnp.asarray(wl.cnt),
                         backend="pallas_skip_interpret", chunk_rows=chunk)
        dense = halo_spmm(nbr, wts, data, scale,
                          backend="pallas_stream_interpret",
                          chunk_rows=chunk)
        np.testing.assert_array_equal(np.asarray(skip), np.asarray(dense))
        # and tolerance-equal to the chunking-free oracle / resident path
        ref = halo_spmm_ref(nbr, wts, data, scale)
        np.testing.assert_allclose(np.asarray(skip), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)
        resident = halo_spmm(nbr, wts, data, scale,
                             backend="pallas_interpret")
        np.testing.assert_allclose(np.asarray(skip), np.asarray(resident),
                                   atol=1e-4, rtol=1e-4)


def test_skip_single_chunk_bitwise_resident():
    """One chunk spanning the slab → no reassociation at all: bitwise
    equal to the resident scaled kernel (same guarantee the dense stream
    pins in test_kernels_spmm)."""
    rng = np.random.default_rng(13)
    nbr, wts, table = _clustered_case(rng, 128, 4, 60, 128)
    data, scale = _quantized(table, "int8")
    wl = build_chunk_worklist(np.asarray(nbr), 60, 64)
    want = halo_spmm(nbr, wts, data, scale, backend="pallas_interpret")
    got = halo_spmm(nbr, wts, data, scale, wl_ids=jnp.asarray(wl.ids),
                    wl_cnt=jnp.asarray(wl.cnt),
                    backend="pallas_skip_interpret", chunk_rows=64)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_skip_visited_chunks_equal_worklist_length():
    """Interpret-mode visit log: the kernel processes exactly the
    worklist's entries — NOT row_blocks × n_chunks — on a clustered
    synthetic fixture (and the padded steps are masked, id −1)."""
    rng = np.random.default_rng(17)
    rows, deg, ntab, feat, chunk = 384, 6, 1024, 128, 128
    nbr, wts, table = _clustered_case(rng, rows, deg, ntab, feat)
    wl = build_chunk_worklist(np.asarray(nbr), ntab, chunk)
    out, visits = halo_spmm_skip_pallas(
        nbr, wts, table, None, wl_ids=jnp.asarray(wl.ids),
        wl_cnt=jnp.asarray(wl.cnt), chunk_rows=chunk, interpret=True,
        count_visits=True)
    v = np.asarray(visits)
    assert (v >= 0).sum() == wl.visited_chunks
    assert wl.visited_chunks < wl.total_pairs, (wl.visited_chunks,
                                                wl.total_pairs)
    # logged ids are exactly the worklist prefix, in order
    for i in range(v.shape[0]):
        np.testing.assert_array_equal(v[i, :wl.cnt[i]],
                                      wl.ids[i, :wl.cnt[i]])
        assert (v[i, wl.cnt[i]:] == -1).all()
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(halo_spmm_ref(nbr, wts, table)),
                               atol=1e-4, rtol=1e-4)


def test_skip_visited_fewer_on_real_partition():
    """A real owner-grouped partition slab: the worklist is strictly
    sparser than the dense (row_blocks × chunks) schedule, and reading a
    pulled slab through it matches the oracle bitwise-vs-dense-stream."""
    g = make_dataset("flickr-sim", scale=0.25, seed=0)
    sp = build_partitions(g, 8)
    chunk = 64
    wl = sp.chunk_worklist(chunk)
    assert wl.visited_chunks < wl.total_pairs, (wl.visited_chunks,
                                                wl.total_pairs)
    # one subgraph's layer read: slab = pulled (H+1, hid) rows
    rng = np.random.default_rng(5)
    store = hx.init_store(1, sp.store_rows - 1, 32, HaloPrecision())
    reps = rng.normal(size=(sp.num_parts, 1, sp.part_size, 32)
                      ).astype(np.float32)
    store = hx.push(store, jnp.asarray(sp.local_slots),
                    jnp.asarray(sp.local_valid), jnp.asarray(reps),
                    jnp.asarray(sp.sentinel_slots))
    slab = hx.pull_slab(store, jnp.asarray(sp.halo_slots))
    m = 0
    data, scale = hx.layer_table({k: v[m] for k, v in slab.items()}, 0)
    nbr = jnp.asarray(sp.out_nbr[m])
    wts = jnp.asarray(sp.out_wts[m])
    skip = halo_spmm(nbr, wts, data, scale,
                     wl_ids=jnp.asarray(wl.ids[m]),
                     wl_cnt=jnp.asarray(wl.cnt[m]),
                     backend="pallas_skip_interpret", chunk_rows=chunk)
    dense = halo_spmm(nbr, wts, data, scale,
                      backend="pallas_stream_interpret", chunk_rows=chunk)
    np.testing.assert_array_equal(np.asarray(skip), np.asarray(dense))
    np.testing.assert_allclose(
        np.asarray(skip), np.asarray(halo_spmm_ref(nbr, wts, data, scale)),
        atol=1e-4, rtol=1e-4)


def test_skip_geometry_guard_and_selection():
    rng = np.random.default_rng(19)
    nbr, wts, table = _clustered_case(rng, 256, 4, 600, 64)
    wl = build_chunk_worklist(np.asarray(nbr), 600, 128)
    bad_ids = jnp.asarray(wl.ids[:1])        # wrong row-block count
    with pytest.raises(ValueError, match="worklist geometry"):
        halo_spmm(nbr, wts, table, None, wl_ids=bad_ids,
                  wl_cnt=jnp.asarray(wl.cnt[:1]),
                  backend="pallas_skip_interpret", chunk_rows=128)
    with pytest.raises(ValueError, match="needs the"):
        halo_spmm(nbr, wts, table, None, backend="pallas_skip_interpret")
    # finer-grained worklist than the call's chunk tiling → loud error
    # (the kernel would otherwise silently aggregate the wrong chunks)
    fine = build_chunk_worklist(np.asarray(nbr), 600, 32)
    assert fine.max_chunks > 600 // 512 + 1
    with pytest.raises(ValueError, match="chunk-geometry"):
        halo_spmm(nbr, wts, table, None, wl_ids=jnp.asarray(fine.ids),
                  wl_cnt=jnp.asarray(fine.cnt),
                  backend="pallas_skip_interpret", chunk_rows=512)
    # Auto-selection is static and occupancy-gated: with occupancy above
    # the threshold the (bogus) worklist must NOT be consulted; at or
    # below it, it is — the geometry guard makes the choice observable.
    halo_spmm(nbr, wts, table, None, wl_ids=bad_ids,
              wl_cnt=jnp.asarray(wl.cnt[:1]), backend="pallas_interpret",
              resident_max_bytes=1024, chunk_rows=128,
              occupancy=0.9, skip_occupancy_max=0.5)
    with pytest.raises(ValueError, match="worklist geometry"):
        halo_spmm(nbr, wts, table, None, wl_ids=bad_ids,
                  wl_cnt=jnp.asarray(wl.cnt[:1]),
                  backend="pallas_interpret", resident_max_bytes=1024,
                  chunk_rows=128, occupancy=0.3, skip_occupancy_max=0.5)
    # jnp backend ignores the worklist entirely
    out = halo_spmm(nbr, wts, table, None, wl_ids=bad_ids,
                    wl_cnt=jnp.asarray(wl.cnt[:1]), backend="jnp")
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(halo_spmm_ref(nbr, wts,
                                                           table)))


def test_worklist_build_vs_call_chunk_rows_guard():
    """The build-side knob (prepare_graph_data) and the call-side knob
    (GNNConfig.stream_chunk_rows) must agree — a coarser worklist than
    the kernel tiling would silently skip referenced rows, so the epoch
    entry points reject the mismatch loudly."""
    g = make_dataset("flickr-sim", scale=0.05, seed=2)
    data = prepare_graph_data(g, 2, stream_chunk_rows=1024)
    cfg = GNNConfig(model="gcn", num_layers=2, in_dim=g.features.shape[1],
                    hidden_dim=16, num_classes=int(g.labels.max()) + 1)
    with pytest.raises(ValueError, match="chunk_rows=1024"):
        init_state(cfg, adam(5e-3), data)      # call side defaults to 512
    # matching knobs pass
    init_state(dataclasses.replace(cfg, stream_chunk_rows=1024),
               adam(5e-3), data)


def test_prepare_graph_data_threads_worklist():
    g = make_dataset("flickr-sim", scale=0.1, seed=2)
    data = prepare_graph_data(g, 4, stream_chunk_rows=64)
    wl = data["_worklist"]
    assert 0.0 < wl.occupancy <= 1.0
    assert wl.chunk_rows == 64
    M, S, _ = data["struct"]["out_nbr"].shape
    assert data["struct"]["wl_ids"].shape[:2] == (M, max(-(-S // 128), 1))
    assert data["struct"]["wl_cnt"].shape == data["struct"][
        "wl_ids"].shape[:2]
    np.testing.assert_array_equal(np.asarray(data["struct"]["wl_ids"]),
                                  wl.ids)


# ---------------------------------------------------------------------------
# Boundary-aware partitioning score
# ---------------------------------------------------------------------------

def test_halo_weight_zero_preserves_assignments():
    g = make_dataset("flickr-sim", scale=0.1, seed=0)
    np.testing.assert_array_equal(greedy_partition(g, 4),
                                  greedy_partition(g, 4, halo_weight=0.0))


def test_halo_weight_reduces_halo_rows():
    """A positive marginal-halo weight lowers Σ_m |halo(G_m)| on the test
    graphs (partition_report's halo_rows) at unchanged balance."""
    from repro.graph import partition_report

    for ds, scale, M, w in (("flickr-sim", 0.25, 4, 0.25),
                            ("reddit-sim", 0.1, 8, 0.25)):
        g = make_dataset(ds, scale=scale, seed=0)
        base = partition_report(g, build_partitions(g, M))
        tuned = partition_report(g, build_partitions(g, M, halo_weight=w))
        assert tuned["halo_rows"] < base["halo_rows"], (ds, base, tuned)
        assert tuned["balance"] <= base["balance"] + 1e-6, (ds, base,
                                                           tuned)


# ---------------------------------------------------------------------------
# GAT owner-shard projection dedup
# ---------------------------------------------------------------------------

def _gat_setup(storage="fp32", dedup=True, interval=1):
    g = make_dataset("flickr-sim", scale=0.1, seed=4)
    data = prepare_graph_data(g, 4)
    cfg = GNNConfig(model="gat", num_layers=3, in_dim=g.features.shape[1],
                    hidden_dim=32, num_classes=int(g.labels.max()) + 1,
                    heads=2, gat_halo_dedup=dedup)
    settings = TrainSettings(sync_interval=interval, mode="digest",
                             precision=HaloPrecision(storage))
    return g, data, cfg, settings


def test_gat_dedup_pull_epoch_forward_equality():
    """At sync_interval=1 every epoch projects at the current W, so the
    dedup epoch's forward must equal the legacy per-subgraph projection
    (fp32 exact to reassociation; int8 re-quantizes z once).  From the
    next update on the trajectories may drift: the frozen projection
    rides the stale branch's stop_gradient, dropping the legacy path's
    W-gradient through the halo einsum — that is the documented
    semantics, not an accident."""
    for storage, atol in (("fp32", 1e-6), ("int8", 5e-3)):
        losses = {}
        for dedup in (True, False):
            g, data, cfg, settings = _gat_setup(storage, dedup)
            tdata = {k: v for k, v in data.items()
                     if not k.startswith("_")}
            opt = adam(5e-3)
            state = init_state(cfg, opt, data,
                               precision=settings.precision)
            fn = jax.jit(make_epoch_fn(cfg, opt, settings))
            tr = []
            for _ in range(2):
                state, m = fn(state, tdata)
                tr.append(float(m["loss"]))
            losses[dedup] = tr
        np.testing.assert_allclose(losses[True], losses[False], atol=atol,
                                   err_msg=storage)


def test_gat_dedup_projects_once_per_layer_and_cuts_flops():
    """project_store_tables emits exactly one (R, d)·W projection per
    hidden layer — R = owner shards × shard_rows, i.e. once per owner
    shard per layer — and the compiled dedup epoch costs strictly fewer
    FLOPs than the legacy epoch (which re-projects every subgraph's
    (H+1, d) slab every epoch)."""
    g, data, cfg, settings = _gat_setup("fp32", True, interval=2)
    tdata = {k: v for k, v in data.items() if not k.startswith("_")}
    opt = adam(5e-3)
    sp = data["_sp"]
    state = init_state(cfg, opt, data)
    zs = project_store_tables(state["store"], state["params"], cfg,
                              settings.precision)
    assert sorted(zs) == ["z0", "z1"]
    assert zs["z0"]["data"].shape == (1, sp.store_rows, cfg.hidden_dim)
    assert zs["z1"]["data"].shape == (1, sp.store_rows, cfg.num_classes)
    # once per layer: exactly L-1 projection contractions in the jaxpr
    jaxpr = jax.make_jaxpr(
        lambda s, p: project_store_tables(s, p, cfg, settings.precision))(
            state["store"], state["params"])
    dots = [e for e in jaxpr.jaxpr.eqns if e.primitive.name ==
            "dot_general"]
    assert len(dots) == cfg.num_layers - 1, jaxpr

    flops = {}
    for dedup in (True, False):
        cfg_d = dataclasses.replace(cfg, gat_halo_dedup=dedup)
        st = init_state(cfg_d, opt, data)
        fn = jax.jit(make_epoch_fn(cfg_d, opt, settings))
        cost = fn.lower(st, tdata).compile().cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        flops[dedup] = float(cost["flops"])
    assert flops[True] < flops[False], flops


def test_gat_dedup_cache_layout():
    g, data, cfg, _ = _gat_setup("int8", True)
    assert gat_projected(cfg)
    opt = adam(5e-3)
    state = init_state(cfg, opt, data, precision=HaloPrecision("int8"))
    M = int(data["halo_ids"].shape[0])
    H = int(data["halo_ids"].shape[1])
    cache = state["cache"]
    assert sorted(cache) == ["z0", "z0_scale", "z1", "z1_scale"]
    assert cache["z0"].shape == (M, 1, H + 1, cfg.hidden_dim)
    assert cache["z0"].dtype == jnp.int8
    assert cache["z1"].shape == (M, 1, H + 1, cfg.num_classes)
    assert cache["z1_scale"].shape == (M, 1, H + 1, 1)
    # legacy layout untouched
    cfg_l = dataclasses.replace(cfg, gat_halo_dedup=False)
    assert not gat_projected(cfg_l)
    state_l = init_state(cfg_l, opt, data, precision=HaloPrecision("int8"))
    assert sorted(state_l["cache"]) == ["data", "scale"]
