"""Public entry: fused GAT aggregation over DIGEST's split adjacency."""
from __future__ import annotations

import functools

import jax

from repro.kernels.gat_edge.gat_edge import gat_edge_partial_pallas
from repro.kernels.gat_edge.ref import gat_edge_partial_ref, merge_partials


@functools.partial(jax.jit, static_argnames=("backend",))
def gat_aggregate(in_nbr, in_valid, out_nbr, out_valid, s_dst,
                  s_src_local, s_src_halo, z_local, z_halo,
                  backend: str = "auto") -> jax.Array:
    """Single-head fused GAT layer aggregation (DIGEST split form).

    z_local/z_halo and s_src_* must include the sentinel row. Returns the
    softmax-normalized aggregation over the union of both edge sets.
    """
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend == "jnp":
        fn = gat_edge_partial_ref
        p_in = fn(in_nbr, in_valid, s_dst, s_src_local, z_local)
        p_out = fn(out_nbr, out_valid, s_dst, s_src_halo, z_halo)
    else:
        interp = backend != "pallas"
        p_in = gat_edge_partial_pallas(in_nbr, in_valid, s_dst,
                                       s_src_local, z_local,
                                       interpret=interp)
        p_out = gat_edge_partial_pallas(out_nbr, out_valid, s_dst,
                                        s_src_halo, z_halo,
                                        interpret=interp)
    return merge_partials([p_in, p_out])
