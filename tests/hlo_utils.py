"""Compiled-HLO assertion harness for the DIGEST epoch.

Lowers the *jitted* epoch function on a forced multi-device mesh with the
production shardings (``repro.launch.train_gnn.subgraph_shardings``) and
exposes the compiled module's collective-op census, so tests can assert
communication invariants on the program XLA actually emits instead of
spot-checking trajectories.

The key fact the assertions lean on: after SPMD partitioning, every HLO
op is device-local — **all** cross-device data movement is explicit
collective ops (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute).  "The push never crosses devices" is therefore
exactly the statement "the only collectives in the module are the
expected ragged all-to-all pulls plus the (L-1)-or-scalar-sized metric /
gradient all-reduces" — zero all-gathers, zero collective-permutes.
"""
from __future__ import annotations

import os
import subprocess
import sys

import jax

# One grammar for collective-op matching and group parsing, shared with
# the dry-run's inter-pod byte split (see repro.launch.hlo_census) —
# the two censuses must never disagree about what counts as an op.
from repro.launch.hlo_census import (COLLECTIVES, match_collective,
                                     op_groups)


def run_forced_device_subprocess(test_file: str, marker: str,
                                 devices: int = 8, timeout: int = 900):
    """Re-launch ``test_file`` as ``__main__`` with a forced N-device CPU
    platform, so multi-device checks run even on single-device hosts
    (the in-process pytest variants cover the CI forced-device jobs).
    Asserts a clean exit and that ``marker`` was printed."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(test_file)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = (os.path.join(repo, "src") + os.pathsep
                         + os.path.join(repo, "tests") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    res = subprocess.run([sys.executable, os.path.abspath(test_file)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\n" \
                                f"stderr:\n{res.stderr}"
    assert marker in res.stdout, res.stdout


def collective_counts(hlo_text: str) -> dict:
    """Count each collective op in a compiled HLO module's text.

    Async pairs (``-start``/``-done``) are counted once, at ``-start``.
    """
    counts = {c: 0 for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        op = match_collective(line)
        if op is not None:
            counts[op] += 1
    return counts


def collective_axis_census(hlo_text: str, mesh) -> dict:
    """Per-mesh-axis collective census of a compiled HLO module.

    Returns ``{op: {axes_tuple: count}}`` where ``axes_tuple`` is the
    (mesh-ordered) tuple of axis names whose coordinate *varies* inside
    the op's replica groups — e.g. on the ("pod", "data", "model") mesh
    an intra-pod all-to-all shows up as ``("data",)``, the inter-pod
    permute as ``("pod",)`` and a global gradient all-reduce as the
    full axis tuple.  Ops whose groups cannot be parsed (or that carry
    no groups) are filed under ``None`` so they are never silently
    dropped.  This is what lets tests assert not just *how many*
    collectives the two-stage exchange emits but *which links they
    ride* — the inter-pod hop must never widen to the combined axes.

    Group parsing (explicit / iota ``replica_groups``, permute
    ``source_target_pairs``) is shared with the dry-run's inter-pod
    byte split via ``repro.launch.hlo_census.op_groups`` — one grammar,
    two consumers that must agree.
    """
    import numpy as np

    # HLO group entries are LOGICAL device numbers — positions in the
    # flattened device assignment (mesh.devices C order) — not hardware
    # device ids; the two coincide on forced-CPU host meshes but not on
    # a real TPU mesh (make_mesh reorders devices for ICI topology).
    shape = np.asarray(mesh.devices).shape
    names = mesh.axis_names

    def classify(groups):
        varying = set()
        for grp in groups:
            cs = [np.unravel_index(i, shape) for i in grp]
            for d, name in enumerate(names):
                if len({c[d] for c in cs}) > 1:
                    varying.add(name)
        return tuple(a for a in names if a in varying)

    census = {c: {} for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        op = match_collective(line)
        if op is not None:
            groups = op_groups(line.strip())
            key = classify(groups) if groups is not None else None
            census[op][key] = census[op].get(key, 0) + 1
    return census


def expected_collective_permute(storage: str, pods: int,
                                model: str = "gcn",
                                num_layers: int = None) -> int:
    """collective-permute count of one *multi-pod* collective PULL: the
    inter-pod stage ships each pulled tensor through ``pods - 1``
    shifted ppermute rounds (one permute per tensor on the 2-pod
    production mesh); tensor count is the same per-storage/per-model
    arithmetic as :func:`expected_all_to_all`.  Zero on a single-pod
    mesh — the exchange collapses to the intra-pod all_to_all alone."""
    return (pods - 1) * expected_all_to_all(storage, model, num_layers)


def expected_all_to_all(storage: str, model: str = "gcn",
                        num_layers: int = None,
                        predictor: bool = False) -> int:
    """all-to-all count of one collective PULL.

    gcn/sage pull the raw store: one op per store tensor ({data} or
    {data, scale}), the (L-1)-layer axis batched inside the exchange
    buffer — independent of depth.  gat (projected-row pull) exchanges
    one z tensor per hidden layer (widths differ per layer, so layers
    cannot batch into one buffer): (L-1) ops, ×2 with int8 scales.

    With the SAT ``predictor`` the pstore mirrors the store's tensors
    and rides the same routing — one extra op per pstore tensor on the
    raw-store pull; ZERO extra under the GAT dedup, whose prediction is
    folded shard-locally before projection (the pulled z tensors are
    unchanged)."""
    per_tensor = 2 if storage == "int8" else 1
    if model != "gat":
        return per_tensor * (2 if predictor else 1)
    if num_layers is None:
        num_layers = 2                    # make_epoch's gat default
    return per_tensor * (num_layers - 1)


def make_epoch(g, num_parts: int, mesh=None, *, storage: str = "fp32",
               pull_mode: str = "collective", model: str = "gcn",
               hidden: int = 32, sync_interval: int = 2,
               error_feedback: bool = False, fault_state: bool = False,
               max_staleness: int = None, predictor=None):
    """Build (jitted_epoch_fn, state, tdata) for graph ``g``.

    With ``mesh`` the epoch is jitted with the production shardings
    (store slot-sharded, (M, ...) arrays over "data"); without it the
    plain single-device program is returned.  ``fault_state`` attaches
    the fault-injection leaves (``push_ok`` / ``last_push_round``) so
    the fault-aware program's census can be compared to the plain one.
    """
    from repro.core import (PredictorConfig, TrainSettings,
                            attach_fault_state, init_state,
                            make_epoch_fn, prepare_graph_data)
    from repro.core.halo_exchange import HaloPrecision
    from repro.launch.train_gnn import subgraph_shardings
    from repro.models.gnn import GNNConfig
    from repro.optim import adam

    data = prepare_graph_data(g, num_parts)
    tdata = {k: v for k, v in data.items() if not k.startswith("_")}
    cfg = GNNConfig(model=model, num_layers=3 if model != "gat" else 2,
                    in_dim=g.features.shape[1], hidden_dim=hidden,
                    num_classes=int(g.labels.max()) + 1, heads=2)
    opt = adam(5e-3)
    pcfg = predictor or PredictorConfig()
    settings = TrainSettings(
        sync_interval=sync_interval, mode="digest", pull_mode=pull_mode,
        precision=HaloPrecision(storage, error_feedback=error_feedback),
        max_staleness=max_staleness, predictor=pcfg)
    state = init_state(cfg, opt, data, precision=settings.precision,
                       predictor=pcfg)
    if fault_state:
        state = attach_fault_state(state, num_parts)
    if mesh is None:
        fn = jax.jit(make_epoch_fn(cfg, opt, settings))
    else:
        data_sh, state_sh = subgraph_shardings(tdata, state, mesh)
        fn = jax.jit(make_epoch_fn(cfg, opt, settings, mesh=mesh),
                     in_shardings=(state_sh, data_sh))
    return fn, state, tdata


def compile_epoch(g, num_parts: int, mesh, **kw):
    """Lower + compile the sharded epoch; returns the Compiled object
    (``.as_text()`` is the partitioned per-device HLO module)."""
    fn, state, tdata = make_epoch(g, num_parts, mesh, **kw)
    return fn.lower(state, tdata).compile()


def make_sampled_epoch(g, num_parts: int, mesh=None, *,
                       storage: str = "fp32",
                       pull_mode: str = "collective", model: str = "gcn",
                       hidden: int = 32, sync_interval: int = 2,
                       fanout: int = 3, batch_seeds: int = 32,
                       estimator: str = "cv"):
    """Sampled-regime analogue of :func:`make_epoch`: build
    ``(jitted_step_fn, state, tdata, batch)`` where ``batch`` is the
    deterministic step-0 sampler draw (jnp-converted).  Same cfg /
    settings construction so census comparisons against ``make_epoch``
    are apples-to-apples."""
    import jax.numpy as jnp

    from repro.core import (TrainSettings, init_sampled_state,
                            make_sampled_epoch_fn, prepare_graph_data)
    from repro.core.halo_exchange import HaloPrecision
    from repro.graph import build_sampler
    from repro.launch.train_gnn import batch_shardings, subgraph_shardings
    from repro.models.gnn import GNNConfig
    from repro.optim import adam

    data = prepare_graph_data(g, num_parts)
    tdata = {k: v for k, v in data.items() if not k.startswith("_")}
    cfg = GNNConfig(model=model, num_layers=3 if model != "gat" else 2,
                    in_dim=g.features.shape[1], hidden_dim=hidden,
                    num_classes=int(g.labels.max()) + 1, heads=2)
    opt = adam(5e-3)
    settings = TrainSettings(
        sync_interval=sync_interval, mode="digest", pull_mode=pull_mode,
        precision=HaloPrecision(storage), sample_estimator=estimator)
    state = init_sampled_state(cfg, opt, data, precision=settings.precision)
    sampler = build_sampler(data, fanout, batch_seeds)
    batch = {k: jnp.asarray(v) for k, v in sampler.sample(0).items()}
    if mesh is None:
        fn = jax.jit(make_sampled_epoch_fn(cfg, opt, settings))
    else:
        data_sh, state_sh = subgraph_shardings(tdata, state, mesh)
        fn = jax.jit(make_sampled_epoch_fn(cfg, opt, settings, mesh=mesh),
                     in_shardings=(state_sh, data_sh,
                                   batch_shardings(mesh)))
    return fn, state, tdata, batch


def compile_sampled_epoch(g, num_parts: int, mesh, **kw):
    """Lower + compile the sharded sampled step (see
    :func:`compile_epoch`)."""
    fn, state, tdata, batch = make_sampled_epoch(g, num_parts, mesh, **kw)
    return fn.lower(state, tdata, batch).compile()
