from repro.kernels.spmm.ops import spmm
from repro.kernels.spmm.ref import spmm_ref
from repro.kernels.spmm.spmm import spmm_pallas

__all__ = ["spmm", "spmm_ref", "spmm_pallas"]
