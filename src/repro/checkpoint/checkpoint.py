"""Pytree checkpointing: flattened-key npz payload + JSON manifest.

Atomic (write to tmp, rename), step-indexed, restores into an arbitrary
template pytree (used for both DIGEST GNN training state and the transformer
train states).  Leaf dtypes are preserved by npz, so the compact
HaloExchange store ({"data": int8/bf16/fp32, "scale": fp32}) round-trips
its quantized layout byte-for-byte; ``meta`` lets callers record the
precision/layout config alongside (see ``read_manifest``).

Crash safety: both the npz payload and the JSON manifest are written to
temp files in the checkpoint directory and published with ``os.replace``
— the manifest first, then the npz — so a crash at any byte leaves
either (a) only temp litter, (b) a manifest whose npz is missing, or
(c) a manifest whose npz bytes don't match its recorded CRC32s.  All
three are *invalid* states that ``latest_step`` skips and
``restore_checkpoint`` rejects with :class:`CheckpointCorruptError`; a
checkpoint is only ever observed as valid once every byte of it is on
disk.  Per-array CRC32 checksums in the manifest extend the same
guarantee to torn/truncated npz writes and bit rot.

The owner-sharded store needs no special casing on save — ``np.asarray``
on a sharded jax array gathers the full (L-1, M·shard_rows, hidden) slab
to host, and the slot layout is positional *in part order, not device
order*, so a checkpoint written from an M-part run restores
bit-identically on any device count — including a different
parts-per-device blocking (M parts on M devices vs M parts on M/k
devices resolve to the same host slab).  Pass ``sharding=`` (a pytree of
shardings, or one sharding for all leaves) to ``restore_checkpoint`` to
place restored leaves straight onto the mesh instead of round-tripping
through a replicated host buffer.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
import zlib
from typing import Any, Optional

import jax
import numpy as np

Pytree = Any

_SEP = "/"


class CheckpointCorruptError(RuntimeError):
    """A checkpoint exists on disk but fails validation.

    Raised when the manifest is unreadable, the npz payload is missing or
    unloadable, the key sets disagree, or a per-array CRC32 in the
    manifest doesn't match the bytes actually on disk.  Distinct from
    ``FileNotFoundError`` (no checkpoint at all) and from the
    ``KeyError``/``ValueError`` a *valid* checkpoint raises when it
    doesn't fit the caller's template.
    """


def _flatten_with_paths(tree: Pytree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_fmt(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":
            # ml_dtypes extension types (bfloat16 etc.) round-trip through
            # npz as raw void bytes that np can't cast back; store as f32
            # (lossless widening) and let restore narrow to the template
            # dtype.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _fmt(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


def _crc32(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _npz_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")


def _manifest_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"ckpt_{step:08d}.json")


def save_checkpoint(ckpt_dir: str, step: int, tree: Pytree,
                    meta: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten_with_paths(tree)
    manifest = {"step": int(step), "keys": sorted(flat),
                "checksums": {k: _crc32(v) for k, v in flat.items()}}
    if meta:
        manifest["meta"] = meta
    path = _npz_path(ckpt_dir, step)
    fd, tmp_npz = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    os.close(fd)
    fd, tmp_json = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    os.close(fd)
    try:
        # Stage both files completely before publishing either: the
        # manifest is the commit record (it carries the CRCs the npz
        # must match), so it is replaced into place first — a crash
        # between the two replaces leaves manifest-without-payload,
        # which validation rejects.
        with open(tmp_npz, "wb") as f:
            np.savez(f, **flat)
            f.flush()
            os.fsync(f.fileno())
        with open(tmp_json, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp_json, _manifest_path(ckpt_dir, step))
        os.replace(tmp_npz, path)
    finally:
        for tmp in (tmp_npz, tmp_json):
            if os.path.exists(tmp):
                os.unlink(tmp)
    return path


def read_manifest(ckpt_dir: str, step: int) -> dict:
    """Load a step's manifest; malformed JSON → CheckpointCorruptError."""
    path = _manifest_path(ckpt_dir, step)
    try:
        with open(path) as f:
            return json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CheckpointCorruptError(
            f"manifest {path} is not valid JSON: {e}") from e


def verify_checkpoint(ckpt_dir: str, step: int) -> dict:
    """Validate manifest + npz payload for ``step``; return the manifest.

    Raises ``FileNotFoundError`` if the manifest is absent and
    :class:`CheckpointCorruptError` if any part of the checkpoint fails
    validation: unloadable npz, key-set mismatch, or CRC32 mismatch.
    Manifests written before checksums existed (no ``"checksums"`` key)
    pass the key check only.
    """
    manifest = read_manifest(ckpt_dir, step)
    path = _npz_path(ckpt_dir, step)
    try:
        with np.load(path) as data:
            keys = set(data.files)
            arrays = {k: data[k] for k in keys}
    except FileNotFoundError as e:
        raise CheckpointCorruptError(
            f"manifest for step {step} present but payload {path} "
            f"missing") from e
    except Exception as e:  # zipfile/pickle errors from a torn write
        raise CheckpointCorruptError(
            f"payload {path} unreadable: {e}") from e
    want = set(manifest.get("keys", []))
    if want and keys != want:
        raise CheckpointCorruptError(
            f"payload {path} key set disagrees with manifest "
            f"(missing {sorted(want - keys)[:4]}, "
            f"extra {sorted(keys - want)[:4]})")
    for key, crc in (manifest.get("checksums") or {}).items():
        if key not in arrays:
            raise CheckpointCorruptError(
                f"payload {path} missing checksummed key {key!r}")
        if _crc32(arrays[key]) != int(crc):
            raise CheckpointCorruptError(
                f"CRC32 mismatch for {key!r} in {path}")
    return manifest


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest step whose checkpoint validates (see ``verify_checkpoint``).

    Partial or corrupt checkpoints — npz without a manifest, manifest
    without its npz, truncated payloads, checksum mismatches — are
    skipped, so a crash mid-save (or bit rot on the newest file) falls
    back to the most recent checkpoint that is actually restorable.
    """
    if not os.path.isdir(ckpt_dir):
        return None
    steps = {int(m.group(1))
             for name in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"ckpt_(\d+)\.(?:npz|json)", name))}
    for step in sorted(steps, reverse=True):
        try:
            verify_checkpoint(ckpt_dir, step)
        except (FileNotFoundError, CheckpointCorruptError):
            continue
        return step
    return None


def restore_checkpoint(ckpt_dir: str, template: Pytree,
                       step: Optional[int] = None,
                       sharding: Optional[Any] = None
                       ) -> tuple[Pytree, int]:
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoints in {ckpt_dir}")
    verify_checkpoint(ckpt_dir, step)
    path = _npz_path(ckpt_dir, step)
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path_entries, leaf in paths:
        key = _SEP.join(_fmt(p) for p in path_entries)
        if key not in data:
            raise KeyError(f"checkpoint missing key {key!r}")
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(
                f"shape mismatch for {key!r}: ckpt {arr.shape} vs "
                f"template {np.shape(leaf)}")
        leaves.append(arr.astype(np.asarray(leaf).dtype))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if sharding is not None:
        tree = jax.device_put(tree, sharding)
    return tree, int(step)
