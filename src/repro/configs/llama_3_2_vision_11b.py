"""llama-3.2-vision-11b [vlm] — cross-attn image layers every 5th layer.

[hf:meta-llama/Llama-3.2-11B-Vision] 40L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=128256. Vision frontend is a stub: input_specs supplies
precomputed patch embeddings (B, 1601, 1280) consumed by xattn layers.
"""
import dataclasses
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256, head_dim=128,
    pattern=("attn", "attn", "attn", "attn", "xattn"),
    vision_dim=1280, num_patches=1601, rope_theta=500000.0,
    optimizer="adafactor", learning_rate=1.5e-4,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=32,
    pattern=("attn", "xattn"), vision_dim=64, num_patches=17,
    dtype="float32", optimizer="adamw")
