"""Trainer: loss decreases; DIGEST pod-sync semantics."""
import dataclasses

import jax
import numpy as np

from repro.configs import get_smoke_arch
from repro.data import make_lm_pipeline
from repro.train import TrainSettings, init_train_state, make_train_step


def test_loss_decreases_on_synthetic_lm():
    cfg = dataclasses.replace(get_smoke_arch("qwen3-0.6b"),
                              vocab_size=64, learning_rate=3e-3)
    settings = TrainSettings(total_steps=60, warmup_steps=5)
    state = init_train_state(cfg, settings)
    step = jax.jit(make_train_step(cfg, settings))
    it = make_lm_pipeline(vocab_size=64, batch=8, seq=32, seed=0)
    losses = []
    for i in range(50):
        b = next(it)
        state, m = step(state, {"tokens": b.tokens, "labels": b.labels,
                                "mask": b.mask})
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.2


def test_digest_pod_sync_converges_and_syncs():
    """n_pod=2 local SGD: copies diverge between syncs, equal at syncs."""
    cfg = dataclasses.replace(get_smoke_arch("qwen3-0.6b"), vocab_size=64)
    settings = TrainSettings(sync_mode="digest", n_pod=2, sync_interval=4,
                             total_steps=40, warmup_steps=2)
    state = init_train_state(cfg, settings)
    # params have the leading pod dim
    leaf = jax.tree.leaves(state["params"])[0]
    assert leaf.shape[0] == 2
    step = jax.jit(make_train_step(cfg, settings))
    it = make_lm_pipeline(vocab_size=64, batch=8, seq=16, seed=1)
    divs = []
    for i in range(8):
        b = next(it)
        state, m = step(state, {"tokens": b.tokens, "labels": b.labels,
                                "mask": b.mask})
        divs.append(float(m["pod_divergence"]))
    # steps 4 and 8 are sync steps → divergence exactly 0 after averaging
    assert divs[3] == 0.0 and divs[7] == 0.0
    # between syncs the pods genuinely diverge (local SGD)
    assert divs[1] > 0.0 and divs[5] > 0.0


def test_every_step_mode_has_no_pod_dim():
    cfg = get_smoke_arch("qwen3-0.6b")
    settings = TrainSettings(sync_mode="every_step", n_pod=1)
    state = init_train_state(cfg, settings)
    leaf = jax.tree.leaves(state["params"])[0]
    assert leaf.ndim in (1, 2, 3, 4)
