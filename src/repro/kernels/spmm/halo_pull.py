"""Pallas TPU kernel: fused halo pull + aggregate over the compact slab.

Computes the out-of-subgraph side of DIGEST's Eq. 5

    out[i] = sum_k wts[i, k] * dequant(slab[nbr[i, k]])

where ``slab`` is the HaloExchange compact store layer — fp32, bf16, or
int8 with per-row fp32 scales — and ``nbr`` holds *compact-store slot*
indices (sentinel == slab.shape[0]-1, a zero row).  Fusing the gather into
the ELL product means the non-pull epochs of Algorithm 1 never materialize
the ``(M, L-1, H, hidden)`` halo cache the seed implementation kept: each
row block reads exactly the slab rows its edges touch, and int8 rows are
dequantized in-register (VMEM traffic shrinks by the same 2–4× as the
§3.3 wire format).

Grid/block design matches ``spmm.py``: grid = (row_blocks, feature_blocks),
the slab carried per feature-block into VMEM — int8 slabs fit 4× more rows
in the same VMEM budget.  Per-row scales ride along as a (rows, 1) fp32
column and are folded into the edge weight (``w · scale[idx]``) before the
FMA, so the inner loop stays a gather + single fused multiply-add.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.spmm.spmm import BLOCK_F, BLOCK_ROWS, spmm_pallas


def _halo_kernel_scaled(nbr_ref, wts_ref, data_ref, scale_ref, out_ref):
    deg = nbr_ref.shape[1]
    table = data_ref[...]                        # (rows_tab, BF) int8
    scale = scale_ref[...][:, 0]                 # (rows_tab,) fp32

    def body(k, acc):
        idx = nbr_ref[:, k]
        gathered = jnp.take(table, idx, axis=0).astype(jnp.float32)
        # Fold the per-row dequant scale into the edge weight: one FMA.
        w = wts_ref[:, k].astype(jnp.float32) * jnp.take(scale, idx, axis=0)
        return acc + w[:, None] * gathered

    acc = jnp.zeros(out_ref.shape, jnp.float32)
    acc = jax.lax.fori_loop(0, deg, body, acc)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def halo_spmm_pallas(nbr: jax.Array, wts: jax.Array, data: jax.Array,
                     scale: jax.Array = None,
                     interpret: bool = True) -> jax.Array:
    """Fused pull+aggregate via pallas_call.

    Args:
      nbr:   (rows, deg) int32 — compact-store slot ids (< data.shape[0]).
      wts:   (rows, deg) float — 0 at padding slots.
      data:  (n_slots_padded, feat) slab incl. sentinel row (fp32/bf16/int8).
      scale: optional (n_slots_padded, 1) fp32 per-row dequant scales.
    Returns:
      (rows, feat) float32 result.
    """
    if scale is None:
        # Unscaled fp32/bf16 slabs are exactly the ELL SpMM (its inner
        # loop already upcasts gathered rows to f32); one kernel body to
        # keep in sync for future block/DMA changes.
        return spmm_pallas(nbr, wts, data, interpret=interpret)
    rows, deg = nbr.shape
    n_tab, feat = data.shape
    br = min(BLOCK_ROWS, rows)
    bf = min(BLOCK_F, feat)
    if rows % br or feat % bf:
        raise ValueError(f"rows={rows} feat={feat} must be divisible by "
                         f"block ({br},{bf}); pad upstream")
    grid = (rows // br, feat // bf)
    return pl.pallas_call(
        _halo_kernel_scaled,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, deg), lambda i, j: (i, 0)),
            pl.BlockSpec((br, deg), lambda i, j: (i, 0)),
            pl.BlockSpec((n_tab, bf), lambda i, j: (0, j)),
            pl.BlockSpec((n_tab, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, feat), jnp.float32),
        interpret=interpret,
    )(nbr, wts, data, scale)
