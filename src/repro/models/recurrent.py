"""Recurrent sequence blocks: RG-LRU (RecurrentGemma/Griffin) and xLSTM
(mLSTM + sLSTM).

All three expose a *training* form over (B, S, ...) and a *decode* form
(single step + carried state), which is what makes ``long_500k`` native for
these families (O(1) state instead of a 524k KV cache).

Training parallelization:
  * RG-LRU: ``jax.lax.associative_scan`` over the sequence (log-depth).
  * mLSTM: parallel quadratic form with stabilized exponential gating
    (xLSTM paper, Eq. 19-27).
  * sLSTM: genuinely sequential (hidden-to-hidden recurrence) →
    ``jax.lax.scan``; xLSTM-1.3b places it in 1 of 8 blocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

C_RGLRU = 8.0


def rg_lru(x: jax.Array, gate_x: jax.Array, gate_a: jax.Array,
           log_lambda: jax.Array,
           h0: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Real-Gated LRU scan.

    x, gate_x, gate_a: (B, S, D) — input branch and the two gate
    pre-activations; log_lambda: (D,) learned decay parameter.
    Returns (y (B,S,D), h_last (B,D)).
    """
    r = jax.nn.sigmoid(gate_a.astype(jnp.float32))
    i = jax.nn.sigmoid(gate_x.astype(jnp.float32))
    log_a = -C_RGLRU * jax.nn.softplus(log_lambda.astype(jnp.float32)) * r
    a = jnp.exp(log_a)                                   # (B, S, D)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) \
        * (i * x.astype(jnp.float32))

    if h0 is not None:
        # Fold the carried state into the first step.
        first = a[:, :1] * h0[:, None] + gated[:, :1]
        gated = jnp.concatenate([first, gated[:, 1:]], axis=1)
        a = jnp.concatenate([jnp.zeros_like(a[:, :1]), a[:, 1:]], axis=1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a2 * a1, a2 * b1 + b2

    a_sc, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rg_lru_step(x: jax.Array, gate_x: jax.Array, gate_a: jax.Array,
                log_lambda: jax.Array, h: jax.Array
                ) -> tuple[jax.Array, jax.Array]:
    """One decode step; x, gates: (B, D); h: (B, D) carried state."""
    r = jax.nn.sigmoid(gate_a.astype(jnp.float32))
    i = jax.nn.sigmoid(gate_x.astype(jnp.float32))
    log_a = -C_RGLRU * jax.nn.softplus(log_lambda.astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    h_new = a * h + jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2 * log_a), 1e-9)) \
        * (i * x.astype(jnp.float32))
    return h_new.astype(x.dtype), h_new


# ---------------------------------------------------------------------------
# mLSTM (matrix memory, parallel training form)
# ---------------------------------------------------------------------------

def mlstm_parallel(q: jax.Array, k: jax.Array, v: jax.Array,
                   i_pre: jax.Array, f_pre: jax.Array) -> jax.Array:
    """q, k, v: (B, H, S, D); i_pre, f_pre: (B, H, S) gate pre-activations.

    Stabilized parallel form (xLSTM Eq. 19-27).
    """
    b, h, s, d = q.shape
    f32 = jnp.float32
    logf = jax.nn.log_sigmoid(f_pre.astype(f32))         # (B, H, S)
    csum = jnp.cumsum(logf, axis=-1)
    # D̃_ij = Σ_{t=j+1}^{i} log f_t + ĩ_j  (j ≤ i)
    dtil = csum[..., :, None] - csum[..., None, :] + \
        i_pre.astype(f32)[..., None, :]
    causal = jnp.tril(jnp.ones((s, s), bool))
    dtil = jnp.where(causal, dtil, -jnp.inf)
    m = jnp.max(dtil, axis=-1)                            # (B, H, S)
    dmat = jnp.exp(dtil - m[..., None])
    scores = jnp.einsum("bhsd,bhtd->bhst", q.astype(f32),
                        k.astype(f32)) * (d ** -0.5)
    c = scores * dmat
    norm = jnp.maximum(jnp.abs(jnp.sum(c, axis=-1)), jnp.exp(-m))
    out = jnp.einsum("bhst,bhtd->bhsd", c, v.astype(f32)) \
        / jnp.maximum(norm, 1e-12)[..., None]
    return out.astype(q.dtype)


def mlstm_step(q: jax.Array, k: jax.Array, v: jax.Array,
               i_pre: jax.Array, f_pre: jax.Array,
               state: dict) -> tuple[jax.Array, dict]:
    """One decode step. q,k,v: (B,H,D); i_pre,f_pre: (B,H);
    state: {C (B,H,D,D), n (B,H,D), m (B,H)}."""
    f32 = jnp.float32
    d = q.shape[-1]
    logf = jax.nn.log_sigmoid(f_pre.astype(f32))
    m_new = jnp.maximum(logf + state["m"], i_pre.astype(f32))
    f_s = jnp.exp(logf + state["m"] - m_new)
    i_s = jnp.exp(i_pre.astype(f32) - m_new)
    kf = k.astype(f32) * (d ** -0.5)
    C = f_s[..., None, None] * state["C"] + \
        i_s[..., None, None] * jnp.einsum("bhd,bhe->bhde", vf(v), kf)
    n = f_s[..., None] * state["n"] + i_s[..., None] * kf
    num = jnp.einsum("bhde,bhe->bhd", C, q.astype(f32))
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n,
                                         q.astype(f32))), jnp.exp(-m_new))
    out = num / jnp.maximum(den, 1e-12)[..., None]
    return out.astype(q.dtype), {"C": C, "n": n, "m": m_new}


def vf(v: jax.Array) -> jax.Array:
    return v.astype(jnp.float32)


# ---------------------------------------------------------------------------
# sLSTM (scalar memory, sequential)
# ---------------------------------------------------------------------------

def slstm_scan(wx: jax.Array, r_weights: dict,
               state: dict | None = None
               ) -> tuple[jax.Array, dict]:
    """Sequential sLSTM over a sequence.

    wx: dict-free packed input pre-activations (B, S, H, 4, D) for gates
    (z, i, f, o); r_weights: per-gate recurrent matrices {gate: (H, D, D)}.
    Returns (h (B,S,H,D), final state {c,n,m,h}).
    """
    b, s, h, _, d = wx.shape
    f32 = jnp.float32
    if state is None:
        zero = jnp.zeros((b, h, d), f32)
        state = {"c": zero, "n": zero, "h": zero,
                 "m": jnp.zeros((b, h, d), f32)}

    rz, ri, rf, ro = (r_weights["z"], r_weights["i"], r_weights["f"],
                      r_weights["o"])

    def step(carry, x_t):
        c, n, m, h_prev = carry["c"], carry["n"], carry["m"], carry["h"]
        rec = lambda r: jnp.einsum("bhd,hde->bhe", h_prev, r.astype(f32))
        z = jnp.tanh(x_t[:, :, 0].astype(f32) + rec(rz))
        i_pre = x_t[:, :, 1].astype(f32) + rec(ri)
        f_pre = x_t[:, :, 2].astype(f32) + rec(rf)
        o = jax.nn.sigmoid(x_t[:, :, 3].astype(f32) + rec(ro))
        logf = jax.nn.log_sigmoid(f_pre)
        m_new = jnp.maximum(logf + m, i_pre)
        i_s = jnp.exp(i_pre - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c_new = f_s * c + i_s * z
        n_new = f_s * n + i_s
        h_new = o * c_new / jnp.maximum(n_new, 1e-12)
        carry = {"c": c_new, "n": n_new, "m": m_new, "h": h_new}
        return carry, h_new

    carry, hs = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    return jnp.moveaxis(hs, 0, 1).astype(wx.dtype), carry
