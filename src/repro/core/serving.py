"""Online embedding serving over the owner-sharded DIGEST store.

ROADMAP's "store as a product" path: the stale-representation KVS
already holds everything needed to answer node-prediction queries
(recommendations / fraud scores) — h^(L-1), the input rows of the top
GNN layer.  This module turns it into a read-optimized inference
service: a jitted batched query engine over an **all-node** serving
store, a device-resident hot-row cache for skewed (Zipf) traffic, and a
donation-friendly in-place refresh so serving and periodic DIGEST sync
coexist without doubling store memory.

Serving-store layout
--------------------

Training stores only *boundary* rows; a query can hit any node, so the
serving store is a second, single-layer owner-sharded slab over ALL
nodes, reusing every HaloExchange convention (and therefore every
pull/push/quantize code path):

    slot(v) = assign[v] · (S + 1) + local_row(v)

with S the padded part size, one zero sentinel row per shard at local
row S, and the global sentinel the last row (``serve_map[N] = R - 1``).
Two consequences do the heavy lifting:

  * shard m, in local-row order, IS part m's ``x_local`` table for the
    top layer — ``store["data"][0].reshape(M, S+1, hidden)`` is a
    collective-free re-view under pjit (the slot axis splits into the
    sharded part axis times local rows), sentinel row included exactly
    where ``_pad_sentinel`` would put it;
  * ``owner = slot // (S+1)`` — so the generic
    :func:`repro.graph.partition.build_pull_plan` routes the serving
    pull, and :func:`halo_exchange.collective_pull` ships out-of-shard
    rows through the same ragged ``all_to_all`` as training (zero
    all-gathers, pinned by the HLO census in tests/test_serving.py).

The store dict carries one extra leaf next to {"data"[, "scale"]}: an
int32 ``version`` scalar, bumped by every refresh — the cache
invalidation signal (below).

Query engines
-------------

:func:`serve_query` — the single-program fast path: a batch of global
node ids is resolved through ``serve_map``, the (L-1)-layer rows of
each query node and its in-neighbors are gathered from the store (the
gcn/sage neighbor reduction rides :func:`repro.kernels.spmm.halo_spmm`,
i.e. the resident/stream/skip kernel-selection ladder; GAT's attention
gathers rows through :func:`repro.kernels.spmm.halo_gather`), and only
the top layer runs — logits for exactly the queried rows.  The
aggregation mirrors the full-graph forward's ELL math term for term, so
served gcn/sage logits are bitwise equal to
``full_graph_forward``/``evaluate()`` on a frozen store (gat ≤ 1e-6,
attention softmax reassociation).

:func:`serve_query_sharded` — the SPMD form over a mesh: per-part local
row batches, out-of-shard halo rows pulled via ``collective_pull`` with
the serving PullPlan, in-shard rows read from the device's own slab
re-view, the top layer vmapped over parts.  Same split-aggregation
(in + out) form as the training epoch.

Hot-row cache
-------------

A fixed-capacity, set-associative (``cache_ways``-way, LRU) slot cache
in front of the store, holding the **maximally-collapsed** hot row — a
query node's finished logits row, the pure function of (slot, store
version) that a repeat query needs.  Entries carry (tag = serve slot,
version); a hit requires both to match, so a refresh invalidates every
cached row by bumping ``version`` — no scanning, no eviction sweep.
Lookup and miss-fill are fully vectorized: one gather for the lookup,
one deterministic scatter for the fill (at most one fill per set per
batch; the winner is picked by a scatter-max over batch indices, so the
tag and data writes can never interleave rows).  Hit/miss counters
count valid queries only.

Refresh
-------

:func:`make_refresh_fn` returns a jitted ``refresh(store, reps_top,
rdata)`` with ``donate_argnums=(0,)``: the old store's buffers are
donated, so XLA scatters the new representations in place — serving
and periodic sync share one store-sized allocation.  ``reps_top`` is
:func:`repro.core.digest.top_layer_reps` (byte-for-byte the tensor a
training PUSH writes for layer L-2), routed through the same
``halo_exchange.push`` / ``shard_push`` scatter as training.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import halo_exchange
from repro.core.halo_exchange import PRECISIONS, HaloPrecision
from repro.graph.partition import PullPlan, build_pull_plan
from repro.kernels.spmm import halo_gather, halo_spmm
from repro.nn import dense


# ---------------------------------------------------------------------------
# Static serving knobs (jit-cache keys)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static serving knobs — a frozen, hashable jit-cache key.

    Every field is part of the compiled program (batch geometry, cache
    geometry, storage precision, kernel-selection knobs), so the whole
    config is passed through ``static_argnames`` like the PR-4 kernel
    knobs: a benchmark sweeping capacity / batch / precision retraces
    exactly when it must and can never reuse a wrong executable.
    """

    batch_size: int = 256
    # Hot-row cache capacity in rows; 0 disables the cache (queries
    # always recompute).  Must be a multiple of cache_ways.
    cache_rows: int = 0
    cache_ways: int = 4
    # Serving-store storage precision (same vocabulary as HaloPrecision).
    storage: str = "fp32"
    # Aggregation backend + halo_spmm selection-ladder overrides for the
    # query-time neighbor reduction (see repro.kernels.spmm.ops).
    backend: str = "jnp"
    resident_max_bytes: Optional[int] = None
    chunk_rows: Optional[int] = None
    skip_occupancy_max: Optional[float] = None

    def __post_init__(self):
        if self.storage not in PRECISIONS:
            raise ValueError(f"storage {self.storage!r} not in {PRECISIONS}")
        if self.batch_size < 1:
            raise ValueError(f"batch_size {self.batch_size} < 1")
        if self.cache_ways < 1:
            raise ValueError(f"cache_ways {self.cache_ways} < 1")
        if self.cache_rows < 0 or self.cache_rows % self.cache_ways:
            raise ValueError(
                f"cache_rows {self.cache_rows} must be a non-negative "
                f"multiple of cache_ways {self.cache_ways}")

    @property
    def cache_sets(self) -> int:
        return self.cache_rows // self.cache_ways

    @property
    def precision(self) -> HaloPrecision:
        return HaloPrecision(self.storage)


# ---------------------------------------------------------------------------
# Host-side plan: slot layout, routing, query ELL
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServePlan:
    """Host-side serving layout/routing (numpy; build once per graph).

    ``query_data()`` / ``refresh_data()`` / ``sharded_data(data)`` bundle
    the traced-array views each jitted entry point takes.
    """

    num_nodes: int
    num_parts: int
    part_rows: int            # S — padded local rows per part
    serve_rows: int           # S + 1 (per-shard sentinel row included)
    store_rows: int           # R = M · (S + 1)
    halo_size: int            # H — per-part out-of-part slots
    serve_map: np.ndarray     # (N+1,) global id → serve slot (sentinel R-1)
    local_ids: np.ndarray     # (M, S) global id of each local row
    local_valid: np.ndarray   # (M, S) bool
    local_slots: np.ndarray   # (M, S) serve slot of each local row
    sentinel_slots: np.ndarray  # (M,) per-shard sentinel slots
    halo_slots: np.ndarray    # (M, H) serve slot of each halo entry
    pull: PullPlan            # serving-layout collective-pull routing
    nbr: np.ndarray           # (N+1, Din) in-neighbor global ids, sentinel N
    wts: np.ndarray           # (N+1, Din) in-edge weights

    def query_data(self) -> dict:
        """Traced arrays of :func:`serve_query` (the ``qdata`` dict)."""
        return {"serve_map": jnp.asarray(self.serve_map),
                "nbr": jnp.asarray(self.nbr),
                "wts": jnp.asarray(self.wts)}

    def refresh_data(self) -> dict:
        """Traced arrays of the refresh step (the ``rdata`` dict)."""
        return {"local_ids": jnp.asarray(self.local_ids),
                "local_valid": jnp.asarray(self.local_valid),
                "local_slots": jnp.asarray(self.local_slots),
                "sentinel_slots": jnp.asarray(self.sentinel_slots)}

    def sharded_data(self, data: dict) -> dict:
        """Traced arrays of :func:`serve_query_sharded`: the serving
        PullPlan routing plus the per-part training ELL (the out-ELL
        addresses the pulled slab by halo position, which is exactly
        where the serving plan's ``recv_positions`` land each row)."""
        struct = data["struct"]
        return {"send": jnp.asarray(self.pull.send_offsets),
                "recv": jnp.asarray(self.pull.recv_positions),
                "in_nbr": struct["in_nbr"], "in_wts": struct["in_wts"],
                "out_nbr": struct["out_nbr"], "out_wts": struct["out_wts"]}


def build_serve_plan(data: dict) -> ServePlan:
    """Derive the serving layout from a ``prepare_graph_data`` dict.

    Needs the host-side ``_sp`` metadata (the partition build) and the
    full M=1 view; the serving slot space is the all-node owner-sharded
    layout described in the module docstring.
    """
    sp = data.get("_sp")
    if sp is None:
        raise ValueError("build_serve_plan needs prepare_graph_data's "
                         "host-side '_sp' metadata (don't strip it "
                         "before building the plan)")
    local_ids = np.asarray(sp.local_ids)
    local_valid = np.asarray(sp.local_valid)
    M, S = local_ids.shape
    srows = S + 1
    R = M * srows
    n = int(sp.num_nodes)

    serve_map = np.full(n + 1, R - 1, np.int32)
    for m in range(M):
        v = local_valid[m]
        serve_map[local_ids[m][v]] = m * srows + np.where(v)[0]
    local_slots = (np.arange(M, dtype=np.int32)[:, None] * srows
                   + np.arange(S, dtype=np.int32)[None, :])
    sentinel_slots = (np.arange(M, dtype=np.int32) + 1) * srows - 1

    halo_ids = np.asarray(sp.halo_ids)
    halo_valid = np.asarray(sp.halo_valid)
    halo_slots = np.where(halo_valid,
                          serve_map[np.minimum(halo_ids, n)],
                          R - 1).astype(np.int32)
    pull = build_pull_plan(halo_slots, halo_valid, sp.halo_size, srows)

    # Full-view in-ELL re-keyed to (n+1) global-id rows: row v lists v's
    # in-neighbors (full view local index == global id by construction),
    # row n is the all-sentinel padding row queries clamp into.
    full_nbr = np.asarray(data["full_struct"]["in_nbr"])[0]
    full_wts = np.asarray(data["full_struct"]["in_wts"])[0]
    full_ids = np.asarray(data["full_ids"])[0]
    if not np.array_equal(full_ids[:n], np.arange(n)):
        raise ValueError("full view rows are not in ascending global-id "
                         "order; the serving query ELL cannot be "
                         "re-keyed by node id")
    din = full_nbr.shape[1]
    nbr = np.full((n + 1, din), n, np.int32)
    wts = np.zeros((n + 1, din), np.float32)
    nbr[:n] = np.where(full_nbr[:n] >= n, n, full_nbr[:n])
    wts[:n] = full_wts[:n]

    return ServePlan(num_nodes=n, num_parts=M, part_rows=S,
                     serve_rows=srows, store_rows=R,
                     halo_size=int(sp.halo_size), serve_map=serve_map,
                     local_ids=local_ids, local_valid=local_valid,
                     local_slots=local_slots.astype(np.int32),
                     sentinel_slots=sentinel_slots,
                     halo_slots=halo_slots, pull=pull, nbr=nbr, wts=wts)


# ---------------------------------------------------------------------------
# Serving store: init + donation-friendly refresh
# ---------------------------------------------------------------------------

def init_serve_store(plan: ServePlan, hidden: int,
                     precision: HaloPrecision = HaloPrecision()) -> dict:
    """All-node single-layer serving slab + the version scalar:
    {"data": (1, R, hidden)[, "scale"], "version": int32 ()}."""
    store = halo_exchange.init_store(1, plan.store_rows - 1, hidden,
                                     precision)
    store["version"] = jnp.zeros((), jnp.int32)
    return store


def store_bare(store: dict) -> dict:
    """The HaloExchange view of a serving store (version leaf stripped —
    pull/push paths iterate exactly {"data"[, "scale"]})."""
    return {k: store[k] for k in ("data", "scale") if k in store}


def make_refresh_fn(mesh=None, serve_rows: int = None, donate: bool = True):
    """Jitted in-place serving-store refresh.

    Returns ``refresh(store, reps_top, rdata) -> store`` where
    ``reps_top`` is the (N_pad, hidden) top-layer input table
    (:func:`repro.core.digest.top_layer_reps`) and ``rdata`` is
    ``ServePlan.refresh_data()``.  The store argument is **donated**: the
    scatter reuses the old slab's buffers, so a serving deployment holds
    one store-sized allocation across refreshes.  Every refresh bumps
    ``version``, invalidating all hot-row cache entries at once.

    With ``mesh`` the scatter goes through the shard-local
    :func:`halo_exchange.shard_push` (pass ``serve_rows`` =
    ``ServePlan.serve_rows``); otherwise the SPMD
    :func:`halo_exchange.push` fallback.
    """
    if mesh is not None and serve_rows is None:
        raise ValueError("mesh refresh needs serve_rows "
                         "(ServePlan.serve_rows)")

    def _refresh(store, reps_top, rdata):
        ids = jnp.minimum(rdata["local_ids"], reps_top.shape[0] - 1)
        reps = reps_top[ids][:, None]                   # (M, 1, S, hidden)
        bare = store_bare(store)
        if mesh is None:
            new = halo_exchange.push(bare, rdata["local_slots"],
                                     rdata["local_valid"], reps,
                                     rdata["sentinel_slots"])
        else:
            new = halo_exchange.shard_push(bare, rdata["local_slots"],
                                           rdata["local_valid"], reps,
                                           serve_rows, mesh)
        new["version"] = store["version"] + 1
        return new

    return jax.jit(_refresh, donate_argnums=(0,) if donate else ())


def refresh_or_degrade(refresh_fn, store, reps_top, rdata,
                       stats: dict = None) -> tuple[dict, dict]:
    """Deploy a refresh; on ANY failure keep serving the old store.

    The degraded-mode contract: a refresh that raises mid-deployment
    (bad reps shape, placement error, an upstream trainer handing over
    garbage) must not take serving down — the previous store version
    keeps answering queries bitwise-identically, and because the
    version scalar was never bumped, every hot-row cache entry remains
    valid (the version-compare cache needs no special casing; pinned
    by tests/test_serving.py).  The failure is *counted*, not hidden:
    ``stats["degraded_refreshes"]`` increments so operators can alarm
    on a store that has silently stopped updating.

    Pair with ``make_refresh_fn(donate=False)`` when degradation
    matters: a donated store argument may have its buffers consumed by
    the very call that fails, leaving nothing to keep serving from.

    Returns ``(store, stats)`` — the new store on success, the old one
    on failure; ``stats`` gains ``refreshes``/``degraded_refreshes``
    counts (a fresh dict when None is passed).
    """
    stats = dict(stats) if stats else {"refreshes": 0,
                                       "degraded_refreshes": 0}
    try:
        new = refresh_fn(store, reps_top, rdata)
        jax.block_until_ready(new)
    except Exception:
        stats["degraded_refreshes"] += 1
        return store, stats
    stats["refreshes"] += 1
    return new, stats


# ---------------------------------------------------------------------------
# Hot-row cache
# ---------------------------------------------------------------------------

def init_cache(scfg: ServeConfig, width: int) -> dict:
    """Empty hot-row cache pytree for rows of ``width`` (= num_classes).

    tags/vers are -1 (no slot, no version — never matches), so a fresh
    cache misses everything; ``last`` is the LRU clock (per-way last
    access step), ``step`` the batch counter, hits/misses the counters
    the benchmark reads.  ``cache_rows == 0`` keeps only the counters.
    """
    counters = {"hits": jnp.zeros((), jnp.int32),
                "misses": jnp.zeros((), jnp.int32)}
    if scfg.cache_rows == 0:
        return counters
    sets, ways = scfg.cache_sets, scfg.cache_ways
    return {"tags": jnp.full((sets, ways), -1, jnp.int32),
            "vers": jnp.full((sets, ways), -1, jnp.int32),
            "last": jnp.zeros((sets, ways), jnp.int32),
            "rows": jnp.zeros((sets, ways, width), jnp.float32),
            "step": jnp.zeros((), jnp.int32), **counters}


def hit_rate(cache: dict) -> float:
    """hits / (hits + misses) over every valid query served so far."""
    h, m = int(cache["hits"]), int(cache["misses"])
    return h / max(h + m, 1)


def _cache_lookup(cache, slots, version):
    """Vectorized set-associative probe: returns (hit, rows, line, way)."""
    sets = cache["tags"].shape[0]
    line = slots % sets                                     # (B,)
    hit_w = ((cache["tags"][line] == slots[:, None])
             & (cache["vers"][line] == version))            # (B, ways)
    hit = jnp.any(hit_w, axis=1)
    way = jnp.argmax(hit_w, axis=1)
    return hit, cache["rows"][line, way], line, way


def _cache_commit(cache, slots, version, fresh_rows, hit, line, way, valid):
    """Touch LRU on hits, fill at most one victim way per set from the
    missed rows, and advance the counters — one deterministic scatter.

    Among a set's misses the *highest batch index* wins (scatter-max over
    batch positions), and all of a winner's writes (tag, version, clock,
    data) go to the same (line, way) — losers are redirected to a padded
    dummy set row that is sliced off, so a duplicate-slot batch can never
    interleave one row's tag with another row's data.
    """
    sets = cache["tags"].shape[0]
    b = slots.shape[0]
    step2 = cache["step"] + 1
    touched = cache["last"].at[line, way].max(
        jnp.where(hit & valid, step2, 0))
    # Victim way per probe: any dead way first (empty tag or stale
    # version — both unreadable), else least-recently-used.
    dead = (cache["vers"][line] != version) | (cache["tags"][line] < 0)
    evict_way = jnp.argmin(jnp.where(dead, -1, touched[line]), axis=1)
    want = (~hit) & valid
    cand = jnp.where(want, jnp.arange(b, dtype=jnp.int32), -1)
    winner = jnp.full((sets,), -1, jnp.int32).at[line].max(cand)
    do = want & (winner[line] == jnp.arange(b, dtype=jnp.int32))
    wline = jnp.where(do, line, sets)           # losers → dummy set row

    def pad1(a):
        return jnp.pad(a, ((0, 1),) + ((0, 0),) * (a.ndim - 1))

    return {
        "tags": pad1(cache["tags"]).at[wline, evict_way].set(slots)[:sets],
        "vers": pad1(cache["vers"]).at[wline, evict_way]
                .set(version)[:sets],
        "last": pad1(touched).at[wline, evict_way].set(step2)[:sets],
        "rows": pad1(cache["rows"]).at[wline, evict_way]
                .set(fresh_rows)[:sets],
        "step": step2,
        "hits": cache["hits"] + jnp.sum((hit & valid).astype(jnp.int32)),
        "misses": cache["misses"] + jnp.sum(want.astype(jnp.int32)),
    }


# ---------------------------------------------------------------------------
# The top-layer math over a query batch (shared by both engines)
# ---------------------------------------------------------------------------

def _side_spmm(scfg: ServeConfig, side: dict, wts) -> jax.Array:
    """One aggregation side through the halo_spmm selection ladder."""
    return halo_spmm(side["nbr"], wts, side["data"], side.get("scale"),
                     backend=scfg.backend,
                     resident_max_bytes=scfg.resident_max_bytes,
                     chunk_rows=scfg.chunk_rows,
                     skip_occupancy_max=scfg.skip_occupancy_max)


def _batch_top_layer(cfg, scfg: ServeConfig, p, h_self, sides):
    """Top GNN layer restricted to a query batch.

    ``sides`` are aggregation sides, each {"nbr": (B, D) row ids into its
    "data" slab, "wts": (B, D), "valid": (B, D), "data"[, "scale"]}: the
    fast path passes ONE side (the full-view ELL against the whole
    store, exactly the fused sum the full-graph forward computes — the
    gcn/sage bitwise-parity invariant), the SPMD engine two (the
    in-shard + pulled-halo split of the training epoch).  Mirrors the
    layer math of ``repro.models.gnn`` term for term.
    """
    if cfg.model == "gcn":
        agg = _side_spmm(scfg, sides[0], sides[0]["wts"])
        for s in sides[1:]:
            agg = agg + _side_spmm(scfg, s, s["wts"])
        return dense(agg, p["w"], p["b"])
    if cfg.model == "sage":
        denom = jnp.sum(sides[0]["wts"], axis=1, keepdims=True)
        for s in sides[1:]:
            denom = denom + jnp.sum(s["wts"], axis=1, keepdims=True)
        denom = jnp.maximum(denom, 1e-12)
        agg = _side_spmm(scfg, sides[0], sides[0]["wts"] / denom)
        for s in sides[1:]:
            agg = agg + _side_spmm(scfg, s, s["wts"] / denom)
        return (dense(h_self, p["w_self"]) + dense(agg, p["w_nbr"])
                + p["b"])
    if cfg.model != "gat":
        raise ValueError(cfg.model)

    z_self = jnp.einsum("bd,dhk->bhk", h_self, p["w"])
    s_dst = jnp.einsum("bhk,hk->bh", z_self, p["a_dst"])
    scored = []
    for s in sides:
        rows = halo_gather(s["nbr"], s["data"], s.get("scale"))
        z = jnp.einsum("bkd,dhj->bkhj", rows, p["w"])       # (B, D, h, j)
        e = jax.nn.leaky_relu(
            s_dst[:, None, :] + jnp.einsum("bkhj,hj->bkh", z, p["a_src"]),
            0.2)
        v = s["valid"][..., None]
        scored.append((z, jnp.where(v, e, -1e30), v))
    m = scored[0][1].max(axis=1)
    for _, e, _ in scored[1:]:
        m = jnp.maximum(m, e.max(axis=1))
    m = jax.lax.stop_gradient(m)                            # (B, heads)
    probs = [jnp.exp(e - m[:, None, :]) * v for _, e, v in scored]
    denom = jnp.sum(probs[0], axis=1)
    for pe in probs[1:]:
        denom = denom + jnp.sum(pe, axis=1)
    denom = denom + 1e-16
    out = 0.0
    for (z, _, _), pe in zip(scored, probs):
        out = out + jnp.einsum("bkh,bkhj->bhj", pe / denom[:, None, :], z)
    return out.reshape(out.shape[0], -1) + p["b"]


# ---------------------------------------------------------------------------
# Query engines
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "scfg"))
def serve_query(cfg, scfg: ServeConfig, params, store, cache, qdata,
                q) -> tuple[jax.Array, dict]:
    """Batched prediction query against the serving store (fast path).

    q: (batch_size,) global node ids; pad short batches with
    ``num_nodes`` (padding rows are excluded from the cache counters and
    return the sentinel-row logits).  Returns (logits (B, classes),
    new_cache).  ``cfg``/``scfg`` are static jit-cache keys.
    """
    n = qdata["serve_map"].shape[0] - 1
    if q.shape != (scfg.batch_size,):
        raise ValueError(
            f"query batch shape {q.shape} != (batch_size={scfg.batch_size},)"
            " — pad with the sentinel id num_nodes (ServeConfig.batch_size"
            " is a static jit-cache key, not a bound)")
    valid = q < n
    qc = jnp.minimum(q, n)
    slots = qdata["serve_map"][qc]

    data, scale = halo_exchange.layer_table(store_bare(store), 0)
    nbr_ids = qdata["nbr"][qc]                              # (B, Din)
    side = {"nbr": qdata["serve_map"][nbr_ids],
            "wts": qdata["wts"][qc],
            "valid": nbr_ids < n, "data": data}
    if scale is not None:
        side["scale"] = scale
    h_self = halo_gather(slots, data, scale)
    p = params[f"layer_{cfg.num_layers - 1}"]
    fresh = _batch_top_layer(cfg, scfg, p, h_self, [side])

    if scfg.cache_rows == 0:
        counters = dict(cache)
        counters["misses"] = (cache["misses"]
                              + jnp.sum(valid.astype(jnp.int32)))
        return fresh, counters
    hit, rows, line, way = _cache_lookup(cache, slots, store["version"])
    hit = hit & valid
    logits = jnp.where(hit[:, None], rows, fresh)
    new_cache = _cache_commit(cache, slots, store["version"], fresh, hit,
                              line, way, valid)
    return logits, new_cache


@functools.partial(jax.jit,
                   static_argnames=("cfg", "scfg", "mesh", "halo_size"))
def serve_query_sharded(cfg, scfg: ServeConfig, mesh, halo_size: int,
                        params, store, sdata, q_rows) -> jax.Array:
    """SPMD batched query over the mesh-sharded serving store.

    q_rows: (M, B) part-local row indices (use ``part_rows`` as padding).
    Out-of-shard halo rows arrive through ``collective_pull`` with the
    serving PullPlan — the ragged all_to_all, zero all-gathers — while
    in-shard rows are read from the device's own slab re-view; the top
    layer is vmapped over parts in the training epoch's split (in + out)
    aggregation form.  Returns (M, B, classes) logits.
    """
    slab = halo_exchange.collective_pull(store_bare(store), sdata["send"],
                                         sdata["recv"], halo_size, mesh)
    m_parts, s_rows = sdata["in_nbr"].shape[:2]
    srows = s_rows + 1
    hidden = store["data"].shape[-1]
    loc = store["data"][0].reshape(m_parts, srows, hidden)
    loc_scale = (store["scale"][0].reshape(m_parts, srows, 1)
                 if "scale" in store else None)

    qc = jnp.minimum(q_rows, s_rows - 1)                    # (M, B)
    take = jax.vmap(lambda a, i: a[i])
    in_nbr = take(sdata["in_nbr"], qc)
    out_nbr = take(sdata["out_nbr"], qc)
    side_in = {"nbr": in_nbr, "wts": take(sdata["in_wts"], qc),
               "valid": in_nbr < s_rows, "data": loc}
    side_out = {"nbr": out_nbr, "wts": take(sdata["out_wts"], qc),
                "valid": out_nbr < halo_size, "data": slab["data"][:, 0]}
    if loc_scale is not None:
        side_in["scale"] = loc_scale
        side_out["scale"] = slab["scale"][:, 0]
        h_self = jax.vmap(halo_gather)(qc, loc, loc_scale)
    else:
        h_self = jax.vmap(lambda i, d: halo_gather(i, d))(qc, loc)

    p = params[f"layer_{cfg.num_layers - 1}"]
    return jax.vmap(
        lambda hs, si, so: _batch_top_layer(cfg, scfg, p, hs, [si, so])
    )(h_self, side_in, side_out)


def serve_shardings(store: dict, sdata: dict, mesh, axis: str = "data"):
    """(store, sdata, q_rows) NamedShardings for the SPMD query step:
    store slot-sharded over the exchange axes (version replicated), the
    PullPlan tables by their leading owner/requester axis, per-part
    arrays by the part axis, params replicated by the caller."""
    axes = halo_exchange.exchange_axes(mesh, axis)
    mdim = axes if len(axes) > 1 else axes[0]
    rep = NamedSharding(mesh, P())
    slot = NamedSharding(mesh, P(None, mdim, None))
    store_sh = {"data": slot, "version": rep}
    if "scale" in store:
        store_sh["scale"] = slot
    plan_sh = NamedSharding(mesh, P(mdim, None, None))
    m_sh = NamedSharding(mesh, P(mdim))
    sdata_sh = {k: (plan_sh if k in ("send", "recv") else m_sh)
                for k in sdata}
    return store_sh, sdata_sh, NamedSharding(mesh, P(mdim, None))


# ---------------------------------------------------------------------------
# Workload synthesis (host-side)
# ---------------------------------------------------------------------------

def zipf_queries(num_nodes: int, batch_size: int, num_batches: int,
                 skew: float = 1.1, *, seed: int = 0,
                 hot_ids: Optional[np.ndarray] = None) -> np.ndarray:
    """(num_batches, batch_size) int32 Zipf(``skew``) query stream.

    Rank r is drawn with probability ∝ r^-skew; ``hot_ids`` optionally
    maps popularity rank → node id (e.g. nodes sorted by descending
    degree, so hubs are hottest — the realistic correlation for social /
    recommendation traffic).  Identity by default.
    """
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, num_nodes + 1, dtype=np.float64)
    prob = ranks ** -float(skew)
    prob /= prob.sum()
    draws = rng.choice(num_nodes, size=(num_batches, batch_size), p=prob)
    if hot_ids is not None:
        draws = np.asarray(hot_ids, np.int64)[draws]
    return draws.astype(np.int32)
