"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
combination on the production mesh with ShapeDtypeStruct stand-ins (no
allocation), and extract the roofline terms.

MUST be run as its own process (`python -m repro.launch.dryrun ...`) — the
XLA_FLAGS assignment below executes before any jax import so jax sees 512
placeholder devices. Do NOT import this module from tests/benches.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --multi-pod both \
      --out results/dryrun.jsonl
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re
import subprocess
import sys
import time
import traceback

import jax

from repro.configs import ALIASES, ARCH_IDS, get_arch
from repro.distributed.sharding import axis_rules, shardings_for_specs
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS,
                               make_production_mesh)
from repro.launch.specs import (SHAPES, abstract_from_specs,
                                batch_logical_axes, input_specs,
                                serve_state_specs, train_state_specs)
from repro.models.transformer import forward
from repro.train.trainer import (TrainSettings, make_serve_step,
                                 make_train_step)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
from repro.launch.hlo_census import COLLECTIVES as _COLLECTIVES
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def cost_properties(compiled) -> dict:
    """jax-version compat: ``Compiled.cost_analysis()`` returns a dict on
    jax ≥ 0.5 but a one-element list of dicts on 0.4.x jaxlib."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def _groups_cross_pod(line: str, pod_boundary: int) -> bool:
    """True if any replica group / permute pair spans devices on both
    sides of ``pod_boundary`` — i.e. the collective rides the slow
    inter-pod link.  Group parsing (incl. collective-permute's
    ``source_target_pairs``: the two-stage halo exchange's inter-pod
    hop is exactly such an op, and it must show up in the inter-pod
    byte split) is shared with tests/hlo_utils via
    ``repro.launch.hlo_census``."""
    from repro.launch.hlo_census import groups_cross_boundary, op_groups

    groups = op_groups(line)
    return bool(groups) and groups_cross_boundary(groups, pod_boundary)


def collective_bytes(hlo_text: str, pod_boundary: int = 0) -> dict:
    """Sum result-shape bytes of every collective op in the HLO.

    ``pod_boundary`` > 0 additionally splits the total into intra-pod vs
    inter-pod bytes by replica-group analysis (devices [0, boundary) =
    pod 0)."""
    from repro.launch.hlo_census import match_collective

    totals = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    inter_pod = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # Shared op matching (-done lines skipped, counted at -start) —
        # the test census must agree line for line.
        op = match_collective(stripped)
        if op is None:
            continue
        lhs = stripped.split("=")[1] if "=" in stripped else stripped
        lhs = lhs.split(f" {op}")[0]
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(lhs):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        totals[op] += nbytes
        counts[op] += 1
        if pod_boundary and _groups_cross_pod(stripped, pod_boundary):
            inter_pod += nbytes
    totals_all = sum(totals.values())
    return {"per_op": totals, "counts": counts, "total": totals_all,
            "inter_pod": inter_pod}


def _rules_for(cfg, mesh, overrides: dict | None = None) -> dict:
    rules = {"embed": "data"}          # FSDP: shard big params over data
    rules.update(overrides or {})
    return rules


def _lower_case(cfg, shape_name: str, mesh, rules, sync_mode: str):
    sh = SHAPES[shape_name]
    kind = sh["kind"]
    n_pod = mesh.shape.get("pod", 1)
    with axis_rules(mesh, rules):
        batch_abs = input_specs(cfg, shape_name)
        b_axes = batch_logical_axes(cfg, shape_name)
        batch_sh = {
            k: shardings_for_specs(
                _axes_spec(v, b_axes[k]), mesh, rules)
            for k, v in batch_abs.items()}

        if kind == "train":
            digest = sync_mode == "digest" and n_pod > 1
            # NOTE: pod_impl="shard_map" (the cleaner production form)
            # trips an XLA SPMD-partitioner CHECK
            # (spmd_partitioner_util.cc:504 partition_group_list) on the
            # CPU backend at 512 devices — documented in EXPERIMENTS §Perf;
            # the vmap form lowers everywhere.
            settings = TrainSettings(
                sync_mode="digest" if digest else "every_step",
                n_pod=n_pod if digest else 1, sync_interval=10,
                pod_impl="vmap", total_steps=10_000)
            step_fn = make_train_step(cfg, settings)
            state_specs = train_state_specs(cfg, n_pod=settings.n_pod,
                                            digest_pods=digest)
            state_abs = abstract_from_specs(state_specs)
            state_sh = shardings_for_specs(state_specs, mesh, rules)
            # Donate the train state: params/opt buffers are reused for
            # the outputs (in-place update), as a real trainer would.
            jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_abs, batch_abs)
        elif kind == "prefill":
            def prefill(params, batch):
                return forward(cfg, params, batch["tokens"],
                               batch.get("vision"))
            state_specs = serve_state_specs(cfg, shape_name)["params"]
            state_abs = abstract_from_specs(state_specs)
            state_sh = shardings_for_specs(state_specs, mesh, rules)
            jitted = jax.jit(prefill, in_shardings=(state_sh, batch_sh))
            lowered = jitted.lower(state_abs, batch_abs)
        else:
            long = kind == "decode_long"
            serve = make_serve_step(cfg, long=long)
            ss = serve_state_specs(cfg, shape_name)
            state_abs = abstract_from_specs(ss)
            state_sh = shardings_for_specs(ss, mesh, rules)
            jitted = jax.jit(
                lambda params, cache, batch:
                serve(params, cache, batch["tokens"]),
                in_shardings=(state_sh["params"], state_sh["cache"],
                              batch_sh))
            lowered = jitted.lower(state_abs["params"], state_abs["cache"],
                                   batch_abs)
    return lowered


def dryrun_case(arch: str, shape_name: str, multi_pod: bool,
                rules_override: dict | None = None,
                sync_mode: str = "digest",
                skip_unrolled: bool = False,
                cfg_overrides: dict | None = None) -> dict:
    """Lower + compile one (arch × shape × mesh) case, twice:

    * scanned layers → fast compile; ``memory_analysis`` (capacity / "does
      it fit" — the loop reuses buffers, so temp size is the real live set);
    * unrolled layers → true ``cost_analysis``/collective traffic (XLA
      counts while-loop bodies once, so the scanned HLO under-reports
      FLOPs/bytes/collectives by ~num_layers ×).
    """
    import dataclasses as _dc
    base = get_arch(arch)
    if cfg_overrides:
        base = _dc.replace(base, **cfg_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rules = _rules_for(base, mesh, rules_override)

    out = {
        "arch": base.name, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
    }

    pod_boundary = (mesh.devices.size // mesh.shape["pod"]
                    if "pod" in mesh.axis_names else 0)

    # Pass 1: scanned — memory fit.
    t0 = time.perf_counter()
    cfg_scan = _dc.replace(base, scan_layers=True)
    compiled = _lower_case(cfg_scan, shape_name, mesh, rules,
                           sync_mode).compile()
    out["t_compile_scan_s"] = round(time.perf_counter() - t0, 2)
    mem = compiled.memory_analysis()
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes"):
            if hasattr(mem, attr):
                out[f"mem_{attr}"] = int(getattr(mem, attr))

    # Pass 2: unrolled — true per-device traffic for the roofline.
    if skip_unrolled:
        cost = cost_properties(compiled)
        coll = collective_bytes(compiled.as_text(), pod_boundary)
        scale = float(base.repeats)  # approximate loop-body rescale
        flops = float(cost.get("flops", 0.0)) * scale
        bytes_acc = float(cost.get("bytes accessed", 0.0)) * scale
        coll_total = coll["total"] * scale
        out["cost_basis"] = "scan_rescaled"
    else:
        t1 = time.perf_counter()
        cfg_unroll = _dc.replace(base, scan_layers=False)
        compiled_u = _lower_case(cfg_unroll, shape_name, mesh, rules,
                                 sync_mode).compile()
        out["t_compile_unroll_s"] = round(time.perf_counter() - t1, 2)
        cost = cost_properties(compiled_u)
        coll = collective_bytes(compiled_u.as_text(), pod_boundary)
        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        coll_total = coll["total"]
        out["cost_basis"] = "unrolled"
        out["collective_per_op"] = coll["per_op"]
        out["collective_counts"] = coll["counts"]

    out.update({
        "hlo_flops": flops,
        "hlo_bytes": bytes_acc,
        "collective_bytes": coll_total,
        "inter_pod_bytes": coll.get("inter_pod", 0),
        # Roofline terms (seconds). cost_analysis and the HLO text are the
        # PER-DEVICE partitioned module (verified empirically: a matmul
        # sharded 8-ways reports 1/8 the FLOPs), so each term divides by a
        # single chip's peak, not by the fleet.
        "compute_term_s": flops / PEAK_FLOPS,
        "memory_term_s": bytes_acc / HBM_BW,
        "collective_term_s": coll_total / ICI_BW,
    })
    return out


def _axes_spec(sds, axes):
    from repro.nn.params import ParamSpec
    return ParamSpec(tuple(sds.shape), tuple(axes), init="zeros",
                     dtype=sds.dtype)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all",
                    choices=list(SHAPES) + ["all"])
    ap.add_argument("--multi-pod", dest="multi_pod", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--sync-mode", default="digest",
                    choices=["digest", "every_step"])
    ap.add_argument("--rules", default="{}",
                    help='JSON logical-rule overrides, e.g. {"embed":null}')
    ap.add_argument("--cfg", default="{}",
                    help='JSON ArchConfig overrides, e.g. '
                         '{"remat":false,"param_dtype":"bfloat16"}')
    ap.add_argument("--out", default=None, help="append JSONL here")
    ap.add_argument("--skip-unrolled", action="store_true",
                    help="skip the unrolled pass; rescale scan costs by "
                         "repeats (for compile-time-prohibitive cases)")
    ap.add_argument("--subprocess-each", action="store_true",
                    help="isolate every case in its own process")
    args = ap.parse_args()

    archs = ([ALIASES.get(args.arch, args.arch)] if args.arch != "all"
             else ARCH_IDS)
    shapes = [args.shape] if args.shape != "all" else list(SHAPES)
    pods = {"single": [False], "multi": [True],
            "both": [False, True]}[args.multi_pod]
    rules_override = json.loads(args.rules)
    cfg_overrides = json.loads(args.cfg)
    cfg_overrides = {k: (tuple(v) if isinstance(v, list) else v)
                     for k, v in cfg_overrides.items()}

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                if args.subprocess_each:
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape,
                           "--multi-pod", "multi" if mp else "single",
                           "--sync-mode", args.sync_mode,
                           "--rules", args.rules, "--cfg", args.cfg]
                    if args.out:
                        cmd += ["--out", args.out]
                    rc = subprocess.call(cmd)
                    failures += rc != 0
                    continue
                try:
                    res = dryrun_case(arch, shape, mp,
                                      rules_override=rules_override,
                                      sync_mode=args.sync_mode,
                                      skip_unrolled=args.skip_unrolled,
                                      cfg_overrides=cfg_overrides)
                    res["rules_override"] = rules_override
                    res["cfg_overrides"] = cfg_overrides
                    res["sync_mode"] = args.sync_mode
                    line = json.dumps(res)
                    print(line, flush=True)
                    if args.out:
                        with open(args.out, "a") as f:
                            f.write(line + "\n")
                except Exception:
                    failures += 1
                    print(f"FAILED {arch} {shape} multi_pod={mp}",
                          file=sys.stderr)
                    traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
