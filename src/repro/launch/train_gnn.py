#!/usr/bin/env python
"""SPMD DIGEST GNN training launcher.

The DIGEST epoch function is written over stacked (M, ...) subgraph arrays;
under pjit we shard that leading M axis over the mesh "data" axis — one
subgraph per device slice, which *is* Algorithm 1's `for m in parallel`.
On CPU (1 device) the same program runs vmapped; on a fleet, identical code.

  PYTHONPATH=src python -m repro.launch.train_gnn --dataset flickr-sim \
      --parts 4 --epochs 40
"""
from __future__ import annotations

import argparse
import time

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import HaloPrecision, HaloSpec, TrainSettings, evaluate, \
    init_state, make_epoch_fn, prepare_graph_data
from repro.graph import make_dataset
from repro.launch.mesh import make_host_mesh
from repro.models.gnn import GNNConfig
from repro.optim import adam


def subgraph_shardings(data: dict, state: dict, mesh) -> tuple[dict, dict]:
    """Shard every stacked (M, ...) array over the mesh's halo-exchange
    axes — the "data" axis alone, or the combined ("pod", "data") axes
    when the mesh carries a pod axis (the multi-pod production layout;
    device (p, d) then holds subgraph/shard block e = p·data + d).  The
    compact HaloExchange store is owner-sharded slot-wise (the
    partitioner groups slots by owning part, so each device holds
    exactly the boundary rows it pushes) and the pulled halo slabs
    (``state["cache"]``) are device-local, sharded over their leading
    subgraph axis — nothing about the stale state is replicated; pull
    epochs pay the §3.3 wire cost once.  Params/opt replicated (GNN
    weights are tiny)."""
    from repro.core.halo_exchange import exchange_axes

    axes = exchange_axes(mesh)
    mdim = axes if len(axes) > 1 else axes[0]
    rep = NamedSharding(mesh, P())
    m_shard = NamedSharding(mesh, P(mdim))
    slot_shard = NamedSharding(mesh, P(None, mdim, None))
    slab_shard = NamedSharding(mesh, P(mdim, None, None, None))

    data_sh = {}
    for k, v in data.items():
        if k.startswith("_"):
            continue
        if k in ("x_global", "store_ids") or k.startswith("full_"):
            data_sh[k] = jax.tree.map(lambda _: rep, v)
        elif k in ("pull_send", "pull_recv"):
            # PullPlan routing: leading axis is the owner/requester part.
            data_sh[k] = NamedSharding(mesh, P(mdim, None, None))
        elif k == "struct":
            data_sh[k] = {kk: m_shard for kk in v}
        else:
            data_sh[k] = m_shard
    state_sh = {
        "params": jax.tree.map(lambda _: rep, state["params"]),
        "opt_state": jax.tree.map(lambda _: rep, state["opt_state"]),
        "store": jax.tree.map(lambda _: slot_shard, state["store"]),
        "cache": jax.tree.map(lambda _: slab_shard, state["cache"]),
        "epoch": rep, "step": rep,
    }
    if "push_residual" in state:
        state_sh["push_residual"] = slab_shard
    if "pstore" in state:
        # SAT predictor leaves (repro.core.predictor): the pstore is
        # owner-sharded exactly like the store, the pulled pcache slab
        # device-local like the cache, and the push-side history rides
        # the push buffers' (M, ...) layout (count is per-part).
        state_sh["pstore"] = jax.tree.map(lambda _: slot_shard,
                                          state["pstore"])
        state_sh["predictor"] = {"prev": slab_shard, "ema": slab_shard,
                                 "coef": NamedSharding(mesh, P(mdim, None)),
                                 "count": m_shard}
        if "pcache" in state:
            state_sh["pcache"] = jax.tree.map(lambda _: slab_shard,
                                              state["pcache"])
    if "hist" in state:
        # Control-variate history (M, L-1, S, hidden): each device keeps
        # its own subgraphs' last-step representations — never exchanged.
        state_sh["hist"] = slab_shard
    if "push_ok" in state:
        # Fault-aware leaves (repro.core.faults.attach_fault_state):
        # per-shard (M,) push mask + last-push age table — sharded like
        # the subgraphs they gate.
        state_sh["push_ok"] = m_shard
        state_sh["last_push_round"] = m_shard
    return data_sh, state_sh


def batch_shardings(mesh) -> dict:
    """Shardings for one sampler batch (``NeighborSampler.sample``):
    every array is stacked (M, ...) like the subgraph data, so it shards
    over the same halo-exchange axes — each device receives only its own
    subgraphs' seed masks and edge samples."""
    from repro.core.halo_exchange import exchange_axes

    axes = exchange_axes(mesh)
    mdim = axes if len(axes) > 1 else axes[0]
    m_shard = NamedSharding(mesh, P(mdim))
    return {k: m_shard for k in ("seed_mask", "edge_scale", "edge_keep")}


def _push_ok(schedule, rnd: int, num_parts: int):
    import jax.numpy as jnp
    import numpy as np
    ok = (schedule.push_ok(rnd, num_parts) if schedule is not None
          else np.ones(num_parts, dtype=bool))
    return jnp.asarray(ok)


def _maybe_resume(args, state) -> int:
    """Epoch/step to start from: the newest valid checkpoint's, or 0."""
    if not args.resume:
        return 0
    from repro.checkpoint import latest_step
    step = latest_step(args.ckpt_dir)
    if step is None:
        print(f"resume: no valid checkpoint in {args.ckpt_dir}, "
              f"starting fresh")
        return 0
    return int(step)


def _restore(args, state):
    from repro.checkpoint import restore_checkpoint
    state, step = restore_checkpoint(args.ckpt_dir, state)
    print(f"resume: restored step {step} from {args.ckpt_dir}")
    return state, step


def _maybe_ckpt(args, step: int, state) -> None:
    if args.ckpt_dir and args.ckpt_every and step % args.ckpt_every == 0:
        from repro.checkpoint import save_checkpoint
        save_checkpoint(args.ckpt_dir, step, state)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="flickr-sim")
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--model", default="gcn")
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=40)
    ap.add_argument("--interval", type=int, default=10)
    ap.add_argument("--precision", default="fp32",
                    choices=("fp32", "bf16", "int8"),
                    help="HaloExchange wire/storage precision")
    ap.add_argument("--error-feedback", action="store_true",
                    help="accumulate int8/bf16 rounding residual at the "
                         "pusher (unbiased repeated pushes)")
    ap.add_argument("--pull", default="gather",
                    choices=("gather", "collective"),
                    help="PULL transport: dense gather (XLA all-gather "
                         "fallback; any device count) or the fully-SPMD "
                         "shard_map path — ragged all_to_all pulls plus "
                         "shard-local pushes (two-stage intra-pod + "
                         "inter-pod exchange when --pods > 1); needs "
                         "--parts to be a multiple of pods x data-axis "
                         "(k = parts/devices subgraphs and owner shards "
                         "per device)")
    ap.add_argument("--data-axis", type=int, default=1,
                    help="mesh data-axis size (1 on CPU)")
    ap.add_argument("--pods", type=int, default=1,
                    help="mesh pod-axis size; > 1 builds the multi-pod "
                         "('pod', 'data') mesh — collective mode then "
                         "runs the two-stage intra-pod all_to_all + "
                         "inter-pod ppermute exchange and needs --parts "
                         "to be a multiple of pods x data-axis")
    ap.add_argument("--halo-weight", type=float, default=0.0,
                    help="boundary-aware partitioning: weight of the "
                         "marginal-new-halo-rows term in the greedy "
                         "streaming score (0 = classic edge-cut LDG)")
    ap.add_argument("--order", default="none", choices=("none", "rcm"),
                    help="local-row layout: 'rcm' reorders each part's "
                         "rows by reverse Cuthill-McKee (halo slab runs "
                         "re-laid to match) so 128-row blocks reference "
                         "clustered slab chunks — lower worklist "
                         "occupancy, same math (pure row permutation)")
    ap.add_argument("--backend", default="jnp",
                    choices=("jnp", "auto", "pallas"),
                    help="aggregation kernel backend: 'jnp' reference "
                         "(CPU default), 'auto' picks the Pallas kernels "
                         "on TPU hosts, 'pallas' forces them — the "
                         "streaming/skip knobs below act on the Pallas "
                         "paths (the jnp oracle has no DMA to schedule)")
    ap.add_argument("--stream-chunk-rows", type=int, default=None,
                    help="slab rows per streamed halo_spmm chunk "
                         "(default: kernel STREAM_CHUNK_ROWS; also sets "
                         "the precomputed worklist geometry)")
    ap.add_argument("--resident-max-bytes", type=int, default=None,
                    help="VMEM budget above which halo_spmm streams the "
                         "slab (default: kernel RESIDENT_STRIPE_MAX_BYTES)")
    ap.add_argument("--skip-occupancy-max", type=float, default=None,
                    help="highest measured (row-block x chunk) occupancy "
                         "at which the chunk-skipping stream is selected "
                         "over the dense stream (default: kernel "
                         "SKIP_OCCUPANCY_MAX; >=1 forces it whenever "
                         "streaming)")
    ap.add_argument("--sampling", action="store_true",
                    help="mini-batch sampled training: fanout-bounded "
                         "neighbor sampling with stale-store control "
                         "variates (out-of-batch neighbors read the "
                         "HaloExchange store / local history as the "
                         "variance-reduction baseline); --epochs then "
                         "counts optimizer steps")
    ap.add_argument("--fanout", type=int, default=5,
                    help="sampled in-neighbors per row (rows with "
                         "deg <= fanout aggregate exactly)")
    ap.add_argument("--batch-seeds", type=int, default=512,
                    help="training seed rows per subgraph per step")
    ap.add_argument("--estimator", default="cv", choices=("cv", "plain"),
                    help="'cv' = VR-GCN control variates over the stale "
                         "store; 'plain' = scaled-sample-only neighbor "
                         "sampling (the variance-ablation control)")
    ap.add_argument("--predictor", default="none",
                    choices=("none", "delta", "ema"),
                    help="SAT staleness-alleviated prediction "
                         "(repro.core.predictor): serve dequant(store) "
                         "+ gamma*dequant(pstore) where the pstore "
                         "carries each row's last-sync delta ('delta') "
                         "or its beta-EMA ('ema'); 'none' compiles the "
                         "bitwise-identical predictor-free program")
    ap.add_argument("--predictor-gamma", type=float, default=1.0,
                    help="pull-time extrapolation coefficient gamma "
                         "(1.0 with 'delta' = linear extrapolation)")
    ap.add_argument("--predictor-beta", type=float, default=0.5,
                    help="EMA weight of the newest delta "
                         "(--predictor ema only)")
    ap.add_argument("--no-gat-dedup", action="store_true",
                    help="disable the GAT owner-shard projection dedup "
                         "(legacy per-subgraph halo projection)")
    ap.add_argument("--fault-crash-rate", type=float, default=0.0,
                    help="deterministic fault injection: per-(round, "
                         "worker) probability a shard's owner is inside "
                         "a crash window (its pushes are lost for "
                         "crash_rounds rounds; store keeps last-known-"
                         "good rows)")
    ap.add_argument("--fault-drop-rate", type=float, default=0.0,
                    help="probability a push round's wire transfer is "
                         "dropped for a shard")
    ap.add_argument("--fault-corrupt-rate", type=float, default=0.0,
                    help="probability a push payload is corrupted in "
                         "flight and CRC-rejected by the receiver "
                         "(observable effect = a drop)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed of the FaultSchedule (decisions are a "
                         "pure function of (seed, class, round, part) — "
                         "replayable)")
    ap.add_argument("--max-staleness", type=int, default=None,
                    help="bounded-staleness watchdog: force-push any "
                         "shard whose last accepted push is this many "
                         "rounds old (Theorem-1/3 bound under faults)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="directory for atomic checksummed checkpoints "
                         "of the full training state")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint every N epochs/steps (0 = never)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest VALID checkpoint from "
                         "--ckpt-dir (partial/corrupt ones are skipped) "
                         "and continue to --epochs")
    args = ap.parse_args()
    if args.resume and not args.ckpt_dir:
        ap.error("--resume needs --ckpt-dir")

    g = make_dataset(args.dataset, scale=args.scale)
    t_part = time.perf_counter()
    data = prepare_graph_data(g, args.parts, halo_weight=args.halo_weight,
                              stream_chunk_rows=args.stream_chunk_rows,
                              order=args.order)
    t_part = time.perf_counter() - t_part
    print(f"partition: {args.parts} parts, order={args.order}, "
          f"halo_weight={args.halo_weight} built in {t_part:.2f}s "
          f"({g.num_nodes} nodes, {len(g.indices) // 2} edges)")
    cfg = GNNConfig(model=args.model, num_layers=3,
                    in_dim=g.features.shape[1], hidden_dim=64,
                    num_classes=int(g.labels.max()) + 1,
                    backend=args.backend,
                    stream_chunk_rows=args.stream_chunk_rows,
                    resident_max_bytes=args.resident_max_bytes,
                    skip_occupancy_max=args.skip_occupancy_max,
                    halo_occupancy=data["_worklist"].occupancy,
                    gat_halo_dedup=not args.no_gat_dedup)
    opt = adam(5e-3)
    from repro.core import PredictorConfig
    predictor = PredictorConfig(kind=args.predictor,
                                gamma=args.predictor_gamma,
                                beta=args.predictor_beta)
    settings = TrainSettings(
        sync_interval=args.interval, mode="digest", pull_mode=args.pull,
        precision=HaloPrecision(args.precision,
                                error_feedback=args.error_feedback),
        sample_estimator=args.estimator,
        max_staleness=args.max_staleness,
        predictor=predictor)
    if predictor.enabled:
        print(f"predictor: kind={predictor.kind} gamma={predictor.gamma} "
              f"beta={predictor.beta}")
    from repro.core import faults as faults_mod
    schedule = faults_mod.check_schedule(faults_mod.FaultConfig(
        seed=args.fault_seed, crash_rate=args.fault_crash_rate,
        drop_push_rate=args.fault_drop_rate,
        corrupt_rate=args.fault_corrupt_rate))
    fault_aware = schedule is not None or args.max_staleness is not None
    if schedule is not None:
        print(f"faults: crash={args.fault_crash_rate} "
              f"drop={args.fault_drop_rate} "
              f"corrupt={args.fault_corrupt_rate} seed={args.fault_seed} "
              f"max_staleness={args.max_staleness}")
    mesh = make_host_mesh(data=args.data_axis, model=1, pod=args.pods)
    if args.pull == "collective":
        # Fail fast with the M-vs-mesh mismatch spelled out (the epoch
        # would raise the same error at trace time).  Counts every
        # exchange axis: pods x data on a multi-pod mesh.
        from repro.core import check_collective_geometry
        ppd = check_collective_geometry(data, mesh)
        print(f"collective mode: {ppd} subgraph(s)/owner shard(s) "
              f"per device over {dict(mesh.shape)}")

    tdata = {k: v for k, v in data.items() if not k.startswith("_")}
    sp = data["_sp"]
    spec = HaloSpec.from_partitions(sp, cfg.hidden_dim, cfg.num_layers,
                                    settings.precision)
    if args.sampling:
        from repro.core import init_sampled_state, make_sampled_epoch_fn
        from repro.graph import build_sampler

        sampler = build_sampler(data, args.fanout, args.batch_seeds)
        print(f"sampling: fanout={args.fanout} (max in-degree "
              f"{sampler.max_in_degree}), batch_seeds={args.batch_seeds}, "
              f"estimator={args.estimator}")
        state = init_sampled_state(cfg, opt, data,
                                   precision=settings.precision,
                                   predictor=settings.predictor)
        if fault_aware:
            state = faults_mod.attach_fault_state(state, args.parts)
        start = _maybe_resume(args, state)
        if start:
            state, _ = _restore(args, state)
        data_sh, state_sh = subgraph_shardings(tdata, state, mesh)
        step_fn = jax.jit(
            make_sampled_epoch_fn(cfg, opt, settings, mesh=mesh),
            in_shardings=(state_sh, data_sh, batch_shardings(mesh)))
        t0 = time.perf_counter()
        m = {"loss": float("nan")}
        for t in range(start, args.epochs):
            if fault_aware:
                state["push_ok"] = _push_ok(schedule, t + 1, args.parts)
            batch = {k: jax.numpy.asarray(v)
                     for k, v in sampler.sample(t).items()}
            state, m = step_fn(state, tdata, batch)
            _maybe_ckpt(args, t + 1, state)
        ev = evaluate(cfg, state["params"], tdata)
    else:
        state = init_state(cfg, opt, data, precision=settings.precision,
                           predictor=settings.predictor)
        if fault_aware:
            state = faults_mod.attach_fault_state(state, args.parts)
        start = _maybe_resume(args, state)
        if start:
            state, _ = _restore(args, state)
        data_sh, state_sh = subgraph_shardings(tdata, state, mesh)
        epoch_fn = jax.jit(make_epoch_fn(cfg, opt, settings, mesh=mesh),
                           in_shardings=(state_sh, data_sh))
        t0 = time.perf_counter()
        m = {"loss": float("nan")}
        for e in range(start, args.epochs):
            if fault_aware:
                state["push_ok"] = _push_ok(schedule, e + 1, args.parts)
            state, m = epoch_fn(state, tdata)
            _maybe_ckpt(args, e + 1, state)
        ev = evaluate(cfg, state["params"], tdata)
    if fault_aware and "last_push_round" in state:
        import numpy as np
        age = int(state["epoch"]) - np.asarray(state["last_push_round"])
        print(f"fault staleness: max push age {int(age.max())} round(s) "
              f"(bound {args.max_staleness})")
    sync = spec.comm_bytes(sp.pull_rows(), sp.push_rows())
    wl = data["_worklist"]
    print(f"mesh={dict(mesh.shape)} epochs={args.epochs} "
          f"loss={float(m['loss']):.4f} val_f1={float(ev['val_f1']):.4f} "
          f"({(time.perf_counter()-t0)/args.epochs:.3f}s/epoch)")
    print(f"halo worklist: {wl.visited_chunks}/{wl.total_pairs} "
          f"(row-block x chunk) pairs occupied "
          f"({100 * wl.occupancy:.1f}%; chunk_rows={wl.chunk_rows})")
    print(f"store: {spec.store_nbytes()/1e6:.2f} MB total, "
          f"{spec.shard_nbytes()/1e6:.2f} MB/device; pull/sync: "
          f"sharded {sync['pull_bytes']/1e6:.2f} MB vs replicated "
          f"{spec.replicated_pull_nbytes()/1e6:.2f} MB")


if __name__ == "__main__":
    main()
