"""GNN models (GCN / GraphSAGE / GAT) in DIGEST's split-aggregation form.

Every layer implements Eq. 4/5 of the paper: the aggregation over neighbors
is split into an **in-subgraph** ELL product (fresh representations) and an
**out-of-subgraph** ELL product against whatever halo table the caller
supplies — fresh features (layer 0), *stale* representations (DIGEST),
zeros (partition-based baseline), or fresh remote reps (propagation-based
baseline).  The trainer chooses the table; the model is agnostic, which is
exactly what makes the baseline frameworks share 95% of the code path.

A halo table is either

  * a plain ``(H, d)`` array — per-subgraph tables (propagation baselines,
    direct model tests): aggregated through ``struct["out_nbr"]`` with a
    zero sentinel row appended at H; or
  * a **halo ref** dict ``{"data", "scale", "nbr", "wts"}`` — a shared
    slab (the HaloExchange compact store layer, or ``x_global`` for layer
    0) in storage precision plus the ELL indices *into that slab*.  The
    out-of-subgraph product then runs through the fused pull+aggregate
    kernel (:func:`repro.kernels.spmm.halo_spmm`): no per-subgraph halo
    table is ever materialized, and int8/bf16 rows are dequantized inside
    the kernel.  Under ``jax.vmap`` the slab enters unbatched, so slab-wide
    work (e.g. GAT's halo projection) is computed once, not per subgraph.

Shapes (single subgraph):
  x_local   (S, d)      padded local node features/reps
  x_halo    (H, d)      halo table for this layer's input (legacy form)
  in_nbr    (S, Din)    local slot ids, sentinel == S
  out_nbr   (S, Dout)   halo slot ids, sentinel == H
  ref[nbr]  (S, Dout)   slab row ids, sentinel == ref["data"].shape[0]-1
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels.spmm import halo_spmm, spmm
from repro.nn import ParamSpec, dense

Pytree = Any


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class _StaticScalar:
    """A number carried through a pytree as aux data, not a leaf: it
    survives ``stop_gradient``/``vmap`` untouched and stays a plain
    Python float for the static-argument kernel knobs (``gamma`` keys
    the jit cache through ``halo_spmm``)."""
    value: float


def halo_ref(data: jax.Array, scale: Optional[jax.Array],
             nbr: jax.Array, wts: jax.Array,
             wl_ids: Optional[jax.Array] = None,
             wl_cnt: Optional[jax.Array] = None,
             pdata: Optional[jax.Array] = None,
             pscale: Optional[jax.Array] = None,
             gamma: float = 1.0) -> dict:
    """Bundle a shared halo slab (with sentinel zero row last) + indices.

    ``wl_ids``/``wl_cnt`` optionally carry the (row_block × chunk)
    occupancy worklist of this adjacency against the slab (see
    :class:`repro.graph.partition.ChunkWorklist`), enabling the chunk-
    skipping streamed kernel on the Pallas backends.

    ``pdata``/``pscale``/``gamma`` optionally carry the SAT predictor-
    history slab (``repro.core.predictor``) in the data slab's exact
    layout: the aggregation then reads the staleness-alleviated
    prediction ``dequant(data) + gamma·dequant(pdata)`` per row, fused
    into the kernel's dequant epilogue.  ``gamma`` is a static Python
    float (it keys the jit cache through ``halo_spmm``)."""
    ref = {"data": data, "nbr": nbr, "wts": wts}
    if scale is not None:
        ref["scale"] = scale
    if wl_ids is not None and wl_cnt is not None:
        ref["wl_ids"] = wl_ids
        ref["wl_cnt"] = wl_cnt
    if pdata is not None:
        ref["pdata"] = pdata
        ref["gamma"] = _StaticScalar(float(gamma))
        if pscale is not None:
            ref["pscale"] = pscale
    return ref


def projected_halo_ref(zdata: jax.Array, zscale: Optional[jax.Array],
                       nbr: jax.Array, wts: jax.Array) -> dict:
    """Bundle a *pre-projected* GAT halo table: rows are ``W·h̃`` (flat
    ``heads·head_dim`` wide, sentinel zero row last) computed once per
    owner shard at pull time, so the layer skips its per-subgraph slab
    projection entirely (see ``repro.core.digest`` and the GAT dedup
    notes in this module's layer code)."""
    ref = {"zdata": zdata, "nbr": nbr, "wts": wts}
    if zscale is not None:
        ref["zscale"] = zscale
    return ref


def _as_halo_ref(table, struct: dict) -> dict:
    """Normalize a legacy (H, d) table to the halo-ref form, picking up
    the adjacency's chunk worklist when the struct dict carries one."""
    if isinstance(table, dict):
        return table
    return halo_ref(_pad_sentinel(table), None,
                    struct["out_nbr"], struct["out_wts"],
                    struct.get("wl_ids"), struct.get("wl_cnt"))


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    model: str = "gcn"            # gcn | sage | gat
    num_layers: int = 3
    in_dim: int = 64
    hidden_dim: int = 128
    num_classes: int = 8
    heads: int = 4                # GAT only
    normalize: bool = True        # Algorithm 1 line 11 (L2 per node)
    residual: bool = False
    backend: str = "jnp"          # aggregation backend (jnp | pallas*)
    # -- streamed halo_spmm knobs (static; override the module constants
    # of repro.kernels.spmm.ops — None keeps the kernel defaults) -------
    stream_chunk_rows: Optional[int] = None    # STREAM_CHUNK_ROWS
    resident_max_bytes: Optional[int] = None   # RESIDENT_STRIPE_MAX_BYTES
    skip_occupancy_max: Optional[float] = None  # SKIP_OCCUPANCY_MAX
    # Measured (row_block × chunk) occupancy of the partition's chunk
    # worklist (ChunkWorklist.occupancy) — a host-side float the launcher
    # copies in after building the data; drives skip-vs-dense stream
    # auto-selection.  None disables the skip stream under backend="auto"
    # selection (forced "pallas_skip*" backends still work).
    halo_occupancy: Optional[float] = None
    # GAT: project each owner shard's stale halo rows once per layer at
    # pull time and ship projected rows (True, the dedup path) instead of
    # re-projecting every subgraph's (H+1, d) slab every epoch (False,
    # the legacy ~M×-redundant path, kept for A/B cost comparison).
    gat_halo_dedup: bool = True

    @property
    def layer_dims(self) -> list[tuple[int, int]]:
        dims = []
        for ell in range(self.num_layers):
            din = self.in_dim if ell == 0 else self.hidden_dim
            dout = (self.num_classes if ell == self.num_layers - 1
                    else self.hidden_dim)
            dims.append((din, dout))
        return dims


def _pad_sentinel(x: jax.Array) -> jax.Array:
    """Append the zero sentinel row the ELL kernels gather for padding."""
    return jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)], axis=0)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def gnn_specs(cfg: GNNConfig) -> Pytree:
    specs: dict[str, Any] = {}
    for ell, (din, dout) in enumerate(cfg.layer_dims):
        layer: dict[str, Any] = {}
        if cfg.model == "gcn":
            layer["w"] = ParamSpec((din, dout), ("embed", "embed_out"))
            layer["b"] = ParamSpec((dout,), ("embed_out",), init="zeros")
        elif cfg.model == "sage":
            layer["w_self"] = ParamSpec((din, dout), ("embed", "embed_out"))
            layer["w_nbr"] = ParamSpec((din, dout), ("embed", "embed_out"))
            layer["b"] = ParamSpec((dout,), ("embed_out",), init="zeros")
        elif cfg.model == "gat":
            heads = cfg.heads if ell < cfg.num_layers - 1 else 1
            if dout % heads:
                raise ValueError(f"layer {ell}: dout {dout} % heads {heads}")
            dh = dout // heads
            layer["w"] = ParamSpec((din, heads, dh),
                                   ("embed", "heads", "head_dim"),
                                   fan_in_dims=(0,))
            layer["a_src"] = ParamSpec((heads, dh), ("heads", "head_dim"),
                                       init="normal")
            layer["a_dst"] = ParamSpec((heads, dh), ("heads", "head_dim"),
                                       init="normal")
            layer["b"] = ParamSpec((dout,), ("embed_out",), init="zeros")
        else:
            raise ValueError(cfg.model)
        specs[f"layer_{ell}"] = layer
    return specs


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------

def _halo_agg(cfg, ref: dict, wts: jax.Array) -> jax.Array:
    """Out-of-subgraph fused pull+aggregate with the config's streaming
    knobs (chunk size, VMEM budget, occupancy-driven chunk skipping)
    threaded into the kernel selection in repro.kernels.spmm.ops."""
    g = ref.get("gamma")
    return halo_spmm(ref["nbr"], wts, ref["data"], ref.get("scale"),
                     wl_ids=ref.get("wl_ids"), wl_cnt=ref.get("wl_cnt"),
                     pdata=ref.get("pdata"), pscale=ref.get("pscale"),
                     gamma=g.value if g is not None else 1.0,
                     backend=cfg.backend,
                     resident_max_bytes=cfg.resident_max_bytes,
                     chunk_rows=cfg.stream_chunk_rows,
                     occupancy=cfg.halo_occupancy,
                     skip_occupancy_max=cfg.skip_occupancy_max)


def _gcn_layer(cfg, p, x_local, x_halo, struct) -> jax.Array:
    ref = _as_halo_ref(x_halo, struct)
    agg = spmm(struct["in_nbr"], struct["in_wts"], _pad_sentinel(x_local),
               backend=cfg.backend)
    agg = agg + _halo_agg(cfg, ref, ref["wts"])
    return dense(agg, p["w"], p["b"])


def _sage_layer(cfg, p, x_local, x_halo, struct) -> jax.Array:
    # Mean aggregator: row-normalize the (GCN) weights to a mean.
    ref = _as_halo_ref(x_halo, struct)
    in_w, out_w = struct["in_wts"], ref["wts"]
    denom = jnp.sum(in_w, axis=1, keepdims=True) + jnp.sum(
        out_w, axis=1, keepdims=True)
    denom = jnp.maximum(denom, 1e-12)
    agg = spmm(struct["in_nbr"], in_w / denom, _pad_sentinel(x_local),
               backend=cfg.backend)
    agg = agg + _halo_agg(cfg, ref, out_w / denom)
    return (dense(x_local, p["w_self"]) + dense(agg, p["w_nbr"]) + p["b"])


def _multihead_spmm(nbr, att, z_pad, backend):
    """(S, D, heads) attention × (T, heads, dh) tables → (S, heads·dh).

    One batched aggregation (vmap over the head axis) instead of a Python
    loop of per-head spmm calls — compiles to a single kernel launch per
    adjacency side.
    """
    per_head = jax.vmap(lambda a, z: spmm(nbr, a, z, backend=backend),
                        in_axes=(2, 1), out_axes=1)
    out = per_head(att, z_pad)                    # (S, heads, dh)
    return out.reshape(out.shape[0], -1)


def _gat_layer(cfg, p, x_local, x_halo, struct) -> jax.Array:
    S = x_local.shape[0]
    ref = _as_halo_ref(x_halo, struct)
    heads, dh = p["a_src"].shape
    z_loc = jnp.einsum("sd,dhk->shk", x_local, p["w"])    # (S, heads, dh)
    if "zdata" in ref:
        # Pre-projected halo table (projected_halo_ref): rows are already
        # W·h̃, projected ONCE per owner shard at pull time instead of
        # once per subgraph per epoch — the owner-shard dedup path.  Only
        # the (cheap) attention scores below still use this epoch's
        # a_src.
        z_out = ref["zdata"].astype(jnp.float32)
        if "zscale" in ref:
            z_out = z_out * ref["zscale"]
        T = z_out.shape[0]                        # slab rows incl. sentinel
        z_out = z_out.reshape(T, heads, dh)
    else:
        # Legacy: dequantize the raw halo rows and project here.  When the
        # slab enters vmap unbatched (a shared store slab) this happens
        # once for all subgraphs; with device-local per-subgraph slabs it
        # is the M×-redundant projection the dedup path removes.
        x_out = ref["data"].astype(jnp.float32)
        if "scale" in ref:
            x_out = x_out * ref["scale"]
        if "pdata" in ref:
            # SAT prediction before projection — exact by linearity of W.
            p_out = ref["pdata"].astype(jnp.float32)
            if "pscale" in ref:
                p_out = p_out * ref["pscale"]
            x_out = x_out + jnp.float32(ref["gamma"].value) * p_out
        T = x_out.shape[0]                        # slab rows incl. sentinel
        z_out = jnp.einsum("sd,dhk->shk", x_out, p["w"])  # (T, heads, dh)

    s_dst = jnp.einsum("shk,hk->sh", z_loc, p["a_dst"])   # (S, heads)
    src_loc = jnp.einsum("shk,hk->sh", z_loc, p["a_src"])  # (S, heads)
    src_out = jnp.einsum("shk,hk->sh", z_out, p["a_src"])  # (T, heads)

    def _scores(nbr, src_table, n_cols):
        s_src = jnp.take(src_table, nbr, axis=0)           # (S, D, heads)
        e = jax.nn.leaky_relu(s_dst[:, None, :] + s_src, 0.2)
        valid = (nbr < n_cols)[..., None]
        return jnp.where(valid, e, -1e30), valid

    src_loc_pad = jnp.concatenate(
        [src_loc, jnp.zeros((1, heads), src_loc.dtype)], 0)
    e_in, v_in = _scores(struct["in_nbr"], src_loc_pad, S)
    e_out, v_out = _scores(ref["nbr"], src_out, T - 1)

    m = jnp.maximum(jnp.max(e_in, axis=1), jnp.max(e_out, axis=1))
    m = jax.lax.stop_gradient(m)                           # (S, heads)
    p_in = jnp.exp(e_in - m[:, None, :]) * v_in
    p_out = jnp.exp(e_out - m[:, None, :]) * v_out
    denom = (jnp.sum(p_in, axis=1) + jnp.sum(p_out, axis=1) + 1e-16)
    a_in = p_in / denom[:, None, :]                        # (S, Din, heads)
    a_out = p_out / denom[:, None, :]

    z_loc_pad = jnp.concatenate(
        [z_loc, jnp.zeros((1,) + z_loc.shape[1:], z_loc.dtype)], 0)
    out = _multihead_spmm(struct["in_nbr"], a_in, z_loc_pad, cfg.backend)
    out = out + _multihead_spmm(ref["nbr"], a_out, z_out, cfg.backend)
    return out + p["b"]


_LAYERS = {"gcn": _gcn_layer, "sage": _sage_layer, "gat": _gat_layer}


# ---------------------------------------------------------------------------
# Sampled (control-variate) layer variants — the mini-batch regime
# ---------------------------------------------------------------------------
#
# VR-GCN estimator (arXiv 1710.10568) at the ELL-weight level: with
# edge_scale = deg/n_sampled at sampled entries (0 elsewhere),
#
#   w_fresh = in_wts · edge_scale        (scaled sampled neighbors, fresh)
#   w_resid = in_wts − w_fresh           (everything else, historical)
#   agg_in  = spmm(w_fresh, h) + spmm(w_resid, h̄)
#           = spmm(in_wts, h̄) + Σ_sampled scale·in_wts·(h − h̄)
#
# i.e. history-of-all-neighbors plus the inverse-inclusion-scaled fresh
# minus-stale correction on the sample — unbiased in the sample, and with
# fanout >= deg the scale is exactly 1.0 so w_fresh == in_wts bitwise and
# w_resid == +0.0: the estimator IS the full-batch aggregation.  The
# out-of-subgraph side always reads the stale store (pure history — its
# own control variate), riding the fused halo_spmm path unchanged.

def _cv_weights(in_wts: jax.Array, samp: dict) -> tuple:
    w_fresh = in_wts * samp["edge_scale"]
    return w_fresh, in_wts - w_fresh


def _gcn_layer_cv(cfg, p, x_local, h_hist, x_halo, struct, samp):
    ref = _as_halo_ref(x_halo, struct)
    w_fresh, w_resid = _cv_weights(struct["in_wts"], samp)
    agg = spmm(struct["in_nbr"], w_fresh, _pad_sentinel(x_local),
               backend=cfg.backend)
    agg = agg + spmm(struct["in_nbr"], w_resid, _pad_sentinel(h_hist),
                     backend=cfg.backend)
    agg = agg + _halo_agg(cfg, ref, ref["wts"])
    return dense(agg, p["w"], p["b"])


def _sage_layer_cv(cfg, p, x_local, h_hist, x_halo, struct, samp):
    # Same full-neighborhood mean denominator as _sage_layer: the CV
    # split redistributes the numerator, not the normalization.
    ref = _as_halo_ref(x_halo, struct)
    in_w, out_w = struct["in_wts"], ref["wts"]
    denom = jnp.sum(in_w, axis=1, keepdims=True) + jnp.sum(
        out_w, axis=1, keepdims=True)
    denom = jnp.maximum(denom, 1e-12)
    w_fresh, w_resid = _cv_weights(in_w, samp)
    agg = spmm(struct["in_nbr"], w_fresh / denom, _pad_sentinel(x_local),
               backend=cfg.backend)
    agg = agg + spmm(struct["in_nbr"], w_resid / denom,
                     _pad_sentinel(h_hist), backend=cfg.backend)
    agg = agg + _halo_agg(cfg, ref, out_w / denom)
    return (dense(x_local, p["w_self"]) + dense(agg, p["w_nbr"]) + p["b"])


def sampled_struct(struct: dict, samp: dict, sentinel: int) -> dict:
    """GAT fallback view: unsampled in-ELL entries remapped to the zero
    sentinel, so the layer runs full attention over the sampled rows only
    (attention renormalizes per destination — no inclusion scaling; and
    no control variate, since the nonlinear score has no additive
    history decomposition).  With fanout >= deg this is the identity
    remap: unsampled entries are exactly the sentinel entries already."""
    out = dict(struct)
    out["in_nbr"] = jnp.where(samp["edge_keep"], struct["in_nbr"],
                              sentinel)
    return out


def gnn_layer(cfg: GNNConfig, layer_params: Pytree, x_local: jax.Array,
              x_halo, struct: dict) -> jax.Array:
    """Run ONE split-aggregation layer — the public single-layer entry.

    ``layer_params`` is one ``params[f"layer_{ell}"]`` subtree; the rest
    of the contract matches the per-layer step inside
    :func:`gnn_forward` (x_halo is a plain table or a halo ref).  The
    serving path (``repro.core.serving``) uses this to run just the top
    layer over rows read back from the owner-sharded store, instead of
    replaying the whole forward.
    """
    return _LAYERS[cfg.model](cfg, layer_params, x_local, x_halo, struct)


# ---------------------------------------------------------------------------
# Full forward (single subgraph)
# ---------------------------------------------------------------------------

def gnn_forward(cfg: GNNConfig, params: Pytree, x_local: jax.Array,
                halo_tables: list[jax.Array], struct: dict,
                ) -> tuple[jax.Array, list[jax.Array]]:
    """Run the L-layer GNN on one subgraph.

    Args:
      x_local: (S, in_dim) local node features.
      halo_tables: per-layer halo input tables; halo_tables[ℓ] feeds layer ℓ
        (ℓ=0 is raw halo features; ℓ≥1 are stale hidden reps of width
        hidden_dim — this is the DIGEST pull result).
      struct: ELL adjacency dict (in_nbr/in_wts/out_nbr/out_wts).
    Returns:
      (logits (S, num_classes), reps) where reps[ℓ] is the layer-(ℓ+1) input
      representation this subgraph would *push* to the stale store
      (post-activation, post-normalization hidden states, ℓ = 0..L-2).
    """
    layer_fn = _LAYERS[cfg.model]
    h = x_local
    push: list[jax.Array] = []
    for ell in range(cfg.num_layers):
        p = params[f"layer_{ell}"]
        out = layer_fn(cfg, p, h, halo_tables[ell], struct)
        h = _finish_layer(cfg, out, h, ell, push)
    return h, push


def _finish_layer(cfg: GNNConfig, out: jax.Array, h: jax.Array, ell: int,
                  push: list) -> jax.Array:
    """Post-layer tail shared by the full-batch and sampled forwards:
    relu + Algorithm-1 line-11 normalize (+ optional residual) on hidden
    layers, recording the layer's PUSH representation."""
    if ell < cfg.num_layers - 1:
        out = jax.nn.relu(out)
        if cfg.normalize:   # Algorithm 1 line 11
            out = out / jnp.maximum(
                jnp.linalg.norm(out, axis=-1, keepdims=True), 1e-12)
        if cfg.residual and out.shape == h.shape:
            out = out + h
        push.append(out)
    return out


def gnn_forward_sampled(cfg: GNNConfig, params: Pytree, x_local: jax.Array,
                        halo_tables: list, hist_tables: list, struct: dict,
                        samp: dict) -> tuple[jax.Array, list[jax.Array]]:
    """Sampled (mini-batch) L-layer forward with stale-history control
    variates — the VR-GCN estimator over DIGEST's split aggregation.

    Layer 0 aggregates in full: its "history" is the raw features, which
    are exact, so the CV estimate degenerates to the exact sum — sampling
    it would only add variance.  Hidden layers ℓ >= 1 aggregate sampled
    in-subgraph neighbors fresh and the complement from
    ``hist_tables[ℓ-1]`` (the device-local last-step representations of
    this subgraph's own rows, same (S, hidden) row space as ``x_local``);
    the out-of-subgraph side reads the pulled stale slab in
    ``halo_tables`` — history by construction — through the unchanged
    fused halo_spmm path.  ``samp`` is one subgraph's slice of a
    :class:`repro.graph.sampler.NeighborSampler` batch
    (``edge_scale``/``edge_keep``).  GAT has no additive decomposition of
    its attention scores, so it falls back to full in-batch attention
    over the sampled rows (``sampled_struct``; no control variate).

    With ``fanout >= max degree`` this reproduces :func:`gnn_forward`
    bitwise for gcn/sage (the residual weights are exactly +0.0) and to
    float tolerance for gat (identical remapped ELL).
    """
    h = x_local
    push: list[jax.Array] = []
    for ell in range(cfg.num_layers):
        p = params[f"layer_{ell}"]
        if ell == 0:
            out = _LAYERS[cfg.model](cfg, p, h, halo_tables[0], struct)
        elif cfg.model == "gat":
            out = _gat_layer(cfg, p, h, halo_tables[ell],
                             sampled_struct(struct, samp,
                                            x_local.shape[0]))
        elif cfg.model == "gcn":
            out = _gcn_layer_cv(cfg, p, h, hist_tables[ell - 1],
                                halo_tables[ell], struct, samp)
        else:
            out = _sage_layer_cv(cfg, p, h, hist_tables[ell - 1],
                                 halo_tables[ell], struct, samp)
        h = _finish_layer(cfg, out, h, ell, push)
    return h, push
