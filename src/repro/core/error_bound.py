"""Theorem-1 instrumentation: measured staleness gradient error vs. bound.

‖∇L − ∇L*‖₂ ≤ (τ/M) Σ_{ℓ=1}^{L-1} ε^(ℓ) r₁^{L-ℓ} r₂^{L-ℓ} Σ_m Δ(G_m)^{L-ℓ}

Constant estimates (documented, conservative):
  * r₁ (aggregation Φ Lipschitz): 1.0 — the GCN propagation matrix is
    symmetric-normalized, spectral norm ≤ 1, and each row is a convex-ish
    combination with weights ≤ 1.
  * r₂ (update Ψ Lipschitz): max_ℓ ‖W^(ℓ)‖₂ · C_σ with C_ReLU = 1.
  * τ (loss smoothness w.r.t. final representation): ‖W^(L)‖₂ — CE is
    1-Lipschitz-smooth in the logits; the last linear layer maps reps to
    logits.

Quantized storage adds a representation error on top of staleness:
ε_total^(ℓ) ≤ ε_stale^(ℓ) + ε_quant^(ℓ), with the explicit additive term

  * int8:  ε_quant^(ℓ) = max_v scale_v^(ℓ)/2 · √d — symmetric per-row
    quantization has per-element error ≤ scale/2, so ℓ₂ row error ≤
    scale/2·√d (max over the rows other subgraphs actually pull);
  * bf16:  ε_quant^(ℓ) = max_v ‖h_v^(ℓ)‖₂ · 2⁻⁸ — 8 significand bits
    give a relative ulp of 2⁻⁷, so round-to-nearest per-element error
    ≤ half an ulp = 2⁻⁸;
  * fp32:  0.

``measure_error_and_bound`` reports the Theorem-1 bound with the measured
ε (which silently absorbs rounding) *and* ``bound_with_quant`` built from
ε + ε_quant — the corrected bound quantized modes should be judged by.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import halo_exchange
from repro.core.digest import full_graph_forward, make_subgraph_loss
from repro.models.gnn import GNNConfig

Pytree = Any


def _tree_norm(tree: Pytree) -> float:
    return float(jnp.sqrt(sum(jnp.sum(jnp.square(l))
                              for l in jax.tree.leaves(tree))))


def _grads(cfg: GNNConfig, params: Pytree, data: dict,
           halo_cache: jax.Array) -> Pytree:
    """Mean-over-subgraphs gradient with the given halo tables."""
    loss_fn = make_subgraph_loss(cfg)
    x_local = data["x_global"][data["local_ids"]]
    x_halo0 = data["x_global"][data["halo_ids"]]

    def sub_loss(p, x_loc, x_h0, m_cache, struct, labels, mask):
        tables = [x_h0] + [m_cache[i] for i in range(cfg.num_layers - 1)]
        return loss_fn(p, x_loc, tables, struct, labels, mask)[0]

    vg = jax.vmap(jax.grad(sub_loss), in_axes=(None, 0, 0, 0, 0, 0, 0))
    g = vg(params, x_local, x_halo0, halo_cache, data["struct"],
           data["labels"], data["train_mask"])
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), g)


def fresh_halo_cache(cfg: GNNConfig, params: Pytree, data: dict
                     ) -> jax.Array:
    """Exact halo tables at current params (the ∇L* side)."""
    _, reps = full_graph_forward(cfg, params, data)
    fresh = jnp.stack([
        jnp.concatenate([r, jnp.zeros((1, r.shape[-1]), r.dtype)], 0)
        for r in reps])
    return jnp.swapaxes(fresh[:, data["halo_ids"], :], 0, 1)


def quantization_eps(store: dict, data: dict) -> np.ndarray:
    """Per-layer ε_quant^(ℓ) of the store's precision over *pulled* rows.

    int8: max served scale/2·√d; bf16: max served row norm · 2⁻⁸
    (half-ulp of the 8-bit significand); fp32: zeros.  Only rows some
    subgraph actually pulls participate (padding slots carry init values
    that would inflate the max).
    """
    precision = halo_exchange.precision_of(store)
    l1 = store["data"].shape[0]
    hv = data["halo_valid"]                                  # (M, H)
    if precision.storage == "int8":
        d = store["data"].shape[-1]
        sc = store["scale"][:, data["halo_slots"], 0]        # (L-1, M, H)
        sc = jnp.where(hv[None], sc, 0.0)
        return np.asarray(jnp.max(sc, axis=(1, 2))) / 2.0 * np.sqrt(d)
    if precision.storage == "bf16":
        rows = store["data"][:, data["halo_slots"], :].astype(jnp.float32)
        norms = jnp.linalg.norm(rows, axis=-1)               # (L-1, M, H)
        norms = jnp.where(hv[None], norms, 0.0)
        return np.asarray(jnp.max(norms, axis=(1, 2))) * 2.0 ** -8
    return np.zeros((l1,), np.float64)


def measure_error_and_bound(cfg: GNNConfig, params: Pytree, data: dict,
                            store: dict, pstore: dict = None,
                            gamma: float = 1.0) -> dict:
    """Compare the DIGEST gradient (stale halo from the compact HaloExchange
    `store`) against the exact gradient (fresh halo), and evaluate the
    Theorem-1 bound — plus its quantization-corrected form for bf16/int8
    storage.

    With a SAT predictor history (``pstore``/``gamma`` — see
    ``repro.core.predictor``) the stale side becomes the *predicted*
    rows ``dequant(store) + γ·dequant(pstore)``, so ε and the measured
    gradient error are the RESIDUAL staleness the predictor leaves
    behind; ``eps_raw`` then also reports the uncorrected ε the same
    store would serve without prediction (the Fig. 6 comparison axis).
    """
    stale_cache = halo_exchange.pull(store, data["halo_slots"])
    hv = data["halo_valid"][:, None, :]                    # (M, 1, H)
    n_valid = jnp.maximum(jnp.sum(hv), 1)
    eps_raw = eps_raw_mean = None
    if pstore is not None:
        diff_raw = jnp.linalg.norm(
            fresh_halo_cache(cfg, params, data) - stale_cache, axis=-1)
        eps_raw = np.asarray(jnp.max(diff_raw, axis=(0, 2)))
        eps_raw_mean = np.asarray(
            jnp.sum(jnp.where(hv, diff_raw, 0.0), axis=(0, 2)) / n_valid)
        stale_cache = stale_cache + (
            jnp.float32(gamma)
            * halo_exchange.pull(pstore, data["halo_slots"]))
    fresh_cache = fresh_halo_cache(cfg, params, data)

    g_stale = _grads(cfg, params, data, stale_cache)
    g_fresh = _grads(cfg, params, data, fresh_cache)
    err = _tree_norm(jax.tree.map(lambda a, b: a - b, g_stale, g_fresh))

    # ε^(ℓ): max over *used* (halo) nodes of the rep difference; the
    # valid-row mean rides along (the stable statistic the SAT bench
    # gate compares — a max is a single-row draw).
    diff = jnp.linalg.norm(fresh_cache - stale_cache, axis=-1)  # (M,L-1,H)
    eps = np.asarray(jnp.max(diff, axis=(0, 2)))                # (L-1,)
    eps_mean = np.asarray(
        jnp.sum(jnp.where(hv, diff, 0.0), axis=(0, 2)) / n_valid)
    eps_quant = quantization_eps(store, data)                   # (L-1,)

    # Lipschitz-constant estimates.
    L = cfg.num_layers
    w_norms = []
    for ell in range(L):
        p = params[f"layer_{ell}"]
        w = p.get("w", p.get("w_nbr"))
        w2 = np.linalg.norm(np.asarray(w).reshape(w.shape[0], -1), 2)
        w_norms.append(float(w2))
    r1 = 1.0
    r2 = max(w_norms)
    tau = w_norms[-1]

    # Δ(G_m): max per-node degree (in + out) within each subgraph.
    deg = (jnp.sum(data["struct"]["in_wts"] > 0, axis=-1)
           + jnp.sum(data["struct"]["out_wts"] > 0, axis=-1))   # (M, S)
    delta_m = np.asarray(jnp.max(deg, axis=-1)).astype(np.float64)  # (M,)

    M = delta_m.shape[0]

    def _bound(eps_arr: np.ndarray) -> float:
        eps_arr = np.asarray(eps_arr, np.float64)
        total = 0.0
        for ell in range(1, L):       # ℓ = 1..L-1
            power = L - ell
            total += (eps_arr[ell - 1] * (r1 * r2) ** power
                      * np.sum(delta_m ** power))
        return float(total * tau / M)

    out = {"err_measured": float(err), "bound": _bound(eps),
           "bound_with_quant": _bound(eps + eps_quant),
           "eps": eps.tolist(), "eps_mean": eps_mean.tolist(),
           "eps_quant": eps_quant.tolist(),
           "storage": halo_exchange.precision_of(store).storage,
           "r2": r2, "tau": tau,
           "delta_max": float(delta_m.max()),
           "grad_norm_fresh": _tree_norm(g_fresh)}
    if eps_raw is not None:
        out["eps_raw"] = eps_raw.tolist()
        out["eps_raw_mean"] = eps_raw_mean.tolist()
    return out
