"""Deterministic fault injection for DIGEST training and simulation.

Failure testing only pays off when a failing run can be replayed
exactly, so every fault decision here is a pure function of
``(seed, fault_class, round, worker)`` — the same counter-based design
as the PR-8 neighbor sampler: ``np.random.default_rng([seed, tag,
round, worker])`` seeds a fresh generator per decision, so decisions
are order-independent (it doesn't matter which worker's event fires
first), stable under resume (re-querying round r after a restore gives
the same answer), and independent of the engines' own RNG streams (a
zero-rate schedule perturbs nothing — trajectories stay bitwise
identical to a run with no schedule at all).

Fault classes
-------------
``crash``         worker goes down at the start of a round and is back
                  ``crash_rounds`` rounds later (restart re-fetches
                  server params; its shard's store rows freeze).
``drop_push``     a push round's wire transfer is lost.
``delay_pull``    a due pull is deferred to the next round; the worker
                  keeps computing on its last-known-good halo cache.
``corrupt_push``  the wire payload is bit-flipped in flight; the
                  receiver detects the CRC mismatch and rejects the
                  rows (observable effect = a dropped push, plus a
                  ``rejected_pushes`` count).

The SPMD epoch consumes the schedule as a per-shard boolean
``push_ok`` mask (see :meth:`FaultSchedule.push_ok`) threaded through
``state`` so the compiled program is unchanged — rows of a masked
shard route to the shard's sentinel slot inside the existing push
scatter, leaving last-known-good store contents in place.  The
DIGEST-A event simulator consumes the per-decision predicates
directly.

The paper's Theorems 1/3 bound convergence by the *staleness* of
pulled representations, which is what makes dropping a push a
degradation rather than an error: the affected rows simply age.  The
age table (``last_push_round``) keeps that extra staleness measured,
and a ``max_staleness`` watchdog turns "too stale" into a forced
resync instead of silent divergence.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Optional

import jax.numpy as jnp
import numpy as np

# Distinct integer tags keep the per-class decision streams disjoint.
_TAG_CRASH = 0x11
_TAG_DROP = 0x22
_TAG_DELAY = 0x33
_TAG_CORRUPT = 0x44


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Rates and knobs for a :class:`FaultSchedule`.

    Rates are per-(round, worker) probabilities in [0, 1].  ``enabled``
    is False when every rate is zero — engines use it to skip fault
    bookkeeping entirely, which is what makes the zero-fault parity
    guarantee trivial to uphold.
    """
    seed: int = 0
    crash_rate: float = 0.0
    crash_rounds: int = 3          # rounds a crashed worker stays down
    drop_push_rate: float = 0.0
    delay_pull_rate: float = 0.0
    corrupt_rate: float = 0.0
    retry_backoff: int = 1         # rounds before first push retry; doubles
    retry_backoff_cap: int = 8     # ... up to this many rounds

    def __post_init__(self):
        for name in ("crash_rate", "drop_push_rate", "delay_pull_rate",
                     "corrupt_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name}={v} not in [0, 1]")
        if self.crash_rounds < 1:
            raise ValueError("crash_rounds must be >= 1")
        if self.retry_backoff < 1:
            raise ValueError("retry_backoff must be >= 1")

    @property
    def enabled(self) -> bool:
        return (self.crash_rate > 0 or self.drop_push_rate > 0
                or self.delay_pull_rate > 0 or self.corrupt_rate > 0)


class FaultSchedule:
    """Counter-based fault decisions; see module docstring for design."""

    def __init__(self, config: FaultConfig):
        self.config = config

    def _hit(self, tag: int, rate: float, rnd: int, worker: int) -> bool:
        if rate <= 0.0:
            return False
        rng = np.random.default_rng(
            [int(self.config.seed), tag, int(rnd), int(worker)])
        return bool(rng.random() < rate)

    def crashes(self, rnd: int, worker: int) -> bool:
        return self._hit(_TAG_CRASH, self.config.crash_rate, rnd, worker)

    def drops_push(self, rnd: int, worker: int) -> bool:
        return self._hit(_TAG_DROP, self.config.drop_push_rate, rnd, worker)

    def delays_pull(self, rnd: int, worker: int) -> bool:
        return self._hit(_TAG_DELAY, self.config.delay_pull_rate, rnd, worker)

    def corrupts_push(self, rnd: int, worker: int) -> bool:
        return self._hit(_TAG_CORRUPT, self.config.corrupt_rate, rnd, worker)

    def down(self, rnd: int, worker: int) -> bool:
        """True if a crash at any round in (rnd - crash_rounds, rnd]
        leaves the worker still restarting at round ``rnd``."""
        k = self.config.crash_rounds
        return any(self.crashes(c, worker)
                   for c in range(max(1, rnd - k + 1), rnd + 1))

    def push_ok(self, rnd: int, num_parts: int) -> np.ndarray:
        """(num_parts,) bool mask for the SPMD epoch's push at round
        ``rnd``: False where the shard's push is lost this round —
        dropped, corrupted-and-rejected, or owned by a worker inside
        its crash window.  Host-side; the epoch consumes it as a
        ``state["push_ok"]`` leaf so the compiled program is fixed."""
        ok = np.ones(num_parts, dtype=bool)
        for m in range(num_parts):
            if (self.drops_push(rnd, m) or self.corrupts_push(rnd, m)
                    or self.down(rnd, m)):
                ok[m] = False
        return ok


def attach_fault_state(state: dict, num_parts: int) -> dict:
    """Add the fault-aware leaves the SPMD epoch threads through
    ``state``: the per-shard ``push_ok`` mask (refreshed host-side
    every round via ``FaultSchedule.push_ok``) and the per-shard
    ``last_push_round`` age table feeding the staleness probe and the
    ``max_staleness`` watchdog.  Without these keys ``_digest_push``
    compiles the exact pre-fault program."""
    state = dict(state)
    state["push_ok"] = jnp.ones((num_parts,), dtype=bool)
    state["last_push_round"] = jnp.zeros((num_parts,), dtype=jnp.int32)
    return state


def wire_crc32(rows: np.ndarray) -> int:
    """Checksum of a wire payload (quantized push rows), as the
    receiver would compute it before accepting the scatter."""
    return zlib.crc32(np.ascontiguousarray(rows).tobytes()) & 0xFFFFFFFF


def corrupt_rows(rows: np.ndarray, seed: int, rnd: int,
                 worker: int) -> np.ndarray:
    """Deterministically bit-flip one byte of a wire payload — the
    in-flight corruption that the receiver's CRC check must catch."""
    buf = np.ascontiguousarray(rows).copy()
    raw = buf.view(np.uint8).reshape(-1)
    if raw.size == 0:
        return buf
    rng = np.random.default_rng([int(seed), _TAG_CORRUPT, int(rnd),
                                 int(worker), 0x5A])
    pos = int(rng.integers(raw.size))
    raw[pos] ^= np.uint8(1 << int(rng.integers(8)))
    return buf


def measured_staleness(last_push_round, rnd) -> jnp.ndarray:
    """Max age (rounds since last successful push) across shards — the
    fault-induced component of the Theorem-1 staleness the probe
    reports."""
    return jnp.max(jnp.asarray(rnd, jnp.int32)
                   - jnp.asarray(last_push_round, jnp.int32))


def check_schedule(schedule: Optional[FaultSchedule]) -> Optional[FaultSchedule]:
    """Normalize: None, a disabled schedule → None; else the schedule."""
    if schedule is None:
        return None
    if isinstance(schedule, FaultConfig):
        schedule = FaultSchedule(schedule)
    return schedule if schedule.config.enabled else None
