"""minitron-8b [dense] — width-pruned Nemotron-4.

[arXiv:2407.14679] 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
"""
import dataclasses
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=16384, vocab_size=256000, head_dim=128,
    pattern=("attn",), rope_theta=500000.0,
    optimizer="adafactor", learning_rate=2e-4,
    source="arXiv:2407.14679",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=32, dtype="float32",
    optimizer="adamw")
