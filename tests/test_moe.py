"""MoE: dropless equivalence, capacity behaviour, load-balance loss."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.moe import load_balance_loss, moe_ep, moe_ref


def _params(rng, d, e, ff):
    return {"router": jnp.asarray(rng.normal(size=(d, e)), jnp.float32),
            "w_gate": jnp.asarray(rng.normal(size=(e, d, ff)) * 0.1,
                                  jnp.float32),
            "w_up": jnp.asarray(rng.normal(size=(e, d, ff)) * 0.1,
                                jnp.float32),
            "w_down": jnp.asarray(rng.normal(size=(e, ff, d)) * 0.1,
                                  jnp.float32)}


@settings(max_examples=10, deadline=None)
@given(e=st.sampled_from([2, 4, 8]), k=st.integers(1, 3),
       seed=st.integers(0, 100))
def test_capacity_path_matches_dropless(e, k, seed):
    if k > e:
        k = e
    rng = np.random.default_rng(seed)
    params = _params(rng, 16, e, 32)
    x = jnp.asarray(rng.normal(size=(2, 8, 16)), jnp.float32)
    ref = moe_ref(x, params, k)
    out = moe_ep(x, params, k, capacity_factor=float(e))
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


def test_low_capacity_drops_but_stays_close():
    rng = np.random.default_rng(0)
    params = _params(rng, 32, 4, 64)
    x = jnp.asarray(rng.normal(size=(4, 32, 32)), jnp.float32)
    ref = moe_ref(x, params, 2)
    out = moe_ep(x, params, 2, capacity_factor=1.25)
    corr = float(jnp.corrcoef(out.reshape(-1), ref.reshape(-1))[0, 1])
    assert corr > 0.9


def test_gradients_flow_to_router_and_experts():
    rng = np.random.default_rng(1)
    params = _params(rng, 16, 4, 32)
    x = jnp.asarray(rng.normal(size=(2, 8, 16)), jnp.float32)
    g = jax.grad(lambda p: jnp.sum(moe_ep(x, p, 2,
                                          capacity_factor=4.0) ** 2))(params)
    for key in ("router", "w_gate", "w_up", "w_down"):
        assert float(jnp.abs(g[key]).max()) > 0, key


def test_load_balance_loss_prefers_uniform():
    e = 4
    t = 1000
    rng = np.random.default_rng(0)
    uniform_logits = jnp.asarray(rng.normal(size=(t, e)) * 0.01)
    skewed_logits = uniform_logits.at[:, 0].add(10.0)
    ids_u = jnp.argmax(uniform_logits, axis=-1)[:, None].astype(jnp.int32)
    ids_s = jnp.argmax(skewed_logits, axis=-1)[:, None].astype(jnp.int32)
    lu = float(load_balance_loss(uniform_logits, ids_u, e))
    ls = float(load_balance_loss(skewed_logits, ids_s, e))
    assert ls > lu
    assert abs(lu - 1.0) < 0.2     # E·Σ f·p ≈ 1 at uniform
