"""Pallas flash attention kernel vs dense oracle."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import (attention_ref,
                                           flash_attention_pallas,
                                           multi_head_attention)


def _qkv(rng, bh, seq, hd, dtype=np.float32):
    q = rng.normal(size=(bh, seq, hd)).astype(dtype)
    k = rng.normal(size=(bh, seq, hd)).astype(dtype)
    v = rng.normal(size=(bh, seq, hd)).astype(dtype)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)


@pytest.mark.parametrize("seq,hd,bq,bk", [
    (128, 64, 128, 128), (256, 64, 128, 64), (256, 128, 64, 128),
    (512, 32, 128, 128),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(seq, hd, bq, bk, causal):
    rng = np.random.default_rng(seq + hd)
    q, k, v = _qkv(rng, 2, seq, hd)
    out = flash_attention_pallas(q, k, v, causal=causal, block_q=bq,
                                 block_k=bk, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(seq_blocks=st.integers(1, 4), hd=st.sampled_from([32, 64, 128]),
       seed=st.integers(0, 2**31 - 1))
def test_flash_property(seq_blocks, hd, seed):
    rng = np.random.default_rng(seed)
    seq = 128 * seq_blocks
    q, k, v = _qkv(rng, 1, seq, hd)
    out = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)


def test_gqa_wrapper():
    rng = np.random.default_rng(3)
    b, s, h, d, kv = 2, 128, 8, 32, 2
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    o1 = multi_head_attention(q, k, v, backend="jnp")
    o2 = multi_head_attention(q, k, v, backend="pallas_interpret")
    np.testing.assert_allclose(o1, o2, atol=2e-5, rtol=2e-5)


def test_chunked_attention_matches_dense():
    from repro.models.attention import chunked_attention
    rng = np.random.default_rng(5)
    b, s, h, d, kv = 2, 192, 4, 32, 2
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    out = chunked_attention(q, k, v, causal=True, chunk=64)
    ref = multi_head_attention(q, k, v, causal=True, backend="jnp")
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_sliding_window_chunked():
    """window=W must equal dense attention with a banded mask."""
    import jax
    from repro.models.attention import chunked_attention
    rng = np.random.default_rng(6)
    b, s, h, d, w = 1, 128, 2, 16, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    out = chunked_attention(q, k, v, causal=True, window=w, chunk=32)
    # dense banded oracle
    s_mat = jnp.einsum("bqhd,bkhd->bhqk", q, k) * (d ** -0.5)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = (qpos >= kpos) & (qpos - kpos < w)
    s_mat = jnp.where(mask[None, None], s_mat, -1e30)
    p = jax.nn.softmax(s_mat, axis=-1)
    ref = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)
